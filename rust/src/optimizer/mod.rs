//! Execution-plan optimizers — the schemes compared throughout §4:
//!
//! | scheme                | objective      | controls      | module |
//! |-----------------------|----------------|---------------|--------|
//! | uniform               | none (eq 15/16)| —             | [`uniform`] |
//! | myopic multi-phase    | phase times    | push + shuffle| [`myopic`] |
//! | e2e single-phase push | makespan       | push only     | [`single_phase`] |
//! | e2e single-phase shuf | makespan       | shuffle only  | [`single_phase`] |
//! | e2e multi-phase       | makespan       | push + shuffle| [`alternating`] (LP), [`mip_opt`] (PWL-MIP), [`gradient`] (JAX/PJRT) |

pub mod alternating;
pub mod gradient;
pub mod lp_build;
pub mod mip_opt;
pub mod myopic;
pub mod single_phase;
pub mod uniform;

use crate::model::barrier::BarrierConfig;
use crate::model::makespan::AppModel;
use crate::model::plan::Plan;
use crate::platform::Topology;

/// A plan optimizer: produces a valid execution plan for an instance.
pub trait PlanOptimizer {
    fn name(&self) -> &'static str;
    fn optimize(&self, topo: &Topology, app: AppModel, cfg: BarrierConfig) -> Plan;
}

pub use alternating::AlternatingLp;
pub use gradient::GradientOptimizer;
pub use lp_build::Objective;
pub use mip_opt::PwlMipOptimizer;
pub use myopic::Myopic;
pub use single_phase::{E2ePush, E2eShuffle};
pub use uniform::Uniform;
