//! Execution-plan optimizers — the schemes compared throughout §4:
//!
//! | scheme                | objective      | controls      | module |
//! |-----------------------|----------------|---------------|--------|
//! | uniform               | none (eq 15/16)| —             | [`uniform`] |
//! | myopic multi-phase    | phase times    | push + shuffle| [`myopic`] |
//! | e2e single-phase push | makespan       | push only     | [`single_phase`] |
//! | e2e single-phase shuf | makespan       | shuffle only  | [`single_phase`] |
//! | e2e multi-phase       | makespan       | push + shuffle| [`alternating`] (LP), [`mip_opt`] (PWL-MIP), [`gradient`] (analytic / finite-diff / JAX-PJRT) |
//! | e2e hedged            | expected makespan under failures | push + shuffle | [`hedged`] (failure-discounted alternating LP) |
//! | mid-run replanner     | makespan on the *effective* platform | push + shuffle | [`replanner`] (short warm-started descent; see `engine::replan`) |
//!
//! ## Scale paths (256-node plans in seconds)
//!
//! Both end-to-end multi-phase optimizers run a layered fast path on
//! large generated topologies; every layer is exact. Aggregation, the
//! sparse solver dispatch and start capping are inert at paper scale
//! (8×8×8), which keeps the historical code path there; the [`lp_build`]
//! reformulation applies at every scale — it preserves the optimal
//! objective exactly, though a degenerate LP may surface a different
//! optimal vertex than the pre-reformulation build:
//!
//! * [`aggregate`] — identical-node symmetry quotient (≥32 nodes): a
//!   `hier-wan:256` instance optimizes over ~22 distinct node kinds per
//!   role instead of ~85 raw nodes, then expands the plan back with
//!   exactly the same makespan.
//! * [`lp_build`] — explicit `load_j` variables factor the repeated
//!   `Σ_i D_i·x_ij` subexpression (3-term instead of (s+2)-term epigraph
//!   rows), and dominated epigraph rows (constant-rhs reduce rows,
//!   zero-share shuffle rows, Pareto-dominated y-LP rows) are pruned at
//!   build time.
//! * [`crate::solver::revised`] — sparse revised simplex (CSC matrix +
//!   product-form inverse) takes LPs above
//!   [`crate::solver::DENSE_ROW_CUTOVER`] rows; [`alternating`] re-feeds
//!   each round's basis as a warm start. The dense tableau remains the
//!   small-problem path and cross-check oracle.
//! * [`gradient`] — analytic reverse-mode gradients
//!   ([`crate::model::smooth::smooth_makespan_grad`]) replace the
//!   `O(S·M + R)` finite-difference evaluations per step with one
//!   forward+backward pass, so the pure-rust path (no `pjrt`) is fast.
//!
//! Measured on `hier-wan:64` (see `optimizer/scale_*` in
//! `benches/bench_main.rs`, which asserts ≥10×): both paths land two to
//! three orders of magnitude under the pre-optimization code, and both
//! produce valid 256-node plans in well under the 30 s acceptance bound.

pub mod aggregate;
pub mod alternating;
pub mod gradient;
pub mod hedged;
pub mod lp_build;
pub mod mip_opt;
pub mod myopic;
pub mod perf;
pub mod replanner;
pub mod single_phase;
pub mod uniform;

use crate::model::barrier::BarrierConfig;
use crate::model::makespan::AppModel;
use crate::model::plan::Plan;
use crate::platform::Topology;

/// A plan optimizer: produces a valid execution plan for an instance.
pub trait PlanOptimizer {
    fn name(&self) -> &'static str;
    fn optimize(&self, topo: &Topology, app: AppModel, cfg: BarrierConfig) -> Plan;
}

/// Diagnose a solver-failure fallback (the heuristic-degrade paths in
/// [`myopic`]/[`single_phase`]): silent by default — the schemes still
/// produce valid plans — but visible under `MRPERF_LP_DEBUG` so a table
/// quietly built on fallback plans can be detected.
pub(crate) fn warn_lp_fallback(what: &str, fallback: &str) {
    if std::env::var("MRPERF_LP_DEBUG").is_ok() {
        eprintln!("[optimizer] {what} had no usable LP solution; using {fallback}");
    }
}

pub use alternating::AlternatingLp;
pub use gradient::{AnalyticBackend, FiniteDiffBackend, GradientOptimizer};
pub use hedged::FailureAwareOptimizer;
pub use lp_build::Objective;
pub use mip_opt::PwlMipOptimizer;
pub use myopic::Myopic;
pub use replanner::Replanner;
pub use single_phase::{E2ePush, E2eShuffle};
pub use uniform::Uniform;
