//! End-to-end *single-phase* optimizers (§4.3): control the data placement
//! of exactly one communication phase — push or shuffle — while the other
//! phase stays uniform (eq 15 or 16). Both minimize total *makespan* (they
//! are end-to-end, unlike [`super::myopic`]); what they lack is control of
//! both phases, which is what Fig 6 quantifies.

use super::lp_build::{build_lp_x, build_lp_y, extract_x, extract_y, Objective};
use super::PlanOptimizer;
use crate::model::barrier::BarrierConfig;
use crate::model::makespan::AppModel;
use crate::model::plan::Plan;
use crate::platform::Topology;
use crate::solver::solve_robust as solve;
use crate::util::mat::Mat;

/// e2e push: optimize `x`, uniform shuffle (`y = 1/|R|`).
#[derive(Debug, Clone, Copy, Default)]
pub struct E2ePush;

impl PlanOptimizer for E2ePush {
    fn name(&self) -> &'static str {
        "e2e-push"
    }

    fn optimize(&self, topo: &Topology, app: AppModel, cfg: BarrierConfig) -> Plan {
        let r = topo.n_reducers();
        let y = vec![1.0 / r as f64; r];
        let (lp, vars) = build_lp_x(topo, app, cfg, &y, Objective::Makespan);
        // Degrade to the local-push heuristic (which keeps the uniform
        // shuffle this scheme fixes) if the solver fails numerically.
        let mut plan = match solve(&lp).optimal() {
            Some((sol, _)) => Plan { x: extract_x(&sol, &vars), y },
            None => {
                super::warn_lp_fallback("e2e push LP", "local-push heuristic");
                let mut p = Plan::local_push(topo);
                p.y = y;
                p
            }
        };
        plan.renormalize();
        plan
    }
}

/// e2e shuffle: uniform push (`x = 1/|M|`), optimize `y`.
#[derive(Debug, Clone, Copy, Default)]
pub struct E2eShuffle;

impl PlanOptimizer for E2eShuffle {
    fn name(&self) -> &'static str {
        "e2e-shuffle"
    }

    fn optimize(&self, topo: &Topology, app: AppModel, cfg: BarrierConfig) -> Plan {
        let (s, m) = (topo.n_sources(), topo.n_mappers());
        let x = Mat::filled(s, m, 1.0 / m as f64);
        let (lp, vars) = build_lp_y(topo, app, cfg, &x, Objective::Makespan);
        // Degrade to the fully uniform plan if the solver fails.
        let r = topo.n_reducers();
        let y = match solve(&lp).optimal() {
            Some((sol, _)) => extract_y(&sol, &vars),
            None => {
                super::warn_lp_fallback("e2e shuffle LP", "uniform shuffle");
                vec![1.0 / r as f64; r]
            }
        };
        let mut plan = Plan { x, y };
        plan.renormalize();
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::makespan::makespan;
    use crate::optimizer::uniform::Uniform;
    use crate::platform::{build_env, EnvKind};

    #[test]
    fn single_phase_beats_uniform() {
        let t = build_env(EnvKind::Global8);
        let cfg = BarrierConfig::ALL_GLOBAL;
        for &alpha in &[0.1, 1.0, 10.0] {
            let app = AppModel::new(alpha);
            let uni = makespan(&t, app, cfg, &Uniform.optimize(&t, app, cfg));
            let push = E2ePush.optimize(&t, app, cfg);
            push.check(&t).unwrap();
            let shuf = E2eShuffle.optimize(&t, app, cfg);
            shuf.check(&t).unwrap();
            assert!(makespan(&t, app, cfg, &push) <= uni + 1e-6, "α={alpha} push");
            assert!(makespan(&t, app, cfg, &shuf) <= uni + 1e-6, "α={alpha} shuffle");
        }
    }

    #[test]
    fn push_opt_keeps_uniform_shuffle_and_vice_versa() {
        let t = build_env(EnvKind::Global4);
        let app = AppModel::new(1.0);
        let cfg = BarrierConfig::ALL_GLOBAL;
        let p = E2ePush.optimize(&t, app, cfg);
        assert!(p.y.iter().all(|&v| (v - 0.125).abs() < 1e-9));
        let s = E2eShuffle.optimize(&t, app, cfg);
        for i in 0..8 {
            for j in 0..8 {
                assert!((s.x.get(i, j) - 0.125).abs() < 1e-9);
            }
        }
    }

    /// §4.3's bottleneck observation: at α=0.1 push optimization helps
    /// more than shuffle optimization; at α=10 the reverse.
    #[test]
    fn bottleneck_phase_gets_bigger_benefit() {
        let t = build_env(EnvKind::Global8);
        let cfg = BarrierConfig::ALL_GLOBAL;

        let app = AppModel::new(0.1);
        let uni = makespan(&t, app, cfg, &Plan::uniform(8, 8, 8));
        let push01 = makespan(&t, app, cfg, &E2ePush.optimize(&t, app, cfg));
        let shuf01 = makespan(&t, app, cfg, &E2eShuffle.optimize(&t, app, cfg));
        assert!(push01 < shuf01, "α=0.1: push opt {push01} should beat shuffle opt {shuf01} (uniform {uni})");

        // At α=10 the shuffle/reduce phases dominate. Controlling either
        // phase attacks them (push placement also shapes shuffle volume —
        // §4.3's observation that "optimizing earlier phases can have a
        // beneficial impact on the performance of the later phases"), so
        // we only require that shuffle optimization is genuinely useful:
        // a large improvement over uniform.
        let app = AppModel::new(10.0);
        let uni10 = makespan(&t, app, cfg, &Plan::uniform(8, 8, 8));
        let shuf10 = makespan(&t, app, cfg, &E2eShuffle.optimize(&t, app, cfg));
        assert!(
            shuf10 < 0.7 * uni10,
            "α=10: shuffle opt {shuf10} should improve ≥30% on uniform {uni10}"
        );
    }
}
