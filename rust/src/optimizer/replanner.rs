//! Warm-started mid-run re-optimization (the solver side of
//! [`crate::engine::replan`]).
//!
//! A replan is not a fresh planning problem: the platform moved a
//! little, the plan should move a little. So instead of the full
//! multi-start [`super::AlternatingLp`] search (pre-screen + one-hot
//! consolidation starts), [`Replanner`] runs a *short* alternating
//! descent seeded from the **currently executing** shuffle split, and —
//! crucially — carries the revised-simplex bases **across replans**:
//! consecutive effective platforms differ in a handful of coefficients,
//! so the second-and-later re-solves are a few warm pivots instead of a
//! cold solve (pinned by tests/replan.rs against
//! [`crate::solver::hot_path_counters`]). The bases round-trip through
//! snapshots (see [`crate::engine::replan::ReplanState`]) so a resumed
//! run re-solves from the same vertex and stays bit-identical.

use super::lp_build::{build_lp_x, build_lp_y, extract_x, extract_y, Objective};
use crate::model::barrier::BarrierConfig;
use crate::model::makespan::{makespan, AppModel};
use crate::model::plan::Plan;
use crate::platform::Topology;
use crate::solver::{solve_smart, Lp, LpOutcome};

/// Short warm-started alternating descent for mid-run re-solves. The
/// x/y bases persist across [`Replanner::replan`] calls — that is the
/// whole point of the type.
#[derive(Debug, Clone, PartialEq)]
pub struct Replanner {
    /// Maximum x/y alternations per replan (short on purpose: the seed
    /// split is the incumbent plan, already near-optimal for a platform
    /// one event ago).
    pub rounds: usize,
    /// Relative improvement below which the descent is converged.
    pub tol: f64,
    /// Warm-start basis for the x-step LP, carried across replans.
    /// `None` until the first sparse solve (small instances stay on the
    /// dense path, which neither uses nor produces bases).
    pub x_basis: Option<Vec<usize>>,
    /// Warm-start basis for the y-step LP, carried across replans.
    pub y_basis: Option<Vec<usize>>,
}

impl Default for Replanner {
    fn default() -> Self {
        Replanner { rounds: 3, tol: 1e-6, x_basis: None, y_basis: None }
    }
}

impl Replanner {
    /// One warm LP solve; the basis slot is refreshed with whatever the
    /// solver hands back (the dense path hands back `None`).
    fn solve_step(lp: &Lp, basis: &mut Option<Vec<usize>>) -> LpOutcome {
        let (out, next) = solve_smart(lp, basis.as_deref());
        *basis = next;
        out
    }

    /// Re-solve the plan for the (effective) platform `topo`, descending
    /// from the currently executing shuffle split `y0`. Returns `None`
    /// when no LP of the descent produces a usable solution — the caller
    /// keeps the incumbent plan and counts a skip; a degenerate
    /// effective platform must never tear down a running job.
    pub fn replan(
        &mut self,
        topo: &Topology,
        app: AppModel,
        cfg: BarrierConfig,
        y0: &[f64],
    ) -> Option<Plan> {
        // Guard the seed: the executing split is a probability vector by
        // construction, but a failed-reducer discount upstream may have
        // zeroed mass. Renormalize; fall back to uniform if empty.
        let r = topo.n_reducers();
        debug_assert_eq!(y0.len(), r);
        let s: f64 = y0.iter().filter(|v| v.is_finite() && **v > 0.0).sum();
        let mut y: Vec<f64> = if s > 0.0 {
            y0.iter().map(|v| if v.is_finite() && *v > 0.0 { v / s } else { 0.0 }).collect()
        } else {
            vec![1.0 / r as f64; r]
        };

        let mut best: Option<(Plan, f64)> = None;
        for _round in 0..self.rounds {
            // x-step: optimal push for the current split.
            let (lp, vars) = build_lp_x(topo, app, cfg, &y, Objective::Makespan);
            let sol = match Self::solve_step(&lp, &mut self.x_basis).optimal() {
                Some((sol, _)) => sol,
                None => break,
            };
            let x = {
                let mut p = Plan { x: extract_x(&sol, &vars), y: y.clone() };
                p.renormalize();
                p.x
            };

            // y-step: optimal shuffle split for that push.
            let (lp, vars) = build_lp_y(topo, app, cfg, &x, Objective::Makespan);
            let sol = match Self::solve_step(&lp, &mut self.y_basis).optimal() {
                Some((sol, _)) => sol,
                None => break,
            };
            let mut candidate = Plan { x, y: extract_y(&sol, &vars) };
            candidate.renormalize();
            y = candidate.y.clone();
            let ms = makespan(topo, app, cfg, &candidate);
            let done = match &best {
                Some((_, b)) => ms >= b * (1.0 - self.tol),
                None => false,
            };
            if best.as_ref().map_or(true, |(_, b)| ms < *b) {
                best = Some((candidate, ms));
            }
            if done {
                break;
            }
        }
        best.map(|(plan, _)| plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::scale::{generate_kind, ScaleKind};
    use crate::platform::{build_env, EnvKind};

    #[test]
    fn replan_returns_a_valid_plan_from_any_seed() {
        let topo = build_env(EnvKind::Global8);
        let app = AppModel::new(2.0);
        let cfg = BarrierConfig::HADOOP;
        for y0 in [
            vec![1.0 / 8.0; 8],
            {
                let mut y = vec![0.0; 8];
                y[3] = 1.0;
                y
            },
            vec![0.0; 8], // degenerate: all mass discounted away
        ] {
            let mut rp = Replanner::default();
            let plan = rp.replan(&topo, app, cfg, &y0).expect("solvable");
            plan.check(&topo).unwrap();
        }
    }

    #[test]
    fn replan_improves_on_a_bad_seed() {
        // Seed the descent with the worst one-hot split; the re-solved
        // plan must not be worse than the plain seed plan.
        let topo = build_env(EnvKind::Global8);
        let app = AppModel::new(2.0);
        let cfg = BarrierConfig::HADOOP;
        let mut y0 = vec![0.0; 8];
        y0[0] = 1.0;
        let seeded = {
            let mut p = Plan::uniform(topo.n_sources(), topo.n_mappers(), 8);
            p.y = y0.clone();
            p.renormalize();
            p
        };
        let seed_ms = makespan(&topo, app, cfg, &seeded);
        let mut rp = Replanner::default();
        let plan = rp.replan(&topo, app, cfg, &y0).expect("solvable");
        let ms = makespan(&topo, app, cfg, &plan);
        assert!(ms <= seed_ms + 1e-6, "replan {ms} vs seed {seed_ms}");
    }

    #[test]
    fn replan_is_deterministic_and_populates_bases_at_scale() {
        // 64-node hier-wan LPs are above DENSE_ROW_CUTOVER: the sparse
        // path runs and hands back bases for the next replan.
        let topo = generate_kind(ScaleKind::HierarchicalWan, 64, 7);
        let app = AppModel::new(1.0);
        let cfg = BarrierConfig::HADOOP;
        let y0 = vec![1.0 / topo.n_reducers() as f64; topo.n_reducers()];
        let mut a = Replanner::default();
        let mut b = Replanner::default();
        let pa = a.replan(&topo, app, cfg, &y0).expect("solvable");
        let pb = b.replan(&topo, app, cfg, &y0).expect("solvable");
        assert_eq!(pa, pb);
        assert_eq!(a, b, "bases must evolve deterministically");
        assert!(a.x_basis.is_some() && a.y_basis.is_some(), "sparse path must run at 64 nodes");
        // Second replan on a perturbed platform reuses them.
        let mut t2 = topo.clone();
        for j in 0..t2.n_mappers() {
            for k in 0..t2.n_reducers() {
                t2.b_mr.set(j, k, t2.b_mr.get(j, k) * 0.9);
            }
        }
        let p2 = a.replan(&t2, app, cfg, &pa.y).expect("solvable");
        p2.check(&t2).unwrap();
    }
}
