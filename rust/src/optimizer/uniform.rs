//! The uniform baseline (eqs 15–16): no optimization at all. Every source
//! spreads its data evenly over all mappers; the intermediate key space is
//! split evenly over all reducers. This is (approximately) what vanilla
//! Hadoop's hash partitioner does, and the normalization baseline of
//! Figs 5, 6 and 8.

use super::PlanOptimizer;
use crate::model::barrier::BarrierConfig;
use crate::model::makespan::AppModel;
use crate::model::plan::Plan;
use crate::platform::Topology;

#[derive(Debug, Clone, Copy, Default)]
pub struct Uniform;

impl PlanOptimizer for Uniform {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn optimize(&self, topo: &Topology, _app: AppModel, _cfg: BarrierConfig) -> Plan {
        Plan::uniform(topo.n_sources(), topo.n_mappers(), topo.n_reducers())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{build_env, EnvKind};

    #[test]
    fn uniform_plan_valid_on_all_envs() {
        for kind in EnvKind::all() {
            let t = build_env(kind);
            let p = Uniform.optimize(&t, AppModel::new(1.0), BarrierConfig::ALL_GLOBAL);
            p.check(&t).unwrap();
        }
    }
}
