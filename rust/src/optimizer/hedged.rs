//! Failure-aware end-to-end optimization: plans that hedge the push and
//! shuffle split against an expected reducer failure rate.
//!
//! The paper's end-to-end plans assume reducers never die — so the
//! optimum freely concentrates the key space on the best-provisioned,
//! best-connected reducers, which is exactly the plan a single reducer
//! outage hurts most: under strict plan enforcement the orphaned key
//! range waits for recovery and its whole input is replayed
//! (`engine::dynamics` reducer-failure lifecycle). Geo-distributed
//! deployments make this the dominant robustness gap (arXiv:1707.01869),
//! and communication-aware placement of reduce work is where the replay
//! bytes are won or lost (Meta-MapReduce, arXiv:1508.01171).
//!
//! [`FailureAwareOptimizer`] wraps any [`PlanOptimizer`] and re-solves it
//! against a *failure-discounted* platform, then mixes the resulting
//! shuffle split toward uniform:
//!
//! 1. **Per-reducer capacity discounting** — every reducer is available
//!    only a `(1 − rate)` fraction of the time, so its effective compute
//!    capacity is `c_red · (1 − rate)`.
//! 2. **Replay-cost term** — in expectation a `rate` fraction of each
//!    reducer's shuffle bytes crosses the network twice (lost to a
//!    failure, replayed from the mappers), so the effective mapper→
//!    reducer bandwidth is `b_mr / (1 + rate)`. Both terms inflate the
//!    shuffle/reduce phase times in the alternating LPs relative to the
//!    (failure-free) push/map constants, which provably spreads the
//!    optimal `y` over more reducers: as the `y`-coefficients grow
//!    relative to the constant terms, the epigraph optimum moves from a
//!    few concentrated reducers toward the inverse-cost split.
//! 3. **Uniform insurance mix** — the solved split is blended as
//!    `y ← (1 − rate)·y* + rate/|R|`: against an adversary that may take
//!    down *any* reducer with probability `rate`, mixing with uniform
//!    bounds the key-range mass a single outage can strand (the classic
//!    hedge of smooth fictitious play). A final x-step LP re-optimizes
//!    the push fractions for the blended split on the discounted
//!    platform.
//!
//! With `rate = 0` the wrapper returns the inner optimizer's plan
//! unchanged — bit-identical, property-tested in
//! tests/optimizer_hedge.rs — so hedging is strictly opt-in
//! (`mrperf run … --hedge RATE`, `mrperf experiment churn … --hedge`).
//!
//! # Example
//!
//! ```
//! use mrperf::model::barrier::BarrierConfig;
//! use mrperf::model::makespan::AppModel;
//! use mrperf::optimizer::{AlternatingLp, FailureAwareOptimizer, PlanOptimizer};
//! use mrperf::platform::{build_env, EnvKind};
//!
//! let topo = build_env(EnvKind::Global4);
//! let (app, cfg) = (AppModel::new(1.0), BarrierConfig::HADOOP);
//!
//! // Rate 0 is bit-identical to the unhedged optimizer …
//! let plain = AlternatingLp::default().optimize(&topo, app, cfg);
//! let zero = FailureAwareOptimizer::new(0.0).optimize(&topo, app, cfg);
//! assert_eq!(zero, plain);
//!
//! // … while a positive rate floors every reducer's share at rate/|R|
//! // (the uniform insurance mix bounding strandable key-range mass).
//! let rate = 0.2;
//! let hedged = FailureAwareOptimizer::new(rate).optimize(&topo, app, cfg);
//! let floor = rate / topo.n_reducers() as f64;
//! assert!(hedged.y.iter().all(|&y| y >= floor - 1e-9));
//! ```

use super::lp_build::{build_lp_x, extract_x, Objective};
use super::{AlternatingLp, PlanOptimizer};
use crate::model::barrier::BarrierConfig;
use crate::model::makespan::AppModel;
use crate::model::plan::Plan;
use crate::platform::Topology;
use crate::solver::solve_smart;

/// Validate a hedge rate: finite and in `[0, 1)`. The single source of
/// truth for the accepted range — the CLI, the churn matrix and this
/// module's asserts all go through it, so they can never drift apart.
pub fn validate_hedge(rate: f64) -> Result<(), String> {
    if rate.is_finite() && (0.0..1.0).contains(&rate) {
        Ok(())
    } else {
        Err(format!("hedge rate must be in [0, 1), got {rate}"))
    }
}

/// Wraps a plan optimizer with failure-aware capacity discounting, a
/// replay-cost term and a uniform insurance mix (see the module docs).
#[derive(Debug, Clone, Copy)]
pub struct FailureAwareOptimizer<O = AlternatingLp> {
    pub inner: O,
    /// Expected per-reducer unavailability, in `[0, 1)`. `0` delegates to
    /// the inner optimizer untouched.
    pub rate: f64,
}

impl FailureAwareOptimizer<AlternatingLp> {
    /// Hedge the default end-to-end multi-phase optimizer.
    pub fn new(rate: f64) -> FailureAwareOptimizer<AlternatingLp> {
        FailureAwareOptimizer::wrap(AlternatingLp::default(), rate)
    }
}

impl<O: PlanOptimizer> FailureAwareOptimizer<O> {
    pub fn wrap(inner: O, rate: f64) -> FailureAwareOptimizer<O> {
        validate_hedge(rate).unwrap_or_else(|e| panic!("{e}"));
        FailureAwareOptimizer { inner, rate }
    }
}

/// The failure-discounted platform a hedged optimizer plans against:
/// reducer capacities scaled by `1 − rate` (availability), mapper→reducer
/// bandwidths by `1 / (1 + rate)` (expected replay traffic). Sources,
/// mappers and push links are untouched — mapper recovery has existed
/// since the dynamics layer landed and is already priced by the engine.
pub fn discount_topology(topo: &Topology, rate: f64) -> Topology {
    validate_hedge(rate).unwrap_or_else(|e| panic!("{e}"));
    let mut t = topo.clone();
    for c in t.c_red.iter_mut() {
        *c *= 1.0 - rate;
    }
    let (m, r) = (t.n_mappers(), t.n_reducers());
    for j in 0..m {
        for k in 0..r {
            let b = t.b_mr.get(j, k);
            t.b_mr.set(j, k, b / (1.0 + rate));
        }
    }
    t
}

impl<O: PlanOptimizer> PlanOptimizer for FailureAwareOptimizer<O> {
    fn name(&self) -> &'static str {
        "e2e-hedged"
    }

    fn optimize(&self, topo: &Topology, app: AppModel, cfg: BarrierConfig) -> Plan {
        if self.rate == 0.0 {
            // Bit-identical to the unhedged optimizer by construction.
            return self.inner.optimize(topo, app, cfg);
        }
        let hedged = discount_topology(topo, self.rate);
        let base = self.inner.optimize(&hedged, app, cfg);

        // Uniform insurance mix: bound the mass any single outage can
        // strand. Every reducer ends up with at least rate/|R|.
        let r = topo.n_reducers();
        let y: Vec<f64> =
            base.y.iter().map(|v| (1.0 - self.rate) * v + self.rate / r as f64).collect();

        // Final x-step: the optimal push for the blended split on the
        // discounted platform (one more round of the alternating LP). A
        // numerically hopeless LP keeps the inner optimizer's x.
        let (lp, vars) = build_lp_x(&hedged, app, cfg, &y, Objective::Makespan);
        let x = match solve_smart(&lp, None).0.optimal() {
            Some((sol, _)) => extract_x(&sol, &vars),
            None => base.x.clone(),
        };
        let mut plan = Plan { x, y };
        plan.renormalize();
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{build_env, EnvKind, MB};

    #[test]
    fn discount_scales_reduce_side_only() {
        let t = build_env(EnvKind::Global8);
        let h = discount_topology(&t, 0.2);
        assert_eq!(h.d, t.d);
        assert_eq!(h.c_map, t.c_map);
        assert_eq!(h.b_sm, t.b_sm);
        for k in 0..t.n_reducers() {
            assert!((h.c_red[k] - 0.8 * t.c_red[k]).abs() < 1e-9 * t.c_red[k]);
        }
        for j in 0..t.n_mappers() {
            for k in 0..t.n_reducers() {
                let expect = t.b_mr.get(j, k) / 1.2;
                assert!((h.b_mr.get(j, k) - expect).abs() < 1e-9 * expect);
            }
        }
    }

    #[test]
    fn hedged_plan_is_valid_and_floors_every_reducer() {
        let t = build_env(EnvKind::Global4);
        let app = AppModel::new(1.0);
        let cfg = BarrierConfig::HADOOP;
        let rate = 0.25;
        let plan = FailureAwareOptimizer::new(rate).optimize(&t, app, cfg);
        plan.check(&t).unwrap();
        let r = t.n_reducers() as f64;
        for &y in &plan.y {
            // renormalize() can shave a hair off the exact floor.
            assert!(y >= rate / r - 1e-9, "insurance floor violated: y={y}");
        }
    }

    #[test]
    #[should_panic(expected = "hedge rate")]
    fn rejects_out_of_range_rate() {
        let _ = FailureAwareOptimizer::new(1.0);
    }

    #[test]
    fn zero_rate_delegates_unchanged() {
        let t = crate::platform::topology::example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let app = AppModel::new(10.0);
        let cfg = BarrierConfig::ALL_GLOBAL;
        let hedged = FailureAwareOptimizer::new(0.0).optimize(&t, app, cfg);
        let plain = AlternatingLp::default().optimize(&t, app, cfg);
        assert_eq!(hedged, plain);
    }
}
