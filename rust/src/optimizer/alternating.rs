//! End-to-end **multi-phase** optimization via alternating LPs — the
//! paper's headline optimizer ("e2e multi" in Figs 5–8).
//!
//! The full problem is bilinear (products `m_j·y_k` in eq 8). The paper
//! linearizes with the §2.3 PWL trick and hands Gurobi a MIP; offline we
//! exploit the bilinear structure instead: fixing `y` makes the program
//! linear in `x`, and fixing `x` makes it linear in `y` (see
//! [`super::lp_build`]). Alternating the two exact LP solves descends
//! monotonically and converges to a partitionwise-optimal plan; multiple
//! seeded starts guard against local minima. The PWL-MIP reference
//! implementation ([`super::mip_opt`]) cross-validates this on small
//! instances, and the gradient optimizer ([`super::gradient`]) does so on
//! large ones.

use super::lp_build::{build_lp_x, build_lp_y, extract_x, extract_y, Objective};
use super::PlanOptimizer;
use crate::model::barrier::BarrierConfig;
use crate::model::makespan::{makespan, AppModel};
use crate::model::plan::Plan;
use crate::platform::Topology;
use crate::solver::{solve_robust_dense, solve_smart, LpOutcome};
use crate::util::rng::Pcg64;

/// Cap on one-hot consolidation starts once `accel` is on and the
/// instance outgrows the paper's 8-reducer environments (the starts —
/// and with them the pre-screen LP count — would otherwise scale O(r)).
const ONE_HOT_CAP: usize = 8;

/// Alternating-LP e2e multi-phase optimizer.
#[derive(Debug, Clone, Copy)]
pub struct AlternatingLp {
    /// Random restarts in addition to the deterministic seeds.
    pub random_starts: usize,
    /// Maximum x/y alternations per start.
    pub max_rounds: usize,
    /// Relative improvement below which a start is converged.
    pub tol: f64,
    /// RNG seed for the random restarts.
    pub seed: u64,
    /// Scale accelerations: symmetry aggregation ([`super::aggregate`]),
    /// sparse/warm-started solver dispatch, one-hot start capping.
    /// Disable for the A/B benchmark baseline: that reproduces the
    /// pre-optimization *solver and search* path (the
    /// [`super::lp_build`] sparsity reformulation applies either way —
    /// same optimal objectives, so the comparison is conservative).
    /// Exact in both modes.
    pub accel: bool,
}

impl Default for AlternatingLp {
    fn default() -> Self {
        AlternatingLp { random_starts: 3, max_rounds: 15, tol: 1e-6, seed: 0xA17E, accel: true }
    }
}

impl AlternatingLp {
    /// Solve one LP of a descent. With `accel` the size-dispatching
    /// solver is used and the basis is carried between rounds (the next
    /// round's LP differs only in a few coefficients, so the warm solve
    /// is usually a handful of pivots); without it, the historical dense
    /// portfolio runs cold every time.
    fn solve_step(&self, lp: &crate::solver::Lp, basis: &mut Option<Vec<usize>>) -> LpOutcome {
        if self.accel {
            let (out, next) = solve_smart(lp, basis.as_deref());
            *basis = next;
            out
        } else {
            solve_robust_dense(lp)
        }
    }

    /// One descent from an initial `y`; returns the refined plan and its
    /// exact makespan.
    fn descend(
        &self,
        topo: &Topology,
        app: AppModel,
        cfg: BarrierConfig,
        mut y: Vec<f64>,
    ) -> (Plan, f64) {
        let mut best = f64::INFINITY;
        let mut plan = Plan::uniform(topo.n_sources(), topo.n_mappers(), topo.n_reducers());
        let mut x_basis: Option<Vec<usize>> = None;
        let mut y_basis: Option<Vec<usize>> = None;
        for _round in 0..self.max_rounds {
            // x-step: optimal push for the current shuffle split. A rare
            // numerically hopeless LP ends this start's descent; the
            // incumbent plan stands and other starts cover the search.
            let (lp, vars) = build_lp_x(topo, app, cfg, &y, Objective::Makespan);
            let sol = match self.solve_step(&lp, &mut x_basis).optimal() {
                Some((sol, _)) => sol,
                None => break,
            };
            let x = {
                // Clean simplex drift before the y-step sees the matrix.
                let mut p = Plan { x: extract_x(&sol, &vars), y: y.clone() };
                p.renormalize();
                p.x
            };

            // y-step: optimal shuffle split for that push.
            let (lp, vars) = build_lp_y(topo, app, cfg, &x, Objective::Makespan);
            let sol = match self.solve_step(&lp, &mut y_basis).optimal() {
                Some((sol, _)) => sol,
                None => break,
            };
            let mut candidate = Plan { x, y: extract_y(&sol, &vars) };
            candidate.renormalize();
            y = candidate.y.clone();
            let ms = makespan(topo, app, cfg, &candidate);
            if ms >= best * (1.0 - self.tol) {
                if ms < best {
                    return (candidate, ms);
                }
                return (plan, best);
            }
            best = ms;
            plan = candidate;
        }
        (plan, best)
    }

    /// Deterministic starting `y`s: uniform, capacity-proportional, and
    /// bandwidth-in-proportional splits.
    fn deterministic_starts(&self, topo: &Topology) -> Vec<Vec<f64>> {
        let r = topo.n_reducers();
        let mut starts = Vec::new();
        starts.push(vec![1.0 / r as f64; r]);
        // Proportional to reducer compute capacity.
        let csum: f64 = topo.c_red.iter().sum();
        starts.push(topo.c_red.iter().map(|c| c / csum).collect());
        // Proportional to aggregate incoming shuffle bandwidth.
        let bw: Vec<f64> = (0..r)
            .map(|k| (0..topo.n_mappers()).map(|j| topo.b_mr.get(j, k)).sum::<f64>())
            .collect();
        let bsum: f64 = bw.iter().sum();
        starts.push(bw.iter().map(|b| b / bsum).collect());
        // One-hot starts: consolidate all reduction at a single reducer.
        // These capture the §1.3 "keep the heavy shuffle inside one
        // cluster" optima that interior starts miss (they are the extreme
        // points of the y-simplex, where the bilinear objective's local
        // minima often sit). Past the paper's 8-reducer scale (accel on)
        // only the ONE_HOT_CAP best-connected reducers are tried: the
        // starts would otherwise grow O(r) and dominate the pre-screen.
        let one_hot_ks: Vec<usize> = if !self.accel || r <= ONE_HOT_CAP {
            (0..r).collect()
        } else {
            let mut ks: Vec<usize> = (0..r).collect();
            // total_cmp (descending): a zero/NaN-bandwidth node must
            // degrade the ranking, not panic the sort.
            ks.sort_by(|&a, &b| bw[b].total_cmp(&bw[a]).then(a.cmp(&b)));
            ks.truncate(ONE_HOT_CAP);
            ks.sort_unstable();
            ks
        };
        for k in one_hot_ks {
            let mut y = vec![0.0; r];
            y[k] = 1.0;
            starts.push(y);
        }
        starts
    }
}

impl PlanOptimizer for AlternatingLp {
    fn name(&self) -> &'static str {
        "e2e-multi"
    }

    fn optimize(&self, topo: &Topology, app: AppModel, cfg: BarrierConfig) -> Plan {
        // Collapse identical nodes first (exact; ≥32-node topologies
        // only): a hier-wan:256 instance descends over ~22 distinct node
        // kinds per role instead of ~85 raw nodes, shrinking every LP in
        // the alternation quadratically. The quotient plan expands back
        // with identical makespan.
        if self.accel {
            if let Some(plan) = super::aggregate::optimize_via_quotient(topo, app, cfg, |qt| {
                self.optimize(qt, app, cfg)
            }) {
                return plan;
            }
        }
        let r = topo.n_reducers();
        let mut starts = self.deterministic_starts(topo);
        let mut rng = Pcg64::new(self.seed);
        for _ in 0..self.random_starts {
            let mut y: Vec<f64> = (0..r).map(|_| rng.exponential(1.0)).collect();
            let s: f64 = y.iter().sum();
            y.iter_mut().for_each(|v| *v /= s);
            starts.push(y);
        }

        // Pre-screen: one x-step LP per start, keep the most promising
        // few for the full descent (perf pass: cuts LP solves ~3× with
        // no measured quality loss — see EXPERIMENTS.md §Perf).
        const KEEP: usize = 4;
        let mut scored: Vec<(f64, Vec<f64>)> = starts
            .into_iter()
            .map(|y0| {
                let (lp, vars) = build_lp_x(topo, app, cfg, &y0, Objective::Makespan);
                let mut no_basis = None;
                let score = match self.solve_step(&lp, &mut no_basis).optimal() {
                    Some((sol, _)) => {
                        let mut p = Plan { x: extract_x(&sol, &vars), y: y0.clone() };
                        p.renormalize();
                        makespan(topo, app, cfg, &p)
                    }
                    None => f64::INFINITY,
                };
                (score, y0)
            })
            .collect();
        // total_cmp: a NaN score (degenerate topology) ranks last instead
        // of panicking the pre-screen sort.
        scored.sort_by(|a, b| a.0.total_cmp(&b.0));

        let mut best_plan = None;
        let mut best_ms = f64::INFINITY;
        for (_, y0) in scored.into_iter().take(KEEP) {
            let (plan, ms) = self.descend(topo, app, cfg, y0);
            if ms < best_ms {
                best_ms = ms;
                best_plan = Some(plan);
            }
        }
        best_plan.expect("at least one start")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::myopic::Myopic;
    use crate::optimizer::single_phase::{E2ePush, E2eShuffle};
    use crate::optimizer::uniform::Uniform;
    use crate::platform::topology::example_1_3;
    use crate::platform::{build_env, EnvKind, MB};

    #[test]
    fn dominates_all_weaker_schemes_on_global8() {
        let t = build_env(EnvKind::Global8);
        let cfg = BarrierConfig::ALL_GLOBAL;
        let opt = AlternatingLp::default();
        for &alpha in &[0.1, 1.0, 10.0] {
            let app = AppModel::new(alpha);
            let e2e = makespan(&t, app, cfg, &opt.optimize(&t, app, cfg));
            for other in [
                makespan(&t, app, cfg, &Uniform.optimize(&t, app, cfg)),
                makespan(&t, app, cfg, &Myopic.optimize(&t, app, cfg)),
                makespan(&t, app, cfg, &E2ePush.optimize(&t, app, cfg)),
                makespan(&t, app, cfg, &E2eShuffle.optimize(&t, app, cfg)),
            ] {
                assert!(e2e <= other + 1e-6, "α={alpha}: e2e {e2e} vs {other}");
            }
        }
    }

    /// Regression (NaN-unsafe sort): ranking one-hot starts by aggregate
    /// shuffle bandwidth used `partial_cmp(..).unwrap()`, which panics
    /// when a degenerate topology carries a zero/NaN-bandwidth node
    /// (0-capacity column sums can propagate NaN). `f64::total_cmp` must
    /// keep the ranking deterministic and panic-free. Fails on the
    /// pre-fix code.
    #[test]
    fn one_hot_start_ranking_survives_nan_bandwidth_nodes() {
        use crate::platform::topology::{Cluster, Continent, Topology};
        use crate::util::mat::Mat;
        let r = ONE_HOT_CAP + 4; // past the cap so the ranking sort runs
        let mut b_mr = Mat::filled(2, r, 5.0 * MB);
        for j in 0..2 {
            b_mr[(j, 0)] = f64::NAN; // dead link probe / NaN telemetry
            b_mr[(j, 1)] = 0.0; // zero-bandwidth node
        }
        let topo = Topology {
            name: "degenerate".into(),
            clusters: vec![Cluster { id: 0, name: "c0".into(), continent: Continent::US }],
            source_cluster: vec![0; 2],
            mapper_cluster: vec![0; 2],
            reducer_cluster: vec![0; r],
            d: vec![1.0 * MB; 2],
            c_map: vec![10.0 * MB; 2],
            c_red: vec![10.0 * MB; r],
            b_sm: Mat::filled(2, 2, 10.0 * MB),
            b_mr,
        };
        let starts = AlternatingLp::default().deterministic_starts(&topo);
        // 3 seeded interior starts + ONE_HOT_CAP capped one-hot starts,
        // each a valid vertex of the y-simplex.
        assert_eq!(starts.len(), 3 + ONE_HOT_CAP);
        for y in &starts[3..] {
            assert_eq!(y.iter().filter(|&&v| v == 1.0).count(), 1);
            assert_eq!(y.iter().filter(|&&v| v == 0.0).count(), r - 1);
        }
    }

    #[test]
    fn recovers_the_1_3_consolidation_insight() {
        // §1.3, α=10: optimal plan consolidates work in cluster 1.
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let app = AppModel::new(10.0);
        let cfg = BarrierConfig::ALL_GLOBAL;
        let plan = AlternatingLp::default().optimize(&t, app, cfg);
        plan.check(&t).unwrap();
        let ms = makespan(&t, app, cfg, &plan);
        // Hand-built consolidation plan from the paper's narrative.
        let mut x = crate::util::mat::Mat::zeros(2, 2);
        x[(0, 0)] = 1.0;
        x[(1, 0)] = 1.0;
        let narrative = Plan { x, y: vec![1.0, 0.0] };
        let ms_narrative = makespan(&t, app, cfg, &narrative);
        assert!(
            ms <= ms_narrative + 1e-6,
            "optimizer {ms} vs narrative plan {ms_narrative}"
        );
    }

    #[test]
    fn works_across_barrier_configs() {
        let t = build_env(EnvKind::Global4);
        let app = AppModel::new(1.0);
        let opt = AlternatingLp { random_starts: 2, ..Default::default() };
        for cfg in [
            BarrierConfig::ALL_GLOBAL,
            BarrierConfig::HADOOP,
            BarrierConfig::ALL_PIPELINED,
        ] {
            let plan = opt.optimize(&t, app, cfg);
            plan.check(&t).unwrap();
            let uni = makespan(&t, app, cfg, &Plan::uniform(8, 8, 8));
            let e2e = makespan(&t, app, cfg, &plan);
            assert!(e2e <= uni + 1e-6, "cfg {}: {e2e} vs uniform {uni}", cfg.label());
        }
    }

    #[test]
    fn descent_is_deterministic() {
        let t = build_env(EnvKind::Global4);
        let app = AppModel::new(2.0);
        let cfg = BarrierConfig::ALL_GLOBAL;
        let opt = AlternatingLp::default();
        let a = opt.optimize(&t, app, cfg);
        let b = opt.optimize(&t, app, cfg);
        assert_eq!(a, b);
    }
}
