//! Gradient-based end-to-end multi-phase optimizer over the smooth
//! makespan relaxation ([`crate::model::smooth`]).
//!
//! Plans are parameterized by unconstrained logits (row-softmax → `x`,
//! softmax → `y`), so eqs 1–3 hold by construction and plain Adam
//! applies. The sharpness `β` of the logsumexp max is annealed from soft
//! to hard over the run; multiple starts guard against local minima and
//! the returned plan is the best start under the *exact* (hard-max)
//! model.
//!
//! Three interchangeable gradient backends:
//! * [`AnalyticBackend`] — hand-written reverse-mode gradients of the
//!   smooth relaxation in pure rust
//!   ([`crate::model::smooth::smooth_makespan_grad`]): one forward +
//!   backward pass per step instead of `O(S·M + R)` finite-difference
//!   evaluations. **The default**; fast without the `pjrt` feature.
//! * [`FiniteDiffBackend`] — central finite differences against the rust
//!   smooth evaluator; retained as the oracle the analytic gradients are
//!   property-tested against, and for A/B perf benchmarks.
//! * `runtime::planner_art::ArtifactBackend` — the AOT-compiled JAX/
//!   Pallas artifact executed via PJRT (batched multi-start in one device
//!   call). This is the L1/L2 integration.
//!
//! On ≥32-node topologies the optimizer first collapses identical nodes
//! via [`super::aggregate`] — exact for this model — so a `hier-wan:256`
//! instance optimizes over ~22 distinct node kinds per role instead of
//! ~85 raw nodes.

use super::PlanOptimizer;
use crate::model::barrier::BarrierConfig;
use crate::model::makespan::{makespan, AppModel};
use crate::model::plan::Plan;
use crate::model::smooth::{
    smooth_makespan_grad, smooth_makespan_logits, softmax, softmax_rows,
};
use crate::platform::Topology;
use crate::util::mat::Mat;
use crate::util::rng::Pcg64;

/// A gradient backend evaluates ∂(smooth makespan)/∂logits.
pub trait GradBackend {
    /// Returns (loss, grad_x (S×M), grad_y (R)) at the given logits.
    fn value_and_grad(
        &mut self,
        topo: &Topology,
        app: AppModel,
        cfg: BarrierConfig,
        logits_x: &Mat,
        logits_y: &[f64],
        beta: f64,
    ) -> (f64, Mat, Vec<f64>);
}

/// Analytic reverse-mode gradients over the rust smooth evaluator — one
/// forward+backward pass per step (see module docs).
#[derive(Debug, Clone, Copy, Default)]
pub struct AnalyticBackend;

impl GradBackend for AnalyticBackend {
    fn value_and_grad(
        &mut self,
        topo: &Topology,
        app: AppModel,
        cfg: BarrierConfig,
        logits_x: &Mat,
        logits_y: &[f64],
        beta: f64,
    ) -> (f64, Mat, Vec<f64>) {
        smooth_makespan_grad(topo, app, cfg, logits_x, logits_y, beta)
    }
}

/// Central finite differences over the rust smooth evaluator.
pub struct FiniteDiffBackend {
    pub eps: f64,
}

impl Default for FiniteDiffBackend {
    fn default() -> Self {
        FiniteDiffBackend { eps: 1e-4 }
    }
}

impl GradBackend for FiniteDiffBackend {
    fn value_and_grad(
        &mut self,
        topo: &Topology,
        app: AppModel,
        cfg: BarrierConfig,
        logits_x: &Mat,
        logits_y: &[f64],
        beta: f64,
    ) -> (f64, Mat, Vec<f64>) {
        let f = |lx: &Mat, ly: &[f64]| smooth_makespan_logits(topo, app, cfg, lx, ly, beta);
        let loss = f(logits_x, logits_y);
        let mut gx = Mat::zeros(logits_x.rows(), logits_x.cols());
        let mut lx = logits_x.clone();
        for i in 0..lx.rows() {
            for j in 0..lx.cols() {
                let orig = lx.get(i, j);
                lx.set(i, j, orig + self.eps);
                let hi = f(&lx, logits_y);
                lx.set(i, j, orig - self.eps);
                let lo = f(&lx, logits_y);
                lx.set(i, j, orig);
                gx.set(i, j, (hi - lo) / (2.0 * self.eps));
            }
        }
        let mut gy = vec![0.0; logits_y.len()];
        let mut ly = logits_y.to_vec();
        for k in 0..ly.len() {
            let orig = ly[k];
            ly[k] = orig + self.eps;
            let hi = f(logits_x, &ly);
            ly[k] = orig - self.eps;
            let lo = f(logits_x, &ly);
            ly[k] = orig;
            gy[k] = (hi - lo) / (2.0 * self.eps);
        }
        (loss, gx, gy)
    }
}

/// Adam hyperparameters + annealing schedule.
#[derive(Debug, Clone, Copy)]
pub struct GradConfig {
    pub steps: usize,
    pub starts: usize,
    pub lr: f64,
    pub adam_b1: f64,
    pub adam_b2: f64,
    pub adam_eps: f64,
    /// β at step 0 and at the final step, in units of 1/(uniform makespan).
    pub beta_start: f64,
    pub beta_end: f64,
    pub seed: u64,
    /// Collapse identical nodes before optimizing (exact; ≥32-node
    /// topologies only — see [`super::aggregate`]). Disable to reproduce
    /// the pre-aggregation code path for A/B benchmarks.
    pub aggregate: bool,
}

impl Default for GradConfig {
    fn default() -> Self {
        GradConfig {
            steps: 250,
            starts: 4,
            lr: 0.25,
            adam_b1: 0.9,
            adam_b2: 0.999,
            adam_eps: 1e-8,
            beta_start: 20.0,
            beta_end: 400.0,
            seed: 0x6AD,
            aggregate: true,
        }
    }
}

/// The optimizer, generic over the gradient backend.
pub struct GradientOptimizer<B: GradBackend> {
    pub config: GradConfig,
    pub backend: B,
}

impl Default for GradientOptimizer<AnalyticBackend> {
    fn default() -> Self {
        GradientOptimizer { config: GradConfig::default(), backend: AnalyticBackend }
    }
}

impl GradientOptimizer<FiniteDiffBackend> {
    /// The pre-analytic finite-difference path (oracle / A-B baseline).
    pub fn finite_diff() -> Self {
        GradientOptimizer { config: GradConfig::default(), backend: FiniteDiffBackend::default() }
    }
}

impl<B: GradBackend> GradientOptimizer<B> {
    pub fn new(config: GradConfig, backend: B) -> Self {
        GradientOptimizer { config, backend }
    }

    fn run_start(
        &mut self,
        topo: &Topology,
        app: AppModel,
        cfg: BarrierConfig,
        mut lx: Mat,
        mut ly: Vec<f64>,
        scale: f64,
    ) -> Plan {
        let c = self.config;
        let nx = lx.rows() * lx.cols();
        let ny = ly.len();
        let mut m = vec![0.0; nx + ny];
        let mut v = vec![0.0; nx + ny];
        for step in 0..c.steps {
            let frac = step as f64 / (c.steps.max(2) - 1) as f64;
            // geometric anneal of β
            let beta_norm = c.beta_start * (c.beta_end / c.beta_start).powf(frac);
            let beta = beta_norm / scale;
            let (_loss, gx, gy) = self
                .backend
                .value_and_grad(topo, app, cfg, &lx, &ly, beta);
            // Normalize gradient scale: loss is in seconds; keep updates
            // O(lr) by scaling grads by `scale`.
            let t = (step + 1) as f64;
            let bc1 = 1.0 - c.adam_b1.powf(t);
            let bc2 = 1.0 - c.adam_b2.powf(t);
            let mut upd = |idx: usize, g: f64| -> f64 {
                let g = g * scale;
                m[idx] = c.adam_b1 * m[idx] + (1.0 - c.adam_b1) * g;
                v[idx] = c.adam_b2 * v[idx] + (1.0 - c.adam_b2) * g * g;
                let mh = m[idx] / bc1;
                let vh = v[idx] / bc2;
                c.lr * mh / (vh.sqrt() + c.adam_eps)
            };
            for i in 0..lx.rows() {
                for j in 0..lx.cols() {
                    let idx = i * lx.cols() + j;
                    let delta = upd(idx, gx.get(i, j));
                    lx.set(i, j, lx.get(i, j) - delta);
                }
            }
            for k in 0..ny {
                let delta = upd(nx + k, gy[k]);
                ly[k] -= delta;
            }
        }
        let mut plan = Plan { x: softmax_rows(&lx), y: softmax(&ly) };
        plan.renormalize();
        plan
    }
}

impl<B: GradBackend> GradientOptimizer<B> {
    /// Optimize, returning the best plan across starts under the exact model.
    pub fn optimize_mut(
        &mut self,
        topo: &Topology,
        app: AppModel,
        cfg: BarrierConfig,
    ) -> Plan {
        if self.config.aggregate {
            if let Some(plan) =
                super::aggregate::optimize_via_quotient(topo, app, cfg, |qt| {
                    self.optimize_mut(qt, app, cfg)
                })
            {
                return plan;
            }
        }
        let (s, m_, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
        let uniform = Plan::uniform(s, m_, r);
        let scale = makespan(topo, app, cfg, &uniform).max(1e-9);

        let mut rng = Pcg64::new(self.config.seed);
        let mut best = uniform.clone();
        let mut best_ms = makespan(topo, app, cfg, &uniform);
        for start in 0..self.config.starts {
            let (lx, ly) = if start == 0 {
                // Deterministic start: zero logits = uniform plan.
                (Mat::zeros(s, m_), vec![0.0; r])
            } else {
                let mut lx = Mat::zeros(s, m_);
                for i in 0..s {
                    for j in 0..m_ {
                        lx.set(i, j, rng.normal() * 0.5);
                    }
                }
                let ly: Vec<f64> = (0..r).map(|_| rng.normal() * 0.5).collect();
                (lx, ly)
            };
            let plan = self.run_start(topo, app, cfg, lx, ly, scale);
            let ms = makespan(topo, app, cfg, &plan);
            if ms < best_ms {
                best_ms = ms;
                best = plan;
            }
        }
        best
    }
}

impl PlanOptimizer for GradientOptimizer<AnalyticBackend> {
    fn name(&self) -> &'static str {
        "e2e-multi-grad"
    }

    fn optimize(&self, topo: &Topology, app: AppModel, cfg: BarrierConfig) -> Plan {
        // PlanOptimizer is &self; clone config into a fresh instance.
        let mut opt = GradientOptimizer { config: self.config, backend: AnalyticBackend };
        opt.optimize_mut(topo, app, cfg)
    }
}

impl PlanOptimizer for GradientOptimizer<FiniteDiffBackend> {
    fn name(&self) -> &'static str {
        "e2e-multi-grad-fd"
    }

    fn optimize(&self, topo: &Topology, app: AppModel, cfg: BarrierConfig) -> Plan {
        let mut opt = GradientOptimizer {
            config: self.config,
            backend: FiniteDiffBackend { eps: self.backend.eps },
        };
        opt.optimize_mut(topo, app, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::alternating::AlternatingLp;
    use crate::platform::topology::example_1_3;
    use crate::platform::{build_env, EnvKind, MB};

    #[test]
    fn gradient_improves_over_uniform_small() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let cfg = BarrierConfig::ALL_GLOBAL;
        for &alpha in &[0.1, 10.0] {
            let app = AppModel::new(alpha);
            let plan = GradientOptimizer::default().optimize(&t, app, cfg);
            plan.check(&t).unwrap();
            let uni = makespan(&t, app, cfg, &Plan::uniform(2, 2, 2));
            let ms = makespan(&t, app, cfg, &plan);
            assert!(
                ms < uni * 0.9,
                "α={alpha}: gradient {ms} should beat uniform {uni} by >10%"
            );
        }
    }

    #[test]
    fn gradient_close_to_alternating_on_small_instance() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let cfg = BarrierConfig::ALL_GLOBAL;
        let app = AppModel::new(1.0);
        let g = GradientOptimizer::default().optimize(&t, app, cfg);
        let a = AlternatingLp::default().optimize(&t, app, cfg);
        let ms_g = makespan(&t, app, cfg, &g);
        let ms_a = makespan(&t, app, cfg, &a);
        assert!(
            ms_g <= ms_a * 1.25,
            "gradient {ms_g} should be within 25% of alternating {ms_a}"
        );
    }

    #[test]
    fn gradient_runs_on_8x8x8() {
        // Smoke: the fallback backend scales to the paper's size.
        let t = build_env(EnvKind::Global8);
        let app = AppModel::new(1.0);
        let cfg = BarrierConfig::ALL_GLOBAL;
        let mut opt = GradientOptimizer {
            config: GradConfig { steps: 40, starts: 1, ..Default::default() },
            backend: FiniteDiffBackend::default(),
        };
        let plan = opt.optimize_mut(&t, app, cfg);
        plan.check(&t).unwrap();
        let uni = makespan(&t, app, cfg, &Plan::uniform(8, 8, 8));
        let ms = makespan(&t, app, cfg, &plan);
        assert!(ms <= uni + 1e-6, "{ms} vs uniform {uni}");
    }

    #[test]
    fn analytic_backend_matches_finite_diff_optimizer() {
        // The analytic default must reproduce the finite-diff path's
        // results: same config, same starts, gradients agreeing to 1e-5 —
        // the optimized makespans match tightly.
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let cfg = BarrierConfig::ALL_GLOBAL;
        for &alpha in &[0.1, 1.0, 10.0] {
            let app = AppModel::new(alpha);
            let a = GradientOptimizer::default().optimize(&t, app, cfg);
            let f = GradientOptimizer::finite_diff().optimize(&t, app, cfg);
            let ms_a = makespan(&t, app, cfg, &a);
            let ms_f = makespan(&t, app, cfg, &f);
            assert!(
                (ms_a - ms_f).abs() <= 1e-3 * ms_f,
                "α={alpha}: analytic {ms_a} vs finite-diff {ms_f}"
            );
        }
    }

    #[test]
    fn finite_diff_gradient_descends() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let app = AppModel::new(1.0);
        let cfg = BarrierConfig::ALL_GLOBAL;
        let mut backend = FiniteDiffBackend::default();
        let lx = Mat::zeros(2, 2);
        let ly = vec![0.0, 0.0];
        let uni_ms = makespan(&t, app, cfg, &Plan::uniform(2, 2, 2));
        let beta = 100.0 / uni_ms;
        let (loss, gx, gy) = backend.value_and_grad(&t, app, cfg, &lx, &ly, beta);
        // Step along -grad must reduce the smooth loss.
        let step = 0.05;
        let mut lx2 = lx.clone();
        for i in 0..2 {
            for j in 0..2 {
                lx2.set(i, j, lx.get(i, j) - step * gx.get(i, j) * uni_ms);
            }
        }
        let ly2: Vec<f64> = ly
            .iter()
            .zip(&gy)
            .map(|(&l, &g)| l - step * g * uni_ms)
            .collect();
        let loss2 = smooth_makespan_logits(&t, app, cfg, &lx2, &ly2, beta);
        assert!(loss2 < loss, "descent failed: {loss2} vs {loss}");
    }
}
