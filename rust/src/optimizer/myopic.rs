//! Myopic multi-phase optimization (§4.2): optimize each data-
//! dissemination phase *for its own duration*, in sequence — first the
//! push (minimize `max_j push_end_j`), then, holding that push fixed, the
//! shuffle (minimize `max_k shuffle_end_k`). Locally optimal per phase,
//! globally suboptimal — the paper's strawman that end-to-end
//! optimization beats by 65–82%.

use super::lp_build::{build_lp_x, build_lp_y, extract_x, extract_y, Objective};
use super::PlanOptimizer;
use crate::model::barrier::BarrierConfig;
use crate::model::makespan::AppModel;
use crate::model::plan::Plan;
use crate::platform::Topology;
use crate::solver::solve_robust as solve;

#[derive(Debug, Clone, Copy, Default)]
pub struct Myopic;

impl PlanOptimizer for Myopic {
    fn name(&self) -> &'static str {
        "myopic-multi"
    }

    fn optimize(&self, topo: &Topology, app: AppModel, cfg: BarrierConfig) -> Plan {
        let r = topo.n_reducers();
        // Phase 1: minimize push time (y is irrelevant to the objective;
        // pass uniform).
        let y0 = vec![1.0 / r as f64; r];
        let (lp, vars) = build_lp_x(topo, app, cfg, &y0, Objective::PushTime);
        // A numerically hopeless LP (possible on huge ungrouped instances
        // routed through the sparse solver) degrades to the local-push
        // heuristic instead of panicking.
        let x = match solve(&lp).optimal() {
            Some((sol, _)) => extract_x(&sol, &vars),
            None => {
                super::warn_lp_fallback("myopic push LP", "local-push heuristic");
                Plan::local_push(topo).x
            }
        };

        // Phase 2: given that push, minimize the shuffle completion.
        let (lp, vars) = build_lp_y(topo, app, cfg, &x, Objective::ShuffleEnd);
        let y = match solve(&lp).optimal() {
            Some((sol, _)) => extract_y(&sol, &vars),
            None => {
                super::warn_lp_fallback("myopic shuffle LP", "uniform shuffle");
                y0
            }
        };

        let mut plan = Plan { x, y };
        plan.renormalize();
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::makespan::{push_time, shuffle_time};
    use crate::platform::topology::example_1_3;
    use crate::platform::{build_env, EnvKind, MB};
    use crate::util::rng::Pcg64;

    #[test]
    fn myopic_minimizes_push_time() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let app = AppModel::new(1.0);
        let plan = Myopic.optimize(&t, app, BarrierConfig::ALL_GLOBAL);
        plan.check(&t).unwrap();
        // Analytic myopic push optimum: max_i D_i / Σ_j B_ij.
        let expect = (0..2)
            .map(|i| t.d[i] / (0..2).map(|j| t.b_sm.get(i, j)).sum::<f64>())
            .fold(0.0, f64::max);
        let got = push_time(&t, &plan);
        assert!((got - expect).abs() / expect < 1e-6, "push {got} vs {expect}");
    }

    #[test]
    fn myopic_shuffle_no_worse_than_uniform_shuffle() {
        let t = build_env(EnvKind::Global8);
        for &alpha in &[0.1, 1.0, 10.0] {
            let app = AppModel::new(alpha);
            let plan = Myopic.optimize(&t, app, BarrierConfig::ALL_GLOBAL);
            plan.check(&t).unwrap();
            let mut uni_shuffle = plan.clone();
            uni_shuffle.y = vec![1.0 / 8.0; 8];
            assert!(
                shuffle_time(&t, app, &plan)
                    <= shuffle_time(&t, app, &uni_shuffle) + 1e-6,
                "α={alpha}"
            );
        }
    }

    #[test]
    fn myopic_valid_on_random_small_topologies() {
        let mut rng = Pcg64::new(77);
        for _ in 0..10 {
            let local = rng.uniform(50.0, 150.0) * MB;
            let nonlocal = rng.uniform(1.0, 20.0) * MB;
            let compute = rng.uniform(20.0, 120.0) * MB;
            let t = example_1_3(local, nonlocal, compute);
            let plan = Myopic.optimize(&t, AppModel::new(rng.uniform(0.1, 5.0)),
                                       BarrierConfig::ALL_GLOBAL);
            plan.check(&t).unwrap();
        }
    }
}
