//! Symmetry aggregation: collapse identical nodes before optimizing.
//!
//! Generated topologies ([`crate::platform::scale`]) build clusters whose
//! nodes share bit-identical bandwidth rows and compute capacities — a
//! `hier-wan:256` platform has ~85 nodes per role but only ~22 *distinct*
//! node kinds per role. For the makespan model, spreading a plan evenly
//! across the members of an identical-node group never hurts: every phase
//! term is a max/sum of per-node times that scale with the per-node
//! allocation, so the even split weakly dominates any asymmetric split of
//! the same group total (for any barrier configuration; this also
//! preserves the bilinear structure, unlike plain convexity arguments).
//! A group-symmetric optimum therefore always exists, and optimizing over
//! group-symmetric plans is *exact*, not a relaxation.
//!
//! The quotient instance is an ordinary [`Topology`] over one node per
//! group with totals substituted (`D' = Σ D`, `C' = Σ C`) and bandwidths
//! scaled by the group sizes (`B'_GH = n_G·n_H·B`), which makes every
//! optimizer, model and solver run on it unchanged; [`Quotient::expand`]
//! maps the quotient plan back by even within-group splits with exactly
//! the original makespan.
//!
//! Aggregation is only attempted at or above [`MIN_NODES_TO_AGGREGATE`]
//! total nodes, so the paper's 8×8×8 environments keep their historical
//! code path bit-for-bit.

use crate::model::barrier::BarrierConfig;
use crate::model::makespan::{makespan, AppModel};
use crate::model::plan::Plan;
use crate::platform::Topology;
use crate::util::mat::Mat;

/// Below this many total nodes (S+M+R) aggregation is skipped entirely.
pub const MIN_NODES_TO_AGGREGATE: usize = 32;

/// A symmetry-collapsed instance plus the bookkeeping to expand plans.
pub struct Quotient {
    /// The aggregated topology (one node per identical-node group).
    pub topo: Topology,
    src_group: Vec<usize>,
    map_group: Vec<usize>,
    red_group: Vec<usize>,
    map_count: Vec<usize>,
    red_count: Vec<usize>,
}

impl Quotient {
    /// Expand a plan on the quotient topology to the original topology by
    /// splitting each group allocation evenly over the group's members.
    /// Preserves the makespan exactly (see module docs).
    pub fn expand(&self, qplan: &Plan) -> Plan {
        let s = self.src_group.len();
        let m = self.map_group.len();
        let r = self.red_group.len();
        let mut x = Mat::zeros(s, m);
        for i in 0..s {
            let gi = self.src_group[i];
            for j in 0..m {
                let gj = self.map_group[j];
                x[(i, j)] = qplan.x.get(gi, gj) / self.map_count[gj] as f64;
            }
        }
        let y: Vec<f64> = (0..r)
            .map(|k| {
                let gk = self.red_group[k];
                qplan.y[gk] / self.red_count[gk] as f64
            })
            .collect();
        Plan { x, y }
    }
}

/// Cluster-bucketed exact-equality grouping: nodes are candidates for the
/// same group only within one cluster (where generators reuse parameter
/// draws), and must match on every model-relevant value bit-for-bit —
/// conservative by construction: in the worst case every group is a
/// singleton and `quotient` returns `None`.
fn group_nodes<FC, FE>(n: usize, cluster_of: FC, same: FE) -> (Vec<usize>, Vec<Vec<usize>>)
where
    FC: Fn(usize) -> usize,
    FE: Fn(usize, usize) -> bool,
{
    let mut group_of = vec![0usize; n];
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut reps: Vec<usize> = Vec::new();
    for i in 0..n {
        let mut found = None;
        for (g, &rep) in reps.iter().enumerate() {
            if cluster_of(rep) == cluster_of(i) && same(rep, i) {
                found = Some(g);
                break;
            }
        }
        match found {
            Some(g) => {
                group_of[i] = g;
                groups[g].push(i);
            }
            None => {
                group_of[i] = groups.len();
                reps.push(i);
                groups.push(vec![i]);
            }
        }
    }
    (group_of, groups)
}

fn col_eq(mat: &Mat, a: usize, b: usize) -> bool {
    (0..mat.rows()).all(|r| mat.get(r, a) == mat.get(r, b))
}

/// Build the symmetry quotient, or `None` when the instance is too small
/// or no role has two identical nodes (then the original path is both
/// exact and already cheap).
pub fn quotient(topo: &Topology) -> Option<Quotient> {
    let (s, m, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
    if s + m + r < MIN_NODES_TO_AGGREGATE {
        return None;
    }

    let (src_group, src_groups) = group_nodes(
        s,
        |i| topo.source_cluster[i],
        |a, b| topo.d[a] == topo.d[b] && topo.b_sm.row(a) == topo.b_sm.row(b),
    );
    let (map_group, map_groups) = group_nodes(
        m,
        |j| topo.mapper_cluster[j],
        |a, b| {
            topo.c_map[a] == topo.c_map[b]
                && col_eq(&topo.b_sm, a, b)
                && topo.b_mr.row(a) == topo.b_mr.row(b)
        },
    );
    let (red_group, red_groups) = group_nodes(
        r,
        |k| topo.reducer_cluster[k],
        |a, b| topo.c_red[a] == topo.c_red[b] && col_eq(&topo.b_mr, a, b),
    );

    let (sg, mg, rg) = (src_groups.len(), map_groups.len(), red_groups.len());
    if sg == s && mg == m && rg == r {
        return None; // all singletons: nothing to collapse
    }

    let src_count: Vec<usize> = src_groups.iter().map(|g| g.len()).collect();
    let map_count: Vec<usize> = map_groups.iter().map(|g| g.len()).collect();
    let red_count: Vec<usize> = red_groups.iter().map(|g| g.len()).collect();

    let d: Vec<f64> = src_groups
        .iter()
        .map(|g| g.iter().map(|&i| topo.d[i]).sum())
        .collect();
    let c_map: Vec<f64> = map_groups
        .iter()
        .map(|g| g.iter().map(|&j| topo.c_map[j]).sum())
        .collect();
    let c_red: Vec<f64> = red_groups
        .iter()
        .map(|g| g.iter().map(|&k| topo.c_red[k]).sum())
        .collect();

    let mut b_sm = Mat::zeros(sg, mg);
    for (gi, sgm) in src_groups.iter().enumerate() {
        for (gj, mgm) in map_groups.iter().enumerate() {
            b_sm[(gi, gj)] = topo.b_sm.get(sgm[0], mgm[0])
                * (src_count[gi] * map_count[gj]) as f64;
        }
    }
    let mut b_mr = Mat::zeros(mg, rg);
    for (gj, mgm) in map_groups.iter().enumerate() {
        for (gk, rgm) in red_groups.iter().enumerate() {
            b_mr[(gj, gk)] = topo.b_mr.get(mgm[0], rgm[0])
                * (map_count[gj] * red_count[gk]) as f64;
        }
    }

    let qtopo = Topology {
        name: format!("{}-sym{}x{}x{}", topo.name, sg, mg, rg),
        clusters: topo.clusters.clone(),
        source_cluster: src_groups.iter().map(|g| topo.source_cluster[g[0]]).collect(),
        mapper_cluster: map_groups.iter().map(|g| topo.mapper_cluster[g[0]]).collect(),
        reducer_cluster: red_groups.iter().map(|g| topo.reducer_cluster[g[0]]).collect(),
        d,
        c_map,
        c_red,
        b_sm,
        b_mr,
    };
    qtopo.validate();

    Some(Quotient { topo: qtopo, src_group, map_group, red_group, map_count, red_count })
}

/// Optimize through the symmetry quotient: collapse, run `inner` on the
/// quotient topology, expand the result, and re-anchor the
/// never-loses-to-uniform guarantee — the quotient's uniform start is
/// *count-weighted* in full space, not the full-space uniform plan, so
/// the inner optimizer's uniform anchor does not carry over. Returns
/// `None` when the topology does not aggregate (caller runs its direct
/// path).
pub fn optimize_via_quotient<F>(
    topo: &Topology,
    app: AppModel,
    cfg: BarrierConfig,
    inner: F,
) -> Option<Plan>
where
    F: FnOnce(&Topology) -> Plan,
{
    let q = quotient(topo)?;
    let mut plan = q.expand(&inner(&q.topo));
    plan.renormalize();
    let uni = Plan::uniform(topo.n_sources(), topo.n_mappers(), topo.n_reducers());
    if makespan(topo, app, cfg, &uni) < makespan(topo, app, cfg, &plan) {
        return Some(uni);
    }
    Some(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::scale::{generate_kind, ScaleKind};
    use crate::platform::{build_env, EnvKind};
    use crate::util::rng::Pcg64;

    #[test]
    fn paper_envs_do_not_aggregate() {
        // 24 nodes total < MIN_NODES_TO_AGGREGATE: historical path intact.
        for kind in EnvKind::all() {
            assert!(quotient(&build_env(kind)).is_none(), "{kind:?}");
        }
    }

    #[test]
    fn generated_topologies_collapse() {
        for kind in ScaleKind::all() {
            let t = generate_kind(kind, 64, 7);
            let q = quotient(&t).expect("64-node generated topologies have replicas");
            let total = q.topo.n_sources() + q.topo.n_mappers() + q.topo.n_reducers();
            assert!(
                total < t.n_sources() + t.n_mappers() + t.n_reducers(),
                "{kind:?}: quotient must shrink"
            );
        }
    }

    #[test]
    fn expansion_preserves_makespan_exactly() {
        let t = generate_kind(ScaleKind::HierarchicalWan, 64, 3);
        let q = quotient(&t).unwrap();
        let (qs, qm, qr) =
            (q.topo.n_sources(), q.topo.n_mappers(), q.topo.n_reducers());
        let mut rng = Pcg64::new(42);
        for cfg in [
            BarrierConfig::ALL_GLOBAL,
            BarrierConfig::HADOOP,
            BarrierConfig::ALL_PIPELINED,
        ] {
            for &alpha in &[0.2, 1.0, 5.0] {
                let app = AppModel::new(alpha);
                let qplan = Plan::random(qs, qm, qr, &mut rng);
                let plan = q.expand(&qplan);
                plan.check(&t).unwrap();
                let ms_q = makespan(&q.topo, app, cfg, &qplan);
                let ms_full = makespan(&t, app, cfg, &plan);
                let rel = (ms_q - ms_full).abs() / ms_full.max(1e-9);
                assert!(
                    rel < 1e-9,
                    "cfg {cfg:?} α={alpha}: quotient {ms_q} vs expanded {ms_full}"
                );
            }
        }
    }

    #[test]
    fn quotient_terminates_on_requotient() {
        // The quotient of a quotient must strictly shrink or be None —
        // optimizers recurse on it.
        let t = generate_kind(ScaleKind::FederatedDataCenters, 128, 9);
        let mut cur = t;
        let mut guard = 0;
        while let Some(q) = quotient(&cur) {
            let before = cur.n_sources() + cur.n_mappers() + cur.n_reducers();
            let after = q.topo.n_sources() + q.topo.n_mappers() + q.topo.n_reducers();
            assert!(after < before);
            cur = q.topo;
            guard += 1;
            assert!(guard < 10, "aggregation must terminate");
        }
    }
}
