//! Shared scale-bench scaffolding for `cargo bench` (benches/bench_main.rs,
//! which *asserts* the ISSUE 2 acceptance bars) and the `mrperf bench`
//! CLI subcommand (quick JSON-recorded trend tracking). Keeping the A/B
//! configurations in one place guarantees both harnesses measure the
//! same accelerated-vs-pre-PR comparison.

use super::gradient::GradConfig;
use super::{
    AlternatingLp, AnalyticBackend, FiniteDiffBackend, GradientOptimizer, PlanOptimizer,
};
use crate::model::barrier::BarrierConfig;
use crate::model::makespan::AppModel;
use crate::platform::scale::{generate_kind, ScaleKind};
use crate::util::bench::{black_box, BenchSuite};

fn bench_setting() -> (AppModel, BarrierConfig) {
    (AppModel::new(1.0), BarrierConfig::HADOOP)
}

/// Register the accelerated-vs-pre-PR optimizer A/B benches on a
/// `hier-wan:<nodes>` topology (both sides trimmed identically: the
/// baseline is the deliberately slow path). Returns
/// `(label, accelerated_name, baseline_name)` pairs for speedup-ratio
/// assertions.
pub fn add_scale_ab_benches(
    suite: &mut BenchSuite,
    nodes: usize,
) -> [(&'static str, String, String); 2] {
    let (app, bc) = bench_setting();
    let topo = generate_kind(ScaleKind::HierarchicalWan, nodes, 7);

    let fast = AlternatingLp { random_starts: 0, max_rounds: 2, ..Default::default() };
    let slow = AlternatingLp { accel: false, ..fast };
    let alt_new = format!("optimizer/scale_{nodes}_alternating");
    let alt_old = format!("optimizer/scale_{nodes}_alternating_prepr");
    suite.bench(&alt_new, || black_box(fast.optimize(&topo, app, bc)));
    suite.bench(&alt_old, || black_box(slow.optimize(&topo, app, bc)));

    let gc = GradConfig { steps: 20, starts: 1, ..Default::default() };
    let gc_fd = GradConfig { aggregate: false, ..gc };
    let grad_new = format!("optimizer/scale_{nodes}_gradient_analytic");
    let grad_old = format!("optimizer/scale_{nodes}_gradient_finitediff_prepr");
    suite.bench(&grad_new, || {
        let mut o = GradientOptimizer::new(gc, AnalyticBackend);
        black_box(o.optimize_mut(&topo, app, bc))
    });
    suite.bench(&grad_old, || {
        let mut o = GradientOptimizer::new(gc_fd, FiniteDiffBackend::default());
        black_box(o.optimize_mut(&topo, app, bc))
    });

    [("alternating", alt_new, alt_old), ("gradient", grad_new, grad_old)]
}

/// Register the acceptance headline: full-default optimizers on
/// `hier-wan:256`. Returns the bench names for <30 s checks.
pub fn add_scale_headline_benches(suite: &mut BenchSuite) -> [String; 2] {
    let (app, bc) = bench_setting();
    let topo = generate_kind(ScaleKind::HierarchicalWan, 256, 7);
    let alt = "optimizer/scale_256_alternating".to_string();
    let grad = "optimizer/scale_256_gradient".to_string();
    suite.bench(&alt, || black_box(AlternatingLp::default().optimize(&topo, app, bc)));
    suite.bench(&grad, || {
        black_box(GradientOptimizer::default().optimize(&topo, app, bc))
    });
    [alt, grad]
}
