//! LP formulations of the makespan model (§2.3).
//!
//! The full end-to-end multi-phase problem is bilinear (`m_j·y_k` in the
//! shuffle terms, eq 8); but fixing either side makes it *linear*:
//!
//! * [`build_lp_x`] — `y` fixed, optimize the push fractions `x_ij`.
//! * [`build_lp_y`] — `x` fixed, optimize the key-space fractions `y_k`.
//!
//! Every `max` in eqs 4–11 becomes epigraph rows (`Z ≥ term`, minimize
//! `Z`), which is exact because all times appear monotonically. All three
//! barrier semantics are supported; the per-node time variables make
//! local/pipelined boundaries expressible (eqs 12–14).
//!
//! Epigraph rows with a *single* variable and constant rhs (`T ≥ c`)
//! are emitted as implicit variable bounds ([`Lp::bound_below`]) rather
//! than constraint rows — the bounded revised simplex handles them in
//! the ratio test for free, and every row saved shrinks the basis.
//!
//! Objectives:
//! * `Makespan` — eq 11, the end-to-end objective.
//! * `PushTime` — myopic push (§4.2): minimize `max_j push_end_j`.
//! * `ShuffleEnd` — myopic shuffle (§4.2): minimize `max_k shuffle_end_k`.

use crate::model::barrier::{Barrier, BarrierConfig};
use crate::model::makespan::AppModel;
use crate::model::plan::Plan;
use crate::platform::Topology;
use crate::solver::lp::{Cmp, Lp};
use crate::util::mat::Mat;

/// What the LP minimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    Makespan,
    PushTime,
    ShuffleEnd,
}

/// Handle mapping solved LP columns back to plan fractions.
pub struct XVars {
    /// `x[i][j]` LP column of `x_ij`.
    pub x: Vec<Vec<usize>>,
    pub obj_var: usize,
}

pub struct YVars {
    pub y: Vec<usize>,
    pub obj_var: usize,
}

/// Build the LP over `x` with `y` fixed.
pub fn build_lp_x(
    topo: &Topology,
    app: AppModel,
    cfg: BarrierConfig,
    y: &[f64],
    objective: Objective,
) -> (Lp, XVars) {
    let (s, m, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
    assert_eq!(y.len(), r);
    let alpha = app.alpha;
    let mut lp = Lp::new();

    // Decision variables.
    let x: Vec<Vec<usize>> = (0..s)
        .map(|i| (0..m).map(|j| lp.var(format!("x[{i}][{j}]"))).collect())
        .collect();
    let push_end = lp.vars("push_end", m);
    let map_end = lp.vars("map_end", m);
    let shuffle_end = lp.vars("shuffle_end", r);
    let t = lp.var("T");
    // Explicit per-mapper load variables `load_j = Σ_i D_i x_ij`: factoring
    // the repeated subexpression turns every (s+2)-term map/shuffle
    // epigraph row into a 3-term row — the ~s-fold sparsity win that makes
    // the revised simplex cheap on 256-node instances.
    let load = lp.vars("load", m);

    // (eq 2) rows sum to one.
    for i in 0..s {
        let row: Vec<(usize, f64)> = (0..m).map(|j| (x[i][j], 1.0)).collect();
        lp.constraint(&row, Cmp::Eq, 1.0);
    }

    // Load definitions.
    for j in 0..m {
        let mut terms: Vec<(usize, f64)> = Vec::with_capacity(s + 1);
        for i in 0..s {
            terms.push((x[i][j], topo.d[i]));
        }
        terms.push((load[j], -1.0));
        lp.constraint(&terms, Cmp::Eq, 0.0);
    }

    // (eq 4) push_end_j ≥ D_i x_ij / B_ij.
    for j in 0..m {
        for i in 0..s {
            let coef = topo.d[i] / topo.b_sm.get(i, j);
            lp.constraint(&[(push_end[j], 1.0), (x[i][j], -coef)], Cmp::Ge, 0.0);
        }
    }

    // (eqs 5/6/12) map phase.
    let gp = match cfg.push_map {
        Barrier::Global => {
            let gp = lp.var("push_max");
            for j in 0..m {
                lp.constraint(&[(gp, 1.0), (push_end[j], -1.0)], Cmp::Ge, 0.0);
            }
            Some(gp)
        }
        _ => None,
    };
    for j in 0..m {
        let scale = 1.0 / topo.c_map[j];
        match cfg.push_map {
            Barrier::Global => {
                // map_end_j ≥ gp + load_j/C_j
                lp.constraint(
                    &[(map_end[j], 1.0), (gp.unwrap(), -1.0), (load[j], -scale)],
                    Cmp::Ge,
                    0.0,
                );
            }
            Barrier::Local => {
                lp.constraint(
                    &[(map_end[j], 1.0), (push_end[j], -1.0), (load[j], -scale)],
                    Cmp::Ge,
                    0.0,
                );
            }
            Barrier::Pipelined => {
                lp.constraint(&[(map_end[j], 1.0), (push_end[j], -1.0)], Cmp::Ge, 0.0);
                lp.constraint(&[(map_end[j], 1.0), (load[j], -scale)], Cmp::Ge, 0.0);
            }
        }
    }

    // (eqs 7/8/13) shuffle phase; cost_jk = (α·y_k/B_jk)·load_j. Reducers
    // with no effective key share (α·y_k = 0) incur no transfer time, so
    // their per-mapper cost rows collapse to start-only rows — a single
    // row under a global barrier. One-hot shuffle splits (the §1.3
    // consolidation starts) prune almost the whole block this way.
    let gm = match cfg.map_shuffle {
        Barrier::Global => {
            let gm = lp.var("map_max");
            for j in 0..m {
                lp.constraint(&[(gm, 1.0), (map_end[j], -1.0)], Cmp::Ge, 0.0);
            }
            Some(gm)
        }
        _ => None,
    };
    for k in 0..r {
        if alpha * y[k] <= 0.0 {
            match cfg.map_shuffle {
                Barrier::Global => {
                    lp.constraint(
                        &[(shuffle_end[k], 1.0), (gm.unwrap(), -1.0)],
                        Cmp::Ge,
                        0.0,
                    );
                }
                _ => {
                    for j in 0..m {
                        lp.constraint(
                            &[(shuffle_end[k], 1.0), (map_end[j], -1.0)],
                            Cmp::Ge,
                            0.0,
                        );
                    }
                }
            }
            continue;
        }
        for j in 0..m {
            let coef = alpha * y[k] / topo.b_mr.get(j, k);
            match cfg.map_shuffle {
                Barrier::Global => {
                    lp.constraint(
                        &[(shuffle_end[k], 1.0), (gm.unwrap(), -1.0), (load[j], -coef)],
                        Cmp::Ge,
                        0.0,
                    );
                }
                Barrier::Local => {
                    lp.constraint(
                        &[(shuffle_end[k], 1.0), (map_end[j], -1.0), (load[j], -coef)],
                        Cmp::Ge,
                        0.0,
                    );
                }
                Barrier::Pipelined => {
                    lp.constraint(
                        &[(shuffle_end[k], 1.0), (map_end[j], -1.0)],
                        Cmp::Ge,
                        0.0,
                    );
                    lp.constraint(
                        &[(shuffle_end[k], 1.0), (load[j], -coef)],
                        Cmp::Ge,
                        0.0,
                    );
                }
            }
        }
    }

    // (eqs 9/10/14) reduce phase; rcost_k = α·D_total·y_k / C_k is a
    // *constant* in the x-LP, so rows sharing a variable pattern are
    // dominated by the largest rcost and pruned (r rows → 1 under global
    // and pipelined shuffle-reduce boundaries).
    let d_total = topo.total_data();
    let rcost = |k: usize| alpha * d_total * y[k] / topo.c_red[k];
    let rcost_max = (0..r).map(rcost).fold(0.0f64, f64::max);
    match cfg.shuffle_reduce {
        Barrier::Global => {
            let gs = lp.var("shuffle_max");
            for k in 0..r {
                lp.constraint(&[(gs, 1.0), (shuffle_end[k], -1.0)], Cmp::Ge, 0.0);
            }
            // T ≥ gs + rcost_k ∀k  ⟺  T ≥ gs + max_k rcost_k.
            lp.constraint(&[(t, 1.0), (gs, -1.0)], Cmp::Ge, rcost_max);
        }
        Barrier::Local => {
            for k in 0..r {
                lp.constraint(&[(t, 1.0), (shuffle_end[k], -1.0)], Cmp::Ge, rcost(k));
            }
        }
        Barrier::Pipelined => {
            for k in 0..r {
                lp.constraint(&[(t, 1.0), (shuffle_end[k], -1.0)], Cmp::Ge, 0.0);
            }
            // Single-variable row `T ≥ rcost_max` → implicit bound (free).
            lp.bound_below(t, rcost_max);
        }
    }

    // Objective.
    let obj_var = match objective {
        Objective::Makespan => t,
        Objective::PushTime => {
            let p = lp.var("push_sup");
            for j in 0..m {
                lp.constraint(&[(p, 1.0), (push_end[j], -1.0)], Cmp::Ge, 0.0);
            }
            p
        }
        Objective::ShuffleEnd => {
            let ssup = lp.var("shuffle_sup");
            for k in 0..r {
                lp.constraint(&[(ssup, 1.0), (shuffle_end[k], -1.0)], Cmp::Ge, 0.0);
            }
            ssup
        }
    };
    lp.minimize(obj_var, 1.0);

    (lp, XVars { x, obj_var })
}

/// Build the LP over `y` with `x` fixed. Push/map times are constants
/// (they do not depend on `y`), computed with the exact model.
pub fn build_lp_y(
    topo: &Topology,
    app: AppModel,
    cfg: BarrierConfig,
    x: &Mat,
    objective: Objective,
) -> (Lp, YVars) {
    let (_s, m, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
    let alpha = app.alpha;
    // Evaluate push/map with a dummy y (they are y-independent). The
    // incoming x may carry simplex drift; renormalize the probe copy.
    let mut probe = Plan { x: x.clone(), y: vec![1.0 / r as f64; r] };
    probe.renormalize();
    let tl = crate::model::makespan::evaluate(topo, app, cfg, &probe);
    let map_end = tl.map_end;
    let map_max = map_end.iter().cloned().fold(0.0, f64::max);
    let loads = probe.map_loads(&topo.d);

    let mut lp = Lp::new();
    let y: Vec<usize> = (0..r).map(|k| lp.var(format!("y[{k}]"))).collect();
    let shuffle_end = lp.vars("shuffle_end", r);
    let t = lp.var("T");

    // Σ_k y_k = 1.
    let row: Vec<(usize, f64)> = y.iter().map(|&v| (v, 1.0)).collect();
    lp.constraint(&row, Cmp::Eq, 1.0);

    // Shuffle rows; cost_jk = (α·load_j / B_jk)·y_k. Loads are constants
    // in the y-LP, so for each reducer the per-mapper rows share their
    // variable pattern and dominated ones are pruned:
    // * global barrier: identical rhs (map_max) → only the largest
    //   coefficient binds (m rows → 1);
    // * pipelined: constant start rows collapse to max_j map_end_j, cost
    //   rows to the largest coefficient (2m rows → 2);
    // * local: only the Pareto frontier of (coefficient, map_end_j)
    //   survives.
    for k in 0..r {
        let coef = |j: usize| alpha * loads[j] / topo.b_mr.get(j, k);
        match cfg.map_shuffle {
            Barrier::Global => {
                let cmax = (0..m).map(coef).fold(0.0f64, f64::max);
                lp.constraint(&[(shuffle_end[k], 1.0), (y[k], -cmax)], Cmp::Ge, map_max);
            }
            Barrier::Local => {
                let mut idx: Vec<usize> = (0..m).collect();
                // `total_cmp` on both keys: a NaN coefficient (NaN/zero
                // bandwidth entry) must not panic the Pareto sweep.
                idx.sort_by(|&a, &b| {
                    coef(b)
                        .total_cmp(&coef(a))
                        .then(map_end[b].total_cmp(&map_end[a]))
                });
                let mut best_rhs = f64::NEG_INFINITY;
                for &j in &idx {
                    if map_end[j] > best_rhs {
                        lp.constraint(
                            &[(shuffle_end[k], 1.0), (y[k], -coef(j))],
                            Cmp::Ge,
                            map_end[j],
                        );
                        best_rhs = map_end[j];
                    }
                }
            }
            Barrier::Pipelined => {
                // Start row `shuffle_end_k ≥ map_max` is single-variable
                // with a constant rhs → implicit bound (r rows saved).
                lp.bound_below(shuffle_end[k], map_max);
                let cmax = (0..m).map(coef).fold(0.0f64, f64::max);
                lp.constraint(&[(shuffle_end[k], 1.0), (y[k], -cmax)], Cmp::Ge, 0.0);
            }
        }
    }

    // Reduce rows.
    let d_total = topo.total_data();
    let gs = match cfg.shuffle_reduce {
        Barrier::Global => {
            let gs = lp.var("shuffle_max");
            for k in 0..r {
                lp.constraint(&[(gs, 1.0), (shuffle_end[k], -1.0)], Cmp::Ge, 0.0);
            }
            Some(gs)
        }
        _ => None,
    };
    for k in 0..r {
        let coef = alpha * d_total / topo.c_red[k];
        match cfg.shuffle_reduce {
            Barrier::Global => {
                lp.constraint(&[(t, 1.0), (gs.unwrap(), -1.0), (y[k], -coef)], Cmp::Ge, 0.0);
            }
            Barrier::Local => {
                lp.constraint(
                    &[(t, 1.0), (shuffle_end[k], -1.0), (y[k], -coef)],
                    Cmp::Ge,
                    0.0,
                );
            }
            Barrier::Pipelined => {
                lp.constraint(&[(t, 1.0), (shuffle_end[k], -1.0)], Cmp::Ge, 0.0);
                lp.constraint(&[(t, 1.0), (y[k], -coef)], Cmp::Ge, 0.0);
            }
        }
    }
    // The makespan can never undercut the (constant) map completion —
    // an implicit lower bound on T, not a row (every y-LP saves it).
    lp.bound_below(t, map_max);

    let obj_var = match objective {
        Objective::Makespan => t,
        Objective::ShuffleEnd => {
            let ssup = lp.var("shuffle_sup");
            for k in 0..r {
                lp.constraint(&[(ssup, 1.0), (shuffle_end[k], -1.0)], Cmp::Ge, 0.0);
            }
            ssup
        }
        Objective::PushTime => {
            panic!("PushTime objective is independent of y; use build_lp_x")
        }
    };
    lp.minimize(obj_var, 1.0);

    (lp, YVars { y, obj_var })
}

/// Extract the plan's `x` matrix from an LP solution.
pub fn extract_x(sol: &[f64], vars: &XVars) -> Mat {
    let s = vars.x.len();
    let m = vars.x[0].len();
    let mut x = Mat::zeros(s, m);
    for i in 0..s {
        for j in 0..m {
            x[(i, j)] = sol[vars.x[i][j]];
        }
    }
    x
}

/// Extract the plan's `y` vector from an LP solution.
pub fn extract_y(sol: &[f64], vars: &YVars) -> Vec<f64> {
    vars.y.iter().map(|&v| sol[v]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::makespan::{evaluate, makespan, push_time};
    use crate::platform::topology::example_1_3;
    use crate::platform::MB;
    use crate::solver::simplex::solve;

    fn topo() -> Topology {
        example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB)
    }

    /// The LP objective equals the exact model evaluation at the LP's own
    /// solution — the formulations agree.
    #[test]
    fn lp_x_objective_matches_model() {
        let t = topo();
        let app = AppModel::new(1.0);
        for cfg in [
            BarrierConfig::ALL_GLOBAL,
            BarrierConfig::HADOOP,
            BarrierConfig::ALL_PIPELINED,
        ] {
            let y = vec![0.5, 0.5];
            let (lp, vars) = build_lp_x(&t, app, cfg, &y, Objective::Makespan);
            let (sol, obj) = solve(&lp).expect_optimal("lp_x");
            let mut plan = Plan { x: extract_x(&sol, &vars), y: y.clone() };
            plan.renormalize();
            let ms = makespan(&t, app, cfg, &plan);
            let rel = (ms - obj).abs() / obj.max(1.0);
            assert!(rel < 1e-6, "cfg {cfg:?}: model {ms} vs LP {obj}");
        }
    }

    #[test]
    fn lp_y_objective_matches_model() {
        let t = topo();
        let app = AppModel::new(10.0);
        for cfg in [
            BarrierConfig::ALL_GLOBAL,
            BarrierConfig::HADOOP,
            BarrierConfig::ALL_PIPELINED,
        ] {
            let x = Plan::local_push(&t).x;
            let (lp, vars) = build_lp_y(&t, app, cfg, &x, Objective::Makespan);
            let (sol, obj) = solve(&lp).expect_optimal("lp_y");
            let mut plan = Plan { x: x.clone(), y: extract_y(&sol, &vars) };
            plan.renormalize();
            let ms = makespan(&t, app, cfg, &plan);
            let rel = (ms - obj).abs() / obj.max(1.0);
            assert!(rel < 1e-6, "cfg {cfg:?}: model {ms} vs LP {obj}");
        }
    }

    /// Regression (NaN-unsafe sort): the local-barrier Pareto sweep
    /// ranked mappers by shuffle coefficient with
    /// `partial_cmp(..).unwrap()`, which panics when a `b_mr` entry is
    /// NaN (dead-link probe / missing telemetry turns `loads/b` NaN).
    /// `f64::total_cmp` keeps the sweep deterministic and panic-free —
    /// the LP still builds and the NaN row is simply ranked first.
    /// Fails on the pre-fix code.
    #[test]
    fn lp_y_local_barrier_survives_nan_bandwidth() {
        let mut t = topo();
        t.b_mr[(0, 0)] = f64::NAN;
        let app = AppModel::new(10.0);
        let cfg = BarrierConfig::new(Barrier::Global, Barrier::Local, Barrier::Global);
        let x = Plan::local_push(&t).x;
        let (lp, vars) = build_lp_y(&t, app, cfg, &x, Objective::Makespan);
        assert_eq!(vars.y.len(), t.n_reducers());
        assert!(lp.n_rows() > 0);
    }

    /// Myopic push LP: matches the analytic waterfilling optimum
    /// `x_ij ∝ B_ij` (per-source minimax).
    #[test]
    fn push_lp_matches_waterfilling() {
        let t = topo();
        let app = AppModel::new(1.0);
        let y = vec![0.5, 0.5];
        let (lp, vars) = build_lp_x(&t, app, BarrierConfig::ALL_GLOBAL, &y, Objective::PushTime);
        let (sol, obj) = solve(&lp).expect_optimal("push lp");
        // Analytic: per source, time = D_i / Σ_j B_ij; overall max.
        let expect = (0..2)
            .map(|i| t.d[i] / (0..2).map(|j| t.b_sm.get(i, j)).sum::<f64>())
            .fold(0.0, f64::max);
        assert!((obj - expect).abs() / expect < 1e-8, "obj {obj} vs {expect}");
        let mut plan = Plan { x: extract_x(&sol, &vars), y };
        plan.renormalize();
        assert!((push_time(&t, &plan) - expect).abs() / expect < 1e-6);
    }

    /// LP-optimal x beats both uniform and local push end-to-end.
    #[test]
    fn lp_x_improves_over_heuristics() {
        let t = topo();
        for &alpha in &[0.1, 1.0, 10.0] {
            let app = AppModel::new(alpha);
            let cfg = BarrierConfig::ALL_GLOBAL;
            let y = vec![0.5, 0.5];
            let (lp, vars) = build_lp_x(&t, app, cfg, &y, Objective::Makespan);
            let (sol, _) = solve(&lp).expect_optimal("lp");
            let mut plan = Plan { x: extract_x(&sol, &vars), y: y.clone() };
            plan.renormalize();
            let opt = makespan(&t, app, cfg, &plan);
            let uni = makespan(&t, app, cfg, &Plan::uniform(2, 2, 2));
            let local = {
                let mut p = Plan::local_push(&t);
                p.y = y.clone();
                makespan(&t, app, cfg, &p)
            };
            assert!(opt <= uni + 1e-6, "α={alpha}: {opt} vs uniform {uni}");
            assert!(opt <= local + 1e-6, "α={alpha}: {opt} vs local {local}");
        }
    }

    /// Shuffle-end objective: y concentrates away from slow links.
    #[test]
    fn shuffle_lp_prefers_fast_reducers() {
        let t = topo();
        let app = AppModel::new(10.0);
        // Everything is at mapper 0 (cluster 1).
        let mut x = Mat::zeros(2, 2);
        x[(0, 0)] = 1.0;
        x[(1, 0)] = 1.0;
        let (lp, vars) = build_lp_y(&t, app, BarrierConfig::ALL_GLOBAL, &x, Objective::ShuffleEnd);
        let (sol, _) = solve(&lp).expect_optimal("shuffle lp");
        let y = extract_y(&sol, &vars);
        // Reducer 0 is local to mapper 0 (fast); it should get the bulk.
        assert!(y[0] > 0.85, "y = {y:?}");
    }

    /// Full timeline consistency: LP's internal time variables are
    /// dominated by the model's exact evaluation at the extracted plan.
    #[test]
    fn lp_times_consistent_with_model_times() {
        let t = topo();
        let app = AppModel::new(2.0);
        let cfg = BarrierConfig::HADOOP;
        let y = vec![0.3, 0.7];
        let (lp, vars) = build_lp_x(&t, app, cfg, &y, Objective::Makespan);
        let (sol, obj) = solve(&lp).expect_optimal("lp");
        let mut plan = Plan { x: extract_x(&sol, &vars), y };
        plan.renormalize();
        let tl = evaluate(&t, app, cfg, &plan);
        assert!(tl.makespan <= obj * (1.0 + 1e-9) + 1e-9);
    }
}
