//! The paper-faithful §2.3 optimizer: one monolithic MIP over *both*
//! `x` and `y`, with the bilinear shuffle terms `m_j·y_k` rewritten in
//! separable form and piecewise-linearized ([`crate::solver::pwl`]).
//!
//! The paper solves this with Gurobi 5.0; our branch & bound handles the
//! small instances (2–3 nodes per tier) we use to *cross-validate* the
//! alternating-LP optimizer — at 8×8×8 the PWL formulation has
//! `|M|·|R| = 64` products × 9 binary segment selectors each, beyond a
//! naive B&B (see DESIGN.md §3). Use [`super::alternating`] there.

use super::PlanOptimizer;
use crate::model::barrier::{Barrier, BarrierConfig};
use crate::model::makespan::AppModel;
use crate::model::plan::Plan;
use crate::platform::Topology;
use crate::solver::lp::{Cmp, Lp};
use crate::solver::mip::{solve_binary, MipConfig, MipOutcome};
use crate::solver::pwl::{add_product, DEFAULT_POINTS};
use crate::util::mat::Mat;

/// PWL-MIP end-to-end multi-phase optimizer.
#[derive(Debug, Clone, Copy)]
pub struct PwlMipOptimizer {
    /// Breakpoints per quadratic (paper: ~10).
    pub n_points: usize,
    pub mip: MipConfig,
}

impl Default for PwlMipOptimizer {
    fn default() -> Self {
        PwlMipOptimizer { n_points: DEFAULT_POINTS, mip: MipConfig::default() }
    }
}

impl PlanOptimizer for PwlMipOptimizer {
    fn name(&self) -> &'static str {
        "e2e-multi-mip"
    }

    fn optimize(&self, topo: &Topology, app: AppModel, cfg: BarrierConfig) -> Plan {
        let (s, m, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
        let alpha = app.alpha;
        let d_total = topo.total_data();
        let mut lp = Lp::new();

        // Decision variables.
        let x: Vec<Vec<usize>> = (0..s)
            .map(|i| (0..m).map(|j| lp.var(format!("x[{i}][{j}]"))).collect())
            .collect();
        let y: Vec<usize> = (0..r).map(|k| lp.var(format!("y[{k}]"))).collect();
        // u_j = m_j / D_total ∈ [0,1].
        let u: Vec<usize> = (0..m).map(|j| lp.var(format!("u[{j}]"))).collect();
        let push_end = lp.vars("push_end", m);
        let map_end = lp.vars("map_end", m);
        let shuffle_end = lp.vars("shuffle_end", r);
        let t = lp.var("T");

        // Simplex constraints (eqs 1–2) and u definition.
        for i in 0..s {
            let row: Vec<(usize, f64)> = (0..m).map(|j| (x[i][j], 1.0)).collect();
            lp.constraint(&row, Cmp::Eq, 1.0);
        }
        {
            let row: Vec<(usize, f64)> = y.iter().map(|&v| (v, 1.0)).collect();
            lp.constraint(&row, Cmp::Eq, 1.0);
        }
        for j in 0..m {
            // u_j·D_total − Σ_i D_i x_ij = 0
            let mut row: Vec<(usize, f64)> = vec![(u[j], d_total)];
            for i in 0..s {
                row.push((x[i][j], -topo.d[i]));
            }
            lp.constraint(&row, Cmp::Eq, 0.0);
        }

        // Bilinear products p_jk ≈ u_j · y_k.
        let mut binaries = Vec::new();
        let mut p = Mat::zeros(m, r);
        let mut p_vars = vec![vec![0usize; r]; m];
        for j in 0..m {
            for k in 0..r {
                let pw = add_product(&mut lp, u[j], y[k], self.n_points);
                p_vars[j][k] = pw.product;
                binaries.extend(pw.binaries);
            }
        }

        // (eq 4) push rows.
        for j in 0..m {
            for i in 0..s {
                let coef = topo.d[i] / topo.b_sm.get(i, j);
                lp.constraint(&[(push_end[j], 1.0), (x[i][j], -coef)], Cmp::Ge, 0.0);
            }
        }

        // map phase (eqs 5/6/12); load_j = u_j·D_total.
        let gp = match cfg.push_map {
            Barrier::Global => {
                let gp = lp.var("push_max");
                for j in 0..m {
                    lp.constraint(&[(gp, 1.0), (push_end[j], -1.0)], Cmp::Ge, 0.0);
                }
                Some(gp)
            }
            _ => None,
        };
        for j in 0..m {
            let load_coef = d_total / topo.c_map[j];
            match cfg.push_map {
                Barrier::Global => {
                    lp.constraint(
                        &[(map_end[j], 1.0), (gp.unwrap(), -1.0), (u[j], -load_coef)],
                        Cmp::Ge,
                        0.0,
                    );
                }
                Barrier::Local => {
                    lp.constraint(
                        &[(map_end[j], 1.0), (push_end[j], -1.0), (u[j], -load_coef)],
                        Cmp::Ge,
                        0.0,
                    );
                }
                Barrier::Pipelined => {
                    lp.constraint(&[(map_end[j], 1.0), (push_end[j], -1.0)], Cmp::Ge, 0.0);
                    lp.constraint(&[(map_end[j], 1.0), (u[j], -load_coef)], Cmp::Ge, 0.0);
                }
            }
        }

        // shuffle (eqs 7/8/13): cost_jk = α·D_total·p_jk / B_jk.
        let gm = match cfg.map_shuffle {
            Barrier::Global => {
                let gm = lp.var("map_max");
                for j in 0..m {
                    lp.constraint(&[(gm, 1.0), (map_end[j], -1.0)], Cmp::Ge, 0.0);
                }
                Some(gm)
            }
            _ => None,
        };
        for k in 0..r {
            for j in 0..m {
                let coef = alpha * d_total / topo.b_mr.get(j, k);
                match cfg.map_shuffle {
                    Barrier::Global => {
                        lp.constraint(
                            &[
                                (shuffle_end[k], 1.0),
                                (gm.unwrap(), -1.0),
                                (p_vars[j][k], -coef),
                            ],
                            Cmp::Ge,
                            0.0,
                        );
                    }
                    Barrier::Local => {
                        lp.constraint(
                            &[
                                (shuffle_end[k], 1.0),
                                (map_end[j], -1.0),
                                (p_vars[j][k], -coef),
                            ],
                            Cmp::Ge,
                            0.0,
                        );
                    }
                    Barrier::Pipelined => {
                        lp.constraint(
                            &[(shuffle_end[k], 1.0), (map_end[j], -1.0)],
                            Cmp::Ge,
                            0.0,
                        );
                        lp.constraint(
                            &[(shuffle_end[k], 1.0), (p_vars[j][k], -coef)],
                            Cmp::Ge,
                            0.0,
                        );
                    }
                }
            }
        }

        // reduce (eqs 9/10/14): rcost_k = α·D_total·y_k / C_k (linear!).
        let gs = match cfg.shuffle_reduce {
            Barrier::Global => {
                let gs = lp.var("shuffle_max");
                for k in 0..r {
                    lp.constraint(&[(gs, 1.0), (shuffle_end[k], -1.0)], Cmp::Ge, 0.0);
                }
                Some(gs)
            }
            _ => None,
        };
        for k in 0..r {
            let coef = alpha * d_total / topo.c_red[k];
            match cfg.shuffle_reduce {
                Barrier::Global => {
                    lp.constraint(
                        &[(t, 1.0), (gs.unwrap(), -1.0), (y[k], -coef)],
                        Cmp::Ge,
                        0.0,
                    );
                }
                Barrier::Local => {
                    lp.constraint(
                        &[(t, 1.0), (shuffle_end[k], -1.0), (y[k], -coef)],
                        Cmp::Ge,
                        0.0,
                    );
                }
                Barrier::Pipelined => {
                    lp.constraint(&[(t, 1.0), (shuffle_end[k], -1.0)], Cmp::Ge, 0.0);
                    lp.constraint(&[(t, 1.0), (y[k], -coef)], Cmp::Ge, 0.0);
                }
            }
        }

        lp.minimize(t, 1.0);

        match solve_binary(&lp, &binaries, self.mip) {
            MipOutcome::Optimal { x: sol, .. } => {
                for j in 0..m {
                    for k in 0..r {
                        p[(j, k)] = sol[p_vars[j][k]];
                    }
                }
                let mut xm = Mat::zeros(s, m);
                for i in 0..s {
                    for j in 0..m {
                        xm[(i, j)] = sol[x[i][j]];
                    }
                }
                let yv: Vec<f64> = y.iter().map(|&v| sol[v]).collect();
                let mut plan = Plan { x: xm, y: yv };
                plan.renormalize();
                plan
            }
            other => panic!("PWL-MIP solve failed: {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::makespan::makespan;
    use crate::optimizer::alternating::AlternatingLp;
    use crate::platform::topology::example_1_3;
    use crate::platform::MB;

    /// On the §1.3 instance the paper-faithful MIP and the alternating LP
    /// must land within the PWL approximation error of each other.
    #[test]
    fn mip_and_alternating_agree_on_example_1_3() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let cfg = BarrierConfig::ALL_GLOBAL;
        for &alpha in &[0.1, 1.0, 10.0] {
            let app = AppModel::new(alpha);
            let mip_plan = PwlMipOptimizer::default().optimize(&t, app, cfg);
            mip_plan.check(&t).unwrap();
            let alt_plan = AlternatingLp::default().optimize(&t, app, cfg);
            let ms_mip = makespan(&t, app, cfg, &mip_plan);
            let ms_alt = makespan(&t, app, cfg, &alt_plan);
            // MIP is approximate (PWL); allow 8% slack either way.
            let rel = (ms_mip - ms_alt).abs() / ms_alt;
            assert!(
                rel < 0.08,
                "α={alpha}: MIP {ms_mip} vs alternating {ms_alt} (rel {rel})"
            );
        }
    }

    #[test]
    fn mip_beats_uniform() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let cfg = BarrierConfig::ALL_GLOBAL;
        let app = AppModel::new(10.0);
        let plan = PwlMipOptimizer::default().optimize(&t, app, cfg);
        let ms = makespan(&t, app, cfg, &plan);
        let uni = makespan(&t, app, cfg, &Plan::uniform(2, 2, 2));
        assert!(ms < uni, "MIP {ms} vs uniform {uni}");
    }
}
