//! Parameterized topology generators beyond the paper's four 8-node
//! environments (§4.1): hierarchical WANs, federated multi-datacenter
//! fabrics, and edge-heavy deployments, from 16 to [`MAX_NODES`] (4096)
//! nodes.
//!
//! The paper validates its optimizer on an emulated PlanetLab testbed
//! with eight nodes of each role; the geo-distributed MapReduce survey
//! (Dolev et al., arXiv:1707.01869) and communication-pattern modelling
//! work (Ceesay et al., arXiv:2005.11608) both point at much larger and
//! more varied platforms. These generators produce such platforms as
//! ordinary [`Topology`] values, so every optimizer, the closed-form
//! model and the engine run on them unchanged. Every generator is
//! deterministic given its seed — experiments and tests reproduce
//! bit-for-bit.

use super::topology::{Continent, Topology, TopologyBuilder, GB, MB};
use crate::util::rng::Pcg64;

/// Intra-cluster (LAN) bandwidth, matching the PlanetLab testbed fabric.
const LAN: f64 = 125.0 * MB;

/// The generated deployment shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleKind {
    /// Clusters arranged in a bandwidth tree: LAN inside a cluster, fast
    /// metro links within a region, continental backbone between regions,
    /// slow WAN across continents.
    HierarchicalWan,
    /// N comparably provisioned data centers joined by heterogeneous,
    /// directional inter-datacenter links (the geo-federated setting).
    FederatedDataCenters,
    /// Many weak edge sites generating data behind thin uplinks, few
    /// powerful core sites doing the reducing (IoT / edge analytics).
    EdgeHeavy,
}

impl ScaleKind {
    pub fn all() -> [ScaleKind; 3] {
        [
            ScaleKind::HierarchicalWan,
            ScaleKind::FederatedDataCenters,
            ScaleKind::EdgeHeavy,
        ]
    }

    pub fn label(&self) -> &'static str {
        match self {
            ScaleKind::HierarchicalWan => "hier-wan",
            ScaleKind::FederatedDataCenters => "federated",
            ScaleKind::EdgeHeavy => "edge-heavy",
        }
    }
}

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct ScaleConfig {
    pub kind: ScaleKind,
    /// Total node budget across all three roles
    /// (sources + mappers + reducers); must be ≥ 6.
    pub nodes: usize,
    pub seed: u64,
    /// Input data held by each source (the *mean* when `skew > 0`).
    pub data_per_source: f64,
    /// Zipf-ish data-volume skew across sources: source `i` holds data
    /// proportional to `(i+1)^-skew`, normalized so the total volume is
    /// unchanged. `0` (the default) keeps the historical uniform volumes
    /// bit-for-bit; real geo-distributed deployments are skewed (a few
    /// hot sites hold most of the data), which is what makes push-plan
    /// choice hard.
    pub skew: f64,
}

/// Default generator seed (any value works; fixed for reproducibility).
pub const DEFAULT_SEED: u64 = 0x5CA1E;

/// Largest supported generated topology. The generators allocate
/// O(clusters²) bandwidth matrices and the engine run at this size is
/// bench-gated under a second (`benches/bench_main.rs`); the CLI and the
/// scale/churn sweeps all share this single cap.
pub const MAX_NODES: usize = 4096;

impl ScaleConfig {
    pub fn new(kind: ScaleKind, nodes: usize) -> ScaleConfig {
        ScaleConfig { kind, nodes, seed: DEFAULT_SEED, data_per_source: 1.0 * GB, skew: 0.0 }
    }

    pub fn seed(mut self, seed: u64) -> ScaleConfig {
        self.seed = seed;
        self
    }

    pub fn data_per_source(mut self, bytes: f64) -> ScaleConfig {
        assert!(bytes > 0.0 && bytes.is_finite());
        self.data_per_source = bytes;
        self
    }

    pub fn skew(mut self, skew: f64) -> ScaleConfig {
        assert!(skew >= 0.0 && skew.is_finite(), "skew must be ≥ 0, got {skew}");
        self.skew = skew;
        self
    }
}

/// Per-source data volumes under the config's skew: Zipf weights
/// `(i+1)^-skew` scaled so the mean stays `data_per_source` (total data
/// volume is invariant in the skew). Skew 0 returns exactly uniform
/// volumes, keeping default-generated topologies bit-identical.
fn source_volumes(cfg: &ScaleConfig, n: usize) -> Vec<f64> {
    if cfg.skew == 0.0 {
        return vec![cfg.data_per_source; n];
    }
    let w: Vec<f64> = (0..n).map(|i| ((i + 1) as f64).powf(-cfg.skew)).collect();
    let mean = w.iter().sum::<f64>() / n as f64;
    w.into_iter().map(|wi| cfg.data_per_source * wi / mean).collect()
}

/// Generate a topology. Panics if `cfg.nodes < 6` (two clusters of one
/// node per role is the smallest sensible instance).
pub fn generate(cfg: &ScaleConfig) -> Topology {
    assert!(cfg.nodes >= 6, "need at least 6 nodes, got {}", cfg.nodes);
    match cfg.kind {
        ScaleKind::HierarchicalWan => hierarchical_wan(cfg),
        ScaleKind::FederatedDataCenters => federated(cfg),
        ScaleKind::EdgeHeavy => edge_heavy(cfg),
    }
}

/// Convenience wrapper: generate with default data volume.
pub fn generate_kind(kind: ScaleKind, nodes: usize, seed: u64) -> Topology {
    generate(&ScaleConfig::new(kind, nodes).seed(seed))
}

/// Parse a CLI generator spec `kind:nodes[:seed]` (e.g. `hier-wan:256`,
/// `federated:64:9`) into a config — callers can layer further knobs
/// (`--skew`, data volume) on top before generating.
pub fn parse_spec_config(spec: &str) -> Result<ScaleConfig, String> {
    let parts: Vec<&str> = spec.split(':').collect();
    if parts.len() < 2 || parts.len() > 3 {
        return Err(format!("bad generator spec '{spec}' (want kind:nodes[:seed])"));
    }
    let kind = ScaleKind::all()
        .into_iter()
        .find(|k| k.label() == parts[0])
        .ok_or_else(|| {
            format!("unknown topology kind '{}' (hier-wan | federated | edge-heavy)", parts[0])
        })?;
    let nodes: usize = parts[1]
        .parse()
        .map_err(|_| format!("bad node count '{}'", parts[1]))?;
    if nodes < 6 {
        return Err("generated topologies need at least 6 nodes".to_string());
    }
    if nodes > MAX_NODES {
        // The generators allocate O(clusters²) bandwidth matrices; keep a
        // CLI typo from turning into an OOM abort.
        return Err(format!("node count {nodes} too large (max {MAX_NODES})"));
    }
    let seed: u64 = if parts.len() == 3 {
        parts[2].parse().map_err(|_| format!("bad seed '{}'", parts[2]))?
    } else {
        DEFAULT_SEED
    };
    Ok(ScaleConfig::new(kind, nodes).seed(seed))
}

/// Parse a CLI generator spec and generate the topology.
pub fn parse_spec(spec: &str) -> Result<Topology, String> {
    Ok(generate(&parse_spec_config(spec)?))
}

/// Continent of a region index (regions cycle through the continents).
fn continent(region: usize) -> Continent {
    match region % 3 {
        0 => Continent::US,
        1 => Continent::EU,
        _ => Continent::Asia,
    }
}

/// Log-uniform draw in `[lo, hi]` (bandwidths are naturally log-spread,
/// like the Table 1 ranges).
fn log_uniform(rng: &mut Pcg64, lo: f64, hi: f64) -> f64 {
    (lo.ln() + rng.next_f64() * (hi.ln() - lo.ln())).exp()
}

/// Leaf clusters of ~4 nodes per role, 4 clusters per region, regions
/// spread over continents. Bandwidth falls with tree distance.
fn hierarchical_wan(cfg: &ScaleConfig) -> Topology {
    let mut rng = Pcg64::new(cfg.seed);
    let per_role = (cfg.nodes / 3).max(2);
    let n_clusters = ((per_role + 3) / 4).max(2);

    let mut b = TopologyBuilder::new(format!("hier-wan-{}", cfg.nodes));
    let mut compute = Vec::with_capacity(n_clusters);
    for c in 0..n_clusters {
        b.cluster(&format!("hier-c{c}"), continent(c / 4));
        compute.push(rng.uniform(20.0, 90.0) * MB);
    }
    let dvol = source_volumes(cfg, per_role);
    for i in 0..per_role {
        let c = i % n_clusters;
        b.source(c, dvol[i]);
        b.mapper(c, compute[c]);
        b.reducer(c, compute[c]);
    }

    let region = |c: usize| c / 4;
    let mut bw = vec![vec![0.0f64; n_clusters]; n_clusters];
    for a in 0..n_clusters {
        for c2 in 0..n_clusters {
            bw[a][c2] = if a == c2 {
                LAN
            } else if region(a) == region(c2) {
                // Metro links inside a region.
                log_uniform(&mut rng, 20.0 * MB, 60.0 * MB)
            } else if continent(region(a)) == continent(region(c2)) {
                // Continental backbone between regions.
                log_uniform(&mut rng, 4.0 * MB, 15.0 * MB)
            } else {
                // Intercontinental WAN.
                log_uniform(&mut rng, 0.5 * MB, 3.0 * MB)
            };
        }
    }
    b.build_with_bandwidth(|a, c2| bw[a][c2])
}

/// ~8 nodes of each role per data center (the §4.1 granularity), all DCs
/// comparably provisioned, inter-DC links heterogeneous and directional.
fn federated(cfg: &ScaleConfig) -> Topology {
    let mut rng = Pcg64::new(cfg.seed ^ 0xFEDE_47ED);
    let per_role = (cfg.nodes / 3).max(2);
    let n_dc = ((per_role + 7) / 8).max(2);

    let mut b = TopologyBuilder::new(format!("federated-{}", cfg.nodes));
    let mut compute = Vec::with_capacity(n_dc);
    for c in 0..n_dc {
        b.cluster(&format!("dc{c}"), continent(c));
        compute.push(rng.uniform(40.0, 90.0) * MB);
    }
    let dvol = source_volumes(cfg, per_role);
    for i in 0..per_role {
        let c = i % n_dc;
        b.source(c, dvol[i]);
        b.mapper(c, compute[c]);
        b.reducer(c, compute[c]);
    }

    let mut bw = vec![vec![0.0f64; n_dc]; n_dc];
    for a in 0..n_dc {
        for c2 in 0..n_dc {
            bw[a][c2] = if a == c2 { LAN } else { log_uniform(&mut rng, 2.0 * MB, 50.0 * MB) };
        }
    }
    b.build_with_bandwidth(|a, c2| bw[a][c2])
}

/// Asymmetric roles: ~45% sources and ~45% mappers at weak edge sites,
/// ~10% reducers at a couple of powerful core sites; thin edge uplinks.
fn edge_heavy(cfg: &ScaleConfig) -> Topology {
    let mut rng = Pcg64::new(cfg.seed ^ 0x00ED_6E00);
    let n_sources = (cfg.nodes * 9 / 20).max(2);
    let n_reducers = (cfg.nodes / 10).max(1);
    let n_mappers = cfg.nodes.saturating_sub(n_sources + n_reducers).max(2);

    let n_core = 2usize;
    let n_edge = ((n_sources + 3) / 4).max(1);
    let n_clusters = n_core + n_edge;

    let mut b = TopologyBuilder::new(format!("edge-heavy-{}", cfg.nodes));
    let mut compute = Vec::with_capacity(n_clusters);
    for c in 0..n_clusters {
        if c < n_core {
            b.cluster(&format!("core{c}"), continent(c));
            compute.push(rng.uniform(60.0, 90.0) * MB);
        } else {
            b.cluster(&format!("edge{}", c - n_core), continent(c));
            compute.push(rng.uniform(5.0, 20.0) * MB);
        }
    }
    // Sources live at the edge.
    let dvol = source_volumes(cfg, n_sources);
    for i in 0..n_sources {
        b.source(n_core + (i % n_edge), dvol[i]);
    }
    // Mappers: two thirds co-located with the data at the edge, the rest
    // in the core. A dedicated counter cycles the edge clusters so none
    // is starved of mappers (i % n_edge composed with i % 3 would skip
    // residues).
    let mut edge_mapper = 0usize;
    for i in 0..n_mappers {
        let c = if i % 3 < 2 {
            let c = n_core + (edge_mapper % n_edge);
            edge_mapper += 1;
            c
        } else {
            i % n_core
        };
        b.mapper(c, compute[c]);
    }
    // Reducers run in the core.
    for i in 0..n_reducers {
        let c = i % n_core;
        b.reducer(c, compute[c]);
    }

    let mut bw = vec![vec![0.0f64; n_clusters]; n_clusters];
    for a in 0..n_clusters {
        for c2 in 0..n_clusters {
            let a_core = a < n_core;
            let b_core = c2 < n_core;
            bw[a][c2] = if a == c2 {
                LAN
            } else if a_core && b_core {
                // Core interconnect.
                log_uniform(&mut rng, 40.0 * MB, 80.0 * MB)
            } else if !a_core && b_core {
                // Edge uplink — the bottleneck that makes plan choice
                // matter.
                log_uniform(&mut rng, 1.0 * MB, 8.0 * MB)
            } else if a_core && !b_core {
                // Core-to-edge downlink.
                log_uniform(&mut rng, 2.0 * MB, 10.0 * MB)
            } else {
                // Edge-to-edge (rarely useful).
                log_uniform(&mut rng, 0.5 * MB, 2.0 * MB)
            };
        }
    }
    b.build_with_bandwidth(|a, c2| bw[a][c2])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_kinds_validate_across_sizes() {
        for kind in ScaleKind::all() {
            for nodes in [16usize, 64, 256] {
                let t = generate_kind(kind, nodes, 1);
                t.validate();
                let total = t.n_sources() + t.n_mappers() + t.n_reducers();
                assert!(
                    total >= nodes * 9 / 10 && total <= nodes + 3,
                    "{kind:?} nodes={nodes}: built {total} nodes"
                );
                assert!(t.clusters.len() >= 2, "{kind:?} needs ≥2 clusters");
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        for kind in ScaleKind::all() {
            let a = generate_kind(kind, 64, 7);
            let b = generate_kind(kind, 64, 7);
            let c = generate_kind(kind, 64, 8);
            assert_eq!(a.b_sm, b.b_sm, "{kind:?} not deterministic");
            assert_eq!(a.c_map, b.c_map);
            assert_ne!(a.b_sm, c.b_sm, "{kind:?} seed has no effect");
        }
    }

    #[test]
    fn hierarchical_wan_bandwidth_spreads_with_distance() {
        let t = generate_kind(ScaleKind::HierarchicalWan, 256, 3);
        let min_b = t.b_sm.data().iter().cloned().fold(f64::INFINITY, f64::min);
        let max_b = t.b_sm.data().iter().cloned().fold(0.0, f64::max);
        assert!(
            max_b / min_b > 20.0,
            "hier-wan should span orders of magnitude: {min_b}..{max_b}"
        );
        assert_eq!(max_b, 125.0 * MB, "intra-cluster links are LAN");
    }

    #[test]
    fn edge_heavy_is_source_rich_and_reducer_poor() {
        let t = generate_kind(ScaleKind::EdgeHeavy, 100, 5);
        assert!(t.n_sources() > 3 * t.n_reducers());
        assert!(t.n_mappers() > t.n_reducers());
        // Core reducers are faster than the weakest edge mapper.
        let min_map = t.c_map.iter().cloned().fold(f64::INFINITY, f64::min);
        let min_red = t.c_red.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min_red > min_map);
    }

    #[test]
    fn federated_has_uniform_roles_per_dc() {
        let t = generate_kind(ScaleKind::FederatedDataCenters, 48, 2);
        assert_eq!(t.n_sources(), t.n_mappers());
        assert_eq!(t.n_mappers(), t.n_reducers());
        assert_eq!(t.clusters.len(), 2);
    }

    #[test]
    fn parse_spec_round_trips() {
        let t = parse_spec("hier-wan:64").unwrap();
        assert_eq!(t.name, "hier-wan-64");
        let t = parse_spec("federated:48:9").unwrap();
        assert_eq!(t.name, "federated-48");
        assert!(parse_spec("nope:64").is_err());
        assert!(parse_spec("hier-wan").is_err());
        assert!(parse_spec("hier-wan:3").is_err());
        assert!(parse_spec("hier-wan:64:x").is_err());
        assert!(parse_spec("hier-wan:400000000").is_err());
    }

    /// The cap, the error message, and the sweep bounds all come from the
    /// shared `MAX_NODES`: the boundary is accepted, one past it is
    /// rejected with an error naming the real limit.
    #[test]
    fn node_cap_is_exact_and_named_in_error() {
        let at_cap = parse_spec_config(&format!("hier-wan:{MAX_NODES}"));
        assert!(at_cap.is_ok(), "{MAX_NODES} nodes must be accepted");
        assert_eq!(at_cap.unwrap().nodes, MAX_NODES);
        let over = parse_spec_config(&format!("hier-wan:{}", MAX_NODES + 1));
        let msg = over.unwrap_err();
        assert!(
            msg.contains(&MAX_NODES.to_string()),
            "rejection must name the cap: {msg}"
        );
    }

    #[test]
    fn data_per_source_is_respected() {
        let t = generate(&ScaleConfig::new(ScaleKind::HierarchicalWan, 32).data_per_source(2.0 * GB));
        assert!(t.d.iter().all(|&d| d == 2.0 * GB));
    }

    #[test]
    fn zero_skew_is_exactly_uniform() {
        // skew = 0 must reproduce the historical volumes bit-for-bit.
        for kind in ScaleKind::all() {
            let a = generate(&ScaleConfig::new(kind, 64).seed(3));
            let b = generate(&ScaleConfig::new(kind, 64).seed(3).skew(0.0));
            assert_eq!(a.d, b.d, "{kind:?}");
            assert!(a.d.iter().all(|&d| d == 1.0 * GB));
        }
    }

    #[test]
    fn skew_concentrates_volume_but_preserves_total() {
        for kind in ScaleKind::all() {
            let uni = generate(&ScaleConfig::new(kind, 64).seed(3));
            let skewed = generate(&ScaleConfig::new(kind, 64).seed(3).skew(1.0));
            // Same total data (the skew redistributes, not inflates)…
            let rel = (uni.total_data() - skewed.total_data()).abs() / uni.total_data();
            assert!(rel < 1e-12, "{kind:?}: total changed by {rel}");
            // …monotonically decreasing per-source volumes, genuinely skewed.
            for w in skewed.d.windows(2) {
                assert!(w[0] >= w[1], "{kind:?}: volumes must be non-increasing");
            }
            assert!(
                skewed.d[0] > 3.0 * skewed.d[skewed.d.len() - 1],
                "{kind:?}: head/tail spread too small"
            );
            // Bandwidths untouched by the skew knob.
            assert_eq!(uni.b_sm, skewed.b_sm, "{kind:?}");
        }
    }

    #[test]
    fn parse_spec_config_round_trips() {
        let cfg = parse_spec_config("edge-heavy:100:5").unwrap();
        assert_eq!(cfg.kind, ScaleKind::EdgeHeavy);
        assert_eq!(cfg.nodes, 100);
        assert_eq!(cfg.seed, 5);
        assert_eq!(cfg.skew, 0.0);
    }
}
