//! The four network environments of §4.1.
//!
//! Each environment has eight nodes of each type (source, mapper, reducer)
//! distributed over 1, 2, 4 or 8 data centers; where a site must host more
//! than one node of a type, replica nodes share the site's measured
//! characteristics — exactly the construction described in §4.1. Data
//! sources are allocated to clusters in the same proportion as mappers and
//! reducers, and every source holds the same amount of input data.

use super::planetlab::{planetlab, PlanetLabData};
use super::topology::{Topology, TopologyBuilder, GB};

/// Number of nodes of each type in every environment (§4.1).
pub const NODES_PER_TYPE: usize = 8;

/// Which of the paper's environments to instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnvKind {
    /// One local cluster (tamu.edu) — the traditional MapReduce setting.
    LocalDataCenter,
    /// Two US data centers (tamu.edu, ucsb.edu).
    IntraContinental,
    /// Four globally distributed data centers (ucsb, tamu, tu-berlin, nitech).
    Global4,
    /// Eight globally distributed data centers (all sites).
    Global8,
}

impl EnvKind {
    pub fn all() -> [EnvKind; 4] {
        [
            EnvKind::LocalDataCenter,
            EnvKind::IntraContinental,
            EnvKind::Global4,
            EnvKind::Global8,
        ]
    }

    pub fn label(&self) -> &'static str {
        match self {
            EnvKind::LocalDataCenter => "local-dc",
            EnvKind::IntraContinental => "2-dc-intra",
            EnvKind::Global4 => "4-dc-global",
            EnvKind::Global8 => "8-dc-global",
        }
    }

    /// Site indices (into [`planetlab`]'s site list) used by this env.
    pub fn site_indices(&self) -> Vec<usize> {
        match self {
            // tamu.edu only
            EnvKind::LocalDataCenter => vec![1],
            // tamu.edu + ucsb.edu
            EnvKind::IntraContinental => vec![1, 0],
            // ucsb, tamu, tkn.tu-berlin, pnl.nitech
            EnvKind::Global4 => vec![0, 1, 4, 6],
            // all eight
            EnvKind::Global8 => (0..8).collect(),
        }
    }
}

/// Default per-source input volume for model experiments. Normalized
/// results (Figs 5–8) are insensitive to this constant.
pub const DEFAULT_DATA_PER_SOURCE: f64 = 4.0 * GB;

/// Build one of the §4.1 environments from the PlanetLab dataset.
pub fn build_env(kind: EnvKind) -> Topology {
    build_env_with(kind, &planetlab(), DEFAULT_DATA_PER_SOURCE)
}

/// Build with explicit dataset and per-source data volume.
pub fn build_env_with(kind: EnvKind, pl: &PlanetLabData, data_per_source: f64) -> Topology {
    let site_idx = kind.site_indices();
    let n_sites = site_idx.len();
    assert!(NODES_PER_TYPE % n_sites == 0, "8 nodes must split evenly");
    let per_site = NODES_PER_TYPE / n_sites;

    let mut b = TopologyBuilder::new(kind.label());
    // cluster id c corresponds to site site_idx[c]
    for &si in &site_idx {
        b.cluster(pl.sites[si].name, pl.sites[si].continent);
    }
    for (c, &si) in site_idx.iter().enumerate() {
        for _rep in 0..per_site {
            b.source(c, data_per_source);
            b.mapper(c, pl.sites[si].compute_bps);
            b.reducer(c, pl.sites[si].compute_bps);
        }
    }
    b.build_with_bandwidth(|ca, cb| pl.bandwidth(site_idx[ca], site_idx[cb]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::planetlab::LAN_BPS;

    #[test]
    fn all_envs_have_eight_nodes_per_type() {
        for kind in EnvKind::all() {
            let t = build_env(kind);
            assert_eq!(t.n_sources(), 8, "{kind:?}");
            assert_eq!(t.n_mappers(), 8);
            assert_eq!(t.n_reducers(), 8);
            t.validate();
        }
    }

    #[test]
    fn local_dc_is_homogeneous_lan() {
        let t = build_env(EnvKind::LocalDataCenter);
        assert_eq!(t.clusters.len(), 1);
        for v in t.b_sm.data() {
            assert_eq!(*v, LAN_BPS);
        }
        // All compute equal (single site replicas).
        assert!(t.c_map.iter().all(|&c| c == t.c_map[0]));
    }

    #[test]
    fn global8_is_heterogeneous() {
        let t = build_env(EnvKind::Global8);
        assert_eq!(t.clusters.len(), 8);
        let min_b = t.b_sm.data().iter().cloned().fold(f64::INFINITY, f64::min);
        let max_b = t.b_sm.data().iter().cloned().fold(0.0, f64::max);
        assert!(
            max_b / min_b > 50.0,
            "expect orders-of-magnitude bandwidth spread, got {min_b}..{max_b}"
        );
        let min_c = t.c_map.iter().cloned().fold(f64::INFINITY, f64::min);
        let max_c = t.c_map.iter().cloned().fold(0.0, f64::max);
        assert!(max_c / min_c > 5.0, "compute spread {min_c}..{max_c}");
    }

    #[test]
    fn sources_allocated_proportionally() {
        let t = build_env(EnvKind::Global4);
        // two nodes of each type per cluster
        for c in 0..4 {
            assert_eq!(t.source_cluster.iter().filter(|&&x| x == c).count(), 2);
            assert_eq!(t.mapper_cluster.iter().filter(|&&x| x == c).count(), 2);
            assert_eq!(t.reducer_cluster.iter().filter(|&&x| x == c).count(), 2);
        }
    }

    #[test]
    fn uniform_data_per_source() {
        let t = build_env(EnvKind::Global8);
        assert!(t.d.iter().all(|&d| d == DEFAULT_DATA_PER_SOURCE));
    }
}
