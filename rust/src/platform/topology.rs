//! The tripartite platform model from §2.1 of the paper.
//!
//! A [`Topology`] is a tripartite graph over data sources `S`, mapper
//! nodes `M` and reducer nodes `R`. Each node belongs to a *cluster*
//! (a data-center site); edges `(S×M) ∪ (M×R)` carry bandwidths `B_ij`
//! (bytes/s), compute nodes carry capacities `C_i` (bytes of input
//! processed per second), and each source holds `D_i` bytes.
//!
//! Units: bytes and seconds throughout (the paper uses bits; the choice is
//! immaterial since only ratios enter the model).

use crate::util::mat::Mat;

/// Convenience byte-size constants.
pub const KB: f64 = 1e3;
pub const MB: f64 = 1e6;
pub const GB: f64 = 1e9;

/// A data-center site hosting a subset of the nodes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Cluster {
    pub id: usize,
    pub name: String,
    pub continent: Continent,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Continent {
    US,
    EU,
    Asia,
}

impl std::fmt::Display for Continent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Continent::US => write!(f, "US"),
            Continent::EU => write!(f, "EU"),
            Continent::Asia => write!(f, "Asia"),
        }
    }
}

/// The distributed platform: tripartite graph + parameters (§2.1).
#[derive(Debug, Clone)]
pub struct Topology {
    pub name: String,
    pub clusters: Vec<Cluster>,
    /// Cluster id of each source / mapper / reducer node.
    pub source_cluster: Vec<usize>,
    pub mapper_cluster: Vec<usize>,
    pub reducer_cluster: Vec<usize>,
    /// `D_i`: bytes of input data originating at source `i`.
    pub d: Vec<f64>,
    /// `C_j`: mapper compute capacity, input bytes/s.
    pub c_map: Vec<f64>,
    /// `C_k`: reducer compute capacity, input bytes/s.
    pub c_red: Vec<f64>,
    /// `B_ij`: source→mapper bandwidth (bytes/s), `|S| × |M|`.
    pub b_sm: Mat,
    /// `B_jk`: mapper→reducer bandwidth (bytes/s), `|M| × |R|`.
    pub b_mr: Mat,
}

impl Topology {
    pub fn n_sources(&self) -> usize {
        self.d.len()
    }

    pub fn n_mappers(&self) -> usize {
        self.c_map.len()
    }

    pub fn n_reducers(&self) -> usize {
        self.c_red.len()
    }

    pub fn total_data(&self) -> f64 {
        self.d.iter().sum()
    }

    /// Is the source→mapper link intra-cluster ("local" in Fig 2)?
    pub fn sm_local(&self, i: usize, j: usize) -> bool {
        self.source_cluster[i] == self.mapper_cluster[j]
    }

    /// Is the mapper→reducer link intra-cluster?
    pub fn mr_local(&self, j: usize, k: usize) -> bool {
        self.mapper_cluster[j] == self.reducer_cluster[k]
    }

    /// Index of the mapper with the fastest link from source `i`
    /// (Hadoop's locality heuristic: push to the most local mapper).
    pub fn most_local_mapper(&self, i: usize) -> usize {
        // `total_cmp`, not `partial_cmp(..).unwrap()`: a NaN bandwidth
        // (dead-link probe) must not panic the heuristic. NaN totally
        // orders after +inf, so it wins max_by — deterministic, and the
        // degenerate link surfaces downstream rather than aborting here.
        (0..self.n_mappers())
            .max_by(|&a, &b| self.b_sm.get(i, a).total_cmp(&self.b_sm.get(i, b)))
            .expect("topology has no mappers")
    }

    /// Internal consistency check; panics with a description on violation.
    pub fn validate(&self) {
        let (s, m, r) = (self.n_sources(), self.n_mappers(), self.n_reducers());
        assert!(s > 0 && m > 0 && r > 0, "empty node set");
        assert_eq!(self.source_cluster.len(), s);
        assert_eq!(self.mapper_cluster.len(), m);
        assert_eq!(self.reducer_cluster.len(), r);
        assert_eq!((self.b_sm.rows(), self.b_sm.cols()), (s, m), "b_sm shape");
        assert_eq!((self.b_mr.rows(), self.b_mr.cols()), (m, r), "b_mr shape");
        for &c in self
            .source_cluster
            .iter()
            .chain(&self.mapper_cluster)
            .chain(&self.reducer_cluster)
        {
            assert!(c < self.clusters.len(), "dangling cluster id {c}");
        }
        for (idx, &di) in self.d.iter().enumerate() {
            assert!(di >= 0.0 && di.is_finite(), "D[{idx}] = {di}");
        }
        for &c in self.c_map.iter().chain(&self.c_red) {
            assert!(c > 0.0 && c.is_finite(), "non-positive compute capacity {c}");
        }
        for v in self.b_sm.data().iter().chain(self.b_mr.data()) {
            assert!(*v > 0.0 && v.is_finite(), "non-positive bandwidth {v}");
        }
    }

    /// Scale all compute capacities by `f` (models application compute
    /// intensity; §2.1 notes `C_i` is application-dependent).
    pub fn with_compute_scale(mut self, f: f64) -> Topology {
        assert!(f > 0.0);
        for c in self.c_map.iter_mut().chain(self.c_red.iter_mut()) {
            *c *= f;
        }
        self
    }

    /// Replace every source's data volume with `bytes`.
    pub fn with_uniform_data(mut self, bytes: f64) -> Topology {
        for d in self.d.iter_mut() {
            *d = bytes;
        }
        self
    }
}

/// Builder for hand-constructed topologies (tests, the §1.3 example).
pub struct TopologyBuilder {
    name: String,
    clusters: Vec<Cluster>,
    source_cluster: Vec<usize>,
    mapper_cluster: Vec<usize>,
    reducer_cluster: Vec<usize>,
    d: Vec<f64>,
    c_map: Vec<f64>,
    c_red: Vec<f64>,
}

impl TopologyBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        TopologyBuilder {
            name: name.into(),
            clusters: Vec::new(),
            source_cluster: Vec::new(),
            mapper_cluster: Vec::new(),
            reducer_cluster: Vec::new(),
            d: Vec::new(),
            c_map: Vec::new(),
            c_red: Vec::new(),
        }
    }

    pub fn cluster(&mut self, name: &str, continent: Continent) -> usize {
        let id = self.clusters.len();
        self.clusters.push(Cluster { id, name: name.to_string(), continent });
        id
    }

    pub fn source(&mut self, cluster: usize, data_bytes: f64) -> usize {
        self.source_cluster.push(cluster);
        self.d.push(data_bytes);
        self.d.len() - 1
    }

    pub fn mapper(&mut self, cluster: usize, capacity: f64) -> usize {
        self.mapper_cluster.push(cluster);
        self.c_map.push(capacity);
        self.c_map.len() - 1
    }

    pub fn reducer(&mut self, cluster: usize, capacity: f64) -> usize {
        self.reducer_cluster.push(cluster);
        self.c_red.push(capacity);
        self.c_red.len() - 1
    }

    /// Finish, deriving every link bandwidth from `f(cluster_a, cluster_b)`.
    pub fn build_with_bandwidth<F>(self, mut bw: F) -> Topology
    where
        F: FnMut(usize, usize) -> f64,
    {
        let s = self.d.len();
        let m = self.c_map.len();
        let r = self.c_red.len();
        let mut b_sm = Mat::zeros(s, m);
        for i in 0..s {
            for j in 0..m {
                b_sm[(i, j)] = bw(self.source_cluster[i], self.mapper_cluster[j]);
            }
        }
        let mut b_mr = Mat::zeros(m, r);
        for j in 0..m {
            for k in 0..r {
                b_mr[(j, k)] = bw(self.mapper_cluster[j], self.reducer_cluster[k]);
            }
        }
        let topo = Topology {
            name: self.name,
            clusters: self.clusters,
            source_cluster: self.source_cluster,
            mapper_cluster: self.mapper_cluster,
            reducer_cluster: self.reducer_cluster,
            d: self.d,
            c_map: self.c_map,
            c_red: self.c_red,
            b_sm,
            b_mr,
        };
        topo.validate();
        topo
    }
}

/// The two-cluster worked example of §1.3 (Figure 2): data sources D1/D2
/// with 150 GB / 50 GB, local links `local_bw`, non-local `nonlocal_bw`,
/// all compute capacities `compute`.
pub fn example_1_3(local_bw: f64, nonlocal_bw: f64, compute: f64) -> Topology {
    let mut b = TopologyBuilder::new("example-1.3");
    let c1 = b.cluster("cluster-1", Continent::US);
    let c2 = b.cluster("cluster-2", Continent::US);
    b.source(c1, 150.0 * GB);
    b.source(c2, 50.0 * GB);
    b.mapper(c1, compute);
    b.mapper(c2, compute);
    b.reducer(c1, compute);
    b.reducer(c2, compute);
    b.build_with_bandwidth(|a, bb| if a == bb { local_bw } else { nonlocal_bw })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example_1_3_shape() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        assert_eq!(t.n_sources(), 2);
        assert_eq!(t.n_mappers(), 2);
        assert_eq!(t.n_reducers(), 2);
        assert_eq!(t.total_data(), 200.0 * GB);
        assert!(t.sm_local(0, 0));
        assert!(!t.sm_local(0, 1));
        assert_eq!(t.b_sm.get(0, 0), 100.0 * MB);
        assert_eq!(t.b_sm.get(0, 1), 10.0 * MB);
    }

    #[test]
    fn most_local_mapper_picks_fastest_link() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        assert_eq!(t.most_local_mapper(0), 0);
        assert_eq!(t.most_local_mapper(1), 1);
    }

    /// Regression (NaN-unsafe sort): the locality heuristic ranked
    /// links with `partial_cmp(..).unwrap()`, which panics on a NaN
    /// bandwidth entry (dead-link probe / missing telemetry).
    /// `f64::total_cmp` ranks NaN after +inf, so the call stays
    /// deterministic and panic-free. Fails on the pre-fix code.
    #[test]
    fn most_local_mapper_survives_nan_bandwidth() {
        let mut b_sm = Mat::filled(1, 3, 10.0 * MB);
        b_sm[(0, 1)] = f64::NAN;
        let t = Topology {
            name: "degenerate".into(),
            clusters: vec![Cluster { id: 0, name: "c0".into(), continent: Continent::US }],
            source_cluster: vec![0],
            mapper_cluster: vec![0; 3],
            reducer_cluster: vec![0],
            d: vec![1.0 * MB],
            c_map: vec![10.0 * MB; 3],
            c_red: vec![10.0 * MB],
            b_sm,
            b_mr: Mat::filled(3, 1, 10.0 * MB),
        };
        // NaN totally orders above every finite bandwidth, so the NaN
        // link wins — the key property is a deterministic index, not a
        // panic.
        assert_eq!(t.most_local_mapper(0), 1);
    }

    #[test]
    fn with_compute_scale() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB).with_compute_scale(0.5);
        assert_eq!(t.c_map[0], 50.0 * MB);
        assert_eq!(t.c_red[1], 50.0 * MB);
    }

    #[test]
    #[should_panic(expected = "non-positive bandwidth")]
    fn validate_rejects_zero_bandwidth() {
        let mut b = TopologyBuilder::new("bad");
        let c = b.cluster("c", Continent::US);
        b.source(c, 1.0);
        b.mapper(c, 1.0);
        b.reducer(c, 1.0);
        let _ = b.build_with_bandwidth(|_, _| 0.0);
    }
}
