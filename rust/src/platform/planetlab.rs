//! The PlanetLab measurement dataset from §3.2 / Table 1 of the paper.
//!
//! The paper measured eight PlanetLab sites (four US, two Europe, two
//! Asia/Japan), with per-node compute rates between 9 and 90 MBps and the
//! inter-continent bandwidth ranges of Table 1 (slowest/fastest KBps of
//! links between clusters in each continent pair):
//!
//! |      | US         | EU           | Asia          |
//! |------|------------|--------------|---------------|
//! | US   | 216 / 9405 | 110 / 2267   | 61 / 3305     |
//! | EU   | 794 / 2734 | 4475 / 11053 | 1502 / 1593   |
//! | Asia | 401 / 3610 | 290 / 1071   | 23762 / 23875 |
//!
//! We do not have the paper's raw per-link matrix, so per-site-pair
//! bandwidths are drawn log-uniformly *inside the published range* for the
//! corresponding continent pair, from a fixed seed — preserving the
//! heterogeneity structure (fast intra-continent Asia, slow trans-Pacific,
//! asymmetric EU↔US, …) while remaining fully reproducible. Intra-site
//! links are Gigabit-Ethernet LAN (the paper's testbed interconnect).

use super::topology::{Continent, KB, MB};
use crate::util::mat::Mat;
use crate::util::rng::Pcg64;

/// One measured PlanetLab site.
#[derive(Debug, Clone)]
pub struct Site {
    pub name: &'static str,
    pub continent: Continent,
    /// Measured-style compute rate, bytes of input per second (§3.2:
    /// unscaled `C_i` between 9 and 90 MBps).
    pub compute_bps: f64,
}

/// The eight sites used in the paper's evaluation (§4.1).
pub fn sites() -> Vec<Site> {
    use Continent::*;
    vec![
        Site { name: "ucsb.edu", continent: US, compute_bps: 65.0 * MB },
        Site { name: "tamu.edu", continent: US, compute_bps: 90.0 * MB },
        Site { name: "hpl.hp.com", continent: US, compute_bps: 74.0 * MB },
        Site { name: "uiuc.edu", continent: US, compute_bps: 51.0 * MB },
        Site { name: "tkn.tu-berlin.de", continent: EU, compute_bps: 38.0 * MB },
        Site { name: "essex.ac.uk", continent: EU, compute_bps: 27.0 * MB },
        Site { name: "pnl.nitech.ac.jp", continent: Asia, compute_bps: 18.0 * MB },
        Site { name: "wide.ad.jp", continent: Asia, compute_bps: 9.0 * MB },
    ]
}

/// Table 1 bandwidth range (bytes/s) for a continent pair `(from, to)`.
pub fn table1_range(from: Continent, to: Continent) -> (f64, f64) {
    use Continent::*;
    let (lo_kbps, hi_kbps) = match (from, to) {
        (US, US) => (216.0, 9405.0),
        (US, EU) => (110.0, 2267.0),
        (US, Asia) => (61.0, 3305.0),
        (EU, US) => (794.0, 2734.0),
        (EU, EU) => (4475.0, 11053.0),
        (EU, Asia) => (1502.0, 1593.0),
        (Asia, US) => (401.0, 3610.0),
        (Asia, EU) => (290.0, 1071.0),
        (Asia, Asia) => (23762.0, 23875.0),
    };
    (lo_kbps * KB, hi_kbps * KB)
}

/// Intra-site (LAN) bandwidth: Gigabit Ethernet, §3.2's testbed fabric.
pub const LAN_BPS: f64 = 125.0 * MB;

/// Fixed seed for the per-site-pair bandwidth draw; changing this changes
/// the concrete platform but not its statistical structure.
pub const PLANETLAB_SEED: u64 = 0x9_D15_7A1B;

/// A complete measured-style dataset: per-site-pair directional
/// bandwidths, indexed `[from][to]` over [`sites`].
#[derive(Debug, Clone)]
pub struct PlanetLabData {
    pub sites: Vec<Site>,
    pub bw: Mat,
}

impl PlanetLabData {
    /// Bandwidth between two sites (bytes/s).
    pub fn bandwidth(&self, from: usize, to: usize) -> f64 {
        self.bw.get(from, to)
    }
}

/// Build the dataset with the default seed.
pub fn planetlab() -> PlanetLabData {
    planetlab_seeded(PLANETLAB_SEED)
}

/// Build with an explicit seed (used by sensitivity tests).
pub fn planetlab_seeded(seed: u64) -> PlanetLabData {
    let sites = sites();
    let n = sites.len();
    let mut rng = Pcg64::new(seed);
    let mut bw = Mat::zeros(n, n);
    for a in 0..n {
        for b in 0..n {
            bw[(a, b)] = if a == b {
                LAN_BPS
            } else {
                let (lo, hi) = table1_range(sites[a].continent, sites[b].continent);
                // log-uniform inside the published [slowest, fastest] range
                let u = rng.next_f64();
                (lo.ln() + u * (hi.ln() - lo.ln())).exp()
            };
        }
    }
    PlanetLabData { sites, bw }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_sites_with_paper_continent_mix() {
        let s = sites();
        assert_eq!(s.len(), 8);
        let us = s.iter().filter(|x| x.continent == Continent::US).count();
        let eu = s.iter().filter(|x| x.continent == Continent::EU).count();
        let asia = s.iter().filter(|x| x.continent == Continent::Asia).count();
        assert_eq!((us, eu, asia), (4, 2, 2));
        for site in &s {
            assert!(site.compute_bps >= 9.0 * MB && site.compute_bps <= 90.0 * MB);
        }
    }

    #[test]
    fn bandwidths_respect_table1_ranges() {
        let pl = planetlab();
        for a in 0..8 {
            for b in 0..8 {
                let v = pl.bandwidth(a, b);
                if a == b {
                    assert_eq!(v, LAN_BPS);
                } else {
                    let (lo, hi) =
                        table1_range(pl.sites[a].continent, pl.sites[b].continent);
                    assert!(v >= lo && v <= hi, "bw[{a}][{b}]={v} outside [{lo},{hi}]");
                }
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = planetlab_seeded(1);
        let b = planetlab_seeded(1);
        let c = planetlab_seeded(2);
        assert_eq!(a.bw, b.bw);
        assert_ne!(a.bw, c.bw);
    }

    #[test]
    fn asia_asia_much_faster_than_transpacific() {
        // Structure check mirroring the paper's Table 1 discussion.
        let pl = planetlab();
        let asia: Vec<usize> = (0..8)
            .filter(|&i| pl.sites[i].continent == Continent::Asia)
            .collect();
        let us: Vec<usize> = (0..8)
            .filter(|&i| pl.sites[i].continent == Continent::US)
            .collect();
        let intra = pl.bandwidth(asia[0], asia[1]);
        let trans = pl.bandwidth(us[0], asia[0]);
        assert!(intra > 5.0 * trans, "intra-Asia {intra} vs US→Asia {trans}");
    }
}
