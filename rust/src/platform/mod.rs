//! Platform modeling: the tripartite source/mapper/reducer graph (§2.1),
//! the PlanetLab measurement dataset (Table 1, §3.2), and the four network
//! environments of the evaluation (§4.1).

pub mod config;
pub mod envs;
pub mod planetlab;
pub mod topology;

pub use config::{load_topology, parse_topology};
pub use envs::{build_env, EnvKind};
pub use topology::{Topology, TopologyBuilder, GB, KB, MB};
