//! Platform modeling: the tripartite source/mapper/reducer graph (§2.1),
//! the PlanetLab measurement dataset (Table 1, §3.2), the four network
//! environments of the evaluation (§4.1), and parameterized generators
//! for much larger topologies ([`scale`]: hierarchical WAN, federated
//! multi-datacenter, edge-heavy; 16–512+ nodes).

pub mod config;
pub mod envs;
pub mod planetlab;
pub mod scale;
pub mod topology;

pub use config::{load_topology, parse_topology};
pub use envs::{build_env, EnvKind};
pub use scale::{generate_kind, ScaleConfig, ScaleKind};
pub use topology::{Topology, TopologyBuilder, GB, KB, MB};
