//! Topology description files — a TOML-subset parser (no `serde`/`toml`
//! offline) so users can model *their own* distributed platforms instead
//! of the built-in PlanetLab environments:
//!
//! ```toml
//! # my-platform.topo
//! name = "two-region"
//!
//! [cluster.eu]
//! continent = "EU"
//! compute_mbps = 40
//! sources = 2          # nodes of each type hosted by this cluster
//! mappers = 2
//! reducers = 2
//! data_gb = 8          # per source
//!
//! [cluster.us]
//! continent = "US"
//! compute_mbps = 80
//! sources = 2
//! mappers = 2
//! reducers = 2
//! data_gb = 2
//!
//! [bandwidth_mbps]
//! local = 1000         # intra-cluster
//! eu.us = 12           # directional inter-cluster overrides
//! us.eu = 9
//! default = 5          # any pair not listed
//! ```

use std::collections::BTreeMap;

use crate::util::errors::{anyhow, bail, Context, Result};

use super::topology::{Continent, Topology, TopologyBuilder, GB, MB};

#[derive(Debug, Default, Clone)]
struct ClusterSpec {
    continent: Continent,
    compute_mbps: f64,
    sources: usize,
    mappers: usize,
    reducers: usize,
    data_gb: f64,
}

impl Default for Continent {
    fn default() -> Self {
        Continent::US
    }
}

/// Parse a `.topo` file into a [`Topology`].
pub fn parse_topology(text: &str) -> Result<Topology> {
    let mut name = "custom".to_string();
    let mut clusters: BTreeMap<String, ClusterSpec> = BTreeMap::new();
    let mut bw: BTreeMap<String, f64> = BTreeMap::new();
    let mut section: Option<String> = None;

    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(stripped) = line.strip_prefix('[') {
            let sect = stripped
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?;
            section = Some(sect.trim().to_string());
            if let Some(cname) = sect.trim().strip_prefix("cluster.") {
                clusters.entry(cname.to_string()).or_default();
            }
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        let value = value.trim().trim_matches('"');

        match section.as_deref() {
            None => {
                if key == "name" {
                    name = value.to_string();
                }
            }
            Some(sect) if sect.starts_with("cluster.") => {
                let cname = sect.strip_prefix("cluster.").unwrap();
                let spec = clusters.get_mut(cname).unwrap();
                let parse_f = || -> Result<f64> {
                    value
                        .parse()
                        .with_context(|| format!("line {}: bad number '{value}'", lineno + 1))
                };
                let parse_u = || -> Result<usize> {
                    value
                        .parse()
                        .with_context(|| format!("line {}: bad count '{value}'", lineno + 1))
                };
                match key {
                    "continent" => {
                        spec.continent = match value {
                            "US" | "us" => Continent::US,
                            "EU" | "eu" => Continent::EU,
                            "Asia" | "asia" | "ASIA" => Continent::Asia,
                            other => bail!("line {}: unknown continent '{other}'", lineno + 1),
                        }
                    }
                    "compute_mbps" => spec.compute_mbps = parse_f()?,
                    "sources" => spec.sources = parse_u()?,
                    "mappers" => spec.mappers = parse_u()?,
                    "reducers" => spec.reducers = parse_u()?,
                    "data_gb" => spec.data_gb = parse_f()?,
                    other => bail!("line {}: unknown cluster key '{other}'", lineno + 1),
                }
            }
            Some(sect) if sect == "bandwidth_mbps" => {
                let v: f64 = value
                    .parse()
                    .with_context(|| format!("line {}: bad bandwidth '{value}'", lineno + 1))?;
                bw.insert(key.to_string(), v);
            }
            Some(other) => bail!("unknown section [{other}]"),
        }
    }

    if clusters.is_empty() {
        bail!("no [cluster.*] sections");
    }
    for (cname, spec) in &clusters {
        if spec.compute_mbps <= 0.0 {
            bail!("cluster {cname}: compute_mbps must be positive");
        }
        if spec.mappers == 0 || spec.reducers == 0 {
            bail!("cluster {cname}: needs at least one mapper and reducer");
        }
    }
    let default_bw = bw.get("default").copied();
    let local_bw = bw.get("local").copied().unwrap_or(1000.0);

    let mut b = TopologyBuilder::new(name);
    let mut ids = Vec::new();
    let names: Vec<String> = clusters.keys().cloned().collect();
    for (cname, spec) in &clusters {
        let id = b.cluster(cname, spec.continent);
        ids.push(id);
        for _ in 0..spec.sources {
            b.source(id, spec.data_gb * GB);
        }
        for _ in 0..spec.mappers {
            b.mapper(id, spec.compute_mbps * MB);
        }
        for _ in 0..spec.reducers {
            b.reducer(id, spec.compute_mbps * MB);
        }
    }
    let lookup = |a: usize, bb: usize| -> Result<f64> {
        if a == bb {
            return Ok(local_bw * MB);
        }
        let key = format!("{}.{}", names[a], names[bb]);
        if let Some(v) = bw.get(&key) {
            return Ok(v * MB);
        }
        default_bw
            .map(|v| v * MB)
            .ok_or_else(|| anyhow!("no bandwidth for {key} and no default"))
    };
    // Pre-validate all pairs so build_with_bandwidth cannot panic.
    for a in 0..names.len() {
        for bb in 0..names.len() {
            lookup(a, bb)?;
        }
    }
    Ok(b.build_with_bandwidth(|a, bb| lookup(a, bb).unwrap()))
}

/// Load from a file path.
pub fn load_topology(path: &std::path::Path) -> Result<Topology> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_topology(&text).with_context(|| format!("parsing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
name = "two-region"

[cluster.eu]
continent = "EU"
compute_mbps = 40
sources = 2
mappers = 2
reducers = 2
data_gb = 8

[cluster.us]
continent = "US"
compute_mbps = 80
sources = 2
mappers = 2
reducers = 2
data_gb = 2

[bandwidth_mbps]
local = 1000
eu.us = 12
us.eu = 9
default = 5
"#;

    #[test]
    fn parses_sample() {
        let t = parse_topology(SAMPLE).unwrap();
        assert_eq!(t.name, "two-region");
        assert_eq!(t.clusters.len(), 2);
        assert_eq!(t.n_sources(), 4);
        assert_eq!(t.n_mappers(), 4);
        assert_eq!(t.n_reducers(), 4);
        // eu sources carry 8 GB each; clusters are in BTreeMap order
        // (eu before us).
        assert_eq!(t.d[0], 8.0 * GB);
        assert_eq!(t.d[2], 2.0 * GB);
        // eu→us bandwidth 12 MBps, us→eu 9 MBps, intra 1000 MBps.
        assert_eq!(t.b_sm.get(0, 0), 1000.0 * MB);
        assert_eq!(t.b_sm.get(0, 2), 12.0 * MB);
        assert_eq!(t.b_sm.get(2, 0), 9.0 * MB);
        t.validate();
    }

    #[test]
    fn default_bandwidth_fallback() {
        let text = SAMPLE.replace("eu.us = 12\nus.eu = 9\n", "");
        let t = parse_topology(&text).unwrap();
        assert_eq!(t.b_sm.get(0, 2), 5.0 * MB);
    }

    #[test]
    fn missing_bandwidth_is_an_error() {
        let text = SAMPLE.replace("default = 5", "");
        let t = parse_topology(&text.replace("eu.us = 12\nus.eu = 9\n", ""));
        assert!(t.is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_topology("nonsense without sections").is_err());
        assert!(parse_topology("[cluster.x]\ncompute_mbps = -1\nmappers = 1\nreducers = 1\n[bandwidth_mbps]\ndefault = 1").is_err());
        assert!(parse_topology("[weird]\nk = 1").is_err());
    }

    #[test]
    fn comments_and_blank_lines_ok() {
        let text = format!("# heading comment\n\n{SAMPLE}\n# trailing");
        assert!(parse_topology(&text).is_ok());
    }

    #[test]
    fn optimizable_end_to_end() {
        use crate::model::barrier::BarrierConfig;
        use crate::model::makespan::{makespan, AppModel};
        use crate::model::plan::Plan;
        use crate::optimizer::{AlternatingLp, PlanOptimizer};
        let t = parse_topology(SAMPLE).unwrap();
        let app = AppModel::new(1.0);
        let cfg = BarrierConfig::ALL_GLOBAL;
        let plan = AlternatingLp::default().optimize(&t, app, cfg);
        plan.check(&t).unwrap();
        let uni = makespan(&t, app, cfg, &Plan::uniform(4, 4, 4));
        let opt = makespan(&t, app, cfg, &plan);
        assert!(opt <= uni + 1e-9);
    }
}
