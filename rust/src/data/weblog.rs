//! Seeded Web-server-log generator — stands in for the WorldCup98 trace
//! the paper uses for Sessionization (§4.6.2; DESIGN.md §3).
//!
//! Log entries carry a client id and timestamp; clients issue requests in
//! bursts (sessions) separated by long think times, which is exactly the
//! structure Sessionization recovers.

use crate::engine::job::Record;
use crate::util::rng::Pcg64;

#[derive(Debug, Clone, Copy)]
pub struct WeblogConfig {
    pub n_users: u64,
    /// Mean requests per session.
    pub mean_session_len: f64,
    /// Mean gap between requests inside a session (seconds).
    pub intra_gap: f64,
    /// Mean gap between sessions (seconds) — must exceed the
    /// sessionization threshold by a wide margin.
    pub inter_gap: f64,
}

impl Default for WeblogConfig {
    fn default() -> Self {
        WeblogConfig { n_users: 2_000, mean_session_len: 8.0, intra_gap: 30.0, inter_gap: 3600.0 }
    }
}

/// The session gap threshold Sessionization uses (seconds).
pub const SESSION_GAP: u64 = 1800;

const PAGES: [&str; 8] = [
    "/index.html",
    "/scores/live",
    "/teams/list",
    "/news/today",
    "/img/banner.gif",
    "/match/detail",
    "/stats/top",
    "/schedule/week",
];

/// Generate ≈ `target_bytes` of log records. Key = log offset; value =
/// "user_id timestamp path status bytes" (Common-Log-ish).
pub fn generate(cfg: WeblogConfig, target_bytes: usize, rng: &mut Pcg64) -> Vec<Record> {
    let mut out = Vec::new();
    let mut bytes = 0usize;
    let mut line = 0u64;
    // Per-user clock; users interleave in the log ordered by time-ish
    // batches (we emit round-robin over users with advancing clocks,
    // which is realistic enough and keeps generation O(n)).
    let mut clocks: Vec<f64> = (0..cfg.n_users)
        .map(|_| rng.uniform(0.0, cfg.inter_gap))
        .collect();
    while bytes < target_bytes {
        let u = rng.next_below(cfg.n_users);
        // Advance this user's clock: new session or intra-session step.
        let new_session = rng.chance(1.0 / cfg.mean_session_len);
        let dt = if new_session {
            cfg.inter_gap * (0.5 + rng.exponential(1.0))
        } else {
            rng.exponential(1.0 / cfg.intra_gap.max(1e-9)).min(cfg.intra_gap * 10.0)
        };
        clocks[u as usize] += dt;
        let ts = clocks[u as usize] as u64;
        let page = PAGES[rng.range(0, PAGES.len())];
        let status = if rng.chance(0.95) { 200 } else { 404 };
        let size = 200 + rng.next_below(4000);
        let rec = Record::new(
            format!("{line:010}"),
            format!("user{u:06} {ts} {page} {status} {size}"),
        );
        bytes += rec.size();
        out.push(rec);
        line += 1;
    }
    out
}

/// Parse a log value back into (user, timestamp) — used by the app.
pub fn parse_entry(value: &str) -> Option<(&str, u64)> {
    let mut it = value.split(' ');
    let user = it.next()?;
    let ts = it.next()?.parse().ok()?;
    Some((user, ts))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_target_volume_deterministically() {
        let a = generate(WeblogConfig::default(), 80_000, &mut Pcg64::new(3));
        let b = generate(WeblogConfig::default(), 80_000, &mut Pcg64::new(3));
        assert_eq!(a, b);
        let total: usize = a.iter().map(|r| r.size()).sum();
        assert!(total >= 80_000 && total < 90_000);
    }

    #[test]
    fn entries_parse() {
        let recs = generate(WeblogConfig::default(), 20_000, &mut Pcg64::new(4));
        for r in &recs {
            let (user, _ts) = parse_entry(&r.value).expect("parseable");
            assert!(user.starts_with("user"));
        }
    }

    #[test]
    fn users_have_multiple_sessions() {
        let mut rng = Pcg64::new(5);
        let recs = generate(
            WeblogConfig { n_users: 10, ..Default::default() },
            120_000,
            &mut rng,
        );
        // Reconstruct one user's timeline; expect at least one gap >
        // SESSION_GAP (multiple sessions).
        let mut times: Vec<u64> = recs
            .iter()
            .filter_map(|r| parse_entry(&r.value))
            .filter(|(u, _)| *u == "user000000")
            .map(|(_, t)| t)
            .collect();
        times.sort_unstable();
        assert!(times.len() > 10);
        let has_gap = times.windows(2).any(|w| w[1] - w[0] > SESSION_GAP);
        assert!(has_gap, "expected multi-session user");
    }
}
