//! Seeded workload generators replacing the paper's datasets
//! (DESIGN.md §3): Zipf text corpus (↔ Project Gutenberg eBooks),
//! web-server logs (↔ WorldCup98 trace), and the forward index input of
//! the inverted-index application.

pub mod corpus;
pub mod fwdindex;
pub mod weblog;

use crate::engine::job::Record;
use crate::util::rng::Pcg64;

/// Generate per-source inputs of `bytes_per_source` each, with
/// decorrelated per-source streams derived from `seed`.
pub fn per_source<F>(n_sources: usize, bytes_per_source: usize, seed: u64, mut gen: F) -> Vec<Vec<Record>>
where
    F: FnMut(usize, usize, &mut Pcg64) -> Vec<Record>,
{
    let mut root = Pcg64::new(seed);
    (0..n_sources)
        .map(|i| {
            let mut rng = root.fork();
            gen(i, bytes_per_source, &mut rng)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_source_streams_differ() {
        let inputs = per_source(3, 10_000, 42, |_, bytes, rng| {
            corpus::generate(corpus::CorpusConfig::default(), bytes, rng)
        });
        assert_eq!(inputs.len(), 3);
        assert_ne!(inputs[0], inputs[1]);
        assert_ne!(inputs[1], inputs[2]);
    }
}
