//! Forward-index generator: the preprocessed input of the Full Inverted
//! Index application (§4.6.2 — "stop words removed, terms replaced with
//! an integer term identifier; in essence a simple forward index").

use super::corpus::CorpusConfig;
use crate::engine::job::Record;
use crate::util::rng::{Pcg64, Zipf};

/// Generate ≈ `target_bytes` of forward-index records:
/// key = document id, value = space-separated integer term ids.
pub fn generate(cfg: CorpusConfig, target_bytes: usize, rng: &mut Pcg64) -> Vec<Record> {
    let zipf = Zipf::new(cfg.vocab, cfg.zipf_s);
    let mut out = Vec::new();
    let mut bytes = 0usize;
    let mut doc = 0u64;
    while bytes < target_bytes {
        let mut text = String::new();
        for w in 0..cfg.words_per_doc {
            if w > 0 {
                text.push(' ');
            }
            text.push_str(&(zipf.sample(rng) - 1).to_string());
        }
        let rec = Record::new(format!("d{doc:07}"), text);
        bytes += rec.size();
        out.push(rec);
        doc += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn values_are_integer_term_ids() {
        let recs = generate(CorpusConfig::default(), 30_000, &mut Pcg64::new(6));
        for r in recs.iter().take(50) {
            for tok in r.value.split(' ') {
                tok.parse::<u64>().expect("integer term id");
            }
        }
    }

    #[test]
    fn deterministic_and_sized() {
        let a = generate(CorpusConfig::default(), 40_000, &mut Pcg64::new(9));
        let b = generate(CorpusConfig::default(), 40_000, &mut Pcg64::new(9));
        assert_eq!(a, b);
        let total: usize = a.iter().map(|r| r.size()).sum();
        assert!(total >= 40_000);
    }
}
