//! Seeded Zipf text-corpus generator — stands in for the Project
//! Gutenberg eBook collection the paper uses for Word Count and Full
//! Inverted Index (§4.6.2; see DESIGN.md §3 for the substitution).
//!
//! Natural-language word frequencies are Zipfian (s ≈ 1), which is the
//! property Word Count's aggregation (α ≪ 1) and the inverted index's
//! posting-list skew depend on; the generator reproduces it with a
//! deterministic vocabulary.

use crate::engine::job::Record;
use crate::util::rng::{Pcg64, Zipf};

/// Deterministic synthetic vocabulary: pronounceable pseudo-words,
/// rank-indexed (rank 0 = most frequent).
pub fn word(rank: u64) -> String {
    const ONSETS: [&str; 16] = [
        "b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v", "w", "st", "tr",
    ];
    const VOWELS: [&str; 8] = ["a", "e", "i", "o", "u", "ai", "ou", "ea"];
    let mut n = rank + 1;
    let mut out = String::new();
    while n > 0 {
        let o = (n % ONSETS.len() as u64) as usize;
        n /= ONSETS.len() as u64;
        let v = (n % VOWELS.len() as u64) as usize;
        n /= VOWELS.len() as u64;
        out.push_str(ONSETS[o]);
        out.push_str(VOWELS[v]);
    }
    out
}

/// Corpus generation parameters.
#[derive(Debug, Clone, Copy)]
pub struct CorpusConfig {
    /// Vocabulary size.
    pub vocab: u64,
    /// Zipf exponent (natural language ≈ 1.0).
    pub zipf_s: f64,
    /// Words per document line (value payload of one record).
    pub words_per_doc: usize,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig { vocab: 20_000, zipf_s: 1.05, words_per_doc: 24 }
    }
}

/// Generate documents totalling ≈ `target_bytes`. Each record is one
/// document: key = document id, value = space-separated words.
pub fn generate(cfg: CorpusConfig, target_bytes: usize, rng: &mut Pcg64) -> Vec<Record> {
    let zipf = Zipf::new(cfg.vocab, cfg.zipf_s);
    let mut out = Vec::new();
    let mut bytes = 0usize;
    let mut doc = 0u64;
    while bytes < target_bytes {
        let mut text = String::new();
        for w in 0..cfg.words_per_doc {
            if w > 0 {
                text.push(' ');
            }
            text.push_str(&word(zipf.sample(rng) - 1));
        }
        let rec = Record::new(format!("doc{doc:08}"), text);
        bytes += rec.size();
        out.push(rec);
        doc += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_is_deterministic_and_distinct() {
        assert_eq!(word(0), word(0));
        let ws: std::collections::HashSet<String> = (0..2000).map(word).collect();
        assert_eq!(ws.len(), 2000, "ranks map to distinct words");
    }

    #[test]
    fn generate_hits_target_size() {
        let mut rng = Pcg64::new(1);
        let recs = generate(CorpusConfig::default(), 100_000, &mut rng);
        let total: usize = recs.iter().map(|r| r.size()).sum();
        assert!(total >= 100_000);
        assert!(total < 110_000, "within one record of target");
    }

    #[test]
    fn corpus_is_zipfian() {
        let mut rng = Pcg64::new(2);
        let recs = generate(CorpusConfig::default(), 300_000, &mut rng);
        let mut counts: std::collections::HashMap<&str, usize> = Default::default();
        for r in &recs {
            for w in r.value.split(' ') {
                *counts.entry(w).or_default() += 1;
            }
        }
        let mut freqs: Vec<usize> = counts.values().cloned().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        // Top word much more frequent than the 100th.
        assert!(freqs[0] > 20 * freqs.get(100).cloned().unwrap_or(1));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(CorpusConfig::default(), 50_000, &mut Pcg64::new(7));
        let b = generate(CorpusConfig::default(), 50_000, &mut Pcg64::new(7));
        assert_eq!(a, b);
    }
}
