//! Full Inverted Index (§4.6.2, application 3): for each term, the
//! complete list of documents containing it with positions — modeled
//! after Lin & Dyer's example. Adds positional information, so the
//! intermediate data is *larger* than the input (paper: α = 1.88).

use crate::engine::job::{MapReduceApp, Record};

#[derive(Debug, Clone, Copy, Default)]
pub struct InvertedIndex;

impl MapReduceApp for InvertedIndex {
    fn name(&self) -> &'static str {
        "inverted-index"
    }

    /// Input: key = doc id, value = space-separated term ids. Emits, for
    /// every posting, key = `term|doc` and value = position — relying on
    /// the framework's sorting/grouping for the index construction (the
    /// paper's custom comparators).
    fn map(&self, record: &Record, emit: &mut dyn FnMut(Record)) {
        for (pos, term) in record.value.split(' ').enumerate() {
            if term.is_empty() {
                continue;
            }
            emit(Record::new(
                format!("{term}|{}", record.key),
                pos.to_string(),
            ));
        }
    }

    /// Group by term (before '|'): one reduce call sees all postings of
    /// a term, doc-sorted, each with its positions.
    fn group_key<'a>(&self, key: &'a str) -> &'a str {
        key.split('|').next().unwrap_or(key)
    }

    fn reduce(&self, group: &str, records: &[Record], emit: &mut dyn FnMut(Record)) {
        // records sorted by (term, doc); positions in input order.
        let mut postings = String::new();
        let mut cur_doc: Option<&str> = None;
        for rec in records {
            let doc = rec.key.split('|').nth(1).unwrap_or("");
            match cur_doc {
                Some(d) if d == doc => {
                    postings.push(',');
                    postings.push_str(&rec.value);
                }
                Some(_) => {
                    postings.push(' ');
                    postings.push_str(doc);
                    postings.push(':');
                    postings.push_str(&rec.value);
                }
                None => {
                    postings.push_str(doc);
                    postings.push(':');
                    postings.push_str(&rec.value);
                }
            }
            cur_doc = Some(doc);
        }
        emit(Record::new(group, postings));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusConfig;
    use crate::data::fwdindex::generate;
    use crate::engine::{run_job, JobConfig};
    use crate::model::plan::Plan;
    use crate::platform::topology::example_1_3;
    use crate::platform::MB;
    use crate::util::rng::Pcg64;

    #[test]
    fn map_emits_positional_postings() {
        let mut out = Vec::new();
        InvertedIndex.map(&Record::new("d1", "7 3 7"), &mut |r| out.push(r));
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], Record::new("7|d1", "0"));
        assert_eq!(out[1], Record::new("3|d1", "1"));
        assert_eq!(out[2], Record::new("7|d1", "2"));
    }

    #[test]
    fn reduce_builds_posting_list() {
        let recs = vec![
            Record::new("7|d1", "0"),
            Record::new("7|d1", "2"),
            Record::new("7|d2", "5"),
        ];
        let mut out = Vec::new();
        InvertedIndex.reduce("7", &recs, &mut |r| out.push(r));
        assert_eq!(out, vec![Record::new("7", "d1:0,2 d2:5")]);
    }

    #[test]
    fn alpha_exceeds_one() {
        // Positional postings expand the data (paper: α = 1.88).
        let mut rng = Pcg64::new(31);
        let docs = generate(CorpusConfig::default(), 100_000, &mut rng);
        let in_bytes: usize = docs.iter().map(|r| r.size()).sum();
        let mut out_bytes = 0usize;
        for d in &docs {
            InvertedIndex.map(d, &mut |o| out_bytes += o.size());
        }
        let alpha = out_bytes as f64 / in_bytes as f64;
        assert!(alpha > 1.2, "α = {alpha}, expected expansion");
    }

    #[test]
    fn end_to_end_index_covers_every_posting() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let mut rng = Pcg64::new(32);
        let inputs: Vec<Vec<Record>> =
            (0..2).map(|_| generate(CorpusConfig::default(), 25_000, &mut rng)).collect();
        let n_postings: usize = inputs
            .iter()
            .flatten()
            .map(|r| r.value.split(' ').filter(|t| !t.is_empty()).count())
            .sum();
        let res = run_job(
            &t,
            &Plan::uniform(2, 2, 2),
            &InvertedIndex,
            &JobConfig::default(),
            &inputs,
        );
        // Count postings in the final index.
        let mut got = 0usize;
        for outs in &res.outputs {
            for r in outs {
                for doc_part in r.value.split(' ') {
                    if let Some((_, positions)) = doc_part.split_once(':') {
                        got += positions.split(',').count();
                    }
                }
            }
        }
        assert_eq!(got, n_postings);
    }
}
