//! Sessionization (§4.6.2, application 2): recover per-user sessions
//! from a Web server log — "at its core, a large distributed sort",
//! α = 1.0.
//!
//! Map parses a log entry into (user id, timestamp) and emits the
//! composite key `id|timestamp` with the unchanged value. The engine's
//! sort-by-full-key + group-by-`group_key` reproduces Hadoop's custom
//! `SortComparator`/`GroupingComparator` secondary-sort: the reduce sees
//! one user's entries in timestamp order and splits sessions at gaps
//! larger than [`crate::data::weblog::SESSION_GAP`].

use crate::data::weblog::{parse_entry, SESSION_GAP};
use crate::engine::job::{MapReduceApp, Record};

#[derive(Debug, Clone, Copy, Default)]
pub struct Sessionize;

impl MapReduceApp for Sessionize {
    fn name(&self) -> &'static str {
        "sessionize"
    }

    fn map(&self, record: &Record, emit: &mut dyn FnMut(Record)) {
        if let Some((user, ts)) = parse_entry(&record.value) {
            // Zero-padded timestamp so lexicographic order = numeric.
            emit(Record::new(format!("{user}|{ts:012}"), record.value.clone()));
        }
    }

    /// Group on the user id (the part before '|') — the custom
    /// GroupingComparator of the paper's implementation.
    fn group_key<'a>(&self, key: &'a str) -> &'a str {
        key.split('|').next().unwrap_or(key)
    }

    fn reduce(&self, group: &str, records: &[Record], emit: &mut dyn FnMut(Record)) {
        // `records` arrive sorted by full key = (user, timestamp).
        let mut session = 0usize;
        let mut last_ts: Option<u64> = None;
        let mut count = 0usize;
        let mut start_ts = 0u64;
        for rec in records {
            let (_, ts) = match parse_entry(&rec.value) {
                Some(p) => p,
                None => continue,
            };
            match last_ts {
                Some(prev) if ts.saturating_sub(prev) <= SESSION_GAP => {
                    count += 1;
                }
                Some(_) => {
                    emit(Record::new(
                        format!("{group}#s{session}"),
                        format!("start={start_ts} n={count}"),
                    ));
                    session += 1;
                    start_ts = ts;
                    count = 1;
                }
                None => {
                    start_ts = ts;
                    count = 1;
                }
            }
            last_ts = Some(ts);
        }
        if count > 0 {
            emit(Record::new(
                format!("{group}#s{session}"),
                format!("start={start_ts} n={count}"),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::weblog::{generate, WeblogConfig};
    use crate::engine::{run_job, JobConfig};
    use crate::model::plan::Plan;
    use crate::platform::topology::example_1_3;
    use crate::platform::MB;
    use crate::util::rng::Pcg64;

    #[test]
    fn map_builds_composite_key() {
        let mut out = Vec::new();
        Sessionize.map(
            &Record::new("0001", "user000042 1234 /x 200 100"),
            &mut |r| out.push(r),
        );
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].key, "user000042|000000001234");
        assert_eq!(Sessionize.group_key(&out[0].key), "user000042");
    }

    #[test]
    fn reduce_splits_on_gaps() {
        let mk = |ts: u64| Record::new(format!("u|{ts:012}"), format!("u {ts} /x 200 10"));
        let recs = vec![mk(100), mk(200), mk(5000), mk(5100)];
        let mut out = Vec::new();
        Sessionize.reduce("u", &recs, &mut |r| out.push(r));
        assert_eq!(out.len(), 2, "{out:?}");
        assert!(out[0].value.contains("n=2"));
        assert!(out[1].value.contains("n=2"));
    }

    #[test]
    fn single_session_when_gaps_small() {
        let mk = |ts: u64| Record::new(format!("u|{ts:012}"), format!("u {ts} /x 200 10"));
        let recs: Vec<Record> = (0..10).map(|i| mk(i * 60)).collect();
        let mut out = Vec::new();
        Sessionize.reduce("u", &recs, &mut |r| out.push(r));
        assert_eq!(out.len(), 1);
        assert!(out[0].value.contains("n=10"));
    }

    #[test]
    fn end_to_end_sessions_match_sequential_reference() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let mut rng = Pcg64::new(21);
        let inputs: Vec<Vec<Record>> = (0..2)
            .map(|_| {
                generate(
                    WeblogConfig { n_users: 40, ..Default::default() },
                    40_000,
                    &mut rng,
                )
            })
            .collect();
        // Sequential reference: sort all entries, sessionize per user.
        let mut all: Vec<(String, u64)> = inputs
            .iter()
            .flatten()
            .filter_map(|r| parse_entry(&r.value).map(|(u, t)| (u.to_string(), t)))
            .collect();
        all.sort();
        let mut expect_sessions = 0usize;
        {
            let mut cur_user: Option<&str> = None;
            let mut last_ts = 0u64;
            for (u, t) in &all {
                match cur_user {
                    Some(cu) if cu == u && t.saturating_sub(last_ts) <= SESSION_GAP => {}
                    _ => expect_sessions += 1,
                }
                cur_user = Some(u);
                last_ts = *t;
            }
        }
        let res = run_job(
            &t,
            &Plan::uniform(2, 2, 2),
            &Sessionize,
            &JobConfig::default(),
            &inputs,
        );
        let got_sessions: usize = res.outputs.iter().map(Vec::len).sum();
        assert_eq!(got_sessions, expect_sessions);
    }

    #[test]
    fn alpha_is_one_ish() {
        // The mapper routes data without aggregation or expansion
        // (paper: α = 1.0). Composite keys add a little overhead.
        let mut rng = Pcg64::new(22);
        let logs = generate(WeblogConfig::default(), 100_000, &mut rng);
        let in_bytes: usize = logs.iter().map(|r| r.size()).sum();
        let mut out_bytes = 0usize;
        for r in &logs {
            Sessionize.map(r, &mut |o| out_bytes += o.size());
        }
        let alpha = out_bytes as f64 / in_bytes as f64;
        assert!((0.8..1.6).contains(&alpha), "α = {alpha}");
    }
}
