//! Word Count with in-mapper combining (§4.6.2, application 1).
//!
//! Map: tokenize the document, count term occurrences *within the
//! mapper's record* (the Lin & Dyer in-mapper-combining pattern, which is
//! what gives the application its strong aggregation, α ≈ 0.09 in the
//! paper). Reduce: sum the partial counts per term.

use crate::engine::job::{MapReduceApp, Record};
use std::collections::HashMap;

#[derive(Debug, Clone, Copy, Default)]
pub struct WordCount;

impl MapReduceApp for WordCount {
    fn name(&self) -> &'static str {
        "wordcount"
    }

    fn map(&self, record: &Record, emit: &mut dyn FnMut(Record)) {
        // Per-record combining (used when the engine maps record-wise).
        self.map_split(std::slice::from_ref(record), emit)
    }

    /// In-mapper combining across the whole split (Lin & Dyer): one
    /// partial count per distinct term per split — the source of the
    /// application's α ≪ 1.
    fn map_split(&self, records: &[Record], emit: &mut dyn FnMut(Record)) {
        let mut counts: HashMap<&str, u64> = HashMap::new();
        for record in records {
            for token in record.value.split(|c: char| !c.is_alphanumeric()) {
                if !token.is_empty() {
                    *counts.entry(token).or_default() += 1;
                }
            }
        }
        // Deterministic emission order (stable tests).
        let mut entries: Vec<(&str, u64)> = counts.into_iter().collect();
        entries.sort_unstable();
        for (term, count) in entries {
            emit(Record::new(term, count.to_string()));
        }
    }

    fn reduce(&self, group: &str, records: &[Record], emit: &mut dyn FnMut(Record)) {
        let total: u64 = records
            .iter()
            .map(|r| r.value.parse::<u64>().expect("count"))
            .sum();
        emit(Record::new(group, total.to_string()));
    }

    fn map_cost_factor(&self) -> f64 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{generate, CorpusConfig};
    use crate::engine::job::batch_size;
    use crate::engine::{run_job, JobConfig};
    use crate::model::plan::Plan;
    use crate::platform::topology::example_1_3;
    use crate::platform::MB;
    use crate::util::rng::Pcg64;

    #[test]
    fn map_counts_within_document() {
        let mut out = Vec::new();
        WordCount.map(&Record::new("d1", "a b a c a b"), &mut |r| out.push(r));
        out.sort();
        assert_eq!(
            out,
            vec![
                Record::new("a", "3"),
                Record::new("b", "2"),
                Record::new("c", "1")
            ]
        );
    }

    #[test]
    fn reduce_sums() {
        let mut out = Vec::new();
        WordCount.reduce(
            "term",
            &[Record::new("term", "3"), Record::new("term", "4")],
            &mut |r| out.push(r),
        );
        assert_eq!(out, vec![Record::new("term", "7")]);
    }

    #[test]
    fn end_to_end_counts_are_exact() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let mut rng = Pcg64::new(11);
        let inputs: Vec<Vec<Record>> = (0..2)
            .map(|_| generate(CorpusConfig::default(), 60_000, &mut rng))
            .collect();
        // Ground truth.
        let mut truth: HashMap<String, u64> = HashMap::new();
        for src in &inputs {
            for rec in src {
                for tok in rec.value.split(' ') {
                    *truth.entry(tok.to_string()).or_default() += 1;
                }
            }
        }
        let res = run_job(
            &t,
            &Plan::uniform(2, 2, 2),
            &WordCount,
            &JobConfig::default(),
            &inputs,
        );
        let mut got: HashMap<String, u64> = HashMap::new();
        for outs in &res.outputs {
            for r in outs {
                assert!(
                    got.insert(r.key.clone(), r.value.parse().unwrap()).is_none(),
                    "duplicate output key {}",
                    r.key
                );
            }
        }
        assert_eq!(got, truth);
    }

    #[test]
    fn alpha_is_much_less_than_one() {
        // The measured expansion factor on Zipf text should show heavy
        // aggregation when combining across a whole split (paper:
        // α = 0.09 on Gutenberg text).
        let mut rng = Pcg64::new(12);
        let docs = generate(CorpusConfig::default(), 500_000, &mut rng);
        let in_bytes = batch_size(&docs) as f64;
        let mut out_bytes = 0.0;
        WordCount.map_split(&docs, &mut |r| out_bytes += r.size() as f64);
        let alpha = out_bytes / in_bytes;
        assert!(alpha < 0.5, "α = {alpha}, expected strong aggregation");
    }
}
