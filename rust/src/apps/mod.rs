//! The evaluation applications (§4.6.2) plus the synthetic-α validation
//! app (§3.2). Measured expansion factors on our generated workloads are
//! profiled by [`measure_alpha`] — the paper's "α can be determined by
//! profiling the MapReduce application".

pub mod inverted_index;
pub mod sessionize;
pub mod synthetic;
pub mod wordcount;

pub use inverted_index::InvertedIndex;
pub use sessionize::Sessionize;
pub use synthetic::SyntheticApp;
pub use wordcount::WordCount;

use crate::engine::job::{batch_size, MapReduceApp, Record};

/// Profile an application's expansion factor α on a sample input split
/// (ratio of mapper output bytes to input bytes, §2.1).
pub fn measure_alpha(app: &dyn MapReduceApp, sample: &[Record]) -> f64 {
    let in_bytes = batch_size(sample) as f64;
    assert!(in_bytes > 0.0);
    let mut out_bytes = 0.0;
    app.map_split(sample, &mut |r| out_bytes += r.size() as f64);
    out_bytes / in_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{corpus, fwdindex, weblog};
    use crate::util::rng::Pcg64;

    /// The paper's application ordering: WordCount (0.09) < Sessionize
    /// (1.0) < InvertedIndex (1.88). Our generated workloads reproduce
    /// the ordering (absolute values differ with the synthetic data).
    #[test]
    fn alpha_ordering_matches_paper() {
        let mut rng = Pcg64::new(100);
        let text = corpus::generate(corpus::CorpusConfig::default(), 400_000, &mut rng);
        let logs = weblog::generate(weblog::WeblogConfig::default(), 200_000, &mut rng);
        let fwd = fwdindex::generate(corpus::CorpusConfig::default(), 200_000, &mut rng);

        let a_wc = measure_alpha(&WordCount, &text);
        let a_se = measure_alpha(&Sessionize, &logs);
        let a_ii = measure_alpha(&InvertedIndex, &fwd);
        assert!(
            a_wc < a_se && a_se < a_ii,
            "α ordering violated: wc={a_wc} sess={a_se} ii={a_ii}"
        );
        assert!(a_wc < 0.5, "wordcount should aggregate, α={a_wc}");
        assert!(a_ii > 1.2, "inverted index should expand, α={a_ii}");
    }

    #[test]
    fn synthetic_alpha_profiles_close_to_nominal() {
        let recs: Vec<Record> = (0..3000)
            .map(|i| Record::new(format!("k{i:06}"), "x".repeat(40)))
            .collect();
        for &alpha in &[0.1, 1.0, 2.0] {
            let app = SyntheticApp::new(alpha);
            let got = measure_alpha(&app, &recs);
            assert!((got - alpha).abs() < 0.1 * (1.0 + alpha), "α={alpha} got {got}");
        }
    }
}
