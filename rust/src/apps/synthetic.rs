//! The synthetic validation application of §3.2: direct control over the
//! expansion factor α and over per-record compute cost.
//!
//! "Mappers in this job read a key-value pair and emit that same
//! key-value pair an appropriate number of times to achieve the
//! user-specified α value. For example, if α = 0.5, then this synthetic
//! mapper would directly emit only every other input key-value pair;
//! with α = 2, it would emit every input key-value pair twice. This job
//! uses an identity reducer."
//!
//! Fractional α is realized by a deterministic accumulator (e.g. α = 1.5
//! emits a second copy of every other record); compute heterogeneity is
//! emulated with the cost factors (§3.2).

use crate::engine::job::{MapReduceApp, Record};
use std::sync::atomic::{AtomicU64, Ordering};

pub struct SyntheticApp {
    pub alpha: f64,
    pub map_cost: f64,
    pub reduce_cost: f64,
    /// Deterministic fractional-emission accumulator (per process).
    acc: AtomicU64,
}

impl SyntheticApp {
    pub fn new(alpha: f64) -> SyntheticApp {
        assert!(alpha >= 0.0);
        SyntheticApp { alpha, map_cost: 1.0, reduce_cost: 1.0, acc: AtomicU64::new(0) }
    }

    pub fn with_costs(mut self, map_cost: f64, reduce_cost: f64) -> SyntheticApp {
        self.map_cost = map_cost;
        self.reduce_cost = reduce_cost;
        self
    }
}

/// Fixed-point accumulator granularity.
const FP: u64 = 1 << 20;

impl MapReduceApp for SyntheticApp {
    fn name(&self) -> &'static str {
        "synthetic-alpha"
    }

    fn map(&self, record: &Record, emit: &mut dyn FnMut(Record)) {
        // Emit ⌊acc + α⌋ − ⌊acc⌋ copies, advancing acc by α: long-run
        // emission rate is exactly α copies per record.
        let add = (self.alpha * FP as f64).round() as u64;
        let before = self.acc.fetch_add(add, Ordering::Relaxed);
        let copies = ((before + add) / FP - before / FP) as usize;
        for c in 0..copies {
            // Distinct keys per copy keep the key-space hash-uniform.
            if c == 0 {
                emit(record.clone());
            } else {
                emit(Record::new(format!("{}~{c}", record.key), record.value.clone()));
            }
        }
    }

    fn reduce(&self, _group: &str, records: &[Record], emit: &mut dyn FnMut(Record)) {
        // Identity reducer.
        for r in records {
            emit(r.clone());
        }
    }

    fn map_cost_factor(&self) -> f64 {
        self.map_cost
    }

    fn reduce_cost_factor(&self) -> f64 {
        self.reduce_cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::job::batch_size;

    fn run_alpha(alpha: f64, n: usize) -> f64 {
        let app = SyntheticApp::new(alpha);
        let inputs: Vec<Record> = (0..n)
            .map(|i| Record::new(format!("key-{i:06}"), "v".repeat(32)))
            .collect();
        let in_bytes = batch_size(&inputs) as f64;
        let mut out_bytes = 0.0;
        for r in &inputs {
            app.map(r, &mut |o| out_bytes += o.size() as f64);
        }
        out_bytes / in_bytes
    }

    #[test]
    fn alpha_realized_exactly_for_integers() {
        assert!((run_alpha(1.0, 1000) - 1.0).abs() < 0.01);
        let a2 = run_alpha(2.0, 1000);
        assert!((a2 - 2.0).abs() < 0.1, "α=2 realized {a2}");
    }

    #[test]
    fn alpha_realized_for_fractions() {
        for &alpha in &[0.1, 0.5, 1.5] {
            let got = run_alpha(alpha, 4000);
            assert!(
                (got - alpha).abs() < 0.08 * (1.0 + alpha),
                "α={alpha} realized {got}"
            );
        }
    }

    #[test]
    fn identity_reduce() {
        let app = SyntheticApp::new(1.0);
        let recs = vec![Record::new("a", "1"), Record::new("a", "2")];
        let mut out = Vec::new();
        app.reduce("a", &recs, &mut |r| out.push(r));
        assert_eq!(out, recs);
    }

    #[test]
    fn cost_factors_exposed() {
        let app = SyntheticApp::new(1.0).with_costs(2.5, 0.5);
        assert_eq!(app.map_cost_factor(), 2.5);
        assert_eq!(app.reduce_cost_factor(), 0.5);
    }
}
