//! AOT artifact discovery and loading.
//!
//! `make artifacts` (python/compile/aot.py) writes shape-specialized HLO
//! **text** files plus a `manifest.json`; this module finds the artifact
//! directory, parses the manifest (own tiny JSON-subset parser — no
//! serde offline) and compiles artifacts on the PJRT CPU client.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::errors::{anyhow, bail, Context, Result};

/// Shape signature of one artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ArtifactShape {
    pub s: usize,
    pub m: usize,
    pub r: usize,
    pub p: usize,
}

impl ArtifactShape {
    pub fn tag(&self) -> String {
        format!("s{}m{}r{}p{}", self.s, self.m, self.r, self.p)
    }
}

/// Locate the artifacts directory: `$MRPERF_ARTIFACTS`, else `artifacts/`
/// relative to the working directory or the crate root.
pub fn artifacts_dir() -> Option<PathBuf> {
    if let Ok(dir) = std::env::var("MRPERF_ARTIFACTS") {
        let p = PathBuf::from(dir);
        if p.is_dir() {
            return Some(p);
        }
    }
    for base in [
        PathBuf::from("artifacts"),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ] {
        if base.is_dir() {
            return Some(base);
        }
    }
    None
}

/// Parsed manifest entry.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub shape: ArtifactShape,
}

/// Parse `manifest.json`. The file is machine-written with a known flat
/// structure (`{"name": {"file": "...", "S": n, ...}, ...}`), so a
/// minimal tokenizer suffices (no serde in the offline registry).
pub fn parse_manifest(text: &str) -> Result<Vec<ManifestEntry>> {
    let mut entries = Vec::new();
    // Split on top-level `"name": {` ... `}` blocks.
    let mut rest = text;
    while let Some(start) = rest.find('"') {
        rest = &rest[start + 1..];
        let end = rest.find('"').ok_or_else(|| anyhow!("unterminated key"))?;
        let key = &rest[..end];
        rest = &rest[end + 1..];
        let brace = match rest.find('{') {
            Some(b) => b,
            None => break,
        };
        let close = rest[brace..]
            .find('}')
            .ok_or_else(|| anyhow!("unterminated object for {key}"))?;
        let body = &rest[brace + 1..brace + close];
        rest = &rest[brace + close + 1..];

        let fields = parse_flat_object(body);
        let file = fields
            .get("file")
            .ok_or_else(|| anyhow!("{key}: missing file"))?
            .trim_matches('"')
            .to_string();
        let dim = |k: &str| -> Result<usize> {
            fields
                .get(k)
                .and_then(|v| v.parse().ok())
                .ok_or_else(|| anyhow!("{key}: missing {k}"))
        };
        entries.push(ManifestEntry {
            name: key.to_string(),
            file,
            shape: ArtifactShape { s: dim("S")?, m: dim("M")?, r: dim("R")?, p: dim("P")? },
        });
    }
    if entries.is_empty() {
        bail!("manifest contains no entries");
    }
    Ok(entries)
}

fn parse_flat_object(body: &str) -> HashMap<String, String> {
    let mut out = HashMap::new();
    for part in split_top_level_commas(body) {
        if let Some((k, v)) = part.split_once(':') {
            let key = k.trim().trim_matches('"').to_string();
            let value = v.trim().to_string();
            out.insert(key, value);
        }
    }
    out
}

fn split_top_level_commas(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '{' | '[' if !in_str => depth += 1,
            '}' | ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

/// Load the manifest from the artifacts directory.
pub fn load_manifest(dir: &Path) -> Result<Vec<ManifestEntry>> {
    let path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_manifest(&text)
}

/// Find an artifact by base name (`opt_run`, `plan_eval`) and shape.
pub fn find_artifact(
    entries: &[ManifestEntry],
    base: &str,
    s: usize,
    m: usize,
    r: usize,
) -> Option<ManifestEntry> {
    entries
        .iter()
        .find(|e| {
            e.name.starts_with(base)
                && e.shape.s == s
                && e.shape.m == m
                && e.shape.r == r
        })
        .cloned()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
  "opt_run_s8m8r8p16": {
    "file": "opt_run_s8m8r8p16.hlo.txt",
    "S": 8, "M": 8, "R": 8, "P": 16,
    "k_steps": 20
  },
  "plan_eval_s2m2r2p4": {
    "file": "plan_eval_s2m2r2p4.hlo.txt",
    "S": 2, "M": 2, "R": 2, "P": 4,
    "k_steps": null
  }
}"#;

    #[test]
    fn parse_sample_manifest() {
        let entries = parse_manifest(SAMPLE).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].name, "opt_run_s8m8r8p16");
        assert_eq!(entries[0].shape, ArtifactShape { s: 8, m: 8, r: 8, p: 16 });
        assert_eq!(entries[1].file, "plan_eval_s2m2r2p4.hlo.txt");
    }

    #[test]
    fn find_by_base_and_shape() {
        let entries = parse_manifest(SAMPLE).unwrap();
        let e = find_artifact(&entries, "plan_eval", 2, 2, 2).unwrap();
        assert_eq!(e.shape.p, 4);
        assert!(find_artifact(&entries, "plan_eval", 3, 3, 3).is_none());
    }

    #[test]
    fn rejects_empty() {
        assert!(parse_manifest("{}").is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        if let Some(dir) = artifacts_dir() {
            if dir.join("manifest.json").exists() {
                let entries = load_manifest(&dir).unwrap();
                assert!(find_artifact(&entries, "opt_run", 8, 8, 8).is_some());
                for e in &entries {
                    assert!(dir.join(&e.file).exists(), "missing {}", e.file);
                }
            }
        }
    }
}
