//! PJRT runtime: load the `artifacts/*.hlo.txt` files produced by the
//! build-time python AOT path (`make artifacts`) and execute them from
//! the coordinator. Python never runs at job time.

pub mod artifact;
pub mod client;
pub mod planner_art;

pub use artifact::{artifacts_dir, load_manifest};
pub use client::{Executable, Runtime, Tensor};
pub use planner_art::{ArtifactPlanner, ArtifactPlannerConfig};
