//! Typed wrapper over the AOT planner artifacts: the L1/L2-backed
//! end-to-end multi-phase optimizer, executed from rust via PJRT.
//!
//! `opt_run` advances a batch of multi-start plan logits by K Adam steps
//! on the smooth makespan (analytic JAX gradients, lowered once at build
//! time); `plan_eval` scores the decoded plans under the exact model
//! through the L1 Pallas kernel. The rust driver anneals β across
//! `opt_run` calls and returns the best start — the same algorithm as
//! [`crate::optimizer::gradient`] with the finite-difference backend,
//! but with exact gradients and one device dispatch per K steps.

use std::path::PathBuf;

use crate::util::errors::{anyhow, ensure, Context, Result};

use super::artifact::{artifacts_dir, find_artifact, load_manifest, ManifestEntry};
use super::client::{Executable, Runtime, Tensor};
use crate::model::barrier::BarrierConfig;
use crate::model::makespan::AppModel;
use crate::model::plan::Plan;
use crate::model::smooth::{selectors, softmax, softmax_rows};
use crate::platform::Topology;
use crate::util::mat::Mat;
use crate::util::rng::Pcg64;

/// Driver hyperparameters (mirrors `optimizer::gradient::GradConfig`).
#[derive(Debug, Clone, Copy)]
pub struct ArtifactPlannerConfig {
    /// `opt_run` invocations (each = K_STEPS Adam steps).
    pub rounds: usize,
    pub lr: f32,
    pub beta_start: f64,
    pub beta_end: f64,
    pub seed: u64,
}

impl Default for ArtifactPlannerConfig {
    fn default() -> Self {
        ArtifactPlannerConfig {
            rounds: 12,
            lr: 0.25,
            beta_start: 20.0,
            beta_end: 400.0,
            seed: 0x9A7,
        }
    }
}

/// The PJRT-backed planner. Holds compiled executables for one shape.
pub struct ArtifactPlanner {
    runtime: Runtime,
    opt_run: Executable,
    plan_eval: Executable,
    shape: (usize, usize, usize, usize), // S, M, R, P
    pub config: ArtifactPlannerConfig,
}

impl ArtifactPlanner {
    /// Load artifacts for an (S, M, R) topology shape from the default
    /// artifacts directory. Errors if `make artifacts` has not produced
    /// a matching shape.
    pub fn load(s: usize, m: usize, r: usize) -> Result<ArtifactPlanner> {
        let dir = artifacts_dir().ok_or_else(|| {
            anyhow!("artifacts directory not found — run `make artifacts`")
        })?;
        let entries = load_manifest(&dir).context("loading artifact manifest")?;
        let opt_entry = find_artifact(&entries, "opt_run", s, m, r)
            .ok_or_else(|| anyhow!("no opt_run artifact for s{s}m{m}r{r}"))?;
        let eval_entry = find_artifact(&entries, "plan_eval", s, m, r)
            .ok_or_else(|| anyhow!("no plan_eval artifact for s{s}m{m}r{r}"))?;
        Self::load_entries(&dir, &opt_entry, &eval_entry)
    }

    fn load_entries(
        dir: &PathBuf,
        opt_entry: &ManifestEntry,
        eval_entry: &ManifestEntry,
    ) -> Result<ArtifactPlanner> {
        let runtime = Runtime::cpu()?;
        let opt_run = runtime.compile_hlo_text(&dir.join(&opt_entry.file))?;
        let plan_eval = runtime.compile_hlo_text(&dir.join(&eval_entry.file))?;
        let sh = opt_entry.shape;
        Ok(ArtifactPlanner {
            runtime,
            opt_run,
            plan_eval,
            shape: (sh.s, sh.m, sh.r, sh.p),
            config: ArtifactPlannerConfig::default(),
        })
    }

    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    /// Run the optimization; returns the best plan under the exact model.
    pub fn optimize(
        &self,
        topo: &Topology,
        app: AppModel,
        cfg: BarrierConfig,
    ) -> Result<Plan> {
        let (s, m, r, p) = self.shape;
        ensure!(
            topo.n_sources() == s && topo.n_mappers() == m && topo.n_reducers() == r,
            "topology shape {}x{}x{} does not match artifact {}x{}x{}",
            topo.n_sources(),
            topo.n_mappers(),
            topo.n_reducers(),
            s,
            m,
            r
        );
        let c = self.config;

        // Work in GB/GBps units to keep f32 comfortable.
        const U: f64 = 1e9;
        let d: Vec<f32> = topo.d.iter().map(|&v| (v / U) as f32).collect();
        let flat = |mat: &Mat| -> Vec<f32> {
            mat.data().iter().map(|&v| (v / U) as f32).collect()
        };
        let b_sm = flat(&topo.b_sm);
        let b_mr = flat(&topo.b_mr);
        let c_map: Vec<f32> = topo.c_map.iter().map(|&v| (v / U) as f32).collect();
        let c_red: Vec<f32> = topo.c_red.iter().map(|&v| (v / U) as f32).collect();
        let sel: Vec<f32> = selectors(cfg).iter().map(|&v| v as f32).collect();

        // Scale: the uniform plan's exact makespan (in scaled units).
        let uniform = Plan::uniform(s, m, r);
        let mut topo_scaled = topo.clone();
        for v in topo_scaled.d.iter_mut() {
            *v /= U;
        }
        for v in topo_scaled
            .b_sm
            .data_mut()
            .iter_mut()
            .chain(topo_scaled.b_mr.data_mut().iter_mut())
        {
            *v /= U;
        }
        for v in topo_scaled
            .c_map
            .iter_mut()
            .chain(topo_scaled.c_red.iter_mut())
        {
            *v /= U;
        }
        let gscale =
            crate::model::makespan::makespan(&topo_scaled, app, cfg, &uniform).max(1e-12);

        // Multi-start logits; start 0 = uniform.
        let mut rng = Pcg64::new(c.seed);
        let mut lx: Vec<f32> = (0..p * s * m).map(|_| rng.normal() as f32 * 0.5).collect();
        let mut ly: Vec<f32> = (0..p * r).map(|_| rng.normal() as f32 * 0.5).collect();
        for v in lx.iter_mut().take(s * m) {
            *v = 0.0;
        }
        for v in ly.iter_mut().take(r) {
            *v = 0.0;
        }
        let mut mx = vec![0.0f32; p * s * m];
        let mut vx = vec![0.0f32; p * s * m];
        let mut my = vec![0.0f32; p * r];
        let mut vy = vec![0.0f32; p * r];
        let mut t = 0.0f32;

        for round in 0..c.rounds {
            let frac = round as f64 / (c.rounds.max(2) - 1) as f64;
            let beta_norm = c.beta_start * (c.beta_end / c.beta_start).powf(frac);
            let beta = (beta_norm / gscale) as f32;
            let out = self.opt_run.run_f32(&[
                Tensor::new(vec![p, s, m], lx.clone()),
                Tensor::new(vec![p, r], ly.clone()),
                Tensor::new(vec![p, s, m], mx.clone()),
                Tensor::new(vec![p, s, m], vx.clone()),
                Tensor::new(vec![p, r], my.clone()),
                Tensor::new(vec![p, r], vy.clone()),
                Tensor::scalar(t),
                Tensor::scalar(beta),
                Tensor::scalar(c.lr),
                Tensor::vec(d.clone()),
                Tensor::new(vec![s, m], b_sm.clone()),
                Tensor::new(vec![m, r], b_mr.clone()),
                Tensor::vec(c_map.clone()),
                Tensor::vec(c_red.clone()),
                Tensor::scalar(app.alpha as f32),
                Tensor::vec(sel.clone()),
                Tensor::scalar(gscale as f32),
            ])?;
            ensure!(out.len() == 8, "opt_run returned {} outputs", out.len());
            lx = out[0].clone();
            ly = out[1].clone();
            mx = out[2].clone();
            vx = out[3].clone();
            my = out[4].clone();
            vy = out[5].clone();
            t = out[6][0];
        }

        // Score every start with the exact (hard) model via plan_eval.
        let eval = self.plan_eval.run_f32(&[
            Tensor::new(vec![p, s, m], lx.clone()),
            Tensor::new(vec![p, r], ly.clone()),
            Tensor::vec(d),
            Tensor::new(vec![s, m], b_sm),
            Tensor::new(vec![m, r], b_mr),
            Tensor::vec(c_map),
            Tensor::vec(c_red),
            Tensor::scalar(app.alpha as f32),
            Tensor::vec(sel),
        ])?;
        let scores = &eval[0]; // (P, 5)
        let best = best_start(&scores.data, p);

        // Decode the winning start's logits into a Plan.
        let mut logits_x = Mat::zeros(s, m);
        for i in 0..s {
            for j in 0..m {
                logits_x[(i, j)] = lx[best * s * m + i * m + j] as f64;
            }
        }
        let logits_y: Vec<f64> = (0..r).map(|k| ly[best * r + k] as f64).collect();
        let mut plan = Plan { x: softmax_rows(&logits_x), y: softmax(&logits_y) };
        plan.renormalize();
        Ok(plan)
    }
}

/// Index of the start whose hard-model makespan (column 4 of the
/// `(P, 5)` score matrix) is smallest. `f32::total_cmp` so a NaN score
/// — e.g. from a degenerate topology propagating through the evaluator
/// — totally orders after every finite value instead of panicking.
fn best_start(scores: &[f32], p: usize) -> usize {
    (0..p)
        .min_by(|&a, &b| scores[a * 5 + 4].total_cmp(&scores[b * 5 + 4]))
        .expect("planner evaluated zero starts")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::makespan::makespan;
    use crate::platform::topology::example_1_3;
    use crate::platform::MB;

    /// Regression (NaN-unsafe sort): picking the best start used
    /// `partial_cmp(..).unwrap()` over hard-model scores, which panics
    /// when an evaluator score is NaN (degenerate bandwidth propagates
    /// through the softmax/cost graph). `f32::total_cmp` ranks NaN
    /// after +inf, so the finite starts still win deterministically.
    /// Fails on the pre-fix code.
    #[test]
    fn best_start_survives_nan_scores() {
        let scores = vec![
            0.0, 0.0, 0.0, 0.0, f32::NAN, // start 0: NaN makespan
            0.0, 0.0, 0.0, 0.0, 3.5, // start 1: best finite
            0.0, 0.0, 0.0, 0.0, 7.0, // start 2: worse finite
        ];
        assert_eq!(best_start(&scores, 3), 1);
        // All-NaN still resolves (first index) rather than panicking.
        assert_eq!(best_start(&[f32::NAN; 5], 1), 0);
    }

    fn artifacts_available() -> bool {
        artifacts_dir()
            .map(|d| d.join("manifest.json").exists())
            .unwrap_or(false)
    }

    /// Full L3→PJRT→L2/L1 integration: the artifact-backed planner beats
    /// uniform on the §1.3 instance. Skipped without `make artifacts`.
    #[test]
    fn artifact_planner_beats_uniform_2x2x2() {
        if !artifacts_available() {
            return;
        }
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let planner = ArtifactPlanner::load(2, 2, 2).unwrap();
        for &alpha in &[0.1, 10.0] {
            let app = AppModel::new(alpha);
            let cfg = BarrierConfig::ALL_GLOBAL;
            let plan = planner.optimize(&t, app, cfg).unwrap();
            plan.check(&t).unwrap();
            let uni = makespan(&t, app, cfg, &Plan::uniform(2, 2, 2));
            let got = makespan(&t, app, cfg, &plan);
            assert!(
                got < uni * 0.9,
                "α={alpha}: artifact planner {got} should beat uniform {uni} by 10%"
            );
        }
    }

    /// Artifact gradients vs rust finite-difference backend: both land
    /// within 30% of each other on the same instance.
    #[test]
    fn artifact_matches_finitediff_gradient() {
        if !artifacts_available() {
            return;
        }
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let app = AppModel::new(1.0);
        let cfg = BarrierConfig::ALL_GLOBAL;
        let planner = ArtifactPlanner::load(2, 2, 2).unwrap();
        let art = makespan(&t, app, cfg, &planner.optimize(&t, app, cfg).unwrap());
        // Explicitly the finite-difference oracle: the default backend is
        // analytic now, but this cross-check wants an independent path.
        let fd_plan = crate::optimizer::GradientOptimizer::finite_diff();
        use crate::optimizer::PlanOptimizer;
        let fd = makespan(&t, app, cfg, &fd_plan.optimize(&t, app, cfg));
        let rel = (art - fd).abs() / fd;
        assert!(rel < 0.3, "artifact {art} vs finite-diff {fd} (rel {rel})");
    }
}
