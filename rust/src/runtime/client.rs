//! PJRT client wrapper: load HLO-text artifacts, compile once, execute
//! many times. Pattern from /opt/xla-example/load_hlo.
//!
//! The PJRT CPU client is created lazily and shared; executables are
//! cached per artifact path so repeated optimizer invocations pay the
//! compile cost once.
//!
//! The XLA-backed implementation is gated behind the `pjrt` cargo feature
//! (which requires the vendored `xla` crate from the rust_pallas
//! toolchain). The default build ships API-compatible stubs whose
//! constructor returns an error, so every caller degrades gracefully:
//! `ArtifactPlanner::load` fails cleanly, and the `artifact` optimizer /
//! runtime benches report the feature as unavailable instead of failing
//! to link.

use std::path::Path;

use crate::util::errors::Result;

#[cfg(feature = "pjrt")]
use crate::util::errors::Context;

#[cfg(not(feature = "pjrt"))]
use crate::util::errors::Error;

/// A compiled artifact ready to execute.
pub struct Executable {
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    #[cfg(not(feature = "pjrt"))]
    _unconstructible: std::convert::Infallible,
}

/// Wrapper around the process-wide PJRT CPU client.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(not(feature = "pjrt"))]
    _unconstructible: std::convert::Infallible,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a CPU PJRT runtime.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO **text** file and compile it.
    ///
    /// Text is the interchange format: jax ≥ 0.5 emits protos with
    /// 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
    /// parser reassigns ids (see /opt/xla-example/README.md).
    pub fn compile_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with f32 tensor inputs, returning all tuple outputs as
    /// flat f32 vectors (jax lowers with `return_tuple=True`).
    pub fn run_f32(&self, inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data).reshape(&dims).context("reshape input")
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let parts = result.to_tuple().context("untupling result")?;
        parts
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Stub: the default build has no PJRT backend.
    pub fn cpu() -> Result<Runtime> {
        Err(Error::msg(
            "mrperf was built without the PJRT backend; add the vendored \
             `xla` crate to rust/Cargo.toml and rebuild with `--features \
             pjrt` to execute AOT artifacts",
        ))
    }

    pub fn platform(&self) -> String {
        match self._unconstructible {}
    }

    pub fn compile_hlo_text(&self, _path: &Path) -> Result<Executable> {
        match self._unconstructible {}
    }
}

#[cfg(not(feature = "pjrt"))]
impl Executable {
    pub fn run_f32(&self, _inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        match self._unconstructible {}
    }
}

/// A dense f32 tensor (input helper).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Tensor {
        let n: usize = dims.iter().product();
        assert_eq!(n, data.len(), "tensor data length mismatch");
        Tensor { dims, data }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { dims: vec![], data: vec![v] }
    }

    pub fn vec(data: Vec<f32>) -> Tensor {
        Tensor { dims: vec![data.len()], data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_and_vec_constructors() {
        let s = Tensor::scalar(2.5);
        assert!(s.dims.is_empty());
        let v = Tensor::vec(vec![1.0, 2.0]);
        assert_eq!(v.dims, vec![2]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn tensor_shape_mismatch_panics() {
        let _ = Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = Runtime::cpu().unwrap_err();
        assert!(format!("{err}").contains("pjrt"));
    }

    /// End-to-end PJRT round trip on the mini plan_eval artifact:
    /// uniform 2×2×2 plan on the §1.3-style homogeneous platform.
    /// Requires `make artifacts`; skipped silently otherwise.
    #[cfg(feature = "pjrt")]
    #[test]
    fn plan_eval_artifact_roundtrip() {
        use crate::runtime::artifact::{artifacts_dir, find_artifact, load_manifest};
        let Some(dir) = artifacts_dir() else { return };
        if !dir.join("manifest.json").exists() {
            return;
        }
        let entries = load_manifest(&dir).unwrap();
        let entry = find_artifact(&entries, "plan_eval", 2, 2, 2).unwrap();
        let rt = Runtime::cpu().unwrap();
        let exe = rt.compile_hlo_text(&dir.join(&entry.file)).unwrap();

        let p = entry.shape.p;
        // logits zero = uniform plan; platform in GB / GBps units.
        let lx = Tensor::new(vec![p, 2, 2], vec![0.0; p * 4]);
        let ly = Tensor::new(vec![p, 2], vec![0.0; p * 2]);
        let d = Tensor::vec(vec![150.0, 50.0]);
        let b = Tensor::new(vec![2, 2], vec![0.1, 0.1, 0.1, 0.1]);
        let c = Tensor::vec(vec![0.1, 0.1]);
        let sel = Tensor::vec(vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0]); // G-G-G
        let out = exe
            .run_f32(&[
                lx,
                ly,
                d,
                b.clone(),
                b,
                c.clone(),
                c,
                Tensor::scalar(1.0),
                sel,
            ])
            .unwrap();
        // Single output: (P, 5).
        assert_eq!(out.len(), 1);
        let vals = &out[0];
        assert_eq!(vals.len(), p * 5);
        // §1.3 scenario 1: push 750, map 1000, shuffle 500, reduce 1000,
        // makespan 3250 — for every plan in the batch (all uniform).
        for plan in 0..p {
            let row = &vals[plan * 5..plan * 5 + 5];
            let expect = [750.0, 1000.0, 500.0, 1000.0, 3250.0];
            for (got, want) in row.iter().zip(expect) {
                assert!(
                    (got - want).abs() < 0.5,
                    "plan {plan}: got {row:?}, want {expect:?}"
                );
            }
        }
    }
}
