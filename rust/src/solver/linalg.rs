//! Dense symmetric linear algebra for the interior-point solver:
//! Cholesky factorization with diagonal regularization.

/// Dense symmetric positive-definite solve via Cholesky (in place).
///
/// `m` is row-major `n×n`; only the lower triangle is read. A small
/// multiple of the diagonal mean is added when a pivot underflows
/// (regularization for the near-rank-deficient normal equations that
/// degenerate LPs produce).
pub struct Cholesky {
    l: Vec<f64>,
    n: usize,
}

impl Cholesky {
    pub fn factor(mut a: Vec<f64>, n: usize) -> Cholesky {
        assert_eq!(a.len(), n * n);
        // Regularization floor from the diagonal scale.
        let diag_mean: f64 =
            (0..n).map(|i| a[i * n + i].abs()).sum::<f64>() / n.max(1) as f64;
        let floor = (diag_mean * 1e-12).max(1e-30);
        for j in 0..n {
            // d = a_jj - Σ l_jk²
            let mut d = a[j * n + j];
            for k in 0..j {
                let l = a[j * n + k];
                d -= l * l;
            }
            if d < floor {
                d = floor;
            }
            let dj = d.sqrt();
            a[j * n + j] = dj;
            let inv = 1.0 / dj;
            for i in (j + 1)..n {
                let mut v = a[i * n + j];
                let (row_i, row_j) = (i * n, j * n);
                for k in 0..j {
                    v -= a[row_i + k] * a[row_j + k];
                }
                a[i * n + j] = v * inv;
            }
        }
        Cholesky { l: a, n }
    }

    /// Solve `L Lᵀ x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.n);
        let n = self.n;
        let l = &self.l;
        let mut x = b.to_vec();
        // Forward: L z = b
        for i in 0..n {
            let mut v = x[i];
            let row = i * n;
            for k in 0..i {
                v -= l[row + k] * x[k];
            }
            x[i] = v / l[row + i];
        }
        // Backward: Lᵀ y = z
        for i in (0..n).rev() {
            let mut v = x[i];
            for k in (i + 1)..n {
                v -= l[k * n + i] * x[k];
            }
            x[i] = v / l[i * n + i];
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn identity() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let ch = Cholesky::factor(a, 2);
        let x = ch.solve(&[3.0, 4.0]);
        assert!((x[0] - 3.0).abs() < 1e-12);
        assert!((x[1] - 4.0).abs() < 1e-12);
    }

    #[test]
    fn known_spd() {
        // A = [[4,2],[2,3]], b = [10, 9] → x = [1.5, 2.0]
        let a = vec![4.0, 2.0, 2.0, 3.0];
        let ch = Cholesky::factor(a, 2);
        let x = ch.solve(&[10.0, 9.0]);
        assert!((x[0] - 1.5).abs() < 1e-10, "{x:?}");
        assert!((x[1] - 2.0).abs() < 1e-10);
    }

    #[test]
    fn random_spd_roundtrip() {
        let mut rng = Pcg64::new(42);
        for n in [3usize, 8, 20] {
            // A = G Gᵀ + I (SPD), x random, b = A x; solve and compare.
            let g: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
            let mut a = vec![0.0; n * n];
            for i in 0..n {
                for j in 0..n {
                    let mut v = if i == j { 1.0 } else { 0.0 };
                    for k in 0..n {
                        v += g[i * n + k] * g[j * n + k];
                    }
                    a[i * n + j] = v;
                }
            }
            let x_true: Vec<f64> = (0..n).map(|_| rng.uniform(-2.0, 2.0)).collect();
            let b: Vec<f64> = (0..n)
                .map(|i| (0..n).map(|j| a[i * n + j] * x_true[j]).sum())
                .collect();
            let ch = Cholesky::factor(a, n);
            let x = ch.solve(&b);
            for i in 0..n {
                assert!((x[i] - x_true[i]).abs() < 1e-8, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn regularized_singular_does_not_nan() {
        // Rank-1 matrix: factorization must not produce NaN.
        let a = vec![1.0, 1.0, 1.0, 1.0];
        let ch = Cholesky::factor(a, 2);
        let x = ch.solve(&[1.0, 1.0]);
        assert!(x.iter().all(|v| v.is_finite()));
    }
}
