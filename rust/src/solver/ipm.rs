//! Primal-dual interior-point LP solver (Mehrotra predictor-corrector).
//!
//! The plan-optimization LPs are heavily degenerate (dozens of identical
//! epigraph rows active at the optimum), which is hostile territory for a
//! tableau simplex — error accumulation plus cycling. Interior-point
//! methods are indifferent to degeneracy: every iteration refactors the
//! normal equations from the *original* data, so errors do not compound.
//! This is the default solver for all plan LPs; the simplex
//! ([`super::simplex`]) remains for branch & bound, which wants vertex
//! solutions.
//!
//! Standard form: rows are converted to `A x = b, x ≥ 0` by appending a
//! slack (`≤`) or surplus (`≥`) column per inequality. The infeasible-
//! start method needs no artificial variables or phase 1.
//!
//! Reference: Nocedal & Wright, *Numerical Optimization*, ch. 14.

use super::linalg::Cholesky;
use super::lp::{Cmp, Lp, LpOutcome};

/// Iteration cap; typical solves converge in 15–35 iterations.
const MAX_ITERS: usize = 60;
/// Relative tolerance on primal/dual residuals and the duality gap.
const TOL: f64 = 1e-8;
/// Acceptance tolerance at the iteration cap (best iterate).
const TOL_ACCEPT: f64 = 1e-6;
/// Fraction of the way to the boundary a step may travel.
const STEP_FRAC: f64 = 0.995;
/// Divergence guard: variables beyond this magnitude ⇒ unbounded.
const BLOWUP: f64 = 1e14;

struct Standard {
    /// Row-major dense `m × n` (including slack columns).
    a: Vec<f64>,
    b: Vec<f64>,
    c: Vec<f64>,
    m: usize,
    n: usize,
    n_orig: usize,
    /// Per-column scale applied (solution must be multiplied back).
    col_scale: Vec<f64>,
    /// Per-row scale applied to b.
    row_scale: Vec<f64>,
}

/// Equilibrated standard-form conversion.
fn standardize(lp: &Lp) -> Standard {
    let m = lp.n_rows();
    let n_slack = lp
        .rows
        .iter()
        .filter(|r| r.cmp != Cmp::Eq)
        .count();
    let n = lp.n_vars + n_slack;

    // --- scaling (same geometric-mean equilibration idea as simplex) ---
    let mut row_scale = vec![1.0f64; m];
    let mut col_scale = vec![1.0f64; lp.n_vars];
    for _ in 0..3 {
        for (ri, row) in lp.rows.iter().enumerate() {
            let mut lo = f64::INFINITY;
            let mut hi = 0.0f64;
            for &(v, cf) in &row.terms {
                let a = (cf * col_scale[v] / row_scale[ri]).abs();
                if a > 0.0 {
                    lo = lo.min(a);
                    hi = hi.max(a);
                }
            }
            if hi > 0.0 {
                row_scale[ri] *= (lo * hi).sqrt();
            }
        }
        let mut lo = vec![f64::INFINITY; lp.n_vars];
        let mut hi = vec![0.0f64; lp.n_vars];
        for (ri, row) in lp.rows.iter().enumerate() {
            for &(v, cf) in &row.terms {
                let a = (cf * col_scale[v] / row_scale[ri]).abs();
                if a > 0.0 {
                    lo[v] = lo[v].min(a);
                    hi[v] = hi[v].max(a);
                }
            }
        }
        for v in 0..lp.n_vars {
            if hi[v] > 0.0 {
                col_scale[v] /= (lo[v] * hi[v]).sqrt();
            }
        }
    }

    let mut a = vec![0.0f64; m * n];
    let mut b = vec![0.0f64; m];
    let mut c = vec![0.0f64; n];
    for v in 0..lp.n_vars {
        c[v] = lp.objective[v] * col_scale[v];
    }
    let mut slack = lp.n_vars;
    let mut full_scale = col_scale.clone();
    for (ri, row) in lp.rows.iter().enumerate() {
        for &(v, cf) in &row.terms {
            a[ri * n + v] += cf * col_scale[v] / row_scale[ri];
        }
        b[ri] = row.rhs / row_scale[ri];
        match row.cmp {
            Cmp::Le => {
                a[ri * n + slack] = 1.0;
                full_scale.push(1.0);
                slack += 1;
            }
            Cmp::Ge => {
                a[ri * n + slack] = -1.0;
                full_scale.push(1.0);
                slack += 1;
            }
            Cmp::Eq => {}
        }
    }
    Standard { a, b, c, m, n, n_orig: lp.n_vars, col_scale: full_scale, row_scale }
}

/// Solve a minimization LP with the interior-point method.
pub fn solve(lp: &Lp) -> LpOutcome {
    if lp.has_implicit_bounds() {
        // Row-only solver: lower implicit bounds into explicit rows
        // (the recursive call sees no bounds).
        return solve(&lp.materialize_bounds());
    }
    if lp.n_rows() == 0 {
        // Unconstrained: optimum at 0 for c ≥ 0, else unbounded.
        if lp.objective.iter().any(|&c| c < 0.0) {
            return LpOutcome::Unbounded;
        }
        return LpOutcome::Optimal { x: vec![0.0; lp.n_vars], objective: 0.0 };
    }
    let std = standardize(lp);
    let (m, n) = (std.m, std.n);
    let a = &std.a;
    // Column-wise sparse view: cols[j] = [(row, value)…] with row indices
    // ascending — used to build the normal equations sparsely.
    let cols: Vec<Vec<(usize, f64)>> = {
        let mut cols = vec![Vec::new(); n];
        for i in 0..m {
            let row = &a[i * n..(i + 1) * n];
            for (j, &v) in row.iter().enumerate() {
                if v != 0.0 {
                    cols[j].push((i, v));
                }
            }
        }
        cols
    };

    // Mehrotra's starting point (N&W §14.2): least-squares x̃ = Aᵀ(AAᵀ)⁻¹b,
    // ỹ = (AAᵀ)⁻¹Ac, s̃ = c − Aᵀỹ, shifted into the positive orthant.
    let (mut x, mut y, mut s) = {
        let mut m0 = vec![0.0f64; m * m];
        for i in 0..m {
            let rowi = &a[i * n..(i + 1) * n];
            for k in i..m {
                let rowk = &a[k * n..(k + 1) * n];
                let mut acc = 0.0;
                for j in 0..n {
                    acc += rowi[j] * rowk[j];
                }
                m0[i * m + k] = acc;
                m0[k * m + i] = acc;
            }
        }
        let chol = Cholesky::factor(m0, m);
        let w = chol.solve(&std.b);
        let mut x0 = vec![0.0f64; n];
        for i in 0..m {
            let row = &a[i * n..(i + 1) * n];
            for j in 0..n {
                x0[j] += row[j] * w[i];
            }
        }
        let mut ac = vec![0.0f64; m];
        for i in 0..m {
            let row = &a[i * n..(i + 1) * n];
            let mut acc = 0.0;
            for j in 0..n {
                acc += row[j] * std.c[j];
            }
            ac[i] = acc;
        }
        let y0 = chol.solve(&ac);
        let mut s0 = std.c.clone();
        for i in 0..m {
            let row = &a[i * n..(i + 1) * n];
            let yi = y0[i];
            for j in 0..n {
                s0[j] -= row[j] * yi;
            }
        }
        // Shift into the interior.
        let dx = x0.iter().cloned().fold(0.0f64, |acc, v| acc.max(-1.5 * v)).max(0.0);
        let ds = s0.iter().cloned().fold(0.0f64, |acc, v| acc.max(-1.5 * v)).max(0.0);
        for v in x0.iter_mut() {
            *v += dx;
        }
        for v in s0.iter_mut() {
            *v += ds;
        }
        let xs: f64 = x0.iter().zip(&s0).map(|(a, b)| a * b).sum();
        let sx: f64 = s0.iter().sum();
        let sxv: f64 = x0.iter().sum();
        let dxh = if sx > 0.0 { 0.5 * xs / sx } else { 1.0 };
        let dsh = if sxv > 0.0 { 0.5 * xs / sxv } else { 1.0 };
        for v in x0.iter_mut() {
            *v += dxh.max(1e-2);
        }
        for v in s0.iter_mut() {
            *v += dsh.max(1e-2);
        }
        (x0, y0, s0)
    };

    let norm_b = 1.0 + std.b.iter().map(|v| v * v).sum::<f64>().sqrt();
    let norm_c = 1.0 + std.c.iter().map(|v| v * v).sum::<f64>().sqrt();

    let mut best: Option<Vec<f64>> = None;
    let mut best_score = f64::INFINITY;
    for _iter in 0..MAX_ITERS {
        // Residuals.
        let mut rp = std.b.clone(); // b - A x
        for i in 0..m {
            let row = &a[i * n..(i + 1) * n];
            let mut dot = 0.0;
            for (rv, xv) in row.iter().zip(&x) {
                dot += rv * xv;
            }
            rp[i] -= dot;
        }
        let mut rd = std.c.clone(); // c - A'y - s
        for i in 0..m {
            let row = &a[i * n..(i + 1) * n];
            let yi = y[i];
            if yi != 0.0 {
                for (j, rv) in row.iter().enumerate() {
                    rd[j] -= rv * yi;
                }
            }
        }
        for j in 0..n {
            rd[j] -= s[j];
        }
        let mu: f64 = x.iter().zip(&s).map(|(a, b)| a * b).sum::<f64>() / n as f64;

        let rp_norm = rp.iter().map(|v| v * v).sum::<f64>().sqrt() / norm_b;
        let rd_norm = rd.iter().map(|v| v * v).sum::<f64>().sqrt() / norm_c;
        if std::env::var("MRPERF_IPM_DEBUG").is_ok() {
            eprintln!("[ipm] iter {_iter}: rp {rp_norm:.3e} rd {rd_norm:.3e} mu {mu:.3e}");
        }
        // Track the best iterate seen (IPMs can degrade after numerical
        // convergence; we keep the cleanest point).
        let score = rp_norm.max(rd_norm).max(mu / (1.0 + mu));
        if score < best_score {
            best_score = score;
            best = Some(x.clone());
        }
        if rp_norm < TOL && rd_norm < TOL && mu < TOL {
            break;
        }
        if x.iter().any(|v| !v.is_finite() || v.abs() > BLOWUP)
            || y.iter().any(|v| !v.is_finite() || v.abs() > BLOWUP)
        {
            // Diverging: primal or dual infeasible. Disambiguate crudely
            // by which residual refuses to shrink.
            return if rp_norm > rd_norm {
                LpOutcome::Infeasible
            } else {
                LpOutcome::Unbounded
            };
        }

        // Normal-equations matrix M = A D A', D = diag(x/s). Built
        // sparsely: rows carry ≲ 70 of ~450 columns, so accumulating
        // per-nonzero (M += a_ij·d_j · a_kj over the column's rows) is
        // ~8× cheaper than the dense triple loop (perf pass).
        let d: Vec<f64> = x.iter().zip(&s).map(|(xv, sv)| xv / sv).collect();
        let mut mmat = vec![0.0f64; m * m];
        for (j, col) in cols.iter().enumerate() {
            let dj = d[j];
            for (ci, &(i, aij)) in col.iter().enumerate() {
                let w = aij * dj;
                let base = i * m;
                for &(k, akj) in &col[ci..] {
                    mmat[base + k] += w * akj;
                }
            }
        }
        // Mirror the upper triangle (we accumulated i ≤ k).
        for i in 0..m {
            for k in (i + 1)..m {
                mmat[k * m + i] = mmat[i * m + k];
            }
        }
        let chol = Cholesky::factor(mmat, m);

        // Helper to solve one Newton system given the complementarity rhs
        // `rc` (length n): returns (dx, dy, ds).
        let solve_newton = |rc: &[f64]| -> (Vec<f64>, Vec<f64>, Vec<f64>) {
            // dy from A D A' dy = rp + A D (rd - X^{-1} rc)
            let mut tmp = vec![0.0f64; n]; // D (rd - X^{-1} rc)
            for j in 0..n {
                tmp[j] = d[j] * (rd[j] - rc[j] / x[j]);
            }
            let mut rhs = rp.clone();
            for i in 0..m {
                let row = &a[i * n..(i + 1) * n];
                let mut dot = 0.0;
                for (rv, tv) in row.iter().zip(&tmp) {
                    dot += rv * tv;
                }
                rhs[i] += dot;
            }
            let dy = chol.solve(&rhs);
            // ds = rd - A' dy ; dx = D (A'dy - rd) + X^{-1} rc * D ... use:
            // dx = D (A'dy - rd + X^{-1} rc)
            let mut aty = vec![0.0f64; n];
            for i in 0..m {
                let row = &a[i * n..(i + 1) * n];
                let dyi = dy[i];
                if dyi != 0.0 {
                    for (j, rv) in row.iter().enumerate() {
                        aty[j] += rv * dyi;
                    }
                }
            }
            let mut dx = vec![0.0f64; n];
            let mut ds = vec![0.0f64; n];
            for j in 0..n {
                ds[j] = rd[j] - aty[j];
                dx[j] = d[j] * (aty[j] - rd[j] + rc[j] / x[j]);
            }
            (dx, dy, ds)
        };

        // Predictor (affine) step: rc = -X S e.
        let rc_aff: Vec<f64> = x.iter().zip(&s).map(|(xv, sv)| -xv * sv).collect();
        let (dx_aff, _dy_aff, ds_aff) = solve_newton(&rc_aff);
        let alpha_p_aff = max_step(&x, &dx_aff);
        let alpha_d_aff = max_step(&s, &ds_aff);
        let mu_aff: f64 = (0..n)
            .map(|j| (x[j] + alpha_p_aff * dx_aff[j]) * (s[j] + alpha_d_aff * ds_aff[j]))
            .sum::<f64>()
            / n as f64;
        let sigma = (mu_aff / mu).powi(3).clamp(0.0, 1.0);

        // Corrector: rc = σμe - XSe - ΔX_aff ΔS_aff e.
        let rc: Vec<f64> = (0..n)
            .map(|j| sigma * mu - x[j] * s[j] - dx_aff[j] * ds_aff[j])
            .collect();
        let (dx, dy, ds) = solve_newton(&rc);
        let alpha_p = (STEP_FRAC * max_step(&x, &dx)).min(1.0);
        let alpha_d = (STEP_FRAC * max_step(&s, &ds)).min(1.0);
        for j in 0..n {
            x[j] += alpha_p * dx[j];
            s[j] += alpha_d * ds[j];
        }
        for i in 0..m {
            y[i] += alpha_d * dy[i];
        }
    }

    let xfull = match best {
        Some(x) if best_score < TOL_ACCEPT => x,
        // Could not reach acceptable residuals: report infeasible so
        // callers of known-feasible programs surface it loudly.
        _ => return LpOutcome::Infeasible,
    };

    // Un-scale and trim to the original variables.
    let mut sol = vec![0.0; std.n_orig];
    for j in 0..std.n_orig {
        sol[j] = (xfull[j] * std.col_scale[j]).max(0.0);
    }
    let _ = &std.row_scale; // row scaling only affects b; solution unaffected
    let objective = lp.objective_at(&sol);
    LpOutcome::Optimal { x: sol, objective }
}

/// Largest α ∈ (0, 1] with `v + α·dv ≥ 0` (componentwise), before damping.
fn max_step(v: &[f64], dv: &[f64]) -> f64 {
    let mut alpha: f64 = 1.0;
    for (vi, di) in v.iter().zip(dv) {
        if *di < 0.0 {
            alpha = alpha.min(-vi / di);
        }
    }
    alpha.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::lp::{Cmp, Lp};
    use crate::util::qcheck::{ensure, qcheck, Config};
    use crate::util::rng::Pcg64;

    fn assert_opt(outcome: LpOutcome, want: f64, tol: f64) -> Vec<f64> {
        match outcome {
            LpOutcome::Optimal { x, objective } => {
                assert!((objective - want).abs() <= tol, "objective {objective} vs {want}");
                x
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn basic_le() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.minimize(x, -1.0);
        lp.minimize(y, -1.0);
        lp.constraint(&[(x, 1.0), (y, 2.0)], Cmp::Le, 4.0);
        lp.constraint(&[(x, 3.0), (y, 1.0)], Cmp::Le, 6.0);
        let sol = assert_opt(solve(&lp), -(8.0 / 5.0 + 6.0 / 5.0), 1e-6);
        assert!((sol[0] - 1.6).abs() < 1e-5);
        assert!((sol[1] - 1.2).abs() < 1e-5);
    }

    #[test]
    fn eq_and_ge() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.minimize(x, 2.0);
        lp.minimize(y, 3.0);
        lp.constraint(&[(x, 1.0), (y, 1.0)], Cmp::Eq, 10.0);
        lp.constraint(&[(x, 1.0)], Cmp::Ge, 3.0);
        let sol = assert_opt(solve(&lp), 20.0, 1e-5);
        assert!((sol[0] - 10.0).abs() < 1e-4);
    }

    #[test]
    fn min_max_epigraph() {
        let mut lp = Lp::new();
        let z = lp.var("z");
        lp.minimize(z, 1.0);
        for &t in &[3.0, 7.0, 5.0] {
            lp.constraint(&[(z, 1.0)], Cmp::Ge, t);
        }
        assert_opt(solve(&lp), 7.0, 1e-6);
    }

    #[test]
    fn transportation() {
        let mut lp = Lp::new();
        let f: Vec<Vec<usize>> = (0..2)
            .map(|i| (0..2).map(|j| lp.var(format!("f{i}{j}"))).collect())
            .collect();
        let costs = [[1.0, 2.0], [3.0, 1.0]];
        for i in 0..2 {
            for j in 0..2 {
                lp.minimize(f[i][j], costs[i][j]);
            }
        }
        lp.constraint(&[(f[0][0], 1.0), (f[0][1], 1.0)], Cmp::Eq, 10.0);
        lp.constraint(&[(f[1][0], 1.0), (f[1][1], 1.0)], Cmp::Eq, 20.0);
        lp.constraint(&[(f[0][0], 1.0), (f[1][0], 1.0)], Cmp::Eq, 15.0);
        lp.constraint(&[(f[0][1], 1.0), (f[1][1], 1.0)], Cmp::Eq, 15.0);
        assert_opt(solve(&lp), 40.0, 1e-5);
    }

    #[test]
    fn degenerate_duplicated_rows() {
        // Heavy degeneracy: 50 identical epigraph rows.
        let mut lp = Lp::new();
        let z = lp.var("z");
        let w = lp.var("w");
        lp.minimize(z, 1.0);
        lp.constraint(&[(w, 1.0)], Cmp::Eq, 0.5);
        for _ in 0..50 {
            lp.constraint(&[(z, 1.0), (w, -2.0)], Cmp::Ge, 0.0);
        }
        let sol = assert_opt(solve(&lp), 1.0, 1e-6);
        assert!((sol[0] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn agrees_with_simplex_on_random_lps() {
        qcheck(Config::default().cases(40), "IPM == simplex", |rng: &mut Pcg64| {
            let nv = rng.range(2, 6);
            let nc = rng.range(1, 8);
            let mut lp = Lp::new();
            let vars: Vec<usize> = (0..nv).map(|i| lp.var(format!("v{i}"))).collect();
            let x0: Vec<f64> = (0..nv).map(|_| rng.uniform(0.0, 5.0)).collect();
            for v in &vars {
                lp.minimize(*v, rng.uniform(-1.0, 2.0));
            }
            for _ in 0..nc {
                let terms: Vec<(usize, f64)> =
                    vars.iter().map(|&v| (v, rng.uniform(-1.0, 1.0))).collect();
                let lhs: f64 = terms.iter().map(|&(v, c)| c * x0[v]).sum();
                lp.constraint(&terms, Cmp::Le, lhs + rng.uniform(0.0, 2.0));
            }
            for v in &vars {
                lp.upper_bound(*v, 10.0);
            }
            let ipm = solve(&lp);
            let spx = crate::solver::simplex::solve(&lp);
            match (ipm, spx) {
                (
                    LpOutcome::Optimal { objective: oi, x: xi },
                    LpOutcome::Optimal { objective: os, .. },
                ) => {
                    ensure(lp.violation(&xi) < 1e-5, format!("viol {}", lp.violation(&xi)))?;
                    ensure(
                        (oi - os).abs() <= 1e-4 * (1.0 + os.abs()),
                        format!("IPM {oi} vs simplex {os}"),
                    )
                }
                (a, b) => Err(format!("IPM {a:?} vs simplex {b:?}")),
            }
        });
    }
}
