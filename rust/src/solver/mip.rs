//! Mixed-integer programming by LP-relaxation branch & bound.
//!
//! The paper's §2.3 formulation needs binary choice variables for the
//! concave side of the piecewise-linear bilinear approximation; Gurobi is
//! unavailable offline, so we branch & bound over our own simplex:
//! depth-first with best-known-incumbent pruning, branching on the most
//! fractional binary.

use super::lp::{Lp, LpOutcome};
use super::simplex::solve;

/// Outcome of a MIP solve.
#[derive(Debug, Clone, PartialEq)]
pub enum MipOutcome {
    Optimal { x: Vec<f64>, objective: f64 },
    Infeasible,
    /// The relaxation was unbounded (the integral problem may be too).
    Unbounded,
}

/// Solver knobs.
#[derive(Debug, Clone, Copy)]
pub struct MipConfig {
    /// Give up after this many branch-and-bound nodes.
    pub max_nodes: usize,
    /// Integrality tolerance.
    pub int_tol: f64,
    /// Relative optimality gap at which a node is pruned.
    pub gap: f64,
}

impl Default for MipConfig {
    fn default() -> Self {
        MipConfig { max_nodes: 200_000, int_tol: 1e-6, gap: 1e-9 }
    }
}

/// Solve `lp` with the given variables restricted to {0, 1}.
///
/// Branching fixes a variable via equality rows appended to a copy of the
/// LP — wasteful asymptotically but fine at the problem sizes the paper's
/// formulation produces for small instances (see DESIGN.md §3: the full
/// PWL-MIP is exercised at 2–3 node scale; larger environments use the
/// alternating-LP optimizer).
pub fn solve_binary(lp: &Lp, binaries: &[usize], config: MipConfig) -> MipOutcome {
    // Root relaxation with 0 ≤ b ≤ 1 bounds on binaries.
    let mut root = lp.clone();
    for &b in binaries {
        root.upper_bound(b, 1.0);
    }

    let mut best: Option<(Vec<f64>, f64)> = None;
    let mut nodes = 0usize;
    // Stack of (lp, fixed-so-far description for debugging).
    let mut stack: Vec<Lp> = vec![root];

    while let Some(node_lp) = stack.pop() {
        nodes += 1;
        if nodes > config.max_nodes {
            break;
        }
        let outcome = solve(&node_lp);
        let (x, obj) = match outcome {
            LpOutcome::Optimal { x, objective } => (x, objective),
            LpOutcome::Infeasible => continue,
            LpOutcome::Unbounded => {
                if best.is_none() && nodes == 1 {
                    return MipOutcome::Unbounded;
                }
                continue;
            }
        };
        // Prune by bound.
        if let Some((_, incumbent)) = &best {
            if obj >= incumbent - config.gap * incumbent.abs().max(1.0) {
                continue;
            }
        }
        // Most fractional binary.
        let mut branch_var = None;
        let mut best_frac = config.int_tol;
        for &b in binaries {
            let frac = (x[b] - x[b].round()).abs();
            if frac > best_frac {
                best_frac = frac;
                branch_var = Some(b);
            }
        }
        match branch_var {
            None => {
                // Integral: new incumbent.
                match &best {
                    Some((_, inc)) if obj >= *inc => {}
                    _ => best = Some((x, obj)),
                }
            }
            Some(b) => {
                let mut lo = node_lp.clone();
                lo.fix(b, 0.0);
                let mut hi = node_lp;
                hi.fix(b, 1.0);
                // DFS: explore the rounded-nearest branch first.
                if x[b] >= 0.5 {
                    stack.push(lo);
                    stack.push(hi);
                } else {
                    stack.push(hi);
                    stack.push(lo);
                }
            }
        }
    }

    match best {
        Some((x, objective)) => MipOutcome::Optimal { x, objective },
        None => MipOutcome::Infeasible,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::lp::{Cmp, Lp};

    #[test]
    fn knapsack_small() {
        // max 10a + 6b + 4c s.t. a+b+c ≤ 2 (binary) → a+b = 16.
        let mut lp = Lp::new();
        let a = lp.var("a");
        let b = lp.var("b");
        let c = lp.var("c");
        lp.minimize(a, -10.0);
        lp.minimize(b, -6.0);
        lp.minimize(c, -4.0);
        lp.constraint(&[(a, 1.0), (b, 1.0), (c, 1.0)], Cmp::Le, 2.0);
        match solve_binary(&lp, &[a, b, c], MipConfig::default()) {
            MipOutcome::Optimal { x, objective } => {
                assert!((objective + 16.0).abs() < 1e-7);
                assert!((x[a] - 1.0).abs() < 1e-6);
                assert!((x[b] - 1.0).abs() < 1e-6);
                assert!(x[c].abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn weighted_knapsack_forces_branching() {
        // max 5a+4b+3c s.t. 2a+3b+c ≤ 3. LP relax is fractional;
        // integer optimum: a + c = 8 (weight 3).
        let mut lp = Lp::new();
        let a = lp.var("a");
        let b = lp.var("b");
        let c = lp.var("c");
        lp.minimize(a, -5.0);
        lp.minimize(b, -4.0);
        lp.minimize(c, -3.0);
        lp.constraint(&[(a, 2.0), (b, 3.0), (c, 1.0)], Cmp::Le, 3.0);
        match solve_binary(&lp, &[a, b, c], MipConfig::default()) {
            MipOutcome::Optimal { objective, .. } => {
                assert!((objective + 8.0).abs() < 1e-7, "objective {objective}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn infeasible_mip() {
        // a + b ≥ 3 with two binaries: impossible.
        let mut lp = Lp::new();
        let a = lp.var("a");
        let b = lp.var("b");
        lp.constraint(&[(a, 1.0), (b, 1.0)], Cmp::Ge, 3.0);
        lp.upper_bound(a, 1.0);
        lp.upper_bound(b, 1.0);
        assert_eq!(
            solve_binary(&lp, &[a, b], MipConfig::default()),
            MipOutcome::Infeasible
        );
    }

    #[test]
    fn mixed_continuous_and_binary() {
        // min y s.t. y ≥ 2 - 4δ, y ≥ 4δ - 2, δ binary → δ=.5 infeasible;
        // δ∈{0,1} gives y=2 either way.
        let mut lp = Lp::new();
        let y = lp.var("y");
        let d = lp.var("d");
        lp.minimize(y, 1.0);
        lp.constraint(&[(y, 1.0), (d, 4.0)], Cmp::Ge, 2.0);
        lp.constraint(&[(y, 1.0), (d, -4.0)], Cmp::Ge, -2.0);
        match solve_binary(&lp, &[d], MipConfig::default()) {
            MipOutcome::Optimal { x, objective } => {
                assert!((objective - 2.0).abs() < 1e-7);
                let dv = x[d];
                assert!(dv.abs() < 1e-6 || (dv - 1.0).abs() < 1e-6, "d = {dv}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn sos2_style_selection() {
        // Minimize a V-shaped PWL via two segments with a binary selector:
        // f(0)=1, f(1)=0, f(2)=1 over w∈[0,2]; min at w=1.
        // λ0,λ1,λ2 ≥ 0, Σλ=1, w=λ1+2λ2, f=λ0+λ2,
        // adjacency: λ0 ≤ δ0, λ1 ≤ δ0+δ1, λ2 ≤ δ1, δ0+δ1 = 1.
        let mut lp = Lp::new();
        let l0 = lp.var("l0");
        let l1 = lp.var("l1");
        let l2 = lp.var("l2");
        let d0 = lp.var("d0");
        let d1 = lp.var("d1");
        lp.minimize(l0, 1.0); // f = λ0 + λ2
        lp.minimize(l2, 1.0);
        lp.constraint(&[(l0, 1.0), (l1, 1.0), (l2, 1.0)], Cmp::Eq, 1.0);
        lp.constraint(&[(l0, 1.0), (d0, -1.0)], Cmp::Le, 0.0);
        lp.constraint(&[(l1, 1.0), (d0, -1.0), (d1, -1.0)], Cmp::Le, 0.0);
        lp.constraint(&[(l2, 1.0), (d1, -1.0)], Cmp::Le, 0.0);
        lp.constraint(&[(d0, 1.0), (d1, 1.0)], Cmp::Eq, 1.0);
        match solve_binary(&lp, &[d0, d1], MipConfig::default()) {
            MipOutcome::Optimal { x, objective } => {
                assert!(objective.abs() < 1e-7, "objective {objective}");
                assert!((x[l1] - 1.0).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }
}
