//! Linear-program builder: sparse rows over non-negative variables.
//!
//! The paper's optimization (§2.3) is expressed as LPs/MIPs; since no
//! solver crates are available offline we implement the whole stack:
//! this module is the problem representation, [`super::simplex`] the LP
//! algorithm, [`super::mip`] branch & bound, [`super::pwl`] the paper's
//! piecewise-linear bilinear linearization.
//!
//! All variables are non-negative; general bounds are encoded as rows.

/// Constraint sense.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

/// One sparse constraint row: `Σ coef·var  (≤|≥|=)  rhs`.
#[derive(Debug, Clone)]
pub struct Row {
    pub terms: Vec<(usize, f64)>,
    pub cmp: Cmp,
    pub rhs: f64,
}

/// A minimization LP over non-negative variables.
#[derive(Debug, Clone, Default)]
pub struct Lp {
    pub n_vars: usize,
    /// Objective coefficients (minimize `c·x`); sparse-by-default vec
    /// sized `n_vars`, zero-filled.
    pub objective: Vec<f64>,
    pub rows: Vec<Row>,
    names: Vec<String>,
}

impl Lp {
    pub fn new() -> Lp {
        Lp::default()
    }

    /// Add a variable, returning its index. `name` aids debugging.
    pub fn var(&mut self, name: impl Into<String>) -> usize {
        let idx = self.n_vars;
        self.n_vars += 1;
        self.objective.push(0.0);
        self.names.push(name.into());
        idx
    }

    /// Add `n` variables named `prefix[0..n)`.
    pub fn vars(&mut self, prefix: &str, n: usize) -> Vec<usize> {
        (0..n).map(|i| self.var(format!("{prefix}[{i}]"))).collect()
    }

    pub fn name(&self, var: usize) -> &str {
        &self.names[var]
    }

    /// Set the objective coefficient of one variable.
    pub fn minimize(&mut self, var: usize, coef: f64) {
        self.objective[var] = coef;
    }

    /// Add a constraint row. Terms with duplicate variables are merged.
    pub fn constraint(&mut self, terms: &[(usize, f64)], cmp: Cmp, rhs: f64) {
        let mut merged: Vec<(usize, f64)> = Vec::with_capacity(terms.len());
        for &(v, c) in terms {
            debug_assert!(v < self.n_vars, "dangling variable {v}");
            if c == 0.0 {
                continue;
            }
            if let Some(slot) = merged.iter_mut().find(|(mv, _)| *mv == v) {
                slot.1 += c;
            } else {
                merged.push((v, c));
            }
        }
        self.rows.push(Row { terms: merged, cmp, rhs });
    }

    /// Convenience: `var ≤ ub`.
    pub fn upper_bound(&mut self, var: usize, ub: f64) {
        self.constraint(&[(var, 1.0)], Cmp::Le, ub);
    }

    /// Convenience: fix `var = value`.
    pub fn fix(&mut self, var: usize, value: f64) {
        self.constraint(&[(var, 1.0)], Cmp::Eq, value);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Evaluate the objective at a point.
    pub fn objective_at(&self, x: &[f64]) -> f64 {
        self.objective.iter().zip(x).map(|(c, v)| c * v).sum()
    }

    /// Maximum constraint violation at a point (0 = feasible).
    pub fn violation(&self, x: &[f64]) -> f64 {
        let mut worst: f64 = 0.0;
        for row in &self.rows {
            let lhs: f64 = row.terms.iter().map(|&(v, c)| c * x[v]).sum();
            let viol = match row.cmp {
                Cmp::Le => (lhs - row.rhs).max(0.0),
                Cmp::Ge => (row.rhs - lhs).max(0.0),
                Cmp::Eq => (lhs - row.rhs).abs(),
            };
            worst = worst.max(viol);
        }
        for &v in x {
            worst = worst.max((-v).max(0.0));
        }
        worst
    }
}

/// LP solve outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum LpOutcome {
    Optimal { x: Vec<f64>, objective: f64 },
    Infeasible,
    Unbounded,
}

impl LpOutcome {
    pub fn optimal(self) -> Option<(Vec<f64>, f64)> {
        match self {
            LpOutcome::Optimal { x, objective } => Some((x, objective)),
            _ => None,
        }
    }

    pub fn expect_optimal(self, ctx: &str) -> (Vec<f64>, f64) {
        match self {
            LpOutcome::Optimal { x, objective } => (x, objective),
            other => panic!("{ctx}: expected optimal LP solution, got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_evaluate() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.minimize(x, 1.0);
        lp.minimize(y, 2.0);
        lp.constraint(&[(x, 1.0), (y, 1.0)], Cmp::Ge, 1.0);
        assert_eq!(lp.n_vars, 2);
        assert_eq!(lp.n_rows(), 1);
        assert_eq!(lp.objective_at(&[1.0, 0.5]), 2.0);
        assert_eq!(lp.violation(&[0.2, 0.3]), 0.5);
        assert_eq!(lp.violation(&[0.5, 0.5]), 0.0);
    }

    #[test]
    fn duplicate_terms_merge() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        lp.constraint(&[(x, 1.0), (x, 2.0)], Cmp::Le, 6.0);
        assert_eq!(lp.rows[0].terms, vec![(x, 3.0)]);
    }

    #[test]
    fn negative_values_are_violations() {
        let mut lp = Lp::new();
        let _ = lp.var("x");
        assert!(lp.violation(&[-0.5]) == 0.5);
    }
}
