//! Linear-program builder: sparse rows over non-negative variables.
//!
//! The paper's optimization (§2.3) is expressed as LPs/MIPs; since no
//! solver crates are available offline we implement the whole stack:
//! this module is the problem representation, [`super::simplex`] the LP
//! algorithm, [`super::mip`] branch & bound, [`super::pwl`] the paper's
//! piecewise-linear bilinear linearization.
//!
//! All variables are non-negative. Simple bounds `l ≤ x ≤ u` can be
//! attached *implicitly* via [`Lp::bound_below`] / [`Lp::bound_above`]:
//! the revised simplex handles them inside the ratio test without
//! spending a constraint row each, which is the row-count cut the plan
//! LPs rely on. Solvers that only understand rows call
//! [`Lp::materialize_bounds`] to lower them back into explicit rows
//! (the dense tableau and IPM paths do this internally, so they remain
//! drop-in oracles for bounded problems).

/// Constraint sense.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

/// One sparse constraint row: `Σ coef·var  (≤|≥|=)  rhs`.
#[derive(Debug, Clone)]
pub struct Row {
    pub terms: Vec<(usize, f64)>,
    pub cmp: Cmp,
    pub rhs: f64,
}

/// A minimization LP over non-negative variables.
#[derive(Debug, Clone, Default)]
pub struct Lp {
    pub n_vars: usize,
    /// Objective coefficients (minimize `c·x`); sparse-by-default vec
    /// sized `n_vars`, zero-filled.
    pub objective: Vec<f64>,
    pub rows: Vec<Row>,
    /// Implicit per-variable lower bounds (default 0; never negative —
    /// the stack's variables are non-negative by construction).
    pub lower: Vec<f64>,
    /// Implicit per-variable upper bounds (default `+∞`).
    pub upper: Vec<f64>,
    names: Vec<String>,
}

impl Lp {
    pub fn new() -> Lp {
        Lp::default()
    }

    /// Add a variable, returning its index. `name` aids debugging.
    pub fn var(&mut self, name: impl Into<String>) -> usize {
        let idx = self.n_vars;
        self.n_vars += 1;
        self.objective.push(0.0);
        self.lower.push(0.0);
        self.upper.push(f64::INFINITY);
        self.names.push(name.into());
        idx
    }

    /// Add `n` variables named `prefix[0..n)`.
    pub fn vars(&mut self, prefix: &str, n: usize) -> Vec<usize> {
        (0..n).map(|i| self.var(format!("{prefix}[{i}]"))).collect()
    }

    pub fn name(&self, var: usize) -> &str {
        &self.names[var]
    }

    /// Set the objective coefficient of one variable.
    pub fn minimize(&mut self, var: usize, coef: f64) {
        self.objective[var] = coef;
    }

    /// Add a constraint row. Terms with duplicate variables are merged.
    pub fn constraint(&mut self, terms: &[(usize, f64)], cmp: Cmp, rhs: f64) {
        let mut merged: Vec<(usize, f64)> = Vec::with_capacity(terms.len());
        for &(v, c) in terms {
            debug_assert!(v < self.n_vars, "dangling variable {v}");
            if c == 0.0 {
                continue;
            }
            if let Some(slot) = merged.iter_mut().find(|(mv, _)| *mv == v) {
                slot.1 += c;
            } else {
                merged.push((v, c));
            }
        }
        self.rows.push(Row { terms: merged, cmp, rhs });
    }

    /// Convenience: `var ≤ ub` as an explicit constraint row. Kept
    /// row-based (MIP branching and the PWL builder rewrite rows);
    /// prefer [`Lp::bound_above`] on pure-LP hot paths.
    pub fn upper_bound(&mut self, var: usize, ub: f64) {
        self.constraint(&[(var, 1.0)], Cmp::Le, ub);
    }

    /// Tighten the implicit lower bound: `var ≥ lb` without a row.
    /// Repeated calls keep the tightest (largest) bound; values below
    /// the default 0 are ignored (variables stay non-negative).
    pub fn bound_below(&mut self, var: usize, lb: f64) {
        self.lower[var] = self.lower[var].max(lb);
    }

    /// Tighten the implicit upper bound: `var ≤ ub` without a row.
    /// Repeated calls keep the tightest (smallest) bound.
    pub fn bound_above(&mut self, var: usize, ub: f64) {
        self.upper[var] = self.upper[var].min(ub);
    }

    /// Whether any implicit bound is tighter than the default `[0, ∞)`.
    pub fn has_implicit_bounds(&self) -> bool {
        self.lower.iter().any(|&l| l > 0.0)
            || self.upper.iter().any(|u| u.is_finite())
    }

    /// A copy with every implicit bound lowered into an explicit row
    /// (`x ≥ l` / `x ≤ u`) and the bound vectors reset to `[0, ∞)`.
    /// This is the bridge to row-only solvers and the baseline the
    /// bench row-count gate compares against.
    pub fn materialize_bounds(&self) -> Lp {
        let mut out = self.clone();
        for j in 0..out.n_vars {
            out.lower[j] = 0.0;
            out.upper[j] = f64::INFINITY;
        }
        for j in 0..self.n_vars {
            if self.lower[j] > 0.0 {
                out.constraint(&[(j, 1.0)], Cmp::Ge, self.lower[j]);
            }
            if self.upper[j].is_finite() {
                out.constraint(&[(j, 1.0)], Cmp::Le, self.upper[j]);
            }
        }
        out
    }

    /// Convenience: fix `var = value`.
    pub fn fix(&mut self, var: usize, value: f64) {
        self.constraint(&[(var, 1.0)], Cmp::Eq, value);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Evaluate the objective at a point.
    pub fn objective_at(&self, x: &[f64]) -> f64 {
        self.objective.iter().zip(x).map(|(c, v)| c * v).sum()
    }

    /// Maximum constraint violation at a point (0 = feasible).
    pub fn violation(&self, x: &[f64]) -> f64 {
        let mut worst: f64 = 0.0;
        for row in &self.rows {
            let lhs: f64 = row.terms.iter().map(|&(v, c)| c * x[v]).sum();
            let viol = match row.cmp {
                Cmp::Le => (lhs - row.rhs).max(0.0),
                Cmp::Ge => (row.rhs - lhs).max(0.0),
                Cmp::Eq => (lhs - row.rhs).abs(),
            };
            worst = worst.max(viol);
        }
        for (j, &v) in x.iter().enumerate() {
            let lo = self.lower.get(j).copied().unwrap_or(0.0);
            let hi = self.upper.get(j).copied().unwrap_or(f64::INFINITY);
            worst = worst.max(lo - v).max(v - hi).max(-v);
        }
        worst
    }
}

/// LP solve outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum LpOutcome {
    Optimal { x: Vec<f64>, objective: f64 },
    Infeasible,
    Unbounded,
}

impl LpOutcome {
    pub fn optimal(self) -> Option<(Vec<f64>, f64)> {
        match self {
            LpOutcome::Optimal { x, objective } => Some((x, objective)),
            _ => None,
        }
    }

    pub fn expect_optimal(self, ctx: &str) -> (Vec<f64>, f64) {
        match self {
            LpOutcome::Optimal { x, objective } => (x, objective),
            other => panic!("{ctx}: expected optimal LP solution, got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_evaluate() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.minimize(x, 1.0);
        lp.minimize(y, 2.0);
        lp.constraint(&[(x, 1.0), (y, 1.0)], Cmp::Ge, 1.0);
        assert_eq!(lp.n_vars, 2);
        assert_eq!(lp.n_rows(), 1);
        assert_eq!(lp.objective_at(&[1.0, 0.5]), 2.0);
        assert_eq!(lp.violation(&[0.2, 0.3]), 0.5);
        assert_eq!(lp.violation(&[0.5, 0.5]), 0.0);
    }

    #[test]
    fn duplicate_terms_merge() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        lp.constraint(&[(x, 1.0), (x, 2.0)], Cmp::Le, 6.0);
        assert_eq!(lp.rows[0].terms, vec![(x, 3.0)]);
    }

    #[test]
    fn negative_values_are_violations() {
        let mut lp = Lp::new();
        let _ = lp.var("x");
        assert!(lp.violation(&[-0.5]) == 0.5);
    }

    #[test]
    fn implicit_bounds_tighten_and_count_no_rows() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        assert!(!lp.has_implicit_bounds());
        lp.bound_below(x, 2.0);
        lp.bound_below(x, 1.0); // looser: ignored
        lp.bound_above(x, 5.0);
        lp.bound_above(x, 7.0); // looser: ignored
        assert_eq!(lp.lower[x], 2.0);
        assert_eq!(lp.upper[x], 5.0);
        assert!(lp.has_implicit_bounds());
        assert_eq!(lp.n_rows(), 0, "bounds must not spend rows");
        assert_eq!(lp.violation(&[1.0]), 1.0); // below lower
        assert_eq!(lp.violation(&[6.0]), 1.0); // above upper
        assert_eq!(lp.violation(&[3.0]), 0.0);
    }

    #[test]
    fn materialize_bounds_round_trips_to_rows() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.constraint(&[(x, 1.0), (y, 1.0)], Cmp::Ge, 1.0);
        lp.bound_below(x, 0.5);
        lp.bound_above(y, 2.0);
        let mat = lp.materialize_bounds();
        assert_eq!(mat.n_rows(), 3, "one row per non-default bound");
        assert!(!mat.has_implicit_bounds());
        // Same feasible region: violations agree at probe points.
        for probe in [[0.2, 0.9], [0.5, 2.5], [0.6, 0.4], [0.5, 0.5]] {
            assert!(
                (lp.violation(&probe) - mat.violation(&probe)).abs() < 1e-12,
                "probe {probe:?}"
            );
        }
    }
}
