//! Sparse revised simplex with a product-form inverse (PFI).
//!
//! The dense tableau ([`super::simplex`]) carries an explicit `(m+1)×(n+1)`
//! matrix, which is perfect for the paper's ≲300-row plan LPs but blows up
//! quadratically on the 256-node generated topologies (the `hier-wan:256`
//! x-LP has thousands of rows). This module is the large-problem path:
//!
//! * the constraint matrix lives in **CSC** (compressed sparse column)
//!   form and is never densified;
//! * the basis inverse is a **product of eta matrices** (Bartels–Golub
//!   style elementary column transforms), rebuilt from the basis columns
//!   every [`REFACTOR_EVERY`] pivots to bound fill-in and drift;
//! * pricing is Dantzig with **partial (cyclic block) pricing** on wide
//!   problems and a Bland fallback on degenerate plateaus;
//! * a solved basis can be returned and fed back in (**warm start**) —
//!   the alternating optimizer reuses the previous round's basis, which
//!   turns most re-solves into a handful of pivots.
//!
//! Standard-form conversion, scaling, and tolerances deliberately mirror
//! the dense solver so the two are interchangeable behind [`Lp`]; the
//! dense tableau remains the small-problem path and the cross-check
//! oracle (see `tests/optimizer_scale.rs`).

use super::lp::{Cmp, Lp, LpOutcome};
use super::simplex::equilibrate;

const EPS: f64 = 1e-9;
/// Reduced-cost tolerance for the entering test (matches the dense path).
const EPS_RC: f64 = 1e-6;
/// Minimum acceptable pivot magnitude in the ratio test.
const EPS_PIVOT: f64 = 1e-7;
/// Pivots without objective progress before switching to Bland's rule.
const STALL_TO_BLAND: usize = 500;
const MAX_ITERS: usize = 100_000;
/// Eta-file length that triggers a refactorization.
const REFACTOR_EVERY: usize = 64;
/// Partial pricing: once this many columns have been scanned and at least
/// one candidate found, take the best so far instead of finishing the
/// sweep. Optimality is only ever declared after a *full* sweep.
const PARTIAL_SPAN: usize = 4096;

/// Compressed sparse column matrix (column-major, row indices ascending).
struct Csc {
    col_ptr: Vec<usize>,
    row_ix: Vec<usize>,
    val: Vec<f64>,
}

impl Csc {
    fn col(&self, j: usize) -> (&[usize], &[f64]) {
        let a = self.col_ptr[j];
        let b = self.col_ptr[j + 1];
        (&self.row_ix[a..b], &self.val[a..b])
    }

    fn nnz_col(&self, j: usize) -> usize {
        self.col_ptr[j + 1] - self.col_ptr[j]
    }

    /// Scatter column `j` into the dense buffer (caller pre-zeroes).
    fn scatter(&self, j: usize, out: &mut [f64]) {
        let (rows, vals) = self.col(j);
        for (&r, &v) in rows.iter().zip(vals) {
            out[r] = v;
        }
    }

    /// `yᵀ·a_j` for a dense row vector `y`.
    fn dot_col(&self, j: usize, y: &[f64]) -> f64 {
        let (rows, vals) = self.col(j);
        let mut acc = 0.0;
        for (&r, &v) in rows.iter().zip(vals) {
            acc += y[r] * v;
        }
        acc
    }
}

/// One elementary transform: pivot on row `r` with transformed column
/// values `pivot` (at `r`) and `others` (elsewhere).
struct Eta {
    r: usize,
    pivot: f64,
    others: Vec<(usize, f64)>,
}

/// Equilibrated standard form `A x = b, x ≥ 0, b ≥ 0` with explicit
/// slack/surplus and artificial columns (layout mirrors the dense path).
struct Std {
    m: usize,
    n: usize,
    n_orig: usize,
    /// Columns `≥ art_base` are artificial.
    art_base: usize,
    n_art: usize,
    csc: Csc,
    b: Vec<f64>,
    /// Phase-2 objective over all n columns (scaled; slack/art zero).
    cost2: Vec<f64>,
    /// Per row, its slack-or-artificial unit column (basis repair).
    unit_col: Vec<usize>,
    /// Initial (cold) basis: one unit column per row.
    init_basis: Vec<usize>,
}

fn standardize(lp: &Lp, row_scale: &[f64], col_scale: &[f64]) -> Std {
    let m = lp.n_rows();
    let n_orig = lp.n_vars;

    #[derive(Clone, Copy, PartialEq)]
    enum RowKind {
        Slack,
        SurplusArt,
        Art,
    }
    let mut kinds = Vec::with_capacity(m);
    let mut signs = Vec::with_capacity(m);
    let mut n_slack = 0usize;
    let mut n_art = 0usize;
    for (r, row) in lp.rows.iter().enumerate() {
        let rhs_scaled = row.rhs / row_scale[r];
        let (kind, sign) = match row.cmp {
            Cmp::Le => {
                if rhs_scaled >= 0.0 {
                    (RowKind::Slack, 1.0)
                } else {
                    (RowKind::SurplusArt, -1.0)
                }
            }
            Cmp::Ge => {
                if rhs_scaled <= 0.0 {
                    (RowKind::Slack, -1.0)
                } else {
                    (RowKind::SurplusArt, 1.0)
                }
            }
            Cmp::Eq => (RowKind::Art, if rhs_scaled < 0.0 { -1.0 } else { 1.0 }),
        };
        match kind {
            RowKind::Slack => n_slack += 1,
            RowKind::SurplusArt => {
                n_slack += 1;
                n_art += 1;
            }
            RowKind::Art => n_art += 1,
        }
        kinds.push(kind);
        signs.push(sign);
    }

    let art_base = n_orig + n_slack;
    let n = art_base + n_art;

    // Column-major assembly. Structural entries land in row order because
    // rows are scanned in order and each row contributes at most one
    // entry per column (Lp::constraint merges duplicates).
    let mut cols: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n);
    for _ in 0..n {
        cols.push(Vec::new());
    }
    let mut b = vec![0.0; m];
    let mut unit_col = vec![usize::MAX; m];
    let mut init_basis = vec![usize::MAX; m];
    let mut slack_cursor = n_orig;
    let mut art_cursor = art_base;
    for (r, row) in lp.rows.iter().enumerate() {
        let sr = signs[r] / row_scale[r];
        for &(v, c) in &row.terms {
            cols[v].push((r, c * col_scale[v] * sr));
        }
        b[r] = signs[r] * row.rhs / row_scale[r];
        match kinds[r] {
            RowKind::Slack => {
                cols[slack_cursor].push((r, 1.0));
                unit_col[r] = slack_cursor;
                init_basis[r] = slack_cursor;
                slack_cursor += 1;
            }
            RowKind::SurplusArt => {
                cols[slack_cursor].push((r, -1.0));
                slack_cursor += 1;
                cols[art_cursor].push((r, 1.0));
                unit_col[r] = art_cursor;
                init_basis[r] = art_cursor;
                art_cursor += 1;
            }
            RowKind::Art => {
                cols[art_cursor].push((r, 1.0));
                unit_col[r] = art_cursor;
                init_basis[r] = art_cursor;
                art_cursor += 1;
            }
        }
    }

    let mut col_ptr = Vec::with_capacity(n + 1);
    let mut row_ix = Vec::new();
    let mut val = Vec::new();
    col_ptr.push(0);
    for c in &cols {
        for &(r, v) in c {
            row_ix.push(r);
            val.push(v);
        }
        col_ptr.push(row_ix.len());
    }

    let mut cost2 = vec![0.0; n];
    for v in 0..n_orig {
        cost2[v] = lp.objective[v] * col_scale[v];
    }

    Std {
        m,
        n,
        n_orig,
        art_base,
        n_art,
        csc: Csc { col_ptr, row_ix, val },
        b,
        cost2,
        unit_col,
        init_basis,
    }
}

enum Phase {
    Optimal,
    /// Iteration cap hit: the incumbent basis is usable but optimality
    /// was not proven — phase 2 accepts it (callers cross-check the
    /// solution), phase 1 must NOT conclude infeasibility from it.
    IterCap,
    Unbounded,
    Fail,
}

struct Rev<'a> {
    st: &'a Std,
    basis: Vec<usize>,
    in_basis: Vec<bool>,
    etas: Vec<Eta>,
    /// Value of the basic variable sitting at each row position.
    xb: Vec<f64>,
    /// Columns neutralized as numerical noise within a bounded phase.
    banned: Vec<bool>,
    price_cursor: usize,
}

impl<'a> Rev<'a> {
    fn new(st: &'a Std) -> Rev<'a> {
        let mut r = Rev {
            st,
            basis: Vec::new(),
            in_basis: vec![false; st.n],
            etas: Vec::new(),
            xb: Vec::new(),
            banned: vec![false; st.n],
            price_cursor: 0,
        };
        r.reset_cold();
        r
    }

    fn reset_cold(&mut self) {
        self.basis = self.st.init_basis.clone();
        self.in_basis.iter_mut().for_each(|f| *f = false);
        for &c in &self.basis {
            self.in_basis[c] = true;
        }
        self.etas.clear();
        self.xb = self.st.b.clone();
        self.banned.iter_mut().for_each(|f| *f = false);
        self.price_cursor = 0;
    }

    /// Apply `B⁻¹` in place.
    fn ftran(&self, v: &mut [f64]) {
        for e in &self.etas {
            let t = v[e.r];
            if t == 0.0 {
                continue;
            }
            let t = t / e.pivot;
            v[e.r] = t;
            for &(i, a) in &e.others {
                v[i] -= a * t;
            }
        }
    }

    /// Apply `(B⁻¹)ᵀ` in place.
    fn btran(&self, v: &mut [f64]) {
        for e in self.etas.iter().rev() {
            let mut t = v[e.r];
            for &(i, a) in &e.others {
                t -= a * v[i];
            }
            v[e.r] = t / e.pivot;
        }
    }

    /// Rebuild the eta file from the current basis columns (fresh PFI).
    /// Unit-ish columns are eliminated first (no fill), the rest by
    /// ascending sparsity — a poor man's Markowitz that keeps the fill
    /// small for the near-triangular bases these LPs produce. Dependent
    /// columns are replaced by the row's logical unit column; an
    /// unrepairable basis reports failure so the caller can fall back.
    fn refactor(&mut self) -> Result<(), ()> {
        let m = self.st.m;
        self.etas.clear();
        let cols = std::mem::take(&mut self.basis);
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by_key(|&p| self.st.csc.nnz_col(cols[p]));

        let mut row_taken = vec![false; m];
        let mut col_used = vec![false; self.st.n];
        let mut new_basis = vec![usize::MAX; m];
        let mut buf = vec![0.0; m];
        let mut pivot_in = |slf: &mut Rev<'a>,
                            c: usize,
                            want_row: Option<usize>,
                            row_taken: &mut [bool],
                            new_basis: &mut [usize],
                            buf: &mut Vec<f64>|
         -> bool {
            buf.iter_mut().for_each(|v| *v = 0.0);
            slf.st.csc.scatter(c, buf);
            slf.ftran(buf);
            let r = match want_row {
                Some(r) if buf[r].abs() > 1e-10 => r,
                Some(_) => return false,
                None => {
                    let mut best_r = usize::MAX;
                    let mut best_a = 1e-10;
                    for (r, &v) in buf.iter().enumerate() {
                        if !row_taken[r] && v.abs() > best_a {
                            best_a = v.abs();
                            best_r = r;
                        }
                    }
                    if best_r == usize::MAX {
                        return false;
                    }
                    best_r
                }
            };
            let mut others = Vec::new();
            for (i, &v) in buf.iter().enumerate() {
                if i != r && v.abs() > 1e-12 {
                    others.push((i, v));
                }
            }
            slf.etas.push(Eta { r, pivot: buf[r], others });
            row_taken[r] = true;
            new_basis[r] = c;
            true
        };

        for &p in &order {
            let c = cols[p];
            if col_used[c] {
                continue; // duplicate column in a (bogus) warm basis
            }
            if pivot_in(self, c, None, &mut row_taken, &mut new_basis, &mut buf) {
                col_used[c] = true;
            }
            // Dependent column: dropped; its row gets repaired below.
        }
        for r in 0..m {
            if !row_taken[r] {
                let c = self.st.unit_col[r];
                if col_used[c]
                    || !pivot_in(self, c, Some(r), &mut row_taken, &mut new_basis, &mut buf)
                {
                    self.basis = new_basis; // leave consistent-ish state
                    return Err(());
                }
                col_used[c] = true;
            }
        }

        self.in_basis.iter_mut().for_each(|f| *f = false);
        for &c in &new_basis {
            self.in_basis[c] = true;
        }
        self.basis = new_basis;
        let mut v = self.st.b.clone();
        self.ftran(&mut v);
        for x in v.iter_mut() {
            if *x < 0.0 && *x > -1e-9 {
                *x = 0.0;
            }
        }
        self.xb = v;
        Ok(())
    }

    /// Install a warm basis. Returns false (leaving the solver cold) if
    /// the basis has the wrong shape, is singular, or is primal
    /// infeasible for this instance.
    fn try_warm(&mut self, warm: &[usize]) -> bool {
        let m = self.st.m;
        if warm.len() != m || warm.iter().any(|&c| c >= self.st.n) {
            return false;
        }
        self.basis = warm.to_vec();
        if self.refactor().is_err() {
            self.reset_cold();
            return false;
        }
        let mut feasible = true;
        for (r, &x) in self.xb.iter().enumerate() {
            if x < -1e-6 {
                feasible = false;
                break;
            }
            // A warm basis must not resurrect artificial infeasibility.
            if self.basis[r] >= self.st.art_base && x > 1e-7 {
                feasible = false;
                break;
            }
        }
        if !feasible {
            self.reset_cold();
            return false;
        }
        for x in self.xb.iter_mut() {
            if *x < 0.0 {
                *x = 0.0;
            }
        }
        true
    }

    fn objective(&self, cost: &[f64]) -> f64 {
        self.basis
            .iter()
            .zip(&self.xb)
            .map(|(&c, &x)| cost[c] * x)
            .sum()
    }

    /// Entering column, or None when no eligible column prices out
    /// negative after a full sweep (optimality).
    fn price(&mut self, cost: &[f64], allowed: usize, y: &[f64], bland: bool) -> Option<usize> {
        if allowed == 0 {
            return None;
        }
        let mut best = -EPS_RC;
        let mut best_j = None;
        let start = if bland { 0 } else { self.price_cursor % allowed };
        for off in 0..allowed {
            let j = (start + off) % allowed;
            if self.in_basis[j] || self.banned[j] {
                continue;
            }
            let d = cost[j] - self.st.csc.dot_col(j, y);
            if d < best {
                best = d;
                best_j = Some(j);
                if bland {
                    break;
                }
            }
            if !bland && best_j.is_some() && off >= PARTIAL_SPAN {
                break;
            }
        }
        if let Some(j) = best_j {
            self.price_cursor = (j + 1) % allowed;
        }
        best_j
    }

    /// Leaving row for the transformed entering column, or None
    /// (unbounded direction).
    fn choose_leaving(&self, abar: &[f64], phase2: bool) -> Option<usize> {
        let m = self.st.m;
        // Zero-valued basic artificials are kicked out eagerly: pivoting
        // there is degenerate (entering value 0, feasibility untouched)
        // and stops the artificial from creeping positive during phase 2.
        if phase2 {
            for r in 0..m {
                if self.basis[r] >= self.st.art_base
                    && self.xb[r] <= EPS
                    && abar[r].abs() > EPS_PIVOT
                {
                    return Some(r);
                }
            }
        }
        for &min_pivot in &[EPS_PIVOT, EPS] {
            let mut best_ratio = f64::INFINITY;
            let mut prow = usize::MAX;
            for r in 0..m {
                let coef = abar[r];
                if coef > min_pivot {
                    let ratio = self.xb[r] / coef;
                    if ratio < best_ratio - EPS
                        || (ratio < best_ratio + EPS
                            && prow != usize::MAX
                            && self.basis[r] < self.basis[prow])
                    {
                        best_ratio = ratio;
                        prow = r;
                    }
                }
            }
            if prow != usize::MAX {
                return Some(prow);
            }
        }
        None
    }

    fn pivot(&mut self, q: usize, r: usize, abar: &[f64]) {
        let pivot = abar[r];
        debug_assert!(pivot.abs() > EPS);
        let t = self.xb[r] / pivot;
        for (i, x) in self.xb.iter_mut().enumerate() {
            if i != r && abar[i] != 0.0 {
                *x -= abar[i] * t;
                if *x < 0.0 && *x > -1e-9 {
                    *x = 0.0;
                }
            }
        }
        self.xb[r] = if t.abs() < 1e-14 { 0.0 } else { t.max(0.0) };
        let mut others = Vec::new();
        for (i, &v) in abar.iter().enumerate() {
            if i != r && v.abs() > 1e-12 {
                others.push((i, v));
            }
        }
        self.in_basis[self.basis[r]] = false;
        self.in_basis[q] = true;
        self.basis[r] = q;
        self.etas.push(Eta { r, pivot, others });
    }

    /// One simplex phase over the given objective. `allowed` bars columns
    /// `≥ allowed` from entering (artificials in phase 2); `bounded`
    /// marks phases with a known objective lower bound (phase 1), where
    /// an "unbounded" column is numerical noise to be neutralized.
    fn run_phase(&mut self, cost: &[f64], allowed: usize, bounded: bool, phase2: bool) -> Phase {
        let m = self.st.m;
        self.banned.iter_mut().for_each(|f| *f = false);
        let mut last_obj = f64::INFINITY;
        let mut stalled = 0usize;
        let mut y = vec![0.0; m];
        let mut abar = vec![0.0; m];
        for _iter in 0..MAX_ITERS {
            if self.etas.len() >= REFACTOR_EVERY && self.refactor().is_err() {
                return Phase::Fail;
            }
            let cur = self.objective(cost);
            if cur < last_obj - 1e-10 * last_obj.abs().max(1.0) {
                last_obj = cur;
                stalled = 0;
            } else {
                stalled += 1;
            }
            let bland = stalled >= STALL_TO_BLAND;

            y.iter_mut().for_each(|v| *v = 0.0);
            for r in 0..m {
                y[r] = cost[self.basis[r]];
            }
            self.btran(&mut y);
            let q = match self.price(cost, allowed, &y, bland) {
                Some(q) => q,
                None => return Phase::Optimal,
            };
            abar.iter_mut().for_each(|v| *v = 0.0);
            self.st.csc.scatter(q, &mut abar);
            self.ftran(&mut abar);
            match self.choose_leaving(&abar, phase2) {
                Some(r) => self.pivot(q, r, &abar),
                None => {
                    if bounded {
                        self.banned[q] = true;
                        continue;
                    }
                    return Phase::Unbounded;
                }
            }
        }
        Phase::IterCap
    }
}

/// Solve, optionally warm-starting from a previous basis (standard-form
/// column indices, as returned by this function for a *structurally
/// identical* LP). Returns `None` on numerical failure — the caller
/// decides the fallback — plus the final basis for reuse.
pub fn solve_warm(lp: &Lp, warm: Option<&[usize]>) -> (Option<LpOutcome>, Option<Vec<usize>>) {
    let (row_scale, col_scale) = equilibrate(lp);
    let st = standardize(lp, &row_scale, &col_scale);
    let mut solver = Rev::new(&st);

    let mut warmed = match warm {
        Some(w) => solver.try_warm(w),
        None => false,
    };

    // One cold retry on numerical failure: mid-run refactorization
    // failures stem from a degenerate accumulated basis (or a poisoned
    // warm basis), which a fresh start clears; `None` is only reported
    // when even the cold run fails.
    for attempt in 0..2 {
        if attempt > 0 {
            solver.reset_cold();
            warmed = false;
        }
        if !warmed && st.n_art > 0 {
            let mut c1 = vec![0.0; st.n];
            for j in st.art_base..st.n {
                c1[j] = 1.0;
            }
            let p1 = solver.run_phase(&c1, st.n, true, false);
            // Unbounded cannot happen in the bounded phase.
            if matches!(p1, Phase::Fail | Phase::Unbounded) {
                if attempt == 0 {
                    continue;
                }
                return (None, None);
            }
            let phase1 = solver.objective(&c1);
            if phase1 > 1e-5 {
                // Only a *converged* phase 1 proves infeasibility; at the
                // iteration cap the residual artificials just mean we ran
                // out of pivots.
                if matches!(p1, Phase::IterCap) {
                    if attempt == 0 {
                        continue;
                    }
                    return (None, None);
                }
                return (Some(LpOutcome::Infeasible), None);
            }
        }

        match solver.run_phase(&st.cost2, st.art_base, false, true) {
            // Iteration cap: accept the incumbent; callers cross-check
            // the solution against the exact constraints and fall back.
            Phase::Optimal | Phase::IterCap => {}
            Phase::Unbounded => return (Some(LpOutcome::Unbounded), None),
            Phase::Fail => {
                if attempt == 0 {
                    continue;
                }
                return (None, None);
            }
        }

        let mut x = vec![0.0; st.n_orig];
        for r in 0..st.m {
            let c = solver.basis[r];
            if c < st.n_orig {
                x[c] = solver.xb[r].max(0.0);
            }
        }
        for (v, s) in x.iter_mut().zip(&col_scale) {
            *v *= s;
        }
        let objective = lp.objective_at(&x);
        let basis = solver.basis.clone();
        return (Some(LpOutcome::Optimal { x, objective }), Some(basis));
    }
    (None, None)
}

/// Solve a minimization LP. Falls back to the dense tableau on numerical
/// failure so this entry point always produces an answer.
pub fn solve(lp: &Lp) -> LpOutcome {
    match solve_warm(lp, None) {
        (Some(out), _) => out,
        (None, _) => super::simplex::solve(lp),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::lp::{Cmp, Lp};
    use crate::util::qcheck::{ensure, qcheck, Config};
    use crate::util::rng::Pcg64;

    fn assert_opt(outcome: LpOutcome, want_obj: f64, tol: f64) -> Vec<f64> {
        let (x, obj) = outcome.expect_optimal("revised test");
        assert!(
            (obj - want_obj).abs() <= tol,
            "objective {obj}, expected {want_obj}"
        );
        x
    }

    #[test]
    fn basic_le_lp() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.minimize(x, -1.0);
        lp.minimize(y, -1.0);
        lp.constraint(&[(x, 1.0), (y, 2.0)], Cmp::Le, 4.0);
        lp.constraint(&[(x, 3.0), (y, 1.0)], Cmp::Le, 6.0);
        let sol = assert_opt(solve(&lp), -(8.0 / 5.0 + 6.0 / 5.0), 1e-8);
        assert!((sol[0] - 8.0 / 5.0).abs() < 1e-8);
        assert!((sol[1] - 6.0 / 5.0).abs() < 1e-8);
    }

    #[test]
    fn ge_and_eq_need_phase1() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.minimize(x, 2.0);
        lp.minimize(y, 3.0);
        lp.constraint(&[(x, 1.0), (y, 1.0)], Cmp::Eq, 10.0);
        lp.constraint(&[(x, 1.0)], Cmp::Ge, 3.0);
        let sol = assert_opt(solve(&lp), 20.0, 1e-8);
        assert!((sol[0] - 10.0).abs() < 1e-8);
        assert!(sol[1].abs() < 1e-8);
    }

    #[test]
    fn infeasible_detected() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        lp.constraint(&[(x, 1.0)], Cmp::Le, 1.0);
        lp.constraint(&[(x, 1.0)], Cmp::Ge, 2.0);
        assert_eq!(solve(&lp), LpOutcome::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        lp.minimize(x, -1.0);
        lp.constraint(&[(x, 1.0)], Cmp::Ge, 1.0);
        assert_eq!(solve(&lp), LpOutcome::Unbounded);
    }

    #[test]
    fn min_max_epigraph_pattern() {
        let mut lp = Lp::new();
        let z = lp.var("z");
        lp.minimize(z, 1.0);
        for &t in &[3.0, 7.0, 5.0] {
            lp.constraint(&[(z, 1.0)], Cmp::Ge, t);
        }
        assert_opt(solve(&lp), 7.0, 1e-9);
    }

    #[test]
    fn transportation_problem() {
        let mut lp = Lp::new();
        let f: Vec<Vec<usize>> = (0..2)
            .map(|i| (0..2).map(|j| lp.var(format!("f{i}{j}"))).collect())
            .collect();
        let costs = [[1.0, 2.0], [3.0, 1.0]];
        for i in 0..2 {
            for j in 0..2 {
                lp.minimize(f[i][j], costs[i][j]);
            }
        }
        lp.constraint(&[(f[0][0], 1.0), (f[0][1], 1.0)], Cmp::Eq, 10.0);
        lp.constraint(&[(f[1][0], 1.0), (f[1][1], 1.0)], Cmp::Eq, 20.0);
        lp.constraint(&[(f[0][0], 1.0), (f[1][0], 1.0)], Cmp::Eq, 15.0);
        lp.constraint(&[(f[0][1], 1.0), (f[1][1], 1.0)], Cmp::Eq, 15.0);
        assert_opt(solve(&lp), 40.0, 1e-7);
    }

    #[test]
    fn warm_start_round_trip() {
        // Solve, re-solve from the returned basis: same optimum, and the
        // warm solve must succeed without falling back.
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.minimize(x, 1.0);
        lp.minimize(y, 2.0);
        lp.constraint(&[(x, 1.0), (y, 1.0)], Cmp::Ge, 4.0);
        lp.constraint(&[(x, 1.0)], Cmp::Le, 3.0);
        let (first, basis) = solve_warm(&lp, None);
        let (_, obj1) = first.expect("cold solve").expect_optimal("cold");
        let basis = basis.expect("basis returned");
        let (second, _) = solve_warm(&lp, Some(&basis));
        let (_, obj2) = second.expect("warm solve").expect_optimal("warm");
        assert!((obj1 - obj2).abs() < 1e-9, "{obj1} vs {obj2}");

        // A nonsense warm basis must not break correctness either.
        let bogus = vec![0usize; basis.len()];
        let (third, _) = solve_warm(&lp, Some(&bogus));
        let (_, obj3) = third.expect("bogus-warm solve").expect_optimal("bogus");
        assert!((obj1 - obj3).abs() < 1e-9);
    }

    /// Property: revised and dense tableau agree on random feasible LPs.
    #[test]
    fn qcheck_matches_dense_simplex() {
        qcheck(Config::default().cases(60), "revised vs dense", |rng: &mut Pcg64| {
            let nv = rng.range(2, 7);
            let nc = rng.range(1, 9);
            let mut lp = Lp::new();
            let vars: Vec<usize> = (0..nv).map(|i| lp.var(format!("v{i}"))).collect();
            let x0: Vec<f64> = (0..nv).map(|_| rng.uniform(0.0, 5.0)).collect();
            for v in &vars {
                lp.minimize(*v, rng.uniform(-1.0, 2.0));
            }
            for _ in 0..nc {
                let terms: Vec<(usize, f64)> =
                    vars.iter().map(|&v| (v, rng.uniform(-1.0, 1.0))).collect();
                let lhs: f64 = terms.iter().map(|&(v, c)| c * x0[v]).sum();
                if rng.chance(0.3) {
                    lp.constraint(&terms, Cmp::Ge, lhs - rng.uniform(0.0, 2.0));
                } else {
                    lp.constraint(&terms, Cmp::Le, lhs + rng.uniform(0.0, 2.0));
                }
            }
            for v in &vars {
                lp.upper_bound(*v, 10.0);
            }
            let dense = crate::solver::simplex::solve(&lp);
            let sparse = solve(&lp);
            match (dense, sparse) {
                (
                    LpOutcome::Optimal { objective: od, .. },
                    LpOutcome::Optimal { x, objective: os },
                ) => {
                    ensure(
                        lp.violation(&x) < 1e-6,
                        format!("violation {}", lp.violation(&x)),
                    )?;
                    ensure(
                        (od - os).abs() <= 1e-7 * od.abs().max(1.0),
                        format!("dense {od} vs revised {os}"),
                    )
                }
                (d, s) => ensure(
                    std::mem::discriminant(&d) == std::mem::discriminant(&s),
                    format!("outcome mismatch: dense {d:?} vs revised {s:?}"),
                ),
            }
        });
    }
}
