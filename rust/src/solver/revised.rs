//! Sparse bounded-variable revised simplex with a product-form inverse.
//!
//! The dense tableau ([`super::simplex`]) carries an explicit `(m+1)×(n+1)`
//! matrix, which is perfect for the paper's ≲300-row plan LPs but blows up
//! quadratically on the 256-node generated topologies (the `hier-wan:256`
//! x-LP has thousands of rows). This module is the large-problem path:
//!
//! * the constraint matrix lives in **CSC** (compressed sparse column)
//!   form and is never densified;
//! * the basis inverse is a **product of eta matrices** (Bartels–Golub
//!   style elementary column transforms), rebuilt from the basis columns
//!   every [`REFACTOR_EVERY`] pivots to bound fill-in and drift;
//! * simple bounds `l ≤ x ≤ u` ([`Lp::bound_below`]/[`Lp::bound_above`])
//!   are handled **implicitly**: lower bounds are shifted out of the
//!   right-hand side and upper bounds live in the ratio test, so a bound
//!   costs zero constraint rows. A nonbasic variable sits at either of
//!   its bounds, and a "bound flip" step moves it across without a basis
//!   change (no eta, no refactorization pressure);
//! * pricing is **devex** (Forrest–Goldfarb reference weights, a cheap
//!   steepest-edge approximation) with cyclic partial sweeps on wide
//!   problems and a Bland fallback on degenerate plateaus; classic
//!   Dantzig pricing is kept behind [`Pricing::Dantzig`] for A/B
//!   benchmarking;
//! * a solved basis can be returned and fed back in (**warm start**) —
//!   the alternating optimizer reuses the previous round's basis, which
//!   turns most re-solves into a handful of pivots.
//!
//! Standard-form conversion, scaling, and tolerances deliberately mirror
//! the dense solver so the two are interchangeable behind [`Lp`]; the
//! dense tableau remains the small-problem path and the cross-check
//! oracle (see `tests/optimizer_scale.rs` and `tests/solver_bounded.rs`).

use std::sync::atomic::Ordering::Relaxed;

use super::lp::{Cmp, Lp, LpOutcome};
use super::simplex::equilibrate;
use super::{SOLVER_ITERATIONS, SOLVER_REFACTORIZATIONS};

const EPS: f64 = 1e-9;
/// Reduced-cost tolerance for the entering test (matches the dense path).
const EPS_RC: f64 = 1e-6;
/// Minimum acceptable pivot magnitude in the ratio test.
const EPS_PIVOT: f64 = 1e-7;
/// Pivots without objective progress before switching to Bland's rule.
const STALL_TO_BLAND: usize = 500;
const MAX_ITERS: usize = 100_000;
/// Eta-file length that triggers a refactorization.
const REFACTOR_EVERY: usize = 64;
/// Partial pricing: once this many columns have been scanned and at least
/// one candidate found, take the best so far instead of finishing the
/// sweep. Optimality is only ever declared after a *full* sweep.
const PARTIAL_SPAN: usize = 4096;
/// Devex weight ceiling: past this the reference framework has drifted
/// far from the current basis and the weights are reset to 1.
const DEVEX_RESET: f64 = 1e10;

/// Entering-column selection rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pricing {
    /// Most-negative reduced cost (textbook rule; cheap per sweep but
    /// step counts degrade on long thin polytopes).
    Dantzig,
    /// Devex reference weights: approximate steepest edge at Dantzig
    /// cost. The default.
    Devex,
}

/// Compressed sparse column matrix (column-major, row indices ascending).
struct Csc {
    col_ptr: Vec<usize>,
    row_ix: Vec<usize>,
    val: Vec<f64>,
}

impl Csc {
    fn col(&self, j: usize) -> (&[usize], &[f64]) {
        let a = self.col_ptr[j];
        let b = self.col_ptr[j + 1];
        (&self.row_ix[a..b], &self.val[a..b])
    }

    fn nnz_col(&self, j: usize) -> usize {
        self.col_ptr[j + 1] - self.col_ptr[j]
    }

    /// Scatter column `j` into the dense buffer (caller pre-zeroes).
    fn scatter(&self, j: usize, out: &mut [f64]) {
        let (rows, vals) = self.col(j);
        for (&r, &v) in rows.iter().zip(vals) {
            out[r] = v;
        }
    }

    /// `yᵀ·a_j` for a dense row vector `y`.
    fn dot_col(&self, j: usize, y: &[f64]) -> f64 {
        let (rows, vals) = self.col(j);
        let mut acc = 0.0;
        for (&r, &v) in rows.iter().zip(vals) {
            acc += y[r] * v;
        }
        acc
    }
}

/// One elementary transform: pivot on row `r` with transformed column
/// values `pivot` (at `r`) and `others` (elsewhere).
struct Eta {
    r: usize,
    pivot: f64,
    others: Vec<(usize, f64)>,
}

/// Equilibrated standard form `A z = b, 0 ≤ z ≤ u` (z is the scaled,
/// lower-shifted variable) with explicit slack/surplus and artificial
/// columns (layout mirrors the dense path).
struct Std {
    m: usize,
    n: usize,
    n_orig: usize,
    /// Columns `≥ art_base` are artificial.
    art_base: usize,
    n_art: usize,
    csc: Csc,
    b: Vec<f64>,
    /// Phase-2 objective over all n columns (scaled; slack/art zero).
    cost2: Vec<f64>,
    /// Scaled upper bound per column (`(u−l)/col_scale` for structural
    /// columns with a finite bound, `+∞` otherwise — slacks and
    /// artificials are never bounded above).
    upper: Vec<f64>,
    /// Per row, its slack-or-artificial unit column (basis repair).
    unit_col: Vec<usize>,
    /// Initial (cold) basis: one unit column per row.
    init_basis: Vec<usize>,
}

fn standardize(lp: &Lp, row_scale: &[f64], col_scale: &[f64]) -> Std {
    let m = lp.n_rows();
    let n_orig = lp.n_vars;

    // Shift lower bounds out of the right-hand side: the standard-form
    // variable is z = (x − l)/col_scale, so each row's rhs drops by
    // Σ A_ij·l_j. With all-zero lower bounds this is the identity.
    let rhs_eff: Vec<f64> = lp
        .rows
        .iter()
        .map(|row| {
            let shift: f64 = row.terms.iter().map(|&(v, c)| c * lp.lower[v]).sum();
            row.rhs - shift
        })
        .collect();

    #[derive(Clone, Copy, PartialEq)]
    enum RowKind {
        Slack,
        SurplusArt,
        Art,
    }
    let mut kinds = Vec::with_capacity(m);
    let mut signs = Vec::with_capacity(m);
    let mut n_slack = 0usize;
    let mut n_art = 0usize;
    for (r, row) in lp.rows.iter().enumerate() {
        let rhs_scaled = rhs_eff[r] / row_scale[r];
        let (kind, sign) = match row.cmp {
            Cmp::Le => {
                if rhs_scaled >= 0.0 {
                    (RowKind::Slack, 1.0)
                } else {
                    (RowKind::SurplusArt, -1.0)
                }
            }
            Cmp::Ge => {
                if rhs_scaled <= 0.0 {
                    (RowKind::Slack, -1.0)
                } else {
                    (RowKind::SurplusArt, 1.0)
                }
            }
            Cmp::Eq => (RowKind::Art, if rhs_scaled < 0.0 { -1.0 } else { 1.0 }),
        };
        match kind {
            RowKind::Slack => n_slack += 1,
            RowKind::SurplusArt => {
                n_slack += 1;
                n_art += 1;
            }
            RowKind::Art => n_art += 1,
        }
        kinds.push(kind);
        signs.push(sign);
    }

    let art_base = n_orig + n_slack;
    let n = art_base + n_art;

    // Column-major assembly. Structural entries land in row order because
    // rows are scanned in order and each row contributes at most one
    // entry per column (Lp::constraint merges duplicates).
    let mut cols: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n);
    for _ in 0..n {
        cols.push(Vec::new());
    }
    let mut b = vec![0.0; m];
    let mut unit_col = vec![usize::MAX; m];
    let mut init_basis = vec![usize::MAX; m];
    let mut slack_cursor = n_orig;
    let mut art_cursor = art_base;
    for (r, row) in lp.rows.iter().enumerate() {
        let sr = signs[r] / row_scale[r];
        for &(v, c) in &row.terms {
            cols[v].push((r, c * col_scale[v] * sr));
        }
        b[r] = signs[r] * rhs_eff[r] / row_scale[r];
        match kinds[r] {
            RowKind::Slack => {
                cols[slack_cursor].push((r, 1.0));
                unit_col[r] = slack_cursor;
                init_basis[r] = slack_cursor;
                slack_cursor += 1;
            }
            RowKind::SurplusArt => {
                cols[slack_cursor].push((r, -1.0));
                slack_cursor += 1;
                cols[art_cursor].push((r, 1.0));
                unit_col[r] = art_cursor;
                init_basis[r] = art_cursor;
                art_cursor += 1;
            }
            RowKind::Art => {
                cols[art_cursor].push((r, 1.0));
                unit_col[r] = art_cursor;
                init_basis[r] = art_cursor;
                art_cursor += 1;
            }
        }
    }

    let mut col_ptr = Vec::with_capacity(n + 1);
    let mut row_ix = Vec::new();
    let mut val = Vec::new();
    col_ptr.push(0);
    for c in &cols {
        for &(r, v) in c {
            row_ix.push(r);
            val.push(v);
        }
        col_ptr.push(row_ix.len());
    }

    let mut cost2 = vec![0.0; n];
    for v in 0..n_orig {
        cost2[v] = lp.objective[v] * col_scale[v];
    }

    let mut upper = vec![f64::INFINITY; n];
    for v in 0..n_orig {
        if lp.upper[v].is_finite() {
            upper[v] = (lp.upper[v] - lp.lower[v]) / col_scale[v];
        }
    }

    Std {
        m,
        n,
        n_orig,
        art_base,
        n_art,
        csc: Csc { col_ptr, row_ix, val },
        b,
        cost2,
        upper,
        unit_col,
        init_basis,
    }
}

enum Phase {
    Optimal,
    /// Iteration cap hit: the incumbent basis is usable but optimality
    /// was not proven — phase 2 accepts it (callers cross-check the
    /// solution), phase 1 must NOT conclude infeasibility from it.
    IterCap,
    Unbounded,
    Fail,
}

/// Outcome of the bounded ratio test for one entering column.
enum Step {
    /// Basis change: the variable at row `r` leaves (to its lower bound,
    /// or to its upper when `to_upper`) after the entering variable
    /// moves by `t` along its improving direction.
    Pivot { r: usize, t: f64, to_upper: bool },
    /// The entering variable hits its *own* opposite bound first: flip
    /// it across — no eta, no basis change.
    Flip,
}

struct Rev<'a> {
    st: &'a Std,
    pricing: Pricing,
    basis: Vec<usize>,
    in_basis: Vec<bool>,
    /// Nonbasic-at-upper flags (false = at lower bound; only meaningful
    /// for nonbasic columns, kept false while basic).
    at_upper: Vec<bool>,
    /// Devex reference weights, reset to 1 per phase and on blowup.
    weights: Vec<f64>,
    etas: Vec<Eta>,
    /// Value of the basic variable sitting at each row position.
    xb: Vec<f64>,
    /// Columns neutralized as numerical noise within a bounded phase.
    banned: Vec<bool>,
    price_cursor: usize,
}

impl<'a> Rev<'a> {
    fn new(st: &'a Std, pricing: Pricing) -> Rev<'a> {
        let mut r = Rev {
            st,
            pricing,
            basis: Vec::new(),
            in_basis: vec![false; st.n],
            at_upper: vec![false; st.n],
            weights: vec![1.0; st.n],
            etas: Vec::new(),
            xb: Vec::new(),
            banned: vec![false; st.n],
            price_cursor: 0,
        };
        r.reset_cold();
        r
    }

    fn reset_cold(&mut self) {
        self.basis = self.st.init_basis.clone();
        self.in_basis.iter_mut().for_each(|f| *f = false);
        for &c in &self.basis {
            self.in_basis[c] = true;
        }
        self.at_upper.iter_mut().for_each(|f| *f = false);
        self.weights.iter_mut().for_each(|w| *w = 1.0);
        self.etas.clear();
        self.xb = self.st.b.clone();
        self.banned.iter_mut().for_each(|f| *f = false);
        self.price_cursor = 0;
    }

    /// Apply `B⁻¹` in place.
    fn ftran(&self, v: &mut [f64]) {
        for e in &self.etas {
            let t = v[e.r];
            if t == 0.0 {
                continue;
            }
            let t = t / e.pivot;
            v[e.r] = t;
            for &(i, a) in &e.others {
                v[i] -= a * t;
            }
        }
    }

    /// Apply `(B⁻¹)ᵀ` in place.
    fn btran(&self, v: &mut [f64]) {
        for e in self.etas.iter().rev() {
            let mut t = v[e.r];
            for &(i, a) in &e.others {
                t -= a * v[i];
            }
            v[e.r] = t / e.pivot;
        }
    }

    /// The effective right-hand side seen by the basis: `b` minus the
    /// columns parked at their upper bounds.
    fn effective_b(&self) -> Vec<f64> {
        let mut v = self.st.b.clone();
        for j in 0..self.st.n {
            if self.at_upper[j] && !self.in_basis[j] {
                let (rows, vals) = self.st.csc.col(j);
                for (&r, &a) in rows.iter().zip(vals) {
                    v[r] -= a * self.st.upper[j];
                }
            }
        }
        v
    }

    /// Rebuild the eta file from the current basis columns (fresh PFI).
    /// Unit-ish columns are eliminated first (no fill), the rest by
    /// ascending sparsity — a poor man's Markowitz that keeps the fill
    /// small for the near-triangular bases these LPs produce. Dependent
    /// columns are replaced by the row's logical unit column; an
    /// unrepairable basis reports failure so the caller can fall back.
    fn refactor(&mut self) -> Result<(), ()> {
        SOLVER_REFACTORIZATIONS.fetch_add(1, Relaxed);
        let m = self.st.m;
        self.etas.clear();
        let cols = std::mem::take(&mut self.basis);
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by_key(|&p| self.st.csc.nnz_col(cols[p]));

        let mut row_taken = vec![false; m];
        let mut col_used = vec![false; self.st.n];
        let mut new_basis = vec![usize::MAX; m];
        let mut buf = vec![0.0; m];
        let mut pivot_in = |slf: &mut Rev<'a>,
                            c: usize,
                            want_row: Option<usize>,
                            row_taken: &mut [bool],
                            new_basis: &mut [usize],
                            buf: &mut Vec<f64>|
         -> bool {
            buf.iter_mut().for_each(|v| *v = 0.0);
            slf.st.csc.scatter(c, buf);
            slf.ftran(buf);
            let r = match want_row {
                Some(r) if buf[r].abs() > 1e-10 => r,
                Some(_) => return false,
                None => {
                    let mut best_r = usize::MAX;
                    let mut best_a = 1e-10;
                    for (r, &v) in buf.iter().enumerate() {
                        if !row_taken[r] && v.abs() > best_a {
                            best_a = v.abs();
                            best_r = r;
                        }
                    }
                    if best_r == usize::MAX {
                        return false;
                    }
                    best_r
                }
            };
            let mut others = Vec::new();
            for (i, &v) in buf.iter().enumerate() {
                if i != r && v.abs() > 1e-12 {
                    others.push((i, v));
                }
            }
            slf.etas.push(Eta { r, pivot: buf[r], others });
            row_taken[r] = true;
            new_basis[r] = c;
            true
        };

        for &p in &order {
            let c = cols[p];
            if col_used[c] {
                continue; // duplicate column in a (bogus) warm basis
            }
            if pivot_in(self, c, None, &mut row_taken, &mut new_basis, &mut buf) {
                col_used[c] = true;
            }
            // Dependent column: dropped; its row gets repaired below.
        }
        for r in 0..m {
            if !row_taken[r] {
                let c = self.st.unit_col[r];
                if col_used[c]
                    || !pivot_in(self, c, Some(r), &mut row_taken, &mut new_basis, &mut buf)
                {
                    self.basis = new_basis; // leave consistent-ish state
                    return Err(());
                }
                col_used[c] = true;
            }
        }

        self.in_basis.iter_mut().for_each(|f| *f = false);
        for &c in &new_basis {
            self.in_basis[c] = true;
        }
        self.basis = new_basis;
        // A column that re-entered the basis must not keep a stale
        // at-upper flag (possible after warm-basis repair).
        for &c in &self.basis {
            self.at_upper[c] = false;
        }
        let mut v = self.effective_b();
        self.ftran(&mut v);
        for x in v.iter_mut() {
            if *x < 0.0 && *x > -1e-9 {
                *x = 0.0;
            }
        }
        self.xb = v;
        Ok(())
    }

    /// Install a warm basis. Returns false (leaving the solver cold) if
    /// the basis has the wrong shape, is singular, or is primal
    /// infeasible for this instance. Bound status is not part of the
    /// warm handshake: every nonbasic column starts at its lower bound.
    fn try_warm(&mut self, warm: &[usize]) -> bool {
        let m = self.st.m;
        if warm.len() != m || warm.iter().any(|&c| c >= self.st.n) {
            return false;
        }
        self.basis = warm.to_vec();
        self.at_upper.iter_mut().for_each(|f| *f = false);
        if self.refactor().is_err() {
            self.reset_cold();
            return false;
        }
        let mut feasible = true;
        for (r, &x) in self.xb.iter().enumerate() {
            if x < -1e-6 || x > self.st.upper[self.basis[r]] + 1e-6 {
                feasible = false;
                break;
            }
            // A warm basis must not resurrect artificial infeasibility.
            if self.basis[r] >= self.st.art_base && x > 1e-7 {
                feasible = false;
                break;
            }
        }
        if !feasible {
            self.reset_cold();
            return false;
        }
        for x in self.xb.iter_mut() {
            if *x < 0.0 {
                *x = 0.0;
            }
        }
        true
    }

    /// Objective of the current (basic + at-upper nonbasic) point.
    fn objective(&self, cost: &[f64]) -> f64 {
        let mut obj: f64 = self
            .basis
            .iter()
            .zip(&self.xb)
            .map(|(&c, &x)| cost[c] * x)
            .sum();
        for j in 0..self.st.n {
            if self.at_upper[j] && !self.in_basis[j] && cost[j] != 0.0 {
                obj += cost[j] * self.st.upper[j];
            }
        }
        obj
    }

    /// Entering column and its improving direction (+1 = increase from
    /// the lower bound, −1 = decrease from the upper bound), or None
    /// when no eligible column prices out after a full sweep
    /// (optimality).
    fn price(
        &mut self,
        cost: &[f64],
        allowed: usize,
        y: &[f64],
        bland: bool,
    ) -> Option<(usize, f64)> {
        if allowed == 0 {
            return None;
        }
        let mut best_score = 0.0f64;
        let mut best: Option<(usize, f64)> = None;
        let start = if bland { 0 } else { self.price_cursor % allowed };
        for off in 0..allowed {
            let j = (start + off) % allowed;
            if self.in_basis[j] || self.banned[j] {
                continue;
            }
            let d = cost[j] - self.st.csc.dot_col(j, y);
            let dir = if self.at_upper[j] {
                if d > EPS_RC {
                    -1.0
                } else {
                    continue;
                }
            } else if d < -EPS_RC {
                1.0
            } else {
                continue;
            };
            if bland {
                self.price_cursor = (j + 1) % allowed;
                return Some((j, dir));
            }
            let score = match self.pricing {
                Pricing::Dantzig => d.abs(),
                Pricing::Devex => d * d / self.weights[j],
            };
            if score > best_score {
                best_score = score;
                best = Some((j, dir));
            }
            if best.is_some() && off >= PARTIAL_SPAN {
                break;
            }
        }
        if let Some((j, _)) = best {
            self.price_cursor = (j + 1) % allowed;
        }
        best
    }

    /// Bounded ratio test: the entering variable moves by `t ≥ 0` along
    /// `dir`; each basic variable drifts by `−dir·ābar_r·t` and is
    /// blocked at 0 *and* at its own upper bound; the entering variable
    /// itself is blocked at its opposite bound (a flip). None =
    /// unbounded direction.
    fn choose_step(&self, q: usize, dir: f64, abar: &[f64], phase2: bool) -> Option<Step> {
        let m = self.st.m;
        // Zero-valued basic artificials are kicked out eagerly: pivoting
        // there is degenerate (step length 0, feasibility untouched)
        // and stops the artificial from creeping positive during phase 2.
        if phase2 {
            for r in 0..m {
                if self.basis[r] >= self.st.art_base
                    && self.xb[r] <= EPS
                    && abar[r].abs() > EPS_PIVOT
                {
                    return Some(Step::Pivot { r, t: 0.0, to_upper: false });
                }
            }
        }
        let uq = self.st.upper[q];
        for &min_pivot in &[EPS_PIVOT, EPS] {
            let mut best_ratio = f64::INFINITY;
            let mut prow = usize::MAX;
            let mut p_upper = false;
            for r in 0..m {
                let coef = dir * abar[r];
                let (ratio, goes_upper) = if coef > min_pivot {
                    (self.xb[r] / coef, false)
                } else if coef < -min_pivot {
                    let ub = self.st.upper[self.basis[r]];
                    if !ub.is_finite() {
                        continue;
                    }
                    ((ub - self.xb[r]) / -coef, true)
                } else {
                    continue;
                };
                if ratio < best_ratio - EPS
                    || (ratio < best_ratio + EPS
                        && prow != usize::MAX
                        && self.basis[r] < self.basis[prow])
                {
                    best_ratio = ratio;
                    prow = r;
                    p_upper = goes_upper;
                }
            }
            // The entering variable's own bound wins ties: a flip costs
            // no eta and cannot be degenerate.
            if uq.is_finite() && uq <= best_ratio {
                return Some(Step::Flip);
            }
            if prow != usize::MAX {
                return Some(Step::Pivot { r: prow, t: best_ratio.max(0.0), to_upper: p_upper });
            }
        }
        if uq.is_finite() {
            return Some(Step::Flip);
        }
        None
    }

    /// Move the entering variable all the way to its opposite bound
    /// without a basis change.
    fn apply_flip(&mut self, q: usize, dir: f64, abar: &[f64]) {
        let uq = self.st.upper[q];
        for (i, x) in self.xb.iter_mut().enumerate() {
            if abar[i] != 0.0 {
                *x -= dir * abar[i] * uq;
                if *x < 0.0 && *x > -1e-9 {
                    *x = 0.0;
                }
            }
        }
        self.at_upper[q] = !self.at_upper[q];
    }

    /// Devex weight update for the pivot `(q enters at row r)`; must run
    /// *before* the basis changes (needs the outgoing `Bᵀ⁻¹`).
    fn devex_update(&mut self, q: usize, r: usize, abar: &[f64], allowed: usize) {
        let arq = abar[r];
        if arq.abs() < EPS_PIVOT {
            return;
        }
        let wq = self.weights[q];
        let wq_over = wq / (arq * arq);
        // Pivot row of the tableau: ρᵀ a_j gives each column's entry.
        let mut rho = vec![0.0; self.st.m];
        rho[r] = 1.0;
        self.btran(&mut rho);
        let mut blown = false;
        for j in 0..allowed {
            if j == q || self.in_basis[j] || self.banned[j] {
                continue;
            }
            let alpha = self.st.csc.dot_col(j, &rho);
            if alpha != 0.0 {
                let cand = (alpha * alpha) * wq_over;
                if cand > self.weights[j] {
                    self.weights[j] = cand;
                    if cand > DEVEX_RESET {
                        blown = true;
                    }
                }
            }
        }
        let leaving = self.basis[r];
        self.weights[leaving] = wq_over.max(1.0);
        if blown || self.weights[leaving] > DEVEX_RESET {
            // New reference framework.
            self.weights.iter_mut().for_each(|w| *w = 1.0);
        }
    }

    fn pivot(&mut self, q: usize, dir: f64, r: usize, t: f64, to_upper: bool, abar: &[f64]) {
        let pivot = abar[r];
        debug_assert!(pivot.abs() > EPS);
        for (i, x) in self.xb.iter_mut().enumerate() {
            if i != r && abar[i] != 0.0 {
                *x -= dir * abar[i] * t;
                if *x < 0.0 && *x > -1e-9 {
                    *x = 0.0;
                }
            }
        }
        // Entering value: moved `t` up from 0, or `t` down from its
        // upper bound.
        let enter_val = if dir > 0.0 { t } else { self.st.upper[q] - t };
        self.xb[r] = if enter_val.abs() < 1e-14 { 0.0 } else { enter_val.max(0.0) };
        let mut others = Vec::new();
        for (i, &v) in abar.iter().enumerate() {
            if i != r && v.abs() > 1e-12 {
                others.push((i, v));
            }
        }
        let leaving = self.basis[r];
        self.in_basis[leaving] = false;
        self.at_upper[leaving] = to_upper;
        self.in_basis[q] = true;
        self.at_upper[q] = false;
        self.basis[r] = q;
        self.etas.push(Eta { r, pivot, others });
    }

    /// One simplex phase over the given objective. `allowed` bars columns
    /// `≥ allowed` from entering (artificials in phase 2); `bounded`
    /// marks phases with a known objective lower bound (phase 1), where
    /// an "unbounded" column is numerical noise to be neutralized.
    fn run_phase(&mut self, cost: &[f64], allowed: usize, bounded: bool, phase2: bool) -> Phase {
        let m = self.st.m;
        self.banned.iter_mut().for_each(|f| *f = false);
        self.weights.iter_mut().for_each(|w| *w = 1.0);
        let mut last_obj = f64::INFINITY;
        let mut stalled = 0usize;
        let mut y = vec![0.0; m];
        let mut abar = vec![0.0; m];
        for _iter in 0..MAX_ITERS {
            if self.etas.len() >= REFACTOR_EVERY && self.refactor().is_err() {
                return Phase::Fail;
            }
            let cur = self.objective(cost);
            // `!is_finite` seeds the tracker on the first iteration (an
            // `inf − inf` guard: the subtraction below is NaN there and
            // every comparison with NaN is false, which would leave
            // `last_obj` stuck at +∞ and hand the whole run to Bland's
            // rule after the stall cap).
            if !last_obj.is_finite() || cur < last_obj - 1e-10 * last_obj.abs().max(1.0) {
                last_obj = cur;
                stalled = 0;
            } else {
                stalled += 1;
            }
            let bland = stalled >= STALL_TO_BLAND;

            y.iter_mut().for_each(|v| *v = 0.0);
            for r in 0..m {
                y[r] = cost[self.basis[r]];
            }
            self.btran(&mut y);
            let (q, dir) = match self.price(cost, allowed, &y, bland) {
                Some(qd) => qd,
                None => return Phase::Optimal,
            };
            abar.iter_mut().for_each(|v| *v = 0.0);
            self.st.csc.scatter(q, &mut abar);
            self.ftran(&mut abar);
            match self.choose_step(q, dir, &abar, phase2) {
                Some(Step::Flip) => {
                    SOLVER_ITERATIONS.fetch_add(1, Relaxed);
                    self.apply_flip(q, dir, &abar);
                }
                Some(Step::Pivot { r, t, to_upper }) => {
                    SOLVER_ITERATIONS.fetch_add(1, Relaxed);
                    if self.pricing == Pricing::Devex {
                        self.devex_update(q, r, &abar, allowed);
                    }
                    self.pivot(q, dir, r, t, to_upper, &abar);
                }
                None => {
                    if bounded {
                        self.banned[q] = true;
                        continue;
                    }
                    return Phase::Unbounded;
                }
            }
        }
        Phase::IterCap
    }
}

/// Solve, optionally warm-starting from a previous basis (standard-form
/// column indices, as returned by this function for a *structurally
/// identical* LP). Returns `None` on numerical failure — the caller
/// decides the fallback — plus the final basis for reuse.
pub fn solve_warm(lp: &Lp, warm: Option<&[usize]>) -> (Option<LpOutcome>, Option<Vec<usize>>) {
    solve_warm_pricing(lp, warm, Pricing::Devex)
}

/// [`solve_warm`] with an explicit pricing rule (the A/B benches compare
/// devex against classic Dantzig on the same instances).
pub fn solve_warm_pricing(
    lp: &Lp,
    warm: Option<&[usize]>,
    pricing: Pricing,
) -> (Option<LpOutcome>, Option<Vec<usize>>) {
    // Crossed implicit bounds make the box itself empty — no simplex
    // machinery needed (and the shift below would misbehave).
    for j in 0..lp.n_vars {
        if lp.lower[j] > lp.upper[j] + 1e-12 {
            return (Some(LpOutcome::Infeasible), None);
        }
    }
    let (row_scale, col_scale) = equilibrate(lp);
    let st = standardize(lp, &row_scale, &col_scale);
    let mut solver = Rev::new(&st, pricing);

    let mut warmed = match warm {
        Some(w) => solver.try_warm(w),
        None => false,
    };

    // One cold retry on numerical failure: mid-run refactorization
    // failures stem from a degenerate accumulated basis (or a poisoned
    // warm basis), which a fresh start clears; `None` is only reported
    // when even the cold run fails.
    for attempt in 0..2 {
        if attempt > 0 {
            solver.reset_cold();
            warmed = false;
        }
        if !warmed && st.n_art > 0 {
            let mut c1 = vec![0.0; st.n];
            for j in st.art_base..st.n {
                c1[j] = 1.0;
            }
            let p1 = solver.run_phase(&c1, st.n, true, false);
            // Unbounded cannot happen in the bounded phase.
            if matches!(p1, Phase::Fail | Phase::Unbounded) {
                if attempt == 0 {
                    continue;
                }
                return (None, None);
            }
            let phase1 = solver.objective(&c1);
            if phase1 > 1e-5 {
                // Only a *converged* phase 1 proves infeasibility; at the
                // iteration cap the residual artificials just mean we ran
                // out of pivots.
                if matches!(p1, Phase::IterCap) {
                    if attempt == 0 {
                        continue;
                    }
                    return (None, None);
                }
                return (Some(LpOutcome::Infeasible), None);
            }
        }

        match solver.run_phase(&st.cost2, st.art_base, false, true) {
            // Iteration cap: accept the incumbent; callers cross-check
            // the solution against the exact constraints and fall back.
            Phase::Optimal | Phase::IterCap => {}
            Phase::Unbounded => return (Some(LpOutcome::Unbounded), None),
            Phase::Fail => {
                if attempt == 0 {
                    continue;
                }
                return (None, None);
            }
        }

        let mut x = vec![0.0; st.n_orig];
        for (j, xv) in x.iter_mut().enumerate() {
            if solver.at_upper[j] && !solver.in_basis[j] {
                *xv = st.upper[j];
            }
        }
        for r in 0..st.m {
            let c = solver.basis[r];
            if c < st.n_orig {
                x[c] = solver.xb[r].max(0.0);
            }
        }
        for (v, s) in x.iter_mut().zip(&col_scale) {
            *v *= s;
        }
        // Undo the lower-bound shift.
        for (v, &l) in x.iter_mut().zip(&lp.lower) {
            *v += l;
        }
        let objective = lp.objective_at(&x);
        let basis = solver.basis.clone();
        return (Some(LpOutcome::Optimal { x, objective }), Some(basis));
    }
    (None, None)
}

/// Solve a minimization LP. Falls back to the dense tableau on numerical
/// failure so this entry point always produces an answer.
pub fn solve(lp: &Lp) -> LpOutcome {
    match solve_warm(lp, None) {
        (Some(out), _) => out,
        (None, _) => super::simplex::solve(lp),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::lp::{Cmp, Lp};
    use crate::util::qcheck::{ensure, qcheck, Config};
    use crate::util::rng::Pcg64;

    fn assert_opt(outcome: LpOutcome, want_obj: f64, tol: f64) -> Vec<f64> {
        let (x, obj) = outcome.expect_optimal("revised test");
        assert!(
            (obj - want_obj).abs() <= tol,
            "objective {obj}, expected {want_obj}"
        );
        x
    }

    #[test]
    fn basic_le_lp() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.minimize(x, -1.0);
        lp.minimize(y, -1.0);
        lp.constraint(&[(x, 1.0), (y, 2.0)], Cmp::Le, 4.0);
        lp.constraint(&[(x, 3.0), (y, 1.0)], Cmp::Le, 6.0);
        let sol = assert_opt(solve(&lp), -(8.0 / 5.0 + 6.0 / 5.0), 1e-8);
        assert!((sol[0] - 8.0 / 5.0).abs() < 1e-8);
        assert!((sol[1] - 6.0 / 5.0).abs() < 1e-8);
    }

    #[test]
    fn ge_and_eq_need_phase1() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.minimize(x, 2.0);
        lp.minimize(y, 3.0);
        lp.constraint(&[(x, 1.0), (y, 1.0)], Cmp::Eq, 10.0);
        lp.constraint(&[(x, 1.0)], Cmp::Ge, 3.0);
        let sol = assert_opt(solve(&lp), 20.0, 1e-8);
        assert!((sol[0] - 10.0).abs() < 1e-8);
        assert!(sol[1].abs() < 1e-8);
    }

    #[test]
    fn infeasible_detected() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        lp.constraint(&[(x, 1.0)], Cmp::Le, 1.0);
        lp.constraint(&[(x, 1.0)], Cmp::Ge, 2.0);
        assert_eq!(solve(&lp), LpOutcome::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        lp.minimize(x, -1.0);
        lp.constraint(&[(x, 1.0)], Cmp::Ge, 1.0);
        assert_eq!(solve(&lp), LpOutcome::Unbounded);
    }

    #[test]
    fn min_max_epigraph_pattern() {
        let mut lp = Lp::new();
        let z = lp.var("z");
        lp.minimize(z, 1.0);
        for &t in &[3.0, 7.0, 5.0] {
            lp.constraint(&[(z, 1.0)], Cmp::Ge, t);
        }
        assert_opt(solve(&lp), 7.0, 1e-9);
    }

    #[test]
    fn transportation_problem() {
        let mut lp = Lp::new();
        let f: Vec<Vec<usize>> = (0..2)
            .map(|i| (0..2).map(|j| lp.var(format!("f{i}{j}"))).collect())
            .collect();
        let costs = [[1.0, 2.0], [3.0, 1.0]];
        for i in 0..2 {
            for j in 0..2 {
                lp.minimize(f[i][j], costs[i][j]);
            }
        }
        lp.constraint(&[(f[0][0], 1.0), (f[0][1], 1.0)], Cmp::Eq, 10.0);
        lp.constraint(&[(f[1][0], 1.0), (f[1][1], 1.0)], Cmp::Eq, 20.0);
        lp.constraint(&[(f[0][0], 1.0), (f[1][0], 1.0)], Cmp::Eq, 15.0);
        lp.constraint(&[(f[0][1], 1.0), (f[1][1], 1.0)], Cmp::Eq, 15.0);
        assert_opt(solve(&lp), 40.0, 1e-7);
    }

    #[test]
    fn implicit_upper_bounds_respected() {
        // max x+y ⇔ min −x−y over x+y ≤ 4 with the box x ≤ 1.5, y ≤ 3:
        // the row binds (1.5 + 3 > 4) so the optimum is −4.
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.minimize(x, -1.0);
        lp.minimize(y, -1.0);
        lp.constraint(&[(x, 1.0), (y, 1.0)], Cmp::Le, 4.0);
        lp.bound_above(x, 1.5);
        lp.bound_above(y, 3.0);
        let sol = assert_opt(solve(&lp), -4.0, 1e-8);
        assert!(sol[0] <= 1.5 + 1e-8 && sol[1] <= 3.0 + 1e-8, "{sol:?}");
        // Tighten until the box binds instead of the row.
        let mut lp2 = lp.clone();
        lp2.bound_above(x, 1.0);
        lp2.bound_above(y, 2.0);
        let sol2 = assert_opt(solve(&lp2), -3.0, 1e-8);
        assert!((sol2[0] - 1.0).abs() < 1e-8 && (sol2[1] - 2.0).abs() < 1e-8);
    }

    #[test]
    fn implicit_lower_bounds_shift() {
        // min 2x + y, x+y ≥ 4, x ≥ 1, y ≥ 2 → x = 1, y = 3, obj 5.
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.minimize(x, 2.0);
        lp.minimize(y, 1.0);
        lp.constraint(&[(x, 1.0), (y, 1.0)], Cmp::Ge, 4.0);
        lp.bound_below(x, 1.0);
        lp.bound_below(y, 2.0);
        let sol = assert_opt(solve(&lp), 5.0, 1e-8);
        assert!((sol[0] - 1.0).abs() < 1e-8 && (sol[1] - 3.0).abs() < 1e-8);
    }

    #[test]
    fn pure_box_lp_no_rows() {
        // No constraint rows at all: the optimum is a pure bound flip.
        let mut lp = Lp::new();
        let x = lp.var("x");
        lp.minimize(x, -1.0);
        lp.bound_above(x, 2.5);
        let sol = assert_opt(solve(&lp), -2.5, 1e-9);
        assert!((sol[0] - 2.5).abs() < 1e-9);
    }

    #[test]
    fn crossed_bounds_are_infeasible() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        lp.minimize(x, 1.0);
        lp.constraint(&[(x, 1.0)], Cmp::Le, 10.0);
        lp.bound_below(x, 3.0);
        lp.bound_above(x, 2.0);
        assert_eq!(solve(&lp), LpOutcome::Infeasible);
    }

    #[test]
    fn warm_start_round_trip() {
        // Solve, re-solve from the returned basis: same optimum, and the
        // warm solve must succeed without falling back.
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.minimize(x, 1.0);
        lp.minimize(y, 2.0);
        lp.constraint(&[(x, 1.0), (y, 1.0)], Cmp::Ge, 4.0);
        lp.constraint(&[(x, 1.0)], Cmp::Le, 3.0);
        let (first, basis) = solve_warm(&lp, None);
        let (_, obj1) = first.expect("cold solve").expect_optimal("cold");
        let basis = basis.expect("basis returned");
        let (second, _) = solve_warm(&lp, Some(&basis));
        let (_, obj2) = second.expect("warm solve").expect_optimal("warm");
        assert!((obj1 - obj2).abs() < 1e-9, "{obj1} vs {obj2}");

        // A nonsense warm basis must not break correctness either.
        let bogus = vec![0usize; basis.len()];
        let (third, _) = solve_warm(&lp, Some(&bogus));
        let (_, obj3) = third.expect("bogus-warm solve").expect_optimal("bogus");
        assert!((obj1 - obj3).abs() < 1e-9);
    }

    #[test]
    fn warm_start_round_trip_with_bounds() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.minimize(x, 1.0);
        lp.minimize(y, 2.0);
        lp.constraint(&[(x, 1.0), (y, 1.0)], Cmp::Ge, 4.0);
        lp.bound_above(x, 3.0);
        lp.bound_below(y, 0.5);
        let (first, basis) = solve_warm(&lp, None);
        let (_, obj1) = first.expect("cold solve").expect_optimal("cold");
        let basis = basis.expect("basis returned");
        let (second, _) = solve_warm(&lp, Some(&basis));
        let (_, obj2) = second.expect("warm solve").expect_optimal("warm");
        assert!((obj1 - obj2).abs() < 1e-9, "{obj1} vs {obj2}");
    }

    /// Property: revised and dense tableau agree on random feasible LPs
    /// whose variable bounds are *explicit rows* (the pre-bounds shape).
    #[test]
    fn qcheck_matches_dense_simplex() {
        qcheck(Config::default().cases(60), "revised vs dense", |rng: &mut Pcg64| {
            let nv = rng.range(2, 7);
            let nc = rng.range(1, 9);
            let mut lp = Lp::new();
            let vars: Vec<usize> = (0..nv).map(|i| lp.var(format!("v{i}"))).collect();
            let x0: Vec<f64> = (0..nv).map(|_| rng.uniform(0.0, 5.0)).collect();
            for v in &vars {
                lp.minimize(*v, rng.uniform(-1.0, 2.0));
            }
            for _ in 0..nc {
                let terms: Vec<(usize, f64)> =
                    vars.iter().map(|&v| (v, rng.uniform(-1.0, 1.0))).collect();
                let lhs: f64 = terms.iter().map(|&(v, c)| c * x0[v]).sum();
                if rng.chance(0.3) {
                    lp.constraint(&terms, Cmp::Ge, lhs - rng.uniform(0.0, 2.0));
                } else {
                    lp.constraint(&terms, Cmp::Le, lhs + rng.uniform(0.0, 2.0));
                }
            }
            for v in &vars {
                lp.upper_bound(*v, 10.0);
            }
            let dense = crate::solver::simplex::solve(&lp);
            let sparse = solve(&lp);
            match (dense, sparse) {
                (
                    LpOutcome::Optimal { objective: od, .. },
                    LpOutcome::Optimal { x, objective: os },
                ) => {
                    ensure(
                        lp.violation(&x) < 1e-6,
                        format!("violation {}", lp.violation(&x)),
                    )?;
                    ensure(
                        (od - os).abs() <= 1e-7 * od.abs().max(1.0),
                        format!("dense {od} vs revised {os}"),
                    )
                }
                (d, s) => ensure(
                    std::mem::discriminant(&d) == std::mem::discriminant(&s),
                    format!("outcome mismatch: dense {d:?} vs revised {s:?}"),
                ),
            }
        });
    }

    /// Property: the bounded path (implicit box, known-feasible random
    /// LPs) matches the dense oracle, which materializes the bounds into
    /// rows internally.
    #[test]
    fn qcheck_bounded_matches_dense_simplex() {
        qcheck(Config::default().cases(60), "bounded vs dense", |rng: &mut Pcg64| {
            let nv = rng.range(2, 7);
            let nc = rng.range(1, 9);
            let mut lp = Lp::new();
            let vars: Vec<usize> = (0..nv).map(|i| lp.var(format!("v{i}"))).collect();
            // Feasible-by-construction interior point inside the box.
            let x0: Vec<f64> = (0..nv).map(|_| rng.uniform(1.0, 4.0)).collect();
            for (j, v) in vars.iter().enumerate() {
                lp.minimize(*v, rng.uniform(-1.0, 2.0));
                if rng.chance(0.5) {
                    lp.bound_below(*v, rng.uniform(0.0, x0[j]));
                }
                lp.bound_above(*v, rng.uniform(x0[j], 8.0));
            }
            for _ in 0..nc {
                let terms: Vec<(usize, f64)> =
                    vars.iter().map(|&v| (v, rng.uniform(-1.0, 1.0))).collect();
                let lhs: f64 = terms.iter().map(|&(v, c)| c * x0[v]).sum();
                match rng.range(0, 3) {
                    0 => lp.constraint(&terms, Cmp::Ge, lhs - rng.uniform(0.0, 2.0)),
                    1 => lp.constraint(&terms, Cmp::Le, lhs + rng.uniform(0.0, 2.0)),
                    _ => lp.constraint(&terms, Cmp::Eq, lhs),
                }
            }
            let dense = crate::solver::simplex::solve(&lp);
            let sparse = solve(&lp);
            match (dense, sparse) {
                (
                    LpOutcome::Optimal { objective: od, .. },
                    LpOutcome::Optimal { x, objective: os },
                ) => {
                    ensure(
                        lp.violation(&x) < 1e-6,
                        format!("violation {}", lp.violation(&x)),
                    )?;
                    ensure(
                        (od - os).abs() <= 1e-7 * od.abs().max(1.0),
                        format!("dense {od} vs bounded revised {os}"),
                    )
                }
                (d, s) => ensure(
                    std::mem::discriminant(&d) == std::mem::discriminant(&s),
                    format!("outcome mismatch: dense {d:?} vs bounded {s:?}"),
                ),
            }
        });
    }

    /// Property: devex and Dantzig pricing reach the same optimum (the
    /// path differs; the value may not).
    #[test]
    fn qcheck_devex_matches_dantzig() {
        qcheck(Config::default().cases(60), "devex vs dantzig", |rng: &mut Pcg64| {
            let nv = rng.range(2, 7);
            let nc = rng.range(1, 8);
            let mut lp = Lp::new();
            let vars: Vec<usize> = (0..nv).map(|i| lp.var(format!("v{i}"))).collect();
            let x0: Vec<f64> = (0..nv).map(|_| rng.uniform(0.0, 5.0)).collect();
            for v in &vars {
                lp.minimize(*v, rng.uniform(-1.0, 2.0));
                lp.bound_above(*v, 10.0);
            }
            for _ in 0..nc {
                let terms: Vec<(usize, f64)> =
                    vars.iter().map(|&v| (v, rng.uniform(-1.0, 1.0))).collect();
                let lhs: f64 = terms.iter().map(|&(v, c)| c * x0[v]).sum();
                if rng.chance(0.3) {
                    lp.constraint(&terms, Cmp::Ge, lhs - rng.uniform(0.0, 2.0));
                } else {
                    lp.constraint(&terms, Cmp::Le, lhs + rng.uniform(0.0, 2.0));
                }
            }
            let (devex, _) = solve_warm_pricing(&lp, None, Pricing::Devex);
            let (dantzig, _) = solve_warm_pricing(&lp, None, Pricing::Dantzig);
            match (devex, dantzig) {
                (
                    Some(LpOutcome::Optimal { objective: ox, .. }),
                    Some(LpOutcome::Optimal { objective: oz, .. }),
                ) => ensure(
                    (ox - oz).abs() <= 1e-7 * ox.abs().max(1.0),
                    format!("devex {ox} vs dantzig {oz}"),
                ),
                (a, b) => ensure(
                    matches!((&a, &b), (Some(x), Some(y))
                        if std::mem::discriminant(x) == std::mem::discriminant(y)),
                    format!("outcome mismatch: devex {a:?} vs dantzig {b:?}"),
                ),
            }
        });
    }
}
