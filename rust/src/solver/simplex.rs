//! Two-phase primal simplex on a dense tableau.
//!
//! Standard-form conversion: rows are normalized to non-negative rhs;
//! `≤` rows get a slack, `≥` rows a surplus + artificial, `=` rows an
//! artificial. Phase 1 minimizes the artificial sum; phase 2 the true
//! objective. Pivot selection is Dantzig's rule with a Bland fallback
//! after a stall threshold to guarantee termination (anti-cycling).
//!
//! The LPs this crate produces are small (≲ 300 rows × 300 cols for the
//! 8×8×8 environments), so a dense tableau is both simple and fast; the
//! hot loop is the row elimination in [`pivot`], which the perf pass
//! vectorizes by keeping the tableau row-major and contiguous.

use super::lp::{Cmp, Lp, LpOutcome};

const EPS: f64 = 1e-9;
/// Reduced-cost tolerance for the entering test (looser than EPS: after
/// hundreds of pivots the objective row carries ~1e-8 noise).
const EPS_RC: f64 = 1e-6;
/// Minimum acceptable pivot magnitude in the ratio test.
const EPS_PIVOT: f64 = 1e-7;
/// After this many Dantzig pivots without finishing we switch to Bland's
/// rule, which cannot cycle.
const BLAND_SWITCH: usize = 10_000;
const MAX_ITERS: usize = 200_000;

struct Tableau {
    /// (m+1) × (n+1): constraint rows then objective row; last column rhs.
    a: Vec<f64>,
    m: usize,
    n: usize,
    basis: Vec<usize>,
}

impl Tableau {
    #[inline]
    fn at(&self, r: usize, c: usize) -> f64 {
        self.a[r * (self.n + 1) + c]
    }

    #[inline]
    fn set(&mut self, r: usize, c: usize, v: f64) {
        self.a[r * (self.n + 1) + c] = v;
    }

    #[inline]
    fn row(&self, r: usize) -> &[f64] {
        &self.a[r * (self.n + 1)..(r + 1) * (self.n + 1)]
    }

    /// Gauss-Jordan pivot on (prow, pcol).
    fn pivot(&mut self, prow: usize, pcol: usize) {
        let w = self.n + 1;
        let pivot = self.at(prow, pcol);
        debug_assert!(pivot.abs() > EPS);
        let inv = 1.0 / pivot;
        for c in 0..w {
            self.a[prow * w + c] *= inv;
        }
        // Split the buffer around the pivot row so we can scan it while
        // mutating other rows without cloning (hot path).
        let (before, rest) = self.a.split_at_mut(prow * w);
        let (prow_slice, after) = rest.split_at_mut(w);
        let elim = |row: &mut [f64]| {
            let factor = row[pcol];
            if factor.abs() > EPS {
                for c in 0..w {
                    row[c] -= factor * prow_slice[c];
                }
                row[pcol] = 0.0; // exact zero against drift
            }
        };
        for r in before.chunks_exact_mut(w) {
            elim(r);
        }
        for r in after.chunks_exact_mut(w) {
            elim(r);
        }
        self.basis[prow] = pcol;
    }

    /// One simplex phase: minimize the current objective row.
    /// `allowed` limits entering columns (used to bar artificials in
    /// phase 2). Returns false if unbounded.
    ///
    /// `objective_bounded` marks phases whose objective has a known lower
    /// bound (phase 1: the artificial sum is ≥ 0). There an "unbounded"
    /// column is necessarily numerical noise in the priced-out objective
    /// row; we neutralize the column and continue instead of failing.
    fn run_phase(&mut self, allowed: usize, objective_bounded: bool) -> bool {
        let w = self.n + 1;
        // Degeneracy guard: if the objective makes no real progress for a
        // stretch of pivots we are in a degenerate plateau (possibly
        // cycling under Dantzig's rule) — switch to Bland's rule, which
        // cannot cycle. Bland mode persists until progress resumes.
        let mut last_obj = f64::INFINITY;
        let mut stalled = 0usize;
        const STALL_TO_BLAND: usize = 500;
        for iter in 0..MAX_ITERS {
            let cur_obj = -self.at(self.m, self.n);
            if cur_obj < last_obj - 1e-10 * last_obj.abs().max(1.0) {
                last_obj = cur_obj;
                stalled = 0;
            } else {
                stalled += 1;
            }
            let bland = iter >= BLAND_SWITCH || stalled >= STALL_TO_BLAND;
            // Entering column: most negative reduced cost (Dantzig) or
            // first negative (Bland).
            let obj = &self.a[self.m * w..self.m * w + self.n];
            let mut pcol = usize::MAX;
            let mut best = -EPS_RC;
            for (c, &rc) in obj.iter().enumerate().take(allowed) {
                if rc < best {
                    pcol = c;
                    best = rc;
                    if bland {
                        break;
                    }
                }
            }
            if pcol == usize::MAX {
                return true; // optimal
            }
            // Leaving row: min ratio test; ties by smallest basis index
            // (lexicographic-ish, pairs with Bland). Prefer pivots of
            // decent magnitude; fall back to tiny-but-positive ones
            // before declaring the column unbounded.
            let mut prow = usize::MAX;
            for &min_pivot in &[EPS_PIVOT, EPS] {
                let mut best_ratio = f64::INFINITY;
                for r in 0..self.m {
                    let coef = self.at(r, pcol);
                    if coef > min_pivot {
                        let ratio = self.at(r, self.n) / coef;
                        if ratio < best_ratio - EPS
                            || (ratio < best_ratio + EPS
                                && prow != usize::MAX
                                && self.basis[r] < self.basis[prow])
                        {
                            best_ratio = ratio;
                            prow = r;
                        }
                    }
                }
                if prow != usize::MAX {
                    break;
                }
            }
            if prow == usize::MAX {
                if objective_bounded {
                    // Noise column: its reduced cost cannot be genuinely
                    // improving. Clear it and keep going.
                    self.set(self.m, pcol, 0.0);
                    continue;
                }
                return false; // unbounded
            }
            self.pivot(prow, pcol);
        }
        // Iteration cap: the incumbent basis is feasible (phase 1 keeps
        // artificial values non-negative; phase 2 preserves feasibility),
        // so accept it as approximately optimal rather than aborting —
        // callers validate solutions against the exact model anyway.
        true
    }
}

/// Solve a minimization LP.
///
/// The raw problems this crate builds mix O(1) plan fractions with O(1e5)
/// time variables and O(1e5) `D/B` coefficients; we equilibrate before
/// pivoting (geometric-mean row/column scaling, 3 passes) and map the
/// solution back, which keeps the tableau well-conditioned.
pub fn solve(lp: &Lp) -> LpOutcome {
    if lp.has_implicit_bounds() {
        // The dense tableau only understands rows; lower implicit
        // bounds into explicit rows so it stays a drop-in oracle for
        // bounded problems (the recursive call sees no bounds).
        return solve(&lp.materialize_bounds());
    }
    let (row_scale, col_scale) = equilibrate(lp);
    match solve_scaled(lp, &row_scale, &col_scale) {
        LpOutcome::Optimal { mut x, .. } => {
            for (v, s) in x.iter_mut().zip(&col_scale) {
                *v *= s;
            }
            let objective = lp.objective_at(&x);
            LpOutcome::Optimal { x, objective }
        }
        other => other,
    }
}

/// Geometric-mean equilibration: returns per-row and per-column scale
/// factors such that dividing `A_ij` by `row[i]·(1/col[j])`… concretely we
/// use `A'_ij = A_ij · col[j] / row[i]`, `b'_i = b_i / row[i]`, and the
/// scaled variable is `x'_j = x_j / col[j]`.
pub(crate) fn equilibrate(lp: &Lp) -> (Vec<f64>, Vec<f64>) {
    let mut row_scale = vec![1.0f64; lp.n_rows()];
    let mut col_scale = vec![1.0f64; lp.n_vars];
    for _pass in 0..3 {
        // Rows: geometric mean of |A_ij · col_j / row_i| magnitudes.
        for (ri, row) in lp.rows.iter().enumerate() {
            let mut lo = f64::INFINITY;
            let mut hi = 0.0f64;
            for &(v, c) in &row.terms {
                let a = (c * col_scale[v] / row_scale[ri]).abs();
                if a > 0.0 {
                    lo = lo.min(a);
                    hi = hi.max(a);
                }
            }
            if hi > 0.0 {
                row_scale[ri] *= (lo * hi).sqrt();
            }
        }
        // Columns.
        let mut lo = vec![f64::INFINITY; lp.n_vars];
        let mut hi = vec![0.0f64; lp.n_vars];
        for (ri, row) in lp.rows.iter().enumerate() {
            for &(v, c) in &row.terms {
                let a = (c * col_scale[v] / row_scale[ri]).abs();
                if a > 0.0 {
                    lo[v] = lo[v].min(a);
                    hi[v] = hi[v].max(a);
                }
            }
        }
        for v in 0..lp.n_vars {
            if hi[v] > 0.0 {
                col_scale[v] /= (lo[v] * hi[v]).sqrt();
            }
        }
    }
    (row_scale, col_scale)
}

fn solve_scaled(lp: &Lp, row_scale: &[f64], col_scale: &[f64]) -> LpOutcome {
    let m = lp.n_rows();
    let n_orig = lp.n_vars;

    // Classify rows. A `≥` row with rhs == 0 is flipped to `≤ 0` so its
    // slack can serve as the initial basic variable — this avoids one
    // artificial (and its phase-1 degeneracy churn) for each of the many
    // `Z ≥ expr` epigraph rows our formulations produce with zero rhs.
    #[derive(Clone, Copy, PartialEq)]
    enum RowKind {
        Slack,        // ≤ with rhs ≥ 0 (possibly after flipping)
        SurplusArt,   // ≥ with rhs > 0
        Art,          // = (any rhs, normalized non-negative)
    }
    let mut kinds = Vec::with_capacity(m);
    let mut signs = Vec::with_capacity(m);
    let mut n_slack = 0;
    let mut n_art = 0;
    for (r, row) in lp.rows.iter().enumerate() {
        let rhs_scaled = row.rhs / row_scale[r];
        let (kind, sign) = match row.cmp {
            Cmp::Le => {
                if rhs_scaled >= 0.0 {
                    (RowKind::Slack, 1.0)
                } else {
                    // −lhs ≥ −rhs > 0
                    (RowKind::SurplusArt, -1.0)
                }
            }
            Cmp::Ge => {
                if rhs_scaled <= 0.0 {
                    // −lhs ≤ −rhs, rhs ≤ 0 → flipped rhs ≥ 0
                    (RowKind::Slack, -1.0)
                } else {
                    (RowKind::SurplusArt, 1.0)
                }
            }
            Cmp::Eq => (RowKind::Art, if rhs_scaled < 0.0 { -1.0 } else { 1.0 }),
        };
        match kind {
            RowKind::Slack => n_slack += 1,
            RowKind::SurplusArt => {
                n_slack += 1;
                n_art += 1;
            }
            RowKind::Art => n_art += 1,
        }
        kinds.push(kind);
        signs.push(sign);
    }

    let n = n_orig + n_slack + n_art;
    let w = n + 1;
    let mut t = Tableau {
        a: vec![0.0; (m + 1) * w],
        m,
        n,
        basis: vec![usize::MAX; m],
    };

    let mut slack_cursor = n_orig;
    let art_base = n_orig + n_slack;
    let mut art_cursor = art_base;
    let mut art_rows: Vec<usize> = Vec::new();

    for (r, row) in lp.rows.iter().enumerate() {
        let rhs_scaled = row.rhs / row_scale[r];
        let sign = signs[r];
        for &(v, c) in &row.terms {
            let cur = t.at(r, v);
            t.set(r, v, cur + sign * c * col_scale[v] / row_scale[r]);
        }
        t.set(r, n, sign * rhs_scaled);
        match kinds[r] {
            RowKind::Slack => {
                t.set(r, slack_cursor, 1.0);
                t.basis[r] = slack_cursor;
                slack_cursor += 1;
            }
            RowKind::SurplusArt => {
                t.set(r, slack_cursor, -1.0);
                slack_cursor += 1;
                t.set(r, art_cursor, 1.0);
                t.basis[r] = art_cursor;
                art_cursor += 1;
                art_rows.push(r);
            }
            RowKind::Art => {
                t.set(r, art_cursor, 1.0);
                t.basis[r] = art_cursor;
                art_cursor += 1;
                art_rows.push(r);
            }
        }
    }

    // ---- Phase 1: minimize sum of artificials ---------------------------
    if n_art > 0 {
        for c in art_base..n {
            t.set(m, c, 1.0);
        }
        // Price out the artificial basis (objective row must have zero
        // reduced cost on basic columns).
        for &r in &art_rows {
            for c in 0..w {
                let v = t.at(m, c) - t.at(r, c);
                t.set(m, c, v);
            }
        }
        let ok = t.run_phase(n, true);
        debug_assert!(ok, "phase-1 LP cannot be unbounded");
        let phase1_obj = -t.at(m, n); // objective row stores -z
        // Rows are equilibrated to O(1) magnitudes, so 1e-5 residual
        // artificial mass is numerical noise, not real infeasibility.
        if phase1_obj > 1e-5 {
            if std::env::var("MRPERF_LP_DEBUG").is_ok() {
                eprintln!("[simplex] phase1 residual {phase1_obj:e} (m={m}, n={n}, n_art={n_art})");
            }
            return LpOutcome::Infeasible;
        }
        // Drive any artificials out of the basis (degenerate zeros).
        for r in 0..m {
            if t.basis[r] >= art_base {
                // Find a non-artificial column with nonzero coefficient.
                let mut found = None;
                for c in 0..art_base {
                    if t.at(r, c).abs() > EPS {
                        found = Some(c);
                        break;
                    }
                }
                if let Some(c) = found {
                    t.pivot(r, c);
                }
                // Otherwise the row is all-zero: redundant, harmless.
            }
        }
    }

    // ---- Phase 2: the real objective ------------------------------------
    for c in 0..w {
        t.set(m, c, 0.0);
    }
    for v in 0..n_orig {
        t.set(m, v, lp.objective[v] * col_scale[v]);
    }
    // Price out the current basis.
    for r in 0..m {
        let b = t.basis[r];
        if b < n {
            let coef = t.at(m, b);
            if coef.abs() > EPS {
                for c in 0..w {
                    let v = t.at(m, c) - coef * t.at(r, c);
                    t.set(m, c, v);
                }
            }
        }
    }
    // Artificials are barred from re-entering (allowed = art_base).
    if !t.run_phase(art_base, false) {
        return LpOutcome::Unbounded;
    }

    // NB: `x` here is in *scaled* units; the caller (`solve`) multiplies
    // by `col_scale` and recomputes the objective.
    let mut x = vec![0.0; n_orig];
    for r in 0..m {
        let b = t.basis[r];
        if b < n_orig {
            x[b] = t.at(r, n).max(0.0);
        }
    }
    let _ = t.row(0); // keep row() used in release builds
    LpOutcome::Optimal { x, objective: 0.0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::lp::{Cmp, Lp};
    use crate::util::qcheck::{ensure, qcheck, Config};
    use crate::util::rng::Pcg64;

    fn assert_opt(outcome: LpOutcome, want_obj: f64, tol: f64) -> Vec<f64> {
        let (x, obj) = outcome.expect_optimal("test");
        assert!(
            (obj - want_obj).abs() <= tol,
            "objective {obj}, expected {want_obj}"
        );
        x
    }

    #[test]
    fn basic_le_lp() {
        // max x+y s.t. x+2y ≤ 4, 3x+y ≤ 6  →  min -(x+y); opt at (8/5, 6/5).
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.minimize(x, -1.0);
        lp.minimize(y, -1.0);
        lp.constraint(&[(x, 1.0), (y, 2.0)], Cmp::Le, 4.0);
        lp.constraint(&[(x, 3.0), (y, 1.0)], Cmp::Le, 6.0);
        let sol = assert_opt(solve(&lp), -(8.0 / 5.0 + 6.0 / 5.0), 1e-8);
        assert!((sol[0] - 8.0 / 5.0).abs() < 1e-8);
        assert!((sol[1] - 6.0 / 5.0).abs() < 1e-8);
    }

    #[test]
    fn ge_and_eq_need_phase1() {
        // min 2x + 3y s.t. x + y = 10, x ≥ 3  → x=10? no: y free to 0:
        // x+y=10, x≥3; cost 2x+3y = 2x + 3(10-x) = 30 - x → maximize x → x=10,y=0.
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.minimize(x, 2.0);
        lp.minimize(y, 3.0);
        lp.constraint(&[(x, 1.0), (y, 1.0)], Cmp::Eq, 10.0);
        lp.constraint(&[(x, 1.0)], Cmp::Ge, 3.0);
        let sol = assert_opt(solve(&lp), 20.0, 1e-8);
        assert!((sol[0] - 10.0).abs() < 1e-8);
        assert!(sol[1].abs() < 1e-8);
    }

    #[test]
    fn infeasible_detected() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        lp.constraint(&[(x, 1.0)], Cmp::Le, 1.0);
        lp.constraint(&[(x, 1.0)], Cmp::Ge, 2.0);
        assert_eq!(solve(&lp), LpOutcome::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut lp = Lp::new();
        let x = lp.var("x");
        lp.minimize(x, -1.0);
        lp.constraint(&[(x, 1.0)], Cmp::Ge, 1.0);
        assert_eq!(solve(&lp), LpOutcome::Unbounded);
    }

    #[test]
    fn negative_rhs_normalization() {
        // x - y ≤ -2 with x,y ≥ 0: min x+y → x=0, y=2.
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.minimize(x, 1.0);
        lp.minimize(y, 1.0);
        lp.constraint(&[(x, 1.0), (y, -1.0)], Cmp::Le, -2.0);
        let sol = assert_opt(solve(&lp), 2.0, 1e-8);
        assert!(sol[0].abs() < 1e-8 && (sol[1] - 2.0).abs() < 1e-8);
    }

    #[test]
    fn degenerate_lp_terminates() {
        // Classic degeneracy: multiple constraints active at the optimum.
        let mut lp = Lp::new();
        let x = lp.var("x");
        let y = lp.var("y");
        lp.minimize(x, -0.75);
        lp.minimize(y, 150.0);
        lp.constraint(&[(x, 0.25), (y, -60.0)], Cmp::Le, 0.0);
        lp.constraint(&[(x, 0.5), (y, -90.0)], Cmp::Le, 0.0);
        lp.constraint(&[(y, 1.0)], Cmp::Le, 1.0);
        // Beale-like; just require termination + feasibility.
        let (sol, _) = solve(&lp).expect_optimal("degenerate");
        assert!(lp.violation(&sol) < 1e-7);
    }

    #[test]
    fn min_max_epigraph_pattern() {
        // The model's pattern: minimize Z s.t. Z ≥ t_i.
        let mut lp = Lp::new();
        let z = lp.var("z");
        lp.minimize(z, 1.0);
        for &t in &[3.0, 7.0, 5.0] {
            lp.constraint(&[(z, 1.0)], Cmp::Ge, t);
        }
        assert_opt(solve(&lp), 7.0, 1e-9);
    }

    #[test]
    fn transportation_problem() {
        // 2 supplies (10, 20), 2 demands (15, 15), costs [[1,2],[3,1]].
        // Optimal: s0→d0:10, s1→d0:5, s1→d1:15 → 10 + 15 + 15 = 40.
        let mut lp = Lp::new();
        let f: Vec<Vec<usize>> = (0..2)
            .map(|i| (0..2).map(|j| lp.var(format!("f{i}{j}"))).collect())
            .collect();
        let costs = [[1.0, 2.0], [3.0, 1.0]];
        for i in 0..2 {
            for j in 0..2 {
                lp.minimize(f[i][j], costs[i][j]);
            }
        }
        lp.constraint(&[(f[0][0], 1.0), (f[0][1], 1.0)], Cmp::Eq, 10.0);
        lp.constraint(&[(f[1][0], 1.0), (f[1][1], 1.0)], Cmp::Eq, 20.0);
        lp.constraint(&[(f[0][0], 1.0), (f[1][0], 1.0)], Cmp::Eq, 15.0);
        lp.constraint(&[(f[0][1], 1.0), (f[1][1], 1.0)], Cmp::Eq, 15.0);
        assert_opt(solve(&lp), 40.0, 1e-7);
    }

    /// Property: on random feasible-by-construction LPs the simplex
    /// returns a primal-feasible point with objective no worse than a
    /// known feasible point.
    #[test]
    fn qcheck_random_lps_feasible_and_no_worse() {
        qcheck(Config::default().cases(60), "random LP sanity", |rng: &mut Pcg64| {
            let nv = rng.range(2, 6);
            let nc = rng.range(1, 8);
            let mut lp = Lp::new();
            let vars: Vec<usize> = (0..nv).map(|i| lp.var(format!("v{i}"))).collect();
            // A known feasible point.
            let x0: Vec<f64> = (0..nv).map(|_| rng.uniform(0.0, 5.0)).collect();
            for v in &vars {
                lp.minimize(*v, rng.uniform(-1.0, 2.0));
            }
            for _ in 0..nc {
                let terms: Vec<(usize, f64)> = vars
                    .iter()
                    .map(|&v| (v, rng.uniform(-1.0, 1.0)))
                    .collect();
                let lhs: f64 = terms.iter().map(|&(v, c)| c * x0[v]).sum();
                // Make the row feasible at x0 with slack.
                lp.constraint(&terms, Cmp::Le, lhs + rng.uniform(0.0, 2.0));
            }
            // Bound all vars so the LP cannot be unbounded.
            for v in &vars {
                lp.upper_bound(*v, 10.0);
            }
            match solve(&lp) {
                LpOutcome::Optimal { x, objective } => {
                    ensure(lp.violation(&x) < 1e-6, format!("violation {}", lp.violation(&x)))?;
                    ensure(
                        objective <= lp.objective_at(&x0) + 1e-6,
                        format!("obj {objective} worse than feasible {}", lp.objective_at(&x0)),
                    )
                }
                other => Err(format!("expected optimal, got {other:?}")),
            }
        });
    }
}
