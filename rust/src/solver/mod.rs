//! From-scratch LP/MIP solver stack (the paper used Gurobi 5.0; see
//! DESIGN.md §3 for the substitution): problem builder, two-phase dense
//! simplex, sparse revised simplex, branch & bound, and the §2.3
//! piecewise-linear bilinear linearization.

pub mod ipm;
pub mod linalg;
pub mod lp;
pub mod mip;
pub mod pwl;
pub mod revised;
pub mod simplex;

pub use lp::{Cmp, Lp, LpOutcome};
pub use mip::{solve_binary, MipConfig, MipOutcome};
pub use revised::Pricing;
pub use simplex::solve;

use std::sync::atomic::{AtomicU64, Ordering};

/// Hot-path counters for the revised simplex, accumulated process-wide
/// (Relaxed atomics: they are observability, not synchronization).
/// `mrperf bench --json` snapshots them per benchmark so BENCH_*.json
/// files track algorithmic work, not just wall time.
pub(crate) static SOLVER_ITERATIONS: AtomicU64 = AtomicU64::new(0);
pub(crate) static SOLVER_REFACTORIZATIONS: AtomicU64 = AtomicU64::new(0);

/// Snapshot of (simplex iterations — pivots plus bound flips,
/// refactorizations) since process start or the last reset.
pub fn hot_path_counters() -> (u64, u64) {
    (
        SOLVER_ITERATIONS.load(Ordering::Relaxed),
        SOLVER_REFACTORIZATIONS.load(Ordering::Relaxed),
    )
}

/// Zero the hot-path counters (bench harness bracketing).
pub fn reset_hot_path_counters() {
    SOLVER_ITERATIONS.store(0, Ordering::Relaxed);
    SOLVER_REFACTORIZATIONS.store(0, Ordering::Relaxed);
}

/// Default LP solver for the plan optimizers: interior-point (immune to
/// the degeneracy that stalls the tableau simplex on these programs).
pub use ipm::solve as solve_ipm;

/// Row count above which [`solve_robust`]/[`solve_smart`] switch from the
/// dense tableau portfolio to the sparse revised simplex. The paper's
/// 8×8×8 plan LPs stay well below this, so they keep the exact historical
/// code path; generated 128+-node topologies go sparse.
pub const DENSE_ROW_CUTOVER: usize = 300;

/// Largest LP the dense portfolio is allowed to take as a *fallback* when
/// the sparse path reports numerical trouble (the dense tableau is
/// O(rows·cols) memory).
const DENSE_FALLBACK_LIMIT: usize = 2000;

/// Dense portfolio solve: tableau simplex first (an order of magnitude
/// faster on paper-size problems — see EXPERIMENTS.md §Perf),
/// interior-point as the fallback for the degenerate instances where the
/// simplex stalls or mis-declares infeasibility. The two from-scratch
/// solvers have complementary failure modes on the crate's heavily
/// degenerate, badly scaled plan LPs.
///
/// A simplex "optimal" is only accepted when primal-feasible to 1e-6;
/// stall-capped bases that drifted are handed to the IPM instead.
pub fn solve_robust_dense(lp: &Lp) -> LpOutcome {
    let first = simplex::solve(lp);
    if let LpOutcome::Optimal { x, objective } = &first {
        if lp.violation(x) < 1e-6 {
            return LpOutcome::Optimal { x: x.clone(), objective: *objective };
        }
    }
    match ipm::solve(lp) {
        LpOutcome::Optimal { x, objective } => LpOutcome::Optimal { x, objective },
        _ => first,
    }
}

/// Robust solve with automatic dense/sparse dispatch by problem size.
pub fn solve_robust(lp: &Lp) -> LpOutcome {
    solve_smart(lp, None).0
}

/// Size-dispatching solve with optional warm-start basis reuse.
///
/// * rows ≤ [`DENSE_ROW_CUTOVER`]: dense portfolio (no basis to reuse).
/// * larger: sparse revised simplex, warm-started from `warm` when the
///   structure still matches; its final basis is returned for the next
///   structurally identical solve. A sparse solution is accepted only if
///   primal-feasible to 1e-6; otherwise the dense portfolio takes over
///   when the problem is small enough to afford it.
pub fn solve_smart(lp: &Lp, warm: Option<&[usize]>) -> (LpOutcome, Option<Vec<usize>>) {
    if lp.n_rows() <= DENSE_ROW_CUTOVER {
        return (solve_robust_dense(lp), None);
    }
    let (out, basis) = revised::solve_warm(lp, warm);
    match out {
        Some(LpOutcome::Optimal { x, objective }) => {
            if lp.violation(&x) < 1e-6 {
                return (LpOutcome::Optimal { x, objective }, basis);
            }
            if lp.n_rows() <= DENSE_FALLBACK_LIMIT {
                (solve_robust_dense(lp), None)
            } else {
                (LpOutcome::Optimal { x, objective }, basis)
            }
        }
        // Mis-declared infeasibility is the documented failure mode of
        // from-scratch simplexes on these degenerate plan LPs, so a
        // sparse Infeasible/Unbounded verdict gets the same dense
        // cross-check as a drifted optimum whenever it is affordable.
        Some(other) => {
            if lp.n_rows() <= DENSE_FALLBACK_LIMIT {
                (solve_robust_dense(lp), None)
            } else {
                (other, basis)
            }
        }
        None => {
            if lp.n_rows() <= DENSE_FALLBACK_LIMIT {
                (solve_robust_dense(lp), None)
            } else {
                // The sparse solver failed twice (warm + cold retry) and
                // the LP is too large for the dense portfolio's O(m·n)
                // memory. Surfacing Infeasible is a mislabel, but every
                // caller treats it as "no usable solution" and degrades
                // (the alternating descent keeps its incumbent); flag it
                // for diagnosis rather than fail silently.
                if std::env::var("MRPERF_LP_DEBUG").is_ok() {
                    eprintln!(
                        "[solve_smart] sparse solver failed on {}x{} LP with no \
                         affordable dense fallback",
                        lp.n_rows(),
                        lp.n_vars
                    );
                }
                (LpOutcome::Infeasible, None)
            }
        }
    }
}
