//! From-scratch LP/MIP solver stack (the paper used Gurobi 5.0; see
//! DESIGN.md §3 for the substitution): problem builder, two-phase dense
//! simplex, branch & bound, and the §2.3 piecewise-linear bilinear
//! linearization.

pub mod ipm;
pub mod linalg;
pub mod lp;
pub mod mip;
pub mod pwl;
pub mod simplex;

pub use lp::{Cmp, Lp, LpOutcome};
pub use mip::{solve_binary, MipConfig, MipOutcome};
pub use simplex::solve;

/// Default LP solver for the plan optimizers: interior-point (immune to
/// the degeneracy that stalls the tableau simplex on these programs).
pub use ipm::solve as solve_ipm;

/// Portfolio solve: tableau simplex first (an order of magnitude faster
/// on these sizes — see EXPERIMENTS.md §Perf), interior-point as the
/// fallback for the degenerate instances where the simplex stalls or
/// mis-declares infeasibility. The two from-scratch solvers have
/// complementary failure modes on the crate's heavily degenerate, badly
/// scaled plan LPs; together they cover every instance the optimizers
/// generate (see the alternating-LP tests).
///
/// A simplex "optimal" is only accepted when primal-feasible to 1e-6;
/// stall-capped bases that drifted are handed to the IPM instead.
pub fn solve_robust(lp: &Lp) -> LpOutcome {
    let first = simplex::solve(lp);
    if let LpOutcome::Optimal { x, objective } = &first {
        if lp.violation(x) < 1e-6 {
            return LpOutcome::Optimal { x: x.clone(), objective: *objective };
        }
    }
    match ipm::solve(lp) {
        LpOutcome::Optimal { x, objective } => LpOutcome::Optimal { x, objective },
        _ => first,
    }
}
