//! The paper's §2.3 bilinear linearization.
//!
//! A product `u·v` of two LP variables in `[0,1]` is rewritten in
//! separable form via `w = ½(u+v)`, `w' = ½(u−v)`, so that
//! `u·v = w² − w'²`; the two quadratics are then approximated with
//! piecewise-linear interpolants over ~10 evenly spaced breakpoints
//! (9 segments, the paper's compromise with a stated worst-case deviation
//! of ~4%):
//!
//! * `w²` is convex and enters the minimized objective positively, so its
//!   λ-interpolation needs **no** integral variables (the LP naturally
//!   selects the adjacent-breakpoint combination — the secant PWL).
//! * `−w'²` is concave, so its λ-interpolation needs SOS2-style adjacency
//!   enforced with **binary** variables — this is what turns the program
//!   into a MIP.
//!
//! Correct only when the product appears with *non-negative* coefficients
//! in a minimized objective (true for all the makespan formulations).

use super::lp::{Cmp, Lp};

/// Handle returned by [`add_product`].
#[derive(Debug, Clone)]
pub struct PwlProduct {
    /// LP variable approximating `u·v`.
    pub product: usize,
    /// Binary variables created (callers pass these to the MIP solver).
    pub binaries: Vec<usize>,
}

/// Default number of breakpoints (paper: "about 10 evenly spaced points").
pub const DEFAULT_POINTS: usize = 10;

/// Worst-case absolute deviation of the `n`-point secant interpolation of
/// `w²` on `[0,1]`: `h²/4` with `h = 1/(n-1)`.
pub fn worst_case_dev(n_points: usize) -> f64 {
    let h = 1.0 / (n_points as f64 - 1.0);
    h * h / 4.0
}

/// Add the PWL approximation of `product ≈ u·v` for `u, v ∈ [0,1]`.
pub fn add_product(lp: &mut Lp, u: usize, v: usize, n_points: usize) -> PwlProduct {
    assert!(n_points >= 3);
    let tag = lp.n_vars; // unique-ish suffix for debug names

    // w = ½(u+v) ∈ [0,1]
    let w = lp.var(format!("pwl_w#{tag}"));
    lp.constraint(&[(w, 1.0), (u, -0.5), (v, -0.5)], Cmp::Eq, 0.0);

    // t = w' + ½ = ½(u−v) + ½ ∈ [0,1]  (shift keeps the var non-negative)
    let t = lp.var(format!("pwl_t#{tag}"));
    lp.constraint(&[(t, 1.0), (u, -0.5), (v, 0.5)], Cmp::Eq, 0.5);

    // ---- q ≈ w² : convex λ-interpolation, no binaries -------------------
    let lambdas_q = lp.vars(&format!("pwl_lq#{tag}"), n_points);
    let q = lp.var(format!("pwl_q#{tag}"));
    {
        let sum: Vec<(usize, f64)> = lambdas_q.iter().map(|&l| (l, 1.0)).collect();
        lp.constraint(&sum, Cmp::Eq, 1.0);
        // w = Σ λ_i p_i
        let mut row: Vec<(usize, f64)> = lambdas_q
            .iter()
            .enumerate()
            .map(|(i, &l)| (l, breakpoint(i, n_points)))
            .collect();
        row.push((w, -1.0));
        lp.constraint(&row, Cmp::Eq, 0.0);
        // q = Σ λ_i p_i²
        let mut row: Vec<(usize, f64)> = lambdas_q
            .iter()
            .enumerate()
            .map(|(i, &l)| {
                let p = breakpoint(i, n_points);
                (l, p * p)
            })
            .collect();
        row.push((q, -1.0));
        lp.constraint(&row, Cmp::Eq, 0.0);
    }

    // ---- r ≈ w'² = (t−½)² : concave side, SOS2 binaries ------------------
    let lambdas_r = lp.vars(&format!("pwl_lr#{tag}"), n_points);
    let r = lp.var(format!("pwl_r#{tag}"));
    let n_seg = n_points - 1;
    let deltas = lp.vars(&format!("pwl_d#{tag}"), n_seg);
    {
        let sum: Vec<(usize, f64)> = lambdas_r.iter().map(|&l| (l, 1.0)).collect();
        lp.constraint(&sum, Cmp::Eq, 1.0);
        let mut row: Vec<(usize, f64)> = lambdas_r
            .iter()
            .enumerate()
            .map(|(i, &l)| (l, breakpoint(i, n_points)))
            .collect();
        row.push((t, -1.0));
        lp.constraint(&row, Cmp::Eq, 0.0);
        let mut row: Vec<(usize, f64)> = lambdas_r
            .iter()
            .enumerate()
            .map(|(i, &l)| {
                let p = breakpoint(i, n_points) - 0.5;
                (l, p * p)
            })
            .collect();
        row.push((r, -1.0));
        lp.constraint(&row, Cmp::Eq, 0.0);
        // SOS2 adjacency: λ_i ≤ δ_{i-1} + δ_i (boundary cases one term).
        for (i, &l) in lambdas_r.iter().enumerate() {
            let mut row: Vec<(usize, f64)> = vec![(l, 1.0)];
            if i > 0 {
                row.push((deltas[i - 1], -1.0));
            }
            if i < n_seg {
                row.push((deltas[i], -1.0));
            }
            lp.constraint(&row, Cmp::Le, 0.0);
        }
        let sum: Vec<(usize, f64)> = deltas.iter().map(|&d| (d, 1.0)).collect();
        lp.constraint(&sum, Cmp::Eq, 1.0);
    }

    // ---- product = q − r (may be slightly negative near 0; clamp via
    // a free-split: product is non-negative by construction in exact
    // arithmetic since u·v ≥ 0, but the approximation can dip below; we
    // allow it by writing product − neg = q − r with tiny neg slack) ----
    let product = lp.var(format!("pwl_p#{tag}"));
    let neg = lp.var(format!("pwl_neg#{tag}"));
    lp.constraint(
        &[(product, 1.0), (neg, -1.0), (q, -1.0), (r, 1.0)],
        Cmp::Eq,
        0.0,
    );
    lp.upper_bound(neg, worst_case_dev(n_points) * 2.0);

    PwlProduct { product, binaries: deltas }
}

#[inline]
fn breakpoint(i: usize, n_points: usize) -> f64 {
    i as f64 / (n_points as f64 - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::lp::{Cmp, Lp};
    use crate::solver::mip::{solve_binary, MipConfig, MipOutcome};

    /// Build an LP that fixes u and v, minimizes the product variable, and
    /// check the PWL value is close to u·v.
    fn eval_product(u_val: f64, v_val: f64, n_points: usize) -> f64 {
        let mut lp = Lp::new();
        let u = lp.var("u");
        let v = lp.var("v");
        lp.fix(u, u_val);
        lp.fix(v, v_val);
        let pw = add_product(&mut lp, u, v, n_points);
        // Positive objective coefficient, as required.
        lp.minimize(pw.product, 1.0);
        match solve_binary(&lp, &pw.binaries, MipConfig::default()) {
            MipOutcome::Optimal { x, .. } => x[pw.product],
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn product_accuracy_grid() {
        let tol = worst_case_dev(DEFAULT_POINTS) * 4.0 + 1e-6;
        for &u in &[0.0, 0.25, 0.4, 0.7, 1.0] {
            for &v in &[0.0, 0.3, 0.5, 0.9, 1.0] {
                let approx = eval_product(u, v, DEFAULT_POINTS);
                assert!(
                    (approx - u * v).abs() <= tol,
                    "PWL({u}·{v}) = {approx}, want {} ± {tol}",
                    u * v
                );
            }
        }
    }

    #[test]
    fn accuracy_improves_with_points() {
        let coarse = (eval_product(0.35, 0.65, 5) - 0.35 * 0.65).abs();
        let fine = (eval_product(0.35, 0.65, 21) - 0.35 * 0.65).abs();
        assert!(fine <= coarse + 1e-9, "coarse {coarse} vs fine {fine}");
    }

    #[test]
    fn worst_case_dev_matches_paper_scale() {
        // ~10 points / 9 segments: paper reports ~4.15% worst-case
        // deviation on their normalization; ours is h²/4 absolute.
        let d = worst_case_dev(10);
        assert!(d < 0.01, "dev {d}");
    }

    #[test]
    fn product_usable_inside_larger_objective() {
        // minimize T s.t. T ≥ 3·(u·v), u = 0.6 fixed, v free with v ≥ 0.5
        // → optimizer pushes v to 0.5, T* ≈ 0.9.
        let mut lp = Lp::new();
        let u = lp.var("u");
        let v = lp.var("v");
        let t = lp.var("T");
        lp.fix(u, 0.6);
        lp.constraint(&[(v, 1.0)], Cmp::Ge, 0.5);
        lp.upper_bound(v, 1.0);
        let pw = add_product(&mut lp, u, v, DEFAULT_POINTS);
        lp.constraint(&[(t, 1.0), (pw.product, -3.0)], Cmp::Ge, 0.0);
        lp.minimize(t, 1.0);
        match solve_binary(&lp, &pw.binaries, MipConfig::default()) {
            MipOutcome::Optimal { x, .. } => {
                assert!((x[t] - 0.9).abs() < 0.05, "T = {}", x[t]);
                assert!((x[v] - 0.5).abs() < 1e-5);
            }
            other => panic!("{other:?}"),
        }
    }
}
