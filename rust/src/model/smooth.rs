//! Smooth (differentiable) relaxation of the makespan model.
//!
//! This is the rust twin of the L2 JAX graph in `python/compile/model.py`:
//! every hard `max` becomes `smax_β(v) = logsumexp(β·v)/β` and plans are
//! parameterized by unconstrained logits (row-softmax for `x`, softmax for
//! `y`) so the simplex constraints (eqs 1–3) hold by construction. Barrier
//! configurations enter as two floats per boundary (`g` = global?, `p` =
//! pipelined?) so one graph covers all nine G/L/P combinations.
//!
//! It exists for two reasons: (1) parity tests pinning the AOT-compiled
//! HLO artifact against an independent implementation, and (2) a pure-rust
//! fallback for the gradient optimizer when artifacts are absent.

use super::barrier::{Barrier, BarrierConfig};
use super::makespan::AppModel;
use super::plan::Plan;
use crate::platform::Topology;
use crate::util::mat::Mat;

/// Smooth-max with sharpness `beta` (upper-bounds the true max; the gap
/// shrinks as `beta` grows: `max ≤ smax ≤ max + ln(n)/beta`).
pub fn smax(values: &[f64], beta: f64) -> f64 {
    debug_assert!(!values.is_empty());
    let m = values.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let sum: f64 = values.iter().map(|v| ((v - m) * beta).exp()).sum();
    m + sum.ln() / beta
}

/// Two-argument smooth max.
pub fn smax2(a: f64, b: f64, beta: f64) -> f64 {
    smax(&[a, b], beta)
}

/// Row-wise softmax of a logits matrix.
pub fn softmax_rows(logits: &Mat) -> Mat {
    let mut out = Mat::zeros(logits.rows(), logits.cols());
    for r in 0..logits.rows() {
        let row = logits.row(r);
        let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut sum = 0.0;
        for (c, &v) in row.iter().enumerate() {
            let e = (v - m).exp();
            out[(r, c)] = e;
            sum += e;
        }
        for c in 0..logits.cols() {
            out[(r, c)] /= sum;
        }
    }
    out
}

/// Softmax of a logits vector.
pub fn softmax(logits: &[f64]) -> Vec<f64> {
    let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = logits.iter().map(|&v| (v - m).exp()).collect();
    let sum: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / sum).collect()
}

/// Barrier boundary as the two smooth selectors used by the L2 graph.
#[derive(Debug, Clone, Copy)]
pub struct BoundarySel {
    /// 1.0 if the boundary is a global barrier, else 0.0.
    pub g: f64,
    /// 1.0 if the boundary is pipelined, else 0.0.
    pub p: f64,
}

impl From<Barrier> for BoundarySel {
    fn from(b: Barrier) -> Self {
        match b {
            Barrier::Global => BoundarySel { g: 1.0, p: 0.0 },
            Barrier::Local => BoundarySel { g: 0.0, p: 0.0 },
            Barrier::Pipelined => BoundarySel { g: 0.0, p: 1.0 },
        }
    }
}

/// Barrier config as the six selector floats fed to the AOT artifact.
pub fn selectors(cfg: BarrierConfig) -> [f64; 6] {
    let pm: BoundarySel = cfg.push_map.into();
    let ms: BoundarySel = cfg.map_shuffle.into();
    let sr: BoundarySel = cfg.shuffle_reduce.into();
    [pm.g, pm.p, ms.g, ms.p, sr.g, sr.p]
}

#[inline]
fn combine(start: f64, cost: f64, sel: BoundarySel, beta: f64) -> f64 {
    // pipelined: smax(start, cost); local/global: start + cost
    sel.p * smax2(start, cost, beta) + (1.0 - sel.p) * (start + cost)
}

/// Smooth makespan of a *plan* (already on the simplex).
pub fn smooth_makespan_plan(
    topo: &Topology,
    app: AppModel,
    cfg: BarrierConfig,
    plan: &Plan,
    beta: f64,
) -> f64 {
    let (s, m, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
    let alpha = app.alpha;
    let pm: BoundarySel = cfg.push_map.into();
    let ms: BoundarySel = cfg.map_shuffle.into();
    let sr: BoundarySel = cfg.shuffle_reduce.into();

    // push_end_j = smax_i (D_i x_ij / B_ij)
    let mut push_end = vec![0.0; m];
    let mut scratch = vec![0.0; s];
    for j in 0..m {
        for i in 0..s {
            scratch[i] = topo.d[i] * plan.x.get(i, j) / topo.b_sm.get(i, j);
        }
        push_end[j] = smax(&scratch, beta);
    }
    let push_max = smax(&push_end, beta);

    // map_end_j
    let m_loads = plan.map_loads(&topo.d);
    let mut map_end = vec![0.0; m];
    for j in 0..m {
        let start = pm.g * push_max + (1.0 - pm.g) * push_end[j];
        map_end[j] = combine(start, m_loads[j] / topo.c_map[j], pm, beta);
    }
    let map_max = smax(&map_end, beta);

    // shuffle_end_k = smax_j combine(start_j, α m_j y_k / B_jk)
    let mut shuffle_end = vec![0.0; r];
    let mut per_j = vec![0.0; m];
    for k in 0..r {
        for j in 0..m {
            let start = ms.g * map_max + (1.0 - ms.g) * map_end[j];
            let t = alpha * m_loads[j] * plan.y[k] / topo.b_mr.get(j, k);
            per_j[j] = combine(start, t, ms, beta);
        }
        shuffle_end[k] = smax(&per_j, beta);
    }
    let shuffle_max = smax(&shuffle_end, beta);

    // reduce_end_k
    let d_total = topo.total_data();
    let mut reduce_end = vec![0.0; r];
    for k in 0..r {
        let start = sr.g * shuffle_max + (1.0 - sr.g) * shuffle_end[k];
        let t = alpha * d_total * plan.y[k] / topo.c_red[k];
        reduce_end[k] = combine(start, t, sr, beta);
    }
    smax(&reduce_end, beta)
}

/// Smooth makespan of unconstrained *logits* (the optimizer's view).
pub fn smooth_makespan_logits(
    topo: &Topology,
    app: AppModel,
    cfg: BarrierConfig,
    logits_x: &Mat,
    logits_y: &[f64],
    beta: f64,
) -> f64 {
    let plan = Plan { x: softmax_rows(logits_x), y: softmax(logits_y) };
    smooth_makespan_plan(topo, app, cfg, &plan, beta)
}

// ---------------------------------------------------------------------------
// Analytic reverse-mode gradient
// ---------------------------------------------------------------------------

/// Smooth-max that also records the softmax weights (`∂smax/∂v_i`).
fn smax_with_weights(values: &[f64], beta: f64, weights: &mut [f64]) -> f64 {
    debug_assert_eq!(values.len(), weights.len());
    let m = values.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut sum = 0.0;
    for (w, &v) in weights.iter_mut().zip(values) {
        let e = ((v - m) * beta).exp();
        *w = e;
        sum += e;
    }
    for w in weights.iter_mut() {
        *w /= sum;
    }
    m + sum.ln() / beta
}

/// [`combine`] with partials: returns `(value, ∂/∂start, ∂/∂cost)`.
fn combine_with_grad(start: f64, cost: f64, sel: BoundarySel, beta: f64) -> (f64, f64, f64) {
    let mx = start.max(cost);
    let es = ((start - mx) * beta).exp();
    let ec = ((cost - mx) * beta).exp();
    let sum = es + ec;
    let sm = mx + sum.ln() / beta;
    let v = sel.p * sm + (1.0 - sel.p) * (start + cost);
    let ds = sel.p * (es / sum) + (1.0 - sel.p);
    let dc = sel.p * (ec / sum) + (1.0 - sel.p);
    (v, ds, dc)
}

/// Loss and analytic gradient of [`smooth_makespan_logits`] w.r.t. the
/// logits: one forward pass (recording smax/softmax weights) plus one
/// hand-written reverse pass through row-softmax → phase times →
/// logsumexp. Replaces the `O(S·M + R)` finite-difference evaluations per
/// optimizer step with `O(1)` evaluations — the pure-rust fast path of
/// the gradient optimizer (no `pjrt` feature needed).
pub fn smooth_makespan_grad(
    topo: &Topology,
    app: AppModel,
    cfg: BarrierConfig,
    logits_x: &Mat,
    logits_y: &[f64],
    beta: f64,
) -> (f64, Mat, Vec<f64>) {
    let (s, m, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
    let alpha = app.alpha;
    let pm: BoundarySel = cfg.push_map.into();
    let ms: BoundarySel = cfg.map_shuffle.into();
    let sr: BoundarySel = cfg.shuffle_reduce.into();

    let x = softmax_rows(logits_x);
    let y = softmax(logits_y);

    // ---- forward, recording local derivatives --------------------------
    let mut wpush = Mat::zeros(s, m);
    let mut push_end = vec![0.0; m];
    let mut scratch = vec![0.0; s];
    let mut wcol = vec![0.0; s];
    for j in 0..m {
        for i in 0..s {
            scratch[i] = topo.d[i] * x.get(i, j) / topo.b_sm.get(i, j);
        }
        push_end[j] = smax_with_weights(&scratch, beta, &mut wcol);
        for i in 0..s {
            wpush[(i, j)] = wcol[i];
        }
    }
    let mut wpmax = vec![0.0; m];
    let push_max = smax_with_weights(&push_end, beta, &mut wpmax);

    let mut loads = vec![0.0; m];
    for i in 0..s {
        for j in 0..m {
            loads[j] += topo.d[i] * x.get(i, j);
        }
    }
    let mut map_end = vec![0.0; m];
    let mut ds1 = vec![0.0; m];
    let mut dc1 = vec![0.0; m];
    for j in 0..m {
        let start = pm.g * push_max + (1.0 - pm.g) * push_end[j];
        let (v, dsv, dcv) = combine_with_grad(start, loads[j] / topo.c_map[j], pm, beta);
        map_end[j] = v;
        ds1[j] = dsv;
        dc1[j] = dcv;
    }
    let mut wmmax = vec![0.0; m];
    let map_max = smax_with_weights(&map_end, beta, &mut wmmax);

    let mut st2 = vec![0.0; m];
    for j in 0..m {
        st2[j] = ms.g * map_max + (1.0 - ms.g) * map_end[j];
    }
    let mut wshuf = Mat::zeros(r, m);
    let mut ds2 = Mat::zeros(r, m);
    let mut dt2 = Mat::zeros(r, m);
    let mut shuffle_end = vec![0.0; r];
    let mut per_j = vec![0.0; m];
    let mut wrow = vec![0.0; m];
    for k in 0..r {
        for j in 0..m {
            let t = alpha * loads[j] * y[k] / topo.b_mr.get(j, k);
            let (v, dsv, dtv) = combine_with_grad(st2[j], t, ms, beta);
            per_j[j] = v;
            ds2[(k, j)] = dsv;
            dt2[(k, j)] = dtv;
        }
        shuffle_end[k] = smax_with_weights(&per_j, beta, &mut wrow);
        for j in 0..m {
            wshuf[(k, j)] = wrow[j];
        }
    }
    let mut wsmax = vec![0.0; r];
    let shuffle_max = smax_with_weights(&shuffle_end, beta, &mut wsmax);

    let d_total = topo.total_data();
    let mut ds3 = vec![0.0; r];
    let mut dc3 = vec![0.0; r];
    let mut reduce_end = vec![0.0; r];
    for k in 0..r {
        let start = sr.g * shuffle_max + (1.0 - sr.g) * shuffle_end[k];
        let (v, dsv, dcv) =
            combine_with_grad(start, alpha * d_total * y[k] / topo.c_red[k], sr, beta);
        reduce_end[k] = v;
        ds3[k] = dsv;
        dc3[k] = dcv;
    }
    let mut wout = vec![0.0; r];
    let loss = smax_with_weights(&reduce_end, beta, &mut wout);

    // ---- reverse pass ---------------------------------------------------
    let mut gx = Mat::zeros(s, m); // ∂loss/∂x_ij (before the softmax chain)
    let mut gy = vec![0.0; r];
    let mut d_loads = vec![0.0; m];

    let mut d_shuffle_end = vec![0.0; r];
    let mut d_shuffle_max = 0.0;
    for k in 0..r {
        let d_st3 = wout[k] * ds3[k];
        gy[k] += wout[k] * dc3[k] * alpha * d_total / topo.c_red[k];
        d_shuffle_max += d_st3 * sr.g;
        d_shuffle_end[k] += d_st3 * (1.0 - sr.g);
    }
    for k in 0..r {
        d_shuffle_end[k] += d_shuffle_max * wsmax[k];
    }

    let mut d_st2 = vec![0.0; m];
    for k in 0..r {
        for j in 0..m {
            let d_per = d_shuffle_end[k] * wshuf[(k, j)];
            d_st2[j] += d_per * ds2[(k, j)];
            let d_t = d_per * dt2[(k, j)];
            let b = topo.b_mr.get(j, k);
            gy[k] += d_t * alpha * loads[j] / b;
            d_loads[j] += d_t * alpha * y[k] / b;
        }
    }

    let mut d_map_end = vec![0.0; m];
    let mut d_map_max = 0.0;
    for j in 0..m {
        d_map_max += d_st2[j] * ms.g;
        d_map_end[j] += d_st2[j] * (1.0 - ms.g);
    }
    for j in 0..m {
        d_map_end[j] += d_map_max * wmmax[j];
    }

    let mut d_push_end = vec![0.0; m];
    let mut d_push_max = 0.0;
    for j in 0..m {
        let d_st1 = d_map_end[j] * ds1[j];
        d_loads[j] += d_map_end[j] * dc1[j] / topo.c_map[j];
        d_push_max += d_st1 * pm.g;
        d_push_end[j] += d_st1 * (1.0 - pm.g);
    }
    for j in 0..m {
        d_push_end[j] += d_push_max * wpmax[j];
    }

    for j in 0..m {
        for i in 0..s {
            let d_pc = d_push_end[j] * wpush[(i, j)];
            gx[(i, j)] += d_pc * topo.d[i] / topo.b_sm.get(i, j) + d_loads[j] * topo.d[i];
        }
    }

    // ---- softmax chain --------------------------------------------------
    let mut glx = Mat::zeros(s, m);
    for i in 0..s {
        let mut dot = 0.0;
        for j in 0..m {
            dot += gx.get(i, j) * x.get(i, j);
        }
        for j in 0..m {
            glx[(i, j)] = x.get(i, j) * (gx.get(i, j) - dot);
        }
    }
    let doty: f64 = gy.iter().zip(&y).map(|(g, p)| g * p).sum();
    let gly: Vec<f64> = (0..r).map(|k| y[k] * (gy[k] - doty)).collect();

    (loss, glx, gly)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::makespan::makespan;
    use crate::platform::topology::example_1_3;
    use crate::platform::MB;
    use crate::util::rng::Pcg64;

    #[test]
    fn smax_bounds() {
        let v = [1.0, 5.0, 3.0];
        for &beta in &[0.5, 2.0, 20.0] {
            let s = smax(&v, beta);
            assert!(s >= 5.0, "smax upper-bounds max");
            assert!(s <= 5.0 + (3.0f64).ln() / beta + 1e-12);
        }
        // Sharper beta → tighter.
        assert!(smax(&v, 20.0) < smax(&v, 2.0));
    }

    #[test]
    fn smax_handles_large_magnitudes() {
        // No overflow for times in the 1e5 range.
        let v = [1.0e5, 9.0e4];
        let s = smax(&v, 1e-2);
        assert!(s.is_finite() && s >= 1.0e5);
    }

    #[test]
    fn softmax_rows_on_simplex() {
        let logits = Mat::from_rows(&[&[0.0, 1.0, -2.0], &[3.0, 3.0, 3.0]]);
        let p = softmax_rows(&logits);
        for r in 0..2 {
            assert!((p.row_sum(r) - 1.0).abs() < 1e-12);
        }
        assert!((p.get(1, 0) - 1.0 / 3.0).abs() < 1e-12);
        assert!(p.get(0, 1) > p.get(0, 0));
    }

    #[test]
    fn smooth_converges_to_hard_makespan() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let app = AppModel::new(2.0);
        let mut rng = Pcg64::new(4);
        for cfg in [
            BarrierConfig::ALL_GLOBAL,
            BarrierConfig::HADOOP,
            BarrierConfig::ALL_PIPELINED,
        ] {
            for _ in 0..10 {
                let p = Plan::random(2, 2, 2, &mut rng);
                let hard = makespan(&t, app, cfg, &p);
                // β scaled to the problem magnitude.
                let beta = 200.0 / hard;
                let soft = smooth_makespan_plan(&t, app, cfg, &p, beta);
                let rel = (soft - hard).abs() / hard;
                assert!(
                    rel < 0.05,
                    "cfg {cfg:?}: smooth {soft} vs hard {hard} (rel {rel})"
                );
                assert!(soft >= hard - 1e-9, "smooth upper-bounds hard");
            }
        }
    }

    #[test]
    fn logits_evaluation_matches_plan_evaluation() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let app = AppModel::new(1.0);
        let logits_x = Mat::from_rows(&[&[0.3, -0.7], &[1.2, 0.1]]);
        let logits_y = vec![0.5, -0.5];
        let plan = Plan { x: softmax_rows(&logits_x), y: softmax(&logits_y) };
        plan.check(&t).unwrap();
        let beta = 1e-3;
        let a = smooth_makespan_logits(&t, app, BarrierConfig::HADOOP, &logits_x, &logits_y, beta);
        let b = smooth_makespan_plan(&t, app, BarrierConfig::HADOOP, &plan, beta);
        assert_eq!(a, b);
    }

    #[test]
    fn grad_loss_matches_forward_evaluator() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let app = AppModel::new(2.0);
        let lx = Mat::from_rows(&[&[0.3, -0.7], &[1.2, 0.1]]);
        let ly = vec![0.5, -0.5];
        for cfg in [
            BarrierConfig::ALL_GLOBAL,
            BarrierConfig::HADOOP,
            BarrierConfig::ALL_PIPELINED,
        ] {
            let beta = 1e-3;
            let want = smooth_makespan_logits(&t, app, cfg, &lx, &ly, beta);
            let (got, _, _) = smooth_makespan_grad(&t, app, cfg, &lx, &ly, beta);
            let rel = (got - want).abs() / want.abs().max(1.0);
            assert!(rel < 1e-12, "cfg {cfg:?}: grad fwd {got} vs evaluator {want}");
        }
    }

    #[test]
    fn grad_matches_finite_differences_small() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let app = AppModel::new(1.5);
        let mut rng = Pcg64::new(11);
        for cfg in [
            BarrierConfig::ALL_GLOBAL,
            BarrierConfig::HADOOP,
            BarrierConfig::ALL_PIPELINED,
        ] {
            let mut lx = Mat::zeros(2, 2);
            for i in 0..2 {
                for j in 0..2 {
                    lx.set(i, j, rng.normal() * 0.5);
                }
            }
            let ly: Vec<f64> = (0..2).map(|_| rng.normal() * 0.5).collect();
            let uni_ms = makespan(&t, app, cfg, &Plan::uniform(2, 2, 2));
            let beta = 50.0 / uni_ms;
            let (_, glx, gly) = smooth_makespan_grad(&t, app, cfg, &lx, &ly, beta);

            let eps = 1e-5;
            let gmax = glx
                .data()
                .iter()
                .chain(&gly)
                .fold(0.0f64, |a, &g| a.max(g.abs()))
                .max(1e-12);
            for i in 0..2 {
                for j in 0..2 {
                    let mut hi = lx.clone();
                    hi.set(i, j, lx.get(i, j) + eps);
                    let mut lo = lx.clone();
                    lo.set(i, j, lx.get(i, j) - eps);
                    let fd = (smooth_makespan_logits(&t, app, cfg, &hi, &ly, beta)
                        - smooth_makespan_logits(&t, app, cfg, &lo, &ly, beta))
                        / (2.0 * eps);
                    let rel = (glx.get(i, j) - fd).abs() / gmax;
                    assert!(rel < 1e-5, "cfg {cfg:?} x[{i}][{j}]: {} vs fd {fd}", glx.get(i, j));
                }
            }
            for k in 0..2 {
                let mut hi = ly.clone();
                hi[k] += eps;
                let mut lo = ly.clone();
                lo[k] -= eps;
                let fd = (smooth_makespan_logits(&t, app, cfg, &lx, &hi, beta)
                    - smooth_makespan_logits(&t, app, cfg, &lx, &lo, beta))
                    / (2.0 * eps);
                let rel = (gly[k] - fd).abs() / gmax;
                assert!(rel < 1e-5, "cfg {cfg:?} y[{k}]: {} vs fd {fd}", gly[k]);
            }
        }
    }

    #[test]
    fn selectors_roundtrip() {
        let cfg = BarrierConfig::HADOOP; // G-P-L
        let s = selectors(cfg);
        assert_eq!(s, [1.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
    }
}
