//! Inter-phase barrier semantics (§2.2, "Modeling the consecutive
//! execution of phases").
//!
//! Between each pair of consecutive phases (push/map, map/shuffle,
//! shuffle/reduce) the model supports:
//!
//! * **Global** — every node finishes the previous phase before any node
//!   starts the next (`start = max over nodes of previous end`, then the
//!   phase cost is *added*).
//! * **Local** — a node starts its next phase as soon as *it* has all its
//!   inputs (`end = own_start + cost`).
//! * **Pipelined** — a node overlaps the phases (`end = max(own_start,
//!   cost)`, the paper's `⊕ = max` combination).

/// One boundary's semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Barrier {
    Global,
    Local,
    Pipelined,
}

impl Barrier {
    /// The paper's `⊕` combination operator (local: `a+b`; pipelined:
    /// `max(a,b)`). For Global the start is a phase-wide max and the cost
    /// is then added — same `+` shape as Local, different start.
    #[inline]
    pub fn combine(&self, start: f64, cost: f64) -> f64 {
        match self {
            Barrier::Global | Barrier::Local => start + cost,
            Barrier::Pipelined => start.max(cost),
        }
    }

    pub fn letter(&self) -> char {
        match self {
            Barrier::Global => 'G',
            Barrier::Local => 'L',
            Barrier::Pipelined => 'P',
        }
    }
}

/// Barrier choice at each of the three phase boundaries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BarrierConfig {
    pub push_map: Barrier,
    pub map_shuffle: Barrier,
    pub shuffle_reduce: Barrier,
}

impl BarrierConfig {
    pub const fn new(push_map: Barrier, map_shuffle: Barrier, shuffle_reduce: Barrier) -> Self {
        BarrierConfig { push_map, map_shuffle, shuffle_reduce }
    }

    /// All-global-barrier configuration — the Fig 7 normalization baseline.
    pub const ALL_GLOBAL: BarrierConfig =
        BarrierConfig::new(Barrier::Global, Barrier::Global, Barrier::Global);

    /// All-pipelined ("all" bar in Fig 7).
    pub const ALL_PIPELINED: BarrierConfig =
        BarrierConfig::new(Barrier::Pipelined, Barrier::Pipelined, Barrier::Pipelined);

    /// G-P-L: the configuration the paper uses to capture default Hadoop
    /// behaviour (§4.6.1) — global push/map (HDFS materialization),
    /// coarse-grained pipelined map/shuffle, local shuffle/reduce.
    pub const HADOOP: BarrierConfig =
        BarrierConfig::new(Barrier::Global, Barrier::Pipelined, Barrier::Local);

    /// The four configurations instantiated in the validation (§3.2):
    /// G-P-L, P-P-L, P-G-L, G-G-L.
    pub fn validation_set() -> [BarrierConfig; 4] {
        use Barrier::*;
        [
            BarrierConfig::new(Global, Pipelined, Local),
            BarrierConfig::new(Pipelined, Pipelined, Local),
            BarrierConfig::new(Pipelined, Global, Local),
            BarrierConfig::new(Global, Global, Local),
        ]
    }

    /// Fig 7's sweep: all-global, then relax exactly one boundary to
    /// pipelining at a time, then all-pipelined.
    pub fn fig7_set() -> [(&'static str, BarrierConfig); 5] {
        use Barrier::*;
        [
            ("baseline (GGG)", BarrierConfig::ALL_GLOBAL),
            ("push/map", BarrierConfig::new(Pipelined, Global, Global)),
            ("map/shuffle", BarrierConfig::new(Global, Pipelined, Global)),
            ("shuffle/reduce", BarrierConfig::new(Global, Global, Pipelined)),
            ("all", BarrierConfig::ALL_PIPELINED),
        ]
    }

    /// Short name like "G-P-L".
    pub fn label(&self) -> String {
        format!(
            "{}-{}-{}",
            self.push_map.letter(),
            self.map_shuffle.letter(),
            self.shuffle_reduce.letter()
        )
    }
}

impl std::fmt::Display for BarrierConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combine_semantics() {
        assert_eq!(Barrier::Local.combine(3.0, 4.0), 7.0);
        assert_eq!(Barrier::Global.combine(3.0, 4.0), 7.0);
        assert_eq!(Barrier::Pipelined.combine(3.0, 4.0), 4.0);
        assert_eq!(Barrier::Pipelined.combine(5.0, 4.0), 5.0);
    }

    #[test]
    fn labels() {
        assert_eq!(BarrierConfig::HADOOP.label(), "G-P-L");
        assert_eq!(BarrierConfig::ALL_GLOBAL.label(), "G-G-G");
        assert_eq!(BarrierConfig::ALL_PIPELINED.label(), "P-P-P");
        assert_eq!(format!("{}", BarrierConfig::ALL_GLOBAL), "G-G-G");
    }

    #[test]
    fn validation_set_matches_paper() {
        let labels: Vec<String> =
            BarrierConfig::validation_set().iter().map(|c| c.label()).collect();
        assert_eq!(labels, vec!["G-P-L", "P-P-L", "P-G-L", "G-G-L"]);
    }

    #[test]
    fn fig7_relaxes_one_at_a_time() {
        let set = BarrierConfig::fig7_set();
        assert_eq!(set[0].1.label(), "G-G-G");
        assert_eq!(set[1].1.label(), "P-G-G");
        assert_eq!(set[2].1.label(), "G-P-G");
        assert_eq!(set[3].1.label(), "G-G-P");
        assert_eq!(set[4].1.label(), "P-P-P");
    }
}
