//! The closed-form makespan model — Equations 4–14 of the paper.
//!
//! Given a [`Topology`], an application expansion factor `α`, a
//! [`BarrierConfig`] and a [`Plan`], computes per-node phase end times and
//! the job makespan:
//!
//! * push:    `push_end_j   = max_i D_i·x_ij / B_ij`                   (eq 4)
//! * map:     `map_end_j    = map_start_j ⊕ m_j / C_j`                 (eq 6/12)
//! * shuffle: `shuffle_end_k = max_j { shuffle_start_j ⊕ α·m_j·y_k / B_jk }`
//!                                                                     (eq 8/13)
//! * reduce:  `reduce_end_k = reduce_start_k ⊕ α·D_total·y_k / C_k`    (eq 10/14)
//! * makespan = `max_k reduce_end_k`                                   (eq 11)
//!
//! where `m_j = Σ_i D_i·x_ij` and starts are either the phase-wide max
//! (global barrier, eqs 5/7/9) or the node's own previous end
//! (local/pipelined).

use super::barrier::{Barrier, BarrierConfig};
use super::plan::Plan;
use crate::platform::Topology;

/// The application model (§2.1): only `α` and (implicitly, via the
/// topology's `C` values) the compute intensity matter.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AppModel {
    /// Ratio of mapper output size to mapper input size.
    pub alpha: f64,
}

impl AppModel {
    pub fn new(alpha: f64) -> AppModel {
        assert!(alpha >= 0.0 && alpha.is_finite());
        AppModel { alpha }
    }
}

/// Full per-node timeline of one evaluated plan.
#[derive(Debug, Clone)]
pub struct Timeline {
    pub push_end: Vec<f64>,    // per mapper
    pub map_end: Vec<f64>,     // per mapper
    pub shuffle_end: Vec<f64>, // per reducer
    pub reduce_end: Vec<f64>,  // per reducer
    pub makespan: f64,
}

/// Aggregate phase durations for stacked-bar reporting (Figs 5, 6, 9):
/// the marginal time each phase adds to the critical path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseBreakdown {
    pub push: f64,
    pub map: f64,
    pub shuffle: f64,
    pub reduce: f64,
}

impl PhaseBreakdown {
    pub fn total(&self) -> f64 {
        self.push + self.map + self.shuffle + self.reduce
    }
}

impl Timeline {
    pub fn breakdown(&self) -> PhaseBreakdown {
        let max = |xs: &[f64]| xs.iter().cloned().fold(0.0, f64::max);
        let push = max(&self.push_end);
        let map = (max(&self.map_end) - push).max(0.0);
        let shuffle = (max(&self.shuffle_end) - max(&self.map_end)).max(0.0);
        let reduce = (self.makespan - max(&self.shuffle_end)).max(0.0);
        PhaseBreakdown { push, map, shuffle, reduce }
    }
}

/// Evaluate the model for one plan. Returns the full timeline.
///
/// A plan that routes data over a zero-bandwidth link would yield an
/// infinite time; [`Topology::validate`] forbids zero bandwidths, so all
/// results are finite for valid inputs.
pub fn evaluate(topo: &Topology, app: AppModel, cfg: BarrierConfig, plan: &Plan) -> Timeline {
    debug_assert!(plan.check(topo).is_ok(), "invalid plan: {:?}", plan.check(topo));
    let (s, m, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
    let alpha = app.alpha;

    // ---- push (eq 4): slowest incoming transfer per mapper -------------
    let mut push_end = vec![0.0; m];
    for j in 0..m {
        let mut worst: f64 = 0.0;
        for i in 0..s {
            let xij = plan.x.get(i, j);
            if xij > 0.0 {
                worst = worst.max(topo.d[i] * xij / topo.b_sm.get(i, j));
            }
        }
        push_end[j] = worst;
    }

    // ---- map (eqs 5, 6 / 12) --------------------------------------------
    let m_loads = plan.map_loads(&topo.d);
    let push_max = push_end.iter().cloned().fold(0.0, f64::max);
    let mut map_end = vec![0.0; m];
    for j in 0..m {
        let start = match cfg.push_map {
            Barrier::Global => push_max,
            _ => push_end[j],
        };
        map_end[j] = cfg.push_map.combine(start, m_loads[j] / topo.c_map[j]);
    }

    // ---- shuffle (eqs 7, 8 / 13) ----------------------------------------
    let map_max = map_end.iter().cloned().fold(0.0, f64::max);
    let mut shuffle_end = vec![0.0; r];
    for k in 0..r {
        let mut worst: f64 = 0.0;
        for j in 0..m {
            let start = match cfg.map_shuffle {
                Barrier::Global => map_max,
                _ => map_end[j],
            };
            let vol = alpha * m_loads[j] * plan.y[k];
            let t = vol / topo.b_mr.get(j, k);
            worst = worst.max(cfg.map_shuffle.combine(start, t));
        }
        shuffle_end[k] = worst;
    }

    // ---- reduce (eqs 9, 10 / 14) ----------------------------------------
    let shuffle_max = shuffle_end.iter().cloned().fold(0.0, f64::max);
    let d_total = topo.total_data();
    let mut reduce_end = vec![0.0; r];
    for k in 0..r {
        let start = match cfg.shuffle_reduce {
            Barrier::Global => shuffle_max,
            _ => shuffle_end[k],
        };
        let t = alpha * d_total * plan.y[k] / topo.c_red[k];
        reduce_end[k] = cfg.shuffle_reduce.combine(start, t);
    }

    let makespan = reduce_end.iter().cloned().fold(0.0, f64::max);
    Timeline { push_end, map_end, shuffle_end, reduce_end, makespan }
}

/// Just the makespan (eq 11).
pub fn makespan(topo: &Topology, app: AppModel, cfg: BarrierConfig, plan: &Plan) -> f64 {
    evaluate(topo, app, cfg, plan).makespan
}

/// Push completion time `max_j push_end_j` — the myopic push objective (§4.2).
pub fn push_time(topo: &Topology, plan: &Plan) -> f64 {
    let mut worst: f64 = 0.0;
    for j in 0..topo.n_mappers() {
        for i in 0..topo.n_sources() {
            let xij = plan.x.get(i, j);
            if xij > 0.0 {
                worst = worst.max(topo.d[i] * xij / topo.b_sm.get(i, j));
            }
        }
    }
    worst
}

/// Shuffle duration `max_k max_j α·m_j·y_k / B_jk` in isolation — the
/// myopic shuffle objective (§4.2).
pub fn shuffle_time(topo: &Topology, app: AppModel, plan: &Plan) -> f64 {
    let m_loads = plan.map_loads(&topo.d);
    let mut worst: f64 = 0.0;
    for k in 0..topo.n_reducers() {
        for j in 0..topo.n_mappers() {
            let t = app.alpha * m_loads[j] * plan.y[k] / topo.b_mr.get(j, k);
            worst = worst.max(t);
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::topology::example_1_3;
    use crate::platform::MB;

    const GBF: f64 = 1e9;

    fn app(alpha: f64) -> AppModel {
        AppModel::new(alpha)
    }

    /// §1.3 scenario 1: α=1, homogeneous 100 MBps everywhere → uniform
    /// push is optimal and its push phase takes 150GB·0.5/100MBps = 750 s.
    #[test]
    fn example_1_3_homogeneous_uniform() {
        let t = example_1_3(100.0 * MB, 100.0 * MB, 100.0 * MB);
        let uni = Plan::uniform(2, 2, 2);
        let tl = evaluate(&t, app(1.0), BarrierConfig::ALL_GLOBAL, &uni);
        // push: slowest transfer = 75GB over 100MBps = 750 s
        assert!((tl.push_end[0] - 750.0).abs() < 1e-9);
        // map: 100GB per mapper at 100 MBps = 1000 s after global barrier
        assert!((tl.map_end[0] - 1750.0).abs() < 1e-9);
        // shuffle: α·m_j·y_k = 50GB per (j,k) pair at 100MBps = 500 s
        assert!((tl.shuffle_end[0] - 2250.0).abs() < 1e-9);
        // reduce: α·D_total·y_k = 100GB at 100MBps = 1000 s
        assert!((tl.makespan - 3250.0).abs() < 1e-9);
    }

    /// §1.3 scenario 2: slow non-local links (10 MBps), α=1. The paper:
    /// local push finishes the push in 1500 s while uniform needs 7500 s.
    #[test]
    fn example_1_3_slow_nonlocal_push_times() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let local = Plan::local_push(&t);
        let uni = Plan::uniform(2, 2, 2);
        assert!((push_time(&t, &local) - 1500.0).abs() < 1e-9);
        assert!((push_time(&t, &uni) - 7500.0).abs() < 1e-9);
        // The paper: uniform's map phase is 500 s shorter (1000 vs 1500).
        let tl_local = evaluate(&t, app(1.0), BarrierConfig::ALL_GLOBAL, &local);
        let tl_uni = evaluate(&t, app(1.0), BarrierConfig::ALL_GLOBAL, &uni);
        let map_local = tl_local.breakdown().map;
        let map_uni = tl_uni.breakdown().map;
        assert!((map_local - 1500.0).abs() < 1e-9);
        assert!((map_uni - 1000.0).abs() < 1e-9);
        // End-to-end, local push wins in this scenario.
        assert!(tl_local.makespan < tl_uni.makespan);
    }

    /// §1.3 scenario 3: α=10 — pushing D2's data to M1 lets the whole
    /// shuffle+reduce happen inside cluster 1, beating local push.
    #[test]
    fn example_1_3_alpha_10_all_to_cluster1() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let a = app(10.0);
        let cfg = BarrierConfig::ALL_GLOBAL;

        let local = Plan::local_push(&t);
        // all-to-M1 plan with all keys reduced at R1:
        let mut x = crate::util::mat::Mat::zeros(2, 2);
        x[(0, 0)] = 1.0;
        x[(1, 0)] = 1.0;
        let all_c1 = Plan { x, y: vec![1.0, 0.0] };
        all_c1.check(&t).unwrap();

        let ms_local = makespan(&t, a, cfg, &local);
        let ms_c1 = makespan(&t, a, cfg, &all_c1);
        assert!(
            ms_c1 < ms_local,
            "cluster-1 consolidation {ms_c1} should beat local push {ms_local} at α=10"
        );
    }

    /// Local push is a near-myopic-optimal push plan in the §1.3 setup:
    /// far better than uniform, and within 10% of the true LP optimum
    /// (which shaves a sliver of D1 onto the slow link: 1500·10/11 s).
    #[test]
    fn local_push_nearly_minimizes_push_time() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let local = Plan::local_push(&t);
        let uni = Plan::uniform(2, 2, 2);
        assert!(push_time(&t, &local) < 0.25 * push_time(&t, &uni));
        // Analytic myopic optimum: D1 splits f = 1/11 to the slow link.
        let opt = 1500.0 * 10.0 / 11.0;
        assert!(push_time(&t, &local) <= opt * 1.1 + 1e-9);
        let mut rng = crate::util::rng::Pcg64::new(3);
        for _ in 0..100 {
            let p = Plan::random(2, 2, 2, &mut rng);
            assert!(push_time(&t, &p) >= opt - 1e-6, "no plan beats the LP optimum");
        }
    }

    /// Barrier ordering: relaxing barriers can only shorten the makespan:
    /// all-global ≥ G-P-L ≥ all-pipelined for the same plan.
    #[test]
    fn barrier_relaxation_monotone() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let mut rng = crate::util::rng::Pcg64::new(5);
        for &alpha in &[0.1, 1.0, 10.0] {
            for _ in 0..50 {
                let p = Plan::random(2, 2, 2, &mut rng);
                let g = makespan(&t, app(alpha), BarrierConfig::ALL_GLOBAL, &p);
                let h = makespan(&t, app(alpha), BarrierConfig::HADOOP, &p);
                let pp = makespan(&t, app(alpha), BarrierConfig::ALL_PIPELINED, &p);
                assert!(g >= h - 1e-9, "G-G-G {g} < G-P-L {h}");
                assert!(h >= pp - 1e-9, "G-P-L {h} < P-P-P {pp}");
            }
        }
    }

    /// Breakdown components are non-negative and sum to the makespan.
    #[test]
    fn breakdown_sums_to_makespan() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let mut rng = crate::util::rng::Pcg64::new(8);
        for cfg in [
            BarrierConfig::ALL_GLOBAL,
            BarrierConfig::HADOOP,
            BarrierConfig::ALL_PIPELINED,
        ] {
            for _ in 0..20 {
                let p = Plan::random(2, 2, 2, &mut rng);
                let tl = evaluate(&t, app(2.0), cfg, &p);
                let b = tl.breakdown();
                assert!(b.push >= 0.0 && b.map >= 0.0 && b.shuffle >= 0.0 && b.reduce >= 0.0);
                assert!((b.total() - tl.makespan).abs() < 1e-6 * tl.makespan.max(1.0));
            }
        }
    }

    /// α=0 means no intermediate data: shuffle and reduce take zero time.
    #[test]
    fn alpha_zero_collapses_late_phases() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let p = Plan::uniform(2, 2, 2);
        let tl = evaluate(&t, app(0.0), BarrierConfig::ALL_GLOBAL, &p);
        let b = tl.breakdown();
        assert_eq!(b.shuffle, 0.0);
        assert_eq!(b.reduce, 0.0);
        assert!(tl.makespan > 0.0);
    }

    /// Makespan scales linearly with data volume (all barriers, fixed plan).
    #[test]
    fn makespan_scales_with_data() {
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let t2 = {
            let mut t2 = t.clone();
            for d in t2.d.iter_mut() {
                *d *= 3.0;
            }
            t2
        };
        let p = Plan::uniform(2, 2, 2);
        for cfg in [BarrierConfig::ALL_GLOBAL, BarrierConfig::ALL_PIPELINED] {
            let m1 = makespan(&t, app(1.5), cfg, &p);
            let m2 = makespan(&t2, app(1.5), cfg, &p);
            assert!((m2 / m1 - 3.0).abs() < 1e-9);
        }
    }

    #[test]
    fn shuffle_time_matches_global_barrier_increment() {
        // With all-global barriers, the breakdown's shuffle equals the
        // isolated shuffle_time.
        let t = example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB);
        let p = Plan::uniform(2, 2, 2);
        let tl = evaluate(&t, app(2.0), BarrierConfig::ALL_GLOBAL, &p);
        let iso = shuffle_time(&t, app(2.0), &p);
        assert!((tl.breakdown().shuffle - iso).abs() < 1e-9);
    }

    const _: f64 = GBF; // silence unused in some cfg combinations
}
