//! The MapReduce performance model of §2: execution plans (eqs 1–3),
//! barrier semantics, the closed-form makespan model (eqs 4–14) and its
//! smooth (differentiable) relaxation.

pub mod barrier;
pub mod makespan;
pub mod plan;
pub mod smooth;

pub use barrier::{Barrier, BarrierConfig};
pub use makespan::{evaluate, makespan, push_time, shuffle_time, AppModel, PhaseBreakdown, Timeline};
pub use plan::{Plan, PlanError};
