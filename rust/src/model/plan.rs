//! Execution plans (§2.2).
//!
//! A plan is the pair `({x_ij}, {y_k})`: `x_ij` is the fraction of source
//! `i`'s data pushed to mapper `j`; `y_k` is the fraction of the
//! intermediate key space assigned to reducer `k`. The paper's validity
//! conditions (Equations 1–3) are: every `x_ij ∈ [0,1]`, rows sum to 1,
//! and — per the one-reducer-per-key requirement — every mapper shuffles
//! with the *same* fractions `x_jk = y_k` (Equation 3), which we enforce
//! by construction by storing `y` once.

use crate::platform::Topology;
use crate::util::mat::Mat;
use crate::util::rng::Pcg64;

/// A valid-by-construction execution plan.
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    /// `x_ij`, `|S| × |M|`, rows on the probability simplex.
    pub x: Mat,
    /// `y_k`, `|R|`, on the probability simplex.
    pub y: Vec<f64>,
}

/// Violations reported by [`Plan::check`].
#[derive(Debug, Clone, PartialEq)]
pub enum PlanError {
    BadShape { expected: (usize, usize, usize), got: (usize, usize, usize) },
    NegativeFraction { what: &'static str, index: (usize, usize), value: f64 },
    RowSum { what: &'static str, row: usize, sum: f64 },
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::BadShape { expected, got } => {
                write!(f, "plan shape {got:?} does not match topology {expected:?}")
            }
            PlanError::NegativeFraction { what, index, value } => {
                write!(f, "{what}{index:?} = {value} outside [0,1]")
            }
            PlanError::RowSum { what, row, sum } => {
                write!(f, "{what} row {row} sums to {sum}, expected 1")
            }
        }
    }
}

impl std::error::Error for PlanError {}

pub const SIMPLEX_TOL: f64 = 1e-6;

impl Plan {
    /// The uniform plan (Equations 15–16): every source spreads its data
    /// evenly over mappers; the key space is split evenly over reducers.
    pub fn uniform(n_sources: usize, n_mappers: usize, n_reducers: usize) -> Plan {
        Plan {
            x: Mat::filled(n_sources, n_mappers, 1.0 / n_mappers as f64),
            y: vec![1.0 / n_reducers as f64; n_reducers],
        }
    }

    /// "Local push" (§1.3): each source sends everything to its most local
    /// mapper (fastest link), key space uniform.
    pub fn local_push(topo: &Topology) -> Plan {
        let (s, m, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
        let mut x = Mat::zeros(s, m);
        for i in 0..s {
            x[(i, topo.most_local_mapper(i))] = 1.0;
        }
        Plan { x, y: vec![1.0 / r as f64; r] }
    }

    /// Random plan on the simplex (Dirichlet-ish via normalized
    /// exponentials) — used for multi-start initialization and tests.
    pub fn random(
        n_sources: usize,
        n_mappers: usize,
        n_reducers: usize,
        rng: &mut Pcg64,
    ) -> Plan {
        let mut x = Mat::zeros(n_sources, n_mappers);
        for i in 0..n_sources {
            let row = x.row_mut(i);
            let mut sum = 0.0;
            for v in row.iter_mut() {
                *v = rng.exponential(1.0);
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
        let mut y: Vec<f64> = (0..n_reducers).map(|_| rng.exponential(1.0)).collect();
        let s: f64 = y.iter().sum();
        for v in y.iter_mut() {
            *v /= s;
        }
        Plan { x, y }
    }

    pub fn n_sources(&self) -> usize {
        self.x.rows()
    }

    pub fn n_mappers(&self) -> usize {
        self.x.cols()
    }

    pub fn n_reducers(&self) -> usize {
        self.y.len()
    }

    /// Validity check per Equations 1–3.
    pub fn check(&self, topo: &Topology) -> Result<(), PlanError> {
        let got = (self.n_sources(), self.n_mappers(), self.n_reducers());
        let expected = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
        if got != expected {
            return Err(PlanError::BadShape { expected, got });
        }
        for i in 0..self.x.rows() {
            for j in 0..self.x.cols() {
                let v = self.x.get(i, j);
                if !(-SIMPLEX_TOL..=1.0 + SIMPLEX_TOL).contains(&v) || !v.is_finite() {
                    return Err(PlanError::NegativeFraction {
                        what: "x",
                        index: (i, j),
                        value: v,
                    });
                }
            }
            let sum = self.x.row_sum(i);
            if (sum - 1.0).abs() > SIMPLEX_TOL * self.x.cols() as f64 {
                return Err(PlanError::RowSum { what: "x", row: i, sum });
            }
        }
        for (k, &v) in self.y.iter().enumerate() {
            if !(-SIMPLEX_TOL..=1.0 + SIMPLEX_TOL).contains(&v) || !v.is_finite() {
                return Err(PlanError::NegativeFraction {
                    what: "y",
                    index: (k, 0),
                    value: v,
                });
            }
        }
        let ysum: f64 = self.y.iter().sum();
        if (ysum - 1.0).abs() > SIMPLEX_TOL * self.y.len() as f64 {
            return Err(PlanError::RowSum { what: "y", row: 0, sum: ysum });
        }
        Ok(())
    }

    /// `m_j = Σ_i D_i x_ij`: bytes of input pushed to each mapper.
    pub fn map_loads(&self, d: &[f64]) -> Vec<f64> {
        assert_eq!(d.len(), self.n_sources());
        let mut m = vec![0.0; self.n_mappers()];
        for i in 0..self.n_sources() {
            let row = self.x.row(i);
            for (j, &xij) in row.iter().enumerate() {
                m[j] += d[i] * xij;
            }
        }
        m
    }

    /// Clamp tiny numerical negatives and renormalize rows exactly onto the
    /// simplex (used after LP solves which satisfy constraints to 1e-9).
    pub fn renormalize(&mut self) {
        for i in 0..self.x.rows() {
            let row = self.x.row_mut(i);
            let mut sum = 0.0;
            for v in row.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
                sum += *v;
            }
            if sum > 0.0 {
                for v in row.iter_mut() {
                    *v /= sum;
                }
            } else {
                let n = row.len() as f64;
                for v in row.iter_mut() {
                    *v = 1.0 / n;
                }
            }
        }
        let mut sum = 0.0;
        for v in self.y.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
            sum += *v;
        }
        if sum > 0.0 {
            for v in self.y.iter_mut() {
                *v /= sum;
            }
        } else {
            let n = self.y.len() as f64;
            for v in self.y.iter_mut() {
                *v = 1.0 / n;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::topology::example_1_3;
    use crate::platform::MB;

    fn topo() -> Topology {
        example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB)
    }

    #[test]
    fn uniform_is_valid() {
        let t = topo();
        let p = Plan::uniform(2, 2, 2);
        p.check(&t).unwrap();
        assert_eq!(p.x.get(0, 0), 0.5);
        assert_eq!(p.y, vec![0.5, 0.5]);
    }

    #[test]
    fn local_push_is_valid_and_local() {
        let t = topo();
        let p = Plan::local_push(&t);
        p.check(&t).unwrap();
        assert_eq!(p.x.get(0, 0), 1.0);
        assert_eq!(p.x.get(1, 1), 1.0);
    }

    #[test]
    fn random_plans_valid() {
        let t = topo();
        let mut rng = Pcg64::new(9);
        for _ in 0..50 {
            Plan::random(2, 2, 2, &mut rng).check(&t).unwrap();
        }
    }

    #[test]
    fn check_rejects_bad_shapes_and_sums() {
        let t = topo();
        let p = Plan::uniform(3, 2, 2);
        assert!(matches!(p.check(&t), Err(PlanError::BadShape { .. })));

        let mut p = Plan::uniform(2, 2, 2);
        p.x[(0, 0)] = 0.9; // row sums to 1.4
        assert!(matches!(p.check(&t), Err(PlanError::RowSum { .. })));

        let mut p = Plan::uniform(2, 2, 2);
        p.x[(0, 0)] = -0.5;
        p.x[(0, 1)] = 1.5;
        assert!(matches!(p.check(&t), Err(PlanError::NegativeFraction { .. })));
    }

    #[test]
    fn map_loads_example() {
        // §1.3: D = [150, 50] GB; local push → loads [150, 50] GB;
        // uniform → [100, 100] GB.
        let t = topo();
        let local = Plan::local_push(&t);
        let loads = local.map_loads(&t.d);
        assert!((loads[0] - 150e9).abs() < 1.0);
        assert!((loads[1] - 50e9).abs() < 1.0);

        let uni = Plan::uniform(2, 2, 2);
        let loads = uni.map_loads(&t.d);
        assert!((loads[0] - 100e9).abs() < 1.0);
        assert!((loads[1] - 100e9).abs() < 1.0);
    }

    #[test]
    fn renormalize_fixes_drift() {
        let t = topo();
        let mut p = Plan::uniform(2, 2, 2);
        p.x[(0, 0)] = 0.5000004;
        p.x[(0, 1)] = 0.5000004;
        p.y[0] = -1e-9;
        p.y[1] = 1.0;
        p.renormalize();
        p.check(&t).unwrap();
        assert!((p.x.row_sum(0) - 1.0).abs() < 1e-12);
    }
}
