//! `mrperf` — geo-distributed MapReduce planner + engine CLI.
//!
//! ```text
//! mrperf experiment <id>|all          regenerate a paper table/figure
//! mrperf plan [options]               compute an optimized execution plan
//! mrperf run [options]                execute a job on the emulated WAN
//! mrperf bench [--json DIR]           quick perf suite, JSON-recordable
//! mrperf validate                     model-vs-engine validation summary
//! mrperf list                         available experiments / envs / apps
//! ```

use std::path::PathBuf;
use std::process::ExitCode;

use mrperf::engine::job::JobConfig;
use mrperf::engine::{run_job, run_job_with_recovery, RecoveryOpts};
use mrperf::experiments;
use mrperf::model::barrier::{Barrier, BarrierConfig};
use mrperf::model::makespan::{evaluate, AppModel};
use mrperf::model::plan::Plan;
use mrperf::optimizer::{
    AlternatingLp, E2ePush, E2eShuffle, Myopic, PlanOptimizer, Uniform,
};
use mrperf::platform::{build_env, EnvKind};
use mrperf::util::cli;
use mrperf::util::logger::{self, Level};
use mrperf::util::table::{fmt_secs, Table};

const USAGE: &str = "\
mrperf — geo-distributed MapReduce modeling, optimization & execution

USAGE:
  mrperf experiment <table1|fig4..fig12|scale|churn|adversary|tenancy|resilience|replan|all>
               [--results DIR]
               [--gen KIND:NODES[:SEED]] [--dynamics PROFILE[:SEED]]
               [--profiles all] [--hedge RATE]                        (churn only)
               [--budget K] [--seed S] [--restarts R] [--hedge RATE]  (adversary only)
               [--arrivals PROFILE[:RATE[:SEED]]] [--jobs N] [--loads L1,L2,..]
               [--policies P1,P2,..] [--slack S] [--threads N]        (tenancy only)
  mrperf plan  [--env ENV | --topology FILE.topo | --gen KIND:NODES[:SEED]]
               [--alpha A] [--barriers G-P-L] [--optimizer NAME] [--skew S]
               [--hedge RATE]
  mrperf run   [--env ENV | --topology FILE.topo | --gen KIND:NODES[:SEED]]
               [--app APP] [--alpha A] [--optimizer NAME] [--skew S]
               [--bytes-per-source N] [--speculation] [--stealing] [--locality]
               [--replication R] [--dynamics PROFILE[:SEED]] [--hedge RATE]
               [--replan off|on-event|every:T]
               [--threads N] [--max-attempts N]
               [--checkpoint-every T] [--crash-at T2] [--checkpoint-path FILE]
               [--resume-from FILE]
  mrperf bench [--json DIR] [--filter SUBSTR]
  mrperf validate
  mrperf list

ENV:        local-dc | 2-dc-intra | 4-dc-global | 8-dc-global (default)
GEN KIND:   hier-wan | federated | edge-heavy (generated 16-512 node platforms,
            e.g. --gen hier-wan:256 or --gen edge-heavy:64:9)
SKEW:       Zipf data-volume skew across generated sources (0 = uniform,
            default; only meaningful with --gen)
APP:        wordcount | sessionize | inverted-index | synthetic (default)
OPTIMIZER:  uniform | myopic | e2e-push | e2e-shuffle | e2e-multi (default)
            | gradient (pure-rust analytic) | artifact (AOT JAX/Pallas via PJRT)
BARRIERS:   three of G|L|P joined by '-', e.g. G-P-L (default), G-G-G, P-P-P
DYNAMICS:   seeded fault/variability trace injected into the engine run:
            step | periodic | burst | failures | stragglers | churn | staleness
            (e.g. --dynamics burst:7 or --dynamics staleness:3; staleness makes
            sources refresh data mid-push, forcing exact-accounted re-pushes;
            see `mrperf experiment churn`)
LOCALITY:   --locality enables locality-aware work stealing (same-cluster
            steals preferred, WAN only when justified); implies --stealing
HEDGE:      --hedge RATE (0 ≤ RATE < 1) plans against an expected reducer
            failure rate: per-reducer capacity discounting, a replay-cost
            term in the shuffle/reduce times, and a uniform insurance mix
            of the key split. RATE=0 (default) is bit-identical to the
            unhedged optimizer. `experiment churn --profiles all` runs the
            full dynamics-profile × execution-mode matrix with a hedged row
THREADS:    --threads N (N ≥ 1, default 1) solves the fluid network's dirty
            components on N OS threads. Metrics are bit-identical for every
            thread count — the knob trades wall time only, never results
BENCH:      quick perf suite (solver + optimizer scale paths); --json DIR
            writes one BENCH_<name>.json per result for trend tracking, plus
            BENCH_hot_path_counters.json (simplex iterations/refactorizations
            and fluid re-solve counters from a fixed probe job)
TENANCY:    `mrperf experiment tenancy` runs multi-tenant job streams over ONE
            shared fluid network: --loads sweeps offered load ρ (Poisson
            arrivals at λ = ρ / S, S calibrated by a standalone run) across
            --policies (fifo | fair-share | deadline); --arrivals
            poisson:RATE[:SEED] | periodic:RATE | trace:t1,t2,... replaces the
            sweep; every job's deadline is arrival + --slack × S, and the
            goodput column counts deadline hits. --dynamics injects a
            platform-wide trace every concurrent job observes
RECOVERY:   --checkpoint-every T snapshots the run every T virtual seconds
            (in memory, or to --checkpoint-path FILE); --crash-at T2 kills the
            simulated coordinator at T2 and auto-resumes from the latest
            checkpoint (requires --checkpoint-every) — the resumed run is
            bit-identical to the uninterrupted one; --resume-from FILE starts
            from a snapshot file (same topology/plan/app/config required);
            --max-attempts N (≥ 1, default 4) bounds retries per map split /
            key range before the work is dead-lettered (the run then reports
            a partial outcome with exact dead-letter byte accounting)
RESILIENCE: `mrperf experiment resilience` sweeps dynamics profile × retry
            budget × coordinator-crash time on the churn workload and checks
            crash/resume bit-identity plus dead-letter byte conservation
            ([--gen KIND:NODES[:SEED]] picks the platform)
REPLAN:     --replan on-event re-solves the plan at every dynamics-event
            boundary against the live effective platform (warm-started LPs,
            failed nodes discounted, refreshed sources re-priced) and migrates
            only unstarted work; --replan every:T re-solves on a fixed
            virtual-time cadence instead. off (default) is bit-identical to
            the static engine. Selects the replan scheduler family — cannot
            be combined with --speculation/--stealing/--locality.
            `mrperf experiment replan` compares static | adversary-hedged |
            replan | dynamic across every dynamics profile
ADVERSARY:  `mrperf experiment adversary` searches (seeded restarts + greedy
            refinement, deterministic given --seed) for the worst-case trace
            within a perturbation budget: --budget K bounds the node outages
            (default: the seeded failures profile's own outage count), and the
            report compares the found trace against the seeded failures
            profile for every execution mode (plan-local | dynamic |
            dynamic+locality | hedged)

Full reference: docs/CLI.md — paper-figure mapping: rust/src/experiments/README.md
";

fn parse_env(name: &str) -> Option<EnvKind> {
    EnvKind::all().into_iter().find(|k| k.label() == name)
}

fn parse_barriers(s: &str) -> Option<BarrierConfig> {
    let parts: Vec<&str> = s.split('-').collect();
    if parts.len() != 3 {
        return None;
    }
    let one = |p: &str| match p {
        "G" | "g" => Some(Barrier::Global),
        "L" | "l" => Some(Barrier::Local),
        "P" | "p" => Some(Barrier::Pipelined),
        _ => None,
    };
    Some(BarrierConfig::new(one(parts[0])?, one(parts[1])?, one(parts[2])?))
}


/// Resolve the platform: `--topology FILE` (custom .topo description)
/// takes precedence over `--gen KIND:NODES[:SEED]` (generated platform),
/// which takes precedence over `--env NAME`.
fn resolve_topology(args: &cli::Args) -> Result<mrperf::platform::Topology, String> {
    if let Some(path) = args.get("topology") {
        return mrperf::platform::load_topology(std::path::Path::new(path))
            .map_err(|e| format!("{e:#}"));
    }
    if let Some(spec) = args.get("gen") {
        let mut gen_cfg = mrperf::platform::scale::parse_spec_config(spec)?;
        let skew = args.get_f64("skew", 0.0).map_err(|e| e.to_string())?;
        if skew != 0.0 {
            if !(skew > 0.0 && skew.is_finite()) {
                return Err(format!("--skew must be a finite value ≥ 0, got {skew}"));
            }
            gen_cfg = gen_cfg.skew(skew);
        }
        return Ok(mrperf::platform::scale::generate(&gen_cfg));
    }
    match parse_env(args.get_or("env", "8-dc-global")) {
        Some(e) => Ok(build_env(e)),
        None => Err("unknown env; see `mrperf list`".into()),
    }
}

fn make_plan(
    optimizer: &str,
    topo: &mrperf::platform::Topology,
    app: AppModel,
    cfg: BarrierConfig,
    hedge: f64,
) -> Result<Plan, String> {
    if hedge != 0.0 {
        mrperf::optimizer::hedged::validate_hedge(hedge).map_err(|e| format!("--hedge: {e}"))?;
        if optimizer == "e2e-multi" {
            // The first-class hedged path (discounted platform + uniform
            // insurance mix + final x-step).
            return Ok(mrperf::optimizer::FailureAwareOptimizer::new(hedge)
                .optimize(topo, app, cfg));
        }
        // Any other optimizer hedges by planning against the discounted
        // platform (no insurance mix — that is specific to the
        // alternating-LP wrapper).
        let ht = mrperf::optimizer::hedged::discount_topology(topo, hedge);
        return make_plan(optimizer, &ht, app, cfg, 0.0);
    }
    Ok(match optimizer {
        "uniform" => Uniform.optimize(topo, app, cfg),
        "myopic" => Myopic.optimize(topo, app, cfg),
        "e2e-push" => E2ePush.optimize(topo, app, cfg),
        "e2e-shuffle" => E2eShuffle.optimize(topo, app, cfg),
        "e2e-multi" => AlternatingLp::default().optimize(topo, app, cfg),
        "gradient" => {
            mrperf::optimizer::GradientOptimizer::default().optimize(topo, app, cfg)
        }
        "artifact" => {
            let planner = mrperf::runtime::ArtifactPlanner::load(
                topo.n_sources(),
                topo.n_mappers(),
                topo.n_reducers(),
            )
            .map_err(|e| format!("loading artifacts: {e}"))?;
            planner
                .optimize(topo, app, cfg)
                .map_err(|e| format!("artifact planner: {e}"))?
        }
        other => return Err(format!("unknown optimizer '{other}'")),
    })
}

fn cmd_experiment(args: &cli::Args) -> ExitCode {
    let results_dir = PathBuf::from(args.get_or("results", "results"));
    let Some(id) = args.positional.get(1) else {
        eprintln!("experiment id required; see `mrperf list`");
        return ExitCode::FAILURE;
    };
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL.to_vec()
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        println!("\n### experiment {id}\n");
        // `churn`, `adversary`, `tenancy`, `resilience` and `replan`
        // take CLI-configurable knobs; everything else is fixed.
        let ok = if id == "adversary" {
            let gen_spec = args.get_or("gen", experiments::adversary::DEFAULT_GEN);
            let knobs = (|| -> Result<(u64, Option<usize>, usize, f64), String> {
                let seed = args
                    .get_u64("seed", experiments::adversary::DEFAULT_SEED)
                    .map_err(|e| e.to_string())?;
                let budget = match args.get("budget") {
                    None => None,
                    Some(_) => Some(args.get_usize("budget", 0).map_err(|e| e.to_string())?),
                };
                let restarts = args
                    .get_usize("restarts", experiments::adversary::DEFAULT_RESTARTS)
                    .map_err(|e| e.to_string())?;
                let hedge = args
                    .get_f64("hedge", experiments::churn::DEFAULT_HEDGE)
                    .map_err(|e| e.to_string())?;
                Ok((seed, budget, restarts, hedge))
            })();
            let tables = knobs.and_then(|(seed, budget, restarts, hedge)| {
                experiments::adversary::run_with(gen_spec, seed, budget, restarts, hedge)
            });
            match tables {
                Ok(tables) => {
                    experiments::report_tables(id, &tables, &results_dir);
                    true
                }
                Err(e) => {
                    eprintln!("adversary: {e}");
                    return ExitCode::FAILURE;
                }
            }
        } else if id == "churn" {
            let gen_spec = args.get_or("gen", experiments::churn::DEFAULT_GEN);
            let dyn_spec = args.get_or("dynamics", experiments::churn::DEFAULT_DYNAMICS);
            let tables = match args.get("profiles") {
                Some("all") => {
                    let hedge = match args.get_f64("hedge", experiments::churn::DEFAULT_HEDGE)
                    {
                        Ok(h) => h,
                        Err(e) => {
                            eprintln!("{e}");
                            return ExitCode::FAILURE;
                        }
                    };
                    experiments::churn::run_matrix_with(gen_spec, dyn_spec, hedge)
                }
                Some(other) => Err(format!("--profiles only accepts 'all', got '{other}'")),
                None if args.get("hedge").is_some() => Err(
                    "--hedge only applies to the matrix form; add --profiles all \
                     (the single-profile churn table has no hedged row)"
                    .to_string(),
                ),
                None => experiments::churn::run_with(gen_spec, dyn_spec),
            };
            match tables {
                Ok(tables) => {
                    experiments::report_tables(id, &tables, &results_dir);
                    true
                }
                Err(e) => {
                    eprintln!("churn: {e}");
                    return ExitCode::FAILURE;
                }
            }
        } else if id == "tenancy" {
            let gen_spec = args.get_or("gen", experiments::tenancy::DEFAULT_GEN);
            let knobs = (|| -> Result<(usize, f64, usize), String> {
                let jobs = args
                    .get_usize("jobs", experiments::tenancy::DEFAULT_JOBS)
                    .map_err(|e| e.to_string())?;
                let slack = args
                    .get_f64("slack", experiments::tenancy::DEFAULT_SLACK)
                    .map_err(|e| e.to_string())?;
                let threads = args.get_usize("threads", 1).map_err(|e| e.to_string())?;
                Ok((jobs, slack, threads))
            })();
            let tables = knobs.and_then(|(jobs, slack, threads)| {
                experiments::tenancy::run_with(
                    gen_spec,
                    args.get("arrivals"),
                    jobs,
                    args.get_or("loads", experiments::tenancy::DEFAULT_LOADS),
                    args.get_or("policies", experiments::tenancy::DEFAULT_POLICIES),
                    slack,
                    args.get("dynamics"),
                    threads,
                )
            });
            match tables {
                Ok(tables) => {
                    experiments::report_tables(id, &tables, &results_dir);
                    true
                }
                Err(e) => {
                    eprintln!("tenancy: {e}");
                    return ExitCode::FAILURE;
                }
            }
        } else if id == "replan" {
            let gen_spec = args.get_or("gen", experiments::replan::DEFAULT_GEN);
            let dyn_spec = args.get_or("dynamics", experiments::replan::DEFAULT_DYNAMICS);
            match experiments::replan::run_with(gen_spec, dyn_spec) {
                Ok(tables) => {
                    experiments::report_tables(id, &tables, &results_dir);
                    true
                }
                Err(e) => {
                    eprintln!("replan: {e}");
                    return ExitCode::FAILURE;
                }
            }
        } else if id == "resilience" {
            let gen_spec = args.get_or("gen", experiments::resilience::DEFAULT_GEN);
            match experiments::resilience::run_with(gen_spec) {
                Ok(tables) => {
                    experiments::report_tables(id, &tables, &results_dir);
                    true
                }
                Err(e) => {
                    eprintln!("resilience: {e}");
                    return ExitCode::FAILURE;
                }
            }
        } else {
            experiments::run_and_report(id, &results_dir)
        };
        if !ok {
            eprintln!("unknown experiment '{id}'");
            return ExitCode::FAILURE;
        }
    }
    ExitCode::SUCCESS
}

fn cmd_plan(args: &cli::Args) -> ExitCode {
    let topo = match resolve_topology(args) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}");
            return ExitCode::FAILURE;
        }
    };
    let alpha = args.get_f64("alpha", 1.0).unwrap_or(1.0);
    let cfg = match parse_barriers(args.get_or("barriers", "G-P-L")) {
        Some(c) => c,
        None => {
            eprintln!("bad --barriers (e.g. G-P-L)");
            return ExitCode::FAILURE;
        }
    };
    let optimizer = args.get_or("optimizer", "e2e-multi");
    let hedge = match args.get_f64("hedge", 0.0) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("{e}");
            return ExitCode::FAILURE;
        }
    };
    let app = AppModel::new(alpha);
    let plan = match make_plan(optimizer, &topo, app, cfg, hedge) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return ExitCode::FAILURE;
        }
    };
    let tl = evaluate(&topo, app, cfg, &plan);
    let b = tl.breakdown();

    println!(
        "environment: {}  α={alpha}  barriers={}  optimizer={optimizer}\n",
        topo.name,
        cfg.label()
    );
    let mut headers: Vec<String> = vec!["src\\map".into()];
    headers.extend((0..topo.n_mappers()).map(|j| format!("m{j}")));
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut xt = Table::new(
        "push plan x_ij (fraction of source i's data to mapper j)",
        &header_refs,
    )
    .label_first();
    for i in 0..topo.n_sources() {
        let mut row = vec![format!("s{i}")];
        for j in 0..topo.n_mappers() {
            row.push(format!("{:.3}", plan.x.get(i, j)));
        }
        xt.add_row(row);
    }
    println!("{}", xt.render());
    let y_str: Vec<String> = plan.y.iter().map(|v| format!("{v:.3}")).collect();
    println!("shuffle plan y = [{}]", y_str.join(", "));
    println!(
        "\npredicted: push {} + map {} + shuffle {} + reduce {} = makespan {} s",
        fmt_secs(b.push),
        fmt_secs(b.map),
        fmt_secs(b.shuffle),
        fmt_secs(b.reduce),
        fmt_secs(tl.makespan)
    );
    let uni = evaluate(
        &topo,
        app,
        cfg,
        &Plan::uniform(topo.n_sources(), topo.n_mappers(), topo.n_reducers()),
    );
    println!(
        "uniform baseline: {} s  (reduction {:.1}%)",
        fmt_secs(uni.makespan),
        (1.0 - tl.makespan / uni.makespan) * 100.0
    );
    ExitCode::SUCCESS
}

fn cmd_run(args: &cli::Args) -> ExitCode {
    let topo = match resolve_topology(args) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}");
            return ExitCode::FAILURE;
        }
    };
    let app_name = args.get_or("app", "synthetic");
    let alpha_arg = args.get_f64("alpha", 1.0).unwrap_or(1.0);
    let bytes = args.get_usize("bytes-per-source", 1 << 21).unwrap_or(1 << 21);
    let optimizer = args.get_or("optimizer", "e2e-multi");
    let n = topo.n_sources();

    use mrperf::experiments::fig9to12::AppKind;
    let (app, inputs, alpha): (Box<dyn mrperf::engine::MapReduceApp>, _, f64) = match app_name {
        "wordcount" => {
            let k = AppKind::WordCount;
            (k.app(), k.inputs(n, bytes, 7), k.profiled_alpha())
        }
        "sessionize" => {
            let k = AppKind::Sessionize;
            (k.app(), k.inputs(n, bytes, 7), k.profiled_alpha())
        }
        "inverted-index" => {
            let k = AppKind::InvertedIndex;
            (k.app(), k.inputs(n, bytes, 7), k.profiled_alpha())
        }
        "synthetic" => (
            Box::new(mrperf::apps::SyntheticApp::new(alpha_arg)),
            mrperf::experiments::common::synthetic_inputs(n, bytes, 7),
            alpha_arg,
        ),
        other => {
            eprintln!("unknown app '{other}'");
            return ExitCode::FAILURE;
        }
    };

    let cfg =
        parse_barriers(args.get_or("barriers", "G-P-L")).unwrap_or(BarrierConfig::HADOOP);
    let hedge = match args.get_f64("hedge", 0.0) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("{e}");
            return ExitCode::FAILURE;
        }
    };
    let plan = match make_plan(optimizer, &topo, AppModel::new(alpha), cfg, hedge) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return ExitCode::FAILURE;
        }
    };
    let threads = match args.get_usize("threads", 1) {
        Ok(0) => {
            eprintln!(
                "invalid value '0' for --threads (need at least one solver thread)"
            );
            return ExitCode::FAILURE;
        }
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}");
            return ExitCode::FAILURE;
        }
    };
    let max_attempts = match args.get_usize("max-attempts", 4) {
        Ok(0) => {
            eprintln!(
                "invalid value '0' for --max-attempts (must be >= 1: an unbounded \
                 retry budget is not expressible — work needs a finite budget to \
                 ever reach the dead-letter queue)"
            );
            return ExitCode::FAILURE;
        }
        Ok(n) => n as u32,
        Err(e) => {
            eprintln!("{e}");
            return ExitCode::FAILURE;
        }
    };
    let stealing = args.flag("stealing") || args.flag("locality");
    let replan = match args.get("replan") {
        None => mrperf::engine::ReplanPolicy::Off,
        Some(spec) => match mrperf::engine::ReplanPolicy::parse(spec) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("{e}");
                return ExitCode::FAILURE;
            }
        },
    };
    if replan.enabled() && (args.flag("speculation") || stealing) {
        eprintln!(
            "--replan cannot be combined with --speculation/--stealing/--locality: \
             the replan family re-homes work by re-solving the plan, and mixing it \
             with runtime adaptivity would blur what each mechanism contributes \
             (run `mrperf experiment replan` to compare them side by side)"
        );
        return ExitCode::FAILURE;
    }
    let mut jc = JobConfig {
        barriers: cfg,
        speculation: args.flag("speculation"),
        stealing,
        locality_stealing: args.flag("locality"),
        local_only: !(args.flag("speculation") || stealing),
        replication: args.get_usize("replication", 1).unwrap_or(1),
        threads,
        max_attempts,
        replan,
        replan_alpha: alpha,
        ..JobConfig::default()
    };
    if let Some(spec) = args.get("dynamics") {
        let (profile, dseed) = match mrperf::engine::dynamics::parse_spec(spec) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("{e}");
                return ExitCode::FAILURE;
            }
        };
        // Horizon: the model-predicted makespan on the volume actually
        // simulated (topo.d carries the nominal platform volume, which
        // can be orders of magnitude above the synthetic inputs).
        let mean_bytes = inputs
            .iter()
            .map(|v| mrperf::engine::job::batch_size(v) as f64)
            .sum::<f64>()
            / n as f64;
        let href = topo.clone().with_uniform_data(mean_bytes.max(1.0));
        let horizon = evaluate(&href, AppModel::new(alpha), cfg, &plan).makespan.max(1e-9);
        let trace = mrperf::engine::ScenarioTrace::generate(
            profile,
            dseed,
            &mrperf::engine::TraceShape::of(&topo, horizon),
        );
        println!(
            "dynamics: {} — {} events over a {:.3} s horizon",
            trace.label(),
            trace.len(),
            horizon
        );
        jc = jc.with_dynamics(trace);
    }
    println!(
        "running {app_name} (α≈{alpha:.2}) on {} with {optimizer} plan, barriers {} …",
        topo.name,
        cfg.label()
    );
    let recovery = ["checkpoint-every", "crash-at", "checkpoint-path", "resume-from"]
        .iter()
        .any(|k| args.get(k).is_some());
    let res = if recovery {
        let opt_f64 = |key: &str| -> Result<Option<f64>, String> {
            match args.get(key) {
                None => Ok(None),
                Some(_) => {
                    Ok(Some(args.get_f64(key, 0.0).map_err(|e| e.to_string())?))
                }
            }
        };
        let built = (|| -> Result<RecoveryOpts, String> {
            let resume_from = match args.get("resume-from") {
                None => None,
                Some(path) => Some(
                    std::fs::read_to_string(path)
                        .map_err(|e| format!("cannot read snapshot `{path}`: {e}"))?,
                ),
            };
            Ok(RecoveryOpts {
                checkpoint_every: opt_f64("checkpoint-every")?,
                crash_at: opt_f64("crash-at")?,
                checkpoint_path: args.get("checkpoint-path").map(String::from),
                resume_from,
            })
        })();
        let run = built.and_then(|opts| {
            run_job_with_recovery(&topo, &plan, app.as_ref(), &jc, &inputs, &opts)
        });
        match run {
            Ok(r) => r,
            Err(e) => {
                eprintln!("{e}");
                return ExitCode::FAILURE;
            }
        }
    } else {
        // No recovery flag: the plain driver, bit-identical to every
        // pre-checkpoint release.
        run_job(&topo, &plan, app.as_ref(), &jc, &inputs)
    };
    let m = &res.metrics;
    println!("makespan          {:>10} s (virtual time)", fmt_secs(m.makespan));
    println!("  push end        {:>10} s", fmt_secs(m.push_end));
    println!("  map end         {:>10} s", fmt_secs(m.map_end));
    println!("  shuffle end     {:>10} s", fmt_secs(m.shuffle_end));
    println!(
        "map tasks         {:>10}   reduce tasks {}",
        m.n_map_tasks, m.n_reduce_tasks
    );
    println!(
        "records           {:>10} in / {} intermediate / {} out",
        m.input_records, m.intermediate_records, m.output_records
    );
    println!(
        "bytes             {:>10.1} MB pushed / {:.1} MB shuffled / {:.1} MB output",
        m.push_bytes / 1e6,
        m.shuffle_bytes / 1e6,
        m.output_bytes / 1e6
    );
    println!(
        "fluid solver      {:>10} re-solves / {} component resources re-filled \
         ({} thread{})",
        m.fluid_resolves,
        m.fluid_resources_touched,
        threads,
        if threads == 1 { "" } else { "s" }
    );
    if m.spec_launched > 0 || m.stolen > 0 {
        println!(
            "scheduling        {:>10} speculative ({} won), {} stolen",
            m.spec_launched, m.spec_won, m.stolen
        );
    }
    if m.dyn_events > 0 {
        println!(
            "churn             {:>10} trace events, {} failures, {} tasks requeued",
            m.dyn_events, m.failures_injected, m.tasks_requeued
        );
    }
    if m.sources_refreshed > 0 {
        println!(
            "staleness         {:>10} source refreshes, {:.1} KB re-pushed \
             (delivered == pushed: {})",
            m.sources_refreshed,
            m.push_bytes_repushed / 1e3,
            m.push_bytes_delivered == m.push_bytes
        );
    }
    if m.replans > 0 || m.replans_skipped > 0 {
        println!(
            "replanning        {:>10} re-solves accepted ({} declined), \
             {} splits + {} ranges migrated",
            m.replans, m.replans_skipped, m.replan_migrated_splits, m.replan_migrated_ranges
        );
    }
    if m.coordinator_restarts > 0 {
        println!(
            "recovery          {:>10} coordinator restart{} survived",
            m.coordinator_restarts,
            if m.coordinator_restarts == 1 { "" } else { "s" }
        );
    }
    match res.outcome {
        mrperf::engine::executor::JobOutcome::Complete => {}
        mrperf::engine::executor::JobOutcome::PartialWithDlq => {
            println!(
                "outcome           {:>10}   {} split(s) + {} range(s) dead-lettered, \
                 {:.1} KB (delivered + dead-lettered == shuffled: {})",
                "PARTIAL",
                m.splits_dead_lettered,
                m.ranges_dead_lettered,
                m.dlq_bytes / 1e3,
                (m.shuffle_bytes_delivered + m.dlq_bytes).to_bits()
                    == m.shuffle_bytes.to_bits()
            );
        }
    }
    ExitCode::SUCCESS
}

/// Quick, JSON-recordable perf suite over the scale-critical paths. The
/// heavyweight acceptance benches (≥10× assertion, full sweep) live in
/// `cargo bench`; this subcommand is the fast trend-tracker: run it after
/// a perf-relevant change with `--json DIR` and commit/diff the
/// `BENCH_<name>.json` files.
fn cmd_bench(args: &cli::Args) -> ExitCode {
    use mrperf::model::makespan::makespan;
    use mrperf::optimizer::lp_build::{build_lp_x, Objective};
    use mrperf::optimizer::perf::{add_scale_ab_benches, add_scale_headline_benches};
    use mrperf::platform::scale::{generate_kind, ScaleKind};
    use mrperf::util::bench::{black_box, BenchConfig, BenchSuite};
    use std::time::Duration;

    let filter = args.get("filter").map(String::from);
    let bench_cfg = BenchConfig {
        warmup: Duration::from_millis(50),
        min_iters: 1,
        max_iters: 50,
        target_time: Duration::from_millis(300),
    };
    let mut suite = BenchSuite::with_filter(bench_cfg, filter);
    let app = AppModel::new(1.0);
    let bc = BarrierConfig::HADOOP;
    // Bracket the whole suite with the solver's hot-path counters so the
    // JSON snapshot below tracks algorithmic work (pivots + bound flips,
    // refactorizations), not just wall time.
    mrperf::solver::reset_hot_path_counters();

    // Model hot path (reference point for the optimizer numbers).
    let t8 = build_env(EnvKind::Global8);
    let plan8 = Plan::uniform(8, 8, 8);
    suite.bench("model/makespan_eval_8x8x8", || {
        black_box(makespan(&t8, app, bc, &plan8))
    });

    // Solver A/B: the same 64-node x-LP through the dense tableau and the
    // sparse revised simplex.
    let t64 = generate_kind(ScaleKind::HierarchicalWan, 64, 7);
    let y64 = vec![1.0 / t64.n_reducers() as f64; t64.n_reducers()];
    let (lp64, _) = build_lp_x(&t64, app, bc, &y64, Objective::Makespan);
    suite.bench("solver/lp_x_64node_dense_tableau", || {
        black_box(mrperf::solver::simplex::solve(&lp64))
    });
    suite.bench("solver/lp_x_64node_sparse_revised", || {
        black_box(mrperf::solver::revised::solve(&lp64))
    });

    // Optimizer A/B at 32 nodes (shared scaffolding with `cargo bench`,
    // which runs the asserting 64-node variant — the pre-PR baseline is
    // too slow at 64 for a quick CLI suite), plus the 256-node headline.
    let _ab = add_scale_ab_benches(&mut suite, 32);
    let _headline = add_scale_headline_benches(&mut suite);

    suite.report();
    if let Some(dir) = args.get("json") {
        let dir = PathBuf::from(dir);
        match suite.write_json(&dir) {
            Ok(paths) => {
                println!("\nwrote {} BENCH_*.json files to {}", paths.len(), dir.display());
            }
            Err(e) => {
                eprintln!("writing bench JSON to {}: {e}", dir.display());
                return ExitCode::FAILURE;
            }
        }
        // Counter snapshot: solver work accumulated across the suite
        // above, plus the fluid engine's counters from one fixed probe
        // job (both deterministic, so diffs track algorithm changes).
        let (solver_iterations, solver_refactorizations) =
            mrperf::solver::hot_path_counters();
        let probe_topo = generate_kind(ScaleKind::HierarchicalWan, 64, 7);
        let probe_plan = Plan::local_push(&probe_topo);
        let probe_inputs = mrperf::experiments::common::synthetic_inputs(
            probe_topo.n_sources(),
            2_000,
            0x5CA1E,
        );
        let probe = run_job(
            &probe_topo,
            &probe_plan,
            &mrperf::apps::SyntheticApp::new(1.0),
            &JobConfig::default(),
            &probe_inputs,
        );
        let counters = format!(
            "{{\n  \"name\": \"hot_path_counters\",\n  \
             \"solver_iterations\": {solver_iterations},\n  \
             \"solver_refactorizations\": {solver_refactorizations},\n  \
             \"fluid_probe\": \"hier-wan:64 local-push synthetic run\",\n  \
             \"fluid_resolves\": {},\n  \
             \"fluid_resources_touched\": {}\n}}\n",
            probe.metrics.fluid_resolves, probe.metrics.fluid_resources_touched,
        );
        let path = dir.join("BENCH_hot_path_counters.json");
        if let Err(e) = std::fs::write(&path, counters) {
            eprintln!("writing {}: {e}", path.display());
            return ExitCode::FAILURE;
        }
        println!("wrote {}", path.display());
    }
    ExitCode::SUCCESS
}

fn cmd_validate() -> ExitCode {
    println!("running the Fig 4 validation grid (48 model-vs-engine cells)…\n");
    let res = experiments::fig4::run();
    for t in &res.tables[1..] {
        println!("{}", t.render());
    }
    if res.r2 > 0.8 {
        println!("validation PASSED: R² = {:.4} (paper: 0.9412)", res.r2);
        ExitCode::SUCCESS
    } else {
        println!("validation FAILED: R² = {:.4}", res.r2);
        ExitCode::FAILURE
    }
}

fn cmd_list() -> ExitCode {
    println!("experiments: {}", experiments::ALL.join(", "));
    let envs: Vec<&str> = EnvKind::all().iter().map(|k| k.label()).collect();
    println!("environments: {}", envs.join(", "));
    let kinds: Vec<&str> = mrperf::platform::ScaleKind::all()
        .iter()
        .map(|k| k.label())
        .collect();
    println!("generated topologies (--gen KIND:NODES[:SEED]): {}", kinds.join(", "));
    println!("apps: wordcount, sessionize, inverted-index, synthetic");
    println!(
        "optimizers: uniform, myopic, e2e-push, e2e-shuffle, e2e-multi, gradient, artifact \
         (any of them + --hedge RATE plans against an expected reducer failure rate)"
    );
    let profiles: Vec<&str> = mrperf::engine::DynProfile::all()
        .iter()
        .map(|p| p.label())
        .collect();
    println!("dynamics profiles (--dynamics PROFILE[:SEED]): {}", profiles.join(", "));
    ExitCode::SUCCESS
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli::parse(&argv, &["verbose", "speculation", "stealing", "locality"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n\n{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    if args.flag("verbose") {
        logger::set_level(Level::Debug);
    }
    match args.positional.first().map(String::as_str) {
        Some("experiment") => cmd_experiment(&args),
        Some("plan") => cmd_plan(&args),
        Some("run") => cmd_run(&args),
        Some("bench") => cmd_bench(&args),
        Some("validate") => cmd_validate(),
        Some("list") => cmd_list(),
        _ => {
            print!("{USAGE}");
            ExitCode::FAILURE
        }
    }
}
