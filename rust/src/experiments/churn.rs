//! Churn experiment: optimized-plan degradation vs. dynamic-scheduler
//! recovery under injected platform dynamics.
//!
//! For each generated topology size the pipeline is: optimize an
//! end-to-end plan (`e2e-multi`), simulate it **statically**, then
//! simulate it again under a seeded [`ScenarioTrace`] — once with the
//! statically enforced [`PlanLocalScheduler`] (the paper's "our
//! optimization" execution mode) and once with the locality-aware
//! [`DynamicScheduler`] (stealing + speculation). The static plan-local
//! makespan doubles as the trace horizon, so every row of a cell sees
//! the *same* absolute event times and the whole table is deterministic
//! given `(generator seed, trace seed)`.
//!
//! The headline comparison: under failure-bearing profiles (`burst`,
//! `failures`, `churn`) the plan-local row stalls until dead nodes
//! recover, while the dynamic row steals the stranded splits — mostly
//! within the cluster, over the WAN only when justified — and degrades
//! far less.
//!
//! `--profiles all` switches to the **matrix** form: every dynamics
//! profile × {plan-local, dynamic, dynamic+locality, hedged} at the
//! requested size, tabulating makespan degradation, replay bytes and
//! recovery counters. The `hedged` row executes a
//! [`FailureAwareOptimizer`] plan (`--hedge RATE`) under the *same*
//! strict plan-local enforcement as the first row — isolating what
//! failure-aware *planning* buys without any runtime adaptivity — and
//! under a failure-bearing trace it beats the unhedged plan-local row
//! because far less key-range mass strands on the dead reducers. The
//! matrix includes the `staleness` profile (sources refreshing data
//! mid-push): its `refresh` / `repush (KB)` columns account the re-sent
//! push traffic, conserved exactly (`push_bytes_delivered ==
//! push_bytes` is asserted per cell).
//!
//! [`DynamicScheduler`]: crate::engine::scheduler::DynamicScheduler
//! [`PlanLocalScheduler`]: crate::engine::scheduler::PlanLocalScheduler
//! [`FailureAwareOptimizer`]: crate::optimizer::FailureAwareOptimizer

use crate::apps::SyntheticApp;
use crate::engine::dynamics::{self, DynProfile, ScenarioTrace, TraceShape};
use crate::engine::job::{batch_size, JobConfig, Record};
use crate::engine::run_job;
use crate::experiments::common::synthetic_inputs;
use crate::model::barrier::BarrierConfig;
use crate::model::makespan::AppModel;
use crate::model::plan::Plan;
use crate::experiments::scale::SWEEP_NODES;
use crate::optimizer::{AlternatingLp, FailureAwareOptimizer, PlanOptimizer};
use crate::platform::scale::{generate, parse_spec_config, ScaleConfig};
use crate::platform::{ScaleKind, Topology};
use crate::util::table::Table;

/// Defaults for `mrperf experiment churn` (and `experiment all`).
pub const DEFAULT_GEN: &str = "hier-wan:256";
pub const DEFAULT_DYNAMICS: &str = "burst:7";

/// Default hedge rate for the matrix's `hedged` row when `--hedge` is
/// not given (a 5% expected reducer unavailability).
pub const DEFAULT_HEDGE: f64 = 0.05;

/// Input volume per source: larger than the scale sweep's so the map
/// phase spans enough of the run for mid-run failures to matter.
pub const CHURN_BYTES_PER_SOURCE: usize = 4_000;

/// Map compute-cost factor for the churn workload (§3.2 heterogeneity
/// emulation): makes the job compute-bound enough that the map phase
/// spans a sizeable fraction of the run — a mid-run outage then almost
/// surely intersects it, which is the scenario the experiment exists to
/// show (failures during a WAN-bound push would only gate placement).
pub const CHURN_MAP_COST: f64 = 25.0;

/// One (size, scheduler) comparison under one trace.
#[derive(Debug, Clone)]
pub struct ChurnCell {
    pub kind: ScaleKind,
    pub nodes: usize,
    pub scheduler: &'static str,
    /// Makespan with no dynamics (the baseline for degradation).
    pub static_makespan: f64,
    /// Makespan under the injected trace.
    pub churn_makespan: f64,
    pub dyn_events: usize,
    pub failures: usize,
    pub requeued: usize,
    pub stolen: usize,
    pub spec_launched: usize,
    pub reducers_failed: usize,
    pub ranges_reassigned: usize,
    pub replay_bytes: f64,
}

impl ChurnCell {
    /// Relative makespan degradation under churn.
    pub fn degradation(&self) -> f64 {
        self.churn_makespan / self.static_makespan - 1.0
    }
}

/// The two execution modes compared per cell.
fn sched_configs() -> [(&'static str, JobConfig); 2] {
    [
        ("plan-local", JobConfig::optimized()),
        ("dynamic+locality", JobConfig::dynamic_locality()),
    ]
}

/// Sizes swept for a `--gen kind:nodes[:seed]` spec: every standard
/// sweep size below the requested node count, plus the request itself.
fn sweep_sizes(max_nodes: usize) -> Vec<usize> {
    let mut sizes: Vec<usize> =
        SWEEP_NODES.iter().cloned().filter(|&n| n < max_nodes).collect();
    sizes.push(max_nodes);
    sizes
}

/// Run the churn comparison; deterministic given the specs.
pub fn run_cells(gen_spec: &str, dyn_spec: &str) -> Result<Vec<ChurnCell>, String> {
    let base = parse_spec_config(gen_spec)?;
    let (profile, trace_seed) = dynamics::parse_spec(dyn_spec)?;
    run_cells_at(&base, profile, trace_seed, &sweep_sizes(base.nodes))
}

/// Shared per-size setup — the single-profile sweep, the
/// `--profiles all` matrix *and* the adversary experiment build their
/// cells from exactly this, so their `plan-local` rows are the same
/// scenario and the adversary's "vs seeded failures" comparison is
/// apples-to-apples.
pub(crate) struct CellSetup {
    pub(crate) topo: Topology,
    pub(crate) inputs: Vec<Vec<Record>>,
    /// The unhedged end-to-end plan.
    pub(crate) plan: Plan,
    pub(crate) sapp: SyntheticApp,
    pub(crate) app: AppModel,
    pub(crate) bc: BarrierConfig,
}

pub(crate) fn cell_setup(base: &ScaleConfig, nodes: usize) -> CellSetup {
    let app = AppModel::new(1.0);
    let bc = BarrierConfig::HADOOP;
    let gen = generate(&ScaleConfig::new(base.kind, nodes).seed(base.seed));
    let inputs = synthetic_inputs(gen.n_sources(), CHURN_BYTES_PER_SOURCE, 0x5CA1E);
    // Evaluate the model (and thus the optimizer) on the volume the
    // engine will actually simulate (the fig4 idiom).
    let mean_bytes =
        inputs.iter().map(|v| batch_size(v) as f64).sum::<f64>() / gen.n_sources() as f64;
    let topo = gen.with_uniform_data(mean_bytes);
    let plan = AlternatingLp::default().optimize(&topo, app, bc);
    // α = 1 keeps the fractional-emission accumulator exact (safe to
    // reuse one instance across runs); the map-cost factor makes the
    // workload compute-bound (see CHURN_MAP_COST).
    let sapp = SyntheticApp::new(1.0).with_costs(CHURN_MAP_COST, 2.0);
    CellSetup { topo, inputs, plan, sapp, app, bc }
}

/// Inner driver over explicit sizes (tests cap the size so debug builds
/// stay quick; the experiment runs the full range).
pub fn run_cells_at(
    base: &ScaleConfig,
    profile: DynProfile,
    trace_seed: u64,
    sizes: &[usize],
) -> Result<Vec<ChurnCell>, String> {
    let mut cells = Vec::new();
    for &nodes in sizes {
        let CellSetup { topo, inputs, plan, sapp, .. } = cell_setup(base, nodes);

        // Static plan-local makespan anchors the trace horizon: every
        // scheduler row of this cell sees identical event times. The same
        // run doubles as the plan-local row's static baseline (it is
        // deterministic, so re-running it would only repeat work).
        let static_pl = run_job(&topo, &plan, &sapp, &sched_configs()[0].1, &inputs).metrics;
        let horizon = static_pl.makespan.max(1e-9);
        let trace = ScenarioTrace::generate(profile, trace_seed, &TraceShape::of(&topo, horizon));

        for (idx, (label, cfg)) in sched_configs().into_iter().enumerate() {
            let stat = if idx == 0 {
                static_pl.clone()
            } else {
                run_job(&topo, &plan, &sapp, &cfg, &inputs).metrics
            };
            let churn_cfg = cfg.clone().with_dynamics(trace.clone());
            let m = run_job(&topo, &plan, &sapp, &churn_cfg, &inputs).metrics;
            assert_eq!(
                m.output_records, m.input_records,
                "{label} lost records under churn at {nodes} nodes"
            );
            cells.push(ChurnCell {
                kind: base.kind,
                nodes,
                scheduler: label,
                static_makespan: stat.makespan,
                churn_makespan: m.makespan,
                dyn_events: m.dyn_events,
                failures: m.failures_injected,
                requeued: m.tasks_requeued,
                stolen: m.stolen,
                spec_launched: m.spec_launched,
                reducers_failed: m.reducers_failed,
                ranges_reassigned: m.reduce_ranges_reassigned,
                replay_bytes: m.reduce_bytes_replayed,
            });
        }
    }
    Ok(cells)
}

/// Render the churn table for explicit specs.
pub fn run_with(gen_spec: &str, dyn_spec: &str) -> Result<Vec<Table>, String> {
    let cells = run_cells(gen_spec, dyn_spec)?;
    let mut t = Table::new(
        format!(
            "churn: optimized plan under dynamics (--gen {gen_spec} --dynamics {dyn_spec}) — \
             plan-local enforcement vs locality-aware dynamic recovery"
        ),
        &[
            "kind",
            "nodes",
            "scheduler",
            "static (s)",
            "churn (s)",
            "degradation",
            "events",
            "failures",
            "requeued",
            "stolen",
            "spec",
            "red-fail",
            "adopted",
            "replay (KB)",
        ],
    );
    for c in &cells {
        t.add_row(vec![
            c.kind.label().to_string(),
            c.nodes.to_string(),
            c.scheduler.to_string(),
            format!("{:.4}", c.static_makespan),
            format!("{:.4}", c.churn_makespan),
            format!("{:+.1}%", c.degradation() * 100.0),
            c.dyn_events.to_string(),
            c.failures.to_string(),
            c.requeued.to_string(),
            c.stolen.to_string(),
            c.spec_launched.to_string(),
            c.reducers_failed.to_string(),
            c.ranges_reassigned.to_string(),
            format!("{:.1}", c.replay_bytes / 1e3),
        ]);
    }
    Ok(vec![t])
}

/// The `churn` experiment with its default specs (used by
/// `mrperf experiment all`).
pub fn run() -> Vec<Table> {
    run_with(DEFAULT_GEN, DEFAULT_DYNAMICS).expect("default churn specs are valid")
}

// ------------------------------------------------------ profile matrix

/// One cell of the `--profiles all` matrix.
#[derive(Debug, Clone)]
pub struct MatrixCell {
    pub profile: DynProfile,
    /// Execution mode: `plan-local` | `dynamic` | `dynamic+locality` |
    /// `hedged` (hedged plan under plan-local enforcement).
    pub mode: &'static str,
    pub static_makespan: f64,
    pub churn_makespan: f64,
    pub dyn_events: usize,
    pub failures: usize,
    pub reducers_failed: usize,
    pub requeued: usize,
    pub stolen: usize,
    pub ranges_reassigned: usize,
    pub replay_bytes: f64,
    /// Staleness counters (non-zero only under the `staleness` profile).
    pub sources_refreshed: usize,
    pub repush_bytes: f64,
}

impl MatrixCell {
    pub fn degradation(&self) -> f64 {
        self.churn_makespan / self.static_makespan - 1.0
    }
}

/// The four execution modes of the matrix. The first three run the
/// unhedged e2e plan; `hedged` runs the failure-aware plan under the same
/// strict enforcement as `plan-local`, so the pairwise comparison
/// isolates planning from runtime adaptivity.
fn matrix_modes() -> [(&'static str, bool, JobConfig); 4] {
    [
        ("plan-local", false, JobConfig::optimized()),
        ("dynamic", false, JobConfig::vanilla_hadoop()),
        ("dynamic+locality", false, JobConfig::dynamic_locality()),
        ("hedged", true, JobConfig::optimized()),
    ]
}

/// Run the full profile × mode matrix at the spec's topology size. Every
/// mode of a profile row sees the *same* trace (horizon anchored on the
/// unhedged plan-local static run), so the whole matrix is deterministic
/// given `(generator seed, trace seed, hedge)`.
pub fn run_matrix_at(
    base: &ScaleConfig,
    trace_seed: u64,
    hedge: f64,
) -> Result<Vec<MatrixCell>, String> {
    crate::optimizer::hedged::validate_hedge(hedge).map_err(|e| format!("--hedge: {e}"))?;
    let CellSetup { topo, inputs, plan, sapp, app, bc } = cell_setup(base, base.nodes);
    let hedged_plan = FailureAwareOptimizer::new(hedge).optimize(&topo, app, bc);

    // Static baselines per mode; the unhedged plan-local one anchors the
    // trace horizon for every row.
    let statics: Vec<f64> = matrix_modes()
        .iter()
        .map(|(_, hedged, cfg)| {
            let p = if *hedged { &hedged_plan } else { &plan };
            run_job(&topo, p, &sapp, cfg, &inputs).metrics.makespan
        })
        .collect();
    let horizon = statics[0].max(1e-9);

    let mut cells = Vec::new();
    for profile in DynProfile::all() {
        let trace =
            ScenarioTrace::generate(profile, trace_seed, &TraceShape::of(&topo, horizon));
        for (idx, (mode, hedged, cfg)) in matrix_modes().into_iter().enumerate() {
            let p = if hedged { &hedged_plan } else { &plan };
            let churn_cfg = cfg.with_dynamics(trace.clone());
            let m = run_job(&topo, p, &sapp, &churn_cfg, &inputs).metrics;
            assert_eq!(
                m.output_records, m.input_records,
                "{mode} lost records under {profile:?}"
            );
            assert_eq!(
                m.push_bytes_delivered, m.push_bytes,
                "{mode} lost push bytes under {profile:?}"
            );
            cells.push(MatrixCell {
                profile,
                mode,
                static_makespan: statics[idx],
                churn_makespan: m.makespan,
                dyn_events: m.dyn_events,
                failures: m.failures_injected,
                reducers_failed: m.reducers_failed,
                requeued: m.tasks_requeued,
                stolen: m.stolen,
                ranges_reassigned: m.reduce_ranges_reassigned,
                replay_bytes: m.reduce_bytes_replayed,
                sources_refreshed: m.sources_refreshed,
                repush_bytes: m.push_bytes_repushed,
            });
        }
    }
    Ok(cells)
}

/// Render the `--profiles all` matrix for explicit specs.
pub fn run_matrix_with(
    gen_spec: &str,
    dyn_spec: &str,
    hedge: f64,
) -> Result<Vec<Table>, String> {
    let base = parse_spec_config(gen_spec)?;
    // The profile part of `--dynamics` is ignored in matrix form (all
    // profiles run); the seed is honored.
    let (_, trace_seed) = dynamics::parse_spec(dyn_spec)?;
    let cells = run_matrix_at(&base, trace_seed, hedge)?;
    let mut t = Table::new(
        format!(
            "churn matrix: every dynamics profile × execution mode \
             (--gen {gen_spec} --dynamics seed {trace_seed} --hedge {hedge}) — \
             the hedged row is the failure-aware plan under plan-local enforcement"
        ),
        &[
            "profile",
            "mode",
            "static (s)",
            "churn (s)",
            "degradation",
            "events",
            "failures",
            "red-fail",
            "requeued",
            "stolen",
            "adopted",
            "replay (KB)",
            "refresh",
            "repush (KB)",
        ],
    );
    for c in &cells {
        t.add_row(vec![
            c.profile.label().to_string(),
            c.mode.to_string(),
            format!("{:.4}", c.static_makespan),
            format!("{:.4}", c.churn_makespan),
            format!("{:+.1}%", c.degradation() * 100.0),
            c.dyn_events.to_string(),
            c.failures.to_string(),
            c.reducers_failed.to_string(),
            c.requeued.to_string(),
            c.stolen.to_string(),
            c.ranges_reassigned.to_string(),
            format!("{:.1}", c.replay_bytes / 1e3),
            c.sources_refreshed.to_string(),
            format!("{:.1}", c.repush_bytes / 1e3),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Same specs → bit-identical cells (the determinism acceptance
    /// criterion, sized down so the debug-build test stays quick).
    #[test]
    fn churn_cells_are_deterministic() {
        let base = parse_spec_config("hier-wan:16").unwrap();
        let a = run_cells_at(&base, DynProfile::Burst, 7, &[16]).unwrap();
        let b = run_cells_at(&base, DynProfile::Burst, 7, &[16]).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.scheduler, y.scheduler);
            assert_eq!(x.static_makespan.to_bits(), y.static_makespan.to_bits());
            assert_eq!(x.churn_makespan.to_bits(), y.churn_makespan.to_bits());
            assert_eq!(x.replay_bytes.to_bits(), y.replay_bytes.to_bits());
            assert_eq!(
                (x.dyn_events, x.failures, x.requeued, x.stolen, x.spec_launched),
                (y.dyn_events, y.failures, y.requeued, y.stolen, y.spec_launched)
            );
            assert_eq!(
                (x.reducers_failed, x.ranges_reassigned),
                (y.reducers_failed, y.ranges_reassigned)
            );
        }
        // The trace must actually do something in this scenario.
        assert!(a.iter().all(|c| c.dyn_events > 0), "{a:?}");
    }

    /// The matrix form is deterministic and covers every profile × mode
    /// combination; under the failures profile the reducer outages must
    /// actually fire and the adaptive modes must adopt orphaned ranges.
    #[test]
    fn matrix_is_deterministic_and_covers_all_modes() {
        let base = parse_spec_config("hier-wan:16").unwrap();
        let a = run_matrix_at(&base, 7, 0.1).unwrap();
        let b = run_matrix_at(&base, 7, 0.1).unwrap();
        assert_eq!(a.len(), DynProfile::all().len() * 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.profile, x.mode), (y.profile, y.mode));
            assert_eq!(x.churn_makespan.to_bits(), y.churn_makespan.to_bits());
            assert_eq!(x.replay_bytes.to_bits(), y.replay_bytes.to_bits());
        }
        let failures: Vec<&MatrixCell> =
            a.iter().filter(|c| c.profile == DynProfile::Failures).collect();
        assert!(failures.iter().all(|c| c.reducers_failed > 0), "{failures:?}");
        assert!(
            failures
                .iter()
                .filter(|c| c.mode.starts_with("dynamic"))
                .all(|c| c.ranges_reassigned > 0),
            "adaptive modes must adopt the orphaned ranges: {failures:?}"
        );
    }

    #[test]
    fn matrix_rejects_bad_hedge() {
        let base = parse_spec_config("hier-wan:16").unwrap();
        assert!(run_matrix_at(&base, 7, 1.0).is_err());
        assert!(run_matrix_at(&base, 7, f64::NAN).is_err());
        assert!(run_matrix_with("hier-wan:16", "failures:7", -0.1).is_err());
    }

    #[test]
    fn rendered_tables_are_deterministic() {
        let a = run_with("hier-wan:16", "failures:3").unwrap();
        let b = run_with("hier-wan:16", "failures:3").unwrap();
        let ra: Vec<String> = a.iter().map(Table::render).collect();
        let rb: Vec<String> = b.iter().map(Table::render).collect();
        assert_eq!(ra, rb);
    }

    #[test]
    fn bad_specs_error_cleanly() {
        assert!(run_with("nope:16", "burst:7").is_err());
        assert!(run_with("hier-wan:16", "nope:7").is_err());
    }
}
