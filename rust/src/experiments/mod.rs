//! Regenerates every table and figure of the paper's evaluation.
//!
//! | id     | content                                         | module |
//! |--------|--------------------------------------------------|--------|
//! | table1 | measured inter-cluster bandwidths               | [`table1`] |
//! | fig4   | model validation (R², slope)                    | [`fig4`] |
//! | fig5   | uniform vs myopic vs e2e multi                  | [`fig5678`] |
//! | fig6   | single-phase vs multi-phase                     | [`fig5678`] |
//! | fig7   | barrier relaxation                              | [`fig5678`] |
//! | fig8   | environment sweep                               | [`fig5678`] |
//! | fig9   | engine: 3 apps, uniform / hadoop / optimized    | [`fig9to12`] |
//! | fig10  | dynamics atop optimized plan                    | [`fig9to12`] |
//! | fig11  | dynamics atop hadoop baseline                   | [`fig9to12`] |
//! | fig12  | wide-area replication                           | [`fig9to12`] |
//! | scale  | engine sweep on generated 16–256-node platforms | [`scale`] |
//! | churn  | plan-local vs dynamic schedulers under dynamics | [`churn`] |
//! | adversary | worst-case trace search, per-scheduler robustness | [`adversary`] |
//! | tenancy | multi-tenant job streams: load × cross-job policy | [`tenancy`] |
//! | resilience | crash/resume bit-identity, dead-letter accounting | [`resilience`] |
//! | replan | static vs adversary-hedged vs online re-planning vs dynamic | [`replan`] |
//!
//! See `rust/src/experiments/README.md` for the paper-figure ↔
//! experiment mapping and docs/CLI.md for the full flag reference.

pub mod adversary;
pub mod churn;
pub mod common;
pub mod fig4;
pub mod fig5678;
pub mod fig9to12;
pub mod replan;
pub mod resilience;
pub mod scale;
pub mod table1;
pub mod tenancy;

use crate::util::table::Table;
use std::path::Path;

/// All experiment ids, in paper order (plus the post-paper scale,
/// churn, adversary, tenancy, resilience and replan sweeps).
pub const ALL: [&str; 16] = [
    "table1", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12",
    "scale", "churn", "adversary", "tenancy", "resilience", "replan",
];

/// Run one experiment by id (`churn`, `adversary` and `tenancy` with
/// their default knobs; the CLI passes `--gen`/`--dynamics`/
/// `--arrivals`/… through [`churn::run_with`] /
/// [`adversary::run_with`] / [`tenancy::run_with`] directly).
pub fn run(id: &str) -> Option<Vec<Table>> {
    Some(match id {
        "table1" => table1::run(),
        "fig4" => fig4::run().tables,
        "fig5" => fig5678::run_fig5(),
        "fig6" => fig5678::run_fig6(),
        "fig7" => fig5678::run_fig7(),
        "fig8" => fig5678::run_fig8(),
        "fig9" => fig9to12::run_fig9(),
        "fig10" => fig9to12::run_fig10(),
        "fig11" => fig9to12::run_fig11(),
        "fig12" => fig9to12::run_fig12(),
        "scale" => scale::run(),
        "churn" => churn::run(),
        "adversary" => adversary::run(),
        "tenancy" => tenancy::run(),
        "resilience" => resilience::run(),
        "replan" => replan::run(),
        _ => return None,
    })
}

/// Print tables and persist CSVs under `results/`.
pub fn report_tables(id: &str, tables: &[Table], results_dir: &Path) {
    for (i, t) in tables.iter().enumerate() {
        println!("{}", t.render());
        let name = if tables.len() == 1 {
            id.to_string()
        } else {
            format!("{id}_{i}")
        };
        if let Err(e) = t.write_csv(results_dir, &name) {
            eprintln!("warning: could not write CSV for {id}: {e}");
        }
    }
}

/// Run, print, and persist CSVs under `results/`.
pub fn run_and_report(id: &str, results_dir: &Path) -> bool {
    match run(id) {
        Some(tables) => {
            report_tables(id, &tables, results_dir);
            true
        }
        None => false,
    }
}
