//! Replan experiment: does closing the dynamics→planner loop beat both
//! static planning and runtime adaptivity?
//!
//! Four execution modes run the churn workload under every dynamics
//! profile, all over the *same* seeded trace per profile (horizon
//! anchored on the static plan-local makespan, the churn-matrix idiom):
//!
//! * `static` — the unhedged e2e plan under strict plan-local
//!   enforcement (the paper's "our optimization" mode);
//! * `hedged-adv` — a [`FailureAwareOptimizer`] plan whose hedge rate is
//!   *derived from adversary-found traces*: the budgeted worst-case
//!   search ([`adversary::search`]) attacks the static plan, and the
//!   resulting trace's per-reducer downtime fraction
//!   ([`replan::hedge_rate_from_traces`]) becomes the robust scenario
//!   set the hedged optimizer plans against — still zero runtime
//!   adaptivity;
//! * `replan` — the same unhedged plan, re-solved online at every
//!   dynamics-event boundary ([`crate::engine::replan`],
//!   `--replan on-event`): warm-started LPs against the live effective
//!   platform, migration of unstarted work only;
//! * `dynamic` — locality-aware stealing + speculation (runtime
//!   adaptivity with no re-planning).
//!
//! The table reports per-cell makespan degradation plus the replan
//! counters (re-solves accepted/declined, splits/ranges migrated), and
//! every cell asserts the exact conservation identities
//! (`output == input` records, `push delivered == pushed`,
//! `shuffle delivered + DLQ == shuffled`).

use crate::engine::adversary::{self, PerturbBudget, SearchConfig};
use crate::engine::dynamics::{self, DynProfile, ScenarioTrace, TraceShape};
use crate::engine::job::JobConfig;
use crate::engine::replan::{self, ReplanPolicy};
use crate::engine::run_job;
use crate::experiments::churn::{cell_setup, CellSetup};
use crate::optimizer::{FailureAwareOptimizer, PlanOptimizer};
use crate::platform::scale::{parse_spec_config, ScaleConfig};
use crate::util::table::Table;

/// Defaults for `mrperf experiment replan` (and `experiment all`).
/// 32 nodes keeps the x-LPs on the dense solver path while still giving
/// the replanner enough topology to re-route around.
pub const DEFAULT_GEN: &str = "hier-wan:32";
/// Profile part is ignored (all profiles run); the seed is honored.
pub const DEFAULT_DYNAMICS: &str = "failures:7";

/// Adversary budget feeding the `hedged-adv` row: a couple of node
/// outages, a couple of restarts — enough to find a damaging trace,
/// cheap enough for `experiment all`.
pub const ADVERSARY_OUTAGES: usize = 2;
pub const ADVERSARY_RESTARTS: usize = 2;

/// One profile × mode cell.
#[derive(Debug, Clone)]
pub struct ReplanCell {
    pub profile: DynProfile,
    /// `static` | `hedged-adv` | `replan` | `dynamic`.
    pub mode: &'static str,
    pub static_makespan: f64,
    pub dyn_makespan: f64,
    pub dyn_events: usize,
    pub replans: usize,
    pub replans_skipped: usize,
    pub migrated_splits: usize,
    pub migrated_ranges: usize,
    pub stolen: usize,
    pub requeued: usize,
    pub replay_bytes: f64,
}

impl ReplanCell {
    pub fn degradation(&self) -> f64 {
        self.dyn_makespan / self.static_makespan - 1.0
    }
}

/// The four execution modes. The bool selects the hedged plan; every
/// other mode runs the unhedged e2e plan. `replan_alpha` is 1.0 — the
/// α the churn workload's plan was solved with (`cell_setup`).
fn modes() -> [(&'static str, bool, JobConfig); 4] {
    [
        ("static", false, JobConfig::optimized()),
        ("hedged-adv", true, JobConfig::optimized()),
        ("replan", false, JobConfig::optimized().with_replan(ReplanPolicy::OnEvent, 1.0)),
        ("dynamic", false, JobConfig::dynamic_locality()),
    ]
}

/// Run the full profile × mode matrix at the spec's topology size.
/// Deterministic given `(generator seed, trace seed)` — the adversary
/// search seeds from the trace seed too.
pub fn run_matrix_at(base: &ScaleConfig, trace_seed: u64) -> Result<Vec<ReplanCell>, String> {
    let CellSetup { topo, inputs, plan, sapp, app, bc } = cell_setup(base, base.nodes);

    // Static plan-local run anchors the trace horizon for every row.
    let static_cfg = JobConfig::optimized();
    let static_pl = run_job(&topo, &plan, &sapp, &static_cfg, &inputs).metrics;
    let horizon = static_pl.makespan.max(1e-9);

    // Adversary-found robust scenario set → hedge rate → hedged plan.
    // Seeded with the failures profile so the search starts from a
    // trace that already hurts; the search itself is deterministic.
    let seed_trace = ScenarioTrace::generate(
        DynProfile::Failures,
        trace_seed,
        &TraceShape::of(&topo, horizon),
    );
    let found = adversary::search(
        &topo,
        &plan,
        &sapp,
        &static_cfg,
        &inputs,
        std::slice::from_ref(&seed_trace),
        &SearchConfig {
            restarts: ADVERSARY_RESTARTS,
            known_static_makespan: Some(static_pl.makespan),
            ..SearchConfig::new(PerturbBudget::outages(ADVERSARY_OUTAGES), trace_seed)
        },
    )?;
    let hedge_rate = replan::hedge_rate_from_traces(
        std::slice::from_ref(&found.trace),
        horizon,
        topo.n_reducers(),
    );
    let hedged_plan = if hedge_rate > 0.0 {
        FailureAwareOptimizer::new(hedge_rate).optimize(&topo, app, bc)
    } else {
        plan.clone()
    };

    // Static baselines per mode (replan without dynamics is plan-local
    // by the neutrality invariant, but measure it — degradation should
    // be relative to what the mode itself does on the quiet platform).
    let statics: Vec<f64> = modes()
        .iter()
        .map(|(_, hedged, cfg)| {
            let p = if *hedged { &hedged_plan } else { &plan };
            run_job(&topo, p, &sapp, cfg, &inputs).metrics.makespan
        })
        .collect();

    let mut cells = Vec::new();
    for profile in DynProfile::all() {
        let trace =
            ScenarioTrace::generate(profile, trace_seed, &TraceShape::of(&topo, horizon));
        for (idx, (mode, hedged, cfg)) in modes().into_iter().enumerate() {
            let p = if hedged { &hedged_plan } else { &plan };
            let m = run_job(&topo, p, &sapp, &cfg.with_dynamics(trace.clone()), &inputs)
                .metrics;
            assert_eq!(
                m.output_records, m.input_records,
                "{mode} lost records under {profile:?}"
            );
            assert_eq!(
                m.push_bytes_delivered.to_bits(),
                m.push_bytes.to_bits(),
                "{mode} lost push bytes under {profile:?}"
            );
            assert_eq!(
                (m.shuffle_bytes_delivered + m.dlq_bytes).to_bits(),
                m.shuffle_bytes.to_bits(),
                "{mode} lost shuffle bytes under {profile:?}"
            );
            cells.push(ReplanCell {
                profile,
                mode,
                static_makespan: statics[idx],
                dyn_makespan: m.makespan,
                dyn_events: m.dyn_events,
                replans: m.replans,
                replans_skipped: m.replans_skipped,
                migrated_splits: m.replan_migrated_splits,
                migrated_ranges: m.replan_migrated_ranges,
                stolen: m.stolen,
                requeued: m.tasks_requeued,
                replay_bytes: m.reduce_bytes_replayed,
            });
        }
    }
    Ok(cells)
}

/// Render the matrix for explicit specs.
pub fn run_with(gen_spec: &str, dyn_spec: &str) -> Result<Vec<Table>, String> {
    let base = parse_spec_config(gen_spec)?;
    let (_, trace_seed) = dynamics::parse_spec(dyn_spec)?;
    let cells = run_matrix_at(&base, trace_seed)?;
    let mut t = Table::new(
        format!(
            "replan: static vs adversary-hedged vs online re-planning vs dynamic stealing \
             (--gen {gen_spec} --dynamics seed {trace_seed}) — every profile row shares \
             one seeded trace"
        ),
        &[
            "profile",
            "mode",
            "static (s)",
            "dyn (s)",
            "degradation",
            "events",
            "replans",
            "skipped",
            "mig-splits",
            "mig-ranges",
            "stolen",
            "requeued",
            "replay (KB)",
        ],
    );
    for c in &cells {
        t.add_row(vec![
            c.profile.label().to_string(),
            c.mode.to_string(),
            format!("{:.4}", c.static_makespan),
            format!("{:.4}", c.dyn_makespan),
            format!("{:+.1}%", c.degradation() * 100.0),
            c.dyn_events.to_string(),
            c.replans.to_string(),
            c.replans_skipped.to_string(),
            c.migrated_splits.to_string(),
            c.migrated_ranges.to_string(),
            c.stolen.to_string(),
            c.requeued.to_string(),
            format!("{:.1}", c.replay_bytes / 1e3),
        ]);
    }
    Ok(vec![t])
}

/// The `replan` experiment with its default specs (used by
/// `mrperf experiment all`).
pub fn run() -> Vec<Table> {
    run_with(DEFAULT_GEN, DEFAULT_DYNAMICS).expect("default replan specs are valid")
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Same specs → bit-identical cells, full profile × mode coverage,
    /// and the replan mode must actually re-solve somewhere (sized down
    /// so the debug-build test stays quick).
    #[test]
    fn matrix_is_deterministic_and_replans_fire() {
        let base = parse_spec_config("hier-wan:16").unwrap();
        let a = run_matrix_at(&base, 7).unwrap();
        let b = run_matrix_at(&base, 7).unwrap();
        assert_eq!(a.len(), DynProfile::all().len() * 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.profile, x.mode), (y.profile, y.mode));
            assert_eq!(x.static_makespan.to_bits(), y.static_makespan.to_bits());
            assert_eq!(x.dyn_makespan.to_bits(), y.dyn_makespan.to_bits());
            assert_eq!(
                (x.replans, x.replans_skipped, x.migrated_splits, x.migrated_ranges),
                (y.replans, y.replans_skipped, y.migrated_splits, y.migrated_ranges)
            );
            assert_eq!(x.replay_bytes.to_bits(), y.replay_bytes.to_bits());
        }
        // Only the replan mode ever re-solves …
        assert!(
            a.iter().filter(|c| c.mode != "replan").all(|c| c.replans == 0
                && c.replans_skipped == 0
                && c.migrated_splits == 0
                && c.migrated_ranges == 0),
            "{a:?}"
        );
        // … and under at least one profile it actually does (the
        // failure profiles swing the effective platform far past the
        // hysteresis threshold).
        assert!(
            a.iter().any(|c| c.mode == "replan" && c.replans > 0),
            "no profile triggered a replan: {a:?}"
        );
    }

    #[test]
    fn bad_specs_error_cleanly() {
        assert!(run_with("nope:16", "failures:7").is_err());
        assert!(run_with("hier-wan:16", "nope:7").is_err());
    }
}
