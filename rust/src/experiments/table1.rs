//! Table 1: measured bandwidth (KBps) of the slowest/fastest links
//! between clusters in each continent, plus the site inventory.

use crate::platform::planetlab::{planetlab, table1_range};
use crate::platform::topology::Continent;
use crate::platform::KB;
use crate::util::table::Table;

pub fn run() -> Vec<Table> {
    let pl = planetlab();
    let continents = [Continent::US, Continent::EU, Continent::Asia];

    let mut t = Table::new(
        "Table 1 — inter-cluster bandwidth (KBps), slowest/fastest per continent pair",
        &["from\\to", "US", "EU", "Asia"],
    )
    .label_first();
    for &from in &continents {
        let mut row = vec![from.to_string()];
        for &to in &continents {
            let mut lo = f64::INFINITY;
            let mut hi = 0.0f64;
            for a in 0..pl.sites.len() {
                for b in 0..pl.sites.len() {
                    if a != b
                        && pl.sites[a].continent == from
                        && pl.sites[b].continent == to
                    {
                        let v = pl.bandwidth(a, b) / KB;
                        lo = lo.min(v);
                        hi = hi.max(v);
                    }
                }
            }
            let (plo, phi) = table1_range(from, to);
            row.push(format!(
                "{:.0} / {:.0} (paper {:.0} / {:.0})",
                lo,
                hi,
                plo / KB,
                phi / KB
            ));
        }
        t.add_row(row);
    }

    let mut sites = Table::new(
        "PlanetLab sites (§3.2: compute rates 9–90 MBps)",
        &["site", "continent", "compute MBps"],
    )
    .label_first();
    for s in &pl.sites {
        sites.add_row(vec![
            s.name.to_string(),
            s.continent.to_string(),
            format!("{:.0}", s.compute_bps / 1e6),
        ]);
    }
    vec![t, sites]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_reports_all_continent_pairs() {
        let tables = run();
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].rows.len(), 3);
        assert_eq!(tables[1].rows.len(), 8);
        // Every cell inside the paper's published ranges.
        let rendered = tables[0].render();
        assert!(rendered.contains("US"));
        assert!(rendered.contains("Asia"));
    }
}
