//! Adversary experiment: worst-case trace search for an optimized plan,
//! reported per scheduler family.
//!
//! The churn experiment answers "how does the plan fare under *random*
//! seeded churn"; this one answers the harder question the paper's
//! end-to-end claim ultimately rests on: "how bad can churn *get* for
//! this specific plan, within an explicit perturbation budget" — and
//! how much of that worst case each execution mode recovers. The
//! pipeline:
//!
//! 1. build the same scenario as `experiment churn` at the requested
//!    size ([`super::churn::cell_setup`] — same topology, inputs and
//!    unhedged `e2e-multi` plan, so the comparison against the seeded
//!    `failures` profile is apples-to-apples);
//! 2. run the seeded `failures` profile under plan-local enforcement —
//!    the random-churn baseline;
//! 3. run the adversarial search ([`crate::engine::adversary::search`])
//!    against the plan-local mode, seeding the candidate pool with that
//!    same `failures` trace so the found trace is at least as damaging
//!    (greedy refinement then makes it strictly worse in practice: the
//!    budget allows longer outage windows than the seeded profile ever
//!    draws);
//! 4. replay the worst-case trace under every execution mode —
//!    plan-local, dynamic, dynamic+locality, and the hedged plan under
//!    plan-local enforcement — tabulating static vs adversarial
//!    makespan, degradation, and the seeded-failures degradation next to
//!    it. The spread across rows is the measurable robustness gap.
//!
//! Deterministic given `(generator seed, search seed, budget, restarts,
//! hedge)`.

use crate::engine::adversary::{search, PerturbBudget, SearchConfig, SearchResult};
use crate::engine::dynamics::{DynEvent, DynProfile, ScenarioTrace, TraceShape};
use crate::engine::job::JobConfig;
use crate::engine::run_job;
use crate::experiments::churn::{cell_setup, CellSetup, DEFAULT_HEDGE};
use crate::optimizer::{FailureAwareOptimizer, PlanOptimizer};
use crate::platform::scale::parse_spec_config;
use crate::util::table::Table;

/// Defaults for `mrperf experiment adversary` (and `experiment all`).
pub const DEFAULT_GEN: &str = "hier-wan:64";
pub const DEFAULT_SEED: u64 = 7;
pub const DEFAULT_RESTARTS: usize = 6;

/// One execution mode's showing under the worst-case trace.
#[derive(Debug, Clone)]
pub struct AdversaryCell {
    /// `plan-local` | `dynamic` | `dynamic+locality` | `hedged`.
    pub mode: &'static str,
    pub static_makespan: f64,
    pub adversary_makespan: f64,
    /// The same mode under the seeded `failures` profile (the
    /// random-churn baseline the adversary must beat).
    pub failures_makespan: f64,
    pub ranges_reassigned: usize,
    pub stolen: usize,
    pub replay_bytes: f64,
}

impl AdversaryCell {
    pub fn degradation(&self) -> f64 {
        self.adversary_makespan / self.static_makespan - 1.0
    }

    pub fn failures_degradation(&self) -> f64 {
        self.failures_makespan / self.static_makespan - 1.0
    }
}

/// The experiment outcome: the search result plus the per-mode table.
#[derive(Debug, Clone)]
pub struct AdversaryOutcome {
    pub result: SearchResult,
    /// Plan-local degradation under the seeded `failures` profile.
    pub failures_degradation: f64,
    pub cells: Vec<AdversaryCell>,
}

/// The execution modes replayed under the worst-case trace. Mirrors the
/// churn matrix: the `hedged` row is the failure-aware plan under the
/// same strict enforcement as `plan-local`.
fn modes() -> [(&'static str, bool, JobConfig); 4] {
    [
        ("plan-local", false, JobConfig::optimized()),
        ("dynamic", false, JobConfig::vanilla_hadoop()),
        ("dynamic+locality", false, JobConfig::dynamic_locality()),
        ("hedged", true, JobConfig::optimized()),
    ]
}

/// Run the full adversary pipeline. `budget` of `None` derives the node
/// budget from the seeded `failures` profile's own outage count (so the
/// seeded trace always fits the pool un-clipped).
pub fn run_at(
    gen_spec: &str,
    seed: u64,
    budget: Option<usize>,
    restarts: usize,
    hedge: f64,
) -> Result<AdversaryOutcome, String> {
    crate::optimizer::hedged::validate_hedge(hedge).map_err(|e| format!("--hedge: {e}"))?;
    if restarts == 0 {
        return Err("--restarts must be at least 1".into());
    }
    if budget == Some(0) {
        return Err("--budget 0 allows the adversary no outage at all".into());
    }
    let base = parse_spec_config(gen_spec)?;
    let CellSetup { topo, inputs, plan, sapp, app, bc } = cell_setup(&base, base.nodes);
    let hedged_plan = FailureAwareOptimizer::new(hedge).optimize(&topo, app, bc);

    // Plan-local static run anchors the horizon, exactly as in churn.
    let plan_local = JobConfig::optimized();
    let static_pl = run_job(&topo, &plan, &sapp, &plan_local, &inputs).metrics;
    let horizon = static_pl.makespan.max(1e-9);
    let shape = TraceShape::of(&topo, horizon);

    // Random-churn baseline: the seeded failures profile.
    let failures_trace = ScenarioTrace::generate(DynProfile::Failures, seed, &shape);

    // Budget: default to the seeded profile's own outage count, so the
    // imported seed candidate is never clipped.
    let k = budget.unwrap_or_else(|| {
        failures_trace
            .events()
            .iter()
            .filter(|te| {
                matches!(
                    te.event,
                    DynEvent::MapperFail { .. } | DynEvent::ReducerFail { .. }
                )
            })
            .count()
            .max(1)
    });
    // The static run above anchors the horizon; hand its makespan to the
    // search so it doesn't repeat the identical deterministic simulation.
    let search_cfg = SearchConfig {
        restarts,
        known_static_makespan: Some(static_pl.makespan),
        ..SearchConfig::new(PerturbBudget::outages(k), seed)
    };
    let result = search(
        &topo,
        &plan,
        &sapp,
        &plan_local,
        &inputs,
        std::slice::from_ref(&failures_trace),
        &search_cfg,
    )?;

    // Replay worst case + baseline under every mode. The plan-local
    // static run is the one already measured for the horizon (the
    // executor is deterministic, so re-running it would only repeat
    // work).
    let mut cells = Vec::new();
    for (idx, (mode, hedged, cfg)) in modes().into_iter().enumerate() {
        let p = if hedged { &hedged_plan } else { &plan };
        let stat = if idx == 0 {
            static_pl.clone()
        } else {
            run_job(&topo, p, &sapp, &cfg, &inputs).metrics
        };
        let adv_cfg = cfg.clone().with_dynamics(result.trace.clone());
        let adv = run_job(&topo, p, &sapp, &adv_cfg, &inputs).metrics;
        assert_eq!(
            adv.output_records, adv.input_records,
            "{mode} lost records under the adversarial trace"
        );
        let fail_cfg = cfg.with_dynamics(failures_trace.clone());
        let fail = run_job(&topo, p, &sapp, &fail_cfg, &inputs).metrics;
        cells.push(AdversaryCell {
            mode,
            static_makespan: stat.makespan,
            adversary_makespan: adv.makespan,
            failures_makespan: fail.makespan,
            ranges_reassigned: adv.reduce_ranges_reassigned,
            stolen: adv.stolen,
            replay_bytes: adv.reduce_bytes_replayed,
        });
    }
    let failures_degradation = cells[0].failures_degradation();
    Ok(AdversaryOutcome { result, failures_degradation, cells })
}

/// Render the adversary report for explicit knobs.
pub fn run_with(
    gen_spec: &str,
    seed: u64,
    budget: Option<usize>,
    restarts: usize,
    hedge: f64,
) -> Result<Vec<Table>, String> {
    let out = run_at(gen_spec, seed, budget, restarts, hedge)?;

    // Table 1: the worst-case trace itself, event by event.
    let mut tt = Table::new(
        format!(
            "adversary: worst-case trace found (--gen {gen_spec} --seed {seed}, \
             {} executor evaluations)",
            out.result.evals
        ),
        &["time (s)", "event"],
    )
    .label_first();
    for te in out.result.trace.events() {
        let desc = match te.event {
            DynEvent::WanScale { factor } => format!("WAN links × {factor:.3}"),
            DynEvent::ClusterLinkScale { cluster, factor } => {
                format!("cluster {cluster} links × {factor:.3}")
            }
            DynEvent::MapperFail { node } => format!("mapper {node} fails"),
            DynEvent::MapperRecover { node } => format!("mapper {node} recovers"),
            DynEvent::ReducerFail { node } => format!("reducer {node} fails"),
            DynEvent::ReducerRecover { node } => format!("reducer {node} recovers"),
            DynEvent::MapperSlowdown { node, factor } => {
                format!("mapper {node} compute × {factor:.3}")
            }
            DynEvent::ReducerSlowdown { node, factor } => {
                format!("reducer {node} compute × {factor:.3}")
            }
            DynEvent::SourceRefresh { source, fraction } => {
                format!("source {source} refreshes {:.0}% of its data", fraction * 100.0)
            }
        };
        tt.add_row(vec![format!("{:.4}", te.time), desc]);
    }

    // Table 2: per-mode robustness under the worst case, with the seeded
    // failures profile alongside.
    let mut t = Table::new(
        format!(
            "adversary robustness: worst-case vs seeded failures per execution mode \
             (plan-local worst-case {:+.1}% vs seeded {:+.1}%)",
            out.cells[0].degradation() * 100.0,
            out.failures_degradation * 100.0
        ),
        &[
            "mode",
            "static (s)",
            "adversary (s)",
            "adv-deg.",
            "failures (s)",
            "fail-deg.",
            "adopted",
            "stolen",
            "replay (KB)",
        ],
    );
    for c in &out.cells {
        t.add_row(vec![
            c.mode.to_string(),
            format!("{:.4}", c.static_makespan),
            format!("{:.4}", c.adversary_makespan),
            format!("{:+.1}%", c.degradation() * 100.0),
            format!("{:.4}", c.failures_makespan),
            format!("{:+.1}%", c.failures_degradation() * 100.0),
            c.ranges_reassigned.to_string(),
            c.stolen.to_string(),
            format!("{:.1}", c.replay_bytes / 1e3),
        ]);
    }
    Ok(vec![tt, t])
}

/// The `adversary` experiment with its default knobs (used by
/// `mrperf experiment all`).
pub fn run() -> Vec<Table> {
    run_with(DEFAULT_GEN, DEFAULT_SEED, None, DEFAULT_RESTARTS, DEFAULT_HEDGE)
        .expect("default adversary knobs are valid")
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Same knobs → bit-identical outcome (sized down for debug builds).
    #[test]
    fn adversary_outcome_is_deterministic() {
        let a = run_at("hier-wan:16", 7, Some(2), 2, 0.1).unwrap();
        let b = run_at("hier-wan:16", 7, Some(2), 2, 0.1).unwrap();
        assert_eq!(a.result.trace, b.result.trace);
        assert_eq!(a.result.worst_makespan.to_bits(), b.result.worst_makespan.to_bits());
        assert_eq!(a.result.evals, b.result.evals);
        assert_eq!(a.cells.len(), 4);
        for (x, y) in a.cells.iter().zip(&b.cells) {
            assert_eq!(x.mode, y.mode);
            assert_eq!(x.adversary_makespan.to_bits(), y.adversary_makespan.to_bits());
            assert_eq!(x.failures_makespan.to_bits(), y.failures_makespan.to_bits());
        }
        // The adversary must be at least as damaging to plan-local as
        // the seeded failures profile it was seeded with.
        assert!(
            a.cells[0].degradation() >= a.failures_degradation,
            "adversary {:+.3} < seeded failures {:+.3}",
            a.cells[0].degradation(),
            a.failures_degradation
        );
    }

    #[test]
    fn bad_knobs_error_cleanly() {
        assert!(run_at("nope:16", 7, None, 2, 0.0).is_err());
        assert!(run_at("hier-wan:16", 7, Some(0), 2, 0.0).is_err());
        assert!(run_at("hier-wan:16", 7, None, 0, 0.0).is_err());
        assert!(run_at("hier-wan:16", 7, None, 2, 1.5).is_err());
    }
}
