//! Figure 4 — model validation: predicted vs measured makespan.
//!
//! Grid (§3.2): α ∈ {0.1, 1, 2} × network heterogeneity ∈ {PlanetLab,
//! LAN} × compute heterogeneity ∈ {PlanetLab, none} × barrier configs
//! {G-P-L, P-P-L, P-G-L, G-G-L} × plans {uniform, optimized}. For each
//! cell the closed-form model predicts the makespan and the engine
//! "measures" it (virtual-time execution with contention the model
//! ignores). The paper reports R² = 0.9412 and slope 1.1464; we report
//! the same fit statistics on our grid.

use crate::apps::SyntheticApp;
use crate::engine::job::{batch_size, JobConfig};
use crate::engine::run_job;
use crate::model::barrier::BarrierConfig;
use crate::model::makespan::{makespan, AppModel};
use crate::model::plan::Plan;
use crate::optimizer::{AlternatingLp, PlanOptimizer};
use crate::platform::planetlab::{planetlab, LAN_BPS};
use crate::platform::{envs, Topology};
use crate::util::stats::linear_fit;
use crate::util::table::{fmt_secs, Table};

use super::common::synthetic_inputs;

/// Bytes of input per data source for the engine runs (scaled from the
/// paper's 256 MB — see DESIGN.md §3 on virtual-time scaling).
pub const BYTES_PER_SOURCE: usize = 1 << 21; // 2 MiB

fn variant_topo(net_het: bool, comp_het: bool, d_bytes: f64) -> Topology {
    let pl = planetlab();
    let mut topo = envs::build_env_with(envs::EnvKind::Global8, &pl, d_bytes);
    if !net_het {
        for v in topo.b_sm.data_mut().iter_mut() {
            *v = LAN_BPS;
        }
        for v in topo.b_mr.data_mut().iter_mut() {
            *v = LAN_BPS;
        }
    }
    if !comp_het {
        let c = 50.0e6;
        for v in topo.c_map.iter_mut().chain(topo.c_red.iter_mut()) {
            *v = c;
        }
    }
    topo
}

pub struct Fig4Result {
    pub tables: Vec<Table>,
    pub r2: f64,
    pub slope: f64,
}

pub fn run() -> Fig4Result {
    let mut rows_table = Table::new(
        "Fig 4 — predicted vs measured makespan (every validation cell)",
        &["alpha", "net", "comp", "barriers", "plan", "predicted s", "measured s", "ratio"],
    )
    .label_first();

    let mut predicted = Vec::new();
    let mut measured = Vec::new();

    for &alpha in &[0.1, 1.0, 2.0] {
        for &(net_het, comp_het) in &[(true, true), (false, false)] {
            for cfg in BarrierConfig::validation_set() {
                for optimized in [false, true] {
                    // Build inputs first so the model sees the true bytes.
                    let inputs = synthetic_inputs(8, BYTES_PER_SOURCE, 0xF16_4);
                    let actual_bytes: f64 = inputs
                        .iter()
                        .map(|v| batch_size(v) as f64)
                        .sum::<f64>()
                        / 8.0;
                    let topo = variant_topo(net_het, comp_het, actual_bytes);
                    let app_model = AppModel::new(alpha);
                    let plan = if optimized {
                        AlternatingLp { random_starts: 2, ..Default::default() }
                            .optimize(&topo, app_model, cfg)
                    } else {
                        Plan::uniform(8, 8, 8)
                    };
                    let pred = makespan(&topo, app_model, cfg, &plan);

                    let app = SyntheticApp::new(alpha);
                    let jc = JobConfig { barriers: cfg, ..Default::default() };
                    let metrics = run_job(&topo, &plan, &app, &jc, &inputs).metrics;
                    let meas = metrics.makespan;

                    predicted.push(pred);
                    measured.push(meas);
                    rows_table.add_row(vec![
                        format!("{alpha}"),
                        if net_het { "PL" } else { "LAN" }.into(),
                        if comp_het { "PL" } else { "none" }.into(),
                        cfg.label(),
                        if optimized { "optimized" } else { "uniform" }.into(),
                        fmt_secs(pred),
                        fmt_secs(meas),
                        format!("{:.3}", meas / pred),
                    ]);
                }
            }
        }
    }

    let fit = linear_fit(&predicted, &measured);
    let mut summary = Table::new(
        "Fig 4 — fit statistics (paper: R² = 0.9412, slope 1.1464)",
        &["statistic", "ours", "paper"],
    )
    .label_first();
    summary.add_row(vec!["R²".into(), format!("{:.4}", fit.r2), "0.9412".into()]);
    summary.add_row(vec!["slope".into(), format!("{:.4}", fit.slope), "1.1464".into()]);
    summary.add_row(vec!["points".into(), format!("{}", fit.n), "—".into()]);

    Fig4Result { tables: vec![rows_table, summary], r2: fit.r2, slope: fit.slope }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The headline validation claim: strong correlation between model
    /// and engine. (Slower test — full 48-cell grid.)
    #[test]
    fn model_predicts_engine_makespan() {
        let res = run();
        assert!(
            res.r2 > 0.8,
            "validation R² = {} — model does not track the engine",
            res.r2
        );
        assert!(
            res.slope > 0.5 && res.slope < 2.0,
            "slope {} out of plausible range",
            res.slope
        );
    }
}
