//! The engine (testbed) experiments:
//!
//! * **Fig 9**  — uniform plan vs vanilla Hadoop vs our optimized plan,
//!   three real applications, per-phase bars + 95% CIs.
//! * **Fig 10** — Hadoop's dynamic mechanisms (speculation, stealing)
//!   applied atop the optimized static plan.
//! * **Fig 11** — the same mechanisms atop the competitive Hadoop
//!   baseline plan (locality push + uniform shuffle).
//! * **Fig 12** — HDFS replication across slow wide-area links.
//!
//! "Vanilla Hadoop" = locality-hinted push (each source → most local
//! mapper), uniform shuffle, coarse pipelining (G-P-L is the *model*
//! image of its behaviour), dynamic mechanisms on (§4.6.1).

use crate::apps::{measure_alpha, InvertedIndex, Sessionize, WordCount};
use crate::data::{corpus, fwdindex, weblog};
use crate::engine::job::{JobConfig, MapReduceApp, Record};
use crate::model::barrier::BarrierConfig;
use crate::model::makespan::AppModel;
use crate::model::plan::Plan;
use crate::optimizer::{AlternatingLp, PlanOptimizer};
use crate::platform::{build_env, EnvKind, Topology};
use crate::util::stats::Summary;
use crate::util::table::{fmt_secs, Table};

use super::common::run_engine_repeats;

/// Input volume per source (scaled from the paper's GB-scale datasets).
pub const BYTES_PER_SOURCE: usize = 1 << 21; // 2 MiB
pub const REPEATS: usize = 3;

pub enum AppKind {
    WordCount,
    Sessionize,
    InvertedIndex,
}

impl AppKind {
    pub fn all() -> [AppKind; 3] {
        [AppKind::WordCount, AppKind::Sessionize, AppKind::InvertedIndex]
    }

    pub fn label(&self) -> &'static str {
        match self {
            AppKind::WordCount => "Word Count",
            AppKind::Sessionize => "Sessionization",
            AppKind::InvertedIndex => "Full Inverted Index",
        }
    }

    pub fn app(&self) -> Box<dyn MapReduceApp> {
        match self {
            AppKind::WordCount => Box::new(WordCount),
            AppKind::Sessionize => Box::new(Sessionize),
            AppKind::InvertedIndex => Box::new(InvertedIndex),
        }
    }

    pub fn inputs(&self, n_sources: usize, bytes: usize, seed: u64) -> Vec<Vec<Record>> {
        match self {
            AppKind::WordCount => crate::data::per_source(n_sources, bytes, seed, |_, b, rng| {
                corpus::generate(corpus::CorpusConfig::default(), b, rng)
            }),
            AppKind::Sessionize => crate::data::per_source(n_sources, bytes, seed, |_, b, rng| {
                weblog::generate(weblog::WeblogConfig::default(), b, rng)
            }),
            AppKind::InvertedIndex => {
                crate::data::per_source(n_sources, bytes, seed, |_, b, rng| {
                    fwdindex::generate(corpus::CorpusConfig::default(), b, rng)
                })
            }
        }
    }

    /// Profile α on a sample split (§2.1: "determined by profiling").
    pub fn profiled_alpha(&self) -> f64 {
        let sample = self.inputs(1, 1 << 20, 0xA1FA)
            .pop()
            .unwrap();
        measure_alpha(self.app().as_ref(), &sample)
    }
}

/// The three execution setups of Fig 9.
fn plans_for(topo: &Topology, alpha: f64) -> [(String, Plan, JobConfig); 3] {
    let app_model = AppModel::new(alpha);
    // The model uses G-P-L to capture Hadoop's behaviour (§4.6.1).
    let cfg = BarrierConfig::HADOOP;
    let uniform = Plan::uniform(topo.n_sources(), topo.n_mappers(), topo.n_reducers());
    let hadoop_plan = Plan::local_push(topo);
    let optimized = AlternatingLp::default().optimize(topo, app_model, cfg);
    [
        ("uniform".into(), uniform, JobConfig::optimized()),
        ("vanilla hadoop".into(), hadoop_plan, JobConfig::vanilla_hadoop()),
        ("optimized".into(), optimized, JobConfig::optimized()),
    ]
}

pub fn run_fig9() -> Vec<Table> {
    let topo = build_env(EnvKind::Global8);
    let mut t = Table::new(
        "Fig 9 — engine makespan: uniform vs vanilla Hadoop vs optimized plan (8-node emulated PlanetLab)",
        &["app", "alpha", "scheme", "push", "map+shuffle", "shuffle+reduce", "makespan s", "95% CI"],
    )
    .label_first();
    for kind in AppKind::all() {
        let alpha = kind.profiled_alpha();
        let app = kind.app();
        for (name, plan, jc) in plans_for(&topo, alpha) {
            let runs = run_engine_repeats(
                &topo,
                &plan,
                app.as_ref(),
                &jc,
                &|seed| kind.inputs(8, BYTES_PER_SOURCE, seed),
                REPEATS,
            );
            let makespans: Vec<f64> = runs.iter().map(|m| m.makespan).collect();
            let s = Summary::of(&makespans);
            let segs: Vec<(f64, f64, f64)> = runs.iter().map(|m| m.fig9_segments()).collect();
            let avg = |f: fn(&(f64, f64, f64)) -> f64| {
                segs.iter().map(f).sum::<f64>() / segs.len() as f64
            };
            t.add_row(vec![
                kind.label().into(),
                format!("{alpha:.2}"),
                name,
                fmt_secs(avg(|s| s.0)),
                fmt_secs(avg(|s| s.1)),
                fmt_secs(avg(|s| s.2)),
                fmt_secs(s.mean),
                format!("±{}", fmt_secs(s.ci95)),
            ]);
        }
    }
    vec![t]
}

fn dynamics_table(title: &str, base: &str) -> Table {
    let topo = build_env(EnvKind::Global8);
    let mut t = Table::new(
        title,
        &["app", "mechanisms", "makespan s", "95% CI"],
    )
    .label_first();
    for kind in AppKind::all() {
        let alpha = kind.profiled_alpha();
        let app = kind.app();
        let plan = if base == "optimized" {
            AlternatingLp::default().optimize(&topo, AppModel::new(alpha), BarrierConfig::HADOOP)
        } else {
            Plan::local_push(&topo)
        };
        for (mech, spec, steal) in [
            ("static", false, false),
            ("+speculation", true, false),
            ("+spec+steal", true, true),
        ] {
            let jc = JobConfig {
                local_only: !(spec || steal),
                speculation: spec,
                stealing: steal,
                ..JobConfig::default()
            };
            let runs = run_engine_repeats(
                &topo,
                &plan,
                app.as_ref(),
                &jc,
                &|seed| kind.inputs(8, BYTES_PER_SOURCE, seed),
                REPEATS,
            );
            let makespans: Vec<f64> = runs.iter().map(|m| m.makespan).collect();
            let s = Summary::of(&makespans);
            t.add_row(vec![
                kind.label().into(),
                mech.into(),
                fmt_secs(s.mean),
                format!("±{}", fmt_secs(s.ci95)),
            ]);
        }
    }
    t
}

pub fn run_fig10() -> Vec<Table> {
    vec![dynamics_table(
        "Fig 10 — dynamic mechanisms atop the optimized static plan",
        "optimized",
    )]
}

pub fn run_fig11() -> Vec<Table> {
    vec![dynamics_table(
        "Fig 11 — dynamic mechanisms atop the Hadoop baseline plan",
        "hadoop",
    )]
}

pub fn run_fig12() -> Vec<Table> {
    let topo = build_env(EnvKind::Global8);
    let mut t = Table::new(
        "Fig 12 — HDFS replication across wide-area links (vanilla Hadoop execution)",
        &["app", "replication", "push", "makespan s", "95% CI"],
    )
    .label_first();
    for kind in AppKind::all() {
        let app = kind.app();
        let plan = Plan::local_push(&topo);
        for repl in [1usize, 2, 3] {
            let jc = JobConfig { replication: repl, ..JobConfig::vanilla_hadoop() };
            let runs = run_engine_repeats(
                &topo,
                &plan,
                app.as_ref(),
                &jc,
                &|seed| kind.inputs(8, BYTES_PER_SOURCE, seed),
                REPEATS,
            );
            let makespans: Vec<f64> = runs.iter().map(|m| m.makespan).collect();
            let push: f64 =
                runs.iter().map(|m| m.push_end).sum::<f64>() / runs.len() as f64;
            let s = Summary::of(&makespans);
            t.add_row(vec![
                kind.label().into(),
                format!("{repl}"),
                fmt_secs(push),
                fmt_secs(s.mean),
                format!("±{}", fmt_secs(s.ci95)),
            ]);
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Profiled α ordering matches the paper's three applications.
    #[test]
    fn profiled_alphas_ordered() {
        let wc = AppKind::WordCount.profiled_alpha();
        let se = AppKind::Sessionize.profiled_alpha();
        let ii = AppKind::InvertedIndex.profiled_alpha();
        assert!(wc < se && se < ii, "wc={wc} se={se} ii={ii}");
    }

    /// Fig 9 headline (shape): optimized beats vanilla Hadoop, which
    /// beats uniform, for at least two of the three applications.
    #[test]
    fn fig9_optimized_beats_hadoop_beats_uniform() {
        let topo = build_env(EnvKind::Global8);
        let mut wins_opt = 0;
        let mut wins_hadoop = 0;
        for kind in AppKind::all() {
            let alpha = kind.profiled_alpha();
            let app = kind.app();
            let mut ms = Vec::new();
            for (_, plan, jc) in plans_for(&topo, alpha) {
                let runs = run_engine_repeats(
                    &topo,
                    &plan,
                    app.as_ref(),
                    &jc,
                    &|seed| kind.inputs(8, 1 << 20, seed),
                    1,
                );
                ms.push(runs[0].makespan);
            }
            let (uni, hadoop, opt) = (ms[0], ms[1], ms[2]);
            if opt < hadoop {
                wins_opt += 1;
            }
            if hadoop < uni {
                wins_hadoop += 1;
            }
        }
        assert!(wins_opt >= 2, "optimized should beat vanilla Hadoop on ≥2/3 apps");
        assert!(wins_hadoop >= 2, "vanilla Hadoop should beat uniform on ≥2/3 apps");
    }

    /// Fig 12 headline: wide-area replication raises push cost and
    /// overall makespan.
    #[test]
    fn fig12_replication_hurts() {
        let topo = build_env(EnvKind::Global8);
        let kind = AppKind::WordCount;
        let app = kind.app();
        let plan = Plan::local_push(&topo);
        let mut makespans = Vec::new();
        for repl in [1usize, 3] {
            let jc = JobConfig { replication: repl, ..JobConfig::vanilla_hadoop() };
            let runs = run_engine_repeats(
                &topo,
                &plan,
                app.as_ref(),
                &jc,
                &|seed| kind.inputs(8, 1 << 20, seed),
                1,
            );
            makespans.push(runs[0].makespan);
        }
        assert!(
            makespans[1] > makespans[0] * 1.2,
            "repl=3 {} should be ≥20% slower than repl=1 {}",
            makespans[1],
            makespans[0]
        );
    }
}
