//! Scale sweep: the full pipeline — **optimize, predict, simulate** — on
//! generated topologies far beyond the paper's 8-node environments.
//!
//! Two sub-sweeps:
//!
//! * **engine sweep** (since PR 1): one synthetic job per (kind, size)
//!   cell with a fixed local-push plan; checks the discrete-event core
//!   stays fast as the platform grows (256-node job ≪ 1 s).
//! * **optimizer sweep** (this PR): for each cell, run the two scalable
//!   end-to-end optimizers — `AlternatingLp` over the sparse/warm-started
//!   LP stack and `GradientOptimizer` over analytic reverse-mode
//!   gradients — then *simulate the optimized plan* on the engine, so the
//!   table shows model-predicted and engine-simulated makespans next to
//!   the optimizer's own wall-clock cost, 16 → 256 nodes end to end.
//!
//! Both sweeps are deterministic given the generator seeds.

use std::time::Instant;

use crate::apps::SyntheticApp;
use crate::engine::job::{batch_size, JobConfig};
use crate::engine::run_job;
use crate::experiments::common::synthetic_inputs;
use crate::model::barrier::BarrierConfig;
use crate::model::makespan::{makespan, AppModel};
use crate::model::plan::Plan;
use crate::optimizer::{AlternatingLp, GradientOptimizer, PlanOptimizer};
use crate::platform::scale::{generate_kind, ScaleKind};
use crate::util::table::Table;

/// Node counts swept per topology kind by the *optimizer* sweep (the
/// LP/gradient pipeline is the costly half; its range stays 16→256).
pub const SWEEP_NODES: [usize; 4] = [16, 64, 128, 256];

/// Node counts swept by the *engine* sweep — extends to the generator
/// cap ([`crate::platform::scale::MAX_NODES`]); the incremental fluid
/// re-solve keeps even the 4096-node run sub-second (bench-gated in
/// `benches/bench_main.rs`).
pub const ENGINE_SWEEP_NODES: [usize; 7] = [16, 64, 128, 256, 512, 1024, 4096];

/// Input volume per source — kept small because the sweep measures the
/// simulator's scaling with topology size, not with data volume.
pub const SWEEP_BYTES_PER_SOURCE: usize = 2_000;

/// One engine-sweep cell's result.
#[derive(Debug, Clone)]
pub struct ScaleCell {
    pub kind: ScaleKind,
    pub nodes: usize,
    pub n_sources: usize,
    pub n_mappers: usize,
    pub n_reducers: usize,
    pub map_tasks: usize,
    pub virtual_makespan: f64,
    pub wall_seconds: f64,
}

/// Run the engine sweep over the full 16→4096 range (the experiment).
pub fn sweep() -> Vec<ScaleCell> {
    sweep_at(*ENGINE_SWEEP_NODES.last().unwrap())
}

/// Engine sweep capped at `max_nodes` — tests cap the size so
/// debug-build runs stay fast; the release-mode experiment runs the
/// full range.
pub fn sweep_at(max_nodes: usize) -> Vec<ScaleCell> {
    let mut cells = Vec::new();
    for kind in ScaleKind::all() {
        for &nodes in ENGINE_SWEEP_NODES.iter().filter(|&&n| n <= max_nodes) {
            let topo = generate_kind(kind, nodes, 7);
            // Local push keeps the activity count proportional to the
            // node count (uniform would create |S|·|M| transfers).
            let plan = Plan::local_push(&topo);
            let inputs =
                synthetic_inputs(topo.n_sources(), SWEEP_BYTES_PER_SOURCE, 0x5CA1E);
            let app = SyntheticApp::new(1.0);
            let cfg = JobConfig::default();
            let t0 = Instant::now();
            let res = run_job(&topo, &plan, &app, &cfg, &inputs);
            cells.push(ScaleCell {
                kind,
                nodes,
                n_sources: topo.n_sources(),
                n_mappers: topo.n_mappers(),
                n_reducers: topo.n_reducers(),
                map_tasks: res.metrics.n_map_tasks,
                virtual_makespan: res.metrics.makespan,
                wall_seconds: t0.elapsed().as_secs_f64(),
            });
        }
    }
    cells
}

/// One optimizer-sweep cell: an (optimizer, kind, size) combination,
/// optimized end to end and then simulated.
#[derive(Debug, Clone)]
pub struct OptCell {
    pub kind: ScaleKind,
    pub nodes: usize,
    pub scheme: &'static str,
    /// Wall-clock seconds spent producing the plan.
    pub opt_wall_seconds: f64,
    /// Model-predicted makespan of the optimized plan.
    pub predicted_makespan: f64,
    /// Model-predicted makespan of the uniform baseline plan.
    pub uniform_makespan: f64,
    /// Engine-simulated (virtual-time) makespan of the optimized plan.
    pub simulated_makespan: f64,
    /// Wall-clock seconds the engine spent simulating it.
    pub sim_wall_seconds: f64,
}

/// Run the optimize-and-simulate sweep over `kinds` up to `max_nodes`
/// (tests cap the size so debug builds stay fast; the experiment runs the
/// full 16→256 range).
pub fn optimizer_sweep(kinds: &[ScaleKind], max_nodes: usize) -> Vec<OptCell> {
    let app = AppModel::new(1.0);
    let cfg = BarrierConfig::HADOOP;
    let mut cells = Vec::new();
    for &kind in kinds {
        for &nodes in &SWEEP_NODES {
            if nodes > max_nodes {
                continue;
            }
            // Build inputs first so the model sees the true bytes (the
            // fig4 idiom): the generated topology carries 1 GB/source,
            // but the sweep simulates tiny synthetic inputs — predicted
            // and simulated makespans are only comparable if the model
            // is evaluated on the simulated volume.
            let gen = generate_kind(kind, nodes, 7);
            let n_src = gen.n_sources();
            let inputs = synthetic_inputs(n_src, SWEEP_BYTES_PER_SOURCE, 0x5CA1E);
            let actual_bytes: f64 =
                inputs.iter().map(|v| batch_size(v) as f64).sum::<f64>() / n_src as f64;
            let topo = gen.with_uniform_data(actual_bytes);
            let (s, m, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
            let uniform = makespan(&topo, app, cfg, &Plan::uniform(s, m, r));
            let schemes: [(&'static str, Box<dyn PlanOptimizer>); 2] = [
                ("e2e-multi", Box::new(AlternatingLp::default())),
                ("gradient", Box::new(GradientOptimizer::default())),
            ];
            for (scheme, opt) in schemes {
                let t0 = Instant::now();
                let plan = opt.optimize(&topo, app, cfg);
                let opt_wall = t0.elapsed().as_secs_f64();
                let predicted = makespan(&topo, app, cfg, &plan);

                let sapp = SyntheticApp::new(1.0);
                let jc = JobConfig { barriers: cfg, ..JobConfig::default() };
                let t1 = Instant::now();
                let res = run_job(&topo, &plan, &sapp, &jc, &inputs);
                cells.push(OptCell {
                    kind,
                    nodes,
                    scheme,
                    opt_wall_seconds: opt_wall,
                    predicted_makespan: predicted,
                    uniform_makespan: uniform,
                    simulated_makespan: res.metrics.makespan,
                    sim_wall_seconds: t1.elapsed().as_secs_f64(),
                });
            }
        }
    }
    cells
}

/// The `scale` experiment: engine sweep + full optimize-and-simulate
/// sweep, rendered as tables.
pub fn run() -> Vec<Table> {
    let mut t = Table::new(
        "engine scale sweep: run_job on generated topologies, 16→4096 nodes (virtual vs wall time)",
        &["kind", "nodes", "S/M/R", "map tasks", "virtual makespan (s)", "wall (ms)"],
    );
    for c in sweep() {
        t.add_row(vec![
            c.kind.label().to_string(),
            c.nodes.to_string(),
            format!("{}/{}/{}", c.n_sources, c.n_mappers, c.n_reducers),
            c.map_tasks.to_string(),
            format!("{:.1}", c.virtual_makespan),
            format!("{:.2}", c.wall_seconds * 1e3),
        ]);
    }

    let mut o = Table::new(
        "optimizer scale sweep: optimize + simulate, 16→256 nodes (α=1, G-P-L)",
        &[
            "kind",
            "nodes",
            "scheme",
            "opt wall (s)",
            "predicted (s)",
            "vs uniform",
            "simulated (s)",
            "sim wall (ms)",
        ],
    );
    for c in optimizer_sweep(&ScaleKind::all(), *SWEEP_NODES.last().unwrap()) {
        o.add_row(vec![
            c.kind.label().to_string(),
            c.nodes.to_string(),
            c.scheme.to_string(),
            format!("{:.2}", c.opt_wall_seconds),
            format!("{:.4}", c.predicted_makespan),
            format!("{:+.1}%", (c.predicted_makespan / c.uniform_makespan - 1.0) * 100.0),
            format!("{:.4}", c.simulated_makespan),
            format!("{:.2}", c.sim_wall_seconds * 1e3),
        ]);
    }
    vec![t, o]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The engine sweep must complete and every cell must do real work.
    /// Capped at 256 nodes so the debug-build test stays quick; the full
    /// 16→4096 range runs in the release-mode experiment and its bench
    /// gate.
    #[test]
    fn sweep_produces_sane_cells() {
        let cells = sweep_at(256);
        let sizes = ENGINE_SWEEP_NODES.iter().filter(|&&n| n <= 256).count();
        assert_eq!(cells.len(), ScaleKind::all().len() * sizes);
        for c in &cells {
            assert!(c.virtual_makespan > 0.0, "{c:?}");
            assert!(c.map_tasks > 0, "{c:?}");
            assert!(c.n_sources + c.n_mappers + c.n_reducers >= c.nodes * 9 / 10);
        }
    }

    /// The engine sweep's extended range must stay inside the generator
    /// cap the CLI enforces.
    #[test]
    fn engine_sweep_respects_generator_cap() {
        assert!(ENGINE_SWEEP_NODES
            .iter()
            .all(|&n| n <= crate::platform::scale::MAX_NODES));
        assert_eq!(
            *ENGINE_SWEEP_NODES.last().unwrap(),
            crate::platform::scale::MAX_NODES,
            "the sweep should exercise the cap itself"
        );
    }

    /// Optimize-and-simulate cells: plans beat (or tie) uniform under the
    /// model and the engine agrees the job completes. Capped at 64 nodes
    /// so the debug-build test stays quick; the full range runs in the
    /// release-mode experiment.
    #[test]
    fn optimizer_sweep_optimizes_and_simulates() {
        let cells = optimizer_sweep(&[ScaleKind::HierarchicalWan], 64);
        assert_eq!(cells.len(), 2 * 2); // {16, 64} × {e2e-multi, gradient}
        for c in &cells {
            assert!(
                c.predicted_makespan <= c.uniform_makespan * (1.0 + 1e-9),
                "{c:?}: optimized plan must not lose to uniform"
            );
            assert!(c.simulated_makespan > 0.0, "{c:?}");
        }
    }
}
