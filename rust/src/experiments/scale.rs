//! Scale sweep: engine wall-clock and virtual makespan on generated
//! topologies far beyond the paper's 8-node environments.
//!
//! This is the substrate check for every later optimizer/scenario PR:
//! the event-driven engine core must stay fast as the platform grows.
//! The sweep runs one synthetic job per (kind, size) cell and reports
//! the virtual-time makespan next to the real wall-clock cost of
//! simulating it (target: a 256-node job in well under a second —
//! asserted by the `engine/scale_*` benches in benches/bench_main.rs).

use std::time::Instant;

use crate::apps::SyntheticApp;
use crate::engine::job::JobConfig;
use crate::engine::run_job;
use crate::experiments::common::synthetic_inputs;
use crate::model::plan::Plan;
use crate::platform::scale::{generate_kind, ScaleKind};
use crate::util::table::Table;

/// Node counts swept per topology kind.
pub const SWEEP_NODES: [usize; 4] = [16, 64, 128, 256];

/// Input volume per source — kept small because the sweep measures the
/// simulator's scaling with topology size, not with data volume.
pub const SWEEP_BYTES_PER_SOURCE: usize = 2_000;

/// One sweep cell's result.
#[derive(Debug, Clone)]
pub struct ScaleCell {
    pub kind: ScaleKind,
    pub nodes: usize,
    pub n_sources: usize,
    pub n_mappers: usize,
    pub n_reducers: usize,
    pub map_tasks: usize,
    pub virtual_makespan: f64,
    pub wall_seconds: f64,
}

/// Run the full sweep (used by the experiment *and* by tests).
pub fn sweep() -> Vec<ScaleCell> {
    let mut cells = Vec::new();
    for kind in ScaleKind::all() {
        for &nodes in &SWEEP_NODES {
            let topo = generate_kind(kind, nodes, 7);
            // Local push keeps the activity count proportional to the
            // node count (uniform would create |S|·|M| transfers).
            let plan = Plan::local_push(&topo);
            let inputs =
                synthetic_inputs(topo.n_sources(), SWEEP_BYTES_PER_SOURCE, 0x5CA1E);
            let app = SyntheticApp::new(1.0);
            let cfg = JobConfig::default();
            let t0 = Instant::now();
            let res = run_job(&topo, &plan, &app, &cfg, &inputs);
            cells.push(ScaleCell {
                kind,
                nodes,
                n_sources: topo.n_sources(),
                n_mappers: topo.n_mappers(),
                n_reducers: topo.n_reducers(),
                map_tasks: res.metrics.n_map_tasks,
                virtual_makespan: res.metrics.makespan,
                wall_seconds: t0.elapsed().as_secs_f64(),
            });
        }
    }
    cells
}

/// The `scale` experiment: render the sweep as a table.
pub fn run() -> Vec<Table> {
    let mut t = Table::new(
        "engine scale sweep: run_job on generated topologies (virtual vs wall time)",
        &["kind", "nodes", "S/M/R", "map tasks", "virtual makespan (s)", "wall (ms)"],
    );
    for c in sweep() {
        t.add_row(vec![
            c.kind.label().to_string(),
            c.nodes.to_string(),
            format!("{}/{}/{}", c.n_sources, c.n_mappers, c.n_reducers),
            c.map_tasks.to_string(),
            format!("{:.1}", c.virtual_makespan),
            format!("{:.2}", c.wall_seconds * 1e3),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The sweep must complete and every cell must do real work.
    #[test]
    fn sweep_produces_sane_cells() {
        let cells = sweep();
        assert_eq!(cells.len(), ScaleKind::all().len() * SWEEP_NODES.len());
        for c in &cells {
            assert!(c.virtual_makespan > 0.0, "{c:?}");
            assert!(c.map_tasks > 0, "{c:?}");
            assert!(c.n_sources + c.n_mappers + c.n_reducers >= c.nodes * 9 / 10);
        }
    }
}
