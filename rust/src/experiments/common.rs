//! Shared experiment machinery: scheme registry, engine-run helpers,
//! result capture.

use crate::apps::SyntheticApp;
use crate::engine::job::{JobConfig, MapReduceApp, Record};
use crate::engine::{run_job, JobMetrics};
use crate::model::barrier::BarrierConfig;
use crate::model::makespan::{evaluate, AppModel, PhaseBreakdown};
use crate::model::plan::Plan;
use crate::optimizer::{
    AlternatingLp, E2ePush, E2eShuffle, Myopic, PlanOptimizer, Uniform,
};
use crate::platform::Topology;
use crate::util::rng::Pcg64;

/// The model-experiment schemes of Figs 5–8.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    Uniform,
    MyopicMulti,
    E2ePush,
    E2eShuffle,
    E2eMulti,
}

impl Scheme {
    pub fn label(&self) -> &'static str {
        match self {
            Scheme::Uniform => "uniform",
            Scheme::MyopicMulti => "myopic multi",
            Scheme::E2ePush => "e2e push",
            Scheme::E2eShuffle => "e2e shuffle",
            Scheme::E2eMulti => "e2e multi",
        }
    }

    pub fn plan(&self, topo: &Topology, app: AppModel, cfg: BarrierConfig) -> Plan {
        match self {
            Scheme::Uniform => Uniform.optimize(topo, app, cfg),
            Scheme::MyopicMulti => Myopic.optimize(topo, app, cfg),
            Scheme::E2ePush => E2ePush.optimize(topo, app, cfg),
            Scheme::E2eShuffle => E2eShuffle.optimize(topo, app, cfg),
            Scheme::E2eMulti => AlternatingLp::default().optimize(topo, app, cfg),
        }
    }
}

/// One scheme's evaluated breakdown.
#[derive(Debug, Clone)]
pub struct SchemeResult {
    pub scheme: Scheme,
    pub breakdown: PhaseBreakdown,
}

/// Evaluate a set of schemes under the model.
pub fn run_schemes(
    topo: &Topology,
    app: AppModel,
    cfg: BarrierConfig,
    schemes: &[Scheme],
) -> Vec<SchemeResult> {
    schemes
        .iter()
        .map(|&scheme| {
            let plan = scheme.plan(topo, app, cfg);
            let tl = evaluate(topo, app, cfg, &plan);
            SchemeResult { scheme, breakdown: tl.breakdown() }
        })
        .collect()
}

/// Generate per-source synthetic records of `bytes_per_source` each
/// (fixed-size records, hash-uniform keys) — the §3.2 synthetic job's
/// input.
pub fn synthetic_inputs(
    n_sources: usize,
    bytes_per_source: usize,
    seed: u64,
) -> Vec<Vec<Record>> {
    crate::data::per_source(n_sources, bytes_per_source, seed, |src, bytes, rng| {
        let mut out = Vec::new();
        let mut total = 0usize;
        let mut i = 0u64;
        while total < bytes {
            let rec = Record::new(
                format!("k{src:02}-{i:08}-{:04x}", rng.next_below(65536)),
                "v".repeat(40),
            );
            total += rec.size();
            out.push(rec);
            i += 1;
        }
        out
    })
}

/// Run the engine `repeats` times with distinct data seeds, returning
/// per-run metrics (the 95% CI machinery of Figs 9–12).
pub fn run_engine_repeats(
    topo: &Topology,
    plan: &Plan,
    app: &dyn MapReduceApp,
    config: &JobConfig,
    inputs_for_seed: &dyn Fn(u64) -> Vec<Vec<Record>>,
    repeats: usize,
) -> Vec<JobMetrics> {
    (0..repeats)
        .map(|rep| {
            let inputs = inputs_for_seed(0xDA7A + rep as u64);
            run_job(topo, plan, app, config, &inputs).metrics
        })
        .collect()
}

/// Measure the synthetic app's α on a probe input (profiling, §2.1).
pub fn probe_alpha(alpha: f64) -> f64 {
    let app = SyntheticApp::new(alpha);
    let recs: Vec<Record> = (0..2000)
        .map(|i| Record::new(format!("k{i:06}"), "v".repeat(40)))
        .collect();
    crate::apps::measure_alpha(&app, &recs)
}

/// Deterministic per-experiment RNG.
pub fn exp_rng(tag: &str) -> Pcg64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in tag.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    Pcg64::new(h)
}
