//! `mrperf experiment resilience` — checkpoint/resume and dead-letter
//! accounting under injected dynamics.
//!
//! For each dynamics profile the sweep runs one churn-style cell
//! (identical workload construction to `experiment churn` via
//! [`super::churn::cell_setup`], so rows here are apples-to-apples with
//! that table) across retry budget × coordinator-crash time:
//!
//! * the **uninterrupted** run (no checkpoints, no crash) is the
//!   reference;
//! * the **crashed** run checkpoints every 1/8 of the reference
//!   makespan and kills the coordinator at the given fraction of it,
//!   resuming from the latest snapshot;
//! * the `bit-identical` column asserts the recovery invariant: the
//!   resumed run's metrics match the reference bit for bit (makespan,
//!   delivered/dead-lettered bytes, requeue and DLQ counters,
//!   fluid re-solve count — everything except the restart counter,
//!   which is provenance, not physics).
//!
//! Budget 1 sends every failure-evicted work item straight to the
//! dead-letter queue (`partial` outcome, non-zero DLQ columns); the
//! default budget 4 absorbs the seeded profiles' failures (`complete`,
//! empty DLQ). Byte conservation
//! (`shuffle_bytes_delivered + dlq_bytes == shuffle_bytes`) is asserted
//! on every run.

use crate::engine::dynamics::{DynProfile, ScenarioTrace, TraceShape};
use crate::engine::executor::JobOutcome;
use crate::engine::job::JobConfig;
use crate::engine::metrics::JobMetrics;
use crate::engine::{run_job, run_job_with_recovery, JobResult, RecoveryOpts};
use crate::platform::scale::parse_spec_config;
use crate::util::table::Table;

use super::churn::cell_setup;

/// Default platform: one churn-sweep size, kept modest because every
/// (profile, budget, crash) cell is a full engine run.
pub const DEFAULT_GEN: &str = "hier-wan:64";

const PROFILES: [DynProfile; 2] = [DynProfile::Failures, DynProfile::Churn];
const BUDGETS: [u32; 2] = [1, 4];
const CRASH_FRACS: [f64; 2] = [0.3, 0.7];
const TRACE_SEED: u64 = 7;

/// The determinism fingerprint compared between the uninterrupted and
/// the crash/resume run — every physics-bearing field, bit-exact;
/// `coordinator_restarts` is deliberately excluded (provenance).
fn fingerprint(m: &JobMetrics) -> (u64, u64, u64, u64, usize, usize, usize, u64) {
    (
        m.makespan.to_bits(),
        m.shuffle_bytes_delivered.to_bits(),
        m.push_bytes_delivered.to_bits(),
        m.dlq_bytes.to_bits(),
        m.tasks_requeued,
        m.splits_dead_lettered,
        m.ranges_dead_lettered,
        m.fluid_resolves,
    )
}

fn check_conservation(r: &JobResult, what: &str) {
    let m = &r.metrics;
    assert_eq!(
        (m.shuffle_bytes_delivered + m.dlq_bytes).to_bits(),
        m.shuffle_bytes.to_bits(),
        "{what}: delivered + dead-lettered must equal shuffled exactly"
    );
    let partial = matches!(r.outcome, JobOutcome::PartialWithDlq);
    assert_eq!(partial, !r.dlq.is_empty(), "{what}: outcome/DLQ mismatch");
}

pub fn run() -> Vec<Table> {
    run_with(DEFAULT_GEN).expect("resilience defaults are valid")
}

/// Run the sweep on a `--gen KIND:NODES[:SEED]` platform.
pub fn run_with(gen_spec: &str) -> Result<Vec<Table>, String> {
    let base = parse_spec_config(gen_spec)?;
    let setup = cell_setup(&base, base.nodes);

    // Trace horizon: the static (no-dynamics) plan-local makespan, the
    // churn-experiment idiom — every profile sees the same event shape.
    let static_m =
        run_job(&setup.topo, &setup.plan, &setup.sapp, &JobConfig::optimized(), &setup.inputs)
            .metrics;
    let horizon = static_m.makespan.max(1e-9);
    let shape = TraceShape::of(&setup.topo, horizon);

    let mut table = Table::new(
        "resilience: crash/resume bit-identity + dead-letter accounting \
         (reference = uninterrupted run of the same cell)",
        &[
            "profile",
            "budget",
            "crash@",
            "makespan s",
            "restarts",
            "dlq splits",
            "dlq ranges",
            "dlq KB",
            "outcome",
            "bit-identical",
        ],
    );

    for profile in PROFILES {
        let trace = ScenarioTrace::generate(profile, TRACE_SEED, &shape);
        for budget in BUDGETS {
            let config = JobConfig {
                max_attempts: budget,
                ..JobConfig::optimized()
            }
            .with_dynamics(trace.clone());

            let reference =
                run_job(&setup.topo, &setup.plan, &setup.sapp, &config, &setup.inputs);
            check_conservation(&reference, "reference");

            for frac in CRASH_FRACS {
                let opts = RecoveryOpts {
                    checkpoint_every: Some(reference.metrics.makespan / 8.0),
                    crash_at: Some(reference.metrics.makespan * frac),
                    ..RecoveryOpts::default()
                };
                let resumed = run_job_with_recovery(
                    &setup.topo,
                    &setup.plan,
                    &setup.sapp,
                    &config,
                    &setup.inputs,
                    &opts,
                )?;
                check_conservation(&resumed, "resumed");
                let identical =
                    fingerprint(&reference.metrics) == fingerprint(&resumed.metrics);
                let m = &resumed.metrics;
                table.add_row(vec![
                    profile.label().to_string(),
                    budget.to_string(),
                    format!("{:.0}%", frac * 100.0),
                    format!("{:.3}", m.makespan),
                    m.coordinator_restarts.to_string(),
                    m.splits_dead_lettered.to_string(),
                    m.ranges_dead_lettered.to_string(),
                    format!("{:.1}", m.dlq_bytes / 1e3),
                    match resumed.outcome {
                        JobOutcome::Complete => "complete".to_string(),
                        JobOutcome::PartialWithDlq => "partial".to_string(),
                    },
                    if identical { "yes" } else { "NO" }.to_string(),
                ]);
            }
        }
    }
    Ok(vec![table])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One small cell through the full sweep machinery: recovery must be
    /// bit-identical and conservation must hold (the row asserts it).
    #[test]
    fn small_cell_is_bit_identical() {
        let tables = run_with("hier-wan:16").unwrap();
        assert_eq!(tables.len(), 1);
        let t = &tables[0];
        assert_eq!(t.rows.len(), PROFILES.len() * BUDGETS.len() * CRASH_FRACS.len());
        for row in &t.rows {
            assert_eq!(row.last().unwrap(), "yes", "recovery not bit-identical: {row:?}");
        }
    }
}
