//! The model-experiment figures:
//!
//! * **Fig 5** — end-to-end vs myopic (multi-phase both), per-phase
//!   stacked times, α ∈ {0.1, 1, 10}, 8-DC environment.
//! * **Fig 6** — single-phase (e2e push / e2e shuffle) vs multi-phase.
//! * **Fig 7** — barrier relaxation: optimized makespan per barrier
//!   configuration normalized to the all-global optimum.
//! * **Fig 8** — environment sweep (1/2/4/8 DCs): myopic and e2e
//!   makespans normalized to uniform.

use crate::model::barrier::BarrierConfig;
use crate::model::makespan::{makespan, AppModel};
use crate::optimizer::{AlternatingLp, PlanOptimizer};
use crate::platform::{build_env, EnvKind};
use crate::util::table::{fmt_pct, fmt_secs, Table};

use super::common::{run_schemes, Scheme};

pub const ALPHAS: [f64; 3] = [0.1, 1.0, 10.0];

fn scheme_table(title: &str, schemes: &[Scheme]) -> Table {
    let mut t = Table::new(
        title,
        &["alpha", "scheme", "push", "map", "shuffle", "reduce", "total", "vs uniform"],
    )
    .label_first();
    let topo = build_env(EnvKind::Global8);
    let cfg = BarrierConfig::ALL_GLOBAL;
    for &alpha in &ALPHAS {
        let app = AppModel::new(alpha);
        let results = run_schemes(&topo, app, cfg, schemes);
        let uniform_total = results
            .iter()
            .find(|r| r.scheme == Scheme::Uniform)
            .map(|r| r.breakdown.total())
            .unwrap();
        for r in &results {
            let b = r.breakdown;
            let red = 1.0 - b.total() / uniform_total;
            t.add_row(vec![
                format!("{alpha}"),
                r.scheme.label().into(),
                fmt_secs(b.push),
                fmt_secs(b.map),
                fmt_secs(b.shuffle),
                fmt_secs(b.reduce),
                fmt_secs(b.total()),
                if r.scheme == Scheme::Uniform {
                    "—".into()
                } else {
                    format!("-{}", fmt_pct(red))
                },
            ]);
        }
    }
    t
}

pub fn run_fig5() -> Vec<Table> {
    vec![scheme_table(
        "Fig 5 — uniform vs myopic multi-phase vs e2e multi-phase (8-DC, G-G-G)",
        &[Scheme::Uniform, Scheme::MyopicMulti, Scheme::E2eMulti],
    )]
}

pub fn run_fig6() -> Vec<Table> {
    vec![scheme_table(
        "Fig 6 — single-phase vs multi-phase end-to-end optimization (8-DC, G-G-G)",
        &[
            Scheme::Uniform,
            Scheme::E2ePush,
            Scheme::E2eShuffle,
            Scheme::E2eMulti,
        ],
    )]
}

pub fn run_fig7() -> Vec<Table> {
    let topo = build_env(EnvKind::Global8);
    let mut t = Table::new(
        "Fig 7 — optimized makespan per barrier configuration, normalized to G-G-G optimum",
        &["alpha", "boundary relaxed", "config", "makespan s", "normalized"],
    )
    .label_first();
    for &alpha in &ALPHAS {
        let app = AppModel::new(alpha);
        let base_cfg = BarrierConfig::ALL_GLOBAL;
        let opt = AlternatingLp::default();
        let base_plan = opt.optimize(&topo, app, base_cfg);
        let base = makespan(&topo, app, base_cfg, &base_plan);
        for (label, cfg) in BarrierConfig::fig7_set() {
            let plan = opt.optimize(&topo, app, cfg);
            let ms = makespan(&topo, app, cfg, &plan);
            t.add_row(vec![
                format!("{alpha}"),
                label.into(),
                cfg.label(),
                fmt_secs(ms),
                format!("{:.3}", ms / base),
            ]);
        }
    }
    vec![t]
}

pub fn run_fig8() -> Vec<Table> {
    let mut t = Table::new(
        "Fig 8 — myopic and e2e vs uniform across network environments (G-G-G)",
        &["env", "alpha", "scheme", "makespan s", "normalized to uniform"],
    )
    .label_first();
    for kind in EnvKind::all() {
        let topo = build_env(kind);
        for &alpha in &ALPHAS {
            let app = AppModel::new(alpha);
            let cfg = BarrierConfig::ALL_GLOBAL;
            let results = run_schemes(
                &topo,
                app,
                cfg,
                &[Scheme::Uniform, Scheme::MyopicMulti, Scheme::E2eMulti],
            );
            let uniform_total = results[0].breakdown.total();
            for r in &results {
                t.add_row(vec![
                    kind.label().into(),
                    format!("{alpha}"),
                    r.scheme.label().into(),
                    fmt_secs(r.breakdown.total()),
                    format!("{:.3}", r.breakdown.total() / uniform_total),
                ]);
            }
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::plan::Plan;

    /// Fig 5 headline: e2e multi ≪ myopic ≪/≈ uniform on the 8-DC env.
    #[test]
    fn fig5_ordering_holds() {
        let topo = build_env(EnvKind::Global8);
        let cfg = BarrierConfig::ALL_GLOBAL;
        for &alpha in &ALPHAS {
            let app = AppModel::new(alpha);
            let res = run_schemes(
                &topo,
                app,
                cfg,
                &[Scheme::Uniform, Scheme::MyopicMulti, Scheme::E2eMulti],
            );
            let uni = res[0].breakdown.total();
            let myo = res[1].breakdown.total();
            let e2e = res[2].breakdown.total();
            assert!(e2e <= myo + 1e-6, "α={alpha}: e2e {e2e} vs myopic {myo}");
            assert!(e2e < 0.5 * uni, "α={alpha}: expect ≥50% reduction, got e2e {e2e} vs uniform {uni}");
        }
    }

    /// Fig 8 headline: optimization benefit grows with distribution;
    /// in the homogeneous local DC uniform is already near-optimal.
    #[test]
    fn fig8_benefit_grows_with_heterogeneity() {
        let cfg = BarrierConfig::ALL_GLOBAL;
        let app = AppModel::new(1.0);

        let local = build_env(EnvKind::LocalDataCenter);
        let uni_local =
            makespan(&local, app, cfg, &Plan::uniform(8, 8, 8));
        let e2e_local = makespan(
            &local,
            app,
            cfg,
            &AlternatingLp::default().optimize(&local, app, cfg),
        );
        let local_gain = 1.0 - e2e_local / uni_local;

        let global = build_env(EnvKind::Global8);
        let uni_g = makespan(&global, app, cfg, &Plan::uniform(8, 8, 8));
        let e2e_g = makespan(
            &global,
            app,
            cfg,
            &AlternatingLp::default().optimize(&global, app, cfg),
        );
        let global_gain = 1.0 - e2e_g / uni_g;

        assert!(
            global_gain > local_gain + 0.2,
            "global gain {global_gain} should far exceed local gain {local_gain}"
        );
        assert!(local_gain < 0.3, "uniform should be near-optimal locally");
    }

    /// Fig 7 headline: relaxing barriers never hurts the optimum.
    #[test]
    fn fig7_relaxation_monotone() {
        let topo = build_env(EnvKind::Global4);
        let app = AppModel::new(1.0);
        let opt = AlternatingLp { random_starts: 1, ..Default::default() };
        let base = makespan(
            &topo,
            app,
            BarrierConfig::ALL_GLOBAL,
            &opt.optimize(&topo, app, BarrierConfig::ALL_GLOBAL),
        );
        for (_, cfg) in BarrierConfig::fig7_set() {
            let ms = makespan(&topo, app, cfg, &opt.optimize(&topo, app, cfg));
            assert!(
                ms <= base * 1.01,
                "{}: {ms} should not exceed G-G-G optimum {base}",
                cfg.label()
            );
        }
    }
}
