//! Tenancy experiment: offered load × cross-job policy on ONE shared
//! fluid network.
//!
//! The pipeline: optimize one end-to-end plan for the generated
//! topology, run it **standalone** once to calibrate the service time
//! `S`, then sweep offered loads ρ — each a seeded Poisson stream of
//! jobs at rate λ = ρ / S — under each cross-job [`StreamPolicy`]
//! (`fifo` | `fair-share` | `deadline`). Every job gets the deadline
//! `arrival + slack × S` regardless of policy, so the goodput column
//! (jobs finished by their deadline) is comparable across rows: FIFO
//! protects latency per admitted job but queues the rest; fair-share
//! overlaps jobs on the shared links (max-min contention stretches
//! each); deadline-aware admission sheds jobs it estimates hopeless
//! instead of letting them rot in the queue.
//!
//! An explicit `--arrivals PROFILE[:RATE[:SEED]]` overrides the load
//! sweep with that single arrival process. Job latencies are sojourn
//! times (`finished - arrival`); p50/p99 go through the NaN-safe
//! [`percentile`]. Per-job exact byte conservation
//! (`push_bytes_delivered == push_bytes`,
//! `shuffle_bytes_delivered == shuffle_bytes`) is asserted for every
//! completed job of every cell, including under an optional
//! platform-wide `--dynamics` trace.
//!
//! [`StreamPolicy`]: crate::engine::scheduler::StreamPolicy
//! [`percentile`]: crate::util::stats::percentile

use crate::apps::SyntheticApp;
use crate::engine::dynamics::{self, ScenarioTrace, TraceShape};
use crate::engine::job::{batch_size, JobConfig, Record};
use crate::engine::tenancy::{run_stream, ArrivalSpec, StreamJob};
use crate::engine::{run_job, stream_policy};
use crate::experiments::common::synthetic_inputs;
use crate::model::barrier::BarrierConfig;
use crate::model::makespan::AppModel;
use crate::optimizer::{AlternatingLp, PlanOptimizer};
use crate::platform::scale::{generate, parse_spec_config, ScaleConfig};
use crate::util::stats::percentile;
use crate::util::table::Table;

/// Defaults for `mrperf experiment tenancy` (and `experiment all`).
pub const DEFAULT_GEN: &str = "hier-wan:64";
pub const DEFAULT_JOBS: usize = 10;
pub const DEFAULT_LOADS: &str = "0.5,1,2";
pub const DEFAULT_POLICIES: &str = "fifo,fair-share,deadline";
pub const DEFAULT_SLACK: f64 = 3.0;

/// Input volume per source: modest, so a ten-job stream stays quick
/// while still pushing real bytes through the shared links.
pub const TENANCY_BYTES_PER_SOURCE: usize = 4_096;

/// Data seed for the calibration inputs; job j uses `+ 1 + j`.
const INPUT_SEED: u64 = 0x7E4A;
/// Seed of the swept Poisson arrival processes (explicit `--arrivals`
/// specs carry their own).
const ARRIVAL_SEED: u64 = 11;

/// One (policy, sweep point) cell.
#[derive(Debug, Clone)]
pub struct TenancyCell {
    pub policy: &'static str,
    /// Offered load ρ (`None` when an explicit `--arrivals` spec
    /// replaced the sweep).
    pub load: Option<f64>,
    /// Arrival rate λ in jobs per virtual second (`None` for explicit
    /// trace arrivals, which have no single rate).
    pub lambda: Option<f64>,
    /// Jobs submitted.
    pub jobs: usize,
    pub completed: usize,
    pub rejected: usize,
    /// Sojourn-time percentiles over completed jobs (NaN when no job
    /// completed).
    pub p50: f64,
    pub p99: f64,
    pub max: f64,
    /// Percent of submitted jobs that finished by their deadline.
    pub goodput: f64,
}

fn parse_loads(spec: &str) -> Result<Vec<f64>, String> {
    let mut out = Vec::new();
    for tok in spec.split(',') {
        let v: f64 = tok.trim().parse().map_err(|_| {
            format!(
                "invalid value '{spec}' for --loads ('{tok}' is not a number; \
                 expected comma-separated offered loads, e.g. 0.5,1,2)"
            )
        })?;
        if !(v.is_finite() && v > 0.0) {
            return Err(format!(
                "invalid value '{spec}' for --loads (loads must be finite and > 0)"
            ));
        }
        out.push(v);
    }
    Ok(out)
}

/// Run the sweep; deterministic given the knobs. An explicit
/// `arrivals` spec overrides the `loads` sweep (one point per policy).
#[allow(clippy::too_many_arguments)]
pub fn run_points(
    gen_spec: &str,
    arrivals: Option<&str>,
    n_jobs: usize,
    loads: &[f64],
    policies: &[&str],
    slack: f64,
    dyn_spec: Option<&str>,
    threads: usize,
) -> Result<Vec<TenancyCell>, String> {
    if n_jobs == 0 {
        return Err("invalid value '0' for --jobs (need at least one job)".into());
    }
    if threads == 0 {
        return Err(
            "invalid value '0' for --threads (need at least one solver thread)".into()
        );
    }
    if !(slack.is_finite() && slack > 0.0) {
        return Err(format!(
            "invalid value '{slack}' for --slack (must be finite and > 0)"
        ));
    }
    if policies.is_empty() {
        return Err(
            "invalid value '' for --policies (expected comma-separated \
             fifo | fair-share | deadline)"
                .into(),
        );
    }
    for p in policies {
        stream_policy(p)?; // fail fast on unknown names
    }
    let arrival_spec = arrivals.map(ArrivalSpec::parse).transpose()?;
    if arrival_spec.is_none() {
        if loads.is_empty() {
            return Err("invalid value '' for --loads (need at least one load)".into());
        }
        for &l in loads {
            if !(l.is_finite() && l > 0.0) {
                return Err(format!(
                    "invalid value '{l}' for --loads (loads must be finite and > 0)"
                ));
            }
        }
    }

    let base = parse_spec_config(gen_spec)?;
    let gen = generate(&ScaleConfig::new(base.kind, base.nodes).seed(base.seed));
    let n_sources = gen.n_sources();
    let cal_inputs = synthetic_inputs(n_sources, TENANCY_BYTES_PER_SOURCE, INPUT_SEED);
    // Evaluate the model (and thus the optimizer) on the volume the
    // engine will actually simulate (the fig4 idiom).
    let mean_bytes =
        cal_inputs.iter().map(|v| batch_size(v) as f64).sum::<f64>() / n_sources as f64;
    let topo = gen.with_uniform_data(mean_bytes);
    let app = AppModel::new(1.0);
    let plan = AlternatingLp::default().optimize(&topo, app, BarrierConfig::HADOOP);
    let sapp = SyntheticApp::new(1.0);
    let mut config = JobConfig::optimized();
    // Metrics are bit-identical for every thread count ≥ 1 (property-
    // tested in tests/engine_threads.rs), so the knob only changes wall
    // time — every cell, including the calibration run, uses it.
    config.threads = threads;

    // Calibration run: the standalone service time S anchors the swept
    // arrival rates (λ = ρ / S), every deadline (arrival + slack × S)
    // and the deadline policy's service estimate.
    let s = run_job(&topo, &plan, &sapp, &config, &cal_inputs)
        .metrics
        .makespan
        .max(1e-9);

    let trace = match dyn_spec {
        None => None,
        Some(ds) => {
            let (profile, seed) = dynamics::parse_spec(ds)?;
            // Horizon sized to a fully serialized stream, so events
            // land inside every sweep point's busy period.
            let horizon = s * n_jobs as f64;
            Some(ScenarioTrace::generate(profile, seed, &TraceShape::of(&topo, horizon)))
        }
    };

    // Per-job inputs (distinct seeds) are shared across sweep points:
    // the same job stream meets every (policy, load) cell.
    let job_inputs: Vec<Vec<Vec<Record>>> = (0..n_jobs)
        .map(|j| {
            synthetic_inputs(n_sources, TENANCY_BYTES_PER_SOURCE, INPUT_SEED + 1 + j as u64)
        })
        .collect();

    let points: Vec<(Option<f64>, ArrivalSpec)> = match &arrival_spec {
        Some(spec) => vec![(None, spec.clone())],
        None => loads
            .iter()
            .map(|&rho| {
                (Some(rho), ArrivalSpec::Poisson { rate: rho / s, seed: ARRIVAL_SEED })
            })
            .collect(),
    };

    let mut cells = Vec::new();
    for &pname in policies {
        for (load, spec) in &points {
            let arr = spec.generate(n_jobs);
            let lambda = match spec {
                ArrivalSpec::Poisson { rate, .. } | ArrivalSpec::Periodic { rate } => {
                    Some(*rate)
                }
                ArrivalSpec::Trace(_) => None,
            };
            let jobs: Vec<StreamJob> = arr
                .iter()
                .zip(&job_inputs)
                .map(|(&t, inputs)| {
                    let mut sj = StreamJob::new(t, &plan, &sapp, &config, inputs);
                    sj.deadline = t + slack * s;
                    sj.est_service = s;
                    sj
                })
                .collect();
            let mut policy = stream_policy(pname)?;
            let name = policy.name();
            let result = run_stream(&topo, &jobs, policy.as_mut(), trace.as_ref())?;

            let mut lats = Vec::new();
            let (mut completed, mut rejected, mut met) = (0usize, 0usize, 0usize);
            for o in &result.jobs {
                if o.rejected {
                    rejected += 1;
                    continue;
                }
                let m = o
                    .metrics
                    .as_ref()
                    .expect("non-rejected stream job must carry metrics");
                assert_eq!(
                    m.push_bytes_delivered, m.push_bytes,
                    "{name} lost push bytes in a concurrent stream"
                );
                assert_eq!(
                    m.shuffle_bytes_delivered, m.shuffle_bytes,
                    "{name} lost shuffle bytes in a concurrent stream"
                );
                assert_eq!(
                    m.output_records, m.input_records,
                    "{name} lost records in a concurrent stream"
                );
                completed += 1;
                if o.met_deadline {
                    met += 1;
                }
                lats.push(o.latency());
            }
            let (p50, p99, max) = if lats.is_empty() {
                (f64::NAN, f64::NAN, f64::NAN)
            } else {
                (
                    percentile(&lats, 50.0),
                    percentile(&lats, 99.0),
                    lats.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
                )
            };
            cells.push(TenancyCell {
                policy: name,
                load: *load,
                lambda,
                jobs: result.jobs.len(),
                completed,
                rejected,
                p50,
                p99,
                max,
                goodput: met as f64 / result.jobs.len() as f64 * 100.0,
            });
        }
    }
    Ok(cells)
}

/// Render the tenancy table for explicit knobs (the CLI entry point).
#[allow(clippy::too_many_arguments)]
pub fn run_with(
    gen_spec: &str,
    arrivals: Option<&str>,
    n_jobs: usize,
    loads_spec: &str,
    policies_spec: &str,
    slack: f64,
    dyn_spec: Option<&str>,
    threads: usize,
) -> Result<Vec<Table>, String> {
    let loads = parse_loads(loads_spec)?;
    let policies: Vec<&str> = policies_spec
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect();
    if policies.is_empty() {
        return Err(format!(
            "invalid value '{policies_spec}' for --policies (expected \
             comma-separated fifo | fair-share | deadline)"
        ));
    }
    let cells =
        run_points(gen_spec, arrivals, n_jobs, &loads, &policies, slack, dyn_spec, threads)?;

    let arrivals_note = match arrivals {
        Some(a) => format!(" --arrivals {a} (overrides --loads)"),
        None => String::new(),
    };
    let dyn_note = match dyn_spec {
        Some(d) => format!(" --dynamics {d}"),
        None => String::new(),
    };
    let threads_note = if threads > 1 {
        format!(" --threads {threads}")
    } else {
        String::new()
    };
    let mut t = Table::new(
        format!(
            "tenancy: offered load × cross-job policy on one shared fluid network \
             (--gen {gen_spec} --jobs {n_jobs} --slack \
             {slack}{arrivals_note}{dyn_note}{threads_note}) — \
             latencies are sojourn times, goodput counts deadline \
             (arrival + slack × S) hits"
        ),
        &[
            "policy",
            "load",
            "lambda (j/s)",
            "jobs",
            "done",
            "rejected",
            "p50 (s)",
            "p99 (s)",
            "max (s)",
            "goodput",
        ],
    );
    let fs = |x: f64| if x.is_nan() { "-".to_string() } else { format!("{x:.4}") };
    for c in &cells {
        t.add_row(vec![
            c.policy.to_string(),
            c.load.map_or_else(|| "-".to_string(), |l| format!("{l:.2}")),
            c.lambda.map_or_else(|| "-".to_string(), |l| format!("{l:.4}")),
            c.jobs.to_string(),
            c.completed.to_string(),
            c.rejected.to_string(),
            fs(c.p50),
            fs(c.p99),
            fs(c.max),
            format!("{:.0}%", c.goodput),
        ]);
    }
    Ok(vec![t])
}

/// The `tenancy` experiment with its default knobs (used by
/// `mrperf experiment all`).
pub fn run() -> Vec<Table> {
    run_with(
        DEFAULT_GEN,
        None,
        DEFAULT_JOBS,
        DEFAULT_LOADS,
        DEFAULT_POLICIES,
        DEFAULT_SLACK,
        None,
        1,
    )
    .expect("default tenancy knobs are valid")
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Same knobs → bit-identical cells (sized down so the debug-build
    /// test stays quick).
    #[test]
    fn tenancy_cells_are_deterministic() {
        let run = || {
            run_points(
                "hier-wan:16",
                None,
                4,
                &[1.0],
                &["fifo", "fair-share", "deadline"],
                3.0,
                None,
                1,
            )
            .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.len(), 3, "3 policies × 1 load");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.policy, y.policy);
            assert_eq!(x.p50.to_bits(), y.p50.to_bits());
            assert_eq!(x.p99.to_bits(), y.p99.to_bits());
            assert_eq!(x.max.to_bits(), y.max.to_bits());
            assert_eq!(
                (x.jobs, x.completed, x.rejected),
                (y.jobs, y.completed, y.rejected)
            );
        }
        // Every submitted job is accounted for.
        for c in &a {
            assert_eq!(c.completed + c.rejected, c.jobs, "{c:?}");
        }
    }

    /// Four simultaneous arrivals, slack 3 × S: deadline-aware
    /// admission estimates the 4th job's finish at 4 × S > deadline and
    /// sheds exactly it.
    #[test]
    fn deadline_policy_rejects_overload() {
        let cells = run_points(
            "hier-wan:16",
            Some("trace:0,0,0,0"),
            4,
            &[1.0],
            &["deadline"],
            3.0,
            None,
            1,
        )
        .unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].rejected, 1, "{:?}", cells[0]);
        assert_eq!(cells[0].completed, 3, "{:?}", cells[0]);
    }

    /// An explicit --arrivals spec replaces the whole load sweep.
    #[test]
    fn explicit_arrivals_override_loads() {
        let cells = run_points(
            "hier-wan:16",
            Some("periodic:1"),
            3,
            &[0.5, 1.0, 2.0],
            &["fifo"],
            3.0,
            None,
            1,
        )
        .unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].load, None);
        assert_eq!(cells[0].lambda, Some(1.0));
        assert_eq!(cells[0].jobs, 3);
    }

    #[test]
    fn rejects_bad_knobs() {
        let ok_policies = ["fifo"];
        let e = run_points("hier-wan:16", None, 0, &[1.0], &ok_policies, 3.0, None, 1)
            .unwrap_err();
        assert!(e.contains("--jobs"), "{e}");
        let e = run_points("hier-wan:16", None, 2, &[0.0], &ok_policies, 3.0, None, 1)
            .unwrap_err();
        assert!(e.contains("--loads"), "{e}");
        let e = run_points("hier-wan:16", None, 2, &[1.0], &["bogus"], 3.0, None, 1)
            .unwrap_err();
        assert!(e.contains("stream policy"), "{e}");
        let e = run_points("hier-wan:16", None, 2, &[1.0], &ok_policies, f64::NAN, None, 1)
            .unwrap_err();
        assert!(e.contains("--slack"), "{e}");
        let e = run_points("hier-wan:16", None, 2, &[1.0], &ok_policies, 3.0, None, 0)
            .unwrap_err();
        assert!(e.contains("--threads"), "{e}");
        let e = run_points(
            "hier-wan:16",
            Some("uniform:1"),
            2,
            &[1.0],
            &ok_policies,
            3.0,
            None,
            1,
        )
        .unwrap_err();
        assert!(e.contains("--arrivals"), "{e}");
        assert!(
            run_points("nope:16", None, 2, &[1.0], &ok_policies, 3.0, None, 1).is_err()
        );
        assert!(
            run_with("hier-wan:16", None, 2, "abc", "fifo", 3.0, None, 1).is_err(),
            "--loads must parse"
        );
        assert!(
            run_with("hier-wan:16", None, 2, "1", " , ", 3.0, None, 1).is_err(),
            "--policies must name a policy"
        );
    }
}
