//! # mrperf — geo-distributed MapReduce modeling, optimization & execution
//!
//! A reproduction of *"Optimizing MapReduce for Highly Distributed
//! Environments"* (Heintz, Chandra, Sitaraman; 2012) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **[`platform`]** — the tripartite source/mapper/reducer platform
//!   model, PlanetLab measurement dataset (Table 1) and the evaluation's
//!   four network environments (§4.1).
//! * **[`model`]** — execution plans (eqs 1–3), barrier semantics, the
//!   closed-form makespan model (eqs 4–14) and its smooth relaxation.
//! * **[`solver`]** — from-scratch LP (simplex) and MIP (branch & bound)
//!   with the paper's piecewise-linear bilinear linearization (§2.3).
//! * **[`optimizer`]** — the execution-plan optimizers the evaluation
//!   compares: uniform, myopic, single-phase, end-to-end multi-phase
//!   (alternating LP and PWL-MIP), and a gradient optimizer backed by the
//!   AOT-compiled JAX/Pallas artifact via PJRT.
//! * **[`engine`]** — a plan-enforcing MapReduce runtime (the paper's
//!   modified Hadoop, §3.1) over a virtual-time emulated WAN, with
//!   speculative execution and work stealing (§4.6.4).
//! * **[`apps`]**/**[`data`]** — the evaluation applications (Word Count,
//!   Sessionization, Full Inverted Index, synthetic-α) and seeded
//!   workload generators.
//! * **[`runtime`]** — the PJRT client wrapper that loads
//!   `artifacts/*.hlo.txt` produced by `python/compile/aot.py`.
//! * **[`experiments`]** — regenerates every table and figure of the
//!   paper's evaluation (Table 1, Figs 4–12).
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); the
//! rust binary is self-contained afterwards.

pub mod apps;
pub mod data;
pub mod engine;
pub mod experiments;
pub mod model;
pub mod optimizer;
pub mod platform;
pub mod runtime;
pub mod solver;
pub mod util;
