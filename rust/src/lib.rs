//! # mrperf — geo-distributed MapReduce modeling, optimization & execution
//!
//! A reproduction of *"Optimizing MapReduce for Highly Distributed
//! Environments"* (Heintz, Chandra, Sitaraman; 2012) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **[`platform`]** — the tripartite source/mapper/reducer platform
//!   model, PlanetLab measurement dataset (Table 1), the evaluation's
//!   four network environments (§4.1), and parameterized generators
//!   (`platform::scale`) for 16–512-node hierarchical-WAN, federated
//!   multi-datacenter and edge-heavy platforms.
//! * **[`model`]** — execution plans (eqs 1–3), barrier semantics, the
//!   closed-form makespan model (eqs 4–14) and its smooth relaxation.
//! * **[`solver`]** — from-scratch LP (simplex) and MIP (branch & bound)
//!   with the paper's piecewise-linear bilinear linearization (§2.3).
//! * **[`optimizer`]** — the execution-plan optimizers the evaluation
//!   compares: uniform, myopic, single-phase, end-to-end multi-phase
//!   (alternating LP and PWL-MIP), a gradient optimizer backed by the
//!   AOT-compiled JAX/Pallas artifact via PJRT, and a failure-aware
//!   wrapper (`optimizer::hedged`) that re-solves the alternating LP
//!   against a failure-discounted platform so plans hedge the shuffle
//!   split against an expected reducer failure rate.
//! * **[`engine`]** — a plan-enforcing MapReduce runtime (the paper's
//!   modified Hadoop, §3.1) built as a discrete-event core: a max-min-
//!   fair fluid simulation (`engine::fluid`), a virtual-clock event heap
//!   (`engine::events`), pluggable scheduling policies covering strict
//!   plan enforcement plus speculative execution, (locality-aware) work
//!   stealing and reduce re-partitioning (`engine::scheduler`, §4.6.4),
//!   a seeded dynamics / fault-injection layer (`engine::dynamics`:
//!   time-varying bandwidth, mapper *and reducer* failures, stragglers,
//!   correlated data staleness), a budgeted adversarial trace search
//!   (`engine::adversary`: the worst-case churn for a given plan, with
//!   the executor as deterministic oracle), and a thin orchestrator
//!   (`engine::executor`) driving push/map/shuffle/reduce as events,
//!   re-queuing map work lost to injected failures, replaying reduce
//!   work through a retained shuffle-transfer table (restartable
//!   reduce) and re-sending stale push data through a retained
//!   push-transfer table; plus a multi-tenant job-stream layer
//!   (`engine::tenancy`) where seeded arrival processes feed cross-job
//!   admission policies (FIFO, fair-share, deadline-aware) and every
//!   in-flight job contends on ONE shared fluid network.
//! * **[`apps`]**/**[`data`]** — the evaluation applications (Word Count,
//!   Sessionization, Full Inverted Index, synthetic-α) and seeded
//!   workload generators.
//! * **[`runtime`]** — the PJRT client wrapper that loads
//!   `artifacts/*.hlo.txt` produced by `python/compile/aot.py`.
//! * **[`experiments`]** — regenerates every table and figure of the
//!   paper's evaluation (Table 1, Figs 4–12), plus the post-paper
//!   `scale` sweep over generated 16–256-node platforms, the `churn`
//!   comparison of plan-local vs dynamic scheduling under injected
//!   platform dynamics, the `adversary` worst-case trace search and
//!   the `tenancy` multi-tenant load × policy sweep.
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); the
//! rust binary is self-contained afterwards. The default cargo build has
//! **zero external dependencies** (error handling included, see
//! `util::errors`); the PJRT artifact path is opt-in via the `pjrt`
//! feature, which expects the vendored `xla` crate.
//!
//! **Further reading:** the layer map, paper-§ ↔ module table and the
//! determinism / byte-conservation invariants each layer must preserve
//! live in `docs/ARCHITECTURE.md` (repository root); the full CLI
//! reference is `docs/CLI.md`; the paper-figure ↔ experiment mapping is
//! `rust/src/experiments/README.md`.

pub mod apps;
pub mod data;
pub mod engine;
pub mod experiments;
pub mod model;
pub mod optimizer;
pub mod platform;
pub mod runtime;
pub mod solver;
pub mod util;
