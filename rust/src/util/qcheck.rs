//! Minimal property-based testing framework.
//!
//! The vendored registry carries no `proptest`/`quickcheck`, so we roll a
//! small deterministic harness: a property is a closure over a [`Pcg64`];
//! the harness runs it for `cases` seeds derived from a base seed and, on
//! failure, reports the failing case seed so the case can be replayed by
//! seeding a generator directly.
//!
//! ```no_run
//! // (no_run: doctest binaries don't carry the libxla_extension rpath)
//! use mrperf::util::qcheck::{qcheck, Config};
//! qcheck(Config::default().cases(200), "addition commutes", |rng| {
//!     let a = rng.next_f64();
//!     let b = rng.next_f64();
//!     let ok = (a + b - (b + a)).abs() < 1e-15;
//!     if ok { Ok(()) } else { Err(format!("a={a} b={b}")) }
//! });
//! ```

use super::rng::Pcg64;

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub base_seed: u64,
    pub cases: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { base_seed: 0xC0FFEE, cases: 100 }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.base_seed = s;
        self
    }
}

/// Run `prop` for `config.cases` independent cases; panics (test failure)
/// with the case index + seed on the first counterexample.
pub fn qcheck<F>(config: Config, name: &str, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    for case in 0..config.cases {
        let case_seed = config
            .base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = Pcg64::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{} (seed {case_seed:#x}): {msg}",
                config.cases
            );
        }
    }
}

/// Helper: assert two floats are close, returning a qcheck-style error.
pub fn close(a: f64, b: f64, tol: f64, ctx: &str) -> Result<(), String> {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} !~ {b} (tol {tol}, |Δ|={})", (a - b).abs()))
    }
}

/// Helper: assert a predicate with message context.
pub fn ensure(cond: bool, ctx: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(ctx.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        qcheck(Config::default().cases(50), "trivial", |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        qcheck(Config::default().cases(10), "fails", |rng| {
            let v = rng.next_f64();
            ensure(v < 0.5, format!("v={v}"))
        });
    }

    #[test]
    fn close_scales_tolerance() {
        assert!(close(1000.0, 1000.5, 1e-3, "big").is_ok());
        assert!(close(1.0, 1.0005, 1e-3, "small").is_ok());
        assert!(close(1.0, 1.1, 1e-3, "off").is_err());
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<u64> = Vec::new();
        qcheck(Config::default().cases(5), "record", |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        qcheck(Config::default().cases(5), "record", |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
