//! Deterministic pseudo-random number generation.
//!
//! The offline vendored registry does not carry the `rand` crate, so we
//! implement the generators we need from scratch:
//!
//! * [`SplitMix64`] — used for seeding streams; passes BigCrush for its
//!   intended use as a seed expander.
//! * [`Pcg64`] — PCG-XSL-RR 128/64, the workhorse generator for workload
//!   synthesis, multi-start initialization and property testing.
//!
//! Every consumer in the crate takes an explicit `&mut Pcg64` (or a seed)
//! so experiments are reproducible bit-for-bit given a seed.

/// SplitMix64 seed expander (Steele, Lea, Flood 2014).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSL-RR 128/64 (O'Neill 2014): 128-bit LCG state, 64-bit output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let i0 = sm.next_u64() as u128;
        let i1 = sm.next_u64() as u128;
        let mut rng = Self {
            state: (s0 << 64) | s1,
            inc: ((i0 << 64) | i1) | 1,
        };
        // Warm up past the seed correlation window.
        rng.next_u64();
        rng.next_u64();
        rng
    }

    /// Derive an independent child stream; used to give each simulated
    /// node / task its own generator without sharing mutable state.
    pub fn fork(&mut self) -> Pcg64 {
        Pcg64::new(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift with rejection.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Marsaglia polar method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Log-normal with the given parameters of the underlying normal.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // (0,1]
        -u.ln() / lambda
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index: zero total weight");
        let mut target = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Zipf(s) sampler over ranks `1..=n` using rejection-inversion
/// (Hörmann & Derflinger 1996); O(1) per sample after O(1) setup.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    dense: Option<Vec<f64>>, // CDF for tiny n where rejection is overkill
}

impl Zipf {
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n >= 1);
        assert!(s > 0.0);
        if n <= 64 {
            // Dense CDF path.
            let mut cdf = Vec::with_capacity(n as usize);
            let mut acc = 0.0;
            for k in 1..=n {
                acc += (k as f64).powf(-s);
                cdf.push(acc);
            }
            let total = acc;
            for v in cdf.iter_mut() {
                *v /= total;
            }
            return Self { n, s, h_x1: 0.0, h_n: 0.0, dense: Some(cdf) };
        }
        let h_x1 = Self::h_static(1.5, s) - 1.0;
        let h_n = Self::h_static(n as f64 + 0.5, s);
        Self { n, s, h_x1, h_n, dense: None }
    }

    fn h_static(x: f64, s: f64) -> f64 {
        if (s - 1.0).abs() < 1e-12 {
            x.ln()
        } else {
            x.powf(1.0 - s) / (1.0 - s)
        }
    }

    fn h_inv(&self, x: f64) -> f64 {
        if (self.s - 1.0).abs() < 1e-12 {
            x.exp()
        } else {
            ((1.0 - self.s) * x).powf(1.0 / (1.0 - self.s))
        }
    }

    /// Sample a rank in `1..=n` (rank 1 is the most frequent).
    pub fn sample(&self, rng: &mut Pcg64) -> u64 {
        if let Some(cdf) = &self.dense {
            let u = rng.next_f64();
            let idx = cdf.partition_point(|&c| c < u);
            return (idx as u64 + 1).min(self.n);
        }
        loop {
            let u = self.h_n + rng.next_f64() * (self.h_x1 - self.h_n);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().max(1.0).min(self.n as f64) as u64;
            let hk = Self::h_static(k as f64 + 0.5, self.s) - (k as f64).powf(-self.s);
            if hk >= u || (k as f64 - x).abs() <= 0.5 {
                // Accept: either inside the hat or the rounding band.
                if k >= 1 && k <= self.n {
                    return k;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        let mut sm = SplitMix64::new(1234567);
        // Deterministic: two calls never equal, stream reproducible.
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn pcg_reproducible_and_distinct_streams() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        let mut c = Pcg64::new(43);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut rng = Pcg64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.next_below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn next_f64_in_unit_interval_with_reasonable_mean() {
        let mut rng = Pcg64::new(99);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(5);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.normal();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Pcg64::new(3);
        let w = [0.0, 9.0, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[rng.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[1] > 8 * counts[2], "{counts:?}");
    }

    #[test]
    fn zipf_small_and_large_n_rank1_most_frequent() {
        for &n in &[10u64, 1000u64] {
            let z = Zipf::new(n, 1.1);
            let mut rng = Pcg64::new(17);
            let mut counts = std::collections::HashMap::new();
            for _ in 0..20_000 {
                let k = z.sample(&mut rng);
                assert!(k >= 1 && k <= n);
                *counts.entry(k).or_insert(0usize) += 1;
            }
            let c1 = counts.get(&1).copied().unwrap_or(0);
            let c_max = counts.values().copied().max().unwrap();
            assert_eq!(c1, c_max, "rank 1 should dominate for n={n}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::new(23);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += rng.exponential(2.0);
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn fork_streams_decorrelated() {
        let mut root = Pcg64::new(1);
        let mut a = root.fork();
        let mut b = root.fork();
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
