//! From-scratch substrates: RNG, statistics, tables, CLI parsing,
//! property testing, micro-benchmarking, logging, error handling.
//!
//! These exist because the offline build has no registry at all — no
//! `rand`, `clap`, `criterion`, `proptest`, `serde`, `tokio` or even
//! `anyhow` ([`errors`] is the in-crate replacement). The only optional
//! external dependency is the vendored `xla` crate behind the `pjrt`
//! feature (see [`crate::runtime`]).

pub mod bench;
pub mod cli;
pub mod errors;
pub mod json;
pub mod mat;
pub mod logger;
pub mod qcheck;
pub mod rng;
pub mod stats;
pub mod table;
