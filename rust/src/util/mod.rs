//! From-scratch substrates: RNG, statistics, tables, CLI parsing,
//! property testing, micro-benchmarking, logging.
//!
//! These exist because the offline registry only vendors the `xla`
//! dependency closure — no `rand`, `clap`, `criterion`, `proptest`,
//! `serde` or `tokio`. Everything the framework needs beyond `xla` and
//! `anyhow` is implemented here.

pub mod bench;
pub mod cli;
pub mod mat;
pub mod logger;
pub mod qcheck;
pub mod rng;
pub mod stats;
pub mod table;
