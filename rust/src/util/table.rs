//! Aligned-console-table and CSV rendering for the experiment harness.
//!
//! Every experiment produces a [`Table`]; the harness prints it (the rows
//! the paper's figures/tables report) and optionally writes a CSV next to
//! it under `results/` for plotting.

use std::fmt::Write as _;
use std::path::Path;

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple rows-of-strings table with a title and column headers.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub aligns: Vec<Align>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        let headers: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
        let aligns = vec![Align::Right; headers.len()];
        Table { title: title.into(), headers, aligns, rows: Vec::new() }
    }

    pub fn align(mut self, aligns: &[Align]) -> Table {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    /// First column left-aligned (labels), remainder right-aligned.
    pub fn label_first(mut self) -> Table {
        if !self.aligns.is_empty() {
            self.aligns[0] = Align::Left;
        }
        self
    }

    pub fn add_row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells);
    }

    /// Render to an aligned plain-text block.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let fmt_cell = |c: &str, w: usize, a: Align| -> String {
            match a {
                Align::Left => format!("{c:<w$}"),
                Align::Right => format!("{c:>w$}"),
            }
        };
        let header_line: Vec<String> = (0..ncol)
            .map(|i| fmt_cell(&self.headers[i], widths[i], self.aligns[i]))
            .collect();
        let _ = writeln!(out, "{}", header_line.join("  "));
        let rule: usize = widths.iter().sum::<usize>() + 2 * (ncol.saturating_sub(1));
        let _ = writeln!(out, "{}", "-".repeat(rule));
        for row in &self.rows {
            let line: Vec<String> = (0..ncol)
                .map(|i| fmt_cell(&row[i], widths[i], self.aligns[i]))
                .collect();
            let _ = writeln!(out, "{}", line.join("  "));
        }
        out
    }

    /// Render as RFC-4180-ish CSV (quotes fields containing `",\n`).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write the CSV under `dir/<name>.csv`, creating `dir` if needed.
    pub fn write_csv(&self, dir: &Path, name: &str) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format seconds compactly (used throughout the experiment output).
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        return format!("{s}");
    }
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 1.0 {
        format!("{s:.1}")
    } else {
        format!("{s:.3}")
    }
}

/// Format a ratio as a percentage string.
pub fn fmt_pct(frac: f64) -> String {
    format!("{:.1}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_alignment() {
        let mut t = Table::new("demo", &["name", "value"]).label_first();
        t.add_row(vec!["alpha".into(), "1".into()]);
        t.add_row(vec!["b".into(), "12345".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("alpha"));
        // value column right-aligned to width 5
        assert!(r.contains("    1"), "got:\n{r}");
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.add_row(vec!["has,comma".into(), "has\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.add_row(vec!["only-one".into()]);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(123.4), "123");
        assert_eq!(fmt_secs(12.34), "12.3");
        assert_eq!(fmt_secs(0.1234), "0.123");
        assert_eq!(fmt_pct(0.315), "31.5%");
    }

    #[test]
    fn write_csv_roundtrip() {
        let mut t = Table::new("w", &["k", "v"]);
        t.add_row(vec!["a".into(), "1".into()]);
        let dir = std::env::temp_dir().join("mrperf_table_test");
        let p = t.write_csv(&dir, "t").unwrap();
        let content = std::fs::read_to_string(p).unwrap();
        assert!(content.starts_with("k,v\n"));
    }
}
