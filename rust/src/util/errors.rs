//! Minimal `anyhow`-compatible error handling.
//!
//! The offline build carries no external crates (see the [`crate::util`]
//! module docs), so this module provides the small subset of `anyhow`'s
//! API the crate uses: a dynamic [`Error`] carrying a context chain, the
//! [`Result`] alias, the [`Context`] extension trait for `Result` and
//! `Option`, and the `anyhow!` / `bail!` / `ensure!` macros. Like
//! `anyhow`, `{:#}` formatting prints the whole chain
//! (`outer context: ...: root cause`) while `{}` prints only the
//! outermost message.

use std::fmt;

/// A dynamic error: a root cause plus outer context layers.
pub struct Error {
    /// Outermost context first; the last entry is the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context layer.
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// Deliberately no `impl std::error::Error for Error`: exactly like
// `anyhow::Error`, omitting it keeps the blanket conversion below
// coherent with core's reflexive `From<T> for T`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Crate-wide result alias (`anyhow::Result` equivalent).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!`: format a message into an [`Error`].
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::errors::Error::msg(format!($($arg)*))
    };
}

/// `bail!`: early-return a formatted error.
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::errors::Error::msg(format!($($arg)*)))
    };
}

/// `ensure!`: early-return a formatted error unless the condition holds.
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::util::errors::Error::msg(format!($($arg)*)));
        }
    };
}

pub(crate) use anyhow;
pub(crate) use bail;
pub(crate) use ensure;

#[cfg(test)]
mod tests {
    use super::*;

    fn might_fail(ok: bool) -> Result<u32> {
        ensure!(ok, "condition was {ok}");
        Ok(7)
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = "root cause"
            .parse::<f64>()
            .context("parsing the value")
            .unwrap_err();
        assert_eq!(format!("{e}"), "parsing the value");
        let full = format!("{e:#}");
        assert!(full.starts_with("parsing the value: "), "{full}");
        assert!(e.chain().count() >= 2);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("missing thing").unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        let e = anyhow!("value was {}", 42);
        assert_eq!(format!("{e}"), "value was 42");
        assert_eq!(might_fail(true).unwrap(), 7);
        let err = might_fail(false).unwrap_err();
        assert_eq!(format!("{err}"), "condition was false");
    }

    #[test]
    fn io_error_converts_with_source_chain() {
        let io = std::fs::read_to_string("/definitely/not/a/real/path/xyz");
        let e = io.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").contains("reading config: "));
    }

    #[test]
    fn question_mark_propagates() {
        fn inner() -> Result<()> {
            let _ = std::fs::read_to_string("/definitely/not/a/real/path/xyz")?;
            Ok(())
        }
        assert!(inner().is_err());
    }
}
