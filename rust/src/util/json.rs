//! Minimal deterministic JSON value, writer and parser — the in-crate
//! snapshot codec's foundation (the build is registry-free, so no
//! serde). The *writer* follows the hand-rolled style of
//! [`super::bench`]; the *parser* is the recursive-descent counterpart
//! that checkpoint/resume needs to read snapshots back.
//!
//! Design constraints, both load-bearing for the checkpoint feature:
//!
//! * **Determinism** — objects are ordered `Vec<(String, Json)>`, not a
//!   map, so a value serializes to exactly one byte sequence (and never
//!   through hash-iteration order, detlint D001).
//! * **Bit-exact floats** — virtual times, byte counters and rates must
//!   round-trip *bit for bit* (the resume-equals-uninterrupted
//!   invariant is on `f64::to_bits`). Decimal formatting cannot
//!   guarantee that across parse implementations, so snapshot floats
//!   are written as the 16-hex-digit big-endian form of
//!   [`f64::to_bits`] via [`Json::f64_bits`] / [`Json::as_f64_bits`]
//!   (NaN and infinities included, which plain JSON cannot carry).
//!   Plain [`Json::Num`] is reserved for integers (ids, counts) whose
//!   values stay below 2^53 and therefore round-trip exactly through
//!   f64.

/// A JSON value. Objects preserve insertion order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Numbers are carried as f64; integer values below 2^53 round-trip
    /// exactly. For bit-exact floats use [`Json::f64_bits`].
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// A non-negative integer value (ids, counts).
    pub fn uint(v: usize) -> Json {
        Json::Num(v as f64)
    }

    /// A u64 value; asserts it is exactly representable in f64.
    pub fn u64(v: u64) -> Json {
        assert!(v <= (1u64 << 53), "u64 {v} not exactly representable in f64");
        Json::Num(v as f64)
    }

    /// Bit-exact f64 encoding: the 16-hex-digit form of `to_bits`.
    pub fn f64_bits(v: f64) -> Json {
        Json::Str(format!("{:016x}", v.to_bits()))
    }

    /// An `Option<usize>` as integer-or-null.
    pub fn opt_uint(v: Option<usize>) -> Json {
        match v {
            Some(x) => Json::uint(x),
            None => Json::Null,
        }
    }

    /// Field lookup on an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Required-field lookup with a path-flavored error.
    pub fn field(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing field `{key}`"))
    }

    pub fn as_bool(&self) -> Result<bool, String> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(format!("expected bool, got {}", other.kind())),
        }
    }

    pub fn as_f64(&self) -> Result<f64, String> {
        match self {
            Json::Num(v) => Ok(*v),
            other => Err(format!("expected number, got {}", other.kind())),
        }
    }

    pub fn as_usize(&self) -> Result<usize, String> {
        let v = self.as_f64()?;
        if v < 0.0 || v.fract() != 0.0 || v > (1u64 << 53) as f64 {
            return Err(format!("expected non-negative integer, got {v}"));
        }
        Ok(v as usize)
    }

    pub fn as_u64(&self) -> Result<u64, String> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_opt_usize(&self) -> Result<Option<usize>, String> {
        match self {
            Json::Null => Ok(None),
            other => other.as_usize().map(Some),
        }
    }

    /// Decode a bit-exact f64 written by [`Json::f64_bits`].
    pub fn as_f64_bits(&self) -> Result<f64, String> {
        let s = self.as_str()?;
        if s.len() != 16 {
            return Err(format!("expected 16 hex digits for f64 bits, got `{s}`"));
        }
        let bits = u64::from_str_radix(s, 16)
            .map_err(|_| format!("invalid f64 bit pattern `{s}`"))?;
        Ok(f64::from_bits(bits))
    }

    pub fn as_str(&self) -> Result<&str, String> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(format!("expected string, got {}", other.kind())),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], String> {
        match self {
            Json::Arr(items) => Ok(items),
            other => Err(format!("expected array, got {}", other.kind())),
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    /// Serialize (compact, no whitespace). Deterministic: objects write
    /// their fields in insertion order.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() <= (1u64 << 53) as f64 {
                    // Integers render without a fraction or exponent.
                    let _ = std::fmt::Write::write_fmt(out, format_args!("{}", *v as i64));
                } else {
                    // Shortest round-trip decimal (Rust's f64 Debug).
                    let _ = std::fmt::Write::write_fmt(out, format_args!("{v:?}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse one JSON document (trailing whitespace allowed, trailing
    /// garbage rejected).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing garbage after document"));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = std::fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> String {
        format!("json parse error at byte {}: {msg}", self.pos)
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected byte `{}`", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let Some(b) = self.peek() else {
                return Err(self.err("unterminated string"));
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err(self.err("unterminated escape"));
                    };
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair.
                                if self.peek() != Some(b'\\') {
                                    return Err(self.err("lone high surrogate"));
                                }
                                self.pos += 1;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(c)
                                    .ok_or_else(|| self.err("invalid unicode escape"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                // Raw UTF-8 bytes pass through (the input is a &str, so
                // multi-byte sequences are valid — reassemble them).
                b if b < 0x80 => out.push(b as char),
                _ => {
                    // Back up and take the full UTF-8 char from the str.
                    self.pos -= 1;
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(b'0'..=b'9') | Some(b'.') | Some(b'e') | Some(b'E') | Some(b'+') | Some(b'-')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        let v: f64 = s
            .parse()
            .map_err(|_| format!("json parse error at byte {start}: invalid number `{s}`"))?;
        if !v.is_finite() {
            return Err(format!("json parse error at byte {start}: non-finite number"));
        }
        Ok(Json::Num(v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_scalars_and_containers() {
        let v = Json::Obj(vec![
            ("a".into(), Json::Null),
            ("b".into(), Json::Bool(true)),
            ("c".into(), Json::uint(42)),
            ("d".into(), Json::Str("hi \"there\"\n\ttab".into())),
            (
                "e".into(),
                Json::Arr(vec![Json::uint(1), Json::Bool(false), Json::Str("x".into())]),
            ),
            ("f".into(), Json::Obj(vec![("nested".into(), Json::uint(7))])),
        ]);
        let text = v.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, v);
        // Deterministic: re-rendering the parse is byte-identical.
        assert_eq!(back.render(), text);
    }

    #[test]
    fn f64_bits_round_trip_is_bit_exact() {
        for v in [
            0.0,
            -0.0,
            1.0,
            1.0 / 3.0,
            f64::MIN_POSITIVE,
            f64::MAX,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
            123456789.123456789,
        ] {
            let j = Json::f64_bits(v);
            let text = j.render();
            let back = Json::parse(&text).unwrap().as_f64_bits().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "bits drifted for {v}");
        }
    }

    #[test]
    fn unicode_strings_survive() {
        let v = Json::Str("héllo → 世界 \u{1F600}".into());
        assert_eq!(Json::parse(&v.render()).unwrap(), v);
        // Escaped form parses too (incl. a surrogate pair).
        let parsed = Json::parse(r#""\u4e16\u754c \ud83d\ude00""#).unwrap();
        assert_eq!(parsed, Json::Str("世界 \u{1F600}".into()));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "",
            "{",
            "[1,",
            "{\"a\":}",
            "{\"a\" 1}",
            "tru",
            "\"unterminated",
            "[1] garbage",
            "{\"a\":1,}x",
            "nan",
            "1e999",
            "\"\\q\"",
            "\"\\ud800\"",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted malformed `{bad}`");
        }
    }

    #[test]
    fn field_accessors_report_useful_errors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "neg": -1, "frac": 1.5}"#).unwrap();
        assert_eq!(v.field("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.field("s").unwrap().as_str().unwrap(), "x");
        assert!(v.field("missing").unwrap_err().contains("missing"));
        assert!(v.field("neg").unwrap().as_usize().is_err());
        assert!(v.field("frac").unwrap().as_usize().is_err());
        assert!(v.field("s").unwrap().as_f64().is_err());
        assert_eq!(v.field("neg").unwrap().as_opt_usize().ok(), None);
        assert_eq!(Json::Null.as_opt_usize().unwrap(), None);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::uint(0).render(), "0");
        assert_eq!(Json::u64(1 << 53).render(), "9007199254740992");
        assert_eq!(Json::Num(-4.0).render(), "-4");
    }
}
