//! Tiny command-line argument parser (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed getters and an auto-generated usage block.
//! A `--key` that is not a declared flag and has no value (end of argv,
//! or directly followed by another `--opt`) is a [`CliError`], not a
//! silent boolean flag.

use std::collections::BTreeMap;

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    MissingValue(String),
    BadValue { key: String, value: String, expected: &'static str },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingValue(k) => write!(f, "option --{k} expects a value"),
            CliError::BadValue { key, value, expected } => {
                write!(f, "option --{key}={value}: expected {expected}")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// Option keys that are boolean flags (take no value).
pub fn parse(argv: &[String], flag_keys: &[&str]) -> Result<Args, CliError> {
    let mut args = Args::default();
    let mut it = argv.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(stripped) = a.strip_prefix("--") {
            if let Some((k, v)) = stripped.split_once('=') {
                args.options.insert(k.to_string(), v.to_string());
            } else if flag_keys.contains(&stripped) {
                args.flags.push(stripped.to_string());
            } else if let Some(next) = it.peek() {
                if next.starts_with("--") {
                    // An undeclared key directly followed by another
                    // option has no value: error out instead of silently
                    // recording a bogus flag (`mrperf run --gen --skew 2`
                    // must not run with the default topology).
                    return Err(CliError::MissingValue(stripped.to_string()));
                } else {
                    args.options.insert(stripped.to_string(), it.next().unwrap().clone());
                }
            } else {
                // Undeclared key at end of argv: same story
                // (`mrperf run --gen` used to silently become a flag).
                return Err(CliError::MissingValue(stripped.to_string()));
            }
        } else {
            args.positional.push(a.clone());
        }
    }
    Ok(args)
}

impl Args {
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.options.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: key.to_string(),
                value: v.to_string(),
                expected: "an unsigned integer",
            }),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: key.to_string(),
                value: v.to_string(),
                expected: "an unsigned integer",
            }),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: key.to_string(),
                value: v.to_string(),
                expected: "a number",
            }),
        }
    }

    /// Comma-separated f64 list.
    pub fn get_f64_list(&self, key: &str, default: &[f64]) -> Result<Vec<f64>, CliError> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|_| CliError::BadValue {
                        key: key.to_string(),
                        value: v.to_string(),
                        expected: "a comma-separated list of numbers",
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let a = parse(&sv(&["run", "--alpha", "0.5", "--verbose", "--out=x.csv", "fig4"]),
                      &["verbose"]).unwrap();
        assert_eq!(a.positional, vec!["run", "fig4"]);
        assert_eq!(a.get("alpha"), Some("0.5"));
        assert!(a.flag("verbose"));
        assert_eq!(a.get("out"), Some("x.csv"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&sv(&["--n", "12", "--x", "1.5", "--list", "1,2,3.5"]), &[]).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 12);
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 1.5);
        assert_eq!(a.get_f64_list("list", &[]).unwrap(), vec![1.0, 2.0, 3.5]);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn bad_value_errors() {
        let a = parse(&sv(&["--n", "notanumber"]), &[]).unwrap();
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn trailing_declared_flag_is_fine() {
        let a = parse(&sv(&["run", "--quiet"]), &["quiet"]).unwrap();
        assert!(a.flag("quiet"));
    }

    /// Regression: an undeclared option at end-of-argv was silently
    /// recorded as a boolean flag (`mrperf run --gen` ran with the
    /// default topology). It must error.
    #[test]
    fn trailing_undeclared_option_errors() {
        let err = parse(&sv(&["run", "--gen"]), &["verbose"]).unwrap_err();
        assert!(matches!(err, CliError::MissingValue(ref k) if k == "gen"), "{err}");
    }

    /// Regression: an undeclared option directly followed by another
    /// `--opt` was silently recorded as a flag too (`--gen --skew 2`).
    #[test]
    fn adjacent_undeclared_option_errors() {
        let err = parse(&sv(&["--gen", "--skew", "2"]), &[]).unwrap_err();
        assert!(matches!(err, CliError::MissingValue(ref k) if k == "gen"), "{err}");
    }

    #[test]
    fn declared_flag_before_option_still_parses() {
        let a = parse(&sv(&["--verbose", "--n", "3"]), &["verbose"]).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn negative_number_values_are_not_options() {
        let a = parse(&sv(&["--alpha", "-1.5"]), &[]).unwrap();
        assert_eq!(a.get_f64("alpha", 0.0).unwrap(), -1.5);
    }
}
