//! Small dense row-major `f64` matrix used for plans, bandwidth matrices
//! and the LP tableau.

#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn filled(rows: usize, cols: usize, v: f64) -> Mat {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    pub fn from_rows(rows_data: &[&[f64]]) -> Mat {
        let rows = rows_data.len();
        assert!(rows > 0);
        let cols = rows_data[0].len();
        let mut data = Vec::with_capacity(rows * cols);
        for r in rows_data {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Mat { rows, cols, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Sum of a column.
    pub fn col_sum(&self, c: usize) -> f64 {
        (0..self.rows).map(|r| self.get(r, c)).sum()
    }

    /// Sum of a row.
    pub fn row_sum(&self, r: usize) -> f64 {
        self.row(r).iter().sum()
    }

    /// Elementwise maximum absolute difference.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let mut m = Mat::zeros(2, 3);
        m[(1, 2)] = 5.0;
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.get(0, 0), 0.0);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
    }

    #[test]
    fn from_rows_and_sums() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(m.row_sum(0), 3.0);
        assert_eq!(m.col_sum(1), 6.0);
        assert_eq!(m.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn max_abs_diff() {
        let a = Mat::from_rows(&[&[1.0, 2.0]]);
        let b = Mat::from_rows(&[&[1.5, 2.0]]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }

    #[test]
    #[should_panic]
    fn ragged_rows_panic() {
        let _ = Mat::from_rows(&[&[1.0], &[1.0, 2.0]]);
    }
}
