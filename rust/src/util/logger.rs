//! Leveled stderr logger with a process-global verbosity switch.
//!
//! The engine and optimizers log through this; experiments default to
//! `Info`, `--verbose` bumps to `Debug`, benches set `Warn`.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($module:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, $module,
                                  format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($module:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, $module,
                                  format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($module:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, $module,
                                  format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn set_and_query() {
        let prev = level();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(prev);
    }
}
