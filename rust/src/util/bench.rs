//! Micro-benchmark harness (criterion is not available offline).
//!
//! Provides warmup + timed iterations with mean / p50 / p95 / min stats and
//! an aligned report, used by `cargo bench` (see `rust/benches/bench_main.rs`,
//! built with `harness = false`) and by the perf pass recorded in
//! EXPERIMENTS.md §Perf.

use std::time::{Duration, Instant};

/// One benchmark's results.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    /// Optional throughput denominator: items processed per iteration.
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter
            .map(|items| items / self.mean.as_secs_f64())
    }

    /// Hand-rolled JSON record (no serde offline). Names are
    /// crate-internal (`group/bench_name`), so no string escaping is
    /// needed beyond what [`json_safe`] enforces.
    pub fn to_json(&self) -> String {
        let items = match self.items_per_iter {
            Some(v) => format!("{v}"),
            None => "null".to_string(),
        };
        format!(
            "{{\"name\":\"{}\",\"iters\":{},\"mean_ns\":{},\"p50_ns\":{},\"p95_ns\":{},\"min_ns\":{},\"items_per_iter\":{}}}",
            json_safe(&self.name),
            self.iters,
            self.mean.as_nanos(),
            self.p50.as_nanos(),
            self.p95.as_nanos(),
            self.min.as_nanos(),
            items
        )
    }
}

/// Keep bench names JSON-literal-safe (strip quotes/backslashes/controls).
fn json_safe(name: &str) -> String {
    name.chars()
        .filter(|c| !c.is_control() && *c != '"' && *c != '\\')
        .collect()
}

/// Benchmark runner configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
    pub target_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(200),
            min_iters: 10,
            max_iters: 10_000,
            target_time: Duration::from_secs(1),
        }
    }
}

/// A collection of benchmarks, run and reported together.
pub struct BenchSuite {
    config: BenchConfig,
    results: Vec<BenchResult>,
    filter: Option<String>,
}

impl BenchSuite {
    pub fn new(config: BenchConfig) -> Self {
        // `cargo bench -- <filter>` passes the filter as an argument.
        let filter = std::env::args().nth(1).filter(|a| !a.starts_with('-'));
        Self::with_filter(config, filter)
    }

    /// Explicit-filter constructor for embedding the harness in the CLI
    /// (`mrperf bench`), where argv[1] is the subcommand, not a filter.
    pub fn with_filter(config: BenchConfig, filter: Option<String>) -> Self {
        BenchSuite { config, results: Vec::new(), filter }
    }

    /// Write one `BENCH_<name>.json` file per result into `dir` (created
    /// if needed); returns the paths. `/` in bench names becomes `_`.
    pub fn write_json(&self, dir: &std::path::Path) -> std::io::Result<Vec<std::path::PathBuf>> {
        std::fs::create_dir_all(dir)?;
        let mut paths = Vec::with_capacity(self.results.len());
        for r in &self.results {
            let fname = format!("BENCH_{}.json", r.name.replace('/', "_").replace(' ', "_"));
            let path = dir.join(fname);
            std::fs::write(&path, r.to_json() + "\n")?;
            paths.push(path);
        }
        Ok(paths)
    }

    /// Run one benchmark. `f` is the timed body; return value is
    /// black-boxed to prevent the optimizer deleting the work.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, f: F) {
        self.bench_with_items(name, None, f)
    }

    /// Like [`bench`], reporting items/sec throughput.
    pub fn bench_items<T, F: FnMut() -> T>(&mut self, name: &str, items: f64, f: F) {
        self.bench_with_items(name, Some(items), f)
    }

    fn bench_with_items<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        items: Option<f64>,
        mut f: F,
    ) {
        if let Some(filter) = &self.filter {
            if !name.contains(filter.as_str()) {
                return;
            }
        }
        // Warmup.
        let warm_start = Instant::now();
        let mut warm_iters = 0usize;
        while warm_start.elapsed() < self.config.warmup && warm_iters < self.config.max_iters {
            black_box(f());
            warm_iters += 1;
        }
        // Estimate per-iter cost to size the measured run.
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;
        let iters = ((self.config.target_time.as_secs_f64() / per_iter.max(1e-9)) as usize)
            .clamp(self.config.min_iters, self.config.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let total: Duration = samples.iter().sum();
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean: total / iters as u32,
            p50: samples[iters / 2],
            p95: samples[(iters * 95 / 100).min(iters - 1)],
            min: samples[0],
            items_per_iter: items,
        };
        println!("{}", render_line(&result));
        self.results.push(result);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print the summary block (call at end of the bench binary).
    pub fn report(&self) {
        println!("\n=== bench summary ({} benchmarks) ===", self.results.len());
        for r in &self.results {
            println!("{}", render_line(r));
        }
    }
}

fn render_line(r: &BenchResult) -> String {
    let tp = match r.throughput() {
        Some(t) if t >= 1e6 => format!("  {:>9.2} Mitems/s", t / 1e6),
        Some(t) if t >= 1e3 => format!("  {:>9.2} Kitems/s", t / 1e3),
        Some(t) => format!("  {t:>9.2} items/s"),
        None => String::new(),
    };
    format!(
        "bench {:<44} mean {:>11?}  p50 {:>11?}  p95 {:>11?}  min {:>11?}  ({} iters){}",
        r.name, r.mean, r.p50, r.p95, r.min, r.iters, tp
    )
}

/// Optimizer barrier, stable-API equivalent of `std::hint::black_box`.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(5),
            min_iters: 5,
            max_iters: 50,
            target_time: Duration::from_millis(20),
        };
        let mut suite = BenchSuite { config: cfg, results: Vec::new(), filter: None };
        suite.bench_items("spin", 1000.0, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        let r = &suite.results()[0];
        assert!(r.iters >= 5);
        assert!(r.min <= r.p50 && r.p50 <= r.p95);
        assert!(r.throughput().unwrap() > 0.0);
    }

    #[test]
    fn json_roundtrip_fields_present() {
        let r = BenchResult {
            name: "optimizer/scale_64_alternating".to_string(),
            iters: 7,
            mean: Duration::from_micros(1500),
            p50: Duration::from_micros(1400),
            p95: Duration::from_micros(2000),
            min: Duration::from_micros(1200),
            items_per_iter: None,
        };
        let j = r.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"name\":\"optimizer/scale_64_alternating\""));
        assert!(j.contains("\"iters\":7"));
        assert!(j.contains("\"mean_ns\":1500000"));
        assert!(j.contains("\"items_per_iter\":null"));
    }

    #[test]
    fn write_json_emits_one_file_per_bench() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(1),
            min_iters: 1,
            max_iters: 2,
            target_time: Duration::from_millis(1),
        };
        let mut suite = BenchSuite::with_filter(cfg, None);
        suite.bench("group/alpha", || 1);
        suite.bench("group/beta", || 2);
        let dir = std::env::temp_dir().join(format!(
            "mrperf_bench_json_{}",
            std::process::id()
        ));
        let paths = suite.write_json(&dir).unwrap();
        assert_eq!(paths.len(), 2);
        let first = std::fs::read_to_string(&paths[0]).unwrap();
        assert!(paths[0].file_name().unwrap().to_str().unwrap() == "BENCH_group_alpha.json");
        assert!(first.contains("\"name\":\"group/alpha\""));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn filter_skips_nonmatching() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(1),
            min_iters: 1,
            max_iters: 2,
            target_time: Duration::from_millis(1),
        };
        let mut suite = BenchSuite {
            config: cfg,
            results: Vec::new(),
            filter: Some("yes".to_string()),
        };
        suite.bench("no_match", || 1);
        suite.bench("yes_match", || 1);
        assert_eq!(suite.results().len(), 1);
        assert_eq!(suite.results()[0].name, "yes_match");
    }
}
