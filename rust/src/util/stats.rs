//! Descriptive statistics, confidence intervals and linear regression.
//!
//! Used by the experiment harness for the paper's 95% confidence-interval
//! error bars (Figs 9–12) and the model-validation fit (Fig 4: R², slope).

/// Summary of a sample of repeated measurements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    /// Half-width of the 95% confidence interval of the mean.
    pub ci95: f64,
}

impl Summary {
    /// NaN policy: `f64::min`/`f64::max` folds would silently *drop* NaN
    /// extremes (IEEE min/max prefer the non-NaN operand), producing a
    /// Summary whose `min`/`max` look clean while `mean`/`stddev` are
    /// poisoned — so we reject NaN input outright with a clear message
    /// instead of returning an inconsistent summary.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        assert!(
            xs.iter().all(|x| !x.is_nan()),
            "Summary::of on NaN-bearing sample"
        );
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n as f64 - 1.0)
        } else {
            0.0
        };
        let stddev = var.sqrt();
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let ci95 = if n > 1 {
            t_crit_95(n - 1) * stddev / (n as f64).sqrt()
        } else {
            0.0
        };
        Summary { n, mean, stddev, min, max, ci95 }
    }
}

/// Two-sided 95% Student-t critical value for `df` degrees of freedom.
/// Table for small df, asymptote 1.96 beyond.
pub fn t_crit_95(df: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228,
        2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086,
        2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    if df == 0 {
        f64::INFINITY
    } else if df <= 30 {
        TABLE[df - 1]
    } else if df <= 60 {
        2.000
    } else {
        1.960
    }
}

/// Ordinary least squares y = a + b·x with the goodness-of-fit statistics
/// the paper reports in Fig 4 (R² and slope).
#[derive(Debug, Clone, Copy)]
pub struct LinFit {
    pub intercept: f64,
    pub slope: f64,
    pub r2: f64,
    pub n: usize,
}

/// Panics on a degenerate x sample (`sxx == 0`: all x identical, or any
/// NaN, which poisons `sxx` into NaN and fails the `sxx > 0` guard). A
/// zero-variance *y* sample is fine: the fit is the horizontal line and
/// R² is reported as 1.0 (the line explains all — i.e. none — of the
/// variance) rather than dividing by `syy == 0`.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> LinFit {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least two points");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    assert!(sxx > 0.0, "degenerate x sample");
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let r2 = if syy > 0.0 { (sxy * sxy) / (sxx * syy) } else { 1.0 };
    LinFit { intercept, slope, r2, n: xs.len() }
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    let fit = linear_fit(xs, ys);
    fit.r2.sqrt() * fit.slope.signum()
}

/// Percentile (nearest-rank) of an unsorted sample, `p` in [0,100].
///
/// NaN-safe: sorts with [`f64::total_cmp`], under which NaN orders after
/// `+inf`, so a NaN-bearing sample never panics — high percentiles of such
/// a sample return NaN (poisoned tail) rather than aborting the run.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * v.len() as f64).ceil().max(1.0) as usize;
    v[rank.min(v.len()) - 1]
}

/// Geometric mean of strictly positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| {
        assert!(*x > 0.0, "geomean needs positive values");
        x.ln()
    }).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        // t(4) = 2.776; CI = 2.776 * sqrt(2.5)/sqrt(5)
        assert!((s.ci95 - 2.776 * (2.5f64).sqrt() / (5f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn summary_single_point() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.ci95, 0.0);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let f = linear_fit(&xs, &ys);
        assert!((f.slope - 2.0).abs() < 1e-12);
        assert!((f.intercept - 1.0).abs() < 1e-12);
        assert!((f.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_noisy() {
        // y = 2x + noise; R² should be high but < 1.
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().enumerate()
            .map(|(i, &x)| 2.0 * x + if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        let f = linear_fit(&xs, &ys);
        assert!((f.slope - 2.0).abs() < 0.01);
        assert!(f.r2 > 0.99 && f.r2 < 1.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 1.0), 1.0);
    }

    #[test]
    fn percentile_nan_does_not_panic() {
        // Regression: the old partial_cmp().unwrap() sort aborted on NaN.
        let xs = [1.0, f64::NAN, 2.0, 3.0];
        // NaN totally-orders after +inf, so low/mid percentiles stay clean…
        assert_eq!(percentile(&xs, 25.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert_eq!(percentile(&xs, 75.0), 3.0);
        // …and the poisoned tail reports NaN instead of panicking.
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    #[should_panic(expected = "NaN-bearing sample")]
    fn summary_rejects_nan() {
        let _ = Summary::of(&[1.0, f64::NAN]);
    }

    #[test]
    fn linear_fit_zero_y_variance() {
        let xs = [0.0, 1.0, 2.0];
        let ys = [4.0, 4.0, 4.0];
        let f = linear_fit(&xs, &ys);
        assert_eq!(f.slope, 0.0);
        assert_eq!(f.intercept, 4.0);
        assert_eq!(f.r2, 1.0);
    }

    #[test]
    #[should_panic(expected = "degenerate x sample")]
    fn linear_fit_degenerate_x() {
        let _ = linear_fit(&[2.0, 2.0], &[1.0, 3.0]);
    }

    #[test]
    fn geomean_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_sign() {
        let xs = [1.0, 2.0, 3.0];
        let up = [1.0, 2.0, 3.1];
        let down = [3.0, 2.0, 0.9];
        assert!(pearson(&xs, &up) > 0.99);
        assert!(pearson(&xs, &down) < -0.99);
    }
}
