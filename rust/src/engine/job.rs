//! Job definition: the MapReduce programming model (§1.2) plus the
//! execution knobs our modified-Hadoop engine exposes (§3.1, §4.6).

use super::dynamics::ScenarioTrace;
use super::replan::ReplanPolicy;
use crate::model::barrier::BarrierConfig;

/// A key/value record. Keys and values are strings (like Hadoop `Text`);
/// the engine charges network/compute work by serialized size.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Record {
    pub key: String,
    pub value: String,
}

/// Serialization overhead per record (length headers), bytes.
pub const RECORD_OVERHEAD: usize = 8;

impl Record {
    pub fn new(key: impl Into<String>, value: impl Into<String>) -> Record {
        Record { key: key.into(), value: value.into() }
    }

    /// Serialized size in bytes.
    pub fn size(&self) -> usize {
        self.key.len() + self.value.len() + RECORD_OVERHEAD
    }
}

/// Total serialized size of a record batch.
pub fn batch_size(records: &[Record]) -> usize {
    records.iter().map(Record::size).sum()
}

/// A MapReduce application (map + reduce + grouping semantics).
///
/// `group_key` mirrors Hadoop's `GroupingComparator`: records are
/// partitioned and grouped by `group_key(key)` while values arrive sorted
/// by the full key — which is how Sessionization implements its
/// secondary sort (§4.6.2).
pub trait MapReduceApp: Send + Sync {
    fn name(&self) -> &'static str;

    /// Process one input record, emitting intermediate records.
    fn map(&self, record: &Record, emit: &mut dyn FnMut(Record));

    /// Process one whole input split. The default maps record-by-record;
    /// applications using the *in-mapper-combining* pattern (Word Count,
    /// §4.6.2) override this to aggregate across the split before
    /// emitting, which is where their α ≪ 1 comes from.
    fn map_split(&self, records: &[Record], emit: &mut dyn FnMut(Record)) {
        for r in records {
            self.map(r, emit);
        }
    }

    /// Reduce one group: `group` is the grouping key, `records` all
    /// intermediate records of that group sorted by full key.
    fn reduce(&self, group: &str, records: &[Record], emit: &mut dyn FnMut(Record));

    /// Grouping key (defaults to the whole key).
    fn group_key<'a>(&self, key: &'a str) -> &'a str {
        key
    }

    /// Relative compute intensity of this app's map function (1.0 = the
    /// platform's calibrated `C` rates). Lets the synthetic app emulate
    /// computation heterogeneity (§3.2).
    fn map_cost_factor(&self) -> f64 {
        1.0
    }

    /// Relative compute intensity of the reduce function.
    fn reduce_cost_factor(&self) -> f64 {
        1.0
    }
}

/// Engine execution configuration (the §3.1 Hadoop modifications).
#[derive(Debug, Clone)]
pub struct JobConfig {
    /// Barrier configuration at the three phase boundaries.
    pub barriers: BarrierConfig,
    /// Input split size in bytes (paper: 64 MB; scaled down with our
    /// scaled-down inputs).
    pub split_size: usize,
    /// Intermediate-key buckets (must be ≫ reducers; §3.1.3).
    pub n_buckets: usize,
    /// Map slots per node (§4.6.1: two).
    pub map_slots: usize,
    /// Reduce slots per node (§4.6.1: one).
    pub reduce_slots: usize,
    /// `LocalOnly` (§3.1.1): strictly couple task placement to the plan.
    pub local_only: bool,
    /// Speculative execution of straggler tasks (§4.6.4).
    pub speculation: bool,
    /// Work stealing: idle nodes take non-local pending tasks (§4.6.4).
    pub stealing: bool,
    /// Locality-aware stealing: prefer same-cluster victims, cross-WAN
    /// only when the remote backlog (or a dead home node) justifies the
    /// penalty. Implies stealing when `local_only` is off.
    pub locality_stealing: bool,
    /// HDFS-style replication factor for pushed input and reducer output
    /// (§4.6.5). 1 = no replication.
    pub replication: usize,
    /// Injected platform dynamics (time-varying bandwidth, failures,
    /// stragglers). `None` — and a `Some` trace with zero events — leave
    /// the engine's static behavior bit-identical.
    pub dynamics: Option<ScenarioTrace>,
    /// Worker threads for the fluid re-solve (`FluidSim::set_threads`).
    /// Results are bit-identical for every value ≥ 1; values > 1 only
    /// change wall-clock time. Must be ≥ 1.
    pub threads: usize,
    /// Retry budget per work item (map split / reduce key range). Each
    /// node failure that evicts the item counts one attempt; an item
    /// reaching `max_attempts` failed attempts is routed to the
    /// dead-letter queue instead of being requeued forever (the pre-DLQ
    /// engine livelocked under flapping traces). Must be ≥ 1 — an
    /// unbounded budget is deliberately not expressible.
    pub max_attempts: u32,
    /// Online re-optimization policy ([`super::replan`]): re-solve the
    /// plan at dynamics-event boundaries (`on-event`) or on a fixed
    /// virtual-time cadence (`every:T`), migrating only unstarted work
    /// to the new plan. `Off` (the default) is bit-identical to the
    /// static path. Enabling it selects the `ReplanScheduler` family;
    /// it cannot be combined with stealing or speculation (the CLI
    /// rejects the combination so the experiment comparison stays
    /// clean).
    pub replan: ReplanPolicy,
    /// Model α the replanner prices its re-solves with. The engine-side
    /// [`MapReduceApp`] deliberately exposes no model-level α (it is a
    /// property of the *plan model*, not the record-level app), so the
    /// caller that built the original plan passes it along. Only read
    /// when `replan` is enabled.
    pub replan_alpha: f64,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            barriers: BarrierConfig::HADOOP,
            split_size: 2 << 20, // 2 MB at our scaled data sizes
            n_buckets: 512,
            map_slots: 2,
            reduce_slots: 1,
            local_only: true,
            speculation: false,
            stealing: false,
            locality_stealing: false,
            replication: 1,
            dynamics: None,
            threads: 1,
            max_attempts: 4,
            replan: ReplanPolicy::Off,
            replan_alpha: 1.0,
        }
    }
}

impl JobConfig {
    /// The configuration used for "our optimization" rows in Figs 9–11:
    /// statically enforced plan, no dynamic mechanisms (§4.6.1).
    pub fn optimized() -> JobConfig {
        JobConfig { local_only: true, speculation: false, stealing: false, ..Default::default() }
    }

    /// Vanilla-Hadoop-style execution (§4.6.1): dynamic mechanisms on,
    /// plan not strictly enforced.
    pub fn vanilla_hadoop() -> JobConfig {
        JobConfig { local_only: false, speculation: true, stealing: true, ..Default::default() }
    }

    /// Dynamic execution with locality-aware stealing and speculation —
    /// the churn-recovery configuration compared against the statically
    /// enforced plan in `mrperf experiment churn`.
    pub fn dynamic_locality() -> JobConfig {
        JobConfig {
            local_only: false,
            speculation: true,
            stealing: true,
            locality_stealing: true,
            ..Default::default()
        }
    }

    /// Attach a dynamics trace (builder style).
    pub fn with_dynamics(mut self, trace: ScenarioTrace) -> JobConfig {
        self.dynamics = Some(trace);
        self
    }

    /// Enable online re-optimization (builder style). `alpha` is the
    /// plan-model α the original plan was solved with.
    pub fn with_replan(mut self, policy: ReplanPolicy, alpha: f64) -> JobConfig {
        self.replan = policy;
        self.replan_alpha = alpha;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_size_accounts_overhead() {
        let r = Record::new("key", "value");
        assert_eq!(r.size(), 3 + 5 + RECORD_OVERHEAD);
        assert_eq!(batch_size(&[r.clone(), r]), 2 * (8 + 8));
    }

    #[test]
    fn default_config_matches_paper() {
        let c = JobConfig::default();
        assert_eq!(c.map_slots, 2);
        assert_eq!(c.reduce_slots, 1);
        assert_eq!(c.barriers.label(), "G-P-L");
        assert_eq!(c.replication, 1);
        assert!(c.n_buckets >= 64);
        // Finite retry budget by default: the failure profiles fail each
        // node at most a couple of times, so 4 keeps their behavior
        // identical while bounding flapping traces.
        assert_eq!(c.max_attempts, 4);
        // Replanning is strictly opt-in: the default engine is static.
        assert_eq!(c.replan, ReplanPolicy::Off);
        assert_eq!(c.replan_alpha, 1.0);
    }

    #[test]
    fn presets() {
        assert!(JobConfig::optimized().local_only);
        assert!(!JobConfig::optimized().speculation);
        let h = JobConfig::vanilla_hadoop();
        assert!(!h.local_only && h.speculation && h.stealing);
        assert!(!h.locality_stealing && h.dynamics.is_none());
        let d = JobConfig::dynamic_locality();
        assert!(!d.local_only && d.stealing && d.locality_stealing && d.speculation);
        let with = JobConfig::default().with_dynamics(ScenarioTrace::empty("none"));
        assert!(with.dynamics.is_some());
        let rp = JobConfig::optimized().with_replan(ReplanPolicy::OnEvent, 4.0);
        assert!(rp.replan.enabled() && rp.replan_alpha == 4.0);
    }
}
