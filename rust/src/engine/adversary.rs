//! Adversarial scenario search: the worst-case trace for a *given plan*.
//!
//! The churn experiment injects *random* seeded traces — but a plan that
//! survives seeded churn may still collapse under the worst-case
//! dynamics for that specific plan, which is exactly the regime
//! geo-distributed deployments care about (WAN variability is the
//! dominant unmodelled effect, arXiv:1707.01869; shuffle-pattern
//! sensitivity makes the damage plan-dependent, arXiv:2005.11608). This
//! module searches the trace space for the perturbation, within an
//! explicit budget, that maximizes makespan degradation of one concrete
//! `(plan, execution mode)` pair, using the deterministic executor as
//! the oracle.
//!
//! ## Perturbation budget
//!
//! A [`PerturbBudget`] bounds what the adversary may do — without a
//! budget the worst case is trivial (fail everything forever):
//!
//! * at most `max_outages` node outages (mapper or reducer), each with a
//!   bounded window (`≤ max_window_frac ×` horizon);
//! * at most `max_link_events` link-degradation windows with bounded
//!   scale factors (`≥ min_link_factor`, itself `≥` [`MIN_FACTOR`]).
//!
//! ## Search
//!
//! Candidates are small *genomes* — a list of outage / link-window genes
//! with times expressed as fractions of the horizon — evaluated by
//! materializing a [`ScenarioTrace`] and running the job. The search is
//! **seeded random restarts + greedy coordinate refinement**:
//!
//! 1. draw `restarts` random genomes from a seeded [`Pcg64`], plus any
//!    caller-provided seed traces (typically the seeded `failures`
//!    profile, so the found trace is guaranteed at least as bad);
//! 2. keep the genome with the largest makespan;
//! 3. per gene, try a deterministic move set (shift the window, extend
//!    it to the budget bound, retarget the victim along the
//!    attractiveness ranking, deepen the link degradation) and accept
//!    strictly improving moves; optionally grow the genome while under
//!    budget. Repeat for `refine_passes` passes or until no move helps.
//!
//! Everything is deterministic given [`SearchConfig::seed`]: the RNG
//! only shapes the initial candidates, moves are a fixed function of the
//! genome, and the executor oracle is bit-reproducible.

use super::dynamics::{DynEvent, ScenarioTrace, TimedEvent, TraceShape, MIN_FACTOR};
use super::executor::run_job;
use super::job::{JobConfig, MapReduceApp, Record};
use crate::model::plan::Plan;
use crate::platform::Topology;
use crate::util::rng::Pcg64;

/// What the adversary is allowed to perturb. All windows are fractions
/// of the search horizon (the static makespan).
#[derive(Debug, Clone, Copy)]
pub struct PerturbBudget {
    /// Maximum number of node outages (mapper + reducer combined).
    pub max_outages: usize,
    /// Maximum number of link-degradation windows.
    pub max_link_events: usize,
    /// Smallest allowed link scale factor (must be ≥ [`MIN_FACTOR`]).
    pub min_link_factor: f64,
    /// Longest outage / degradation window, as a fraction of the
    /// horizon.
    pub max_window_frac: f64,
}

impl PerturbBudget {
    /// A budget of `k` node outages with default link-event allowance
    /// (up to 2 windows), a 0.05 link-factor floor and windows bounded
    /// by one full horizon.
    pub fn outages(k: usize) -> PerturbBudget {
        PerturbBudget {
            max_outages: k,
            max_link_events: k.min(2),
            min_link_factor: 0.05,
            max_window_frac: 1.0,
        }
    }

    /// Budget sanity: the adversary must be allowed to do *something*,
    /// factors must respect the engine's floor, windows must be positive
    /// and bounded.
    pub fn validate(&self) -> Result<(), String> {
        if self.max_outages == 0 && self.max_link_events == 0 {
            return Err("adversary budget allows no perturbation at all".into());
        }
        if !(self.min_link_factor.is_finite() && self.min_link_factor >= MIN_FACTOR) {
            return Err(format!(
                "min_link_factor must be ≥ {MIN_FACTOR}, got {}",
                self.min_link_factor
            ));
        }
        if !(self.max_window_frac.is_finite()
            && self.max_window_frac > 0.0
            && self.max_window_frac <= 4.0)
        {
            return Err(format!(
                "max_window_frac must be in (0, 4], got {}",
                self.max_window_frac
            ));
        }
        Ok(())
    }
}

/// Search knobs. Deterministic given `seed`.
#[derive(Debug, Clone, Copy)]
pub struct SearchConfig {
    pub budget: PerturbBudget,
    pub seed: u64,
    /// Random initial candidates (on top of any caller-seeded traces).
    pub restarts: usize,
    /// Greedy coordinate-refinement passes over the best genome.
    pub refine_passes: usize,
    /// The static makespan of `(plan, base)` if the caller already
    /// measured it — skips the search's own baseline run. Must be the
    /// bit-exact executor result (the executor is deterministic, so a
    /// caller-side run of the same job qualifies); it anchors the
    /// horizon every candidate trace is scaled by.
    pub known_static_makespan: Option<f64>,
}

impl SearchConfig {
    pub fn new(budget: PerturbBudget, seed: u64) -> SearchConfig {
        SearchConfig {
            budget,
            seed,
            restarts: 6,
            refine_passes: 2,
            known_static_makespan: None,
        }
    }
}

/// The search outcome: the worst trace found and its damage.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// The worst-case trace (label `adversary:SEED`), replayable via
    /// [`JobConfig::with_dynamics`].
    pub trace: ScenarioTrace,
    /// Makespan of the attacked mode with no dynamics.
    pub static_makespan: f64,
    /// Makespan under the worst trace found.
    pub worst_makespan: f64,
    /// Executor evaluations spent.
    pub evals: usize,
}

impl SearchResult {
    /// Relative makespan degradation of the worst trace.
    pub fn degradation(&self) -> f64 {
        self.worst_makespan / self.static_makespan - 1.0
    }
}

/// One perturbation gene. Times are fractions of the horizon.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Gene {
    MapperOutage { node: usize, start: f64, window: f64 },
    ReducerOutage { node: usize, start: f64, window: f64 },
    LinkWindow { cluster: Option<usize>, factor: f64, start: f64, window: f64 },
}

fn outage_count(genes: &[Gene]) -> usize {
    genes
        .iter()
        .filter(|g| matches!(g, Gene::MapperOutage { .. } | Gene::ReducerOutage { .. }))
        .count()
}

/// Materialize a genome into a valid, time-sorted trace.
fn to_trace(genes: &[Gene], horizon: f64, label: &str) -> ScenarioTrace {
    let mut events = Vec::with_capacity(2 * genes.len());
    for g in genes {
        match *g {
            Gene::MapperOutage { node, start, window } => {
                events.push(TimedEvent {
                    time: start * horizon,
                    event: DynEvent::MapperFail { node },
                });
                events.push(TimedEvent {
                    time: (start + window) * horizon,
                    event: DynEvent::MapperRecover { node },
                });
            }
            Gene::ReducerOutage { node, start, window } => {
                events.push(TimedEvent {
                    time: start * horizon,
                    event: DynEvent::ReducerFail { node },
                });
                events.push(TimedEvent {
                    time: (start + window) * horizon,
                    event: DynEvent::ReducerRecover { node },
                });
            }
            Gene::LinkWindow { cluster, factor, start, window } => {
                let (hit, restore) = match cluster {
                    Some(c) => (
                        DynEvent::ClusterLinkScale { cluster: c, factor },
                        DynEvent::ClusterLinkScale { cluster: c, factor: 1.0 },
                    ),
                    None => (
                        DynEvent::WanScale { factor },
                        DynEvent::WanScale { factor: 1.0 },
                    ),
                };
                events.push(TimedEvent { time: start * horizon, event: hit });
                events.push(TimedEvent { time: (start + window) * horizon, event: restore });
            }
        }
    }
    ScenarioTrace::from_events(label, events)
}

/// Random genome within budget: mostly reducer outages on the most
/// attractive (plan-loaded) nodes, some mapper outages, an optional link
/// window.
fn gen_random(rng: &mut Pcg64, shape: &TraceShape, b: &PerturbBudget) -> Vec<Gene> {
    let m = shape.mapper_cluster.len();
    let r = shape.n_reducers;
    let mut genes = Vec::new();
    if b.max_outages > 0 && (m > 0 || r > 0) {
        let n_out = rng.range(1, b.max_outages + 1);
        for _ in 0..n_out {
            let start = rng.uniform(0.0, 0.8);
            let window = rng.uniform(0.3 * b.max_window_frac, b.max_window_frac);
            // Reducers hurt plan-enforcing modes most: bias toward them,
            // targeting the top half of the attractiveness ranking.
            if r > 0 && (m == 0 || rng.chance(0.6)) {
                let top = (r / 2).max(1);
                let node = shape.reducer_rank[rng.range(0, top.min(shape.reducer_rank.len()))];
                genes.push(Gene::ReducerOutage { node, start, window });
            } else if m > 0 {
                let node = rng.range(0, m);
                genes.push(Gene::MapperOutage { node, start, window });
            }
        }
    }
    if b.max_link_events > 0 && shape.n_clusters > 0 {
        let n_link = rng.range(0, b.max_link_events + 1);
        for _ in 0..n_link {
            let cluster = if rng.chance(0.3) {
                None // whole-WAN degradation
            } else {
                Some(rng.range(0, shape.n_clusters))
            };
            let factor = rng.uniform(b.min_link_factor, 0.30).max(b.min_link_factor);
            let start = rng.uniform(0.0, 0.7);
            let window = rng
                .uniform(0.25 * b.max_window_frac, 0.75 * b.max_window_frac)
                .max(0.01);
            genes.push(Gene::LinkWindow { cluster, factor, start, window });
        }
    }
    if genes.is_empty() {
        // Budget allows only link events but the coin said zero: take
        // one WAN window so every candidate perturbs something.
        genes.push(Gene::LinkWindow {
            cluster: None,
            factor: b.min_link_factor,
            start: 0.1,
            window: (0.5 * b.max_window_frac).max(0.01),
        });
    }
    genes
}

/// Best-effort import of an existing trace (e.g. the seeded `failures`
/// profile) into a genome, clipped to the budget: paired fail/recover
/// events become outage genes, paired degrade/restore link events become
/// link genes. Unpaired failures get the maximum window.
fn genes_from_trace(
    trace: &ScenarioTrace,
    horizon: f64,
    b: &PerturbBudget,
) -> Vec<Gene> {
    let frac = |t: f64| (t / horizon).max(0.0);
    let clamp_w = |w: f64| w.clamp(0.01, b.max_window_frac);
    let mut outages: Vec<Gene> = Vec::new();
    let mut links: Vec<Gene> = Vec::new();
    // (is_reducer, node) -> (start_frac, resolved)
    let mut open: Vec<(bool, usize, f64)> = Vec::new();
    let mut open_links: Vec<(Option<usize>, f64, f64)> = Vec::new(); // (cluster, factor, start)
    for te in trace.events() {
        match te.event {
            DynEvent::MapperFail { node } => open.push((false, node, frac(te.time))),
            DynEvent::ReducerFail { node } => open.push((true, node, frac(te.time))),
            DynEvent::MapperRecover { node } | DynEvent::ReducerRecover { node } => {
                let is_red = matches!(te.event, DynEvent::ReducerRecover { .. });
                if let Some(pos) =
                    open.iter().position(|&(r, n, _)| r == is_red && n == node)
                {
                    let (_, _, start) = open.remove(pos);
                    let window = clamp_w(frac(te.time) - start);
                    outages.push(if is_red {
                        Gene::ReducerOutage { node, start, window }
                    } else {
                        Gene::MapperOutage { node, start, window }
                    });
                }
            }
            DynEvent::ClusterLinkScale { cluster, factor } => {
                let cl = Some(cluster);
                if factor < 1.0 {
                    open_links.push((cl, factor.max(b.min_link_factor), frac(te.time)));
                } else if let Some(pos) = open_links.iter().position(|&(c, _, _)| c == cl) {
                    let (c, f, start) = open_links.remove(pos);
                    let window = clamp_w(frac(te.time) - start);
                    links.push(Gene::LinkWindow { cluster: c, factor: f, start, window });
                }
            }
            DynEvent::WanScale { factor } => {
                if factor < 1.0 {
                    open_links.push((None, factor.max(b.min_link_factor), frac(te.time)));
                } else if let Some(pos) = open_links.iter().position(|&(c, _, _)| c.is_none()) {
                    let (c, f, start) = open_links.remove(pos);
                    let window = clamp_w(frac(te.time) - start);
                    links.push(Gene::LinkWindow { cluster: c, factor: f, start, window });
                }
            }
            // Slowdowns and refreshes are outside the adversary's budget
            // vocabulary; ignore them in the import.
            _ => {}
        }
    }
    for (is_red, node, start) in open {
        let window = b.max_window_frac;
        outages.push(if is_red {
            Gene::ReducerOutage { node, start, window }
        } else {
            Gene::MapperOutage { node, start, window }
        });
    }
    // When the budget clips the import, keep reducer outages first —
    // they are what plan-enforcing modes cannot recover from.
    outages.sort_by_key(|g| match g {
        Gene::ReducerOutage { .. } => 0u8,
        _ => 1u8,
    });
    outages.truncate(b.max_outages);
    links.truncate(b.max_link_events);
    outages.extend(links);
    outages
}

/// Deterministic move set for one gene: shift / extend the window,
/// retarget the victim, deepen the degradation — each bounded by the
/// budget.
fn moves(g: Gene, b: &PerturbBudget, shape: &TraceShape) -> Vec<Gene> {
    let mut out = Vec::new();
    match g {
        Gene::MapperOutage { node, start, window } => {
            out.push(Gene::MapperOutage { node, start, window: b.max_window_frac });
            out.push(Gene::MapperOutage { node, start: (start - 0.15).max(0.0), window });
            out.push(Gene::MapperOutage { node, start: (start + 0.15).min(1.0), window });
            out.push(Gene::MapperOutage { node, start: 0.0, window: b.max_window_frac });
            let m = shape.mapper_cluster.len();
            if m > 1 {
                out.push(Gene::MapperOutage { node: (node + 1) % m, start, window });
            }
        }
        Gene::ReducerOutage { node, start, window } => {
            out.push(Gene::ReducerOutage { node, start, window: b.max_window_frac });
            out.push(Gene::ReducerOutage { node, start: (start - 0.15).max(0.0), window });
            out.push(Gene::ReducerOutage { node, start: (start + 0.15).min(1.0), window });
            out.push(Gene::ReducerOutage { node, start: 0.35, window: b.max_window_frac });
            // Retarget along the attractiveness ranking (where the plan
            // concentrates shuffle mass).
            let rank = &shape.reducer_rank;
            if rank.len() > 1 {
                let pos = rank.iter().position(|&k| k == node).unwrap_or(0);
                let next = rank[(pos + 1) % rank.len()];
                out.push(Gene::ReducerOutage { node: next, start, window });
            }
        }
        Gene::LinkWindow { cluster, factor, start, window } => {
            out.push(Gene::LinkWindow { cluster, factor: b.min_link_factor, start, window });
            out.push(Gene::LinkWindow {
                cluster,
                factor,
                start,
                window: b.max_window_frac,
            });
            out.push(Gene::LinkWindow {
                cluster,
                factor,
                start: (start - 0.15).max(0.0),
                window,
            });
            out.push(Gene::LinkWindow {
                cluster,
                factor,
                start: (start + 0.15).min(1.0),
                window,
            });
            if shape.n_clusters > 1 {
                let next = match cluster {
                    Some(c) => Some((c + 1) % shape.n_clusters),
                    None => Some(0),
                };
                out.push(Gene::LinkWindow { cluster: next, factor, start, window });
            }
        }
    }
    out
}

/// Search for the trace (within `cfg.budget`) that maximizes the
/// makespan of `(plan, base)` on `topo`. `seed_traces` join the initial
/// candidate pool (clipped to the budget), so passing the seeded
/// `failures` profile guarantees the result is at least as damaging as
/// it. `base` must carry no dynamics of its own.
pub fn search(
    topo: &Topology,
    plan: &Plan,
    app: &dyn MapReduceApp,
    base: &JobConfig,
    inputs: &[Vec<Record>],
    seed_traces: &[ScenarioTrace],
    cfg: &SearchConfig,
) -> Result<SearchResult, String> {
    cfg.budget.validate()?;
    if base.dynamics.is_some() {
        return Err("adversary base config must not carry its own dynamics trace".into());
    }
    let static_makespan = cfg
        .known_static_makespan
        .unwrap_or_else(|| run_job(topo, plan, app, base, inputs).metrics.makespan)
        .max(1e-9);
    let horizon = static_makespan;
    let shape = TraceShape::of(topo, horizon);
    let label = format!("adversary:{}", cfg.seed);

    let mut evals = 0usize;
    let mut eval = |genes: &[Gene]| -> f64 {
        evals += 1;
        let trace = to_trace(genes, horizon, &label);
        let cfg_dyn = base.clone().with_dynamics(trace);
        run_job(topo, plan, app, &cfg_dyn, inputs).metrics.makespan
    };

    // Initial pool: random restarts, then imported seed traces (ties go
    // to the earliest candidate, so an equally-bad random candidate wins
    // over the seed — refinement treats them the same).
    let mut rng = Pcg64::new(cfg.seed);
    let mut pool: Vec<Vec<Gene>> = (0..cfg.restarts.max(1))
        .map(|_| gen_random(&mut rng, &shape, &cfg.budget))
        .collect();
    for tr in seed_traces {
        let genes = genes_from_trace(tr, horizon, &cfg.budget);
        if !genes.is_empty() {
            pool.push(genes);
        }
    }

    let mut best_genes = pool[0].clone();
    let mut best_val = eval(&best_genes);
    for cand in &pool[1..] {
        let val = eval(cand);
        if val > best_val {
            best_val = val;
            best_genes = cand.clone();
        }
    }

    // Greedy coordinate refinement: per gene, take the best strictly
    // improving move; optionally grow the genome while under budget.
    for _pass in 0..cfg.refine_passes {
        let mut improved = false;
        for gi in 0..best_genes.len() {
            let mut best_move: Option<(Gene, f64)> = None;
            for mv in moves(best_genes[gi], &cfg.budget, &shape) {
                if mv == best_genes[gi] {
                    continue;
                }
                let mut cand = best_genes.clone();
                cand[gi] = mv;
                let val = eval(&cand);
                let bar = best_move.as_ref().map_or(best_val, |&(_, v)| v);
                if val > bar {
                    best_move = Some((mv, val));
                }
            }
            if let Some((mv, val)) = best_move {
                best_genes[gi] = mv;
                best_val = val;
                improved = true;
            }
        }
        // Grow: one more reducer outage on the highest-ranked reducer
        // not yet attacked, if the budget allows it.
        if outage_count(&best_genes) < cfg.budget.max_outages && shape.n_reducers > 0 {
            let attacked: Vec<usize> = best_genes
                .iter()
                .filter_map(|g| match g {
                    Gene::ReducerOutage { node, .. } => Some(*node),
                    _ => None,
                })
                .collect();
            if let Some(&fresh) =
                shape.reducer_rank.iter().find(|k| !attacked.contains(*k))
            {
                let mut cand = best_genes.clone();
                cand.push(Gene::ReducerOutage {
                    node: fresh,
                    start: 0.35,
                    window: cfg.budget.max_window_frac,
                });
                let val = eval(&cand);
                if val > best_val {
                    best_genes = cand;
                    best_val = val;
                    improved = true;
                }
            }
        }
        if !improved {
            break;
        }
    }

    Ok(SearchResult {
        trace: to_trace(&best_genes, horizon, &label),
        static_makespan,
        worst_makespan: best_val,
        evals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dynamics::DynProfile;

    fn shape() -> TraceShape {
        TraceShape {
            horizon: 100.0,
            n_clusters: 4,
            mapper_cluster: (0..8).map(|j| j % 4).collect(),
            n_sources: 4,
            n_reducers: 8,
            reducer_rank: (0..8).rev().collect(),
        }
    }

    #[test]
    fn budget_validation() {
        assert!(PerturbBudget::outages(3).validate().is_ok());
        assert!(PerturbBudget { max_outages: 0, max_link_events: 0, ..PerturbBudget::outages(1) }
            .validate()
            .is_err());
        assert!(PerturbBudget { min_link_factor: 0.0, ..PerturbBudget::outages(1) }
            .validate()
            .is_err());
        assert!(PerturbBudget { max_window_frac: 0.0, ..PerturbBudget::outages(1) }
            .validate()
            .is_err());
    }

    #[test]
    fn random_genomes_respect_budget() {
        let b = PerturbBudget::outages(3);
        let mut rng = Pcg64::new(9);
        for _ in 0..50 {
            let genes = gen_random(&mut rng, &shape(), &b);
            assert!(!genes.is_empty());
            assert!(outage_count(&genes) <= b.max_outages);
            let links = genes.len() - outage_count(&genes);
            assert!(links <= b.max_link_events);
            let tr = to_trace(&genes, 100.0, "t");
            // from_events validated every factor/time; outages pair up.
            assert_eq!(tr.len(), 2 * genes.len());
        }
    }

    #[test]
    fn seeded_failures_trace_imports_within_budget() {
        let sh = shape();
        let tr = ScenarioTrace::generate(DynProfile::Failures, 7, &sh);
        let b = PerturbBudget::outages(8);
        let genes = genes_from_trace(&tr, sh.horizon, &b);
        assert!(!genes.is_empty());
        assert!(outage_count(&genes) <= b.max_outages);
        // The failures profile always takes down a top-ranked reducer;
        // the import must preserve at least one reducer outage.
        assert!(
            genes.iter().any(|g| matches!(g, Gene::ReducerOutage { .. })),
            "{genes:?}"
        );
        for g in &genes {
            let window = match g {
                Gene::MapperOutage { window, .. }
                | Gene::ReducerOutage { window, .. }
                | Gene::LinkWindow { window, .. } => *window,
            };
            assert!(window > 0.0 && window <= b.max_window_frac);
        }
    }

    #[test]
    fn moves_stay_within_budget() {
        let b = PerturbBudget::outages(2);
        let sh = shape();
        let genes = [
            Gene::ReducerOutage { node: 7, start: 0.4, window: 0.5 },
            Gene::MapperOutage { node: 1, start: 0.1, window: 0.3 },
            Gene::LinkWindow { cluster: Some(1), factor: 0.2, start: 0.2, window: 0.3 },
        ];
        for g in genes {
            let ms = moves(g, &b, &sh);
            assert!(!ms.is_empty());
            for mv in ms {
                match mv {
                    Gene::MapperOutage { node, start, window } => {
                        assert!(node < sh.mapper_cluster.len());
                        assert!((0.0..=1.0).contains(&start));
                        assert!(window > 0.0 && window <= b.max_window_frac);
                    }
                    Gene::ReducerOutage { node, start, window } => {
                        assert!(node < sh.n_reducers);
                        assert!((0.0..=1.0).contains(&start));
                        assert!(window > 0.0 && window <= b.max_window_frac);
                    }
                    Gene::LinkWindow { cluster, factor, start, window } => {
                        if let Some(c) = cluster {
                            assert!(c < sh.n_clusters);
                        }
                        assert!(factor >= b.min_link_factor);
                        assert!((0.0..=1.0).contains(&start));
                        assert!(window > 0.0 && window <= b.max_window_frac);
                    }
                }
            }
        }
    }

    /// The window-extension move — the one that guarantees strict
    /// improvement over the seeded failures profile under plan
    /// enforcement — must always be present for outage genes.
    #[test]
    fn outage_moves_include_window_extension() {
        let b = PerturbBudget::outages(2);
        let g = Gene::ReducerOutage { node: 3, start: 0.4, window: 0.5 };
        let ms = moves(g, &b, &shape());
        assert!(ms.contains(&Gene::ReducerOutage {
            node: 3,
            start: 0.4,
            window: b.max_window_frac
        }));
    }
}
