//! Online re-optimization: the dynamics→planner loop (ROADMAP
//! direction 4, ISSUE 10).
//!
//! Plans used to be computed once and held static while the dynamics
//! subsystem churned bandwidth, failed nodes and re-dirtied sources.
//! This module closes the loop: at dynamics-event boundaries (policy
//! `on-event`), on a fixed virtual-time cadence (`every:T`), and on
//! resume-from-snapshot, the executor re-solves the end-to-end plan
//! against the *current effective platform* — capacities read live from
//! the fluid simulation, failed nodes discounted to near-zero, refreshed
//! sources re-priced — warm-starting each LP from the previous basis
//! ([`crate::optimizer::Replanner`]). The accepted plan then migrates
//! only **unstarted** work: map splits still `WaitingForData` re-home,
//! and key ranges with an empty shuffle ledger change owner. In-flight
//! transfers are never touched, so the exact byte-conservation ledgers
//! carry through replans unchanged.
//!
//! ## Invariants (pinned by tests/replan.rs)
//!
//! * **Neutrality** — `ReplanPolicy::Off` (the default, and the absent
//!   CLI flag) is bit-identical to the static path; a zero-event trace
//!   with replanning *on* never triggers a re-solve.
//! * **Hysteresis** — a re-solve only fires when the effective platform
//!   fingerprint deviates from the one the current plan was solved
//!   against by more than [`DEFAULT_HYSTERESIS`] (relative, per entry),
//!   so tiny perturbations don't thrash the LP.
//! * **Migration-only-of-unstarted-work** — a range moves only while
//!   its shuffle ledger is empty, its reduce unstarted and itself not
//!   dead-lettered; a split re-homes only while `WaitingForData`.
//! * **Resume composes** — capacities only change at trace events and
//!   the baseline fingerprint is not updated on a hysteresis skip, so
//!   the resume-time evaluation sees exactly the (fingerprint, baseline)
//!   pair of the last pre-crash evaluation and reaches the same
//!   decision: resumed runs finish bit-identical (only the sig-excluded
//!   `replans_skipped` provenance counter can differ).

use crate::model::plan::Plan;
use crate::optimizer::replanner::Replanner;
use crate::platform::Topology;
use crate::util::json::Json;
use crate::util::mat::Mat;

use super::dynamics::{DynEvent, ScenarioTrace};
use super::job::JobConfig;

/// Capacity multiplier for failed nodes in the effective platform. The
/// LP needs strictly positive capacities ([`Topology::validate`]); this
/// keeps a dead node representable while making it useless to the plan.
pub const DOWN_DISCOUNT: f64 = 1e-6;

/// Default hysteresis threshold: the maximum relative per-entry
/// deviation of the effective-platform fingerprint below which a due
/// re-solve is skipped (counted in `replans_skipped`).
pub const DEFAULT_HYSTERESIS: f64 = 0.05;

/// A `WaitingForData` split only re-homes when the best live mapper's
/// planned-load score exceeds this multiple of its current home's score
/// (or the home is down). The factor prices the extra fetch hop a
/// migrated split pays over `mr_link` — moving for marginal gains loses.
pub const REPLAN_MOVE_FACTOR: f64 = 2.0;

/// When to re-solve the plan mid-run. `Off` is bit-identical to the
/// static path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ReplanPolicy {
    /// Never re-solve (the static engine, unchanged).
    Off,
    /// Evaluate a re-solve at every dynamics-event boundary that
    /// actually applied an event.
    OnEvent,
    /// Evaluate a re-solve every `T` virtual seconds (independent of
    /// the trace; ticks stop once the job is idle with no trace events
    /// left, so an unfinished job cannot livelock on its own cadence).
    Every(f64),
}

impl Default for ReplanPolicy {
    fn default() -> Self {
        ReplanPolicy::Off
    }
}

impl ReplanPolicy {
    /// Parse the `--replan {off,on-event,every:T}` CLI spec.
    pub fn parse(spec: &str) -> Result<ReplanPolicy, String> {
        match spec {
            "off" => Ok(ReplanPolicy::Off),
            "on-event" => Ok(ReplanPolicy::OnEvent),
            _ => {
                if let Some(t) = spec.strip_prefix("every:") {
                    let v: f64 = t.parse().map_err(|_| {
                        format!(
                            "invalid value '{spec}' for --replan (every:T needs a \
                             numeric period T, e.g. every:2.5)"
                        )
                    })?;
                    if !(v.is_finite() && v > 0.0) {
                        return Err(format!(
                            "invalid value '{spec}' for --replan (every:T needs a \
                             finite period T > 0 in virtual seconds)"
                        ));
                    }
                    Ok(ReplanPolicy::Every(v))
                } else {
                    Err(format!(
                        "invalid value '{spec}' for --replan (expected off, on-event, \
                         or every:T)"
                    ))
                }
            }
        }
    }

    /// Canonical label — also the snapshot `compat` entry, so a snapshot
    /// taken under one policy refuses to resume under another.
    pub fn label(&self) -> String {
        match self {
            ReplanPolicy::Off => "off".into(),
            ReplanPolicy::OnEvent => "on-event".into(),
            ReplanPolicy::Every(t) => format!("every:{t}"),
        }
    }

    pub fn enabled(&self) -> bool {
        !matches!(self, ReplanPolicy::Off)
    }
}

/// The executor's replanning state: the current shuffle split (seed for
/// the next warm descent), the platform fingerprint the current plan
/// was solved against, the `every:T` tick, per-source refresh pricing,
/// and the warm-start bases (inside [`Replanner`]). Serialized into
/// snapshots by [`ReplanState::encode`] / [`ReplanState::restore`] so
/// post-resume re-solves warm-start from the same bases and stay
/// bit-identical to the uninterrupted run.
#[derive(Debug, Clone)]
pub struct ReplanState {
    pub policy: ReplanPolicy,
    /// Relative fingerprint deviation below which a due re-solve skips.
    pub hysteresis: f64,
    /// The shuffle split of the currently executing plan (the original
    /// plan's `y` until the first accepted re-solve).
    pub cur_y: Vec<f64>,
    /// Effective-platform fingerprint the current plan was solved
    /// against; replaced only on an *accepted* re-solve.
    pub baseline: Vec<f64>,
    /// Next `every:T` tick in virtual time (`None` for the other
    /// policies, or once ticks are exhausted — see `ReplanPolicy`).
    pub next_at: Option<f64>,
    /// Cumulative refreshed fraction per source (staleness pricing: a
    /// high-churn source inflates its effective data volume, steering
    /// the re-solved push toward cheap-to-re-push mappers).
    pub refreshed_frac: Vec<f64>,
    /// Warm-started LP replanner (persistent x/y bases).
    pub replanner: Replanner,
}

impl ReplanState {
    pub fn new(config: &JobConfig, plan: &Plan, topo: &Topology) -> ReplanState {
        ReplanState {
            policy: config.replan,
            hysteresis: DEFAULT_HYSTERESIS,
            cur_y: plan.y.clone(),
            baseline: fingerprint(topo),
            next_at: match config.replan {
                ReplanPolicy::Every(t) => Some(t),
                _ => None,
            },
            refreshed_frac: vec![0.0; topo.n_sources()],
            replanner: Replanner::default(),
        }
    }

    /// Record a landed source refresh (staleness pricing input).
    pub fn note_refresh(&mut self, source: usize, fraction: f64) {
        if source < self.refreshed_frac.len() && fraction.is_finite() && fraction > 0.0 {
            self.refreshed_frac[source] += fraction;
        }
    }

    /// Serialize the dynamic parts (policy and hysteresis are immutable
    /// run configuration, reconstructed from `JobConfig` on resume).
    pub fn encode(&self) -> Json {
        let f64s =
            |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::f64_bits(x)).collect());
        let basis = |b: &Option<Vec<usize>>| match b {
            Some(v) => Json::Arr(v.iter().map(|&x| Json::uint(x)).collect()),
            None => Json::Bool(false),
        };
        Json::Obj(vec![
            ("cur_y".into(), f64s(&self.cur_y)),
            ("baseline".into(), f64s(&self.baseline)),
            ("refreshed_frac".into(), f64s(&self.refreshed_frac)),
            ("next_at_set".into(), Json::Bool(self.next_at.is_some())),
            ("next_at".into(), Json::f64_bits(self.next_at.unwrap_or(0.0))),
            ("x_basis".into(), basis(&self.replanner.x_basis)),
            ("y_basis".into(), basis(&self.replanner.y_basis)),
        ])
    }

    /// Inverse of [`ReplanState::encode`], overlaying a freshly
    /// constructed state.
    pub fn restore(&mut self, j: &Json) -> Result<(), String> {
        let f64s = |j: &Json| -> Result<Vec<f64>, String> {
            j.as_arr()?.iter().map(|v| v.as_f64_bits()).collect()
        };
        let basis = |j: &Json| -> Result<Option<Vec<usize>>, String> {
            match j {
                Json::Bool(_) => Ok(None),
                _ => Ok(Some(
                    j.as_arr()?.iter().map(|v| v.as_usize()).collect::<Result<_, _>>()?,
                )),
            }
        };
        self.cur_y = f64s(j.field("cur_y")?)?;
        self.baseline = f64s(j.field("baseline")?)?;
        self.refreshed_frac = f64s(j.field("refreshed_frac")?)?;
        self.next_at = if j.field("next_at_set")?.as_bool()? {
            Some(j.field("next_at")?.as_f64_bits()?)
        } else {
            None
        };
        self.replanner.x_basis = basis(j.field("x_basis")?)?;
        self.replanner.y_basis = basis(j.field("y_basis")?)?;
        Ok(())
    }
}

/// Flatten the platform quantities the plan depends on, in a fixed
/// order, for hysteresis comparison. Pure function of the (effective)
/// topology.
pub fn fingerprint(topo: &Topology) -> Vec<f64> {
    let (s, m, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
    let mut fp = Vec::with_capacity(m + r + s + s * m + m * r);
    fp.extend_from_slice(&topo.c_map);
    fp.extend_from_slice(&topo.c_red);
    fp.extend_from_slice(&topo.d);
    for i in 0..s {
        for j in 0..m {
            fp.push(topo.b_sm.get(i, j));
        }
    }
    for j in 0..m {
        for k in 0..r {
            fp.push(topo.b_mr.get(j, k));
        }
    }
    fp
}

/// Maximum relative per-entry deviation between two fingerprints.
pub fn deviation(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut worst = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (x - y).abs() / y.abs().max(1e-12);
        if d > worst {
            worst = d;
        }
    }
    worst
}

/// Planned inbound data volume per mapper under a push plan `x` — the
/// split-migration score: the re-solved plan loads the mappers it
/// considers well-placed on the current platform.
pub fn mapper_scores(topo: &Topology, x: &Mat) -> Vec<f64> {
    (0..topo.n_mappers())
        .map(|j| (0..topo.n_sources()).map(|i| topo.d[i] * x.get(i, j)).sum())
        .collect()
}

/// Re-assign the *movable* key ranges to live reducers so owned data
/// mass tracks the new shuffle split `y_new`. `weights[k]` is range
/// `k`'s share of the shuffle volume (the original plan's `y` — the
/// partitioner is never rebuilt, so range mass is fixed at job start);
/// immovable ranges keep charging their current owner's quota. Greedy:
/// heaviest movable range first, into the live reducer with the largest
/// remaining deficit (exact ties prefer the current owner, then the
/// lowest index — fully deterministic).
pub fn assign_ranges(
    y_new: &[f64],
    weights: &[f64],
    owner: &[usize],
    movable: &[bool],
    up: &[bool],
) -> Vec<usize> {
    let r = y_new.len();
    debug_assert!(weights.len() == r && owner.len() == r && movable.len() == r);
    let mut deficit: Vec<f64> =
        (0..r).map(|k| if up[k] { y_new[k].max(0.0) } else { 0.0 }).collect();
    for k in 0..r {
        if !movable[k] {
            deficit[owner[k]] -= weights[k];
        }
    }
    let mut order: Vec<usize> = (0..r).filter(|&k| movable[k]).collect();
    // total_cmp + index tiebreak: deterministic even if a weight is NaN.
    order.sort_by(|&a, &b| weights[b].total_cmp(&weights[a]).then(a.cmp(&b)));
    let mut out = owner.to_vec();
    for k in order {
        let mut best: Option<usize> = None;
        for cand in 0..r {
            if !up[cand] {
                continue;
            }
            let better = match best {
                None => true,
                Some(cur) => {
                    deficit[cand] > deficit[cur]
                        || (deficit[cand] == deficit[cur]
                            && cand == owner[k]
                            && cur != owner[k])
                }
            };
            if better {
                best = Some(cand);
            }
        }
        // No live reducer at all: leave the range where it is (the
        // executor holds it for recovery, exactly like the static path).
        let Some(o) = best else { continue };
        out[k] = o;
        deficit[o] -= weights[k];
    }
    out
}

/// Derive a hedge rate from a set of (typically adversary-found)
/// traces: the mean per-reducer downtime fraction over the horizon,
/// clamped to `[0, 0.9]` (the [`crate::optimizer::FailureAwareOptimizer`]
/// domain is `[0, 1)`). An outage with no recovery extends to the
/// horizon. This is the "adversarial training" feed: search for the
/// worst trace against the static plan, then hedge the plan against
/// exactly the unavailability that trace implies.
pub fn hedge_rate_from_traces(
    traces: &[ScenarioTrace],
    horizon: f64,
    n_reducers: usize,
) -> f64 {
    if traces.is_empty() || n_reducers == 0 || !(horizon.is_finite() && horizon > 0.0) {
        return 0.0;
    }
    let mut total = 0.0f64;
    for tr in traces {
        let mut down_since: Vec<Option<f64>> = vec![None; n_reducers];
        let mut downtime = vec![0.0f64; n_reducers];
        for te in tr.events() {
            match te.event {
                DynEvent::ReducerFail { node } if node < n_reducers => {
                    if down_since[node].is_none() {
                        down_since[node] = Some(te.time);
                    }
                }
                DynEvent::ReducerRecover { node } if node < n_reducers => {
                    if let Some(t0) = down_since[node].take() {
                        downtime[node] += (te.time.min(horizon) - t0.min(horizon)).max(0.0);
                    }
                }
                _ => {}
            }
        }
        for k in 0..n_reducers {
            if let Some(t0) = down_since[k] {
                downtime[k] += (horizon - t0.min(horizon)).max(0.0);
            }
            total += (downtime[k] / horizon).min(1.0);
        }
    }
    (total / (traces.len() * n_reducers) as f64).clamp(0.0, 0.9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dynamics::TimedEvent;
    use crate::platform::scale::{generate_kind, ScaleKind};

    #[test]
    fn policy_parse_accepts_the_three_forms() {
        assert_eq!(ReplanPolicy::parse("off").unwrap(), ReplanPolicy::Off);
        assert_eq!(ReplanPolicy::parse("on-event").unwrap(), ReplanPolicy::OnEvent);
        assert_eq!(
            ReplanPolicy::parse("every:2.5").unwrap(),
            ReplanPolicy::Every(2.5)
        );
        assert!(!ReplanPolicy::Off.enabled());
        assert!(ReplanPolicy::OnEvent.enabled());
        assert!(ReplanPolicy::Every(1.0).enabled());
        assert_eq!(ReplanPolicy::default(), ReplanPolicy::Off);
    }

    #[test]
    fn policy_parse_rejects_garbage() {
        for bad in ["bogus", "every:0", "every:-1", "every:nan", "every:x", "every:", "on"] {
            let e = ReplanPolicy::parse(bad).unwrap_err();
            assert!(e.contains("--replan"), "{bad}: {e}");
        }
    }

    #[test]
    fn policy_label_round_trips() {
        for p in [ReplanPolicy::Off, ReplanPolicy::OnEvent, ReplanPolicy::Every(2.5)] {
            assert_eq!(ReplanPolicy::parse(&p.label()).unwrap(), p);
        }
    }

    #[test]
    fn deviation_is_max_relative_entry_delta() {
        let base = vec![10.0, 20.0, 30.0];
        assert_eq!(deviation(&base, &base), 0.0);
        let moved = vec![10.0, 18.0, 30.0]; // 10% off on entry 1
        assert!((deviation(&moved, &base) - 0.1).abs() < 1e-12);
        // A discounted-then-recovered entry dominates.
        let huge = vec![10.0, 20.0, 30.0 / DOWN_DISCOUNT];
        assert!(deviation(&huge, &base) > 1e3);
    }

    #[test]
    fn fingerprint_covers_every_planned_quantity() {
        let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
        let (s, m, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
        let fp = fingerprint(&topo);
        assert_eq!(fp.len(), m + r + s + s * m + m * r);
        // Scaling one WAN entry moves exactly that fingerprint slot.
        let mut t2 = topo.clone();
        t2.b_mr.set(0, r - 1, topo.b_mr.get(0, r - 1) * 0.5);
        let fp2 = fingerprint(&t2);
        assert_eq!(fp.iter().zip(&fp2).filter(|(a, b)| a != b).count(), 1);
        assert!((deviation(&fp2, &fp) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn assign_ranges_tracks_the_new_split() {
        // 4 ranges of equal weight, all movable, all reducers live; the
        // new split wants everything on reducers 2 and 3.
        let y_new = vec![0.0, 0.0, 0.5, 0.5];
        let w = vec![0.25; 4];
        let owner = vec![0, 1, 2, 3];
        let got = assign_ranges(&y_new, &w, &owner, &[true; 4], &[true; 4]);
        assert!(got.iter().all(|&o| o == 2 || o == 3), "{got:?}");
        // Deficit-greedy balances: two ranges each.
        assert_eq!(got.iter().filter(|&&o| o == 2).count(), 2);
    }

    #[test]
    fn assign_ranges_respects_pins_and_dead_reducers() {
        let y_new = vec![1.0, 0.0, 0.0, 0.0];
        let w = vec![0.25; 4];
        let owner = vec![0, 1, 2, 3];
        // Range 1 immovable; reducer 0 (the split's favorite) is dead.
        let movable = [true, false, true, true];
        let up = [false, true, true, true];
        let got = assign_ranges(&y_new, &w, &owner, &movable, &up);
        assert_eq!(got[1], 1, "immovable range must keep its owner");
        assert!(got.iter().enumerate().all(|(k, &o)| !movable[k] || o != 0));
        // Exact tie on zero deficit: the current owner is preferred.
        let stay = assign_ranges(&[0.25; 4], &[0.25; 4], &owner, &[true; 4], &[true; 4]);
        assert_eq!(stay, owner, "a no-op split must not shuffle owners");
    }

    #[test]
    fn hedge_rate_measures_downtime_fraction() {
        let horizon = 100.0;
        let tr = ScenarioTrace::from_events(
            "one-down",
            vec![
                TimedEvent { time: 0.0, event: DynEvent::ReducerFail { node: 0 } },
                TimedEvent { time: 50.0, event: DynEvent::ReducerRecover { node: 0 } },
            ],
        );
        // One of four reducers down half the horizon: 0.5 / 4 = 0.125.
        let rate = hedge_rate_from_traces(std::slice::from_ref(&tr), horizon, 4);
        assert!((rate - 0.125).abs() < 1e-12, "{rate}");
        // No recovery: the outage extends to the horizon.
        let tr2 = ScenarioTrace::from_events(
            "forever",
            vec![TimedEvent { time: 25.0, event: DynEvent::ReducerFail { node: 0 } }],
        );
        let rate2 = hedge_rate_from_traces(std::slice::from_ref(&tr2), horizon, 1);
        assert!((rate2 - 0.75).abs() < 1e-12, "{rate2}");
        // Clamped into the FailureAwareOptimizer domain.
        let tr3 = ScenarioTrace::from_events(
            "dead-from-start",
            vec![TimedEvent { time: 0.0, event: DynEvent::ReducerFail { node: 0 } }],
        );
        assert_eq!(hedge_rate_from_traces(std::slice::from_ref(&tr3), horizon, 1), 0.9);
        assert_eq!(hedge_rate_from_traces(&[], horizon, 4), 0.0);
        assert_eq!(hedge_rate_from_traces(std::slice::from_ref(&tr), 0.0, 4), 0.0);
    }

    #[test]
    fn mapper_scores_weight_volume_by_plan() {
        let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
        let plan = Plan::local_push(&topo);
        let scores = mapper_scores(&topo, &plan.x);
        assert_eq!(scores.len(), topo.n_mappers());
        let total: f64 = scores.iter().sum();
        let volume: f64 = topo.d.iter().sum();
        assert!((total - volume).abs() <= 1e-9 * volume, "{total} vs {volume}");
    }

    #[test]
    fn state_encode_restore_round_trips() {
        let topo = generate_kind(ScaleKind::HierarchicalWan, 16, 3);
        let plan = Plan::local_push(&topo);
        let cfg = JobConfig { replan: ReplanPolicy::Every(3.5), ..JobConfig::default() };
        let mut st = ReplanState::new(&cfg, &plan, &topo);
        st.note_refresh(2, 0.4);
        st.note_refresh(2, 0.4);
        st.note_refresh(usize::MAX, 0.4); // out of range: ignored
        st.cur_y[0] += 0.125;
        st.next_at = Some(7.0);
        st.replanner.x_basis = Some(vec![3, 1, 4, 1, 5]);
        let j = st.encode();
        let mut back = ReplanState::new(&cfg, &plan, &topo);
        back.restore(&j).unwrap();
        assert_eq!(back.cur_y, st.cur_y);
        assert_eq!(back.baseline, st.baseline);
        assert_eq!(back.refreshed_frac, st.refreshed_frac);
        assert!((back.refreshed_frac[2] - 0.8).abs() < 1e-12);
        assert_eq!(back.next_at, Some(7.0));
        assert_eq!(back.replanner.x_basis, Some(vec![3, 1, 4, 1, 5]));
        assert_eq!(back.replanner.y_basis, None);
    }
}
