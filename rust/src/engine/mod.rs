//! The plan-enforcing MapReduce engine — our substitute for the paper's
//! modified Hadoop 1.0.1 running on the `tc`-emulated PlanetLab testbed
//! (§3.1–3.2). Virtual-time fluid simulation of transfers and compute,
//! real execution of map/reduce functions over real records.

pub mod executor;
pub mod fluid;
pub mod job;
pub mod metrics;
pub mod partitioner;

pub use executor::{run_job, JobResult};
pub use job::{JobConfig, MapReduceApp, Record};
pub use metrics::JobMetrics;
pub use partitioner::Partitioner;
