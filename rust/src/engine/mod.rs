//! The plan-enforcing MapReduce engine — our substitute for the paper's
//! modified Hadoop 1.0.1 running on the `tc`-emulated PlanetLab testbed
//! (§3.1–3.2). Virtual-time fluid simulation of transfers and compute,
//! real execution of map/reduce functions over real records.
//!
//! The engine core is discrete-event and policy-pluggable:
//!
//! * [`fluid`] — max-min-fair fluid simulation of links/NICs/CPUs;
//! * [`events`] — the virtual-clock event heap ([`EventQueue`]) and the
//!   phase-transition vocabulary ([`EngineEvent`]);
//! * [`scheduler`] — the [`Scheduler`] trait with plan-local, dynamic
//!   (stealing + speculation, §4.6.4, including locality-aware
//!   stealing) and replan (home-following) policy families;
//! * [`dynamics`] — seeded scenario traces injecting time-varying
//!   bandwidth, mapper *and reducer* failures/recoveries, compute
//!   stragglers and correlated data staleness (see the reducer-failure
//!   and staleness lifecycles in the module docs);
//! * [`adversary`] — budgeted adversarial trace search: the worst-case
//!   churn for a *given plan*, found by seeded random restarts plus
//!   greedy refinement with the executor as the deterministic oracle;
//! * [`executor`] — the thin orchestrator driving push/map/shuffle/
//!   reduce as events over the pieces above, re-queuing map work lost to
//!   injected failures, replaying/re-partitioning reduce work via the
//!   retained shuffle-transfer table (restartable reduce), and
//!   re-sending stale push data via the retained push-transfer table;
//! * [`tenancy`] — the multi-tenant job-stream layer: seeded arrival
//!   processes feed a queue, a cross-job [`StreamPolicy`] (FIFO,
//!   fair-share, deadline-aware admission) admits jobs, and every
//!   in-flight job runs over ONE shared fluid network, contending for
//!   the same links under max-min fairness;
//! * [`replan`] — online re-optimization: at dynamics-event boundaries
//!   (or on a fixed virtual-time cadence) the executor re-solves the
//!   plan against the *current* effective platform — live fluid
//!   capacities, failed nodes discounted, refreshed sources re-priced —
//!   warm-starting each LP from the previous basis, and migrates only
//!   *unstarted* work to the new plan;
//! * [`snapshot`] — the versioned checkpoint codec and the
//!   crash-surviving drivers: resume from a checkpoint finishes
//!   bit-identical to the uninterrupted run, and work that exhausts its
//!   retry budget lands in the executor's dead-letter queue instead of
//!   requeueing forever.

pub mod adversary;
pub mod dynamics;
pub mod events;
pub mod executor;
pub mod fluid;
pub mod job;
pub mod metrics;
pub mod partitioner;
pub mod replan;
pub mod scheduler;
pub mod snapshot;
pub mod tenancy;

pub use adversary::{PerturbBudget, SearchConfig, SearchResult};
pub use dynamics::{DynEvent, DynProfile, ScenarioTrace, TimedEvent, TraceShape};
pub use events::{EngineEvent, EventQueue};
// `executor::JobOutcome` (how one job ended) is deliberately NOT
// re-exported here: the root-level `JobOutcome` name belongs to the
// tenancy layer's per-job stream outcome. Use the full path.
pub use executor::{run_job, DeadLetterQueue, DlqEntry, DlqKind, JobResult};
pub use job::{JobConfig, MapReduceApp, Record};
pub use metrics::JobMetrics;
pub use partitioner::Partitioner;
pub use replan::ReplanPolicy;
pub use scheduler::{
    stream_policy, DynamicScheduler, PlanLocalScheduler, ReplanScheduler, Scheduler,
    StreamDecision, StreamPolicy,
};
pub use snapshot::{run_job_with_recovery, RecoveryOpts};
pub use tenancy::{
    run_stream, run_stream_with_recovery, ArrivalSpec, JobOutcome, StreamJob, StreamResult,
};
