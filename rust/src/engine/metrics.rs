//! Per-job execution metrics collected by the engine.

/// Phase spans and traffic accounting for one executed job.
#[derive(Debug, Clone, Default)]
pub struct JobMetrics {
    /// Virtual time when the last reducer finished — the makespan.
    pub makespan: f64,
    /// Last push-transfer completion.
    pub push_end: f64,
    /// Last map-task completion.
    pub map_end: f64,
    /// Last shuffle-transfer completion.
    pub shuffle_end: f64,
    /// Bytes moved source→mapper (including replication copies).
    pub push_bytes: f64,
    /// Bytes moved mapper→reducer.
    pub shuffle_bytes: f64,
    /// Bytes written as final output (including replication copies).
    pub output_bytes: f64,
    pub n_map_tasks: usize,
    pub n_reduce_tasks: usize,
    /// Speculative copies launched / won.
    pub spec_launched: usize,
    pub spec_won: usize,
    /// Tasks executed on a non-plan node via work stealing.
    pub stolen: usize,
    /// Dynamics events applied from the scenario trace.
    pub dyn_events: usize,
    /// Node failures injected, mapper and reducer (recoveries are not
    /// counted).
    pub failures_injected: usize,
    /// Map tasks evicted by a node failure and re-queued.
    pub tasks_requeued: usize,
    /// Reducer failures injected.
    pub reducers_failed: usize,
    /// Key ranges adopted by a surviving reducer after a failure
    /// (plan-enforcing schedulers decline and wait for recovery instead).
    pub reduce_ranges_reassigned: usize,
    /// Shuffle bytes re-sent because a reducer failure lost them (the
    /// replay traffic on top of `shuffle_bytes`).
    pub reduce_bytes_replayed: f64,
    /// Shuffle bytes currently *credited* as delivered: incremented on
    /// delivery, de-credited when a reducer failure loses data that had
    /// already arrived. At job end every unique shuffle byte is credited
    /// exactly once — delivered to a reducer or written off to the DLQ —
    /// so `shuffle_bytes_delivered + dlq_bytes == shuffle_bytes`, the
    /// byte-conservation invariant property-tested in tests/dynamics.rs
    /// (total wire traffic is `shuffle_bytes + reduce_bytes_replayed`).
    pub shuffle_bytes_delivered: f64,
    /// Source-refresh events (staleness dynamics) that actually
    /// re-dirtied in-progress push data. A refresh landing after every
    /// affected split sealed is a no-op for this job and is not counted.
    pub sources_refreshed: usize,
    /// Push bytes re-sent because a source refresh re-dirtied them (the
    /// staleness replay traffic on top of `push_bytes`). Mirrors
    /// `reduce_bytes_replayed` on the push side.
    pub push_bytes_repushed: f64,
    /// Push bytes currently *credited* as delivered: incremented on
    /// arrival, de-credited when a source refresh invalidates a copy that
    /// had already arrived. At job end every unique push byte is credited
    /// exactly once, so `push_bytes_delivered == push_bytes` — the same
    /// exact-integer conservation discipline as the shuffle (total push
    /// wire traffic is `push_bytes + push_bytes_repushed`).
    pub push_bytes_delivered: f64,
    /// Input / intermediate / output record counts (conservation checks).
    pub input_records: usize,
    pub intermediate_records: usize,
    pub output_records: usize,
    /// Key ranges routed to the dead-letter queue after exhausting the
    /// retry budget (`JobConfig.max_attempts`). A dead-lettered range
    /// never runs its reduce; its shuffle bytes move to `dlq_bytes`.
    pub ranges_dead_lettered: usize,
    /// Map splits routed to the dead-letter queue after exhausting the
    /// retry budget. The split's map output is never produced, so no
    /// shuffle bytes exist for it (its push bytes were delivered and
    /// stay credited).
    pub splits_dead_lettered: usize,
    /// Shuffle bytes written off to the dead-letter queue. Generalizes
    /// the conservation identity: at job end
    /// `shuffle_bytes_delivered + dlq_bytes == shuffle_bytes` exactly
    /// (with an empty DLQ this collapses to today's equality).
    pub dlq_bytes: f64,
    /// Simulated coordinator crash/restart cycles survived via
    /// checkpoint/resume. Provenance, not simulation state: a resumed
    /// run is bit-identical to the uninterrupted run in every *other*
    /// field, so this counter is excluded from the `sig()` identity
    /// used by the determinism tests.
    pub coordinator_restarts: usize,
    /// Accepted mid-run re-solves (online re-optimization,
    /// `engine::replan`). Part of the `sig()` identity: a resumed
    /// replanning run must replay exactly the re-solves of the
    /// uninterrupted run.
    pub replans: usize,
    /// Due re-solve evaluations that declined: hysteresis (effective
    /// platform within threshold of the one the current plan was solved
    /// against), an unsolvable effective LP, and the resume-time
    /// evaluation (which re-checks an already-evaluated boundary).
    /// Provenance like `coordinator_restarts` — a resumed run records
    /// one extra skip per resume — so this counter is excluded from the
    /// `sig()` identity used by the determinism tests.
    pub replans_skipped: usize,
    /// `WaitingForData` map splits re-homed to a better mapper by an
    /// accepted re-solve.
    pub replan_migrated_splits: usize,
    /// Key ranges moved to a new owning reducer by an accepted re-solve
    /// (only ranges with an empty shuffle ledger and an unstarted
    /// reduce ever move).
    pub replan_migrated_ranges: usize,
    /// Fluid-engine hot-path counters: rate-recompute invocations and the
    /// cumulative number of resources whose component was actually
    /// re-filled (the incremental solver skips clean components, so
    /// `fluid_resources_touched` ≪ resolves × total resources on sparse
    /// event streams). Independent of the configured thread count.
    pub fluid_resolves: u64,
    pub fluid_resources_touched: u64,
}

impl JobMetrics {
    /// The three stacked segments Fig 9 reports (shuffle overlaps map and
    /// reduce under Hadoop semantics, so the paper shows push, overlapped
    /// map/shuffle, and overlapped shuffle/reduce).
    pub fn fig9_segments(&self) -> (f64, f64, f64) {
        let push = self.push_end;
        let map_shuffle = (self.map_end - self.push_end).max(0.0);
        let rest = (self.makespan - self.map_end).max(0.0);
        (push, map_shuffle, rest)
    }

    /// Four-phase breakdown (for model-comparison reporting).
    pub fn phase_breakdown(&self) -> (f64, f64, f64, f64) {
        let push = self.push_end;
        let map = (self.map_end - self.push_end).max(0.0);
        let shuffle = (self.shuffle_end - self.map_end).max(0.0);
        let reduce = (self.makespan - self.shuffle_end.max(self.map_end)).max(0.0);
        (push, map, shuffle, reduce)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segments_sum_to_makespan() {
        let m = JobMetrics {
            makespan: 100.0,
            push_end: 20.0,
            map_end: 55.0,
            shuffle_end: 80.0,
            ..Default::default()
        };
        let (a, b, c) = m.fig9_segments();
        assert_eq!(a + b + c, 100.0);
        let (p, mm, s, r) = m.phase_breakdown();
        assert!((p + mm + s + r - 100.0).abs() < 1e-12);
    }

    #[test]
    fn overlapping_phases_clamp() {
        // Pipelined runs can have map_end > shuffle_end (stragglers).
        let m = JobMetrics {
            makespan: 50.0,
            push_end: 10.0,
            map_end: 45.0,
            shuffle_end: 40.0,
            ..Default::default()
        };
        let (_, _, s, r) = m.phase_breakdown();
        assert_eq!(s, 0.0);
        assert_eq!(r, 5.0);
    }
}
