//! The bucketized fractional partitioner of §3.1.3.
//!
//! Hadoop's default partitioner hashes intermediate keys into exactly
//! `|R|` partitions — which can only express the uniform shuffle. The
//! paper's modification hashes into `n_buckets ≫ |R|` small buckets and
//! assigns each reducer a *number of buckets proportional to its `y_k`
//! fraction* (largest-remainder apportionment here), realizing any
//! execution plan's `{y_k}` while preserving the one-reducer-per-key
//! semantics (eq 3): a key's bucket — hence its reducer — is a pure
//! function of the key, identical at every mapper.

/// FNV-1a 64-bit: deterministic, platform-independent key hashing.
pub fn fnv1a(key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Maps intermediate keys → buckets → reducers per the plan's `y`.
#[derive(Debug, Clone)]
pub struct Partitioner {
    bucket_owner: Vec<usize>,
    n_reducers: usize,
}

impl Partitioner {
    /// Build from the key-space fractions `y` (must sum to ~1).
    pub fn from_fractions(y: &[f64], n_buckets: usize) -> Partitioner {
        assert!(!y.is_empty());
        assert!(n_buckets >= y.len(), "need at least one bucket per reducer");
        let sum: f64 = y.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "y must sum to 1, got {sum}");

        // Largest-remainder apportionment of buckets to reducers.
        let quotas: Vec<f64> = y.iter().map(|f| f * n_buckets as f64).collect();
        let mut counts: Vec<usize> = quotas.iter().map(|q| q.floor() as usize).collect();
        let assigned: usize = counts.iter().sum();
        let mut remainders: Vec<(usize, f64)> = quotas
            .iter()
            .enumerate()
            .map(|(k, q)| (k, q - q.floor()))
            .collect();
        // total_cmp (descending): degenerate fractions must not panic.
        remainders.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        for i in 0..(n_buckets - assigned) {
            counts[remainders[i % remainders.len()].0] += 1;
        }

        // Interleave ownership round-robin-by-share so that consecutive
        // buckets spread across reducers (mirrors hash uniformity).
        let mut bucket_owner = Vec::with_capacity(n_buckets);
        let mut remaining = counts.clone();
        while bucket_owner.len() < n_buckets {
            // Pick the reducer with the largest remaining/total ratio.
            let k = (0..y.len())
                .filter(|&k| remaining[k] > 0)
                .max_by(|&a, &b| {
                    let ra = remaining[a] as f64 / (counts[a].max(1)) as f64;
                    let rb = remaining[b] as f64 / (counts[b].max(1)) as f64;
                    ra.total_cmp(&rb).then(b.cmp(&a))
                })
                .expect("buckets remain but no reducer has quota");
            bucket_owner.push(k);
            remaining[k] -= 1;
        }
        Partitioner { bucket_owner, n_reducers: y.len() }
    }

    pub fn n_buckets(&self) -> usize {
        self.bucket_owner.len()
    }

    pub fn n_reducers(&self) -> usize {
        self.n_reducers
    }

    /// Bucket of a grouping key.
    pub fn bucket(&self, group_key: &str) -> usize {
        (fnv1a(group_key) % self.bucket_owner.len() as u64) as usize
    }

    /// Reducer that owns a grouping key.
    pub fn reducer(&self, group_key: &str) -> usize {
        self.bucket_owner[self.bucket(group_key)]
    }

    /// Number of buckets owned by each reducer.
    pub fn bucket_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_reducers];
        for &o in &self.bucket_owner {
            counts[o] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::qcheck::{ensure, qcheck, Config};

    #[test]
    fn uniform_fractions_even_buckets() {
        let p = Partitioner::from_fractions(&[0.25; 4], 64);
        assert_eq!(p.bucket_counts(), vec![16; 4]);
    }

    #[test]
    fn fractions_respected_paper_example() {
        // §3.1.3's example: R1 gets 2/3 of keys, R2 gets 1/3.
        let p = Partitioner::from_fractions(&[2.0 / 3.0, 1.0 / 3.0], 512);
        let counts = p.bucket_counts();
        assert!((counts[0] as f64 / 512.0 - 2.0 / 3.0).abs() < 0.01, "{counts:?}");
    }

    #[test]
    fn zero_fraction_reducer_gets_nothing() {
        let p = Partitioner::from_fractions(&[1.0, 0.0], 128);
        assert_eq!(p.bucket_counts(), vec![128, 0]);
        for key in ["a", "b", "c", "hello"] {
            assert_eq!(p.reducer(key), 0);
        }
    }

    #[test]
    fn deterministic_and_consistent_across_mappers() {
        // Same construction → same key routing (the eq-3 requirement:
        // every mapper must use the same hash function).
        let p1 = Partitioner::from_fractions(&[0.5, 0.3, 0.2], 256);
        let p2 = Partitioner::from_fractions(&[0.5, 0.3, 0.2], 256);
        for i in 0..1000 {
            let key = format!("key-{i}");
            assert_eq!(p1.reducer(&key), p2.reducer(&key));
        }
    }

    #[test]
    fn realized_key_fractions_approach_y() {
        let y = [0.6, 0.25, 0.15];
        let p = Partitioner::from_fractions(&y, 512);
        let mut counts = [0usize; 3];
        let n = 50_000;
        for i in 0..n {
            counts[p.reducer(&format!("user-{i}"))] += 1;
        }
        for k in 0..3 {
            let realized = counts[k] as f64 / n as f64;
            assert!(
                (realized - y[k]).abs() < 0.03,
                "reducer {k}: realized {realized} vs target {}",
                y[k]
            );
        }
    }

    /// Regression companion to the total_cmp hardening: degenerate
    /// fractions (mass concentrated on one reducer, subnormal-tiny
    /// shares, maximal remainder ties) must apportion without panicking
    /// and still hand out every bucket. (A NaN fraction is rejected
    /// earlier by the sum-to-1 assert; the total_cmp sorts are
    /// defense-in-depth for the comparison itself.)
    #[test]
    fn degenerate_fractions_apportion_without_panic() {
        // Near-total concentration with a dust tail.
        let tiny = 1e-300;
        let y = [1.0 - 3.0 * tiny, tiny, tiny, tiny];
        let p = Partitioner::from_fractions(&y, 64);
        assert_eq!(p.bucket_counts().iter().sum::<usize>(), 64);
        assert_eq!(p.bucket_counts()[0], 64, "dust shares round to zero buckets");
        // All-equal remainders (every quota exactly fractional .5).
        let p = Partitioner::from_fractions(&[0.25; 4], 6);
        assert_eq!(p.bucket_counts().iter().sum::<usize>(), 6);
        // Zero fractions mixed with ties.
        let p = Partitioner::from_fractions(&[0.5, 0.5, 0.0, 0.0], 7);
        let c = p.bucket_counts();
        assert_eq!(c.iter().sum::<usize>(), 7);
        assert_eq!(c[2] + c[3], 0);
    }

    #[test]
    fn qcheck_all_buckets_assigned_and_totals_match() {
        qcheck(Config::default().cases(100), "partitioner apportionment", |rng| {
            let r = rng.range(1, 9);
            let mut y: Vec<f64> = (0..r).map(|_| rng.exponential(1.0)).collect();
            let s: f64 = y.iter().sum();
            y.iter_mut().for_each(|v| *v /= s);
            let n_buckets = rng.range(r, 1024);
            let p = Partitioner::from_fractions(&y, n_buckets);
            let counts = p.bucket_counts();
            ensure(counts.iter().sum::<usize>() == n_buckets, "bucket total")?;
            for (k, &c) in counts.iter().enumerate() {
                let target = y[k] * n_buckets as f64;
                ensure(
                    (c as f64 - target).abs() <= 1.0 + 1e-9,
                    format!("reducer {k}: {c} buckets vs quota {target}"),
                )?;
            }
            Ok(())
        });
    }
}
