//! The plan-enforcing MapReduce executor — our equivalent of the paper's
//! modified Hadoop (§3.1) running on the emulated testbed (§3.2).
//!
//! The executor is a thin orchestrator over three separable pieces:
//!
//! * **[`super::fluid`]** — the fluid (processor-sharing) simulation that
//!   prices every transfer and compute against link/NIC/CPU capacities;
//! * **[`super::events`]** — the virtual-clock event heap: every fluid
//!   completion becomes a timestamped [`EngineEvent`] dispatched in
//!   non-decreasing virtual time (same-time events FIFO);
//! * **[`super::scheduler`]** — pluggable placement policies; the
//!   executor builds a [`SchedView`] snapshot and applies whatever
//!   [`Assignment`]s the policy returns, enforcing slot capacity.
//!
//! The phase state machine it drives (§3.1):
//!
//! * **push** (§3.1.2): input splits destined for mapper `j` read from
//!   each source `i` in proportion to `x_ij`, exactly like the custom
//!   `InputFormat`/`InputSplit`.
//! * **map** (§3.1.1): `LocalOnly` coupling — map tasks run on the node
//!   their split was pushed to (unless stolen/speculated, §4.6.4).
//! * **shuffle** (§3.1.3): intermediate keys hash into buckets; buckets
//!   are apportioned to reducers per `y_k` ([`super::partitioner`]).
//! * **reduce**: a reducer starts when it holds all of its input (the
//!   local shuffle/reduce barrier Hadoop has by default); the global
//!   variant waits for every shuffle. A pipelined shuffle/reduce barrier
//!   requires application-level changes (Verma et al. [28], §3.1.4) and
//!   is treated as Local by the engine (the *model* supports it).
//!
//! **Reduce is restartable**: every shuffle transfer is recorded in a
//! transfer table (source node, key range, payload, bytes), and each key
//! range has a current *owner* reducer (identity until a failure moves
//! it). When a reducer fails ([`super::dynamics::DynEvent::ReducerFail`])
//! its in-flight transfers and running reduce compute are cancelled
//! deterministically, delivered-but-unreduced bytes are de-credited, and
//! the scheduler is asked per orphaned range for a surviving adopter
//! ([`Scheduler::reassign_reduce`]) — plan-enforcing policies decline and
//! the range waits for recovery instead. Lost transfers are replayed
//! from their originating mappers (map outputs are durable until job
//! end, as in Hadoop) and the range's reduce re-executes from scratch;
//! `metrics.reduce_bytes_replayed` accounts the extra wire traffic. A
//! range whose reduce *compute* has completed is durable — a later
//! failure of its owner cannot lose it.
//!
//! **The push is restartable too**: every source→mapper transfer is
//! recorded in a push-transfer table. A source refresh
//! ([`super::dynamics::DynEvent::SourceRefresh`], the `staleness`
//! profile) re-dirties transfers feeding splits that have not sealed
//! yet: in-flight copies restart from byte zero, delivered copies are
//! discarded (de-credited from `metrics.push_bytes_delivered`) and
//! re-sent, with the re-push traffic accounted in
//! `metrics.push_bytes_repushed`. At job end
//! `push_bytes_delivered == push_bytes` exactly — the push-side mirror
//! of the shuffle's byte-conservation invariant.
//!
//! **Retry budgets and the dead-letter queue**: every eviction of a work
//! item by a node failure counts one attempt against
//! `JobConfig::max_attempts`. A map split or key range that exhausts the
//! budget is *dead-lettered* instead of requeued forever (the pre-budget
//! engine replayed the same split indefinitely under a flapping node):
//! its remaining transfers move to [`XferState::Dead`], its bytes move
//! from the delivery credit to `metrics.dlq_bytes`, and the item is
//! recorded in the job's [`DeadLetterQueue`]. The dead-letter decision is
//! made *at failure time*, whether or not a reassignment target exists —
//! which is exactly the classic integration bug (failures counted but
//! never routed to the DLQ) this design rules out. Byte conservation
//! generalizes to `shuffle_bytes_delivered + dlq_bytes == shuffle_bytes`
//! exactly at job end, and a job that dead-lettered anything finishes
//! with [`JobOutcome::PartialWithDlq`].
//!
//! **Checkpoint/resume**: at event boundaries (drained event heap) the
//! executor's full mutable state — task/transfer tables, range owners,
//! byte credits, the virtual clock, the dynamics cursor — can be
//! exported ([`Executor::encode_state`]) and later restored
//! ([`Executor::restore_state`]) onto a freshly constructed executor,
//! continuing bit-identically. The file codec and crash/resume drivers
//! live in [`super::snapshot`].
//!
//! **Online re-optimization** ([`super::replan`]): with `--replan` the
//! executor re-solves the plan at dynamics-event boundaries (or on an
//! `every:T` cadence, and once on resume-from-snapshot) against the
//! *effective* platform — capacities read live from the fluid sim,
//! failed nodes discounted, refreshed sources re-priced — via a
//! warm-started short LP descent ([`crate::optimizer::Replanner`]),
//! then migrates only unstarted work (ranges with empty shuffle
//! ledgers, splits still waiting for data) to the accepted plan. The
//! byte ledgers above are untouched by construction.
//!
//! The engine executes the *real* map/reduce functions on real records —
//! byte counts, skew and record conservation are genuine — while time is
//! virtual (charged from the topology's bandwidths/compute rates).

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::dynamics::{DynEvent, ScenarioTrace};
use super::events::{EngineEvent, EventQueue, TaskId};
use super::fluid::{ActivityId, FluidSim, ResourceId};
use super::job::{batch_size, JobConfig, MapReduceApp, Record};
use super::metrics::JobMetrics;
use super::partitioner::Partitioner;
use super::replan::{self, ReplanPolicy, ReplanState};
use super::scheduler::{self, NodeId, ReduceView, RunningTask, SchedView, Scheduler};
use crate::model::barrier::Barrier;
use crate::model::makespan::AppModel;
use crate::model::plan::Plan;
use crate::platform::Topology;

/// Node NIC capacity (bytes/s): Gigabit Ethernet, §3.2's testbed fabric.
/// Concurrent flows through one node share this — contention the closed-
/// form model ignores (and part of why Fig 4 is a non-trivial check).
pub const NIC_BPS: f64 = 125.0e6;

#[derive(Debug, Clone, Copy, PartialEq)]
enum TaskState {
    WaitingForData,
    Ready,
    Running,
    Done,
    /// Dead-lettered: the split exhausted its retry budget and will never
    /// run. Barrier accounting treats it like a completed map with no
    /// output.
    Dead,
}

struct MapTask {
    mapper: NodeId,
    /// (source, records) parts of this split.
    parts: Vec<(usize, Vec<Record>)>,
    bytes: f64,
    state: TaskState,
    /// Node actually executing (may differ from `mapper` when stolen).
    exec_node: Option<NodeId>,
    activity: Option<ActivityId>,
    /// Speculative copy bookkeeping.
    spec_node: Option<NodeId>,
    spec_activity: Option<ActivityId>,
    spec_fetching: bool,
    pending_parts: usize,
    started_at: f64,
    /// Failed attempts so far (evictions by node failures). Reaching
    /// `JobConfig::max_attempts` dead-letters the split.
    attempts: u32,
    /// Map outputs per reducer (filled when the task first runs).
    outputs: Option<Vec<Vec<Record>>>,
}

/// Lifecycle of one shuffle transfer (restartable reduce).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum XferState {
    /// Waiting to be (re)sent — the owning reducer is down, or the data
    /// was lost to a failure and a resend is pending.
    Held,
    /// On the wire to the range's current owner.
    InFlight,
    /// Delivered to the current owner and still credited.
    Delivered,
    /// Written off: the transfer's range (or producing split) was
    /// dead-lettered. Its bytes are accounted in `metrics.dlq_bytes` and
    /// it is never (re)sent.
    Dead,
}

/// One source→mapper push transfer (a part of a split, or a replica
/// copy of one), kept so a source refresh ([`DynEvent::SourceRefresh`])
/// can invalidate and re-send it while the split is still unsealed.
struct PushXfer {
    /// Map task whose split this transfer feeds.
    task: TaskId,
    /// Source the data originates at.
    source: usize,
    /// Mapper (or replica) node the data lands on.
    to: NodeId,
    bytes: f64,
    state: XferState,
    /// Whether this transfer has ever been put on the wire — re-sends of
    /// a sent transfer are staleness re-push traffic, first sends are not.
    sent_once: bool,
    /// In-flight fluid activity (so a refresh can cancel it).
    activity: Option<ActivityId>,
}

/// One mapper→reducer shuffle transfer, kept until job end so a reducer
/// failure can replay it (map outputs are durable, like Hadoop's).
struct ShuffleXfer {
    /// Node the map output lives on (exec node of the producing task).
    from: NodeId,
    /// Key range (the *plan's* reducer index; ownership may move).
    range: usize,
    records: Vec<Record>,
    bytes: f64,
    state: XferState,
    /// Whether this transfer has ever been put on the wire — resends of
    /// a sent transfer are replay traffic, first sends are not.
    sent_once: bool,
}

/// What kind of work item a dead-letter entry records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DlqKind {
    /// A map split (`id` indexes the task table).
    Split,
    /// A reduce key range (`id` is the plan's reducer index).
    Range,
}

/// One permanently-failed work item.
#[derive(Debug, Clone, PartialEq)]
pub struct DlqEntry {
    pub kind: DlqKind,
    /// Task id (splits) or key-range index (ranges).
    pub id: usize,
    /// Input bytes of the split, or total shuffle bytes written off for
    /// the range (including map outputs emitted after the range died).
    pub bytes: f64,
    /// Failed attempts consumed when the item was dead-lettered.
    pub attempts: u32,
    /// Virtual time of the dead-letter decision.
    pub at: f64,
}

/// Work items that exhausted their retry budget
/// (`JobConfig::max_attempts`). Entries are appended at failure time —
/// *never* deferred to a reassignment that may not exist — in
/// deterministic (event, then id) order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DeadLetterQueue {
    pub entries: Vec<DlqEntry>,
}

impl DeadLetterQueue {
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entries of one kind, in dead-letter order.
    pub fn of_kind(&self, kind: DlqKind) -> impl Iterator<Item = &DlqEntry> {
        self.entries.iter().filter(move |e| e.kind == kind)
    }
}

/// How a job ended. (Distinct from the tenancy layer's per-job stream
/// outcome struct `engine::tenancy::JobOutcome`; refer to this one as
/// `engine::executor::JobOutcome`.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobOutcome {
    /// Every split mapped and every range reduced.
    Complete,
    /// The job finished, but some work exhausted its retry budget and
    /// sits in the dead-letter queue; outputs are partial.
    PartialWithDlq,
}

/// Run one job; returns metrics plus the final output records per reducer.
pub struct JobResult {
    pub metrics: JobMetrics,
    pub outputs: Vec<Vec<Record>>,
    /// `Complete`, or `PartialWithDlq` when the DLQ is non-empty.
    pub outcome: JobOutcome,
    /// Work items that exhausted their retry budget.
    pub dlq: DeadLetterQueue,
}

pub fn run_job(
    topo: &Topology,
    plan: &Plan,
    app: &dyn MapReduceApp,
    config: &JobConfig,
    inputs: &[Vec<Record>],
) -> JobResult {
    let mut sim = FluidSim::new();
    sim.set_threads(config.threads.max(1));
    let res = ResourceSet::build(&mut sim, topo);
    let mut exec =
        Executor::new(topo, plan, app, config, inputs, res, config.dynamics.as_ref(), 0, 1.0);
    // Trace events due at t = 0 (e.g. a node down from the start)
    // apply before any work is placed.
    exec.start(&mut sim);
    // Main loop: advance the fluid clock to the next completion
    // batch — never past the next scenario event — convert
    // completions to engine events on the heap, and dispatch them in
    // (time, FIFO) order. With no dynamics trace every iteration is
    // a plain `sim.step()`, arithmetically identical to the static
    // engine.
    loop {
        let step = match exec.next_dyn_time() {
            Some(tt) if sim.active_count() > 0 => sim.step_until(tt),
            Some(tt) => {
                if exec.is_complete() {
                    // Job finished; drop the trailing trace events.
                    break;
                }
                // Nothing in flight (e.g. every remaining task is
                // homed on a dead node under plan-local placement):
                // idle-jump the clock to the event that may unblock
                // progress.
                sim.jump_to(tt);
                Some((sim.now(), Vec::new()))
            }
            None => sim.step(),
        };
        let Some((now, completed)) = step else { break };
        if completed.is_empty() {
            // The clock reached the next scenario event (no fluid
            // completion fired): inject it and continue.
            exec.apply_dynamics(&mut sim);
            continue;
        }
        for aid in completed {
            // A miss is a cancelled losing copy — nothing to dispatch.
            exec.enqueue(now, aid);
        }
        exec.drain(&mut sim);
        // Straggler check once per batch (needs the clock to have
        // advanced).
        exec.maybe_speculate(&mut sim);
    }
    let mut result = exec.into_result();
    result.metrics.fluid_resolves = sim.resolves();
    result.metrics.fluid_resources_touched = sim.resources_touched();
    result
}

/// The fluid resources of one topology, in their canonical creation
/// order (load-bearing: resource ids feed the max-min solver's
/// deterministic tie-breaks, so replaying this exact order is part of
/// the bit-identity contract). Built once per [`FluidSim`] and shared by
/// every job running on it — concurrent jobs contend for the *same*
/// links, NICs and CPUs, which is the whole point of the tenancy layer.
#[derive(Debug, Clone)]
pub(crate) struct ResourceSet {
    sm_link: Vec<Vec<ResourceId>>,
    mr_link: Vec<Vec<ResourceId>>,
    src_egress: Vec<ResourceId>,
    map_ingress: Vec<ResourceId>,
    map_egress: Vec<ResourceId>,
    red_ingress: Vec<ResourceId>,
    map_compute: Vec<ResourceId>,
    red_compute: Vec<ResourceId>,
}

impl ResourceSet {
    /// The canonical resource-id layout for `topo`: pure arithmetic over
    /// the creation order (ids are assigned `0..` as [`ResourceSet::build`]
    /// adds them), with **no** simulation side effects. Snapshot resume
    /// uses this to rebuild an executor's resource handles against an
    /// already-populated restored [`FluidSim`]; `build` asserts against it
    /// id-by-id, so the two can never drift.
    pub(crate) fn layout(topo: &Topology) -> ResourceSet {
        let (s, m, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
        let mut next: ResourceId = 0;
        let mut take = |n: usize, next: &mut ResourceId| -> Vec<ResourceId> {
            let v: Vec<ResourceId> = (*next..*next + n).collect();
            *next += n;
            v
        };
        let sm_link: Vec<Vec<ResourceId>> = (0..s).map(|_| take(m, &mut next)).collect();
        let mr_link: Vec<Vec<ResourceId>> = (0..m).map(|_| take(r, &mut next)).collect();
        let src_egress = take(s, &mut next);
        let map_ingress = take(m, &mut next);
        let map_egress = take(m, &mut next);
        let red_ingress = take(r, &mut next);
        let map_compute = take(m, &mut next);
        let red_compute = take(r, &mut next);
        ResourceSet {
            sm_link,
            mr_link,
            src_egress,
            map_ingress,
            map_egress,
            red_ingress,
            map_compute,
            red_compute,
        }
    }

    /// Total resources `build` registers for `topo`.
    pub(crate) fn n_resources(topo: &Topology) -> usize {
        let (s, m, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
        s * m + m * r + s + 3 * m + 2 * r
    }

    pub(crate) fn build(sim: &mut FluidSim, topo: &Topology) -> ResourceSet {
        let (s, m, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
        let rs = ResourceSet::layout(topo);
        // Register capacities in exactly the layout's canonical order,
        // asserting each id matches (which also requires the sim to be
        // empty — the layout numbers resources from zero).
        let mut add = |sim: &mut FluidSim, expect: ResourceId, cap: f64| {
            let got = sim.add_resource(cap);
            assert_eq!(got, expect, "ResourceSet::build requires a fresh FluidSim");
        };
        for i in 0..s {
            for j in 0..m {
                add(sim, rs.sm_link[i][j], topo.b_sm.get(i, j));
            }
        }
        for j in 0..m {
            for k in 0..r {
                add(sim, rs.mr_link[j][k], topo.b_mr.get(j, k));
            }
        }
        for i in 0..s {
            add(sim, rs.src_egress[i], NIC_BPS);
        }
        for j in 0..m {
            add(sim, rs.map_ingress[j], NIC_BPS);
        }
        for j in 0..m {
            add(sim, rs.map_egress[j], NIC_BPS);
        }
        for k in 0..r {
            add(sim, rs.red_ingress[k], NIC_BPS);
        }
        for j in 0..m {
            add(sim, rs.map_compute[j], topo.c_map[j]);
        }
        for k in 0..r {
            add(sim, rs.red_compute[k], topo.c_red[k]);
        }
        rs
    }
}

/// One job's execution state machine. The fluid simulation is *not*
/// owned here: the driver ([`run_job`], or the multi-job engine in
/// [`super::tenancy`]) owns the clock and threads `&mut FluidSim`
/// through every method, so several executors can share one simulation
/// (and therefore one contended network).
pub(crate) struct Executor<'a> {
    topo: &'a Topology,
    plan: &'a Plan,
    app: &'a dyn MapReduceApp,
    config: &'a JobConfig,
    /// Routing tag stamped on every fluid activity this job creates
    /// (the tenancy layer uses the job index; single-job runs use 0).
    tag: u64,
    /// Slot capacities after the fair-share weight is applied
    /// (`weight = 1.0` reproduces `config.{map,reduce}_slots` exactly).
    map_slots: usize,
    reduce_slots: usize,
    /// Fluid completion → engine event, drained through `queue`.
    /// A BTreeMap so every traversal is in ActivityId order by
    /// construction — iteration order must never leak into simulation
    /// behavior (detlint D001).
    pending: BTreeMap<ActivityId, EngineEvent>,
    queue: EventQueue<EngineEvent>,
    scheduler: Box<dyn Scheduler>,
    // resources
    sm_link: Vec<Vec<ResourceId>>,
    mr_link: Vec<Vec<ResourceId>>,
    src_egress: Vec<ResourceId>,
    map_ingress: Vec<ResourceId>,
    map_egress: Vec<ResourceId>,
    red_ingress: Vec<ResourceId>,
    map_compute: Vec<ResourceId>,
    red_compute: Vec<ResourceId>,
    // tasks
    tasks: Vec<MapTask>,
    /// Preferred node of every task: the plan node from `build_splits`,
    /// possibly re-homed by an accepted replan while the task was still
    /// `WaitingForData` (cached so per-event scheduling snapshots don't
    /// rebuild it). The *push destination* is `tasks[t].mapper`, which
    /// never changes.
    task_home: Vec<NodeId>,
    partitioner: Partitioner,
    // push state (restartable under source refreshes)
    /// Every push transfer ever emitted (indexed by the `xfer` id in
    /// [`EngineEvent::PushArrived`]); retained so a source refresh can
    /// invalidate and re-send copies of unsealed splits.
    push_xfers: Vec<PushXfer>,
    /// Transfer ids per source, in creation order (refresh selection
    /// walks only the refreshed source's transfers).
    source_xfers: Vec<Vec<usize>>,
    /// Total push bytes originating at each source (incl. replicas) —
    /// the base a refresh fraction applies to.
    source_push_bytes: Vec<f64>,
    // shuffle state
    push_parts_left: usize,
    maps_left: usize,
    maps_left_per_node: Vec<usize>,
    shuffle_xfers_left: Vec<usize>,
    /// Every shuffle transfer ever emitted (indexed by the `xfer` id in
    /// [`EngineEvent::ShuffleArrived`]); payloads are retained until job
    /// end so reducer failures can replay them.
    xfers: Vec<ShuffleXfer>,
    /// Transfer ids per key range, in creation order (so reduce input
    /// gathering touches only the range's own transfers instead of
    /// scanning the whole table).
    range_xfers: Vec<Vec<usize>>,
    /// Cached total input bytes per key range.
    range_bytes: Vec<f64>,
    /// Physical reducer currently owning each key range (identity until a
    /// failure reassigns a range to a survivor).
    range_owner: Vec<NodeId>,
    /// Liveness of each reducer node.
    reducer_up: Vec<bool>,
    /// In-flight reduce compute per range (cancelled on owner failure).
    range_compute: Vec<Option<ActivityId>>,
    /// Reduce compute finished per range — the durability point: from
    /// here a failure of the owner can no longer lose the range's work.
    reduce_compute_done: Vec<bool>,
    /// Map outputs parked until the shuffle may start (barrier).
    /// Keyed by (home node, exec node): the Local barrier gates on the
    /// home node's queue, while the shuffle transfer originates at the
    /// exec node (they differ for stolen / speculative winners).
    parked_outputs: Vec<(NodeId, NodeId, Vec<Vec<Record>>)>,
    reduce_started: Vec<bool>,
    reduce_done: Vec<bool>,
    writes_left: Vec<usize>,
    all_shuffles_done: bool,
    // retry budgets / dead-letter queue
    /// Failed attempts per key range (owner failures while un-durable).
    range_attempts: Vec<u32>,
    /// Dead-lettered ranges (reduce never runs; bytes written off).
    range_dead: Vec<bool>,
    dlq: DeadLetterQueue,
    // slot accounting
    map_slots_free: Vec<usize>,
    reduce_slots_free: Vec<usize>,
    // dynamics (fault injection / time-varying platform)
    dynamics: Option<&'a ScenarioTrace>,
    /// Next un-applied event in the trace.
    dyn_cursor: usize,
    /// Liveness of each mapper node (failures set false, recoveries true).
    node_up: Vec<bool>,
    /// Online re-optimization state ([`super::replan`]): current plan's
    /// shuffle split, hysteresis baseline, `every:T` tick, staleness
    /// pricing, and the warm-started LP bases. Inert under
    /// [`ReplanPolicy::Off`].
    replan: ReplanState,
    // metrics
    metrics: JobMetrics,
    durations: Vec<f64>,
    outputs: Vec<Vec<Record>>,
}

impl<'a> Executor<'a> {
    /// Build one job's executor over an existing simulation. `res` must
    /// have been built by [`ResourceSet::build`] against the same
    /// `FluidSim` the driver will thread through the other methods.
    /// `weight` scales the job's slot capacities (fair-share tenancy);
    /// `1.0` reproduces the config's slot counts exactly.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        topo: &'a Topology,
        plan: &'a Plan,
        app: &'a dyn MapReduceApp,
        config: &'a JobConfig,
        inputs: &[Vec<Record>],
        res: ResourceSet,
        dynamics: Option<&'a ScenarioTrace>,
        tag: u64,
        weight: f64,
    ) -> Executor<'a> {
        plan.check(topo).unwrap_or_else(|e| panic!("invalid plan: {e}"));
        let (s, m, r) = (topo.n_sources(), topo.n_mappers(), topo.n_reducers());
        assert_eq!(inputs.len(), s, "one input vector per source");
        assert!(weight > 0.0 && weight.is_finite(), "job weight must be positive");
        assert!(
            config.max_attempts >= 1,
            "max_attempts must be >= 1 (an unbounded retry budget is not expressible)"
        );
        let map_slots = ((config.map_slots as f64 * weight).round() as usize).max(1);
        let reduce_slots = ((config.reduce_slots as f64 * weight).round() as usize).max(1);

        let partitioner = Partitioner::from_fractions(&plan.y, config.n_buckets);

        let ResourceSet {
            sm_link,
            mr_link,
            src_egress,
            map_ingress,
            map_egress,
            red_ingress,
            map_compute,
            red_compute,
        } = res;

        let mut exec = Executor {
            topo,
            plan,
            app,
            config,
            tag,
            map_slots,
            reduce_slots,
            pending: BTreeMap::new(),
            queue: EventQueue::new(),
            scheduler: scheduler::for_config(config),
            sm_link,
            mr_link,
            src_egress,
            map_ingress,
            map_egress,
            red_ingress,
            map_compute,
            red_compute,
            tasks: Vec::new(),
            task_home: Vec::new(),
            partitioner,
            push_xfers: Vec::new(),
            source_xfers: vec![Vec::new(); s],
            source_push_bytes: vec![0.0; s],
            push_parts_left: 0,
            maps_left: 0,
            maps_left_per_node: vec![0; m],
            shuffle_xfers_left: vec![0; r],
            xfers: Vec::new(),
            range_xfers: vec![Vec::new(); r],
            range_bytes: vec![0.0; r],
            range_owner: (0..r).collect(),
            reducer_up: vec![true; r],
            range_compute: vec![None; r],
            reduce_compute_done: vec![false; r],
            parked_outputs: Vec::new(),
            reduce_started: vec![false; r],
            reduce_done: vec![false; r],
            writes_left: vec![0; r],
            all_shuffles_done: false,
            range_attempts: vec![0; r],
            range_dead: vec![false; r],
            dlq: DeadLetterQueue::default(),
            map_slots_free: vec![map_slots; m],
            reduce_slots_free: vec![reduce_slots; r],
            dynamics,
            dyn_cursor: 0,
            node_up: vec![true; m],
            replan: ReplanState::new(config, plan, topo),
            metrics: JobMetrics::default(),
            durations: Vec::new(),
            outputs: vec![Vec::new(); r],
        };
        exec.build_splits(inputs);
        exec
    }

    /// §3.1.2: build input splits. Each split for mapper `j` reads from
    /// every source `i` in proportion to `x_ij`.
    fn build_splits(&mut self, inputs: &[Vec<Record>]) {
        let (s, m) = (self.topo.n_sources(), self.topo.n_mappers());
        self.metrics.input_records = inputs.iter().map(Vec::len).sum();

        // Per source: cut its records into per-mapper chunks of byte
        // volume ≈ D_i·x_ij (greedy contiguous walk).
        let mut per_mapper_parts: Vec<Vec<(usize, Vec<Record>)>> = vec![Vec::new(); m];
        for i in 0..s {
            let total: f64 = batch_size(&inputs[i]) as f64;
            let mut cursor = 0usize;
            let mut acc = 0.0f64;
            let mut target = 0.0f64;
            for j in 0..m {
                target += self.plan.x.get(i, j) * total;
                let mut chunk = Vec::new();
                while cursor < inputs[i].len() && (acc < target || j == m - 1) {
                    acc += inputs[i][cursor].size() as f64;
                    chunk.push(inputs[i][cursor].clone());
                    cursor += 1;
                }
                if !chunk.is_empty() {
                    per_mapper_parts[j].push((i, chunk));
                }
            }
        }

        // Subdivide each mapper's incoming volume into splits.
        for j in 0..m {
            let vol: usize = per_mapper_parts[j]
                .iter()
                .map(|(_, recs)| batch_size(recs))
                .sum();
            if vol == 0 {
                continue;
            }
            let n_splits = ((vol + self.config.split_size - 1) / self.config.split_size).max(1);
            // Round-robin records of each part across the splits keeps
            // every split reading proportionally from every source.
            // Keyed by source in a BTreeMap so the per-split part list
            // comes out in source order with no explicit sort.
            let mut split_parts: Vec<BTreeMap<usize, Vec<Record>>> =
                vec![BTreeMap::new(); n_splits];
            for (src, recs) in &per_mapper_parts[j] {
                for (idx, rec) in recs.iter().enumerate() {
                    split_parts[idx % n_splits]
                        .entry(*src)
                        .or_default()
                        .push(rec.clone());
                }
            }
            for parts_map in split_parts {
                if parts_map.is_empty() {
                    continue;
                }
                let parts: Vec<(usize, Vec<Record>)> = parts_map.into_iter().collect();
                let bytes: usize = parts.iter().map(|(_, r)| batch_size(r)).sum();
                self.tasks.push(MapTask {
                    mapper: j,
                    parts,
                    bytes: bytes as f64,
                    state: TaskState::WaitingForData,
                    exec_node: None,
                    activity: None,
                    spec_node: None,
                    spec_activity: None,
                    spec_fetching: false,
                    pending_parts: 0,
                    started_at: 0.0,
                    attempts: 0,
                    outputs: None,
                });
            }
        }
        self.maps_left = self.tasks.len();
        self.metrics.n_map_tasks = self.tasks.len();
        self.task_home = self.tasks.iter().map(|t| t.mapper).collect();
        for t in &self.tasks {
            self.maps_left_per_node[t.mapper] += 1;
        }
    }

    /// Kick off all push transfers (each recorded in the push-transfer
    /// table so a source refresh can invalidate and re-send it).
    fn start_push(&mut self, sim: &mut FluidSim) {
        let repl = self.config.replication.max(1);
        let m = self.topo.n_mappers();
        for tid in 0..self.tasks.len() {
            let mapper = self.tasks[tid].mapper;
            let parts: Vec<(usize, f64)> = self.tasks[tid]
                .parts
                .iter()
                .map(|(src, recs)| (*src, batch_size(recs) as f64))
                .collect();
            for (src, bytes) in parts {
                self.emit_push(sim, tid, src, mapper, bytes);
                // HDFS-style replication: each replica is one more
                // wide-area copy of the block (§4.6.5). Replica writes
                // gate the split like primary parts (the HDFS write
                // pipeline completes when all replicas acknowledge).
                for extra in 1..repl {
                    let replica_node = (mapper + extra) % m;
                    self.emit_push(sim, tid, src, replica_node, bytes);
                }
            }
        }
        // Degenerate: no input at all.
        if self.push_parts_left == 0 {
            self.release_maps_after_push(sim);
        }
    }

    /// Record one push transfer and put it on the wire.
    fn emit_push(&mut self, sim: &mut FluidSim, tid: TaskId, src: usize, to: NodeId, bytes: f64) {
        let id = self.push_xfers.len();
        self.push_xfers.push(PushXfer {
            task: tid,
            source: src,
            to,
            bytes,
            state: XferState::Held,
            sent_once: false,
            activity: None,
        });
        self.source_xfers[src].push(id);
        self.source_push_bytes[src] += bytes;
        self.tasks[tid].pending_parts += 1;
        self.push_parts_left += 1;
        self.metrics.push_bytes += bytes;
        self.send_push(sim, id);
    }

    /// Put push transfer `id` on the wire (first send or staleness
    /// re-send). Re-sends of a previously sent transfer are re-push
    /// traffic.
    fn send_push(&mut self, sim: &mut FluidSim, id: usize) {
        let (src, to, bytes) =
            (self.push_xfers[id].source, self.push_xfers[id].to, self.push_xfers[id].bytes);
        let a = sim.add_activity_tagged(
            bytes,
            vec![self.sm_link[src][to], self.src_egress[src], self.map_ingress[to]],
            self.tag,
        );
        self.pending.insert(a, EngineEvent::PushArrived { xfer: id });
        self.push_xfers[id].state = XferState::InFlight;
        self.push_xfers[id].activity = Some(a);
        if self.push_xfers[id].sent_once {
            // Exact: byte counts are integers < 2^53 carried in f64, so
            // this accumulation is exact — no rounding drift across
            // re-pushes.
            self.metrics.push_bytes_repushed += bytes;
        }
        self.push_xfers[id].sent_once = true;
    }

    fn release_maps_after_push(&mut self, sim: &mut FluidSim) {
        for tid in 0..self.tasks.len() {
            if self.tasks[tid].state == TaskState::WaitingForData
                && self.tasks[tid].pending_parts == 0
            {
                self.tasks[tid].state = TaskState::Ready;
            }
        }
        self.schedule_maps(sim);
    }

    /// Execute the map function for a task (eagerly, once).
    fn materialize_outputs(&mut self, tid: TaskId) {
        if self.tasks[tid].outputs.is_some() {
            return;
        }
        let r = self.topo.n_reducers();
        let mut outs: Vec<Vec<Record>> = vec![Vec::new(); r];
        let mut count = 0usize;
        // One map_split call over the whole split (all source parts):
        // this is what lets in-mapper combining aggregate across the
        // split, like the paper's Word Count (§4.6.2).
        let split_records: Vec<Record> = self.tasks[tid]
            .parts
            .iter()
            .flat_map(|(_, recs)| recs.iter().cloned())
            .collect();
        self.app.map_split(&split_records, &mut |out| {
            let k = self.partitioner.reducer(self.app.group_key(&out.key));
            outs[k].push(out);
            count += 1;
        });
        self.metrics.intermediate_records += count;
        self.tasks[tid].outputs = Some(outs);
    }

    /// Snapshot the cluster, ask the scheduler for placements, apply them.
    fn schedule_maps(&mut self, sim: &mut FluidSim) {
        let ready: Vec<TaskId> = (0..self.tasks.len())
            .filter(|&t| self.tasks[t].state == TaskState::Ready)
            .collect();
        if ready.is_empty() {
            return;
        }
        let assignments = {
            let view = SchedView {
                now: sim.now(),
                home: &self.task_home,
                ready: &ready,
                running: &[],
                free_slots: &self.map_slots_free,
                queued: &self.maps_left_per_node,
                capacity: &self.topo.c_map,
                durations: &self.durations,
                cluster: &self.topo.mapper_cluster,
                up: &self.node_up,
            };
            self.scheduler.assign(&view)
        };
        for a in assignments {
            // Enforce the scheduler contract rather than trust it: never
            // oversubscribe a node or re-place a task.
            if self.map_slots_free[a.node] == 0
                || self.tasks[a.task].state != TaskState::Ready
                || a.speculative
            {
                continue;
            }
            if a.node != self.tasks[a.task].mapper {
                self.metrics.stolen += 1;
            }
            self.start_map(sim, a.task, a.node, false);
        }
    }

    fn start_map(&mut self, sim: &mut FluidSim, tid: TaskId, node: NodeId, speculative: bool) {
        let plan_node = self.tasks[tid].mapper;
        if speculative {
            self.tasks[tid].spec_node = Some(node);
            self.tasks[tid].spec_fetching = node != plan_node;
        } else {
            self.tasks[tid].state = TaskState::Running;
            self.tasks[tid].exec_node = Some(node);
            self.tasks[tid].started_at = sim.now();
        }
        self.map_slots_free[node] -= 1;

        if node != plan_node {
            // Remote read of the split from the plan node (the stolen /
            // speculative copy path). Node-pair bandwidth approximated by
            // the cluster-pair mapper→reducer matrix (nodes co-located).
            let bytes = self.tasks[tid].bytes;
            let a = sim.add_activity_tagged(
                bytes,
                vec![
                    self.mr_link[plan_node][node.min(self.topo.n_reducers() - 1)],
                    self.map_egress[plan_node],
                    self.map_ingress[node],
                ],
                self.tag,
            );
            self.pending
                .insert(a, EngineEvent::FetchArrived { task: tid, speculative });
        } else {
            self.start_map_compute(sim, tid, node, speculative);
        }
    }

    fn start_map_compute(
        &mut self,
        sim: &mut FluidSim,
        tid: TaskId,
        node: NodeId,
        speculative: bool,
    ) {
        let work = self.tasks[tid].bytes * self.app.map_cost_factor();
        let a = sim.add_activity_tagged(work, vec![self.map_compute[node]], self.tag);
        self.pending
            .insert(a, EngineEvent::MapFinished { task: tid, speculative });
        if speculative {
            self.tasks[tid].spec_activity = Some(a);
        } else {
            self.tasks[tid].activity = Some(a);
        }
    }

    /// Straggler check (§4.6.4): snapshot the running set and let the
    /// scheduler pick backup copies. Drivers call this once per
    /// completion batch (the clock must have advanced).
    pub(crate) fn maybe_speculate(&mut self, sim: &mut FluidSim) {
        if !self.config.speculation || !self.scheduler.may_speculate(self.durations.len()) {
            return;
        }
        let running: Vec<RunningTask> = self
            .tasks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.state == TaskState::Running && t.spec_node.is_none())
            .map(|(tid, t)| RunningTask {
                task: tid,
                node: t.exec_node.expect("running task has an exec node"),
                started_at: t.started_at,
            })
            .collect();
        if running.is_empty() {
            return;
        }
        let backups = {
            let view = SchedView {
                now: sim.now(),
                home: &self.task_home,
                ready: &[],
                running: &running,
                free_slots: &self.map_slots_free,
                queued: &self.maps_left_per_node,
                capacity: &self.topo.c_map,
                durations: &self.durations,
                cluster: &self.topo.mapper_cluster,
                up: &self.node_up,
            };
            self.scheduler.speculate(&view)
        };
        for a in backups {
            if !a.speculative
                || self.map_slots_free[a.node] == 0
                || self.tasks[a.task].state != TaskState::Running
                || self.tasks[a.task].spec_node.is_some()
            {
                continue;
            }
            self.start_map(sim, a.task, a.node, true);
            self.metrics.spec_launched += 1;
        }
    }

    fn on_map_done(&mut self, sim: &mut FluidSim, tid: TaskId, speculative: bool) {
        if self.tasks[tid].state == TaskState::Done {
            return; // lost the race
        }
        let node = if speculative {
            self.tasks[tid].spec_node.unwrap()
        } else {
            self.tasks[tid].exec_node.unwrap()
        };
        // Cancel the losing copy and free its slot.
        if speculative {
            if let Some(a) = self.tasks[tid].activity {
                if !sim.is_done(a) {
                    sim.cancel(a);
                    self.pending.remove(&a);
                }
            }
            if let Some(loser) = self.tasks[tid].exec_node {
                self.map_slots_free[loser] += 1;
            }
            self.metrics.spec_won += 1;
        } else if let Some(a) = self.tasks[tid].spec_activity {
            if !sim.is_done(a) {
                sim.cancel(a);
                self.pending.remove(&a);
            }
            if let Some(loser) = self.tasks[tid].spec_node {
                self.map_slots_free[loser] += 1;
            }
        } else if self.tasks[tid].spec_fetching {
            // Spec copy still fetching its input; let the fetch event
            // find the task Done and release the slot then.
        }
        self.tasks[tid].state = TaskState::Done;
        self.map_slots_free[node] += 1;
        self.durations.push(sim.now() - self.tasks[tid].started_at);
        self.maps_left -= 1;
        self.maps_left_per_node[self.tasks[tid].mapper] =
            self.maps_left_per_node[self.tasks[tid].mapper].saturating_sub(1);
        self.metrics.map_end = sim.now();

        self.materialize_outputs(tid);
        let outs = self.tasks[tid].outputs.take().unwrap();

        let home = self.tasks[tid].mapper;
        match self.config.barriers.map_shuffle {
            Barrier::Global => {
                self.parked_outputs.push((home, node, outs));
                if self.maps_left == 0 {
                    self.release_shuffle(sim);
                }
            }
            Barrier::Local => {
                self.parked_outputs.push((home, node, outs));
                if self.maps_left_per_node[home] == 0 {
                    self.release_local_cohort(sim, home);
                }
            }
            Barrier::Pipelined => {
                self.emit_shuffle(sim, node, outs);
            }
        }
        self.schedule_maps(sim);
        self.maybe_speculate(sim);
        self.maybe_finish_shuffle_phase(sim);
    }

    fn release_shuffle(&mut self, sim: &mut FluidSim) {
        let parked = std::mem::take(&mut self.parked_outputs);
        for (_home, exec_node, outs) in parked {
            self.emit_shuffle(sim, exec_node, outs);
        }
    }

    /// Release a home cohort's parked outputs once that node has no maps
    /// left (the Local map/shuffle barrier). Filtering by HOME (not exec)
    /// node matches the gate, so outputs of tasks that ran remotely
    /// (stolen or speculative winner) are released with their cohort
    /// instead of stranding unshuffled. Shared by map completion and
    /// split dead-lettering — both retire the cohort's last member.
    fn release_local_cohort(&mut self, sim: &mut FluidSim, home: NodeId) {
        let mine: Vec<(NodeId, NodeId, Vec<Vec<Record>>)> = {
            let mut kept = Vec::new();
            let mut released = Vec::new();
            for entry in self.parked_outputs.drain(..) {
                if entry.0 == home {
                    released.push(entry);
                } else {
                    kept.push(entry);
                }
            }
            self.parked_outputs = kept;
            released
        };
        for (_home, exec_node, outs) in mine {
            self.emit_shuffle(sim, exec_node, outs);
        }
    }

    fn emit_shuffle(&mut self, sim: &mut FluidSim, from_node: NodeId, outs: Vec<Vec<Record>>) {
        for (k, recs) in outs.into_iter().enumerate() {
            if recs.is_empty() {
                continue;
            }
            let bytes = batch_size(&recs) as f64;
            if self.range_dead[k] {
                // The range was dead-lettered while this mapper was still
                // running: record the output as Dead immediately (never
                // wired, payload dropped) so the shuffle barrier cannot
                // deadlock waiting on a range that will never drain.
                let id = self.xfers.len();
                self.xfers.push(ShuffleXfer {
                    from: from_node,
                    range: k,
                    records: Vec::new(),
                    bytes,
                    state: XferState::Dead,
                    sent_once: false,
                });
                self.range_xfers[k].push(id);
                self.range_bytes[k] += bytes;
                // Exact: byte counts are integers < 2^53 carried in f64;
                // crediting the write-off on both sides keeps
                // shuffle_bytes_delivered + dlq_bytes == shuffle_bytes.
                self.metrics.shuffle_bytes += bytes;
                self.metrics.dlq_bytes += bytes;
                if let Some(e) = self
                    .dlq
                    .entries
                    .iter_mut()
                    .find(|e| e.kind == DlqKind::Range && e.id == k)
                {
                    e.bytes += bytes;
                }
                continue;
            }
            let id = self.xfers.len();
            self.xfers.push(ShuffleXfer {
                from: from_node,
                range: k,
                records: recs,
                bytes,
                state: XferState::Held,
                sent_once: false,
            });
            self.range_xfers[k].push(id);
            self.range_bytes[k] += bytes;
            self.shuffle_xfers_left[k] += 1;
            self.metrics.shuffle_bytes += bytes;
            self.send_xfer(sim, id);
        }
    }

    /// Put transfer `id` on the wire to its range's current owner. If the
    /// owner is down the transfer stays `Held` — it is resent when the
    /// owner recovers or the range is adopted by a survivor. Resends of a
    /// previously sent transfer are replay traffic.
    fn send_xfer(&mut self, sim: &mut FluidSim, id: usize) {
        let range = self.xfers[id].range;
        let owner = self.range_owner[range];
        if !self.reducer_up[owner] {
            self.xfers[id].state = XferState::Held;
            return;
        }
        let from = self.xfers[id].from;
        let bytes = self.xfers[id].bytes;
        let a = sim.add_activity_tagged(
            bytes,
            vec![self.mr_link[from][owner], self.map_egress[from], self.red_ingress[owner]],
            self.tag,
        );
        self.pending.insert(a, EngineEvent::ShuffleArrived { xfer: id });
        self.xfers[id].state = XferState::InFlight;
        if self.xfers[id].sent_once {
            // Exact: byte counts are integers < 2^53 carried in f64, so
            // this accumulation is exact — no rounding drift across
            // replays.
            self.metrics.reduce_bytes_replayed += bytes;
        }
        self.xfers[id].sent_once = true;
    }

    /// Move range `k`'s payloads out of the transfer table, concatenated
    /// in transfer order — the same accumulation order the
    /// pre-restartable engine used, so the static path is unchanged.
    /// Only called past the range's durability point
    /// (`reduce_compute_done`), after which no failure path can ever
    /// need to replay these records again, so moving (not cloning) is
    /// safe and keeps the memory profile of the old move-based inbox.
    fn take_range_input(&mut self, k: usize) -> Vec<Record> {
        debug_assert!(self.reduce_compute_done[k], "input taken before durability");
        let mut recs = Vec::new();
        for i in 0..self.range_xfers[k].len() {
            let id = self.range_xfers[k][i];
            recs.append(&mut self.xfers[id].records);
        }
        recs
    }

    /// All maps done and all shuffle transfers delivered?
    fn maybe_finish_shuffle_phase(&mut self, sim: &mut FluidSim) {
        if self.maps_left == 0
            && self.shuffle_xfers_left.iter().all(|&c| c == 0)
            && !self.all_shuffles_done
        {
            self.all_shuffles_done = true;
            self.metrics.shuffle_end = sim.now();
            self.maybe_start_reduces(sim);
        }
    }

    fn maybe_start_reduces(&mut self, sim: &mut FluidSim) {
        let r = self.topo.n_reducers();
        // Shuffle/reduce barrier: Local (Hadoop default) starts range k
        // when its own transfers are all delivered; Global waits for
        // every range. Pipelined is treated as Local (see module docs).
        let global = self.config.barriers.shuffle_reduce == Barrier::Global;
        for k in 0..r {
            let owner = self.range_owner[k];
            if self.reduce_started[k]
                || !self.reducer_up[owner]
                || self.reduce_slots_free[owner] == 0
            {
                continue;
            }
            let mine_done = self.maps_left == 0 && self.shuffle_xfers_left[k] == 0;
            let gate = if global { self.all_shuffles_done } else { mine_done };
            if gate {
                self.start_reduce(sim, k);
            }
        }
    }

    /// Start (or restart, after a failure) the reduce of key range `k` on
    /// its current owner. The real reduce function runs at compute
    /// *completion* ([`Executor::on_reduce_compute_done`]) — a failed
    /// attempt therefore needs no output/metric rollback, it simply never
    /// produced anything.
    fn start_reduce(&mut self, sim: &mut FluidSim, k: usize) {
        let owner = self.range_owner[k];
        self.reduce_started[k] = true;
        self.reduce_slots_free[owner] -= 1;
        self.metrics.n_reduce_tasks += 1;
        // Cached exact-integer byte total — equals the old `batch_size`
        // of the concatenated inbox.
        let in_bytes = self.range_bytes[k];
        let work = in_bytes * self.app.reduce_cost_factor();
        let a = sim.add_activity_tagged(work.max(1.0), vec![self.red_compute[owner]], self.tag);
        self.pending.insert(a, EngineEvent::ReduceFinished { range: k });
        self.range_compute[k] = Some(a);
        self.writes_left[k] = 0;
    }

    fn on_reduce_compute_done(&mut self, sim: &mut FluidSim, k: usize) {
        let owner = self.range_owner[k];
        self.reduce_compute_done[k] = true;
        self.range_compute[k] = None;
        // Free the slot so a survivor can adopt further orphaned ranges.
        self.reduce_slots_free[owner] += 1;
        // Sort by full key (SortComparator), group by group_key
        // (GroupingComparator), run the real reduce function.
        let mut inbox = self.take_range_input(k);
        inbox.sort();
        let mut outs: Vec<Record> = Vec::new();
        let mut idx = 0;
        while idx < inbox.len() {
            let group = self.app.group_key(&inbox[idx].key).to_string();
            let mut end = idx + 1;
            while end < inbox.len() && self.app.group_key(&inbox[end].key) == group {
                end += 1;
            }
            self.app.reduce(&group, &inbox[idx..end], &mut |out| outs.push(out));
            idx = end;
        }
        self.metrics.output_records += outs.len();
        let out_bytes = batch_size(&outs) as f64;
        self.outputs[k] = outs;
        self.metrics.output_bytes += out_bytes;
        // Output materialization to the distributed file system with
        // replication (§4.6.5): repl−1 wide-area copies.
        let repl = self.config.replication.max(1);
        if repl > 1 && out_bytes > 0.0 {
            let r = self.topo.n_reducers();
            for extra in 1..repl {
                let target = (k + extra) % r;
                // Reducer-to-reducer copy over the cluster-pair link.
                let a = sim.add_activity_tagged(
                    out_bytes,
                    vec![
                        self.mr_link[target.min(self.topo.n_mappers() - 1)][owner],
                        self.red_ingress[target],
                    ],
                    self.tag,
                );
                self.pending.insert(a, EngineEvent::OutputWritten { range: k });
                self.writes_left[k] += 1;
                self.metrics.output_bytes += out_bytes;
            }
        }
        if self.writes_left[k] == 0 {
            self.finish_reduce(sim, k);
        }
        // The freed slot may unblock another range adopted by this owner
        // (a survivor can hold several orphaned ranges but drains them
        // one slot at a time). No-op in static runs.
        self.maybe_start_reduces(sim);
    }

    fn finish_reduce(&mut self, sim: &mut FluidSim, k: usize) {
        self.reduce_done[k] = true;
        self.metrics.makespan = sim.now();
    }

    // ------------------------------------------------------- dynamics

    /// Virtual time of the next un-applied trace event or `every:T`
    /// replan tick, whichever comes first. The driver advances the fluid
    /// simulation to this boundary so both kinds of event apply at their
    /// exact virtual time.
    pub(crate) fn next_dyn_time(&self) -> Option<f64> {
        let trace_t = self
            .dynamics
            .and_then(|tr| tr.events().get(self.dyn_cursor))
            .map(|te| te.time);
        match (trace_t, self.replan.next_at) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (t, tick) => t.or(tick),
        }
    }

    /// Apply every trace event due at (or before) the current clock,
    /// then let the scheduler react — failed-node evictions create Ready
    /// tasks to (re)place, recoveries free slots, slowdowns may trip the
    /// straggler detector.
    pub(crate) fn apply_dynamics(&mut self, sim: &mut FluidSim) {
        let now = sim.now();
        let mut applied = false;
        while let Some(te) = self.dynamics.and_then(|tr| tr.events().get(self.dyn_cursor)) {
            if te.time > now {
                break;
            }
            self.dyn_cursor += 1;
            let (s, m, r) =
                (self.topo.n_sources(), self.topo.n_mappers(), self.topo.n_reducers());
            let effective = match te.event {
                DynEvent::WanScale { factor } => {
                    self.scale_links(sim, None, factor);
                    true
                }
                DynEvent::ClusterLinkScale { cluster, factor } => {
                    self.scale_links(sim, Some(cluster), factor);
                    true
                }
                DynEvent::MapperFail { node } if node < m => {
                    self.fail_mapper(sim, node);
                    true
                }
                DynEvent::MapperRecover { node } if node < m => {
                    self.recover_mapper(node);
                    true
                }
                DynEvent::ReducerFail { node } if node < r => {
                    self.fail_reducer(sim, node);
                    true
                }
                DynEvent::ReducerRecover { node } if node < r => {
                    self.recover_reducer(sim, node);
                    true
                }
                DynEvent::MapperSlowdown { node, factor } if node < m => {
                    sim.set_capacity(self.map_compute[node], self.topo.c_map[node] * factor);
                    true
                }
                DynEvent::ReducerSlowdown { node, factor } if node < r => {
                    sim.set_capacity(self.red_compute[node], self.topo.c_red[node] * factor);
                    true
                }
                DynEvent::SourceRefresh { source, fraction } if source < s => {
                    self.refresh_source(sim, source, fraction);
                    true
                }
                // Out-of-range node ids (a trace generated for a different
                // platform): ignore — and don't count as applied — rather
                // than panic mid-simulation.
                DynEvent::MapperFail { .. }
                | DynEvent::MapperRecover { .. }
                | DynEvent::MapperSlowdown { .. }
                | DynEvent::ReducerFail { .. }
                | DynEvent::ReducerRecover { .. }
                | DynEvent::ReducerSlowdown { .. }
                | DynEvent::SourceRefresh { .. } => false,
            };
            if effective {
                self.metrics.dyn_events += 1;
                applied = true;
            }
        }
        let replanned = self.maybe_replan(sim, applied);
        if applied || replanned {
            self.schedule_maps(sim);
            self.maybe_speculate(sim);
        }
    }

    // ---------------------------------------- online re-optimization

    /// Evaluate the replan policy at this event boundary; returns true
    /// when a re-solve was accepted (the caller re-runs the scheduler so
    /// migrated work can place). `events_applied` is the `on-event`
    /// trigger; `every:T` ticks trigger on their own clock.
    fn maybe_replan(&mut self, sim: &FluidSim, events_applied: bool) -> bool {
        let due = match self.config.replan {
            ReplanPolicy::Off => false,
            ReplanPolicy::OnEvent => events_applied,
            ReplanPolicy::Every(period) => match self.replan.next_at {
                Some(t) if t <= sim.now() => {
                    let trace_left = self
                        .dynamics
                        .map_or(false, |tr| self.dyn_cursor < tr.events().len());
                    if sim.active_count() == 0 && !trace_left {
                        // Idle with nothing left in the trace: either the
                        // job is complete (the driver is about to break)
                        // or it is permanently stuck (dead-lettered /
                        // waiting on a recovery that will never come).
                        // Stop ticking — a cadence must not keep an idle
                        // job's clock spinning forever.
                        self.replan.next_at = None;
                        false
                    } else {
                        let mut next = t;
                        while next <= sim.now() {
                            next += period;
                        }
                        self.replan.next_at = Some(next);
                        true
                    }
                }
                _ => false,
            },
        };
        if !due {
            return false;
        }
        self.replan_now(sim)
    }

    /// Hysteresis check + warm re-solve + migration — shared by the
    /// event-boundary path and resume-from-snapshot. Returns true only
    /// when a re-solve was accepted.
    fn replan_now(&mut self, sim: &FluidSim) -> bool {
        let eff = self.effective_topology(sim);
        let fp = replan::fingerprint(&eff);
        if replan::deviation(&fp, &self.replan.baseline) < self.replan.hysteresis {
            self.metrics.replans_skipped += 1;
            return false;
        }
        let app = AppModel::new(self.config.replan_alpha);
        let cur_y = self.replan.cur_y.clone();
        let new_plan =
            match self.replan.replanner.replan(&eff, app, self.config.barriers, &cur_y) {
                Some(p) => p,
                None => {
                    // Unsolvable effective LP (degenerate platform): keep
                    // the incumbent plan — a failed re-solve must never
                    // tear down a running job.
                    self.metrics.replans_skipped += 1;
                    return false;
                }
            };
        self.replan.baseline = fp;
        self.metrics.replans += 1;
        self.migrate_to_plan(&eff, &new_plan);
        self.replan.cur_y = new_plan.y;
        true
    }

    /// The platform as it stands *now*: link and compute capacities read
    /// live from the fluid simulation (bandwidth scalings and slowdowns
    /// land there), failed nodes discounted to
    /// [`replan::DOWN_DISCOUNT`]× so the LP sees a valid
    /// strictly-positive topology but routes nothing through them, and
    /// refreshed sources re-priced by their cumulative churn (staleness
    /// pricing: a high-refresh source should push to cheap-to-re-push
    /// mappers).
    fn effective_topology(&self, sim: &FluidSim) -> Topology {
        let (s, m, r) = (self.topo.n_sources(), self.topo.n_mappers(), self.topo.n_reducers());
        let mut eff = self.topo.clone();
        for i in 0..s {
            for j in 0..m {
                eff.b_sm.set(i, j, sim.capacity(self.sm_link[i][j]));
            }
        }
        for j in 0..m {
            for k in 0..r {
                eff.b_mr.set(j, k, sim.capacity(self.mr_link[j][k]));
            }
        }
        for j in 0..m {
            let c = sim.capacity(self.map_compute[j]);
            eff.c_map[j] = if self.node_up[j] { c } else { c * replan::DOWN_DISCOUNT };
        }
        for k in 0..r {
            let c = sim.capacity(self.red_compute[k]);
            eff.c_red[k] = if self.reducer_up[k] { c } else { c * replan::DOWN_DISCOUNT };
        }
        for i in 0..s {
            eff.d[i] = self.topo.d[i] * (1.0 + self.replan.refreshed_frac[i]);
        }
        eff
    }

    /// Move only *unstarted* work to the re-solved plan.
    ///
    /// Ranges: only those with an empty shuffle ledger, an unstarted
    /// reduce and no dead-letter verdict change owner — in-flight and
    /// delivered transfers keep their exact byte ledgers untouched
    /// (migration happens strictly before any byte exists for the
    /// range, so conservation is trivially preserved).
    ///
    /// Splits: only tasks still [`TaskState::WaitingForData`] re-home,
    /// and only when the new plan loads the target markedly more than
    /// the current home ([`replan::REPLAN_MOVE_FACTOR`]) or the home is
    /// down. `tasks[t].mapper` — the plan node and push destination —
    /// never changes: a re-homed split executes via the same
    /// stolen-fetch machinery as work stealing, which prices the extra
    /// hop.
    fn migrate_to_plan(&mut self, eff: &Topology, new_plan: &Plan) {
        let r = self.topo.n_reducers();
        let movable: Vec<bool> = (0..r)
            .map(|k| {
                self.range_xfers[k].is_empty()
                    && !self.reduce_started[k]
                    && !self.range_dead[k]
            })
            .collect();
        let new_owner = replan::assign_ranges(
            &new_plan.y,
            &self.plan.y,
            &self.range_owner,
            &movable,
            &self.reducer_up,
        );
        for k in 0..r {
            if movable[k] && new_owner[k] != self.range_owner[k] {
                self.range_owner[k] = new_owner[k];
                self.metrics.replan_migrated_ranges += 1;
            }
        }

        let scores = replan::mapper_scores(eff, &new_plan.x);
        for tid in 0..self.tasks.len() {
            if self.tasks[tid].state != TaskState::WaitingForData {
                continue;
            }
            let home = self.task_home[tid];
            let mut best: Option<NodeId> = None;
            for j in 0..self.topo.n_mappers() {
                if !self.node_up[j] {
                    continue;
                }
                if best.map_or(true, |b| scores[j] > scores[b]) {
                    best = Some(j);
                }
            }
            let Some(bj) = best else { continue };
            if bj == home || scores[bj] <= 0.0 {
                continue;
            }
            let move_it = !self.node_up[home]
                || scores[bj]
                    > replan::REPLAN_MOVE_FACTOR * scores[home].max(f64::MIN_POSITIVE);
            if move_it {
                self.task_home[tid] = bj;
                self.metrics.replan_migrated_splits += 1;
            }
        }
    }

    /// Re-evaluate the plan right after a resume-from-snapshot: the run
    /// may be coming back onto a world that changed while it was down.
    /// On an unchanged world this evaluates exactly the (fingerprint,
    /// baseline) pair of the last pre-crash boundary — capacities only
    /// change at trace events, all replayed before the crash — so the
    /// hysteresis skips and the resumed run stays bit-identical (only
    /// the sig-excluded `replans_skipped` records the extra evaluation).
    pub(crate) fn replan_on_resume(&mut self, sim: &mut FluidSim) {
        if !self.config.replan.enabled() {
            return;
        }
        if self.replan_now(sim) {
            self.schedule_maps(sim);
            self.maybe_speculate(sim);
        }
    }

    /// Re-scale inter-cluster links to `factor` × their topology base
    /// bandwidth — all of them (`cluster = None`) or only those touching
    /// one cluster. Factors are absolute w.r.t. the base, so `1.0`
    /// always restores the static platform; the fluid simulation
    /// re-solves its max-min allocation before the next advance.
    fn scale_links(&mut self, sim: &mut FluidSim, cluster: Option<usize>, factor: f64) {
        let (s, m, r) = (self.topo.n_sources(), self.topo.n_mappers(), self.topo.n_reducers());
        for i in 0..s {
            for j in 0..m {
                if self.topo.sm_local(i, j) {
                    continue;
                }
                let touched = match cluster {
                    None => true,
                    Some(c) => {
                        self.topo.source_cluster[i] == c || self.topo.mapper_cluster[j] == c
                    }
                };
                if touched {
                    sim.set_capacity(self.sm_link[i][j], self.topo.b_sm.get(i, j) * factor);
                }
            }
        }
        for j in 0..m {
            for k in 0..r {
                if self.topo.mr_local(j, k) {
                    continue;
                }
                let touched = match cluster {
                    None => true,
                    Some(c) => {
                        self.topo.mapper_cluster[j] == c || self.topo.reducer_cluster[k] == c
                    }
                };
                if touched {
                    sim.set_capacity(self.mr_link[j][k], self.topo.b_mr.get(j, k) * factor);
                }
            }
        }
    }

    /// Mapper `node` fails: cancel the map work executing there (primary
    /// copies go back to Ready and are re-placed — possibly stolen to a
    /// live node; speculative copies are simply dropped) and close its
    /// slots until recovery. Input pushed to the node is not lost (the
    /// split survives on the source/replica side and is re-fetched over
    /// the same link when the task runs elsewhere).
    fn fail_mapper(&mut self, sim: &mut FluidSim, node: NodeId) {
        if !self.node_up[node] {
            return;
        }
        self.node_up[node] = false;
        self.metrics.failures_injected += 1;
        // Collect doomed in-flight activities. `pending` is a BTreeMap,
        // so this traversal is already in ascending ActivityId order —
        // deterministic by construction.
        let doomed: Vec<(ActivityId, EngineEvent)> = self
            .pending
            .iter()
            .filter(|&(_, &ev)| match ev {
                EngineEvent::MapFinished { task, speculative: false }
                | EngineEvent::FetchArrived { task, speculative: false } => {
                    self.tasks[task].state == TaskState::Running
                        && self.tasks[task].exec_node == Some(node)
                }
                EngineEvent::MapFinished { task, speculative: true }
                | EngineEvent::FetchArrived { task, speculative: true } => {
                    self.tasks[task].spec_node == Some(node)
                }
                _ => false,
            })
            .map(|(&a, &ev)| (a, ev))
            .collect();
        let mut exhausted: Vec<TaskId> = Vec::new();
        for (aid, ev) in doomed {
            sim.cancel(aid);
            self.pending.remove(&aid);
            match ev {
                EngineEvent::MapFinished { task, speculative: false }
                | EngineEvent::FetchArrived { task, speculative: false } => {
                    // The eviction consumes one attempt. Within budget,
                    // re-queue the primary copy (a speculative copy, if
                    // any, keeps running on its own node and can still
                    // win the re-queued task outright); at budget, the
                    // split is dead-lettered below, after every doomed
                    // activity has been retired.
                    let budget = self.config.max_attempts;
                    let t = &mut self.tasks[task];
                    t.state = TaskState::Ready;
                    t.exec_node = None;
                    t.activity = None;
                    t.attempts += 1;
                    if t.attempts >= budget {
                        exhausted.push(task);
                    } else {
                        self.metrics.tasks_requeued += 1;
                    }
                }
                EngineEvent::MapFinished { task, speculative: true }
                | EngineEvent::FetchArrived { task, speculative: true } => {
                    let t = &mut self.tasks[task];
                    t.spec_node = None;
                    t.spec_activity = None;
                    t.spec_fetching = false;
                }
                _ => unreachable!("doomed set only holds map/fetch events"),
            }
        }
        for tid in exhausted {
            self.dead_letter_split(sim, tid);
        }
        // No task occupies the node now; close all slots until recovery.
        self.map_slots_free[node] = 0;
    }

    /// Route map split `tid` to the dead-letter queue: kill any surviving
    /// speculative copy, retire the split from every barrier gate exactly
    /// as a completed map with no output would, and record the entry. The
    /// split's push bytes were delivered and stay credited; no shuffle
    /// bytes ever exist for it.
    fn dead_letter_split(&mut self, sim: &mut FluidSim, tid: TaskId) {
        debug_assert!(
            self.tasks[tid].state != TaskState::Done && self.tasks[tid].state != TaskState::Dead,
            "dead-lettering a finished split"
        );
        // A speculative copy on a *surviving* node may still be running
        // (fetching or computing); budget exhaustion retires the split as
        // a whole, so cancel it. `pending` is a BTreeMap — ascending
        // ActivityId order, deterministic.
        let doomed: Vec<ActivityId> = self
            .pending
            .iter()
            .filter(|&(_, &ev)| match ev {
                EngineEvent::MapFinished { task, .. } | EngineEvent::FetchArrived { task, .. } => {
                    task == tid
                }
                _ => false,
            })
            .map(|(&a, _)| a)
            .collect();
        for a in doomed {
            sim.cancel(a);
            self.pending.remove(&a);
        }
        if let Some(spec_node) = self.tasks[tid].spec_node.take() {
            // The spec node is up (a node failure clears spec bookkeeping
            // for copies it hosted), so its slot really is occupied.
            self.map_slots_free[spec_node] += 1;
        }
        self.tasks[tid].spec_activity = None;
        self.tasks[tid].spec_fetching = false;
        self.tasks[tid].state = TaskState::Dead;

        let home = self.tasks[tid].mapper;
        self.maps_left -= 1;
        self.maps_left_per_node[home] = self.maps_left_per_node[home].saturating_sub(1);
        self.metrics.splits_dead_lettered += 1;
        self.dlq.entries.push(DlqEntry {
            kind: DlqKind::Split,
            id: tid,
            bytes: self.tasks[tid].bytes,
            attempts: self.tasks[tid].attempts,
            at: sim.now(),
        });
        self.metrics.makespan = self.metrics.makespan.max(sim.now());

        // Mirror the barrier bookkeeping of a map completion (with no
        // output): the dead split must not gate the shuffle forever.
        match self.config.barriers.map_shuffle {
            Barrier::Global => {
                if self.maps_left == 0 {
                    self.release_shuffle(sim);
                }
            }
            Barrier::Local => {
                if self.maps_left_per_node[home] == 0 {
                    self.release_local_cohort(sim, home);
                }
            }
            Barrier::Pipelined => {}
        }
        self.maybe_finish_shuffle_phase(sim);
    }

    /// Route key range `k` to the dead-letter queue: write off every one
    /// of its transfers (bytes move to `metrics.dlq_bytes`, preserving
    /// `shuffle_bytes_delivered + dlq_bytes == shuffle_bytes`), close its
    /// shuffle gate, and mark the range reduced-without-running so the
    /// job can finish around it. Called at failure time — never deferred
    /// to a reassignment that may not exist.
    fn dead_letter_range(&mut self, sim: &mut FluidSim, k: usize) {
        debug_assert!(!self.range_dead[k] && !self.reduce_compute_done[k]);
        self.range_dead[k] = true;
        let mut dead_bytes = 0.0f64;
        for i in 0..self.range_xfers[k].len() {
            let id = self.range_xfers[k][i];
            debug_assert!(
                self.xfers[id].state != XferState::InFlight,
                "dead-lettered range still has in-flight transfers"
            );
            if self.xfers[id].state == XferState::Dead {
                continue;
            }
            if self.xfers[id].state == XferState::Delivered {
                // Defensive: the reducer-failure path de-credits before
                // dead-lettering, so this arm is normally unreachable.
                self.metrics.shuffle_bytes_delivered -= self.xfers[id].bytes;
            }
            self.xfers[id].state = XferState::Dead;
            self.xfers[id].records = Vec::new();
            // Exact: byte counts are integers < 2^53 carried in f64, so
            // the write-off keeps the conservation identity exact.
            self.metrics.dlq_bytes += self.xfers[id].bytes;
            dead_bytes += self.xfers[id].bytes;
        }
        self.shuffle_xfers_left[k] = 0;
        // Reduced-without-running: the gate flags let `is_complete` and
        // `maybe_start_reduces` treat the range as settled.
        self.reduce_started[k] = true;
        self.reduce_done[k] = true;
        self.reduce_compute_done[k] = true;
        self.range_compute[k] = None;
        self.metrics.ranges_dead_lettered += 1;
        self.dlq.entries.push(DlqEntry {
            kind: DlqKind::Range,
            id: k,
            bytes: dead_bytes,
            attempts: self.range_attempts[k],
            at: sim.now(),
        });
        self.metrics.makespan = self.metrics.makespan.max(sim.now());
    }

    /// Mapper `node` recovers with every slot free (all its work was
    /// evicted at failure time and nothing could be placed since).
    fn recover_mapper(&mut self, node: NodeId) {
        if self.node_up[node] {
            return;
        }
        self.node_up[node] = true;
        self.map_slots_free[node] = self.map_slots;
    }

    /// Source `source` refreshed `fraction` of its data (see the
    /// staleness lifecycle in [`super::dynamics`]): walk the source's
    /// push transfers in creation order and re-dirty transfers feeding
    /// *unsealed* splits (tasks still waiting for data) until the
    /// refreshed byte volume is covered. An in-flight copy is cancelled
    /// and restarted from byte zero; a delivered copy is discarded at the
    /// mapper — de-credited from `push_bytes_delivered` with the split's
    /// push gate re-opened. Every re-send is counted in
    /// `push_bytes_repushed`. Splits whose data fully arrived and whose
    /// barrier released them are sealed: the map task consumed a
    /// consistent snapshot, and the refresh produces a new version this
    /// job never observes.
    fn refresh_source(&mut self, sim: &mut FluidSim, source: usize, fraction: f64) {
        let target = fraction * self.source_push_bytes[source];
        if target <= 0.0 {
            return;
        }
        let mut acc = 0.0f64;
        let mut dirtied: Vec<usize> = Vec::new();
        for &id in &self.source_xfers[source] {
            if acc >= target {
                break;
            }
            if self.tasks[self.push_xfers[id].task].state != TaskState::WaitingForData {
                continue;
            }
            acc += self.push_xfers[id].bytes;
            dirtied.push(id);
        }
        if dirtied.is_empty() {
            return;
        }
        self.metrics.sources_refreshed += 1;
        // Staleness pricing for the replanner: an effective refresh
        // inflates the source's effective volume in later re-solves.
        self.replan.note_refresh(source, fraction);
        for id in dirtied {
            match self.push_xfers[id].state {
                XferState::InFlight => {
                    // The half-written copy is stale: cancel and restart
                    // the transfer from byte zero. `push_parts_left` and
                    // the split's gate still count it as outstanding.
                    let a = self.push_xfers[id]
                        .activity
                        .take()
                        .expect("in-flight push transfer has an activity");
                    sim.cancel(a);
                    self.pending.remove(&a);
                }
                XferState::Delivered => {
                    // The delivered copy is stale: discard it at the
                    // mapper and re-open the split's push gate.
                    self.metrics.push_bytes_delivered -= self.push_xfers[id].bytes;
                    self.tasks[self.push_xfers[id].task].pending_parts += 1;
                    self.push_parts_left += 1;
                }
                XferState::Held => {
                    unreachable!("push transfers are sent immediately and never held")
                }
            }
            self.send_push(sim, id);
        }
    }

    /// Reducer `node` fails (see the module docs for the lifecycle):
    /// cancel its in-flight shuffle/reduce activities deterministically,
    /// de-credit delivered-but-unreduced data, and ask the scheduler to
    /// re-partition each orphaned key range onto a survivor. Ranges whose
    /// reduce compute already finished are durable and unaffected.
    fn fail_reducer(&mut self, sim: &mut FluidSim, node: NodeId) {
        if !self.reducer_up[node] {
            return;
        }
        self.reducer_up[node] = false;
        self.metrics.failures_injected += 1;
        self.metrics.reducers_failed += 1;
        let r = self.topo.n_reducers();

        // 1. Cancel doomed in-flight activities. `pending` is a BTreeMap,
        //    so this traversal is already in ascending ActivityId order —
        //    deterministic by construction.
        let doomed: Vec<(ActivityId, EngineEvent)> = self
            .pending
            .iter()
            .filter(|&(_, &ev)| match ev {
                EngineEvent::ShuffleArrived { xfer } => {
                    self.range_owner[self.xfers[xfer].range] == node
                        && self.xfers[xfer].state == XferState::InFlight
                }
                EngineEvent::ReduceFinished { range } => {
                    self.range_owner[range] == node && !self.reduce_compute_done[range]
                }
                _ => false,
            })
            .map(|(&a, &ev)| (a, ev))
            .collect();
        for (aid, ev) in doomed {
            sim.cancel(aid);
            self.pending.remove(&aid);
            match ev {
                EngineEvent::ShuffleArrived { xfer } => {
                    self.xfers[xfer].state = XferState::Held;
                }
                EngineEvent::ReduceFinished { range } => {
                    // Partial reduce progress is lost; the range restarts
                    // from scratch once its input is back in place.
                    self.range_compute[range] = None;
                    self.reduce_started[range] = false;
                }
                _ => unreachable!("doomed set only holds shuffle/reduce events"),
            }
        }

        // 2. Data already delivered to the dead node for unreduced ranges
        //    died with its disk: de-credit and mark for resend (touching
        //    only the affected ranges' transfer lists).
        let mut lost_any = false;
        for k in 0..r {
            if self.range_owner[k] != node || self.reduce_compute_done[k] {
                continue;
            }
            for i in 0..self.range_xfers[k].len() {
                let id = self.range_xfers[k][i];
                if self.xfers[id].state == XferState::Delivered {
                    self.xfers[id].state = XferState::Held;
                    self.metrics.shuffle_bytes_delivered -= self.xfers[id].bytes;
                    self.shuffle_xfers_left[k] += 1;
                    lost_any = true;
                }
            }
        }
        if lost_any && self.all_shuffles_done {
            // Re-open the shuffle phase so the Global shuffle/reduce
            // barrier re-gates on the replayed deliveries.
            self.all_shuffles_done = false;
        }

        // 2.5 The eviction consumes one attempt per orphaned range.
        //     Exhausted ranges are dead-lettered *at failure time* —
        //     never deferred to a reassignment that may not exist (a
        //     full reducer blackout leaves no adoption target, and a
        //     deferred write-off would strand the range forever).
        //     Dead-lettering marks the range settled, so steps 3–4
        //     skip it via their `reduce_compute_done` filters.
        for k in 0..r {
            if self.range_owner[k] != node || self.reduce_compute_done[k] {
                continue;
            }
            self.range_attempts[k] += 1;
            if self.range_attempts[k] >= self.config.max_attempts {
                self.dead_letter_range(sim, k);
            }
        }
        self.maybe_finish_shuffle_phase(sim);

        // 3. Re-partition each orphaned range via the scheduler (ascending
        //    range order for determinism). Outstanding-bytes bookkeeping
        //    lets the policy spread successive adoptions. Capacities are
        //    the *current* fluid-sim rates, not the topology base, so an
        //    actively slowed straggler (ReducerSlowdown in effect) does
        //    not win the adoption tie-break on its nominal speed.
        let capacity: Vec<f64> =
            (0..r).map(|k| sim.capacity(self.red_compute[k])).collect();
        let mut assigned = vec![0.0f64; r];
        for k in 0..r {
            if !self.reduce_compute_done[k] {
                assigned[self.range_owner[k]] += self.range_bytes[k];
            }
        }
        for k in 0..r {
            if self.range_owner[k] != node || self.reduce_compute_done[k] {
                continue;
            }
            let choice = {
                let view = ReduceView {
                    dead: node,
                    up: &self.reducer_up,
                    cluster: &self.topo.reducer_cluster,
                    capacity: &capacity,
                    assigned_bytes: &assigned,
                };
                self.scheduler.reassign_reduce(&view)
            };
            // Enforce the contract rather than trust the policy: the
            // adopter must be a live reducer other than the dead one.
            if let Some(new_owner) = choice {
                if new_owner != node && new_owner < r && self.reducer_up[new_owner] {
                    self.range_owner[k] = new_owner;
                    assigned[node] -= self.range_bytes[k];
                    assigned[new_owner] += self.range_bytes[k];
                    self.metrics.reduce_ranges_reassigned += 1;
                    // Replay the range's held transfers to the adopter.
                    self.resend_held(sim, k);
                }
            }
            // No adopter (plan enforcement / no survivor): the range and
            // its held transfers wait for the node's recovery.
        }

        // 4. Close the dead node's reduce slots until recovery.
        self.reduce_slots_free[node] = 0;
        // Adopted zero-transfer ranges may be immediately startable.
        self.maybe_start_reduces(sim);
    }

    /// Reducer `node` recovers with every reduce slot free (its work was
    /// evicted at failure time and nothing could start there since).
    /// Transfers still targeting ranges it kept through the outage are
    /// resent.
    fn recover_reducer(&mut self, sim: &mut FluidSim, node: NodeId) {
        if self.reducer_up[node] {
            return;
        }
        self.reducer_up[node] = true;
        self.reduce_slots_free[node] = self.reduce_slots;
        // Resend held transfers for ranges this node kept through the
        // outage (range then transfer-id order — deterministic).
        for k in 0..self.topo.n_reducers() {
            if self.range_owner[k] == node {
                self.resend_held(sim, k);
            }
        }
        self.maybe_start_reduces(sim);
    }

    /// Resend range `k`'s held transfers to its current owner, in
    /// transfer-id (creation) order — deterministic. Shared by the
    /// adoption and recovery paths so their replay behavior can never
    /// diverge.
    fn resend_held(&mut self, sim: &mut FluidSim, k: usize) {
        let held: Vec<usize> = self.range_xfers[k]
            .iter()
            .copied()
            .filter(|&id| self.xfers[id].state == XferState::Held)
            .collect();
        for id in held {
            self.send_xfer(sim, id);
        }
    }

    /// Dispatch one engine event (popped from the heap in virtual-time
    /// order).
    fn dispatch(&mut self, sim: &mut FluidSim, ev: EngineEvent) {
        match ev {
            EngineEvent::PushArrived { xfer } => {
                let task = self.push_xfers[xfer].task;
                self.push_xfers[xfer].state = XferState::Delivered;
                self.push_xfers[xfer].activity = None;
                // Exact: byte counts are integers < 2^53 carried in f64;
                // at job end push_bytes_delivered == push_bytes exactly.
                self.metrics.push_bytes_delivered += self.push_xfers[xfer].bytes;
                self.push_parts_left -= 1;
                self.metrics.push_end = sim.now();
                self.tasks[task].pending_parts -= 1;
                match self.config.barriers.push_map {
                    Barrier::Global => {
                        if self.push_parts_left == 0 {
                            self.release_maps_after_push(sim);
                        }
                    }
                    _ => {
                        // Local/pipelined: the split is runnable as soon
                        // as its own data is in place.
                        if self.tasks[task].pending_parts == 0
                            && self.tasks[task].state == TaskState::WaitingForData
                        {
                            self.tasks[task].state = TaskState::Ready;
                            self.schedule_maps(sim);
                        }
                    }
                }
            }
            EngineEvent::FetchArrived { task, speculative: false } => {
                // Stolen task: its input arrived at the thief.
                if self.tasks[task].state == TaskState::Running {
                    let node = self.tasks[task].exec_node.unwrap();
                    self.start_map_compute(sim, task, node, false);
                }
            }
            EngineEvent::FetchArrived { task, speculative: true } => {
                self.tasks[task].spec_fetching = false;
                if self.tasks[task].state == TaskState::Done {
                    // Original finished while we were fetching.
                    if let Some(node) = self.tasks[task].spec_node.take() {
                        self.map_slots_free[node] += 1;
                    }
                } else {
                    let node = self.tasks[task].spec_node.unwrap();
                    self.start_map_compute(sim, task, node, true);
                }
            }
            EngineEvent::MapFinished { task, speculative } => {
                self.on_map_done(sim, task, speculative);
            }
            EngineEvent::ShuffleArrived { xfer } => {
                let range = self.xfers[xfer].range;
                self.xfers[xfer].state = XferState::Delivered;
                // Exact: byte counts are integers < 2^53 carried in f64;
                // shuffle credits sum to shuffle_bytes exactly at job end.
                self.metrics.shuffle_bytes_delivered += self.xfers[xfer].bytes;
                self.shuffle_xfers_left[range] -= 1;
                self.metrics.shuffle_end = sim.now();
                self.maybe_finish_shuffle_phase(sim);
                self.maybe_start_reduces(sim);
            }
            EngineEvent::ReduceFinished { range } => {
                self.on_reduce_compute_done(sim, range);
            }
            EngineEvent::OutputWritten { range } => {
                self.writes_left[range] -= 1;
                if self.writes_left[range] == 0 {
                    self.finish_reduce(sim, range);
                }
            }
        }
    }

    // ----------------------------------------------- checkpoint codec
    //
    // `encode_state` serializes every mutable field (the immutable
    // inputs — topology, plan, app, config, inputs — are the resume
    // contract: the caller reconstructs the executor from the same
    // arguments and `restore_state` overlays the dynamic state).
    // Snapshots are only legal at *event boundaries*: the event heap
    // drained (`drain` returned), so only its clock survives; in-flight
    // fluid activities are captured by the separately exported
    // [`FluidSim`] state, referenced here by [`ActivityId`].

    /// Number of map splits (`build_splits` is deterministic, so this is
    /// a cheap compatibility probe for snapshot headers).
    pub(crate) fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Serialize the executor's mutable state. Panics if called away
    /// from an event boundary (undrained event heap) or mid-dispatch
    /// (materialized-but-untaken map outputs).
    pub(crate) fn encode_state(&self) -> Json {
        assert!(
            self.queue.is_empty(),
            "snapshots are only legal at event boundaries (event heap drained)"
        );
        let recs = |rs: &[Record]| -> Json {
            Json::Arr(
                rs.iter()
                    .map(|r| {
                        Json::Arr(vec![Json::Str(r.key.clone()), Json::Str(r.value.clone())])
                    })
                    .collect(),
            )
        };
        let uints = |v: &[usize]| Json::Arr(v.iter().map(|&x| Json::uint(x)).collect());
        let bools = |v: &[bool]| Json::Arr(v.iter().map(|&b| Json::Bool(b)).collect());
        let tasks = Json::Arr(
            self.tasks
                .iter()
                .map(|t| {
                    assert!(
                        t.outputs.is_none(),
                        "snapshots are only legal at event boundaries (untaken map outputs)"
                    );
                    Json::Obj(vec![
                        ("state".into(), Json::uint(task_state_code(t.state))),
                        ("exec".into(), Json::opt_uint(t.exec_node)),
                        ("act".into(), Json::opt_uint(t.activity)),
                        ("spec".into(), Json::opt_uint(t.spec_node)),
                        ("spec_act".into(), Json::opt_uint(t.spec_activity)),
                        ("spec_fetch".into(), Json::Bool(t.spec_fetching)),
                        ("parts_left".into(), Json::uint(t.pending_parts)),
                        ("started".into(), Json::f64_bits(t.started_at)),
                        ("attempts".into(), Json::uint(t.attempts as usize)),
                    ])
                })
                .collect(),
        );
        let push_xfers = Json::Arr(
            self.push_xfers
                .iter()
                .map(|x| {
                    Json::Obj(vec![
                        ("task".into(), Json::uint(x.task)),
                        ("src".into(), Json::uint(x.source)),
                        ("to".into(), Json::uint(x.to)),
                        ("bytes".into(), Json::f64_bits(x.bytes)),
                        ("state".into(), Json::uint(xfer_state_code(x.state))),
                        ("sent".into(), Json::Bool(x.sent_once)),
                        ("act".into(), Json::opt_uint(x.activity)),
                    ])
                })
                .collect(),
        );
        let xfers = Json::Arr(
            self.xfers
                .iter()
                .map(|x| {
                    Json::Obj(vec![
                        ("from".into(), Json::uint(x.from)),
                        ("range".into(), Json::uint(x.range)),
                        ("bytes".into(), Json::f64_bits(x.bytes)),
                        ("state".into(), Json::uint(xfer_state_code(x.state))),
                        ("sent".into(), Json::Bool(x.sent_once)),
                        ("recs".into(), recs(&x.records)),
                    ])
                })
                .collect(),
        );
        let parked = Json::Arr(
            self.parked_outputs
                .iter()
                .map(|(home, exec, outs)| {
                    Json::Obj(vec![
                        ("home".into(), Json::uint(*home)),
                        ("exec".into(), Json::uint(*exec)),
                        ("outs".into(), Json::Arr(outs.iter().map(|o| recs(o)).collect())),
                    ])
                })
                .collect(),
        );
        let pending = Json::Arr(
            self.pending
                .iter()
                .map(|(&aid, ev)| Json::Arr(vec![Json::uint(aid), event_to_json(ev)]))
                .collect(),
        );
        let dlq = Json::Arr(
            self.dlq
                .entries
                .iter()
                .map(|e| {
                    Json::Obj(vec![
                        (
                            "kind".into(),
                            Json::Str(
                                match e.kind {
                                    DlqKind::Split => "split",
                                    DlqKind::Range => "range",
                                }
                                .into(),
                            ),
                        ),
                        ("id".into(), Json::uint(e.id)),
                        ("bytes".into(), Json::f64_bits(e.bytes)),
                        ("attempts".into(), Json::uint(e.attempts as usize)),
                        ("at".into(), Json::f64_bits(e.at)),
                    ])
                })
                .collect(),
        );
        Json::Obj(vec![
            ("clock".into(), Json::f64_bits(self.queue.now())),
            ("dyn_cursor".into(), Json::uint(self.dyn_cursor)),
            ("push_parts_left".into(), Json::uint(self.push_parts_left)),
            ("maps_left".into(), Json::uint(self.maps_left)),
            ("maps_left_per_node".into(), uints(&self.maps_left_per_node)),
            ("shuffle_xfers_left".into(), uints(&self.shuffle_xfers_left)),
            ("all_shuffles_done".into(), Json::Bool(self.all_shuffles_done)),
            ("range_owner".into(), uints(&self.range_owner)),
            ("reducer_up".into(), bools(&self.reducer_up)),
            (
                "range_compute".into(),
                Json::Arr(self.range_compute.iter().map(|&a| Json::opt_uint(a)).collect()),
            ),
            ("reduce_compute_done".into(), bools(&self.reduce_compute_done)),
            ("reduce_started".into(), bools(&self.reduce_started)),
            ("reduce_done".into(), bools(&self.reduce_done)),
            ("writes_left".into(), uints(&self.writes_left)),
            (
                "range_attempts".into(),
                Json::Arr(self.range_attempts.iter().map(|&a| Json::uint(a as usize)).collect()),
            ),
            ("range_dead".into(), bools(&self.range_dead)),
            ("node_up".into(), bools(&self.node_up)),
            ("map_slots_free".into(), uints(&self.map_slots_free)),
            ("reduce_slots_free".into(), uints(&self.reduce_slots_free)),
            (
                "durations".into(),
                Json::Arr(self.durations.iter().map(|&d| Json::f64_bits(d)).collect()),
            ),
            ("tasks".into(), tasks),
            ("push_xfers".into(), push_xfers),
            ("xfers".into(), xfers),
            ("parked_outputs".into(), parked),
            ("pending".into(), pending),
            ("dlq".into(), dlq),
            (
                "outputs".into(),
                Json::Arr(self.outputs.iter().map(|o| recs(o)).collect()),
            ),
            ("metrics".into(), super::snapshot::encode_metrics(&self.metrics)),
            ("replan".into(), self.replan.encode()),
        ])
    }

    /// Overlay a decoded snapshot onto a freshly constructed executor
    /// (same topology/plan/app/config/inputs/weight/tag — the caller's
    /// contract, compatibility-probed by [`super::snapshot`]'s header).
    /// `n_activities` is the restored fluid simulation's activity count,
    /// used to bounds-check every [`ActivityId`] reference. On error the
    /// executor is left partially overwritten — discard it.
    pub(crate) fn restore_state(&mut self, st: &Json, n_activities: usize) -> Result<(), String> {
        let (m, r) = (self.topo.n_mappers(), self.topo.n_reducers());
        let uints = |j: &Json, n: usize, what: &str| -> Result<Vec<usize>, String> {
            let arr = j.as_arr()?;
            if arr.len() != n {
                return Err(format!("{what}: expected {n} entries, got {}", arr.len()));
            }
            arr.iter().map(|v| v.as_usize()).collect()
        };
        let bools = |j: &Json, n: usize, what: &str| -> Result<Vec<bool>, String> {
            let arr = j.as_arr()?;
            if arr.len() != n {
                return Err(format!("{what}: expected {n} entries, got {}", arr.len()));
            }
            arr.iter().map(|v| v.as_bool()).collect()
        };
        let recs = |j: &Json| -> Result<Vec<Record>, String> {
            j.as_arr()?
                .iter()
                .map(|p| {
                    let kv = p.as_arr()?;
                    if kv.len() != 2 {
                        return Err("record must be a [key, value] pair".into());
                    }
                    Ok(Record::new(kv[0].as_str()?, kv[1].as_str()?))
                })
                .collect()
        };
        let opt_act = |j: &Json| -> Result<Option<ActivityId>, String> {
            let a = j.as_opt_usize()?;
            if let Some(id) = a {
                if id >= n_activities {
                    return Err(format!("activity id {id} out of range (< {n_activities})"));
                }
            }
            Ok(a)
        };

        self.queue.restore_clock(st.field("clock")?.as_f64_bits()?);
        self.dyn_cursor = st.field("dyn_cursor")?.as_usize()?;
        if let Some(trace) = self.dynamics {
            if self.dyn_cursor > trace.events().len() {
                return Err("dynamics cursor past the end of the trace".into());
            }
        }
        self.push_parts_left = st.field("push_parts_left")?.as_usize()?;
        self.maps_left = st.field("maps_left")?.as_usize()?;
        self.maps_left_per_node = uints(st.field("maps_left_per_node")?, m, "maps_left_per_node")?;
        self.shuffle_xfers_left =
            uints(st.field("shuffle_xfers_left")?, r, "shuffle_xfers_left")?;
        self.all_shuffles_done = st.field("all_shuffles_done")?.as_bool()?;
        self.range_owner = uints(st.field("range_owner")?, r, "range_owner")?;
        if self.range_owner.iter().any(|&o| o >= r) {
            return Err("range owner out of range".into());
        }
        self.reducer_up = bools(st.field("reducer_up")?, r, "reducer_up")?;
        {
            let arr = st.field("range_compute")?.as_arr()?;
            if arr.len() != r {
                return Err(format!("range_compute: expected {r} entries, got {}", arr.len()));
            }
            self.range_compute = arr.iter().map(&opt_act).collect::<Result<_, _>>()?;
        }
        self.reduce_compute_done =
            bools(st.field("reduce_compute_done")?, r, "reduce_compute_done")?;
        self.reduce_started = bools(st.field("reduce_started")?, r, "reduce_started")?;
        self.reduce_done = bools(st.field("reduce_done")?, r, "reduce_done")?;
        self.writes_left = uints(st.field("writes_left")?, r, "writes_left")?;
        self.range_attempts = uints(st.field("range_attempts")?, r, "range_attempts")?
            .into_iter()
            .map(|a| a as u32)
            .collect();
        self.range_dead = bools(st.field("range_dead")?, r, "range_dead")?;
        self.node_up = bools(st.field("node_up")?, m, "node_up")?;
        self.map_slots_free = uints(st.field("map_slots_free")?, m, "map_slots_free")?;
        self.reduce_slots_free = uints(st.field("reduce_slots_free")?, r, "reduce_slots_free")?;
        self.durations = st
            .field("durations")?
            .as_arr()?
            .iter()
            .map(|d| d.as_f64_bits())
            .collect::<Result<_, _>>()?;

        let tasks = st.field("tasks")?.as_arr()?;
        if tasks.len() != self.tasks.len() {
            return Err(format!(
                "snapshot has {} tasks, this job builds {}",
                tasks.len(),
                self.tasks.len()
            ));
        }
        for (t, j) in self.tasks.iter_mut().zip(tasks) {
            t.state = task_state_from_code(j.field("state")?.as_usize()?)?;
            t.exec_node = j.field("exec")?.as_opt_usize()?;
            t.activity = opt_act(j.field("act")?)?;
            t.spec_node = j.field("spec")?.as_opt_usize()?;
            t.spec_activity = opt_act(j.field("spec_act")?)?;
            t.spec_fetching = j.field("spec_fetch")?.as_bool()?;
            t.pending_parts = j.field("parts_left")?.as_usize()?;
            t.started_at = j.field("started")?.as_f64_bits()?;
            t.attempts = j.field("attempts")?.as_usize()? as u32;
            t.outputs = None;
            if t.exec_node.map_or(false, |n| n >= m) || t.spec_node.map_or(false, |n| n >= m) {
                return Err("task exec/spec node out of range".into());
            }
        }

        // Transfer tables are rebuilt wholesale; the per-source and
        // per-range indexes (and their byte totals) are re-derived by
        // walking in creation order — the same accumulation order the
        // original run used, so the f64 sums are bit-identical.
        let s = self.topo.n_sources();
        self.push_xfers = Vec::new();
        self.source_xfers = vec![Vec::new(); s];
        self.source_push_bytes = vec![0.0; s];
        for j in st.field("push_xfers")?.as_arr()? {
            let x = PushXfer {
                task: j.field("task")?.as_usize()?,
                source: j.field("src")?.as_usize()?,
                to: j.field("to")?.as_usize()?,
                bytes: j.field("bytes")?.as_f64_bits()?,
                state: xfer_state_from_code(j.field("state")?.as_usize()?)?,
                sent_once: j.field("sent")?.as_bool()?,
                activity: opt_act(j.field("act")?)?,
            };
            if x.task >= self.tasks.len() || x.source >= s || x.to >= m {
                return Err("push transfer reference out of range".into());
            }
            self.source_xfers[x.source].push(self.push_xfers.len());
            self.source_push_bytes[x.source] += x.bytes;
            self.push_xfers.push(x);
        }
        self.xfers = Vec::new();
        self.range_xfers = vec![Vec::new(); r];
        self.range_bytes = vec![0.0; r];
        for j in st.field("xfers")?.as_arr()? {
            let x = ShuffleXfer {
                from: j.field("from")?.as_usize()?,
                range: j.field("range")?.as_usize()?,
                bytes: j.field("bytes")?.as_f64_bits()?,
                state: xfer_state_from_code(j.field("state")?.as_usize()?)?,
                sent_once: j.field("sent")?.as_bool()?,
                records: recs(j.field("recs")?)?,
            };
            if x.from >= m || x.range >= r {
                return Err("shuffle transfer reference out of range".into());
            }
            self.range_xfers[x.range].push(self.xfers.len());
            self.range_bytes[x.range] += x.bytes;
            self.xfers.push(x);
        }

        self.parked_outputs = Vec::new();
        for j in st.field("parked_outputs")?.as_arr()? {
            let home = j.field("home")?.as_usize()?;
            let exec = j.field("exec")?.as_usize()?;
            if home >= m || exec >= m {
                return Err("parked output node out of range".into());
            }
            let outs = j
                .field("outs")?
                .as_arr()?
                .iter()
                .map(&recs)
                .collect::<Result<Vec<_>, _>>()?;
            if outs.len() != r {
                return Err("parked output must have one record list per range".into());
            }
            self.parked_outputs.push((home, exec, outs));
        }

        self.pending = BTreeMap::new();
        for j in st.field("pending")?.as_arr()? {
            let pair = j.as_arr()?;
            if pair.len() != 2 {
                return Err("pending entry must be [activity, event]".into());
            }
            let aid = pair[0].as_usize()?;
            if aid >= n_activities {
                return Err(format!("pending activity {aid} out of range (< {n_activities})"));
            }
            let ev = event_from_json(&pair[1])?;
            let (n_push, n_shuf, n_tasks) = (self.push_xfers.len(), self.xfers.len(), self.tasks.len());
            let ok = match ev {
                EngineEvent::PushArrived { xfer } => xfer < n_push,
                EngineEvent::ShuffleArrived { xfer } => xfer < n_shuf,
                EngineEvent::FetchArrived { task, .. } | EngineEvent::MapFinished { task, .. } => {
                    task < n_tasks
                }
                EngineEvent::ReduceFinished { range } | EngineEvent::OutputWritten { range } => {
                    range < r
                }
            };
            if !ok {
                return Err("pending event reference out of range".into());
            }
            if self.pending.insert(aid, ev).is_some() {
                return Err(format!("duplicate pending activity {aid}"));
            }
        }

        self.dlq = DeadLetterQueue::default();
        for j in st.field("dlq")?.as_arr()? {
            let kind = match j.field("kind")?.as_str()? {
                "split" => DlqKind::Split,
                "range" => DlqKind::Range,
                other => return Err(format!("unknown dlq kind `{other}`")),
            };
            self.dlq.entries.push(DlqEntry {
                kind,
                id: j.field("id")?.as_usize()?,
                bytes: j.field("bytes")?.as_f64_bits()?,
                attempts: j.field("attempts")?.as_usize()? as u32,
                at: j.field("at")?.as_f64_bits()?,
            });
        }

        let outputs = st.field("outputs")?.as_arr()?;
        if outputs.len() != r {
            return Err(format!("outputs: expected {r} entries, got {}", outputs.len()));
        }
        self.outputs = outputs.iter().map(&recs).collect::<Result<_, _>>()?;
        self.metrics = super::snapshot::decode_metrics(st.field("metrics")?)?;
        self.replan.restore(st.field("replan")?)?;
        Ok(())
    }

    // ----------------------------------------------- driver interface
    //
    // The granular lifecycle [`run_job`] and the tenancy engine both
    // drive: `start`, then per completion batch `enqueue` every
    // completed activity, `drain`, `maybe_speculate`; `apply_dynamics`
    // on empty (limit-hit) batches; `into_result` once `is_complete`.

    /// Apply trace events due at t = 0 and put the push on the wire.
    pub(crate) fn start(&mut self, sim: &mut FluidSim) {
        self.apply_dynamics(sim);
        self.start_push(sim);
    }

    /// Route one completed fluid activity to this job's event heap.
    /// Returns false for a cancelled losing copy (nothing to dispatch).
    pub(crate) fn enqueue(&mut self, now: f64, aid: ActivityId) -> bool {
        if let Some(ev) = self.pending.remove(&aid) {
            self.queue.push(now, ev);
            true
        } else {
            false
        }
    }

    /// Dispatch every queued engine event in (time, FIFO) order.
    pub(crate) fn drain(&mut self, sim: &mut FluidSim) {
        while let Some((_t, ev)) = self.queue.pop() {
            self.dispatch(sim, ev);
        }
    }

    /// Every key range reduced and written?
    pub(crate) fn is_complete(&self) -> bool {
        self.reduce_done.iter().all(|&d| d)
    }

    /// The routing tag this executor stamps on its activities.
    pub(crate) fn tag(&self) -> u64 {
        self.tag
    }

    /// Finalize a completed job.
    pub(crate) fn into_result(self) -> JobResult {
        assert!(
            self.reduce_done.iter().all(|&d| d),
            "job ended with unfinished reducers (maps_left={}, xfers={:?})",
            self.maps_left,
            self.shuffle_xfers_left
        );
        let outcome = if self.dlq.is_empty() {
            JobOutcome::Complete
        } else {
            JobOutcome::PartialWithDlq
        };
        JobResult { metrics: self.metrics, outputs: self.outputs, outcome, dlq: self.dlq }
    }
}

// Snapshot enum codes (stable on-disk values — extend, never renumber).

fn task_state_code(s: TaskState) -> usize {
    match s {
        TaskState::WaitingForData => 0,
        TaskState::Ready => 1,
        TaskState::Running => 2,
        TaskState::Done => 3,
        TaskState::Dead => 4,
    }
}

fn task_state_from_code(c: usize) -> Result<TaskState, String> {
    Ok(match c {
        0 => TaskState::WaitingForData,
        1 => TaskState::Ready,
        2 => TaskState::Running,
        3 => TaskState::Done,
        4 => TaskState::Dead,
        other => return Err(format!("unknown task state code {other}")),
    })
}

fn xfer_state_code(s: XferState) -> usize {
    match s {
        XferState::Held => 0,
        XferState::InFlight => 1,
        XferState::Delivered => 2,
        XferState::Dead => 3,
    }
}

fn xfer_state_from_code(c: usize) -> Result<XferState, String> {
    Ok(match c {
        0 => XferState::Held,
        1 => XferState::InFlight,
        2 => XferState::Delivered,
        3 => XferState::Dead,
        other => return Err(format!("unknown transfer state code {other}")),
    })
}

fn event_to_json(ev: &EngineEvent) -> Json {
    let one = |t: &str, v: usize| Json::Arr(vec![Json::Str(t.into()), Json::uint(v)]);
    let two = |t: &str, v: usize, s: bool| {
        Json::Arr(vec![Json::Str(t.into()), Json::uint(v), Json::Bool(s)])
    };
    match *ev {
        EngineEvent::PushArrived { xfer } => one("push", xfer),
        EngineEvent::FetchArrived { task, speculative } => two("fetch", task, speculative),
        EngineEvent::MapFinished { task, speculative } => two("map", task, speculative),
        EngineEvent::ShuffleArrived { xfer } => one("shuffle", xfer),
        EngineEvent::ReduceFinished { range } => one("reduce", range),
        EngineEvent::OutputWritten { range } => one("output", range),
    }
}

fn event_from_json(j: &Json) -> Result<EngineEvent, String> {
    let arr = j.as_arr()?;
    if arr.len() < 2 {
        return Err("event must be [tag, id, ...]".into());
    }
    let id = arr[1].as_usize()?;
    let spec = |arr: &[Json]| -> Result<bool, String> {
        arr.get(2)
            .ok_or_else(|| "event missing speculative flag".to_string())?
            .as_bool()
    };
    Ok(match arr[0].as_str()? {
        "push" => EngineEvent::PushArrived { xfer: id },
        "fetch" => EngineEvent::FetchArrived { task: id, speculative: spec(arr)? },
        "map" => EngineEvent::MapFinished { task: id, speculative: spec(arr)? },
        "shuffle" => EngineEvent::ShuffleArrived { xfer: id },
        "reduce" => EngineEvent::ReduceFinished { range: id },
        "output" => EngineEvent::OutputWritten { range: id },
        other => return Err(format!("unknown event tag `{other}`")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::barrier::BarrierConfig;
    use crate::platform::topology::example_1_3;
    use std::collections::HashMap;
    use crate::platform::MB;

    /// Identity app: passes records through unchanged (α = 1).
    struct Identity;
    impl MapReduceApp for Identity {
        fn name(&self) -> &'static str {
            "identity"
        }
        fn map(&self, record: &Record, emit: &mut dyn FnMut(Record)) {
            emit(record.clone());
        }
        fn reduce(&self, _group: &str, records: &[Record], emit: &mut dyn FnMut(Record)) {
            for r in records {
                emit(r.clone());
            }
        }
    }

    fn small_inputs(n_sources: usize, records_per_source: usize) -> Vec<Vec<Record>> {
        (0..n_sources)
            .map(|i| {
                (0..records_per_source)
                    .map(|r| Record::new(format!("key-{i}-{r}"), format!("value-{r}")))
                    .collect()
            })
            .collect()
    }

    fn topo() -> crate::platform::Topology {
        example_1_3(100.0 * MB, 10.0 * MB, 100.0 * MB)
    }

    #[test]
    fn identity_job_conserves_records() {
        let t = topo();
        let plan = Plan::uniform(2, 2, 2);
        let inputs = small_inputs(2, 500);
        let total: usize = inputs.iter().map(Vec::len).sum();
        let res = run_job(&t, &plan, &Identity, &JobConfig::default(), &inputs);
        assert_eq!(res.metrics.input_records, total);
        assert_eq!(res.metrics.intermediate_records, total);
        assert_eq!(res.metrics.output_records, total);
        let out_total: usize = res.outputs.iter().map(Vec::len).sum();
        assert_eq!(out_total, total);
        assert!(res.metrics.makespan > 0.0);
    }

    #[test]
    fn one_reducer_per_key_invariant() {
        let t = topo();
        let plan = Plan { x: crate::util::mat::Mat::filled(2, 2, 0.5), y: vec![0.3, 0.7] };
        let inputs = small_inputs(2, 400);
        let res = run_job(&t, &plan, &Identity, &JobConfig::default(), &inputs);
        // Every key must appear at exactly one reducer.
        let mut seen: HashMap<String, usize> = HashMap::new();
        for (k, recs) in res.outputs.iter().enumerate() {
            for r in recs {
                if let Some(prev) = seen.insert(r.key.clone(), k) {
                    assert_eq!(prev, k, "key {} split across reducers", r.key);
                }
            }
        }
    }

    #[test]
    fn local_push_plan_avoids_cross_traffic() {
        let t = topo();
        let local = Plan::local_push(&t);
        let uniform = Plan::uniform(2, 2, 2);
        let inputs = small_inputs(2, 800);
        let cfg = JobConfig::default();
        let m_local = run_job(&t, &local, &Identity, &cfg, &inputs).metrics;
        let m_uni = run_job(&t, &uniform, &Identity, &cfg, &inputs).metrics;
        // Local push must finish its push much faster (no slow links).
        assert!(
            m_local.push_end < m_uni.push_end * 0.5,
            "local push {} vs uniform {}",
            m_local.push_end,
            m_uni.push_end
        );
    }

    #[test]
    fn makespan_roughly_tracks_model() {
        // Engine vs closed-form model on the same instance: within 2×
        // either way (the engine adds NIC contention and slot queueing).
        let t = topo();
        let plan = Plan::uniform(2, 2, 2);
        let inputs = small_inputs(2, 1000);
        let cfg = JobConfig { barriers: BarrierConfig::ALL_GLOBAL, ..Default::default() };
        let res = run_job(&t, &plan, &Identity, &cfg, &inputs);
        // Scale the model to the actual input bytes.
        let total_bytes: f64 = inputs.iter().map(|v| batch_size(v) as f64).sum();
        let mut t2 = t.clone();
        for d in t2.d.iter_mut() {
            *d = total_bytes / 2.0;
        }
        let model_ms = crate::model::makespan::makespan(
            &t2,
            crate::model::makespan::AppModel::new(1.0),
            BarrierConfig::ALL_GLOBAL,
            &plan,
        );
        let ratio = res.metrics.makespan / model_ms;
        assert!(
            (0.5..2.0).contains(&ratio),
            "engine {} vs model {model_ms} (ratio {ratio})",
            res.metrics.makespan
        );
    }

    #[test]
    fn barriers_order_makespan() {
        let t = topo();
        let plan = Plan::uniform(2, 2, 2);
        let inputs = small_inputs(2, 600);
        let ggl = JobConfig {
            barriers: BarrierConfig::new(Barrier::Global, Barrier::Global, Barrier::Local),
            ..Default::default()
        };
        let ppl = JobConfig {
            barriers: BarrierConfig::new(Barrier::Pipelined, Barrier::Pipelined, Barrier::Local),
            ..Default::default()
        };
        let m_g = run_job(&t, &plan, &Identity, &ggl, &inputs).metrics;
        let m_p = run_job(&t, &plan, &Identity, &ppl, &inputs).metrics;
        assert!(
            m_p.makespan <= m_g.makespan * 1.001,
            "pipelined {} should not exceed global {}",
            m_p.makespan,
            m_g.makespan
        );
    }

    #[test]
    fn replication_slows_the_job() {
        let t = topo();
        let plan = Plan::local_push(&t);
        let inputs = small_inputs(2, 600);
        let r1 = JobConfig { replication: 1, ..Default::default() };
        let r3 = JobConfig { replication: 3, ..Default::default() };
        let m1 = run_job(&t, &plan, &Identity, &r1, &inputs).metrics;
        let m3 = run_job(&t, &plan, &Identity, &r3, &inputs).metrics;
        assert!(m3.push_bytes > 2.5 * m1.push_bytes);
        assert!(
            m3.makespan > m1.makespan,
            "replication should cost time: {} vs {}",
            m3.makespan,
            m1.makespan
        );
    }

    #[test]
    fn zero_fraction_reducer_unused() {
        let t = topo();
        let plan = Plan { x: crate::util::mat::Mat::filled(2, 2, 0.5), y: vec![1.0, 0.0] };
        let inputs = small_inputs(2, 300);
        let res = run_job(&t, &plan, &Identity, &JobConfig::default(), &inputs);
        assert!(res.outputs[1].is_empty());
        assert_eq!(
            res.outputs[0].len(),
            res.metrics.input_records
        );
    }

    /// Regression: under a Local map/shuffle barrier, outputs of tasks
    /// that executed away from their home node (stolen) must be released
    /// with their home cohort — not stranded unshuffled (which silently
    /// dropped records).
    #[test]
    fn local_map_shuffle_barrier_with_stealing_conserves_records() {
        let t = topo();
        // All data homed on mapper 0 → mapper 1 idles and must steal.
        let plan = Plan {
            x: crate::util::mat::Mat::from_rows(&[&[1.0, 0.0], &[1.0, 0.0]]),
            y: vec![0.5, 0.5],
        };
        let inputs = small_inputs(2, 600);
        let cfg = JobConfig {
            barriers: BarrierConfig::new(Barrier::Global, Barrier::Local, Barrier::Local),
            stealing: true,
            local_only: false,
            split_size: 4 << 10, // small splits → several tasks to steal
            ..Default::default()
        };
        let res = run_job(&t, &plan, &Identity, &cfg, &inputs);
        assert!(res.metrics.stolen > 0, "scenario must actually steal");
        assert_eq!(res.metrics.output_records, res.metrics.input_records);
    }

    #[test]
    fn speculation_and_stealing_smoke() {
        let t = topo();
        let plan = Plan::uniform(2, 2, 2);
        let inputs = small_inputs(2, 800);
        let cfg = JobConfig::vanilla_hadoop();
        let res = run_job(&t, &plan, &Identity, &cfg, &inputs);
        // Dynamic mechanisms must preserve correctness.
        assert_eq!(res.metrics.output_records, res.metrics.input_records);
        assert!(res.metrics.makespan > 0.0);
    }

    /// The event-driven core must run unchanged on a topology far bigger
    /// than the paper's environments (the ISSUE 1 scale substrate).
    #[test]
    fn runs_on_generated_64_node_topology() {
        let t = crate::platform::scale::generate_kind(
            crate::platform::scale::ScaleKind::HierarchicalWan,
            64,
            11,
        );
        let plan = Plan::local_push(&t);
        let inputs: Vec<Vec<Record>> = (0..t.n_sources())
            .map(|i| {
                (0..20)
                    .map(|r| Record::new(format!("k-{i}-{r}"), "v".repeat(24)))
                    .collect()
            })
            .collect();
        let res = run_job(&t, &plan, &Identity, &JobConfig::default(), &inputs);
        assert_eq!(res.metrics.output_records, res.metrics.input_records);
        assert!(res.metrics.makespan > 0.0);
    }
}
