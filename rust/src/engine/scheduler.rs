//! Pluggable map-task scheduling policies.
//!
//! The executor delegates every placement decision to a [`Scheduler`]:
//! given a read-only [`SchedView`] of the cluster (ready tasks, free
//! slots, queue depths, straggler timings), a policy returns
//! [`Assignment`]s. Two policies cover the paper's execution modes
//! (§4.6.1, §4.6.4):
//!
//! * [`PlanLocalScheduler`] — the statically enforced plan: each map task
//!   runs on the node its split was pushed to ("our optimization" rows of
//!   Figs 9–11).
//! * [`DynamicScheduler`] — vanilla-Hadoop-style dynamics: work stealing
//!   (idle nodes take queued work from the most-loaded node, paying a
//!   wide-area fetch) and speculative execution (a running task slower
//!   than `straggler_factor ×` the median completed duration gets a
//!   backup copy on the fastest free node).
//!
//! Contract: a scheduler must never assign more tasks to a node than it
//! has free slots. The executor additionally enforces this, and
//! tests/engine_props.rs property-tests it for every implementation.

use super::events::TaskId;
use super::job::JobConfig;

/// Node index (mapper id) in the topology.
pub type NodeId = usize;

/// One running map task as the scheduler sees it (only tasks without a
/// speculative copy are listed — one backup per task, like Hadoop).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RunningTask {
    pub task: TaskId,
    /// Node executing the primary copy.
    pub node: NodeId,
    /// Virtual time the primary copy started.
    pub started_at: f64,
}

/// Read-only scheduling snapshot handed to a [`Scheduler`].
#[derive(Debug, Clone, Copy)]
pub struct SchedView<'a> {
    /// Current virtual time.
    pub now: f64,
    /// Plan ("home") node of every task, indexed by [`TaskId`].
    pub home: &'a [NodeId],
    /// Tasks ready to run (input pushed, not yet placed), ascending id.
    pub ready: &'a [TaskId],
    /// Running tasks eligible for speculation, ascending id.
    pub running: &'a [RunningTask],
    /// Free map slots per node.
    pub free_slots: &'a [usize],
    /// Unfinished map tasks homed on each node (queue depth).
    pub queued: &'a [usize],
    /// Per-node compute capacity (input bytes/s).
    pub capacity: &'a [f64],
    /// Durations of completed map tasks, in completion order.
    pub durations: &'a [f64],
}

/// A placement decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    pub task: TaskId,
    pub node: NodeId,
    /// `true` for a backup copy of a running task (speculation), `false`
    /// for the first placement of a ready task.
    pub speculative: bool,
}

/// A map-task scheduling policy.
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;

    /// Choose placements for ready tasks. Per-node assignments must not
    /// exceed `view.free_slots`.
    fn assign(&mut self, view: &SchedView) -> Vec<Assignment>;

    /// Choose straggler backups from `view.running`. Same slot contract;
    /// the default launches none.
    fn speculate(&mut self, view: &SchedView) -> Vec<Assignment> {
        let _ = view;
        Vec::new()
    }

    /// Cheap pre-filter: can this policy speculate at all given the
    /// number of completed-duration samples? The executor skips building
    /// the running-set snapshot when `false`. Default mirrors
    /// [`Scheduler::speculate`]'s default of never speculating.
    fn may_speculate(&self, n_duration_samples: usize) -> bool {
        let _ = n_duration_samples;
        false
    }
}

/// Strict plan enforcement (§3.1.1 `LocalOnly`): a ready task runs on its
/// home node as soon as a slot frees, and nowhere else.
pub struct PlanLocalScheduler;

impl Scheduler for PlanLocalScheduler {
    fn name(&self) -> &'static str {
        "plan-local"
    }

    fn assign(&mut self, view: &SchedView) -> Vec<Assignment> {
        let mut free = view.free_slots.to_vec();
        let mut out = Vec::new();
        for &task in view.ready {
            let node = view.home[task];
            if free[node] > 0 {
                free[node] -= 1;
                out.push(Assignment { task, node, speculative: false });
            }
        }
        out
    }
}

/// Hadoop-style dynamic mechanisms (§4.6.4): plan-local placement first,
/// then optional work stealing and speculative backups.
pub struct DynamicScheduler {
    pub stealing: bool,
    pub speculation: bool,
    /// Straggler threshold as a multiple of the median completed-task
    /// duration (Hadoop's heuristic; 1.5 in the paper's runs).
    pub straggler_factor: f64,
    /// Completed-duration samples required before speculation engages.
    pub min_samples: usize,
}

impl DynamicScheduler {
    pub fn new(stealing: bool, speculation: bool) -> DynamicScheduler {
        DynamicScheduler { stealing, speculation, straggler_factor: 1.5, min_samples: 3 }
    }
}

impl Scheduler for DynamicScheduler {
    fn name(&self) -> &'static str {
        "dynamic"
    }

    fn assign(&mut self, view: &SchedView) -> Vec<Assignment> {
        let mut free = view.free_slots.to_vec();
        let mut out = Vec::new();
        // Plan-local placements first.
        let mut waiting: Vec<TaskId> = Vec::new();
        for &task in view.ready {
            let node = view.home[task];
            if free[node] > 0 {
                free[node] -= 1;
                out.push(Assignment { task, node, speculative: false });
            } else {
                waiting.push(task);
            }
        }
        if !self.stealing {
            return out;
        }
        // Work stealing: an idle node with no local queued work takes a
        // waiting task from the most-loaded node; the executor charges
        // the wide-area fetch of the split.
        let n_nodes = view.free_slots.len();
        loop {
            let mut stole = false;
            for thief in 0..n_nodes {
                if free[thief] == 0 {
                    continue;
                }
                // Defensive: a waiting task homed here implies this
                // node's slots were exhausted in the plan-local pass, so
                // with monotonically decreasing `free` this cannot
                // trigger today — kept to preserve the policy's intent
                // (idle nodes defer to local work) if placement order
                // ever changes.
                if waiting.iter().any(|&t| view.home[t] == thief) {
                    continue;
                }
                let victim = waiting
                    .iter()
                    .enumerate()
                    .filter(|&(_, &t)| view.home[t] != thief)
                    .max_by(|a, b| {
                        let qa = view.queued[view.home[*a.1]];
                        let qb = view.queued[view.home[*b.1]];
                        qa.cmp(&qb)
                    })
                    .map(|(idx, _)| idx);
                if let Some(idx) = victim {
                    let task = waiting.remove(idx);
                    free[thief] -= 1;
                    out.push(Assignment { task, node: thief, speculative: false });
                    stole = true;
                }
            }
            if !stole {
                break;
            }
        }
        out
    }

    fn may_speculate(&self, n_duration_samples: usize) -> bool {
        self.speculation && n_duration_samples >= self.min_samples
    }

    fn speculate(&mut self, view: &SchedView) -> Vec<Assignment> {
        if !self.speculation || view.durations.len() < self.min_samples {
            return Vec::new();
        }
        let mut ds = view.durations.to_vec();
        ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = ds[ds.len() / 2];
        let mut free = view.free_slots.to_vec();
        let mut out = Vec::new();
        for rt in view.running {
            if view.now - rt.started_at <= self.straggler_factor * median {
                continue;
            }
            // Fastest node with a free slot, other than the executor.
            let candidate = (0..free.len())
                .filter(|&n| n != rt.node && free[n] > 0)
                .max_by(|&a, &b| view.capacity[a].partial_cmp(&view.capacity[b]).unwrap());
            if let Some(node) = candidate {
                free[node] -= 1;
                out.push(Assignment { task: rt.task, node, speculative: true });
            }
        }
        out
    }
}

/// The scheduler implied by a [`JobConfig`] (§4.6.1 presets): strict plan
/// enforcement unless dynamic mechanisms are enabled.
pub fn for_config(config: &JobConfig) -> Box<dyn Scheduler> {
    let stealing = config.stealing && !config.local_only;
    if stealing || config.speculation {
        Box::new(DynamicScheduler::new(stealing, config.speculation))
    } else {
        Box::new(PlanLocalScheduler)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view<'a>(
        home: &'a [NodeId],
        ready: &'a [TaskId],
        running: &'a [RunningTask],
        free_slots: &'a [usize],
        queued: &'a [usize],
        capacity: &'a [f64],
        durations: &'a [f64],
        now: f64,
    ) -> SchedView<'a> {
        SchedView { now, home, ready, running, free_slots, queued, capacity, durations }
    }

    #[test]
    fn plan_local_respects_home_and_slots() {
        let home = [0, 0, 1];
        let ready = [0, 1, 2];
        let free = [1, 1];
        let queued = [2, 1];
        let cap = [1.0, 1.0];
        let v = view(&home, &ready, &[], &free, &queued, &cap, &[], 0.0);
        let a = PlanLocalScheduler.assign(&v);
        // Only one slot on node 0: task 0 runs, task 1 waits, task 2 runs.
        assert_eq!(
            a,
            vec![
                Assignment { task: 0, node: 0, speculative: false },
                Assignment { task: 2, node: 1, speculative: false },
            ]
        );
    }

    #[test]
    fn stealing_moves_work_to_idle_nodes() {
        // Node 1 has no local work and a free slot; node 0 is overloaded.
        let home = [0, 0, 0];
        let ready = [0, 1, 2];
        let free = [1, 1];
        let queued = [3, 0];
        let cap = [1.0, 1.0];
        let v = view(&home, &ready, &[], &free, &queued, &cap, &[], 0.0);
        let a = DynamicScheduler::new(true, false).assign(&v);
        assert_eq!(a.len(), 2);
        assert_eq!(a[0], Assignment { task: 0, node: 0, speculative: false });
        // One of the remaining tasks is stolen by node 1.
        assert_eq!(a[1].node, 1);
        assert!(!a[1].speculative);
    }

    #[test]
    fn no_stealing_when_disabled() {
        let home = [0, 0];
        let ready = [0, 1];
        let free = [1, 1];
        let queued = [2, 0];
        let cap = [1.0, 1.0];
        let v = view(&home, &ready, &[], &free, &queued, &cap, &[], 0.0);
        let a = DynamicScheduler::new(false, false).assign(&v);
        assert_eq!(a.len(), 1, "second task must wait for its home node");
    }

    #[test]
    fn speculation_targets_stragglers_on_fastest_node() {
        let home = [0, 1];
        let running = [RunningTask { task: 0, node: 0, started_at: 0.0 }];
        let free = [0, 1, 1];
        let queued = [1, 0, 0];
        let cap = [1.0, 5.0, 9.0];
        let durations = [1.0, 1.0, 1.0];
        let v = view(&home, &[], &running, &free, &queued, &cap, &durations, 10.0);
        let a = DynamicScheduler::new(false, true).speculate(&v);
        assert_eq!(a, vec![Assignment { task: 0, node: 2, speculative: true }]);
    }

    #[test]
    fn speculation_waits_for_samples_and_threshold() {
        let home = [0];
        let running = [RunningTask { task: 0, node: 0, started_at: 0.0 }];
        let free = [0, 1];
        let queued = [1, 0];
        let cap = [1.0, 5.0];
        // Too few samples.
        let v = view(&home, &[], &running, &free, &queued, &cap, &[9.0, 9.0], 10.0);
        assert!(DynamicScheduler::new(false, true).speculate(&v).is_empty());
        // Enough samples but the task is not (yet) a straggler.
        let durations = [9.0, 9.0, 9.0];
        let v = view(&home, &[], &running, &free, &queued, &cap, &durations, 10.0);
        assert!(DynamicScheduler::new(false, true).speculate(&v).is_empty());
    }

    #[test]
    fn for_config_selects_policy() {
        use crate::engine::job::JobConfig;
        assert_eq!(for_config(&JobConfig::optimized()).name(), "plan-local");
        assert_eq!(for_config(&JobConfig::vanilla_hadoop()).name(), "dynamic");
        // Speculation alone also needs the dynamic policy.
        let cfg = JobConfig { speculation: true, ..JobConfig::default() };
        assert_eq!(for_config(&cfg).name(), "dynamic");
    }
}
