//! Pluggable map-task scheduling policies.
//!
//! The executor delegates every placement decision to a [`Scheduler`]:
//! given a read-only [`SchedView`] of the cluster (ready tasks, free
//! slots, queue depths, straggler timings), a policy returns
//! [`Assignment`]s. Three policy families cover the execution modes
//! (§4.6.1, §4.6.4, and online re-optimization):
//!
//! * [`PlanLocalScheduler`] — the statically enforced plan: each map task
//!   runs on the node its split was pushed to ("our optimization" rows of
//!   Figs 9–11).
//! * [`DynamicScheduler`] — vanilla-Hadoop-style dynamics: work stealing
//!   (idle nodes take queued work from the most-loaded node, paying a
//!   wide-area fetch) and speculative execution (a running task slower
//!   than `straggler_factor ×` the median completed duration gets a
//!   backup copy on the fastest free node).
//! * [`ReplanScheduler`] — plan enforcement against a *moving* plan
//!   (`--replan`, [`super::replan`]): follows each task's current home,
//!   which an accepted mid-run re-solve may have migrated. No stealing,
//!   no speculation — placement changes only when the re-solved plan
//!   says so, which is what makes the replan experiment's comparison
//!   against the dynamic family meaningful.
//!
//! Contract: a scheduler must never assign more tasks to a node than it
//! has free slots. The executor additionally enforces this, and
//! tests/engine_props.rs property-tests it for every implementation.
//!
//! # Example
//!
//! Policies are plain values over a read-only snapshot — no engine
//! required to exercise one:
//!
//! ```
//! use mrperf::engine::scheduler::{PlanLocalScheduler, SchedView, Scheduler};
//!
//! // Two tasks, homed on nodes 0 and 1; node 1 has no free slot.
//! let view = SchedView {
//!     now: 0.0,
//!     home: &[0, 1],
//!     ready: &[0, 1],
//!     running: &[],
//!     free_slots: &[1, 0],
//!     queued: &[1, 1],
//!     capacity: &[1.0, 1.0],
//!     durations: &[],
//!     cluster: &[0, 0],
//!     up: &[true, true],
//! };
//! let placed = PlanLocalScheduler.assign(&view);
//! // Strict plan enforcement: task 0 runs at home, task 1 must wait.
//! assert_eq!(placed.len(), 1);
//! assert_eq!((placed[0].task, placed[0].node), (0, 0));
//! ```

use super::events::TaskId;
use super::job::JobConfig;

/// Node index (mapper id) in the topology.
pub type NodeId = usize;

/// One running map task as the scheduler sees it (only tasks without a
/// speculative copy are listed — one backup per task, like Hadoop).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RunningTask {
    pub task: TaskId,
    /// Node executing the primary copy.
    pub node: NodeId,
    /// Virtual time the primary copy started.
    pub started_at: f64,
}

/// Read-only scheduling snapshot handed to a [`Scheduler`].
#[derive(Debug, Clone, Copy)]
pub struct SchedView<'a> {
    /// Current virtual time.
    pub now: f64,
    /// Plan ("home") node of every task, indexed by [`TaskId`].
    pub home: &'a [NodeId],
    /// Tasks ready to run (input pushed, not yet placed), ascending id.
    pub ready: &'a [TaskId],
    /// Running tasks eligible for speculation, ascending id.
    pub running: &'a [RunningTask],
    /// Free map slots per node.
    pub free_slots: &'a [usize],
    /// Unfinished map tasks homed on each node (queue depth).
    pub queued: &'a [usize],
    /// Per-node compute capacity (input bytes/s).
    pub capacity: &'a [f64],
    /// Durations of completed map tasks, in completion order.
    pub durations: &'a [f64],
    /// Cluster (data-center site) of each node — the locality signal: an
    /// intra-cluster steal re-fetches the split over the LAN, a
    /// cross-cluster one pays a WAN transfer.
    pub cluster: &'a [usize],
    /// Liveness of each node (fault injection, [`super::dynamics`]). A
    /// down node always shows zero free slots; `up` additionally lets
    /// policies prioritize work *homed* on dead nodes, which cannot run
    /// in place until the node recovers.
    pub up: &'a [bool],
}

/// Read-only snapshot for re-partitioning a dead reducer's outstanding
/// key range (restartable reduce). All slices are indexed by physical
/// reducer id.
#[derive(Debug, Clone, Copy)]
pub struct ReduceView<'a> {
    /// The failed reducer whose key range needs a new home.
    pub dead: NodeId,
    /// Liveness of each reducer.
    pub up: &'a [bool],
    /// Cluster (data-center site) of each reducer — the locality signal:
    /// adopting within the dead reducer's cluster keeps the replayed
    /// shuffle re-fetch mostly on the LAN.
    pub cluster: &'a [usize],
    /// *Current effective* reducer compute capacity (input bytes/s) —
    /// the executor passes the live fluid-sim rates, so an actively
    /// slowed straggler doesn't win an adoption on its nominal speed.
    pub capacity: &'a [f64],
    /// Outstanding (not yet reduced) shuffle bytes currently assigned to
    /// each reducer — own range plus ranges already adopted. Lets a
    /// policy spread successive adoptions instead of piling every
    /// orphaned range on one survivor.
    pub assigned_bytes: &'a [f64],
}

/// A placement decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    pub task: TaskId,
    pub node: NodeId,
    /// `true` for a backup copy of a running task (speculation), `false`
    /// for the first placement of a ready task.
    pub speculative: bool,
}

/// A map-task scheduling policy.
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;

    /// Choose placements for ready tasks. Per-node assignments must not
    /// exceed `view.free_slots`.
    fn assign(&mut self, view: &SchedView) -> Vec<Assignment>;

    /// Choose straggler backups from `view.running`. Same slot contract;
    /// the default launches none.
    fn speculate(&mut self, view: &SchedView) -> Vec<Assignment> {
        let _ = view;
        Vec::new()
    }

    /// Cheap pre-filter: can this policy speculate at all given the
    /// number of completed-duration samples? The executor skips building
    /// the running-set snapshot when `false`. Default mirrors
    /// [`Scheduler::speculate`]'s default of never speculating.
    fn may_speculate(&self, n_duration_samples: usize) -> bool {
        let _ = n_duration_samples;
        false
    }

    /// Pick a surviving reducer to adopt a dead reducer's outstanding key
    /// range, or `None` to leave the range waiting for recovery. The
    /// executor then replays the lost shuffle transfers to the returned
    /// node and re-runs the range's reduce there. The default — strict
    /// plan enforcement — declines: the paper's statically enforced plans
    /// have no recovery path, which is exactly the fragility the hedged
    /// optimizer prices in.
    fn reassign_reduce(&mut self, view: &ReduceView) -> Option<NodeId> {
        let _ = view;
        None
    }
}

/// Strict plan enforcement (§3.1.1 `LocalOnly`): a ready task runs on its
/// home node as soon as a slot frees, and nowhere else.
pub struct PlanLocalScheduler;

impl Scheduler for PlanLocalScheduler {
    fn name(&self) -> &'static str {
        "plan-local"
    }

    fn assign(&mut self, view: &SchedView) -> Vec<Assignment> {
        let mut free = view.free_slots.to_vec();
        let mut out = Vec::new();
        for &task in view.ready {
            let node = view.home[task];
            if free[node] > 0 {
                free[node] -= 1;
                out.push(Assignment { task, node, speculative: false });
            }
        }
        out
    }
}

/// Plan enforcement against a *moving* plan (online re-optimization,
/// [`super::replan`]): place every ready task on its **current** home —
/// the plan node from the original solve, or wherever the latest
/// accepted re-solve migrated it while the task was still waiting for
/// data. Like [`PlanLocalScheduler`] it never steals, never speculates,
/// and declines reduce adoptions (an orphaned range waits for recovery
/// unless a re-solve migrates it before any of its bytes exist); unlike
/// it, the home it follows is not a constant. With `--replan off` the
/// executor never constructs this policy, so the static path is
/// untouched.
pub struct ReplanScheduler;

impl Scheduler for ReplanScheduler {
    fn name(&self) -> &'static str {
        "replan"
    }

    fn assign(&mut self, view: &SchedView) -> Vec<Assignment> {
        let mut free = view.free_slots.to_vec();
        let mut out = Vec::new();
        for &task in view.ready {
            let node = view.home[task];
            if free[node] > 0 {
                free[node] -= 1;
                out.push(Assignment { task, node, speculative: false });
            }
        }
        out
    }
}

/// Hadoop-style dynamic mechanisms (§4.6.4): plan-local placement first,
/// then optional work stealing and speculative backups.
///
/// With `locality` enabled the stealing pass becomes **locality-aware**:
/// a thief prefers victims homed in its own cluster (the split re-fetch
/// stays on the LAN) and falls back to a cross-cluster (WAN) steal only
/// when the remote backlog justifies the penalty — the victim's home
/// node is down, or its queue depth is at least `wan_steal_min_queue`.
/// Speculative backups likewise prefer a node in the straggler's home
/// cluster. With `locality` off, behavior is the historical
/// cluster-oblivious policy, bit-for-bit.
pub struct DynamicScheduler {
    pub stealing: bool,
    pub speculation: bool,
    /// Straggler threshold as a multiple of the median completed-task
    /// duration (Hadoop's heuristic; 1.5 in the paper's runs).
    pub straggler_factor: f64,
    /// Completed-duration samples required before speculation engages.
    pub min_samples: usize,
    /// Locality-aware stealing (prefer same-cluster victims, WAN only
    /// when justified).
    pub locality: bool,
    /// Minimum queue depth at an *up* remote home before a cross-cluster
    /// steal is worth the WAN fetch (locality mode only). Work homed on
    /// a down node is always stealable — it cannot run anywhere else.
    pub wan_steal_min_queue: usize,
}

impl DynamicScheduler {
    pub fn new(stealing: bool, speculation: bool) -> DynamicScheduler {
        DynamicScheduler {
            stealing,
            speculation,
            straggler_factor: 1.5,
            min_samples: 3,
            locality: false,
            wan_steal_min_queue: 2,
        }
    }

    /// Enable locality-aware stealing (builder style).
    pub fn with_locality(mut self) -> DynamicScheduler {
        self.locality = true;
        self
    }

    /// Pick the best victim among `waiting` for `thief`, restricted by
    /// `eligible`. Prefers victims whose home node is down (that work is
    /// stranded), then the deepest home queue; ties resolve to the
    /// lowest waiting-list index for determinism.
    fn best_victim(
        &self,
        view: &SchedView,
        waiting: &[TaskId],
        thief: NodeId,
        eligible: impl Fn(TaskId) -> bool,
    ) -> Option<usize> {
        let mut best: Option<(bool, usize, usize)> = None; // (down, depth, idx)
        for (idx, &t) in waiting.iter().enumerate() {
            if view.home[t] == thief || !eligible(t) {
                continue;
            }
            let down = !view.up[view.home[t]];
            let depth = view.queued[view.home[t]];
            let better = match best {
                None => true,
                Some((bd, bq, _)) => (down, depth) > (bd, bq),
            };
            if better {
                best = Some((down, depth, idx));
            }
        }
        best.map(|(_, _, idx)| idx)
    }
}

impl Scheduler for DynamicScheduler {
    fn name(&self) -> &'static str {
        if self.locality {
            "dynamic-locality"
        } else {
            "dynamic"
        }
    }

    fn assign(&mut self, view: &SchedView) -> Vec<Assignment> {
        let mut free = view.free_slots.to_vec();
        let mut out = Vec::new();
        // Plan-local placements first.
        let mut waiting: Vec<TaskId> = Vec::new();
        for &task in view.ready {
            let node = view.home[task];
            if free[node] > 0 {
                free[node] -= 1;
                out.push(Assignment { task, node, speculative: false });
            } else {
                waiting.push(task);
            }
        }
        if !self.stealing {
            return out;
        }
        // Work stealing: an idle node with no local queued work takes a
        // waiting task from another node; the executor charges the fetch
        // of the split over the corresponding link.
        let n_nodes = view.free_slots.len();
        loop {
            let mut stole = false;
            for thief in 0..n_nodes {
                if free[thief] == 0 {
                    continue;
                }
                // Defensive: a waiting task homed here implies this
                // node's slots were exhausted in the plan-local pass, so
                // with monotonically decreasing `free` this cannot
                // trigger today — kept to preserve the policy's intent
                // (idle nodes defer to local work) if placement order
                // ever changes.
                if waiting.iter().any(|&t| view.home[t] == thief) {
                    continue;
                }
                let victim = if self.locality {
                    // Same-cluster victims first (LAN re-fetch); WAN only
                    // when the remote work is stranded (home down) or the
                    // backlog clears the penalty threshold.
                    self.best_victim(view, &waiting, thief, |t| {
                        view.cluster[view.home[t]] == view.cluster[thief]
                    })
                    .or_else(|| {
                        self.best_victim(view, &waiting, thief, |t| {
                            view.cluster[view.home[t]] != view.cluster[thief]
                                && (!view.up[view.home[t]]
                                    || view.queued[view.home[t]] >= self.wan_steal_min_queue)
                        })
                    })
                } else {
                    // Historical cluster-oblivious policy: deepest queue.
                    waiting
                        .iter()
                        .enumerate()
                        .filter(|&(_, &t)| view.home[t] != thief)
                        .max_by(|a, b| {
                            let qa = view.queued[view.home[*a.1]];
                            let qb = view.queued[view.home[*b.1]];
                            qa.cmp(&qb)
                        })
                        .map(|(idx, _)| idx)
                };
                if let Some(idx) = victim {
                    let task = waiting.remove(idx);
                    free[thief] -= 1;
                    out.push(Assignment { task, node: thief, speculative: false });
                    stole = true;
                }
            }
            if !stole {
                break;
            }
        }
        out
    }

    fn may_speculate(&self, n_duration_samples: usize) -> bool {
        self.speculation && n_duration_samples >= self.min_samples
    }

    fn speculate(&mut self, view: &SchedView) -> Vec<Assignment> {
        if !self.speculation || view.durations.len() < self.min_samples {
            return Vec::new();
        }
        let mut ds = view.durations.to_vec();
        // total_cmp: durations come from the virtual clock and should be
        // finite, but a degenerate input must not panic the sort.
        ds.sort_by(f64::total_cmp);
        let median = ds[ds.len() / 2];
        let mut free = view.free_slots.to_vec();
        let mut out = Vec::new();
        for rt in view.running {
            if view.now - rt.started_at <= self.straggler_factor * median {
                continue;
            }
            // Fastest node with a free slot, other than the executor; in
            // locality mode a node in the straggler's home cluster wins
            // first (the backup's re-fetch stays on the LAN).
            let home_cluster = view.cluster[view.home[rt.task]];
            let candidate = (0..free.len())
                .filter(|&n| n != rt.node && free[n] > 0)
                .max_by(|&a, &b| {
                    if self.locality {
                        let la = view.cluster[a] == home_cluster;
                        let lb = view.cluster[b] == home_cluster;
                        if la != lb {
                            return la.cmp(&lb);
                        }
                    }
                    view.capacity[a].total_cmp(&view.capacity[b])
                });
            if let Some(node) = candidate {
                free[node] -= 1;
                out.push(Assignment { task: rt.task, node, speculative: true });
            }
        }
        out
    }

    /// Adopt the orphaned range on a survivor: in locality mode a
    /// reducer in the dead node's cluster wins first (the replayed
    /// re-fetch stays on the LAN); within the preferred group the
    /// least-loaded survivor is chosen, then the fastest, then the lowest
    /// index for determinism. Stealing-disabled configurations keep the
    /// plan-enforcing behavior (wait for recovery).
    fn reassign_reduce(&mut self, view: &ReduceView) -> Option<NodeId> {
        if !self.stealing {
            return None;
        }
        (0..view.up.len())
            .filter(|&k| k != view.dead && view.up[k])
            .min_by(|&a, &b| {
                if self.locality {
                    let la = view.cluster[a] == view.cluster[view.dead];
                    let lb = view.cluster[b] == view.cluster[view.dead];
                    if la != lb {
                        // Same-cluster survivors sort first.
                        return lb.cmp(&la);
                    }
                }
                view.assigned_bytes[a]
                    .total_cmp(&view.assigned_bytes[b])
                    .then(view.capacity[b].total_cmp(&view.capacity[a]))
                    .then(a.cmp(&b))
            })
    }
}

/// The scheduler implied by a [`JobConfig`] (§4.6.1 presets): strict plan
/// enforcement unless dynamic mechanisms are enabled; locality-aware
/// stealing when the config asks for it; the replan family whenever
/// online re-optimization is on (the CLI rejects combining `--replan`
/// with stealing/speculation, so the branches are disjoint there).
pub fn for_config(config: &JobConfig) -> Box<dyn Scheduler> {
    if config.replan.enabled() {
        return Box::new(ReplanScheduler);
    }
    let stealing = (config.stealing || config.locality_stealing) && !config.local_only;
    if stealing || config.speculation {
        let mut s = DynamicScheduler::new(stealing, config.speculation);
        if config.locality_stealing {
            s = s.with_locality();
        }
        Box::new(s)
    } else {
        Box::new(PlanLocalScheduler)
    }
}

// ------------------------------------------------ cross-job policies

/// One job waiting in the tenancy layer's admission queue.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueuedJob {
    /// Stream index of the job (what [`StreamDecision`] refers to).
    pub job: usize,
    /// Arrival (submission) virtual time.
    pub arrival: f64,
    /// Fair-share weight (scales the job's slot capacities).
    pub weight: f64,
    /// Completion deadline in absolute virtual time
    /// (`f64::INFINITY` = none).
    pub deadline: f64,
    /// Estimated standalone service time (calibration run).
    pub est_service: f64,
}

/// Snapshot of the stream state a policy decides over.
#[derive(Debug, Clone, Copy)]
pub struct StreamView<'a> {
    /// Current virtual time.
    pub now: f64,
    /// Jobs submitted but neither admitted nor rejected, in
    /// (arrival, stream index) order.
    pub queued: &'a [QueuedJob],
    /// Jobs currently executing.
    pub running: usize,
}

/// A cross-job admission decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamDecision {
    /// Start the job (by stream index) now.
    Admit(usize),
    /// Drop the job without running it (deadline-aware admission
    /// control); a rejected job counts against goodput.
    Reject(usize),
}

/// A cross-job scheduling policy: consulted by the tenancy engine
/// whenever the queue or the running set changes (arrivals and job
/// completions). The engine enforces the contract — decisions about
/// jobs not currently queued are ignored, so a policy bug cannot
/// double-admit.
pub trait StreamPolicy: Send {
    fn name(&self) -> &'static str;
    fn decide(&mut self, view: &StreamView) -> Vec<StreamDecision>;
}

/// FIFO: one job at a time, in arrival order — the M/G/1 baseline whose
/// latency knee the tenancy experiment is built to expose.
#[derive(Debug, Default)]
pub struct FifoStream;

impl StreamPolicy for FifoStream {
    fn name(&self) -> &'static str {
        "fifo"
    }
    fn decide(&mut self, view: &StreamView) -> Vec<StreamDecision> {
        if view.running == 0 {
            view.queued.first().map(|q| StreamDecision::Admit(q.job)).into_iter().collect()
        } else {
            Vec::new()
        }
    }
}

/// Fair share: admit up to `max_inflight` concurrent jobs in arrival
/// order. Concurrent jobs contend in the shared fluid network (max-min
/// fair at every link/NIC/CPU); per-job weights materialize as scaled
/// slot capacities at admission.
#[derive(Debug)]
pub struct FairShareStream {
    pub max_inflight: usize,
}

impl Default for FairShareStream {
    fn default() -> Self {
        FairShareStream { max_inflight: 4 }
    }
}

impl StreamPolicy for FairShareStream {
    fn name(&self) -> &'static str {
        "fair-share"
    }
    fn decide(&mut self, view: &StreamView) -> Vec<StreamDecision> {
        let room = self.max_inflight.saturating_sub(view.running);
        view.queued.iter().take(room).map(|q| StreamDecision::Admit(q.job)).collect()
    }
}

/// Deadline-aware admission control: walk the queue in arrival order
/// and admit a job only if its estimated finish — `now + est_service ×
/// (jobs that would then be in flight)`, a processor-sharing slowdown
/// estimate — meets its deadline; otherwise reject it outright rather
/// than let it burn shared bandwidth on a miss.
#[derive(Debug, Default)]
pub struct DeadlineStream;

impl StreamPolicy for DeadlineStream {
    fn name(&self) -> &'static str {
        "deadline"
    }
    fn decide(&mut self, view: &StreamView) -> Vec<StreamDecision> {
        let mut out = Vec::new();
        let mut admitted = 0usize;
        for q in view.queued {
            let inflight = (view.running + admitted + 1) as f64;
            let est_finish = view.now + q.est_service * inflight;
            if q.deadline.is_finite() && est_finish > q.deadline {
                out.push(StreamDecision::Reject(q.job));
            } else {
                out.push(StreamDecision::Admit(q.job));
                admitted += 1;
            }
        }
        out
    }
}

/// Look up a cross-job policy by CLI name.
pub fn stream_policy(name: &str) -> Result<Box<dyn StreamPolicy>, String> {
    match name {
        "fifo" => Ok(Box::new(FifoStream)),
        "fair-share" => Ok(Box::new(FairShareStream::default())),
        "deadline" => Ok(Box::new(DeadlineStream)),
        other => Err(format!(
            "unknown stream policy '{other}' (expected fifo | fair-share | deadline)"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// All nodes in one cluster, all up (the pre-dynamics default).
    fn view<'a>(
        home: &'a [NodeId],
        ready: &'a [TaskId],
        running: &'a [RunningTask],
        free_slots: &'a [usize],
        queued: &'a [usize],
        capacity: &'a [f64],
        durations: &'a [f64],
        now: f64,
    ) -> SchedView<'a> {
        let n = free_slots.len();
        SchedView {
            now,
            home,
            ready,
            running,
            free_slots,
            queued,
            capacity,
            durations,
            cluster: &ONE_CLUSTER[..n],
            up: &ALL_UP[..n],
        }
    }

    const ONE_CLUSTER: [usize; 16] = [0; 16];
    const ALL_UP: [bool; 16] = [true; 16];

    #[test]
    fn plan_local_respects_home_and_slots() {
        let home = [0, 0, 1];
        let ready = [0, 1, 2];
        let free = [1, 1];
        let queued = [2, 1];
        let cap = [1.0, 1.0];
        let v = view(&home, &ready, &[], &free, &queued, &cap, &[], 0.0);
        let a = PlanLocalScheduler.assign(&v);
        // Only one slot on node 0: task 0 runs, task 1 waits, task 2 runs.
        assert_eq!(
            a,
            vec![
                Assignment { task: 0, node: 0, speculative: false },
                Assignment { task: 2, node: 1, speculative: false },
            ]
        );
    }

    #[test]
    fn stealing_moves_work_to_idle_nodes() {
        // Node 1 has no local work and a free slot; node 0 is overloaded.
        let home = [0, 0, 0];
        let ready = [0, 1, 2];
        let free = [1, 1];
        let queued = [3, 0];
        let cap = [1.0, 1.0];
        let v = view(&home, &ready, &[], &free, &queued, &cap, &[], 0.0);
        let a = DynamicScheduler::new(true, false).assign(&v);
        assert_eq!(a.len(), 2);
        assert_eq!(a[0], Assignment { task: 0, node: 0, speculative: false });
        // One of the remaining tasks is stolen by node 1.
        assert_eq!(a[1].node, 1);
        assert!(!a[1].speculative);
    }

    #[test]
    fn no_stealing_when_disabled() {
        let home = [0, 0];
        let ready = [0, 1];
        let free = [1, 1];
        let queued = [2, 0];
        let cap = [1.0, 1.0];
        let v = view(&home, &ready, &[], &free, &queued, &cap, &[], 0.0);
        let a = DynamicScheduler::new(false, false).assign(&v);
        assert_eq!(a.len(), 1, "second task must wait for its home node");
    }

    #[test]
    fn speculation_targets_stragglers_on_fastest_node() {
        let home = [0, 1];
        let running = [RunningTask { task: 0, node: 0, started_at: 0.0 }];
        let free = [0, 1, 1];
        let queued = [1, 0, 0];
        let cap = [1.0, 5.0, 9.0];
        let durations = [1.0, 1.0, 1.0];
        let v = view(&home, &[], &running, &free, &queued, &cap, &durations, 10.0);
        let a = DynamicScheduler::new(false, true).speculate(&v);
        assert_eq!(a, vec![Assignment { task: 0, node: 2, speculative: true }]);
    }

    #[test]
    fn speculation_waits_for_samples_and_threshold() {
        let home = [0];
        let running = [RunningTask { task: 0, node: 0, started_at: 0.0 }];
        let free = [0, 1];
        let queued = [1, 0];
        let cap = [1.0, 5.0];
        // Too few samples.
        let v = view(&home, &[], &running, &free, &queued, &cap, &[9.0, 9.0], 10.0);
        assert!(DynamicScheduler::new(false, true).speculate(&v).is_empty());
        // Enough samples but the task is not (yet) a straggler.
        let durations = [9.0, 9.0, 9.0];
        let v = view(&home, &[], &running, &free, &queued, &cap, &durations, 10.0);
        assert!(DynamicScheduler::new(false, true).speculate(&v).is_empty());
    }

    #[test]
    fn for_config_selects_policy() {
        use crate::engine::job::JobConfig;
        assert_eq!(for_config(&JobConfig::optimized()).name(), "plan-local");
        assert_eq!(for_config(&JobConfig::vanilla_hadoop()).name(), "dynamic");
        // Speculation alone also needs the dynamic policy.
        let cfg = JobConfig { speculation: true, ..JobConfig::default() };
        assert_eq!(for_config(&cfg).name(), "dynamic");
        // Locality-aware stealing selects the locality variant (and
        // implies stealing).
        let cfg = JobConfig {
            locality_stealing: true,
            local_only: false,
            ..JobConfig::default()
        };
        assert_eq!(for_config(&cfg).name(), "dynamic-locality");
        // Online re-optimization selects the third family.
        use crate::engine::replan::ReplanPolicy;
        let cfg = JobConfig { replan: ReplanPolicy::OnEvent, ..JobConfig::optimized() };
        assert_eq!(for_config(&cfg).name(), "replan");
        let cfg = JobConfig { replan: ReplanPolicy::Every(2.0), ..JobConfig::optimized() };
        assert_eq!(for_config(&cfg).name(), "replan");
    }

    #[test]
    fn replan_scheduler_follows_the_current_home() {
        // Task 1's home was migrated to node 1 by a re-solve; the policy
        // follows the view's home slice, wherever it points today.
        let home = [0, 1];
        let ready = [0, 1];
        let free = [1, 1];
        let queued = [1, 1];
        let cap = [1.0, 1.0];
        let v = view(&home, &ready, &[], &free, &queued, &cap, &[], 0.0);
        let a = ReplanScheduler.assign(&v);
        assert_eq!(
            a,
            vec![
                Assignment { task: 0, node: 0, speculative: false },
                Assignment { task: 1, node: 1, speculative: false },
            ]
        );
        // No speculation, no reduce adoption — plan enforcement.
        assert!(ReplanScheduler.speculate(&v).is_empty());
        let rv = ReduceView {
            dead: 0,
            up: &[false, true],
            cluster: &[0, 0],
            capacity: &[1.0, 1.0],
            assigned_bytes: &[0.0, 0.0],
        };
        assert_eq!(ReplanScheduler.reassign_reduce(&rv), None);
    }

    #[test]
    fn locality_prefers_same_cluster_victims() {
        // Nodes 0,1 in cluster 0; node 2 in cluster 1. All three tasks
        // homed on node 0; node 0 has one slot.
        let home = [0, 0, 0];
        let ready = [0, 1, 2];
        let free = [1, 1, 1];
        let queued = [3, 0, 0];
        let cap = [1.0, 1.0, 1.0];
        let cluster = [0, 0, 1];
        let up = [true, true, true];
        let v = SchedView {
            now: 0.0,
            home: &home,
            ready: &ready,
            running: &[],
            free_slots: &free,
            queued: &queued,
            capacity: &cap,
            durations: &[],
            cluster: &cluster,
            up: &up,
        };
        let mut s = DynamicScheduler::new(true, false).with_locality();
        let a = s.assign(&v);
        // Task 0 runs at home; node 1 steals within the cluster; node 2
        // steals over the WAN because the backlog (3) clears the
        // threshold (2).
        assert_eq!(a.len(), 3);
        assert_eq!(a[0], Assignment { task: 0, node: 0, speculative: false });
        assert!(a.iter().any(|x| x.node == 1));
        assert!(a.iter().any(|x| x.node == 2));
    }

    #[test]
    fn locality_blocks_unjustified_wan_steals() {
        // Two tasks homed on node 0 (cluster 0); thief node 1 lives in
        // cluster 1. With the home up and only a shallow queue, the WAN
        // steal is not worth the penalty.
        let home = [0, 0];
        let ready = [0, 1];
        let free = [1, 1];
        let queued = [2, 0];
        let cap = [1.0, 1.0];
        let cluster = [0, 1];
        let up = [true, true];
        let v = SchedView {
            now: 0.0,
            home: &home,
            ready: &ready,
            running: &[],
            free_slots: &free,
            queued: &queued,
            capacity: &cap,
            durations: &[],
            cluster: &cluster,
            up: &up,
        };
        let mut s = DynamicScheduler::new(true, false).with_locality();
        s.wan_steal_min_queue = 3; // queue of 2 is below the bar
        let a = s.assign(&v);
        assert_eq!(a.len(), 1, "shallow remote queue must not be stolen over WAN");
        assert_eq!(a[0].node, 0);

        // Same scenario with the home node DOWN: the work is stranded,
        // so the WAN steal goes through regardless of queue depth.
        let free_down = [0, 1];
        let up_down = [false, true];
        let v = SchedView {
            now: 0.0,
            home: &home,
            ready: &ready,
            running: &[],
            free_slots: &free_down,
            queued: &queued,
            capacity: &cap,
            durations: &[],
            cluster: &cluster,
            up: &up_down,
        };
        let a = s.assign(&v);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].node, 1, "stranded work is stolen over WAN");
    }

    #[test]
    fn reassign_reduce_policies() {
        // Reducer 1 dead; 0 and 2 share its cluster, 3 is remote but
        // fastest and empty.
        let up = [true, false, true, true];
        let cluster = [0, 0, 0, 1];
        let capacity = [5.0, 9.0, 4.0, 20.0];
        let assigned = [10.0, 0.0, 2.0, 0.0];
        let v = ReduceView {
            dead: 1,
            up: &up,
            cluster: &cluster,
            capacity: &capacity,
            assigned_bytes: &assigned,
        };
        // Strict plan enforcement waits for recovery.
        assert_eq!(PlanLocalScheduler.reassign_reduce(&v), None);
        // Stealing-disabled dynamic config also waits.
        assert_eq!(DynamicScheduler::new(false, true).reassign_reduce(&v), None);
        // Cluster-oblivious dynamic: least-loaded survivor anywhere.
        assert_eq!(DynamicScheduler::new(true, false).reassign_reduce(&v), Some(3));
        // Locality: the least-loaded same-cluster survivor wins even
        // though node 3 is faster and emptier.
        let mut s = DynamicScheduler::new(true, false).with_locality();
        assert_eq!(s.reassign_reduce(&v), Some(2));
        // No survivor at all → None.
        let none_up = [false, false, false, false];
        let v = ReduceView { up: &none_up, ..v };
        assert_eq!(s.reassign_reduce(&v), None);
        // Ties on load resolve to the faster, then lower-index node.
        let even = [1.0, 0.0, 1.0, 1.0];
        let v = ReduceView {
            dead: 1,
            up: &up,
            cluster: &[0, 0, 0, 0],
            capacity: &capacity,
            assigned_bytes: &even,
        };
        let mut s = DynamicScheduler::new(true, false);
        assert_eq!(s.reassign_reduce(&v), Some(3), "fastest survivor breaks the load tie");
    }

    #[test]
    fn locality_speculation_prefers_home_cluster() {
        // Straggler homed (and running) in cluster 0; backup candidates:
        // node 1 (cluster 0, slow) and node 2 (cluster 1, fast). The
        // locality policy picks the home-cluster node.
        let home = [0];
        let running = [RunningTask { task: 0, node: 0, started_at: 0.0 }];
        let free = [0, 1, 1];
        let queued = [1, 0, 0];
        let cap = [1.0, 2.0, 9.0];
        let durations = [1.0, 1.0, 1.0];
        let cluster = [0, 0, 1];
        let up = [true, true, true];
        let v = SchedView {
            now: 10.0,
            home: &home,
            ready: &[],
            running: &running,
            free_slots: &free,
            queued: &queued,
            capacity: &cap,
            durations: &durations,
            cluster: &cluster,
            up: &up,
        };
        let mut s = DynamicScheduler::new(false, true).with_locality();
        let a = s.speculate(&v);
        assert_eq!(a, vec![Assignment { task: 0, node: 1, speculative: true }]);
        // Without locality the fastest node wins (historical behavior).
        let mut s = DynamicScheduler::new(false, true);
        let a = s.speculate(&v);
        assert_eq!(a, vec![Assignment { task: 0, node: 2, speculative: true }]);
    }

    // --------------------------------------- cross-job stream policies

    fn qjob(job: usize, arrival: f64, deadline: f64, est: f64) -> QueuedJob {
        QueuedJob { job, arrival, weight: 1.0, deadline, est_service: est }
    }

    #[test]
    fn fifo_admits_one_at_a_time() {
        let q = [qjob(0, 0.0, f64::INFINITY, 10.0), qjob(1, 1.0, f64::INFINITY, 10.0)];
        let mut p = FifoStream;
        let idle = StreamView { now: 1.0, queued: &q, running: 0 };
        assert_eq!(p.decide(&idle), vec![StreamDecision::Admit(0)]);
        let busy = StreamView { now: 1.0, queued: &q[1..], running: 1 };
        assert_eq!(p.decide(&busy), Vec::new());
    }

    #[test]
    fn fair_share_fills_to_cap() {
        let q = [
            qjob(3, 0.0, f64::INFINITY, 10.0),
            qjob(4, 1.0, f64::INFINITY, 10.0),
            qjob(5, 2.0, f64::INFINITY, 10.0),
        ];
        let mut p = FairShareStream { max_inflight: 3 };
        let v = StreamView { now: 2.0, queued: &q, running: 1 };
        assert_eq!(
            p.decide(&v),
            vec![StreamDecision::Admit(3), StreamDecision::Admit(4)],
            "cap 3 with 1 running leaves room for 2, in arrival order"
        );
    }

    #[test]
    fn deadline_rejects_hopeless_jobs() {
        // est_service 10; with one running, the first admit sees slowdown
        // ×2 → finish at 20. Deadline 15 → reject; deadline 25 → admit.
        let q = [qjob(0, 0.0, 15.0, 10.0), qjob(1, 0.0, 25.0, 10.0)];
        let mut p = DeadlineStream;
        let v = StreamView { now: 0.0, queued: &q, running: 1 };
        assert_eq!(
            p.decide(&v),
            vec![StreamDecision::Reject(0), StreamDecision::Admit(1)]
        );
        // No deadline → always admitted.
        let q2 = [qjob(2, 0.0, f64::INFINITY, 1e9)];
        let v2 = StreamView { now: 0.0, queued: &q2, running: 5 };
        assert_eq!(p.decide(&v2), vec![StreamDecision::Admit(2)]);
    }

    #[test]
    fn stream_policy_factory_names() {
        for name in ["fifo", "fair-share", "deadline"] {
            assert_eq!(stream_policy(name).unwrap().name(), name);
        }
        assert!(stream_policy("bogus").is_err());
    }
}
