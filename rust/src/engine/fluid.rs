//! Fluid (processor-sharing) discrete-event simulation core.
//!
//! The engine executes a MapReduce job in *virtual time*: network
//! transfers and compute tasks are **activities** with a fixed amount of
//! remaining work (bytes) that drain through **resources** (links, NICs,
//! CPUs) with finite capacities (bytes/second). Between events the
//! allocation is **max-min fair**: capacities are divided by progressive
//! filling, so an activity's rate is the minimum share over the resources
//! it crosses. Each completion is an event; the driver reacts by adding
//! new activities (state machine in [`super::executor`]).
//!
//! This replaces the paper's `tc`-shaped wall-clock testbed (§3.2) with a
//! deterministic, fast equivalent — and, unlike the closed-form model, it
//! captures contention (NIC sharing, slot queueing), which is what makes
//! the Fig 4 model-vs-measurement correlation a real test.
//!
//! ## Scaling
//!
//! The simulator is sized for the generated 16–512-node topologies of
//! [`crate::platform::scale`], not just the paper's 8-node environments:
//!
//! * the active set is maintained incrementally, so stepping costs
//!   O(active), not O(every activity ever created);
//! * rate recomputation touches only resources crossed by an active
//!   activity (a topology has O(|S|·|M| + |M|·|R|) link resources, almost
//!   all idle at any instant);
//! * progressive filling runs over a lazy min-heap of per-resource fair
//!   shares instead of rescanning every resource per freeze round —
//!   shares only grow as activities freeze, so a popped entry is either
//!   current (freeze at it) or stale (re-push the refreshed share).
//!
//! The max-min allocation is unique, so the heap order changes nothing
//! observable; it only removes the O(resources × rounds) scan that
//! dominated at 256 nodes.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::cmp::Reverse;

/// Identifies a resource (link, NIC, node CPU).
pub type ResourceId = usize;
/// Identifies an activity (transfer, task execution).
pub type ActivityId = usize;

#[derive(Debug, Clone)]
struct Resource {
    capacity: f64,
}

#[derive(Debug, Clone)]
struct Activity {
    remaining: f64,
    resources: Vec<ResourceId>,
    done: bool,
    /// Latest fair rate (recomputed whenever the active set changes).
    rate: f64,
    /// Caller-owned routing tag (the tenancy layer stores a job id here
    /// to route completions back to the owning executor). Never touched
    /// by the allocation arithmetic.
    tag: u64,
}

/// One resource's fair share in the progressive-filling heap.
#[derive(Debug, Clone, Copy)]
struct ShareEntry {
    share: f64,
    slot: usize,
}

impl PartialEq for ShareEntry {
    fn eq(&self, other: &Self) -> bool {
        self.share == other.share && self.slot == other.slot
    }
}

impl Eq for ShareEntry {}

impl PartialOrd for ShareEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ShareEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Shares are finite (capacity > 0, user count ≥ 1); tie-break by
        // slot for determinism.
        self.share
            .partial_cmp(&other.share)
            .unwrap_or(Ordering::Equal)
            .then(self.slot.cmp(&other.slot))
    }
}

/// The simulator.
#[derive(Debug, Default)]
pub struct FluidSim {
    resources: Vec<Resource>,
    activities: Vec<Activity>,
    /// Not-yet-done activity ids (pruned lazily).
    active: Vec<ActivityId>,
    now: f64,
    /// True when rates must be recomputed before advancing.
    dirty: bool,
    // Scratch reused across recomputes (resource → compact slot).
    res_stamp: Vec<u64>,
    res_slot: Vec<usize>,
    stamp: u64,
}

impl FluidSim {
    pub fn new() -> FluidSim {
        FluidSim::default()
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Register a resource with the given capacity (units/second).
    pub fn add_resource(&mut self, capacity: f64) -> ResourceId {
        assert!(capacity > 0.0 && capacity.is_finite());
        self.resources.push(Resource { capacity });
        self.res_stamp.push(0);
        self.res_slot.push(0);
        self.resources.len() - 1
    }

    /// Change a resource's capacity mid-run (time-varying bandwidth or
    /// compute). The max-min allocation is re-solved before the next
    /// advance; in-flight activities keep their remaining work and
    /// continue at the new fair rates.
    pub fn set_capacity(&mut self, r: ResourceId, capacity: f64) {
        assert!(
            capacity > 0.0 && capacity.is_finite(),
            "capacity must be positive and finite, got {capacity}"
        );
        if self.resources[r].capacity != capacity {
            self.resources[r].capacity = capacity;
            self.dirty = true;
        }
    }

    /// Current capacity of a resource.
    pub fn capacity(&self, r: ResourceId) -> f64 {
        self.resources[r].capacity
    }

    /// Advance the clock while idle (no activities): used by drivers that
    /// must wait for an external (scenario) event with nothing in flight.
    /// Never moves the clock backwards.
    pub fn jump_to(&mut self, t: f64) {
        assert!(t.is_finite(), "jump_to target must be finite, got {t}");
        if t > self.now {
            self.now = t;
        }
    }

    /// Start an activity needing `work` units across `resources`.
    /// Zero-work activities complete on the next `step`.
    pub fn add_activity(&mut self, work: f64, resources: Vec<ResourceId>) -> ActivityId {
        self.add_activity_tagged(work, resources, 0)
    }

    /// Like [`FluidSim::add_activity`] but with a caller-owned routing
    /// `tag` retrievable via [`FluidSim::tag`]. The tag does not affect
    /// the allocation: a tagged run is bit-identical to an untagged one.
    pub fn add_activity_tagged(
        &mut self,
        work: f64,
        resources: Vec<ResourceId>,
        tag: u64,
    ) -> ActivityId {
        assert!(work >= 0.0 && work.is_finite());
        assert!(!resources.is_empty(), "activity must use at least one resource");
        for &r in &resources {
            assert!(r < self.resources.len(), "dangling resource {r}");
        }
        self.activities.push(Activity {
            remaining: work,
            resources,
            done: false,
            rate: 0.0,
            tag,
        });
        self.active.push(self.activities.len() - 1);
        self.dirty = true;
        self.activities.len() - 1
    }

    /// Routing tag an activity was created with (0 unless tagged).
    pub fn tag(&self, id: ActivityId) -> u64 {
        self.activities[id].tag
    }

    /// Cancel a running activity (e.g. a losing speculative copy).
    pub fn cancel(&mut self, id: ActivityId) {
        if !self.activities[id].done {
            self.activities[id].done = true;
            self.dirty = true;
        }
    }

    pub fn is_done(&self, id: ActivityId) -> bool {
        self.activities[id].done
    }

    /// Remaining work of an activity.
    pub fn remaining(&self, id: ActivityId) -> f64 {
        self.activities[id].remaining
    }

    /// Current fair rate of an activity (0 if done or not yet computed).
    pub fn rate(&self, id: ActivityId) -> f64 {
        if self.activities[id].done {
            0.0
        } else {
            self.activities[id].rate
        }
    }

    /// Number of activities still running.
    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|&&a| !self.activities[a].done).count()
    }

    /// Max-min fair allocation by progressive filling (lazy-heap form).
    fn recompute_rates(&mut self) {
        self.active.retain(|&a| !self.activities[a].done);
        // Move the active list out so scratch fields can be borrowed
        // mutably alongside it.
        let active = std::mem::take(&mut self.active);

        // Compact slot index over resources actually in use.
        self.stamp += 1;
        let stamp = self.stamp;
        let mut used: Vec<ResourceId> = Vec::new();
        for &a in &active {
            for &r in &self.activities[a].resources {
                if self.res_stamp[r] != stamp {
                    self.res_stamp[r] = stamp;
                    self.res_slot[r] = used.len();
                    used.push(r);
                }
            }
        }
        // users[slot] = indices (into `active`) crossing that resource.
        let mut users: Vec<Vec<usize>> = vec![Vec::new(); used.len()];
        for (ai, &a) in active.iter().enumerate() {
            for &r in &self.activities[a].resources {
                users[self.res_slot[r]].push(ai);
            }
        }
        let mut remaining_cap: Vec<f64> =
            used.iter().map(|&r| self.resources[r].capacity).collect();
        let mut unfrozen_count: Vec<usize> = users.iter().map(Vec::len).collect();
        let mut rate: Vec<f64> = vec![f64::INFINITY; active.len()];
        let mut frozen: Vec<bool> = vec![false; active.len()];
        let mut n_frozen = 0usize;

        let mut heap: BinaryHeap<Reverse<ShareEntry>> =
            BinaryHeap::with_capacity(used.len());
        for slot in 0..used.len() {
            if unfrozen_count[slot] > 0 {
                heap.push(Reverse(ShareEntry {
                    share: remaining_cap[slot] / unfrozen_count[slot] as f64,
                    slot,
                }));
            }
        }
        while n_frozen < active.len() {
            let Some(Reverse(entry)) = heap.pop() else { break };
            let slot = entry.slot;
            if unfrozen_count[slot] == 0 {
                continue; // fully frozen since the entry was pushed
            }
            let share = (remaining_cap[slot].max(0.0)) / unfrozen_count[slot] as f64;
            if share > entry.share {
                // Stale: freezes elsewhere released capacity per user;
                // re-queue at the current (larger) share.
                heap.push(Reverse(ShareEntry { share, slot }));
                continue;
            }
            // This resource is the bottleneck: freeze its unfrozen users.
            let us: Vec<usize> =
                users[slot].iter().cloned().filter(|&ai| !frozen[ai]).collect();
            for ai in us {
                frozen[ai] = true;
                n_frozen += 1;
                rate[ai] = share;
                // Charge this activity to all its resources.
                for &r2 in &self.activities[active[ai]].resources {
                    let s2 = self.res_slot[r2];
                    remaining_cap[s2] -= share;
                    unfrozen_count[s2] -= 1;
                    if s2 != slot && unfrozen_count[s2] > 0 {
                        heap.push(Reverse(ShareEntry {
                            share: (remaining_cap[s2].max(0.0))
                                / unfrozen_count[s2] as f64,
                            slot: s2,
                        }));
                    }
                }
            }
            remaining_cap[slot] = remaining_cap[slot].max(0.0);
        }

        for (ai, &a) in active.iter().enumerate() {
            self.activities[a].rate = rate[ai];
        }
        self.active = active;
        self.dirty = false;
    }

    /// Advance to the next completion. Returns `(time, completed ids)`,
    /// or `None` when no activities remain.
    pub fn step(&mut self) -> Option<(f64, Vec<ActivityId>)> {
        self.step_until(f64::INFINITY)
    }

    /// Like [`FluidSim::step`], but never advance past `t_limit`: if the
    /// earliest completion lies beyond it, drain partial progress up to
    /// `t_limit` and return `Some((t_limit, vec![]))` — an empty
    /// completion batch signalling the limit was reached (so the caller
    /// can apply an external event and resume). With `t_limit =
    /// f64::INFINITY` this is exactly `step` (identical arithmetic).
    pub fn step_until(&mut self, t_limit: f64) -> Option<(f64, Vec<ActivityId>)> {
        self.active.retain(|&a| !self.activities[a].done);
        if self.active.is_empty() {
            return None;
        }
        if self.dirty {
            self.recompute_rates();
        }
        // Zero-work or zero-remaining activities complete immediately.
        let mut instant: Vec<ActivityId> = self
            .active
            .iter()
            .cloned()
            .filter(|&a| self.activities[a].remaining <= 1e-9)
            .collect();
        if !instant.is_empty() {
            for &a in &instant {
                self.activities[a].done = true;
                self.activities[a].remaining = 0.0;
            }
            self.dirty = true;
            instant.sort_unstable();
            return Some((self.now, instant));
        }
        // Time to the earliest completion.
        let mut dt = f64::INFINITY;
        for &a in &self.active {
            let act = &self.activities[a];
            if act.rate > 0.0 {
                dt = dt.min(act.remaining / act.rate);
            }
        }
        assert!(
            dt.is_finite(),
            "deadlock: active activities with zero rate (resource starvation)"
        );
        if self.now + dt > t_limit {
            // The next completion lies beyond the limit: drain partial
            // progress and stop exactly at it (clock never regresses).
            let part = (t_limit - self.now).max(0.0);
            if part > 0.0 {
                for &a in &self.active {
                    let act = &mut self.activities[a];
                    act.remaining = (act.remaining - act.rate * part).max(0.0);
                }
            }
            self.now = self.now.max(t_limit);
            return Some((self.now, Vec::new()));
        }
        self.now += dt;
        let mut completed = Vec::new();
        for &a in &self.active {
            let act = &mut self.activities[a];
            act.remaining -= act.rate * dt;
            if act.remaining <= 1e-6 * act.rate.max(1.0) + 1e-12 {
                act.remaining = 0.0;
                act.done = true;
                completed.push(a);
            }
        }
        debug_assert!(!completed.is_empty());
        self.dirty = true;
        completed.sort_unstable();
        Some((self.now, completed))
    }

    /// Run until all activities complete; returns the final virtual time.
    pub fn run_to_completion(&mut self) -> f64 {
        while self.step().is_some() {}
        self.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_activity_single_resource() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(100.0, vec![r]);
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![a]);
        assert!((t - 10.0).abs() < 1e-9);
        assert!(sim.step().is_none());
    }

    #[test]
    fn two_activities_share_fairly() {
        // Two activities on one 10-unit/s resource, 100 units each:
        // both run at 5/s and finish together at t=20.
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(100.0, vec![r]);
        let b = sim.add_activity(100.0, vec![r]);
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![a, b]);
        assert!((t - 20.0).abs() < 1e-9);
    }

    #[test]
    fn released_capacity_speeds_up_survivor() {
        // a: 50 units, b: 100 units, shared 10/s resource.
        // Phase 1: both at 5/s → a done at t=10 (b has 50 left).
        // Phase 2: b alone at 10/s → done at t=15.
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(50.0, vec![r]);
        let b = sim.add_activity(100.0, vec![r]);
        let (t1, d1) = sim.step().unwrap();
        assert_eq!(d1, vec![a]);
        assert!((t1 - 10.0).abs() < 1e-9);
        let (t2, d2) = sim.step().unwrap();
        assert_eq!(d2, vec![b]);
        assert!((t2 - 15.0).abs() < 1e-9);
    }

    #[test]
    fn bottleneck_is_min_over_resources() {
        // Activity crosses fast (100/s) and slow (5/s) resources:
        // rate = 5/s.
        let mut sim = FluidSim::new();
        let fast = sim.add_resource(100.0);
        let slow = sim.add_resource(5.0);
        let a = sim.add_activity(50.0, vec![fast, slow]);
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![a]);
        assert!((t - 10.0).abs() < 1e-9);
    }

    #[test]
    fn max_min_fairness_with_asymmetric_demands() {
        // Resource R1 (cap 10) carries flows A, B; resource R2 (cap 2)
        // carries flow B only (its bottleneck). Max-min: B gets 2,
        // A gets 8.
        let mut sim = FluidSim::new();
        let r1 = sim.add_resource(10.0);
        let r2 = sim.add_resource(2.0);
        let a = sim.add_activity(80.0, vec![r1]);
        let b = sim.add_activity(20.0, vec![r1, r2]);
        sim.recompute_rates();
        assert!((sim.rate(a) - 8.0).abs() < 1e-9);
        assert!((sim.rate(b) - 2.0).abs() < 1e-9);
        let (t, done) = sim.step().unwrap();
        // both finish at t = 10 exactly (80/8 = 20/2)
        assert_eq!(done.len(), 2);
        assert!((t - 10.0).abs() < 1e-9);
    }

    #[test]
    fn cancel_releases_capacity() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(100.0, vec![r]);
        let b = sim.add_activity(100.0, vec![r]);
        sim.cancel(a);
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![b]);
        assert!((t - 10.0).abs() < 1e-9, "b should run alone at 10/s");
    }

    #[test]
    fn zero_work_completes_instantly() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(0.0, vec![r]);
        let b = sim.add_activity(10.0, vec![r]);
        let (t, done) = sim.step().unwrap();
        assert_eq!((t, done), (0.0, vec![a]));
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![b]);
        assert!((t - 1.0).abs() < 1e-9);
    }

    #[test]
    fn staged_arrivals_advance_clock_monotonically() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(1.0);
        sim.add_activity(5.0, vec![r]);
        let (t1, _) = sim.step().unwrap();
        // New work arrives after the first completes.
        sim.add_activity(3.0, vec![r]);
        let (t2, _) = sim.step().unwrap();
        assert!(t2 > t1);
        assert!((t2 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn run_to_completion_drains_everything() {
        let mut sim = FluidSim::new();
        let r1 = sim.add_resource(3.0);
        let r2 = sim.add_resource(7.0);
        for i in 0..20 {
            let res = if i % 2 == 0 { vec![r1] } else { vec![r2] };
            sim.add_activity((i + 1) as f64, res);
        }
        let t = sim.run_to_completion();
        assert!(t > 0.0);
        for i in 0..20 {
            assert!(sim.is_done(i));
        }
    }

    /// Three-level bottleneck chain: the lazy heap must refresh shares
    /// as freezes release capacity (the stale-entry path).
    #[test]
    fn progressive_filling_multi_round() {
        // R1 cap 6 carries {a, b, c}; R2 cap 1 carries {a}; R3 cap 2
        // carries {b}. Max-min: a=1 (R2), b=2 (R3), c=3 (R1 leftover).
        let mut sim = FluidSim::new();
        let r1 = sim.add_resource(6.0);
        let r2 = sim.add_resource(1.0);
        let r3 = sim.add_resource(2.0);
        let a = sim.add_activity(10.0, vec![r1, r2]);
        let b = sim.add_activity(10.0, vec![r1, r3]);
        let c = sim.add_activity(10.0, vec![r1]);
        sim.recompute_rates();
        assert!((sim.rate(a) - 1.0).abs() < 1e-9, "a at {}", sim.rate(a));
        assert!((sim.rate(b) - 2.0).abs() < 1e-9, "b at {}", sim.rate(b));
        assert!((sim.rate(c) - 3.0).abs() < 1e-9, "c at {}", sim.rate(c));
    }

    /// A capacity change mid-run re-solves the max-min allocation: the
    /// surviving work drains at the new rate from the change point.
    #[test]
    fn set_capacity_rescales_inflight_work() {
        // 100 units on a 10/s resource; at t=5 (50 left) the link halves
        // to 5/s → completion at t = 5 + 50/5 = 15.
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(100.0, vec![r]);
        let (t, done) = sim.step_until(5.0).unwrap();
        assert!(done.is_empty(), "no completion before t=5");
        assert!((t - 5.0).abs() < 1e-9);
        assert!((sim.remaining(a) - 50.0).abs() < 1e-9);
        sim.set_capacity(r, 5.0);
        assert_eq!(sim.capacity(r), 5.0);
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![a]);
        assert!((t - 15.0).abs() < 1e-9, "completed at {t}");
    }

    /// step_until at exactly the completion time delivers the completion
    /// (not an empty limit batch), and an infinite limit is plain step.
    #[test]
    fn step_until_boundary_and_infinity() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(100.0, vec![r]);
        let (t, done) = sim.step_until(10.0).unwrap();
        assert_eq!(done, vec![a], "completion exactly at the limit fires");
        assert!((t - 10.0).abs() < 1e-9);
        let b = sim.add_activity(20.0, vec![r]);
        let (t, done) = sim.step_until(f64::INFINITY).unwrap();
        assert_eq!(done, vec![b]);
        assert!((t - 12.0).abs() < 1e-9);
    }

    /// Chopping a run into many step_until segments conserves total work
    /// and the clock (the dynamics interleaving path).
    #[test]
    fn step_until_segments_conserve_completion_time() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(4.0);
        let a = sim.add_activity(100.0, vec![r]);
        let mut limit = 3.0;
        loop {
            let (t, done) = sim.step_until(limit).unwrap();
            if !done.is_empty() {
                assert_eq!(done, vec![a]);
                assert!((t - 25.0).abs() < 1e-6, "completed at {t}");
                break;
            }
            limit += 3.0;
        }
        assert!(sim.step().is_none());
    }

    /// Tags route completions without perturbing the allocation.
    #[test]
    fn tags_are_inert_and_retrievable() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(100.0, vec![r]);
        let b = sim.add_activity_tagged(100.0, vec![r], 42);
        assert_eq!(sim.tag(a), 0);
        assert_eq!(sim.tag(b), 42);
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![a, b]);
        assert!((t - 20.0).abs() < 1e-9, "tags must not change fair shares");
    }

    #[test]
    fn jump_to_only_moves_forward() {
        let mut sim = FluidSim::new();
        sim.jump_to(7.0);
        assert_eq!(sim.now(), 7.0);
        sim.jump_to(3.0);
        assert_eq!(sim.now(), 7.0, "clock never regresses");
    }

    /// Many short sequential activities: the maintained active set keeps
    /// stepping cheap and the clock strictly ordered.
    #[test]
    fn long_run_active_set_stays_consistent() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(2.0);
        let mut last = 0.0;
        for round in 0..200 {
            sim.add_activity(1.0 + (round % 3) as f64, vec![r]);
            let (t, done) = sim.step().unwrap();
            assert!(t >= last);
            last = t;
            assert_eq!(done.len(), 1);
            assert_eq!(sim.active_count(), 0);
        }
    }
}
