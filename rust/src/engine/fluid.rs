//! Fluid (processor-sharing) discrete-event simulation core.
//!
//! The engine executes a MapReduce job in *virtual time*: network
//! transfers and compute tasks are **activities** with a fixed amount of
//! remaining work (bytes) that drain through **resources** (links, NICs,
//! CPUs) with finite capacities (bytes/second). Between events the
//! allocation is **max-min fair**: capacities are divided by progressive
//! filling, so an activity's rate is the minimum share over the resources
//! it crosses. Each completion is an event; the driver reacts by adding
//! new activities (state machine in [`super::executor`]).
//!
//! This replaces the paper's `tc`-shaped wall-clock testbed (§3.2) with a
//! deterministic, fast equivalent — and, unlike the closed-form model, it
//! captures contention (NIC sharing, slot queueing), which is what makes
//! the Fig 4 model-vs-measurement correlation a real test.

/// Identifies a resource (link, NIC, node CPU).
pub type ResourceId = usize;
/// Identifies an activity (transfer, task execution).
pub type ActivityId = usize;

#[derive(Debug, Clone)]
struct Resource {
    capacity: f64,
}

#[derive(Debug, Clone)]
struct Activity {
    remaining: f64,
    resources: Vec<ResourceId>,
    done: bool,
    /// Latest fair rate (recomputed whenever the active set changes).
    rate: f64,
}

/// The simulator.
#[derive(Debug, Default)]
pub struct FluidSim {
    resources: Vec<Resource>,
    activities: Vec<Activity>,
    now: f64,
    /// True when rates must be recomputed before advancing.
    dirty: bool,
}

impl FluidSim {
    pub fn new() -> FluidSim {
        FluidSim::default()
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Register a resource with the given capacity (units/second).
    pub fn add_resource(&mut self, capacity: f64) -> ResourceId {
        assert!(capacity > 0.0 && capacity.is_finite());
        self.resources.push(Resource { capacity });
        self.resources.len() - 1
    }

    /// Start an activity needing `work` units across `resources`.
    /// Zero-work activities complete on the next `step`.
    pub fn add_activity(&mut self, work: f64, resources: Vec<ResourceId>) -> ActivityId {
        assert!(work >= 0.0 && work.is_finite());
        assert!(!resources.is_empty(), "activity must use at least one resource");
        for &r in &resources {
            assert!(r < self.resources.len(), "dangling resource {r}");
        }
        self.activities.push(Activity { remaining: work, resources, done: false, rate: 0.0 });
        self.dirty = true;
        self.activities.len() - 1
    }

    /// Cancel a running activity (e.g. a losing speculative copy).
    pub fn cancel(&mut self, id: ActivityId) {
        if !self.activities[id].done {
            self.activities[id].done = true;
            self.dirty = true;
        }
    }

    pub fn is_done(&self, id: ActivityId) -> bool {
        self.activities[id].done
    }

    /// Remaining work of an activity.
    pub fn remaining(&self, id: ActivityId) -> f64 {
        self.activities[id].remaining
    }

    /// Current fair rate of an activity (0 if done or not yet computed).
    pub fn rate(&self, id: ActivityId) -> f64 {
        if self.activities[id].done {
            0.0
        } else {
            self.activities[id].rate
        }
    }

    fn active_ids(&self) -> Vec<ActivityId> {
        (0..self.activities.len())
            .filter(|&a| !self.activities[a].done)
            .collect()
    }

    /// Max-min fair allocation by progressive filling.
    fn recompute_rates(&mut self) {
        let active = self.active_ids();
        // usage[r] = indices (into `active`) of activities crossing r.
        let mut usage: Vec<Vec<usize>> = vec![Vec::new(); self.resources.len()];
        for (ai, &a) in active.iter().enumerate() {
            for &r in &self.activities[a].resources {
                usage[r].push(ai);
            }
        }
        let mut remaining_cap: Vec<f64> =
            self.resources.iter().map(|r| r.capacity).collect();
        let mut unfrozen_count: Vec<usize> = usage.iter().map(|u| u.len()).collect();
        let mut rate: Vec<f64> = vec![f64::INFINITY; active.len()];
        let mut frozen: Vec<bool> = vec![false; active.len()];
        let mut n_frozen = 0usize;

        while n_frozen < active.len() {
            // Find the bottleneck resource: min fair share among used ones.
            let mut best_r = usize::MAX;
            let mut best_share = f64::INFINITY;
            for (r, u) in usage.iter().enumerate() {
                if unfrozen_count[r] > 0 {
                    let share = remaining_cap[r] / unfrozen_count[r] as f64;
                    if share < best_share {
                        best_share = share;
                        best_r = r;
                    }
                }
            }
            if best_r == usize::MAX {
                break; // no active resource left (shouldn't happen)
            }
            // Freeze every unfrozen activity on that resource.
            // Iterate over a copy since we mutate bookkeeping.
            let users: Vec<usize> = usage[best_r]
                .iter()
                .cloned()
                .filter(|&ai| !frozen[ai])
                .collect();
            for ai in users {
                frozen[ai] = true;
                n_frozen += 1;
                rate[ai] = best_share;
                // Charge this activity to all its resources.
                for &r in &self.activities[active[ai]].resources {
                    remaining_cap[r] -= best_share;
                    unfrozen_count[r] -= 1;
                }
            }
            remaining_cap[best_r] = remaining_cap[best_r].max(0.0);
        }

        for (ai, &a) in active.iter().enumerate() {
            self.activities[a].rate = rate[ai];
        }
        self.dirty = false;
    }

    /// Advance to the next completion. Returns `(time, completed ids)`,
    /// or `None` when no activities remain.
    pub fn step(&mut self) -> Option<(f64, Vec<ActivityId>)> {
        let active = self.active_ids();
        if active.is_empty() {
            return None;
        }
        if self.dirty {
            self.recompute_rates();
        }
        // Zero-work or zero-remaining activities complete immediately.
        let mut instant: Vec<ActivityId> = active
            .iter()
            .cloned()
            .filter(|&a| self.activities[a].remaining <= 1e-9)
            .collect();
        if !instant.is_empty() {
            for &a in &instant {
                self.activities[a].done = true;
                self.activities[a].remaining = 0.0;
            }
            self.dirty = true;
            instant.sort_unstable();
            return Some((self.now, instant));
        }
        // Time to the earliest completion.
        let mut dt = f64::INFINITY;
        for &a in &active {
            let act = &self.activities[a];
            if act.rate > 0.0 {
                dt = dt.min(act.remaining / act.rate);
            }
        }
        assert!(
            dt.is_finite(),
            "deadlock: active activities with zero rate (resource starvation)"
        );
        self.now += dt;
        let mut completed = Vec::new();
        for &a in &active {
            let act = &mut self.activities[a];
            act.remaining -= act.rate * dt;
            if act.remaining <= 1e-6 * act.rate.max(1.0) + 1e-12 {
                act.remaining = 0.0;
                act.done = true;
                completed.push(a);
            }
        }
        debug_assert!(!completed.is_empty());
        self.dirty = true;
        completed.sort_unstable();
        Some((self.now, completed))
    }

    /// Run until all activities complete; returns the final virtual time.
    pub fn run_to_completion(&mut self) -> f64 {
        while self.step().is_some() {}
        self.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_activity_single_resource() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(100.0, vec![r]);
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![a]);
        assert!((t - 10.0).abs() < 1e-9);
        assert!(sim.step().is_none());
    }

    #[test]
    fn two_activities_share_fairly() {
        // Two activities on one 10-unit/s resource, 100 units each:
        // both run at 5/s and finish together at t=20.
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(100.0, vec![r]);
        let b = sim.add_activity(100.0, vec![r]);
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![a, b]);
        assert!((t - 20.0).abs() < 1e-9);
    }

    #[test]
    fn released_capacity_speeds_up_survivor() {
        // a: 50 units, b: 100 units, shared 10/s resource.
        // Phase 1: both at 5/s → a done at t=10 (b has 50 left).
        // Phase 2: b alone at 10/s → done at t=15.
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(50.0, vec![r]);
        let b = sim.add_activity(100.0, vec![r]);
        let (t1, d1) = sim.step().unwrap();
        assert_eq!(d1, vec![a]);
        assert!((t1 - 10.0).abs() < 1e-9);
        let (t2, d2) = sim.step().unwrap();
        assert_eq!(d2, vec![b]);
        assert!((t2 - 15.0).abs() < 1e-9);
    }

    #[test]
    fn bottleneck_is_min_over_resources() {
        // Activity crosses fast (100/s) and slow (5/s) resources:
        // rate = 5/s.
        let mut sim = FluidSim::new();
        let fast = sim.add_resource(100.0);
        let slow = sim.add_resource(5.0);
        let a = sim.add_activity(50.0, vec![fast, slow]);
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![a]);
        assert!((t - 10.0).abs() < 1e-9);
    }

    #[test]
    fn max_min_fairness_with_asymmetric_demands() {
        // Resource R1 (cap 10) carries flows A, B; resource R2 (cap 2)
        // carries flow B only (its bottleneck). Max-min: B gets 2,
        // A gets 8.
        let mut sim = FluidSim::new();
        let r1 = sim.add_resource(10.0);
        let r2 = sim.add_resource(2.0);
        let a = sim.add_activity(80.0, vec![r1]);
        let b = sim.add_activity(20.0, vec![r1, r2]);
        sim.recompute_rates();
        assert!((sim.rate(a) - 8.0).abs() < 1e-9);
        assert!((sim.rate(b) - 2.0).abs() < 1e-9);
        let (t, done) = sim.step().unwrap();
        // both finish at t = 10 exactly (80/8 = 20/2)
        assert_eq!(done.len(), 2);
        assert!((t - 10.0).abs() < 1e-9);
    }

    #[test]
    fn cancel_releases_capacity() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(100.0, vec![r]);
        let b = sim.add_activity(100.0, vec![r]);
        sim.cancel(a);
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![b]);
        assert!((t - 10.0).abs() < 1e-9, "b should run alone at 10/s");
    }

    #[test]
    fn zero_work_completes_instantly() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(0.0, vec![r]);
        let b = sim.add_activity(10.0, vec![r]);
        let (t, done) = sim.step().unwrap();
        assert_eq!((t, done), (0.0, vec![a]));
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![b]);
        assert!((t - 1.0).abs() < 1e-9);
    }

    #[test]
    fn staged_arrivals_advance_clock_monotonically() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(1.0);
        sim.add_activity(5.0, vec![r]);
        let (t1, _) = sim.step().unwrap();
        // New work arrives after the first completes.
        sim.add_activity(3.0, vec![r]);
        let (t2, _) = sim.step().unwrap();
        assert!(t2 > t1);
        assert!((t2 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn run_to_completion_drains_everything() {
        let mut sim = FluidSim::new();
        let r1 = sim.add_resource(3.0);
        let r2 = sim.add_resource(7.0);
        for i in 0..20 {
            let res = if i % 2 == 0 { vec![r1] } else { vec![r2] };
            sim.add_activity((i + 1) as f64, res);
        }
        let t = sim.run_to_completion();
        assert!(t > 0.0);
        for i in 0..20 {
            assert!(sim.is_done(i));
        }
    }
}
