//! Fluid (processor-sharing) discrete-event simulation core.
//!
//! The engine executes a MapReduce job in *virtual time*: network
//! transfers and compute tasks are **activities** with a fixed amount of
//! remaining work (bytes) that drain through **resources** (links, NICs,
//! CPUs) with finite capacities (bytes/second). Between events the
//! allocation is **max-min fair**: capacities are divided by progressive
//! filling, so an activity's rate is the minimum share over the resources
//! it crosses. Each completion is an event; the driver reacts by adding
//! new activities (state machine in [`super::executor`]).
//!
//! This replaces the paper's `tc`-shaped wall-clock testbed (§3.2) with a
//! deterministic, fast equivalent — and, unlike the closed-form model, it
//! captures contention (NIC sharing, slot queueing), which is what makes
//! the Fig 4 model-vs-measurement correlation a real test.
//!
//! ## Scaling
//!
//! The simulator is sized for the generated 16–4096-node topologies of
//! [`crate::platform::scale`], not just the paper's 8-node environments:
//!
//! * the active set is maintained incrementally, so stepping costs
//!   O(active), not O(every activity ever created);
//! * rate recomputation touches only resources crossed by an active
//!   activity (a topology has O(|S|·|M| + |M|·|R|) link resources, almost
//!   all idle at any instant);
//! * progressive filling runs over a lazy min-heap of per-resource fair
//!   shares instead of rescanning every resource per freeze round —
//!   shares only grow as activities freeze, so a popped entry is either
//!   current (freeze at it) or stale (re-push the refreshed share);
//! * re-solves are **incremental**: each event (activity start/finish,
//!   cancellation, `set_capacity`) dirties the resources it touches, and
//!   only connected components of the activity↔resource graph containing
//!   a dirtied resource are re-filled. A clean component's stored rates
//!   are exactly what re-filling would produce, because the filling
//!   arithmetic is component-local and `retain` preserves the relative
//!   activity order inside untouched components;
//! * with [`FluidSim::set_threads`], dirty components are sharded
//!   round-robin over `std::thread::scope` workers. Every component's
//!   arithmetic is self-contained and the merged rate writes are
//!   disjoint, so metrics are **bit-identical for every thread count**
//!   (property-tested in tests/engine_threads.rs).
//!
//! The max-min allocation is unique, so the heap order changes nothing
//! observable; it only removes the O(resources × rounds) scan that
//! dominated at 256 nodes.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::cmp::Reverse;

/// Identifies a resource (link, NIC, node CPU).
pub type ResourceId = usize;
/// Identifies an activity (transfer, task execution).
pub type ActivityId = usize;

#[derive(Debug, Clone)]
struct Resource {
    capacity: f64,
}

#[derive(Debug, Clone)]
struct Activity {
    remaining: f64,
    resources: Vec<ResourceId>,
    done: bool,
    /// Latest fair rate (recomputed whenever the active set changes).
    rate: f64,
    /// Caller-owned routing tag (the tenancy layer stores a job id here
    /// to route completions back to the owning executor). Never touched
    /// by the allocation arithmetic.
    tag: u64,
}

/// One resource's fair share in the progressive-filling heap.
#[derive(Debug, Clone, Copy)]
struct ShareEntry {
    share: f64,
    slot: usize,
}

impl PartialEq for ShareEntry {
    fn eq(&self, other: &Self) -> bool {
        self.share == other.share && self.slot == other.slot
    }
}

impl Eq for ShareEntry {}

impl PartialOrd for ShareEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ShareEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Shares are finite (capacity > 0, user count ≥ 1); tie-break by
        // slot for determinism.
        self.share
            .partial_cmp(&other.share)
            .unwrap_or(Ordering::Equal)
            .then(self.slot.cmp(&other.slot))
    }
}

/// The simulator.
#[derive(Debug, Default)]
pub struct FluidSim {
    resources: Vec<Resource>,
    activities: Vec<Activity>,
    /// Not-yet-done activity ids (pruned lazily).
    active: Vec<ActivityId>,
    now: f64,
    /// True when rates must be recomputed before advancing.
    dirty: bool,
    // Scratch reused across recomputes (resource → compact slot).
    res_stamp: Vec<u64>,
    res_slot: Vec<usize>,
    stamp: u64,
    /// Per-resource "affected since last solve" flags plus the list of
    /// set flags, so clearing costs O(dirtied), not O(all resources).
    res_dirty: Vec<bool>,
    dirty_res: Vec<ResourceId>,
    /// Worker threads for the component re-solve (0 and 1 both mean
    /// sequential; the default stays zero-cost).
    threads: usize,
    /// Perf counters: re-solve invocations and resources re-filled.
    n_resolves: u64,
    n_resources_touched: u64,
}

impl FluidSim {
    pub fn new() -> FluidSim {
        FluidSim::default()
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Use `n` worker threads for max-min re-solves. Dirty components are
    /// sharded round-robin and merged deterministically, so results are
    /// bit-identical for every `n ≥ 1`. Panics on `n = 0`.
    pub fn set_threads(&mut self, n: usize) {
        assert!(n >= 1, "thread count must be >= 1, got {n}");
        self.threads = n;
    }

    /// Configured worker-thread count (≥ 1).
    pub fn threads(&self) -> usize {
        self.threads.max(1)
    }

    /// Total max-min re-solve invocations since construction.
    pub fn resolves(&self) -> u64 {
        self.n_resolves
    }

    /// Total resources re-filled across all re-solves. Clean components
    /// skipped by the incremental decomposition are not counted, so this
    /// divided by [`FluidSim::resolves`] is the mean re-solve footprint.
    pub fn resources_touched(&self) -> u64 {
        self.n_resources_touched
    }

    /// Register a resource with the given capacity (units/second).
    pub fn add_resource(&mut self, capacity: f64) -> ResourceId {
        assert!(capacity > 0.0 && capacity.is_finite());
        self.resources.push(Resource { capacity });
        self.res_stamp.push(0);
        self.res_slot.push(0);
        self.res_dirty.push(false);
        self.resources.len() - 1
    }

    /// Flag a resource as affected by an event since the last re-solve.
    fn mark_res(&mut self, r: ResourceId) {
        if !self.res_dirty[r] {
            self.res_dirty[r] = true;
            self.dirty_res.push(r);
        }
    }

    /// Flag every resource an activity crosses (membership changed).
    fn mark_activity(&mut self, id: ActivityId) {
        for i in 0..self.activities[id].resources.len() {
            let r = self.activities[id].resources[i];
            if !self.res_dirty[r] {
                self.res_dirty[r] = true;
                self.dirty_res.push(r);
            }
        }
    }

    /// Change a resource's capacity mid-run (time-varying bandwidth or
    /// compute). The max-min allocation is re-solved before the next
    /// advance; in-flight activities keep their remaining work and
    /// continue at the new fair rates.
    pub fn set_capacity(&mut self, r: ResourceId, capacity: f64) {
        assert!(
            capacity > 0.0 && capacity.is_finite(),
            "capacity must be positive and finite, got {capacity}"
        );
        if self.resources[r].capacity != capacity {
            self.resources[r].capacity = capacity;
            self.dirty = true;
            self.mark_res(r);
        }
    }

    /// Current capacity of a resource.
    pub fn capacity(&self, r: ResourceId) -> f64 {
        self.resources[r].capacity
    }

    /// Advance the clock while idle (no activities): used by drivers that
    /// must wait for an external (scenario) event with nothing in flight.
    /// Never moves the clock backwards.
    pub fn jump_to(&mut self, t: f64) {
        assert!(t.is_finite(), "jump_to target must be finite, got {t}");
        if t > self.now {
            self.now = t;
        }
    }

    /// Start an activity needing `work` units across `resources`.
    /// Zero-work activities complete on the next `step`.
    pub fn add_activity(&mut self, work: f64, resources: Vec<ResourceId>) -> ActivityId {
        self.add_activity_tagged(work, resources, 0)
    }

    /// Like [`FluidSim::add_activity`] but with a caller-owned routing
    /// `tag` retrievable via [`FluidSim::tag`]. The tag does not affect
    /// the allocation: a tagged run is bit-identical to an untagged one.
    pub fn add_activity_tagged(
        &mut self,
        work: f64,
        resources: Vec<ResourceId>,
        tag: u64,
    ) -> ActivityId {
        assert!(work >= 0.0 && work.is_finite());
        assert!(!resources.is_empty(), "activity must use at least one resource");
        for &r in &resources {
            assert!(r < self.resources.len(), "dangling resource {r}");
        }
        for i in 0..resources.len() {
            self.mark_res(resources[i]);
        }
        self.activities.push(Activity {
            remaining: work,
            resources,
            done: false,
            rate: 0.0,
            tag,
        });
        self.active.push(self.activities.len() - 1);
        self.dirty = true;
        self.activities.len() - 1
    }

    /// Routing tag an activity was created with (0 unless tagged).
    pub fn tag(&self, id: ActivityId) -> u64 {
        self.activities[id].tag
    }

    /// Cancel a running activity (e.g. a losing speculative copy).
    pub fn cancel(&mut self, id: ActivityId) {
        if !self.activities[id].done {
            self.activities[id].done = true;
            self.dirty = true;
            self.mark_activity(id);
        }
    }

    pub fn is_done(&self, id: ActivityId) -> bool {
        self.activities[id].done
    }

    /// Remaining work of an activity.
    pub fn remaining(&self, id: ActivityId) -> f64 {
        self.activities[id].remaining
    }

    /// Current fair rate of an activity (0 if done or not yet computed).
    pub fn rate(&self, id: ActivityId) -> f64 {
        if self.activities[id].done {
            0.0
        } else {
            self.activities[id].rate
        }
    }

    /// Number of activities still running.
    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|&&a| !self.activities[a].done).count()
    }

    /// Max-min fair allocation by progressive filling, restricted to the
    /// connected components of the activity↔resource graph affected by
    /// events since the last solve. Components without a dirtied
    /// resource keep their stored rates — which are exactly what a
    /// re-fill would produce, since the filling arithmetic is
    /// component-local and `retain` preserves the relative activity
    /// order inside an untouched component. With `threads > 1`, dirty
    /// components are sharded round-robin over scoped worker threads;
    /// each component's arithmetic is self-contained and the merged rate
    /// writes are disjoint, so the result is bit-identical for every
    /// thread count.
    fn recompute_rates(&mut self) {
        self.active.retain(|&a| !self.activities[a].done);
        // Move the active list out so scratch fields can be borrowed
        // mutably alongside it.
        let active = std::mem::take(&mut self.active);

        // Compact slot index over resources actually in use.
        self.stamp += 1;
        let stamp = self.stamp;
        let mut used: Vec<ResourceId> = Vec::new();
        for &a in &active {
            for &r in &self.activities[a].resources {
                if self.res_stamp[r] != stamp {
                    self.res_stamp[r] = stamp;
                    self.res_slot[r] = used.len();
                    used.push(r);
                }
            }
        }
        // users[slot] = indices (into `active`) crossing that resource.
        let mut users: Vec<Vec<usize>> = vec![Vec::new(); used.len()];
        for (ai, &a) in active.iter().enumerate() {
            for &r in &self.activities[a].resources {
                users[self.res_slot[r]].push(ai);
            }
        }

        // Connected components of the bipartite graph, numbered by first
        // appearance along `active` (deterministic).
        let mut comp_of_act: Vec<usize> = vec![usize::MAX; active.len()];
        let mut comp_of_slot: Vec<usize> = vec![usize::MAX; used.len()];
        let mut n_comp = 0usize;
        let mut stack: Vec<usize> = Vec::new();
        for seed in 0..active.len() {
            if comp_of_act[seed] != usize::MAX {
                continue;
            }
            comp_of_act[seed] = n_comp;
            stack.push(seed);
            while let Some(ai) = stack.pop() {
                for &r in &self.activities[active[ai]].resources {
                    let slot = self.res_slot[r];
                    if comp_of_slot[slot] == usize::MAX {
                        comp_of_slot[slot] = n_comp;
                        for &aj in &users[slot] {
                            if comp_of_act[aj] == usize::MAX {
                                comp_of_act[aj] = n_comp;
                                stack.push(aj);
                            }
                        }
                    }
                }
            }
            n_comp += 1;
        }

        // A component re-fills iff an event dirtied one of its resources
        // (any event that can change a sub-component's max-min solution
        // dirties a resource inside it: membership changes dirty the
        // changed activity's resources, capacity changes dirty the
        // target). Dirty components get dense indices in component order.
        let mut dirty_ix: Vec<usize> = vec![usize::MAX; n_comp];
        for slot in 0..used.len() {
            if self.res_dirty[used[slot]] {
                dirty_ix[comp_of_slot[slot]] = 0;
            }
        }
        let mut n_dirty = 0usize;
        for ix in dirty_ix.iter_mut() {
            if *ix != usize::MAX {
                *ix = n_dirty;
                n_dirty += 1;
            }
        }
        for &r in &self.dirty_res {
            self.res_dirty[r] = false;
        }
        self.dirty_res.clear();

        // Member lists per dirty component, ascending — the preserved
        // within-component order is what keeps the arithmetic
        // bit-identical to a full global solve.
        let mut comp_slots: Vec<Vec<usize>> = vec![Vec::new(); n_dirty];
        for slot in 0..used.len() {
            let ix = dirty_ix[comp_of_slot[slot]];
            if ix != usize::MAX {
                comp_slots[ix].push(slot);
            }
        }
        let mut comp_acts: Vec<Vec<usize>> = vec![Vec::new(); n_dirty];
        for ai in 0..active.len() {
            let ix = dirty_ix[comp_of_act[ai]];
            if ix != usize::MAX {
                comp_acts[ix].push(ai);
            }
        }

        self.n_resolves += 1;
        self.n_resources_touched +=
            comp_slots.iter().map(|s| s.len() as u64).sum::<u64>();

        let nt = self.threads.max(1).min(n_dirty.max(1));
        let activities = &self.activities;
        let resources = &self.resources;
        let res_slot = &self.res_slot;
        let active_ref = &active;
        let used_ref = &used;
        let users_ref = &users;
        let comp_acts_ref = &comp_acts;
        let comp_slots_ref = &comp_slots;
        let solve_shard = move |t: usize, nt: usize| -> Vec<(usize, f64)> {
            let mut out = Vec::new();
            let mut slot_local = vec![usize::MAX; used_ref.len()];
            let mut act_local = vec![usize::MAX; active_ref.len()];
            let mut ci = t;
            while ci < n_dirty {
                fill_component(
                    &comp_acts_ref[ci],
                    &comp_slots_ref[ci],
                    active_ref,
                    activities,
                    resources,
                    used_ref,
                    users_ref,
                    res_slot,
                    &mut slot_local,
                    &mut act_local,
                    &mut out,
                );
                ci += nt;
            }
            out
        };
        let updates: Vec<(usize, f64)> = if nt <= 1 {
            solve_shard(0, 1)
        } else {
            std::thread::scope(|s| {
                let solve_shard = &solve_shard;
                let handles: Vec<_> = (0..nt)
                    .map(|t| s.spawn(move || solve_shard(t, nt)))
                    .collect();
                // Join in spawn order; writes are disjoint, so the merge
                // order is immaterial to the result.
                let mut all = Vec::new();
                for h in handles {
                    all.extend(h.join().expect("fluid re-solve shard panicked"));
                }
                all
            })
        };

        for (ai, rate) in updates {
            self.activities[active[ai]].rate = rate;
        }
        self.active = active;
        self.dirty = false;
    }

    /// Advance to the next completion. Returns `(time, completed ids)`,
    /// or `None` when no activities remain.
    pub fn step(&mut self) -> Option<(f64, Vec<ActivityId>)> {
        self.step_until(f64::INFINITY)
    }

    /// Like [`FluidSim::step`], but never advance past `t_limit`: if the
    /// earliest completion lies beyond it, drain partial progress up to
    /// `t_limit` and return `Some((t_limit, vec![]))` — an empty
    /// completion batch signalling the limit was reached (so the caller
    /// can apply an external event and resume). With `t_limit =
    /// f64::INFINITY` this is exactly `step` (identical arithmetic).
    pub fn step_until(&mut self, t_limit: f64) -> Option<(f64, Vec<ActivityId>)> {
        self.active.retain(|&a| !self.activities[a].done);
        if self.active.is_empty() {
            return None;
        }
        if self.dirty {
            self.recompute_rates();
        }
        // Zero-work or zero-remaining activities complete immediately.
        let mut instant: Vec<ActivityId> = self
            .active
            .iter()
            .cloned()
            .filter(|&a| self.activities[a].remaining <= 1e-9)
            .collect();
        if !instant.is_empty() {
            for &a in &instant {
                self.activities[a].done = true;
                self.activities[a].remaining = 0.0;
            }
            self.dirty = true;
            for i in 0..instant.len() {
                self.mark_activity(instant[i]);
            }
            instant.sort_unstable();
            return Some((self.now, instant));
        }
        // Time to the earliest completion.
        let mut dt = f64::INFINITY;
        for &a in &self.active {
            let act = &self.activities[a];
            if act.rate > 0.0 {
                dt = dt.min(act.remaining / act.rate);
            }
        }
        assert!(
            dt.is_finite(),
            "deadlock: active activities with zero rate (resource starvation)"
        );
        if self.now + dt > t_limit {
            // The next completion lies beyond the limit: drain partial
            // progress and stop exactly at it (clock never regresses).
            let part = (t_limit - self.now).max(0.0);
            if part > 0.0 {
                for &a in &self.active {
                    let act = &mut self.activities[a];
                    act.remaining = (act.remaining - act.rate * part).max(0.0);
                }
            }
            self.now = self.now.max(t_limit);
            return Some((self.now, Vec::new()));
        }
        self.now += dt;
        let mut completed = Vec::new();
        for &a in &self.active {
            let act = &mut self.activities[a];
            act.remaining -= act.rate * dt;
            if act.remaining <= 1e-6 * act.rate.max(1.0) + 1e-12 {
                act.remaining = 0.0;
                act.done = true;
                completed.push(a);
            }
        }
        debug_assert!(!completed.is_empty());
        self.dirty = true;
        for i in 0..completed.len() {
            self.mark_activity(completed[i]);
        }
        completed.sort_unstable();
        Some((self.now, completed))
    }

    /// Run until all activities complete; returns the final virtual time.
    pub fn run_to_completion(&mut self) -> f64 {
        while self.step().is_some() {}
        self.now
    }

    /// Export the full dynamic state for checkpointing. Everything that
    /// influences future arithmetic is captured *verbatim* — including
    /// the `active` list order (component numbering follows first
    /// appearance along it), stored rates, the dirty flag/list and the
    /// perf counters — so a sim rebuilt via [`FluidSim::from_state`]
    /// continues bit-identically. Purely transient scratch (`res_stamp`,
    /// `res_slot`, `stamp`) is rebuilt from zero on every recompute and
    /// is not part of the state.
    pub(crate) fn export_state(&self) -> FluidState {
        FluidState {
            now: self.now,
            threads: self.threads,
            capacities: self.resources.iter().map(|r| r.capacity).collect(),
            activities: self
                .activities
                .iter()
                .map(|a| FluidActivityState {
                    remaining: a.remaining,
                    resources: a.resources.clone(),
                    done: a.done,
                    rate: a.rate,
                    tag: a.tag,
                })
                .collect(),
            active: self.active.clone(),
            dirty: self.dirty,
            dirty_res: self.dirty_res.clone(),
            n_resolves: self.n_resolves,
            n_resources_touched: self.n_resources_touched,
        }
    }

    /// Rebuild a simulator from exported state (see
    /// [`FluidSim::export_state`] for what exactness requires).
    pub(crate) fn from_state(st: &FluidState) -> Result<FluidSim, String> {
        let n = st.capacities.len();
        for (i, &c) in st.capacities.iter().enumerate() {
            if !(c > 0.0 && c.is_finite()) {
                return Err(format!("fluid state: resource {i} capacity {c} invalid"));
            }
        }
        let mut res_dirty = vec![false; n];
        for &r in &st.dirty_res {
            if r >= n {
                return Err(format!("fluid state: dirty resource {r} out of range"));
            }
            res_dirty[r] = true;
        }
        for (i, a) in st.activities.iter().enumerate() {
            if a.resources.is_empty() {
                return Err(format!("fluid state: activity {i} crosses no resources"));
            }
            for &r in &a.resources {
                if r >= n {
                    return Err(format!("fluid state: activity {i} resource {r} dangling"));
                }
            }
            if !(a.remaining >= 0.0 && a.remaining.is_finite()) {
                return Err(format!(
                    "fluid state: activity {i} remaining {} invalid",
                    a.remaining
                ));
            }
        }
        for &a in &st.active {
            if a >= st.activities.len() {
                return Err(format!("fluid state: active id {a} out of range"));
            }
        }
        Ok(FluidSim {
            resources: st.capacities.iter().map(|&capacity| Resource { capacity }).collect(),
            activities: st
                .activities
                .iter()
                .map(|a| Activity {
                    remaining: a.remaining,
                    resources: a.resources.clone(),
                    done: a.done,
                    rate: a.rate,
                    tag: a.tag,
                })
                .collect(),
            active: st.active.clone(),
            now: st.now,
            dirty: st.dirty,
            res_stamp: vec![0; n],
            res_slot: vec![0; n],
            stamp: 0,
            res_dirty,
            dirty_res: st.dirty_res.clone(),
            threads: st.threads,
            n_resolves: st.n_resolves,
            n_resources_touched: st.n_resources_touched,
        })
    }
}

/// One activity's exported state (see [`FluidSim::export_state`]).
#[derive(Debug, Clone)]
pub(crate) struct FluidActivityState {
    pub remaining: f64,
    pub resources: Vec<ResourceId>,
    pub done: bool,
    pub rate: f64,
    pub tag: u64,
}

/// Exported dynamic state of a [`FluidSim`], sufficient to continue the
/// simulation bit-identically. Produced by [`FluidSim::export_state`],
/// consumed by [`FluidSim::from_state`]; the snapshot codec
/// ([`super::snapshot`]) serializes it with bit-exact floats.
#[derive(Debug, Clone)]
pub(crate) struct FluidState {
    pub now: f64,
    pub threads: usize,
    pub capacities: Vec<f64>,
    pub activities: Vec<FluidActivityState>,
    /// Verbatim copy of the not-yet-pruned active list: its *order*
    /// drives component numbering, and stale (done) entries are pruned
    /// lazily — both must survive the round trip.
    pub active: Vec<ActivityId>,
    pub dirty: bool,
    pub dirty_res: Vec<ResourceId>,
    pub n_resolves: u64,
    pub n_resources_touched: u64,
}

/// Progressive filling (lazy-heap form) over one connected component.
/// `acts` / `slots` are the component's members — indices into `active` /
/// `used` — in ascending order; `slot_local` / `act_local` are caller
/// scratch (only entries belonging to this component are written, and
/// only those are read, so the scratch needs no clearing between
/// components). Appends `(active-index, rate)` pairs to `out`.
///
/// The arithmetic — share values, freeze order, charge order, stale-entry
/// re-pushes — is exactly the global algorithm restricted to the
/// component: local slot/activity indices preserve the global relative
/// order, and components never interact, which is what makes incremental
/// and sharded solves bit-identical to a full solve.
#[allow(clippy::too_many_arguments)]
fn fill_component(
    acts: &[usize],
    slots: &[usize],
    active: &[ActivityId],
    activities: &[Activity],
    resources: &[Resource],
    used: &[ResourceId],
    users: &[Vec<usize>],
    res_slot: &[usize],
    slot_local: &mut [usize],
    act_local: &mut [usize],
    out: &mut Vec<(usize, f64)>,
) {
    for (ls, &slot) in slots.iter().enumerate() {
        slot_local[slot] = ls;
    }
    for (la, &ai) in acts.iter().enumerate() {
        act_local[ai] = la;
    }
    let mut remaining_cap: Vec<f64> =
        slots.iter().map(|&s| resources[used[s]].capacity).collect();
    let mut unfrozen_count: Vec<usize> = slots.iter().map(|&s| users[s].len()).collect();
    let mut rate: Vec<f64> = vec![f64::INFINITY; acts.len()];
    let mut frozen: Vec<bool> = vec![false; acts.len()];
    let mut n_frozen = 0usize;

    let mut heap: BinaryHeap<Reverse<ShareEntry>> =
        BinaryHeap::with_capacity(slots.len());
    for ls in 0..slots.len() {
        if unfrozen_count[ls] > 0 {
            heap.push(Reverse(ShareEntry {
                share: remaining_cap[ls] / unfrozen_count[ls] as f64,
                slot: ls,
            }));
        }
    }
    while n_frozen < acts.len() {
        let Some(Reverse(entry)) = heap.pop() else { break };
        let ls = entry.slot;
        if unfrozen_count[ls] == 0 {
            continue; // fully frozen since the entry was pushed
        }
        let share = (remaining_cap[ls].max(0.0)) / unfrozen_count[ls] as f64;
        if share > entry.share {
            // Stale: freezes elsewhere released capacity per user;
            // re-queue at the current (larger) share.
            heap.push(Reverse(ShareEntry { share, slot: ls }));
            continue;
        }
        // This resource is the bottleneck: freeze its unfrozen users.
        let us: Vec<usize> = users[slots[ls]]
            .iter()
            .map(|&ai| act_local[ai])
            .filter(|&la| !frozen[la])
            .collect();
        for la in us {
            frozen[la] = true;
            n_frozen += 1;
            rate[la] = share;
            // Charge this activity to all its resources.
            for &r2 in &activities[active[acts[la]]].resources {
                let ls2 = slot_local[res_slot[r2]];
                remaining_cap[ls2] -= share;
                unfrozen_count[ls2] -= 1;
                if ls2 != ls && unfrozen_count[ls2] > 0 {
                    heap.push(Reverse(ShareEntry {
                        share: (remaining_cap[ls2].max(0.0))
                            / unfrozen_count[ls2] as f64,
                        slot: ls2,
                    }));
                }
            }
        }
        remaining_cap[ls] = remaining_cap[ls].max(0.0);
    }
    for (la, &ai) in acts.iter().enumerate() {
        out.push((ai, rate[la]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_activity_single_resource() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(100.0, vec![r]);
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![a]);
        assert!((t - 10.0).abs() < 1e-9);
        assert!(sim.step().is_none());
    }

    #[test]
    fn two_activities_share_fairly() {
        // Two activities on one 10-unit/s resource, 100 units each:
        // both run at 5/s and finish together at t=20.
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(100.0, vec![r]);
        let b = sim.add_activity(100.0, vec![r]);
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![a, b]);
        assert!((t - 20.0).abs() < 1e-9);
    }

    #[test]
    fn released_capacity_speeds_up_survivor() {
        // a: 50 units, b: 100 units, shared 10/s resource.
        // Phase 1: both at 5/s → a done at t=10 (b has 50 left).
        // Phase 2: b alone at 10/s → done at t=15.
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(50.0, vec![r]);
        let b = sim.add_activity(100.0, vec![r]);
        let (t1, d1) = sim.step().unwrap();
        assert_eq!(d1, vec![a]);
        assert!((t1 - 10.0).abs() < 1e-9);
        let (t2, d2) = sim.step().unwrap();
        assert_eq!(d2, vec![b]);
        assert!((t2 - 15.0).abs() < 1e-9);
    }

    #[test]
    fn bottleneck_is_min_over_resources() {
        // Activity crosses fast (100/s) and slow (5/s) resources:
        // rate = 5/s.
        let mut sim = FluidSim::new();
        let fast = sim.add_resource(100.0);
        let slow = sim.add_resource(5.0);
        let a = sim.add_activity(50.0, vec![fast, slow]);
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![a]);
        assert!((t - 10.0).abs() < 1e-9);
    }

    #[test]
    fn max_min_fairness_with_asymmetric_demands() {
        // Resource R1 (cap 10) carries flows A, B; resource R2 (cap 2)
        // carries flow B only (its bottleneck). Max-min: B gets 2,
        // A gets 8.
        let mut sim = FluidSim::new();
        let r1 = sim.add_resource(10.0);
        let r2 = sim.add_resource(2.0);
        let a = sim.add_activity(80.0, vec![r1]);
        let b = sim.add_activity(20.0, vec![r1, r2]);
        sim.recompute_rates();
        assert!((sim.rate(a) - 8.0).abs() < 1e-9);
        assert!((sim.rate(b) - 2.0).abs() < 1e-9);
        let (t, done) = sim.step().unwrap();
        // both finish at t = 10 exactly (80/8 = 20/2)
        assert_eq!(done.len(), 2);
        assert!((t - 10.0).abs() < 1e-9);
    }

    #[test]
    fn cancel_releases_capacity() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(100.0, vec![r]);
        let b = sim.add_activity(100.0, vec![r]);
        sim.cancel(a);
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![b]);
        assert!((t - 10.0).abs() < 1e-9, "b should run alone at 10/s");
    }

    #[test]
    fn zero_work_completes_instantly() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(0.0, vec![r]);
        let b = sim.add_activity(10.0, vec![r]);
        let (t, done) = sim.step().unwrap();
        assert_eq!((t, done), (0.0, vec![a]));
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![b]);
        assert!((t - 1.0).abs() < 1e-9);
    }

    #[test]
    fn staged_arrivals_advance_clock_monotonically() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(1.0);
        sim.add_activity(5.0, vec![r]);
        let (t1, _) = sim.step().unwrap();
        // New work arrives after the first completes.
        sim.add_activity(3.0, vec![r]);
        let (t2, _) = sim.step().unwrap();
        assert!(t2 > t1);
        assert!((t2 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn run_to_completion_drains_everything() {
        let mut sim = FluidSim::new();
        let r1 = sim.add_resource(3.0);
        let r2 = sim.add_resource(7.0);
        for i in 0..20 {
            let res = if i % 2 == 0 { vec![r1] } else { vec![r2] };
            sim.add_activity((i + 1) as f64, res);
        }
        let t = sim.run_to_completion();
        assert!(t > 0.0);
        for i in 0..20 {
            assert!(sim.is_done(i));
        }
    }

    /// Three-level bottleneck chain: the lazy heap must refresh shares
    /// as freezes release capacity (the stale-entry path).
    #[test]
    fn progressive_filling_multi_round() {
        // R1 cap 6 carries {a, b, c}; R2 cap 1 carries {a}; R3 cap 2
        // carries {b}. Max-min: a=1 (R2), b=2 (R3), c=3 (R1 leftover).
        let mut sim = FluidSim::new();
        let r1 = sim.add_resource(6.0);
        let r2 = sim.add_resource(1.0);
        let r3 = sim.add_resource(2.0);
        let a = sim.add_activity(10.0, vec![r1, r2]);
        let b = sim.add_activity(10.0, vec![r1, r3]);
        let c = sim.add_activity(10.0, vec![r1]);
        sim.recompute_rates();
        assert!((sim.rate(a) - 1.0).abs() < 1e-9, "a at {}", sim.rate(a));
        assert!((sim.rate(b) - 2.0).abs() < 1e-9, "b at {}", sim.rate(b));
        assert!((sim.rate(c) - 3.0).abs() < 1e-9, "c at {}", sim.rate(c));
    }

    /// A capacity change mid-run re-solves the max-min allocation: the
    /// surviving work drains at the new rate from the change point.
    #[test]
    fn set_capacity_rescales_inflight_work() {
        // 100 units on a 10/s resource; at t=5 (50 left) the link halves
        // to 5/s → completion at t = 5 + 50/5 = 15.
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(100.0, vec![r]);
        let (t, done) = sim.step_until(5.0).unwrap();
        assert!(done.is_empty(), "no completion before t=5");
        assert!((t - 5.0).abs() < 1e-9);
        assert!((sim.remaining(a) - 50.0).abs() < 1e-9);
        sim.set_capacity(r, 5.0);
        assert_eq!(sim.capacity(r), 5.0);
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![a]);
        assert!((t - 15.0).abs() < 1e-9, "completed at {t}");
    }

    /// step_until at exactly the completion time delivers the completion
    /// (not an empty limit batch), and an infinite limit is plain step.
    #[test]
    fn step_until_boundary_and_infinity() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(100.0, vec![r]);
        let (t, done) = sim.step_until(10.0).unwrap();
        assert_eq!(done, vec![a], "completion exactly at the limit fires");
        assert!((t - 10.0).abs() < 1e-9);
        let b = sim.add_activity(20.0, vec![r]);
        let (t, done) = sim.step_until(f64::INFINITY).unwrap();
        assert_eq!(done, vec![b]);
        assert!((t - 12.0).abs() < 1e-9);
    }

    /// Chopping a run into many step_until segments conserves total work
    /// and the clock (the dynamics interleaving path).
    #[test]
    fn step_until_segments_conserve_completion_time() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(4.0);
        let a = sim.add_activity(100.0, vec![r]);
        let mut limit = 3.0;
        loop {
            let (t, done) = sim.step_until(limit).unwrap();
            if !done.is_empty() {
                assert_eq!(done, vec![a]);
                assert!((t - 25.0).abs() < 1e-6, "completed at {t}");
                break;
            }
            limit += 3.0;
        }
        assert!(sim.step().is_none());
    }

    /// Tags route completions without perturbing the allocation.
    #[test]
    fn tags_are_inert_and_retrievable() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(10.0);
        let a = sim.add_activity(100.0, vec![r]);
        let b = sim.add_activity_tagged(100.0, vec![r], 42);
        assert_eq!(sim.tag(a), 0);
        assert_eq!(sim.tag(b), 42);
        let (t, done) = sim.step().unwrap();
        assert_eq!(done, vec![a, b]);
        assert!((t - 20.0).abs() < 1e-9, "tags must not change fair shares");
    }

    #[test]
    fn jump_to_only_moves_forward() {
        let mut sim = FluidSim::new();
        sim.jump_to(7.0);
        assert_eq!(sim.now(), 7.0);
        sim.jump_to(3.0);
        assert_eq!(sim.now(), 7.0, "clock never regresses");
    }

    /// A disjoint component keeps its rates without being re-filled: the
    /// touched-resource counter grows only by the dirty component.
    #[test]
    fn incremental_skips_clean_components() {
        let mut sim = FluidSim::new();
        let r1 = sim.add_resource(10.0);
        let r2 = sim.add_resource(4.0);
        let a = sim.add_activity(100.0, vec![r1]);
        sim.recompute_rates();
        assert_eq!(sim.resolves(), 1);
        assert_eq!(sim.resources_touched(), 1);
        let b = sim.add_activity(100.0, vec![r2]);
        sim.recompute_rates();
        // Only b's component was re-filled; a's rate is kept.
        assert_eq!(sim.resolves(), 2);
        assert_eq!(sim.resources_touched(), 2);
        assert!((sim.rate(a) - 10.0).abs() < 1e-12);
        assert!((sim.rate(b) - 4.0).abs() < 1e-12);
    }

    /// `set_capacity` re-fills exactly the component of its resource.
    #[test]
    fn set_capacity_refills_only_its_component() {
        let mut sim = FluidSim::new();
        let r1 = sim.add_resource(10.0);
        let r2 = sim.add_resource(4.0);
        let a = sim.add_activity(100.0, vec![r1]);
        let b = sim.add_activity(100.0, vec![r2]);
        sim.recompute_rates();
        assert_eq!(sim.resources_touched(), 2);
        sim.set_capacity(r2, 8.0);
        sim.recompute_rates();
        assert_eq!(sim.resources_touched(), 3, "only r2's component re-filled");
        assert!((sim.rate(a) - 10.0).abs() < 1e-12);
        assert!((sim.rate(b) - 8.0).abs() < 1e-12);
    }

    /// A completion dirties its resources, so the survivor's component
    /// re-fills while disjoint components are skipped.
    #[test]
    fn completion_refills_shared_component_only() {
        let mut sim = FluidSim::new();
        let shared = sim.add_resource(10.0);
        let solo = sim.add_resource(3.0);
        sim.add_activity(50.0, vec![shared]);
        let b = sim.add_activity(100.0, vec![shared]);
        let c = sim.add_activity(300.0, vec![solo]);
        let (_, done) = sim.step().unwrap();
        assert_eq!(done.len(), 1);
        let touched_before = sim.resources_touched();
        sim.recompute_rates();
        // Only the shared resource's component re-fills (1 resource).
        assert_eq!(sim.resources_touched(), touched_before + 1);
        assert!((sim.rate(b) - 10.0).abs() < 1e-12);
        assert!((sim.rate(c) - 3.0).abs() < 1e-12);
    }

    /// The sharded parallel re-solve is bit-identical to sequential for
    /// every thread count, on a randomized mesh of overlapping
    /// activities with mid-run events.
    #[test]
    fn thread_counts_are_bit_identical() {
        use crate::util::rng::Pcg64;
        let run = |threads: usize| -> (u64, Vec<u64>) {
            let mut rng = Pcg64::new(0xF1D0);
            let mut sim = FluidSim::new();
            sim.set_threads(threads);
            let rs: Vec<ResourceId> =
                (0..16).map(|i| sim.add_resource(1.0 + (i % 5) as f64)).collect();
            let mut times = Vec::new();
            for round in 0..30 {
                // 1–3 new activities over random resource subsets.
                for _ in 0..rng.range(1, 4) {
                    let k = rng.range(1, 4);
                    let mut res: Vec<ResourceId> =
                        (0..k).map(|_| rs[rng.range(0, rs.len())]).collect();
                    res.sort_unstable();
                    res.dedup();
                    sim.add_activity(rng.uniform(1.0, 20.0), res);
                }
                if round % 7 == 3 {
                    let r = rs[rng.range(0, rs.len())];
                    sim.set_capacity(r, rng.uniform(0.5, 6.0));
                }
                let (t, done) = sim.step().unwrap();
                times.push(t.to_bits());
                for a in done {
                    assert!(sim.is_done(a));
                }
            }
            (sim.run_to_completion().to_bits(), times)
        };
        let base = run(1);
        for threads in [2usize, 4, 8] {
            assert_eq!(run(threads), base, "threads={threads} diverged");
        }
    }

    #[test]
    #[should_panic(expected = "thread count must be >= 1")]
    fn zero_threads_rejected() {
        FluidSim::new().set_threads(0);
    }

    /// Export/restore mid-run must continue bit-identically: run a mesh
    /// halfway, snapshot, and compare the restored sim's remaining event
    /// times bit-for-bit against the uninterrupted one.
    #[test]
    fn export_restore_continues_bit_identically() {
        use crate::util::rng::Pcg64;
        let build = || -> FluidSim {
            let mut rng = Pcg64::new(0xC0FFEE);
            let mut sim = FluidSim::new();
            let rs: Vec<ResourceId> =
                (0..10).map(|i| sim.add_resource(1.0 + (i % 4) as f64)).collect();
            for round in 0..25 {
                for _ in 0..rng.range(1, 4) {
                    let k = rng.range(1, 4);
                    let mut res: Vec<ResourceId> =
                        (0..k).map(|_| rs[rng.range(0, rs.len())]).collect();
                    res.sort_unstable();
                    res.dedup();
                    sim.add_activity(rng.uniform(1.0, 15.0), res);
                }
                if round % 5 == 2 {
                    sim.set_capacity(rs[rng.range(0, rs.len())], rng.uniform(0.5, 5.0));
                }
                if round < 12 {
                    sim.step().unwrap();
                }
            }
            sim
        };
        let drain = |sim: &mut FluidSim| -> Vec<u64> {
            let mut out = Vec::new();
            while let Some((t, done)) = sim.step() {
                out.push(t.to_bits());
                out.extend(done.iter().map(|&d| d as u64));
            }
            out
        };
        let mut baseline = build();
        let mut restored = FluidSim::from_state(&baseline.export_state()).unwrap();
        assert_eq!(restored.now().to_bits(), baseline.now().to_bits());
        assert_eq!(restored.resolves(), baseline.resolves());
        assert_eq!(drain(&mut restored), drain(&mut baseline));
        assert_eq!(restored.resolves(), baseline.resolves());
        assert_eq!(restored.resources_touched(), baseline.resources_touched());
    }

    #[test]
    fn from_state_rejects_dangling_references() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(1.0);
        sim.add_activity(5.0, vec![r]);
        let good = sim.export_state();

        let mut bad = good.clone();
        bad.activities[0].resources = vec![7];
        assert!(FluidSim::from_state(&bad).is_err());

        let mut bad = good.clone();
        bad.active = vec![9];
        assert!(FluidSim::from_state(&bad).is_err());

        let mut bad = good.clone();
        bad.capacities[0] = 0.0;
        assert!(FluidSim::from_state(&bad).is_err());

        let mut bad = good;
        bad.dirty_res = vec![3];
        assert!(FluidSim::from_state(&bad).is_err());
    }

    /// Many short sequential activities: the maintained active set keeps
    /// stepping cheap and the clock strictly ordered.
    #[test]
    fn long_run_active_set_stays_consistent() {
        let mut sim = FluidSim::new();
        let r = sim.add_resource(2.0);
        let mut last = 0.0;
        for round in 0..200 {
            sim.add_activity(1.0 + (round % 3) as f64, vec![r]);
            let (t, done) = sim.step().unwrap();
            assert!(t >= last);
            last = t;
            assert_eq!(done.len(), 1);
            assert_eq!(sim.active_count(), 0);
        }
    }
}
